"""Layer-2 JAX model: the composed coflow scorer.

``scorer`` is the compute graph the rust coordinator executes per scoring
batch: size estimation (L1 estimator kernel), contention (L1 contention
kernel), and the final contention-adjusted shortest-first priority score.
Lowered once by ``aot.py``; never run from python at serving time.
"""

import jax.numpy as jnp

from .kernels import contention_pallas, estimator_pallas


def scorer(sizes, mask, nflows, w, done, occ, weight):
    """Full scoring pipeline over a padded coflow batch.

    Args:
      sizes:  [C, M]  completed pilot-flow sizes (bytes), zero-padded.
      mask:   [C, M]  1.0 for valid pilot slots.
      nflows: [C]     number of flows per coflow.
      w:      [C,B,M] pre-normalized bootstrap resample weights.
      done:   [C]     bytes of completed flows per coflow.
      occ:    [C, P]  port-occupancy matrix (up/down halves).
      weight: []      contention weight (SchedulerConfig::contention_weight).

    Returns:
      (score, est, lcb, contention) — each [C] float32. Lower score = higher
      priority (shortest contention-adjusted remaining size first).
    """
    est, lcb = estimator_pallas(sizes, mask, nflows, w)
    cont = contention_pallas(occ)
    score = jnp.maximum(est - done, 0.0) * (1.0 + weight * cont)
    return score, est, lcb, cont


def estimator_only(sizes, mask, nflows, w):
    """Estimator artifact entry point."""
    return estimator_pallas(sizes, mask, nflows, w)


def contention_only(occ):
    """Contention artifact entry point (1-tuple for uniform unpacking)."""
    return (contention_pallas(occ),)
