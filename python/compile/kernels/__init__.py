"""Layer-1 Pallas kernels for the Philae coordinator's scoring math.

Fixed AOT shapes (must match ``rust/src/runtime``):

* ``C``  — coflow batch (padded)
* ``M``  — max pilot flows per coflow (SchedulerConfig::pilot_max upper bound)
* ``B``  — bootstrap resamples
* ``P``  — max ports
"""

C = 128
M = 16
B = 100
P = 2048  # port-direction axis: uplinks [0, P/2), downlinks [P/2, P)
LCB_SIGMAS = 3.0

from .estimator import estimator_pallas  # noqa: E402,F401
from .contention import contention_pallas  # noqa: E402,F401
