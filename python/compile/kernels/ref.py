"""Pure-jnp correctness oracles for the L1 kernels.

These are the definitions the pytest/hypothesis suites check the Pallas
kernels against, and the math the rust-native fallback scorer mirrors
(``rust/src/coordinator/{philae,errcorr}.rs``).
"""

import jax.numpy as jnp

from . import LCB_SIGMAS


def estimator_ref(sizes, mask, nflows, w):
    """Masked-mean size estimate + bootstrap LCB. Shapes: sizes/mask [C,M],
    nflows [C], w [C,B,M] (pre-normalized resample weights)."""
    sizes = jnp.asarray(sizes, jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    nflows = jnp.asarray(nflows, jnp.float32)
    w = jnp.asarray(w, jnp.float32)

    masked = sizes * mask
    cnt = jnp.maximum(mask.sum(-1), 1.0)
    mean = masked.sum(-1) / cnt
    est = mean * nflows

    boot = jnp.einsum("cbm,cm->cb", w, masked)
    boot_mean = boot.mean(-1)
    boot_var = jnp.maximum((boot * boot).mean(-1) - boot_mean**2, 0.0)
    lcb = jnp.maximum((mean - LCB_SIGMAS * jnp.sqrt(boot_var)) * nflows, 1.0)
    return est, lcb


def contention_ref(occ):
    """Average extra sharers per occupied port. occ: [C,P] in {0,1}."""
    occ = jnp.asarray(occ, jnp.float32)
    co = occ @ occ.T
    total = co.sum(-1)
    self_overlap = (occ * occ).sum(-1)
    width = occ.sum(-1)
    return jnp.where(width > 0.0, (total - self_overlap) / jnp.maximum(width, 1.0), 0.0)


def score_ref(est, done, contention, weight):
    """Philae priority score: contention-adjusted estimated remaining."""
    est = jnp.asarray(est, jnp.float32)
    done = jnp.asarray(done, jnp.float32)
    contention = jnp.asarray(contention, jnp.float32)
    return jnp.maximum(est - done, 0.0) * (1.0 + weight * contention)
