"""Coflow-contention kernel.

Input: the coflow×port occupancy matrix ``occ`` (``occ[c,p] = 1`` iff
coflow ``c`` has unfinished flows at port ``p``; uplinks and downlinks are
two halves of the padded port axis). Output per coflow: the average number
of *other* active coflows sharing each of its occupied ports —

    contention[c] = (Σ_{c'≠c} Σ_p occ[c,p]·occ[c',p]) / Σ_p occ[c,p]

The numerator is a row-sum of ``occ·occᵀ`` minus the diagonal, i.e. one
``[BC,P]×[P,C]`` matmul per block — the MXU-shaped formulation the paper's
coordinator math reduces to (DESIGN.md §Hardware-Adaptation).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import C, P

BC = 32  # coflow block


def _contention_kernel(occ_blk_ref, occ_all_ref, out_ref):
    occ = occ_blk_ref[...]  # [BC, P] — this block's coflows
    occ_all = occ_all_ref[...]  # [C, P] — everyone (for the co-occupancy matmul)

    co = jnp.dot(occ, occ_all.T)  # [BC, C] co-occupancy counts
    total = co.sum(axis=-1)  # includes self-overlap
    self_overlap = (occ * occ).sum(axis=-1)
    width = occ.sum(axis=-1)
    out_ref[...] = jnp.where(
        width > 0.0, (total - self_overlap) / jnp.maximum(width, 1.0), 0.0
    )


def contention_pallas(occ):
    """Per-coflow contention from a padded ``[C, P]`` occupancy matrix."""
    assert occ.shape == (C, P)
    grid = (C // BC,)
    return pl.pallas_call(
        _contention_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BC, P), lambda i: (i, 0)),
            pl.BlockSpec((C, P), lambda i: (0, 0)),  # broadcast full matrix
        ],
        out_specs=pl.BlockSpec((BC,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((C,), jnp.float32),
        interpret=True,
    )(occ, occ)
