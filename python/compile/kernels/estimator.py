"""Batched sampling estimator + bootstrap lower-confidence-bound kernel.

For each coflow ``c`` in a padded batch of ``C``:

* ``mean_c``  = masked mean of its completed pilot-flow sizes
* ``est_c``   = ``mean_c × num_flows_c``  (Philae's size estimate, §2)
* ``boot_cb`` = ``Σ_m W[c,b,m]·sizes[c,m]`` — the b-th bootstrap resample
  mean, where the host pre-normalizes the resample-count matrix ``W``
  (counts/m, zero for invalid slots). Keeping the RNG on the host keeps the
  kernel deterministic and lets the rust coordinator reproduce the exact
  stream (SmallRng) used by the native fallback path.
* ``lcb_c``   = ``max((mean_c − 3σ_boot)·num_flows_c, 1)`` — the §2.2
  error-correction variants' estimate.

TPU mapping: the batch dimension is tiled into ``BC``-coflow blocks (VMEM
residency: sizes/mask ``BC×M`` + W ``BC×B×M`` ≈ 6400·BC floats); the
bootstrap contraction is a ``[B,M]×[M]`` batched matmul feeding the MXU.
``interpret=True`` everywhere on this CPU-only image (see DESIGN.md
§Hardware-Adaptation).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import C, M, B, LCB_SIGMAS

BC = 32  # coflow block


def _estimator_kernel(sizes_ref, mask_ref, nflows_ref, w_ref, est_ref, lcb_ref):
    sizes = sizes_ref[...]  # [BC, M]
    mask = mask_ref[...]  # [BC, M]
    nflows = nflows_ref[...]  # [BC]
    w = w_ref[...]  # [BC, B, M]

    masked = sizes * mask
    cnt = jnp.maximum(mask.sum(axis=-1), 1.0)
    mean = masked.sum(axis=-1) / cnt  # [BC]
    est = mean * nflows

    # bootstrap resample means: W is pre-normalized so this is a plain
    # batched contraction (MXU-friendly).
    boot = jnp.einsum("cbm,cm->cb", w, masked)  # [BC, B]
    boot_mean = boot.mean(axis=-1)
    boot_var = jnp.maximum((boot * boot).mean(axis=-1) - boot_mean * boot_mean, 0.0)
    sigma = jnp.sqrt(boot_var)
    lcb = jnp.maximum((mean - LCB_SIGMAS * sigma) * nflows, 1.0)

    est_ref[...] = est
    lcb_ref[...] = lcb


def estimator_pallas(sizes, mask, nflows, w):
    """Pallas-tiled estimator over a padded [C, M] batch.

    Returns ``(est, lcb)``, each ``[C]`` float32.
    """
    assert sizes.shape == (C, M) and mask.shape == (C, M)
    assert nflows.shape == (C,) and w.shape == (C, B, M)
    grid = (C // BC,)
    return pl.pallas_call(
        _estimator_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BC, M), lambda i: (i, 0)),
            pl.BlockSpec((BC, M), lambda i: (i, 0)),
            pl.BlockSpec((BC,), lambda i: (i,)),
            pl.BlockSpec((BC, B, M), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BC,), lambda i: (i,)),
            pl.BlockSpec((BC,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C,), jnp.float32),
            jax.ShapeDtypeStruct((C,), jnp.float32),
        ],
        interpret=True,
    )(sizes, mask, nflows, w)
