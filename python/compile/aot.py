"""AOT lowering: jax → HLO **text** artifacts for the rust PJRT runtime.

Interchange is HLO text, NOT ``lowered.compile().serialize()`` — jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the ``xla`` crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text
parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/gen_hlo.py and README gotchas).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits ``scorer.hlo.txt``, ``estimator.hlo.txt``, ``contention.hlo.txt`` and
``manifest.json`` (the fixed shapes the rust side must pad to).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import B, C, LCB_SIGMAS, M, P


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for uniform
    unpacking on the rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


ARTIFACTS = {
    "scorer": (
        model.scorer,
        [
            _spec((C, M)),  # sizes
            _spec((C, M)),  # mask
            _spec((C,)),  # nflows
            _spec((C, B, M)),  # w
            _spec((C,)),  # done
            _spec((C, P)),  # occ
            _spec(()),  # weight
        ],
    ),
    "estimator": (
        model.estimator_only,
        [_spec((C, M)), _spec((C, M)), _spec((C,)), _spec((C, B, M))],
    ),
    "contention": (model.contention_only, [_spec((C, P))]),
}


def build(out_dir: str, only=None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "C": C,
        "M": M,
        "B": B,
        "P": P,
        "lcb_sigmas": LCB_SIGMAS,
        "artifacts": {},
        "format": "hlo-text",
    }
    for name, (fn, specs) in ARTIFACTS.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [list(s.shape) for s in specs],
            "chars": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(out_dir, 'manifest.json')}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of artifact names")
    # kept for the original Makefile interface (single-file output)
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    build(out_dir or ".", args.only)


if __name__ == "__main__":
    main()
