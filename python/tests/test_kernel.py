"""Pallas kernels vs pure-jnp oracle — the core correctness signal,
including hypothesis sweeps over pilot counts, masks, skew, and occupancy
patterns (the shapes themselves are AOT-fixed; the sweeps cover contents
and degenerate fill patterns)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import B, C, LCB_SIGMAS, M, P, contention_pallas, estimator_pallas
from compile.kernels.ref import contention_ref, estimator_ref, score_ref
from compile import model


def make_w(rng, counts):
    """Host-side bootstrap weight matrix: W[c,b,m] = (#times slot m drawn)/m_c
    over m_c valid slots, zero when the coflow has no pilots."""
    w = np.zeros((C, B, M), np.float32)
    for c, mc in enumerate(counts):
        if mc == 0:
            continue
        idx = rng.integers(0, mc, size=(B, mc))
        for b in range(B):
            cnt = np.bincount(idx[b], minlength=M).astype(np.float32)
            w[c, b] = cnt / mc
    return w


def random_batch(seed, max_pilots=M):
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, max_pilots + 1, size=C)
    sizes = np.zeros((C, M), np.float32)
    mask = np.zeros((C, M), np.float32)
    for c, mc in enumerate(counts):
        sizes[c, :mc] = rng.lognormal(3.0, 1.5, mc).astype(np.float32)
        mask[c, :mc] = 1.0
    nflows = rng.integers(1, 5000, size=C).astype(np.float32)
    w = make_w(rng, counts)
    return sizes, mask, nflows, w, counts


class TestEstimator:
    def test_matches_ref_random(self):
        sizes, mask, nflows, w, _ = random_batch(0)
        est_k, lcb_k = estimator_pallas(sizes, mask, nflows, w)
        est_r, lcb_r = estimator_ref(sizes, mask, nflows, w)
        np.testing.assert_allclose(est_k, est_r, rtol=1e-5)
        # the f32 E[x²]−μ² variance is cancellation-prone; kernel and ref
        # reduce in different orders, so the LCB tolerance is looser
        np.testing.assert_allclose(lcb_k, lcb_r, rtol=1e-3)

    def test_mean_times_nflows(self):
        sizes = np.zeros((C, M), np.float32)
        mask = np.zeros((C, M), np.float32)
        sizes[0, :4] = [10, 20, 30, 40]
        mask[0, :4] = 1
        nflows = np.ones(C, np.float32)
        nflows[0] = 100
        w = np.zeros((C, B, M), np.float32)
        est, _ = estimator_pallas(sizes, mask, nflows, w)
        assert est[0] == pytest.approx(25.0 * 100)

    def test_zero_pilots_padded_rows(self):
        sizes = np.zeros((C, M), np.float32)
        mask = np.zeros((C, M), np.float32)
        nflows = np.ones(C, np.float32)
        w = np.zeros((C, B, M), np.float32)
        est, lcb = estimator_pallas(sizes, mask, nflows, w)
        np.testing.assert_allclose(est, 0.0)
        np.testing.assert_allclose(lcb, 1.0)  # floored

    def test_identical_samples_zero_sigma(self):
        rng = np.random.default_rng(1)
        sizes = np.zeros((C, M), np.float32)
        mask = np.zeros((C, M), np.float32)
        sizes[:, :5] = 7.0
        mask[:, :5] = 1.0
        nflows = np.full(C, 10.0, np.float32)
        w = make_w(rng, np.full(C, 5))
        est, lcb = estimator_pallas(sizes, mask, nflows, w)
        np.testing.assert_allclose(est, 70.0, rtol=1e-6)
        # zero variance → LCB == mean estimate
        np.testing.assert_allclose(lcb, 70.0, rtol=1e-5)

    def test_lcb_below_estimate_with_skew(self):
        rng = np.random.default_rng(2)
        counts = np.full(C, 8)
        sizes = np.zeros((C, M), np.float32)
        mask = np.zeros((C, M), np.float32)
        sizes[:, :8] = rng.lognormal(2.0, 2.0, (C, 8)).astype(np.float32)
        mask[:, :8] = 1.0
        nflows = np.full(C, 50.0, np.float32)
        w = make_w(rng, counts)
        est, lcb = estimator_pallas(sizes, mask, nflows, w)
        assert (lcb <= est + 1e-3).all()
        assert (lcb < est).sum() > C // 2  # skewed sample ⇒ real σ

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_hypothesis_matches_ref(self, seed):
        sizes, mask, nflows, w, _ = random_batch(seed)
        est_k, lcb_k = estimator_pallas(sizes, mask, nflows, w)
        est_r, lcb_r = estimator_ref(sizes, mask, nflows, w)
        np.testing.assert_allclose(est_k, est_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(lcb_k, lcb_r, rtol=1e-3, atol=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 1000),
        scale=st.floats(1e-3, 1e6),
    )
    def test_scale_equivariance(self, seed, scale):
        """est and lcb scale linearly with flow sizes."""
        sizes, mask, nflows, w, _ = random_batch(seed, max_pilots=6)
        est1, lcb1 = estimator_ref(sizes, mask, nflows, w)
        est2, lcb2 = estimator_ref(sizes * scale, mask, nflows, w)
        np.testing.assert_allclose(est2, np.asarray(est1) * scale, rtol=1e-3)
        # lcb floors at 1.0 and its f32 variance is cancellation-prone, so
        # only compare comfortably un-floored entries, loosely
        unfloored = (np.asarray(lcb1) > 2.0) & (np.asarray(lcb2) > 2.0)
        np.testing.assert_allclose(
            np.asarray(lcb2)[unfloored],
            (np.asarray(lcb1) * scale)[unfloored],
            rtol=1e-2,
        )


class TestContention:
    def test_matches_ref_random(self):
        rng = np.random.default_rng(0)
        occ = (rng.random((C, P)) < 0.05).astype(np.float32)
        np.testing.assert_allclose(
            contention_pallas(occ), contention_ref(occ), rtol=1e-5, atol=1e-5
        )

    def test_disjoint_coflows_zero_contention(self):
        occ = np.zeros((C, P), np.float32)
        for c in range(8):
            occ[c, c * 4 : c * 4 + 4] = 1.0
        cont = np.asarray(contention_pallas(occ))
        np.testing.assert_allclose(cont[:8], 0.0)

    def test_fully_overlapping_pair(self):
        occ = np.zeros((C, P), np.float32)
        occ[0, :10] = 1.0
        occ[1, :10] = 1.0
        cont = np.asarray(contention_pallas(occ))
        assert cont[0] == pytest.approx(1.0)
        assert cont[1] == pytest.approx(1.0)

    def test_partial_overlap(self):
        occ = np.zeros((C, P), np.float32)
        occ[0, :4] = 1.0  # ports 0-3
        occ[1, 2:6] = 1.0  # ports 2-5: shares 2 of its 4 ports
        cont = np.asarray(contention_pallas(occ))
        assert cont[0] == pytest.approx(0.5)
        assert cont[1] == pytest.approx(0.5)

    def test_empty_rows_zero(self):
        occ = np.zeros((C, P), np.float32)
        cont = np.asarray(contention_pallas(occ))
        np.testing.assert_allclose(cont, 0.0)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), density=st.floats(0.0, 0.3))
    def test_hypothesis_matches_ref(self, seed, density):
        rng = np.random.default_rng(seed)
        occ = (rng.random((C, P)) < density).astype(np.float32)
        np.testing.assert_allclose(
            contention_pallas(occ), contention_ref(occ), rtol=1e-4, atol=1e-4
        )


class TestScorerModel:
    def test_composed_scorer_matches_refs(self):
        rng = np.random.default_rng(3)
        sizes, mask, nflows, w, _ = random_batch(3)
        done = rng.random(C).astype(np.float32) * 100
        occ = (rng.random((C, P)) < 0.03).astype(np.float32)
        weight = np.float32(0.5)
        score, est, lcb, cont = model.scorer(sizes, mask, nflows, w, done, occ, weight)
        est_r, lcb_r = estimator_ref(sizes, mask, nflows, w)
        cont_r = contention_ref(occ)
        score_r = score_ref(est_r, done, cont_r, weight)
        np.testing.assert_allclose(est, est_r, rtol=1e-5)
        np.testing.assert_allclose(lcb, lcb_r, rtol=1e-3)
        np.testing.assert_allclose(cont, cont_r, rtol=1e-5)
        np.testing.assert_allclose(score, score_r, rtol=1e-5)

    def test_score_monotone_in_remaining(self):
        est = np.linspace(0, 1000, C).astype(np.float32)
        done = np.zeros(C, np.float32)
        cont = np.zeros(C, np.float32)
        s = np.asarray(score_ref(est, done, cont, 0.5))
        assert (np.diff(s) >= 0).all()

    def test_score_increases_with_contention(self):
        est = np.full(C, 100.0, np.float32)
        done = np.zeros(C, np.float32)
        lo = np.asarray(score_ref(est, done, np.zeros(C, np.float32), 0.5))
        hi = np.asarray(score_ref(est, done, np.full(C, 4.0, np.float32), 0.5))
        assert (hi > lo).all()

    def test_done_bytes_clamp(self):
        est = np.full(C, 10.0, np.float32)
        done = np.full(C, 100.0, np.float32)  # overshoot
        s = np.asarray(score_ref(est, done, np.zeros(C, np.float32), 0.5))
        np.testing.assert_allclose(s, 0.0)


class TestAotShapes:
    def test_manifest_constants_consistent(self):
        assert C % 32 == 0  # block size divides batch
        assert LCB_SIGMAS == 3.0
        assert M >= 10  # must hold SchedulerConfig::pilot_max
        assert P >= 2 * 900  # up+down directions of the 900-port run
