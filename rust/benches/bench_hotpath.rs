//! §Perf micro-benchmarks of the coordinator hot path: priority-order
//! construction, rate allocation, and (when artifacts are built) the PJRT
//! scorer — the three components every scheduling decision pays for.
//!
//! `cargo bench --bench bench_hotpath`

mod common;

use philae::coordinator::philae::PhilaeCore;
use philae::coordinator::{rate, SchedulerConfig, SchedulerKind};
use philae::runtime::{BatchFeatures, Engine};
use philae::sim::world_from_trace;
use philae::trace::TraceSpec;

fn main() {
    common::banner("hotpath", "order + allocate + PJRT scorer");
    let cfg = SchedulerConfig::default();

    for (ports, coflows) in [(150usize, 200usize), (900, 600)] {
        let trace = TraceSpec::fb_like(ports, coflows).seed(5).generate();
        let mut world = world_from_trace(&trace);
        // activate everything at once — worst-case order/allocate input
        world.active = (0..trace.coflows.len()).collect();
        let mut core = PhilaeCore::new(cfg.clone());
        for cid in 0..trace.coflows.len() {
            core.handle_arrival(cid, &mut world);
            world.coflows[cid].phase = philae::coflow::CoflowPhase::Running;
            world.coflows[cid].est_size = Some(world.coflows[cid].total_bytes);
        }

        let (min_order, _) = common::time_it(20, || core.order(&world));
        let plan = core.order(&world);
        let (min_alloc, _) = common::time_it(20, || {
            rate::allocate(&world.fabric, &world.flows, &world.coflows, &plan)
        });
        let alloc = rate::allocate(&world.fabric, &world.flows, &world.coflows, &plan);
        println!(
            "{ports} ports / {coflows} active coflows: order {:.0} µs | allocate {:.0} µs ({} grants, {} visited)",
            min_order * 1e6,
            min_alloc * 1e6,
            alloc.grants.len(),
            alloc.visited
        );

        // Aalo's per-tick pipeline on the same world (Table 3's "calc").
        let mut aalo = SchedulerKind::Aalo.build(&trace, &cfg);
        let (min_aalo, _) = common::time_it(20, || {
            let p = aalo.order(&world);
            rate::allocate(&world.fabric, &world.flows, &world.coflows, &p)
        });
        println!("  aalo order+allocate: {:.0} µs", min_aalo * 1e6);
    }

    // PJRT scorer (L2 graph of L1 kernels) — the AOT hot path.
    match Engine::load("artifacts") {
        Ok(engine) => {
            let mut batch = BatchFeatures::new(&engine.manifest);
            for row in 0..engine.manifest.c {
                let sizes: Vec<f64> = (0..10).map(|i| 1e6 * (i + row + 1) as f64).collect();
                batch.set_row(row, &sizes, 1000 + row, 5e6, &[row % 512, 1024 + row % 512], row as u64);
            }
            let (min_s, mean_s) = common::time_it(30, || engine.score(&batch, 0.5).unwrap());
            println!(
                "\nPJRT scorer ({}×{} batch, B={}): min {:.2} ms mean {:.2} ms ({:.1} µs/coflow)",
                engine.manifest.c,
                engine.manifest.m,
                engine.manifest.b,
                min_s * 1e3,
                mean_s * 1e3,
                min_s / engine.manifest.c as f64 * 1e6
            );
            let (min_e, _) = common::time_it(30, || engine.estimate(&batch).unwrap());
            println!("PJRT estimator only: min {:.2} ms", min_e * 1e3);
            let (min_c, _) = common::time_it(30, || engine.contention(&batch).unwrap());
            println!("PJRT contention only: min {:.2} ms", min_c * 1e3);
        }
        Err(e) => println!("\n(PJRT scorer skipped: {e:#})"),
    }
}
