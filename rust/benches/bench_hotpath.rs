//! §Perf micro-benchmarks of the coordinator hot path: priority-order
//! construction, rate allocation, and (when artifacts are built) the PJRT
//! scorer — the three components every scheduling decision pays for.
//!
//! Measures both paths of each stage so the incremental engine's win over
//! the from-scratch baseline is tracked per PR:
//!
//! * **full** — `order_full_into` (oracle re-sort) + `allocate` with a
//!   fresh scratch per call: the pre-optimization per-event behavior.
//! * **incremental** — `order_into` against the persistent lane cache +
//!   `allocate_into` with a reused [`AllocScratch`]: the shipping hot path.
//!
//! Emits machine-readable `BENCH_hotpath.json` at the repo root.
//!
//! `cargo bench --bench bench_hotpath`

mod common;

use philae::coordinator::philae::PhilaeCore;
use philae::coordinator::{rate, Plan, Scheduler, SchedulerConfig, SchedulerKind};
use philae::runtime::{BatchFeatures, Engine};
use philae::sim::world_from_trace;
use philae::trace::TraceSpec;

struct Row {
    ports: usize,
    coflows: usize,
    fabric: &'static str,
    full_order_us: f64,
    full_alloc_us: f64,
    inc_order_us: f64,
    inc_alloc_us: f64,
    aalo_full_us: f64,
    aalo_inc_us: f64,
    grants: usize,
    visited: usize,
}

fn main() {
    common::banner("hotpath", "order + allocate + PJRT scorer (full vs incremental)");
    let cfg = SchedulerConfig::default();
    let iters = common::iters(20);
    let mut rows: Vec<Row> = Vec::new();

    // scenario diversity: the paper's homogeneous 1 Gbps testbeds plus a
    // mixed 1/10/40 Gbps fabric (TraceSpec::mixed_rate) at 900 ports
    let scenarios = [(150usize, 200usize, false), (900, 600, false), (900, 600, true)];
    for (ports, coflows, mixed) in scenarios {
        let spec = if mixed {
            TraceSpec::mixed_rate(ports, coflows)
        } else {
            TraceSpec::fb_like(ports, coflows)
        };
        let trace = spec.clone().seed(5).generate();
        let fabric_label = if mixed { "mixed-1-10-40" } else { "homogeneous" };
        let mut world = world_from_trace(&trace);
        world.fabric = spec.fabric();
        // activate everything at once — worst-case order/allocate input
        world.active = (0..trace.coflows.len()).collect();
        let mut core = PhilaeCore::new(cfg.clone());
        for cid in 0..trace.coflows.len() {
            core.handle_arrival(cid, &mut world);
            world.coflows[cid].phase = philae::coflow::CoflowPhase::Running;
            world.coflows[cid].est_size = Some(world.coflows[cid].total_bytes);
        }

        // -- full (from-scratch) baseline: what every event used to pay --
        let mut plan_full = Plan::default();
        let (full_order, _) = common::time_it(iters, || {
            core.order_full_into(&world, &mut plan_full)
        });
        core.order_full_into(&world, &mut plan_full);
        let (full_alloc, _) = common::time_it(iters, || {
            rate::allocate(&world.fabric, &world.flows, &world.coflows, &plan_full)
        });

        // -- incremental steady state: cache warmed by the first call --
        let mut plan = Plan::default();
        let mut scratch = rate::AllocScratch::new();
        core.order_into(&world, &mut plan);
        rate::allocate_into(&world.fabric, &world.flows, &world.coflows, &plan, &mut scratch);
        let (inc_order, _) = common::time_it(iters, || core.order_into(&world, &mut plan));
        let (inc_alloc, _) = common::time_it(iters, || {
            rate::allocate_into(&world.fabric, &world.flows, &world.coflows, &plan, &mut scratch)
        });
        assert_eq!(plan.entries, plan_full.entries, "incremental order diverged");
        let grants = scratch.grants().len();
        let visited = scratch.visited();
        println!(
            "{ports} ports ({fabric_label}) / {coflows} active coflows ({} grants, {} visited):",
            grants, visited
        );
        println!(
            "  philae order    full {:>8.1} µs | incremental {:>8.1} µs ({:.1}x)",
            full_order * 1e6,
            inc_order * 1e6,
            full_order / inc_order.max(1e-12)
        );
        println!(
            "  philae allocate full {:>8.1} µs | incremental {:>8.1} µs ({:.1}x)",
            full_alloc * 1e6,
            inc_alloc * 1e6,
            full_alloc / inc_alloc.max(1e-12)
        );

        // Aalo's per-tick pipeline on the same world (Table 3's "calc").
        let mut aalo = SchedulerKind::Aalo.build(&trace, &cfg);
        let mut aalo_plan = Plan::default();
        let (aalo_full, _) = common::time_it(iters, || {
            aalo.order_full_into(&world, &mut aalo_plan);
            rate::allocate(&world.fabric, &world.flows, &world.coflows, &aalo_plan)
        });
        let mut aalo_scratch = rate::AllocScratch::new();
        aalo.order_into(&world, &mut aalo_plan);
        let (aalo_inc, _) = common::time_it(iters, || {
            aalo.order_into(&world, &mut aalo_plan);
            rate::allocate_into(
                &world.fabric,
                &world.flows,
                &world.coflows,
                &aalo_plan,
                &mut aalo_scratch,
            )
        });
        println!(
            "  aalo order+alloc full {:>8.1} µs | incremental {:>8.1} µs ({:.1}x)",
            aalo_full * 1e6,
            aalo_inc * 1e6,
            aalo_full / aalo_inc.max(1e-12)
        );

        rows.push(Row {
            ports,
            coflows,
            fabric: fabric_label,
            full_order_us: full_order * 1e6,
            full_alloc_us: full_alloc * 1e6,
            inc_order_us: inc_order * 1e6,
            inc_alloc_us: inc_alloc * 1e6,
            aalo_full_us: aalo_full * 1e6,
            aalo_inc_us: aalo_inc * 1e6,
            grants,
            visited,
        });
    }

    // machine-readable trajectory for cross-PR tracking
    let mut json = String::from("{\n  \"bench\": \"hotpath\",\n  \"iters\": ");
    json.push_str(&iters.to_string());
    json.push_str(",\n  \"configs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let combined_full = r.full_order_us + r.full_alloc_us;
        let combined_inc = r.inc_order_us + r.inc_alloc_us;
        json.push_str(&format!(
            "    {{\"ports\": {}, \"active_coflows\": {}, \"fabric\": \"{}\", \"grants\": {}, \"visited\": {},\n      \
             \"full\": {{\"order_us\": {:.3}, \"alloc_us\": {:.3}}},\n      \
             \"incremental\": {{\"order_us\": {:.3}, \"alloc_us\": {:.3}}},\n      \
             \"order_alloc_speedup\": {:.3},\n      \
             \"aalo\": {{\"full_us\": {:.3}, \"incremental_us\": {:.3}}}}}{}\n",
            r.ports,
            r.coflows,
            r.fabric,
            r.grants,
            r.visited,
            r.full_order_us,
            r.full_alloc_us,
            r.inc_order_us,
            r.inc_alloc_us,
            combined_full / combined_inc.max(1e-9),
            r.aalo_full_us,
            r.aalo_inc_us,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    common::write_json("BENCH_hotpath.json", &json);

    // PJRT scorer (L2 graph of L1 kernels) — the AOT hot path.
    match Engine::load("artifacts") {
        Ok(engine) => {
            let mut batch = BatchFeatures::new(&engine.manifest);
            for row in 0..engine.manifest.c {
                let sizes: Vec<f64> = (0..10).map(|i| 1e6 * (i + row + 1) as f64).collect();
                let ports = [row % 512, 1024 + row % 512];
                batch.set_row(row, &sizes, 1000 + row, 5e6, &ports, row as u64);
            }
            let (min_s, mean_s) = common::time_it(30, || engine.score(&batch, 0.5).unwrap());
            println!(
                "\nPJRT scorer ({}×{} batch, B={}): min {:.2} ms mean {:.2} ms ({:.1} µs/coflow)",
                engine.manifest.c,
                engine.manifest.m,
                engine.manifest.b,
                min_s * 1e3,
                mean_s * 1e3,
                min_s / engine.manifest.c as f64 * 1e6
            );
            let (min_e, _) = common::time_it(30, || engine.estimate(&batch).unwrap());
            println!("PJRT estimator only: min {:.2} ms", min_e * 1e3);
            let (min_c, _) = common::time_it(30, || engine.contention(&batch).unwrap());
            println!("PJRT contention only: min {:.2} ms", min_c * 1e3);
        }
        Err(e) => println!("\n(PJRT scorer skipped: {e:#})"),
    }
}
