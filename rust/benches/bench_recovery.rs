//! §Robustness bench of **coordinator crash-failover**
//! (`coordinator/recovery.rs`): what does surviving a coordinator crash
//! cost?
//!
//! Four measurements:
//!
//! 1. **Exact-restore overhead** — a full Philae simulation that seals a
//!    checkpoint and rebuilds the coordinator from it every N events,
//!    asserted bit-identical to the uninterrupted run, vs the plain run's
//!    wall time. This prices the strongest recovery mode end to end.
//! 2. **Checkpoint / restore micro-latency** — mean milliseconds to seal a
//!    full K-shard cluster checkpoint and to kill-and-restore one shard
//!    from it, on a 900-port FB-like fabric mid-run. This is the latency a
//!    live supervisor would pay per crash.
//! 3. **Chaos CCT cost** — mean CCT of a cluster run with the chaos driver
//!    killing shards mid-flight, as a ratio of the crash-free baseline
//!    (higher is better; 1.0 = crashes are free). The crash model loses
//!    learned scheduler state, never bytes in flight, so this measures the
//!    re-learning cost alone.
//! 4. **Live-service recovery latency** — mean wall milliseconds per
//!    recovery (scheduler rebuild + first reallocation) in the threaded
//!    service under injected crashes.
//!
//! Emits machine-readable `BENCH_recovery.json` at the repo root; CI runs
//! a 1-iteration smoke and `bench_gate` holds conservative floors on the
//! chaos CCT ratio and the restore overhead ratio.
//!
//! `cargo bench --bench bench_recovery`

mod common;

use philae::coordinator::{ClusterConfig, CoordinatorCluster, SchedulerConfig, SchedulerKind};
use philae::service::{run_service, ServiceConfig};
use philae::sim::{world_from_trace, SimConfig, Simulation};
use philae::trace::TraceSpec;

fn main() {
    common::banner("recovery", "crash-failover: checkpoint/restore latency and chaos CCT cost");
    let cfg = SchedulerConfig::default();
    let iters = common::iters(3);
    println!("iters: {iters}\n");

    // ---- 1. exact-restore overhead, end to end -------------------------
    // Philae only: event-triggered (no δ ticks), so measured wall time
    // never couples into the event history and the restored run is
    // bit-comparable to the plain one (same reasoning as bench_cluster).
    let kind = SchedulerKind::Philae;
    let trace = TraceSpec::fb_like(300, 300).seed(5).generate();
    let sim_cfg = SimConfig::default();
    let every = 200u64;

    let mut plain_slot = None;
    let (plain_wall, _) = common::time_it(iters, || {
        let mut sched = kind.build(&trace, &cfg);
        plain_slot = Some(Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg));
    });
    let plain = plain_slot.expect("plain run");

    let mut restored_slot = None;
    let (restore_wall, _) = common::time_it(iters, || {
        restored_slot = Some(Simulation::run_with_restore(&trace, kind, &cfg, &sim_cfg, every));
    });
    let (restored, restores) = restored_slot.expect("restored run");
    assert!(restores > 0, "crash injection never fired");
    for (i, (a, b)) in plain.ccts.iter().zip(restored.ccts.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "restored run diverged from plain at coflow {i}");
    }
    let wall_ratio = plain_wall / restore_wall.max(1e-9);
    println!(
        "exact restore  300 ports: plain {:>7.3} s | restore-every-{} {:>7.3} s ({} restores) | wall ratio {:.3}",
        plain_wall, every, restore_wall, restores, wall_ratio
    );

    // ---- 2. checkpoint / restore micro-latency -------------------------
    let big = TraceSpec::fb_like(900, 600).seed(5).generate();
    let k = 4usize;
    let mut world = world_from_trace(&big);
    let ccfg = ClusterConfig { coordinators: k, ..ClusterConfig::default() };
    let mut cluster = CoordinatorCluster::new(kind, &big, &cfg, ccfg);
    for cid in 0..big.coflows.len() {
        world.active.push(cid);
        cluster.on_arrival(cid, &mut world);
    }
    cluster.compute(&mut world, false);

    let reps = 10usize;
    let mut ckpt = String::new();
    let (_, ckpt_mean_s) = common::time_it(reps, || {
        ckpt = cluster.checkpoint(&mut world);
    });
    let ckpt_bytes = ckpt.len();
    let mut victim = 0usize;
    let (_, restore_mean_s) = common::time_it(reps, || {
        let restored = cluster.kill_and_restore_shard(victim, &big, &cfg, Some(&ckpt), &mut world);
        restored.expect("restore from a self-sealed checkpoint");
        victim = (victim + 1) % k;
    });
    let ckpt_ms = ckpt_mean_s * 1e3;
    let restore_ms = restore_mean_s * 1e3;
    println!(
        "micro-latency  900 ports K={k}: checkpoint {:>7.3} ms ({} KiB) | shard restore {:>7.3} ms",
        ckpt_ms,
        ckpt_bytes / 1024,
        restore_ms
    );

    // ---- 3. chaos CCT cost ---------------------------------------------
    let mid = TraceSpec::fb_like(120, 200).seed(5).generate();
    let chaos_k = 4usize;
    let mut baseline = CoordinatorCluster::with_coordinators(chaos_k, kind, &mid, &cfg);
    let base = Simulation::run_with_cluster(&mid, &mut baseline, &cfg, &sim_cfg);
    let mut chaotic = CoordinatorCluster::with_coordinators(chaos_k, kind, &mid, &cfg);
    chaotic.set_chaos(&mid, &cfg, 4, 6, 42);
    let res = Simulation::run_with_cluster(&mid, &mut chaotic, &cfg, &sim_cfg);
    assert!(chaotic.chaos_kills() > 0, "chaos never fired");
    assert!(res.ccts.iter().all(|c| c.is_finite() && *c > 0.0), "unfinished under chaos");
    let base_mean = base.ccts.iter().sum::<f64>() / base.ccts.len() as f64;
    let chaos_mean = res.ccts.iter().sum::<f64>() / res.ccts.len() as f64;
    let cct_ratio = base_mean / chaos_mean.max(1e-12);
    println!(
        "chaos CCT      120 ports K={chaos_k}: baseline mean {:>9.4} s | chaos mean {:>9.4} s ({} kills, {} ckpts) | ratio {:.3}",
        base_mean,
        chaos_mean,
        chaotic.chaos_kills(),
        chaotic.chaos_checkpoints(),
        cct_ratio
    );

    // ---- 4. live-service recovery latency ------------------------------
    let svc_trace = TraceSpec::tiny(10, 20).seed(21).generate();
    let svc_cfg = ServiceConfig {
        kind,
        coordinators: 2,
        time_scale: 200.0,
        checkpoint_every: 2,
        chaos_kill_every: 3,
        ..ServiceConfig::default()
    };
    let report = run_service(&svc_trace, &svc_cfg).expect("chaos service run");
    assert!(report.crashes_injected > 0, "service chaos never fired");
    assert_eq!(report.recoveries, report.crashes_injected, "a crash went unrecovered");
    let recovery_ms = report.recovery_wall.mean() * 1e3;
    println!(
        "service        K=2: {} crashes -> {} recoveries | {:>7.3} ms mean recovery ({} checkpoints)",
        report.crashes_injected, report.recoveries, recovery_ms, report.checkpoints_written
    );

    // ---- machine-readable ----------------------------------------------
    let json = format!(
        "{{\n  \"bench\": \"recovery\",\n  \"iters\": {iters},\n  \
         \"single\": {{\"ports\": 300, \"coflows\": 300, \"restore_every_events\": {every}, \
         \"plain_wall_s\": {plain_wall:.6}, \"restore_wall_s\": {restore_wall:.6}, \
         \"restores\": {restores}, \"wall_ratio_vs_plain\": {wall_ratio:.4}}},\n  \
         \"micro\": {{\"ports\": 900, \"coflows\": 600, \"k\": {k}, \
         \"checkpoint_ms_mean\": {ckpt_ms:.4}, \"restore_ms_mean\": {restore_ms:.4}, \
         \"checkpoint_bytes\": {ckpt_bytes}}},\n  \
         \"chaos\": {{\"ports\": 120, \"coflows\": 200, \"k\": {chaos_k}, \
         \"kills\": {kills}, \"checkpoints\": {ckpts}, \
         \"cct_ratio_vs_baseline\": {cct_ratio:.4}}},\n  \
         \"service\": {{\"crashes\": {crashes}, \"recoveries\": {recoveries}, \
         \"recovery_ms_mean\": {recovery_ms:.4}, \"checkpoints_written\": {cw}}}\n}}\n",
        kills = chaotic.chaos_kills(),
        ckpts = chaotic.chaos_checkpoints(),
        crashes = report.crashes_injected,
        recoveries = report.recoveries,
        cw = report.checkpoints_written,
    );
    common::write_json("BENCH_recovery.json", &json);
}
