//! §Perf bench of the **sharded allocation pipeline**: `allocate_into`
//! wall time vs shard count at 900 and 5000 ports, against the serial
//! baseline, with every sharded result asserted bit-identical to serial.
//!
//! Emits machine-readable `BENCH_shard.json` at the repo root (allocation
//! µs per shard count per fabric size) so the scaling trajectory is
//! tracked across PRs.
//!
//! `cargo bench --bench bench_shard`

mod common;

use philae::coordinator::philae::PhilaeCore;
use philae::coordinator::{rate, Plan, SchedulerConfig};
use philae::sim::world_from_trace;
use philae::trace::TraceSpec;

struct ShardPoint {
    shards: usize,
    us: f64,
}

struct Row {
    ports: usize,
    coflows: usize,
    grants: usize,
    ops_visited: usize,
    serial_us: f64,
    points: Vec<ShardPoint>,
}

fn main() {
    common::banner("shard", "sharded allocate_into scaling (µs vs shard count)");
    let cfg = SchedulerConfig::default();
    let iters = common::iters(10);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut shard_counts = vec![1usize, 2, 4, 8];
    if !shard_counts.contains(&cores) {
        shard_counts.push(cores);
    }
    shard_counts.sort_unstable();
    shard_counts.dedup();
    println!("cores: {cores} | shard settings: {shard_counts:?} | iters: {iters}\n");

    let mut rows: Vec<Row> = Vec::new();
    for (ports, coflows) in [(900usize, 600usize), (5000, 1500)] {
        let trace = TraceSpec::fb_like(ports, coflows).seed(5).generate();
        let mut world = world_from_trace(&trace);
        // worst case: every coflow active and estimated at once
        world.active = (0..trace.coflows.len()).collect();
        let mut core = PhilaeCore::new(cfg.clone());
        for cid in 0..trace.coflows.len() {
            core.handle_arrival(cid, &mut world);
            world.coflows[cid].phase = philae::coflow::CoflowPhase::Running;
            world.coflows[cid].est_size = Some(world.coflows[cid].total_bytes);
        }
        let mut plan = Plan::default();
        core.order_full_into(&world, &mut plan);

        // serial baseline (warmed scratch)
        let mut serial = rate::AllocScratch::new();
        rate::allocate_into(&world.fabric, &world.flows, &world.coflows, &plan, &mut serial);
        let (serial_s, _) = common::time_it(iters, || {
            rate::allocate_into(&world.fabric, &world.flows, &world.coflows, &plan, &mut serial)
        });
        println!(
            "{} ports / {} coflows / {} flows ({} grants, {} visited):",
            ports,
            coflows,
            trace.flows.len(),
            serial.grants().len(),
            serial.visited()
        );
        println!("  serial          {:>10.1} µs", serial_s * 1e6);

        let mut points = Vec::new();
        for &s in &shard_counts {
            let mut scratch = rate::AllocScratch::new();
            scratch.set_shards(s);
            rate::allocate_into(&world.fabric, &world.flows, &world.coflows, &plan, &mut scratch);
            assert_eq!(
                scratch.grants(),
                serial.grants(),
                "sharded S={s} diverged from serial"
            );
            assert_eq!(scratch.visited(), serial.visited(), "visited diverged at S={s}");
            let (t, _) = common::time_it(iters, || {
                rate::allocate_into(
                    &world.fabric,
                    &world.flows,
                    &world.coflows,
                    &plan,
                    &mut scratch,
                )
            });
            println!(
                "  S={s:<2} sharded    {:>10.1} µs ({:.2}x vs serial)",
                t * 1e6,
                serial_s / t.max(1e-12)
            );
            points.push(ShardPoint { shards: s, us: t * 1e6 });
        }
        rows.push(Row {
            ports,
            coflows,
            grants: serial.grants().len(),
            ops_visited: serial.visited(),
            serial_us: serial_s * 1e6,
            points,
        });
        println!();
    }

    let mut json = String::from("{\n  \"bench\": \"shard\",\n  \"iters\": ");
    json.push_str(&iters.to_string());
    json.push_str(&format!(",\n  \"cores\": {cores},\n  \"configs\": [\n"));
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"ports\": {}, \"active_coflows\": {}, \"grants\": {}, \"visited\": {},\n      \
             \"serial_alloc_us\": {:.3},\n      \"sharded\": [",
            r.ports, r.coflows, r.grants, r.ops_visited, r.serial_us
        ));
        for (j, p) in r.points.iter().enumerate() {
            json.push_str(&format!(
                "{{\"shards\": {}, \"alloc_us\": {:.3}, \"speedup_vs_serial\": {:.3}}}{}",
                p.shards,
                p.us,
                r.serial_us / p.us.max(1e-9),
                if j + 1 < r.points.len() { ", " } else { "" }
            ));
        }
        json.push_str(&format!("]}}{}\n", if i + 1 < rows.len() { "," } else { "" }));
    }
    json.push_str("  ]\n}\n");
    common::write_json("BENCH_shard.json", &json);
}
