//! Shared mini bench harness (no criterion on this offline image): runs a
//! closure N times, reports min/mean wall time, and prints paper-table rows.

use std::time::Instant;

// Each bench target compiles its own copy of this module and uses a
// subset of the helpers; CI lints benches with `-D warnings`, so the
// unused copies must not trip dead_code.

/// Time `f` over `iters` runs; returns (min_s, mean_s).
#[allow(dead_code)]
pub fn time_it<T>(iters: usize, mut f: impl FnMut() -> T) -> (f64, f64) {
    assert!(iters > 0);
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    (min, mean)
}

/// Iteration count: `default`, overridable via `PHILAE_BENCH_ITERS` (CI
/// smoke runs set it to 2 so hot-path regressions fail loudly but fast).
#[allow(dead_code)]
pub fn iters(default: usize) -> usize {
    std::env::var("PHILAE_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

/// Standard bench banner.
#[allow(dead_code)]
pub fn banner(name: &str, what: &str) {
    println!("=== bench {name} — {what} ===");
}

/// Write machine-readable results next to the repo root (the parent of the
/// crate directory), so the perf trajectory is tracked across PRs.
#[allow(dead_code)]
pub fn write_json(file_name: &str, json: &str) {
    let root = std::env::var("CARGO_MANIFEST_DIR")
        .map(|d| std::path::PathBuf::from(d).join(".."))
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    let path = root.join(file_name);
    match std::fs::write(&path, json) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
