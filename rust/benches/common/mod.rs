//! Shared mini bench harness (no criterion on this offline image): runs a
//! closure N times, reports min/mean wall time, and prints paper-table rows.

use std::time::Instant;

/// Time `f` over `iters` runs; returns (min_s, mean_s).
pub fn time_it<T>(iters: usize, mut f: impl FnMut() -> T) -> (f64, f64) {
    assert!(iters > 0);
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    (min, mean)
}

/// Standard bench banner.
pub fn banner(name: &str, what: &str) {
    println!("=== bench {name} — {what} ===");
}
