//! §Deadline bench: the SLO workload family end to end.
//!
//! Sweeps deadline **tightness** (deadline = tightness × ideal CCT,
//! `trace::DeadlineModel`) over FB-like fabrics at 150 and 900 ports under
//! elevated load, and runs the deadline-aware `dcoflow` scheduler against
//! the deadline-blind family (philae, aalo, sebf, scf). Reported per
//! (fabric, tightness, scheduler): **deadline-met ratio**, **goodput
//! ratio** (bytes of met-SLO coflows), and avg CCT; `dcoflow` additionally
//! reports its admission counters.
//!
//! The headline assertion mirrors the PR's acceptance bar: at tight SLOs
//! (tightness ≤ 2×) `dcoflow` must beat deadline-blind SCF on met ratio —
//! admission control plus EDF beats shortest-first exactly where a
//! mis-scheduled coflow means a missed SLO rather than a longer tail.
//!
//! Simulated results only (account δ neutralized), so the emitted
//! `BENCH_deadline.json` is machine-independent and deterministic;
//! `bench_gate` tracks conservative met-ratio floors from
//! `ci/bench_baseline.json`.
//!
//! `cargo bench --bench bench_deadline`

mod common;

use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::sim::{SimConfig, SimResult, Simulation};
use philae::trace::{DeadlineModel, TraceSpec};

const TIGHTNESS: [f64; 3] = [1.2, 2.0, 4.0];
const KINDS: [SchedulerKind; 5] = [
    SchedulerKind::Dcoflow,
    SchedulerKind::Philae,
    SchedulerKind::Aalo,
    SchedulerKind::Sebf,
    SchedulerKind::Scf,
];

struct Cell {
    kind: SchedulerKind,
    met_ratio: f64,
    goodput_ratio: f64,
    avg_cct: f64,
    admitted: u64,
    rejected: u64,
    expired: u64,
}

struct SweepPoint {
    tightness: f64,
    cells: Vec<Cell>,
}

struct Row {
    ports: usize,
    coflows: usize,
    points: Vec<SweepPoint>,
}

fn met_of(points: &[Cell], kind: SchedulerKind) -> f64 {
    points
        .iter()
        .find(|c| c.kind == kind)
        .map(|c| c.met_ratio)
        .unwrap_or(f64::NAN)
}

fn main() {
    common::banner(
        "deadline",
        "SLO workloads: deadline-met ratio vs tightness, dcoflow vs deadline-blind",
    );
    let cfg = SchedulerConfig::default();
    // The sweep is deterministic (no wall-time coupling): iterations only
    // smooth wall time, so one pass is enough even locally.
    let iters = common::iters(1);
    // Neutralize the §4.3 tick-latency model so met ratios are
    // machine-independent (same reasoning as tests/cct_equivalence.rs).
    let sim_cfg = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };
    println!("iters: {iters} | tightness sweep: {TIGHTNESS:?}\n");

    let mut rows: Vec<Row> = Vec::new();
    for (ports, coflows, load) in [(150usize, 400usize, 2.0f64), (900, 400, 2.0)] {
        println!("{ports} ports / {coflows} coflows (load ×{load}):");
        let mut points = Vec::new();
        for &tightness in &TIGHTNESS {
            let trace = TraceSpec::fb_like(ports, coflows)
                .with_load_factor(load)
                .seed(5)
                .with_deadlines(DeadlineModel { tightness, spread: 0.5, coverage: 1.0 })
                .generate();
            let mut cells = Vec::new();
            for &kind in &KINDS {
                let mut res: Option<SimResult> = None;
                let _ = common::time_it(iters, || {
                    let mut sched = kind.build(&trace, &cfg);
                    res = Some(Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg));
                });
                let res = res.expect("sim ran");
                let dl = &res.deadline;
                println!(
                    "  t={tightness:<4} {:<16} met {:>6.1}% | goodput {:>6.1}% | avg CCT {:>8.3}s{}",
                    kind.as_str(),
                    100.0 * dl.met_ratio(),
                    100.0 * dl.goodput_ratio(),
                    res.avg_cct(),
                    if kind == SchedulerKind::Dcoflow {
                        format!(
                            " | admitted {} rejected {} expired {}",
                            dl.admitted, dl.rejected, dl.expired
                        )
                    } else {
                        String::new()
                    }
                );
                cells.push(Cell {
                    kind,
                    met_ratio: dl.met_ratio(),
                    goodput_ratio: dl.goodput_ratio(),
                    avg_cct: res.avg_cct(),
                    admitted: dl.admitted,
                    rejected: dl.rejected,
                    expired: dl.expired,
                });
            }
            // acceptance bar: deadline-aware beats deadline-blind SCF on
            // met ratio wherever SLOs are tight
            if tightness <= 2.0 {
                let dc = met_of(&cells, SchedulerKind::Dcoflow);
                let scf = met_of(&cells, SchedulerKind::Scf);
                assert!(
                    dc > scf,
                    "{ports}p t={tightness}: dcoflow met ratio {dc:.4} \
                     must strictly exceed deadline-blind scf {scf:.4}"
                );
            }
            points.push(SweepPoint { tightness, cells });
        }
        rows.push(Row { ports, coflows, points });
        println!();
    }

    // ---- machine-readable JSON ----
    let mut json = String::from("{\n  \"bench\": \"deadline\",\n  \"configs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"ports\": {}, \"coflows\": {}, \"sweep\": [\n",
            r.ports, r.coflows
        ));
        for (j, p) in r.points.iter().enumerate() {
            let dc = met_of(&p.cells, SchedulerKind::Dcoflow);
            let scf = met_of(&p.cells, SchedulerKind::Scf);
            json.push_str(&format!("      {{\"tightness\": {}, ", p.tightness));
            for field in ["met_ratio", "goodput_ratio", "avg_cct"] {
                json.push_str(&format!("\"{field}\": {{"));
                for (k, c) in p.cells.iter().enumerate() {
                    let v = match field {
                        "met_ratio" => c.met_ratio,
                        "goodput_ratio" => c.goodput_ratio,
                        _ => c.avg_cct,
                    };
                    json.push_str(&format!(
                        "\"{}\": {:.6}{}",
                        c.kind.as_str(),
                        v,
                        if k + 1 < p.cells.len() { ", " } else { "" }
                    ));
                }
                json.push_str("}, ");
            }
            let dcoflow = p
                .cells
                .iter()
                .find(|c| c.kind == SchedulerKind::Dcoflow)
                .expect("dcoflow cell");
            json.push_str(&format!(
                "\"dcoflow_admission\": {{\"admitted\": {}, \"rejected\": {}, \"expired\": {}}}, \
                 \"dcoflow_met_minus_scf\": {:.6}}}{}\n",
                dcoflow.admitted,
                dcoflow.rejected,
                dcoflow.expired,
                dc - scf,
                if j + 1 < r.points.len() { "," } else { "" }
            ));
        }
        json.push_str(&format!("    ]}}{}\n", if i + 1 < rows.len() { "," } else { "" }));
    }
    json.push_str("  ]\n}\n");
    common::write_json("BENCH_deadline.json", &json);
}
