//! §Observability overhead: what the flight recorder + metrics registry
//! cost when armed, and the raw throughput of the two hot recording
//! primitives.
//!
//! * **sim overhead** — the full simulation at bench scale, obs off vs
//!   obs on (64Ki-event ring). The ratio of the two minimum wall times is
//!   the number the CI gate holds under the ≤10% ceiling
//!   (`overhead.events_ratio_on_vs_off` in `ci/bench_baseline.json`).
//! * **archive overhead** — the same run with the durable segment spool
//!   armed on top of the ring (`--archive-dir`). Spooling happens on a
//!   background thread off pooled buffers, so the gated ceiling
//!   (`overhead.archive_ratio_vs_off`) is deliberately conservative: it
//!   catches the spool blocking the hot path, not disk speed.
//! * **histogram** — `LogHistogram::record` throughput: two index bumps
//!   into the fixed 64×64 bucket grid, no allocation, no locks.
//! * **recorder** — `ObsPlane::emit` throughput: one ring store plus a
//!   sequence bump, the cost every recorded lifecycle event pays.
//!
//! Emits machine-readable `BENCH_obs.json` at the repo root.
//!
//! `cargo bench --bench bench_obs`

mod common;

use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::obs::{EventKind, LogHistogram, ObsPlane};
use philae::sim::{SimConfig, Simulation};
use philae::trace::TraceSpec;

fn main() {
    common::banner("obs", "flight recorder + metrics overhead (off vs on)");
    let iters = common::iters(10);

    let (ports, coflows) = (150usize, 200usize);
    let trace = TraceSpec::fb_like(ports, coflows).seed(5).generate();
    let cfg = SchedulerConfig::default();

    let run = |obs_events: usize| {
        let sim_cfg = SimConfig { obs_events, ..SimConfig::default() };
        let mut sched = SchedulerKind::Philae.build(&trace, &cfg);
        Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg)
    };

    // warm both paths once so first-touch page faults don't skew either side
    let base = run(0);
    let armed = run(1 << 16);
    let recorded = armed.obs.as_ref().map(|s| s.recorded).unwrap_or(0);
    assert!(recorded > 0, "armed run recorded no events");

    let (wall_off, _) = common::time_it(iters, || run(0));
    let (wall_on, _) = common::time_it(iters, || run(1 << 16));
    let ratio = wall_on / wall_off;
    println!(
        "sim {ports}p/{coflows}c philae: off {:.1} ms | on {:.1} ms | ratio {ratio:.4} ({recorded} events, {} CCTs)",
        wall_off * 1e3,
        wall_on * 1e3,
        base.ccts.len()
    );

    // ring + durable archive spool (background writer, pooled buffers)
    let arc_dir =
        std::env::temp_dir().join(format!("philae_bench_arc_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&arc_dir);
    let run_archived = || {
        let sim_cfg = SimConfig {
            obs_events: 1 << 16,
            archive: Some(philae::obs::ArchiveConfig::new(&arc_dir)),
            ..SimConfig::default()
        };
        let mut sched = SchedulerKind::Philae.build(&trace, &cfg);
        Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg)
    };
    let arch = run_archived(); // warm (and assert the spool kept everything)
    let stats = arch.obs.as_ref().and_then(|s| s.archive).expect("archive armed");
    assert_eq!(
        stats.spooled,
        stats.kept + stats.dropped_ring + stats.dropped_spool,
        "archive accounting identity broken"
    );
    assert_eq!(stats.io_errors, 0, "archive spool hit io errors");
    let (wall_arc, _) = common::time_it(iters, run_archived);
    let arc_ratio = wall_arc / wall_off;
    println!(
        "sim + archive spool:  {:.1} ms | ratio vs off {arc_ratio:.4} ({} kept, {} segment(s), {} bytes)",
        wall_arc * 1e3,
        stats.kept,
        stats.segments,
        stats.bytes
    );
    let _ = std::fs::remove_dir_all(&arc_dir);

    // histogram record throughput
    let mut hist = LogHistogram::new();
    let n_hist = 4_000_000u64;
    let (hist_s, _) = common::time_it(iters, || {
        for i in 0..n_hist {
            hist.record(i.wrapping_mul(2654435761) | 1);
        }
    });
    let hist_rate = n_hist as f64 / hist_s;
    println!("LogHistogram::record: {:.1} M records/s", hist_rate / 1e6);

    // recorder emit throughput (ring at capacity — steady-state overwrite)
    let mut plane = ObsPlane::new(1 << 16);
    let n_emit = 2_000_000u64;
    let (emit_s, _) = common::time_it(iters, || {
        for i in 0..n_emit {
            plane.emit(i as f64 * 1e-9, 0, 0, EventKind::FlowComplete, i % 512, i, i);
        }
    });
    let emit_rate = n_emit as f64 / emit_s;
    println!("ObsPlane::emit:       {:.1} M events/s", emit_rate / 1e6);
    std::hint::black_box((&hist, &plane));

    let json = format!(
        concat!(
            "{{\n",
            "  \"overhead\": {{\n",
            "    \"wall_off_s\": {:.6},\n",
            "    \"wall_on_s\": {:.6},\n",
            "    \"events_ratio_on_vs_off\": {:.6},\n",
            "    \"events_recorded\": {},\n",
            "    \"wall_archived_s\": {:.6},\n",
            "    \"archive_ratio_vs_off\": {:.6},\n",
            "    \"archive_kept\": {},\n",
            "    \"archive_segments\": {},\n",
            "    \"archive_bytes\": {}\n",
            "  }},\n",
            "  \"hist\": {{ \"records_per_sec\": {:.1} }},\n",
            "  \"recorder\": {{ \"emits_per_sec\": {:.1} }}\n",
            "}}\n"
        ),
        wall_off,
        wall_on,
        ratio,
        recorded,
        wall_arc,
        arc_ratio,
        stats.kept,
        stats.segments,
        stats.bytes,
        hist_rate,
        emit_rate
    );
    common::write_json("BENCH_obs.json", &json);
}
