//! §Perf bench of **multi-coordinator sharding**: full end-to-end
//! simulations through [`Simulation::run_cluster`] at K ∈ {1, 2, 4, 8}
//! coordinator shards on 900- and 5000-port FB-like fabrics, against the
//! single-coordinator baseline.
//!
//! Reported per (fabric, K): end-to-end **events/sec** (arrivals + update
//! messages + rate calculations over sim wall time) and the mean
//! **allocation µs per scheduling round** (measured order+allocate wall
//! time / rounds). K=1 is asserted **bit-identical** to the
//! single-coordinator path (same CCTs, same event counts) — the cluster
//! plumbing may cost wall time but must not change behavior.
//!
//! Emits machine-readable `BENCH_cluster.json` at the repo root; CI runs a
//! 1-iteration smoke and `bench_gate` tracks the K=1 overhead ratio
//! against `ci/bench_baseline.json`.
//!
//! `cargo bench --bench bench_cluster`

mod common;

use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::sim::{SimConfig, SimResult, Simulation};
use philae::trace::TraceSpec;

struct KPoint {
    k: usize,
    wall_s: f64,
    events_per_sec: f64,
    alloc_us_mean: f64,
    rate_calcs: u64,
}

struct Row {
    ports: usize,
    coflows: usize,
    flows: usize,
    single_wall_s: f64,
    single_events_per_sec: f64,
    points: Vec<KPoint>,
}

fn events(res: &SimResult, arrivals: usize) -> f64 {
    arrivals as f64 + res.update_msgs as f64 + res.rate_calcs as f64
}

fn main() {
    common::banner(
        "cluster",
        "multi-coordinator sharding: events/sec and allocation µs vs K",
    );
    let cfg = SchedulerConfig::default();
    // full simulations are heavy — default to few iterations; CI smoke
    // uses PHILAE_BENCH_ITERS=1
    let iters = common::iters(3);
    // Philae only: event-triggered (no δ ticks), so the §4.3 deadline
    // model never couples measured wall time into the event history and
    // K=1 is bit-comparable to the single-coordinator run.
    let kind = SchedulerKind::Philae;
    let ks = [1usize, 2, 4, 8];
    println!("iters: {iters} | scheduler: {}\n", kind.as_str());

    let mut rows: Vec<Row> = Vec::new();
    for (ports, coflows) in [(900usize, 600usize), (5000, 800)] {
        let trace = TraceSpec::fb_like(ports, coflows).seed(5).generate();
        let base = SimConfig::default();

        // single-coordinator baseline
        let mut single_res = None;
        let (single_wall, _) = common::time_it(iters, || {
            let mut sched = kind.build(&trace, &cfg);
            let r = Simulation::run_with(&trace, sched.as_mut(), &cfg, &base);
            single_res = Some(r);
        });
        let single = single_res.expect("baseline ran");
        let single_eps = events(&single, trace.coflows.len()) / single_wall.max(1e-9);
        println!(
            "{} ports / {} coflows / {} flows:",
            ports,
            coflows,
            trace.flows.len()
        );
        println!(
            "  single          {:>8.3} s wall | {:>10.0} events/s | {} rate calcs",
            single_wall, single_eps, single.rate_calcs
        );

        let mut points = Vec::new();
        for &k in &ks {
            let sim_cfg = SimConfig { coordinators: k, ..SimConfig::default() };
            let mut res_slot = None;
            let (wall, _) = common::time_it(iters, || {
                let r = Simulation::run_cluster(&trace, kind, &cfg, &sim_cfg);
                res_slot = Some(r);
            });
            let res = res_slot.expect("cluster ran");
            if k == 1 {
                // the K=1 cluster is a pass-through: bit-identical history
                assert_eq!(res.ccts.len(), single.ccts.len());
                for (i, (a, b)) in res.ccts.iter().zip(single.ccts.iter()).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "K=1 cluster CCT diverged from single coordinator at coflow {i}"
                    );
                }
                assert_eq!(res.rate_calcs, single.rate_calcs, "K=1 rate-calc count");
                assert_eq!(res.update_msgs, single.update_msgs, "K=1 update count");
            } else {
                // K>1 trades schedule quality for coordinator scalability —
                // but everything must still finish
                assert!(
                    res.ccts.iter().all(|c| c.is_finite() && *c > 0.0),
                    "K={k}: unfinished coflows"
                );
            }
            let eps = events(&res, trace.coflows.len()) / wall.max(1e-9);
            let alloc_us = if res.rate_calcs > 0 {
                res.rate_calc_wall_s / res.rate_calcs as f64 * 1e6
            } else {
                0.0
            };
            println!(
                "  K={k:<2} cluster    {:>8.3} s wall | {:>10.0} events/s | {:>8.2} µs/round ({:.2}x events/s vs single)",
                wall,
                eps,
                alloc_us,
                eps / single_eps.max(1e-9)
            );
            points.push(KPoint {
                k,
                wall_s: wall,
                events_per_sec: eps,
                alloc_us_mean: alloc_us,
                rate_calcs: res.rate_calcs,
            });
        }
        rows.push(Row {
            ports,
            coflows,
            flows: trace.flows.len(),
            single_wall_s: single_wall,
            single_events_per_sec: single_eps,
            points,
        });
        println!();
    }

    // Streamed-engine scale point: the same engine driven from the
    // bounded-memory arrival stream, never materializing the trace. CI
    // keeps this small; the 1M-coflow / 10k-port run lives in the
    // workflow's streaming smoke (see docs/BENCHMARKS.md).
    let stream_spec = TraceSpec::tiny(2000, 20_000).seed(9);
    let mut stream_res = None;
    let (stream_wall, _) = common::time_it(1, || {
        let mut s = stream_spec.stream();
        stream_res =
            Some(Simulation::run_stream(&mut s, SchedulerKind::Fifo, &cfg, &SimConfig::default()));
    });
    let stream_res = stream_res.expect("streamed run finished");
    assert_eq!(stream_res.ccts.len(), 20_000, "streamed run lost coflows");
    println!(
        "streamed 20k coflows / 2000 ports (fifo): {:.3} s wall | {:.0} coflows/s | peak active flows {}",
        stream_wall,
        20_000.0 / stream_wall.max(1e-9),
        stream_res.peak_active_flows
    );

    let mut json = String::from("{\n  \"bench\": \"cluster\",\n  \"iters\": ");
    json.push_str(&iters.to_string());
    json.push_str(",\n  \"configs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"ports\": {}, \"coflows\": {}, \"flows\": {},\n      \
             \"single\": {{\"wall_s\": {:.6}, \"events_per_sec\": {:.3}}},\n      \
             \"k1_events_ratio_vs_single\": {:.4},\n      \"cluster\": [",
            r.ports,
            r.coflows,
            r.flows,
            r.single_wall_s,
            r.single_events_per_sec,
            r.points
                .first()
                .map(|p| p.events_per_sec / r.single_events_per_sec.max(1e-9))
                .unwrap_or(0.0)
        ));
        for (j, p) in r.points.iter().enumerate() {
            json.push_str(&format!(
                "{{\"k\": {}, \"wall_s\": {:.6}, \"events_per_sec\": {:.3}, \
                 \"alloc_us_mean\": {:.3}, \"rate_calcs\": {}}}{}",
                p.k,
                p.wall_s,
                p.events_per_sec,
                p.alloc_us_mean,
                p.rate_calcs,
                if j + 1 < r.points.len() { ", " } else { "" }
            ));
        }
        json.push_str(&format!("]}}{}\n", if i + 1 < rows.len() { "," } else { "" }));
    }
    json.push_str(&format!(
        "  ],\n  \"stream\": {{\"coflows\": 20000, \"ports\": 2000, \"wall_s\": {:.6}, \
         \"coflows_per_sec\": {:.1}, \"peak_active_flows\": {}}}\n}}\n",
        stream_wall,
        20_000.0 / stream_wall.max(1e-9),
        stream_res.peak_active_flows
    ));
    common::write_json("BENCH_cluster.json", &json);
}
