//! Bench for **Tables 3 & 4** and the §4.3 scalability claim: coordinator
//! per-interval cost and missed-deadline fractions at 150 and 900 ports
//! (6× replicated trace, δ′ = 6δ), plus the 900-port CCT speedup.
//!
//! Emits machine-readable `BENCH_t3_coordinator.json` at the repo root.
//!
//! `cargo bench --bench bench_t3_coordinator`

mod common;

use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::metrics::SpeedupRow;
use philae::sim::Simulation;
use philae::trace::TraceSpec;

fn main() {
    common::banner("t3_coordinator", "Tables 3/4 + §4.3 scalability");
    let cfg = SchedulerConfig::default();
    let base = TraceSpec::fb_like(150, 526)
        .with_load_factor(4.0)
        .seed(42)
        .generate();

    let mut json = String::from("{\n  \"bench\": \"t3_coordinator\",\n  \"configs\": [\n");
    let n_cfgs = 2;
    for (ci, (label, k)) in [("150 ports", 1usize), ("900 ports", 6)].into_iter().enumerate() {
        let trace = if k == 1 { base.clone() } else { base.replicate(k) };
        let mut c = cfg.clone();
        c.delta *= k as f64; // δ' = kδ as in §4.3
        let philae = Simulation::run(&trace, SchedulerKind::Philae, &c);
        let aalo = Simulation::run(&trace, SchedulerKind::Aalo, &c);
        println!("\n-- {label} (δ = {:.0} ms) --", c.delta * 1e3);
        json.push_str(&format!(
            "    {{\"ports\": {}, \"delta_ms\": {:.3}, \"schedulers\": {{\n",
            150 * k,
            c.delta * 1e3
        ));
        for (si, (name, r)) in [("philae", &philae), ("aalo", &aalo)].into_iter().enumerate() {
            println!(
                "  {name:>6}: calc {:.3} ({:.3}) send {:.3} ({:.3}) recv {:.3} ({:.3}) total {:.3} ms/interval",
                r.intervals.rate_calc.mean() * 1e3,
                r.intervals.rate_calc.stddev() * 1e3,
                r.intervals.rate_send.mean() * 1e3,
                r.intervals.rate_send.stddev() * 1e3,
                r.intervals.update_recv.mean() * 1e3,
                r.intervals.update_recv.stddev() * 1e3,
                r.intervals.total_ms_mean()
            );
            println!(
                "          missed {:.1}% | idle-rate {:.1}% | updates/interval {:.1}",
                100.0 * r.intervals.missed_fraction(),
                100.0 * r.intervals.idle_rate_fraction(),
                r.intervals.updates_per_interval.mean()
            );
            json.push_str(&format!(
                "      \"{name}\": {{\"calc_ms\": {:.4}, \"send_ms\": {:.4}, \"recv_ms\": {:.4}, \
                 \"total_ms\": {:.4}, \"missed_frac\": {:.4}, \"avg_cct_s\": {:.4}, \
                 \"rate_calc_wall_s\": {:.4}}}{}\n",
                r.intervals.rate_calc.mean() * 1e3,
                r.intervals.rate_send.mean() * 1e3,
                r.intervals.update_recv.mean() * 1e3,
                r.intervals.total_ms_mean(),
                r.intervals.missed_fraction(),
                r.avg_cct(),
                r.rate_calc_wall_s,
                if si == 0 { "," } else { "" }
            ));
        }
        let row = SpeedupRow::from_ccts(&aalo.ccts, &philae.ccts);
        println!("  CCT speedup philae vs aalo: {row}");
        json.push_str(&format!(
            "    }}, \"cct_speedup_avg\": {:.4}}}{}\n",
            aalo.avg_cct() / philae.avg_cct(),
            if ci + 1 < n_cfgs { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    common::write_json("BENCH_t3_coordinator.json", &json);
    println!("\npaper: T3 total 14.80 vs 32.90 ms @900; T4 1%/16% @150, 10%/37% @900;");
    println!("       §4.3 900-port avg 2.72x (P90 9.78x)");
}
