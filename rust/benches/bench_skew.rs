//! Bench for the **§2.2 skew-robustness** result (Eq. 1): sampling accuracy
//! and CCT as intra-coflow skew grows, vs the clairvoyant oracle.
//!
//! `cargo bench --bench bench_skew`

mod common;

use philae::analysis::{
    cct_lower_bound_default, optimality_gap, skew_distribution, TwoCoflowSetting,
};
use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::metrics::percentile;
use philae::sim::Simulation;
use philae::trace::TraceSpec;

fn main() {
    common::banner("skew", "§2.2 Eq.(1) skew robustness");
    let cfg = SchedulerConfig::default();
    println!(
        "{:>6} {:>12} {:>13} {:>13}",
        "σ", "P50 skew", "philae/sebf", "aalo/sebf"
    );
    for sigma in [0.2, 0.8, 1.2, 2.0, 3.0] {
        let trace = TraceSpec::fb_like(100, 300)
            .with_skew_sigma(sigma)
            .with_load_factor(4.0)
            .seed(11)
            .generate();
        let sk = skew_distribution(&trace);
        let ph = Simulation::run(&trace, SchedulerKind::Philae, &cfg);
        let aalo = Simulation::run(&trace, SchedulerKind::Aalo, &cfg);
        let sebf = Simulation::run(&trace, SchedulerKind::Sebf, &cfg);
        println!(
            "{sigma:>6.1} {:>12.1} {:>13.3} {:>13.3}",
            percentile(&sk, 50.0),
            ph.avg_cct() / sebf.avg_cct(),
            aalo.avg_cct() / sebf.avg_cct()
        );
    }

    println!("\nEq.(1) bound vs pilots (skew h = 0.9, size ratio 1.2):");
    for m in [1.0, 2.0, 4.0, 10.0, 25.0] {
        let b = TwoCoflowSetting::symmetric(200.0, 10.0, 0.9, 1.2, m).hoeffding_bound();
        println!("  m = {m:>4.0}: bound {b:.4}");
    }

    // Adversarial-skew scenario (docs/SCENARIOS.md): the generator's
    // worst case for pilot-based size estimation — lognormal σ up to 3
    // interleaved with a uniform decoy class. Gaps are against the
    // offline SRPT-relaxation lower bound.
    let trace = TraceSpec::adversarial_skew(100, 300).with_load_factor(2.0).generate();
    let lb = cct_lower_bound_default(&trace);
    println!("\nadversarial-skew scenario (avg CCT LB {:.3}s):", lb.avg_cct());
    for kind in [SchedulerKind::Philae, SchedulerKind::Aalo, SchedulerKind::Sebf] {
        let r = Simulation::run(&trace, kind, &cfg);
        println!(
            "  {:>8}: avg CCT {:>7.3}s | gap {:>6.1}%",
            kind.as_str(),
            r.avg_cct(),
            100.0 * optimality_gap(r.avg_cct(), lb.avg_cct())
        );
    }
}
