//! §Perf bench of the **persistent-worker allocation pool** and the
//! **event-loop service runtime**.
//!
//! Part A pins the tentpole claim at the allocator level: sharded
//! `allocate_into` entry cost with the persistent pool (parked workers
//! woken per call) vs the spawn-per-call `thread::scope` baseline vs
//! serial, at 900 and 5000 ports, every variant asserted bit-identical.
//! The gated metric is `pool_entry_speedup_vs_spawn` — the pool must never
//! pay more per call than spawning did.
//!
//! Part B soaks the live coordinator runtime headlessly (`run_soak`: null
//! agents, a feeder thread streaming synthesized completion reports
//! round-robin across coflows) at 5000 ports / 100k+ concurrent flows and
//! reports sustained events/sec plus the p50/p99 reallocation latency
//! under that pressure — absolute numbers for the trajectory record, not
//! gated (they are machine-dependent).
//!
//! Emits machine-readable `BENCH_service.json` at the repo root.
//!
//! `cargo bench --bench bench_service`

mod common;

use philae::coordinator::philae::PhilaeCore;
use philae::coordinator::{rate, Plan, SchedulerConfig, SchedulerKind};
use philae::service::{run_soak, ServiceConfig};
use philae::sim::world_from_trace;
use philae::trace::TraceSpec;

struct AllocRow {
    ports: usize,
    shards: usize,
    serial_us: f64,
    spawn_us: f64,
    pool_us: f64,
}

fn main() {
    common::banner(
        "service",
        "persistent pool vs spawn-per-call + event-loop soak (events/sec, realloc p99)",
    );
    let cfg = SchedulerConfig::default();
    let iters = common::iters(10);
    let shards = 4usize;
    println!("alloc shards: {shards} | iters: {iters}\n");

    // ---- Part A: allocation entry cost, pool vs spawn vs serial --------
    let mut rows: Vec<AllocRow> = Vec::new();
    for (ports, coflows) in [(900usize, 600usize), (5000, 1500)] {
        let trace = TraceSpec::fb_like(ports, coflows).seed(5).generate();
        let mut world = world_from_trace(&trace);
        world.active = (0..trace.coflows.len()).collect();
        let mut core = PhilaeCore::new(cfg.clone());
        for cid in 0..trace.coflows.len() {
            core.handle_arrival(cid, &mut world);
            world.coflows[cid].phase = philae::coflow::CoflowPhase::Running;
            world.coflows[cid].est_size = Some(world.coflows[cid].total_bytes);
        }
        let mut plan = Plan::default();
        core.order_full_into(&world, &mut plan);

        let mut serial = rate::AllocScratch::new();
        rate::allocate_into(&world.fabric, &world.flows, &world.coflows, &plan, &mut serial);
        let (serial_s, _) = common::time_it(iters, || {
            rate::allocate_into(&world.fabric, &world.flows, &world.coflows, &plan, &mut serial)
        });

        let mut spawn = rate::AllocScratch::new();
        spawn.set_shards(shards);
        spawn.set_spawn_workers(true);
        rate::allocate_into(&world.fabric, &world.flows, &world.coflows, &plan, &mut spawn);
        assert_eq!(spawn.grants(), serial.grants(), "spawn path diverged at {ports}p");
        let (spawn_s, _) = common::time_it(iters, || {
            rate::allocate_into(&world.fabric, &world.flows, &world.coflows, &plan, &mut spawn)
        });

        let mut pool = rate::AllocScratch::new();
        pool.set_shards(shards);
        // first call spawns + parks the workers; timed calls only wake them
        rate::allocate_into(&world.fabric, &world.flows, &world.coflows, &plan, &mut pool);
        assert_eq!(pool.grants(), serial.grants(), "pool path diverged at {ports}p");
        let (pool_s, _) = common::time_it(iters, || {
            rate::allocate_into(&world.fabric, &world.flows, &world.coflows, &plan, &mut pool)
        });

        println!(
            "{} ports / {} coflows / {} flows ({} grants):",
            ports,
            coflows,
            trace.flows.len(),
            serial.grants().len()
        );
        println!("  serial              {:>10.1} µs", serial_s * 1e6);
        println!(
            "  S={shards} spawn-per-call {:>10.1} µs ({:.2}x vs serial)",
            spawn_s * 1e6,
            serial_s / spawn_s.max(1e-12)
        );
        println!(
            "  S={shards} persistent    {:>10.1} µs ({:.2}x vs serial, {:.2}x vs spawn)",
            pool_s * 1e6,
            serial_s / pool_s.max(1e-12),
            spawn_s / pool_s.max(1e-12)
        );
        rows.push(AllocRow {
            ports,
            shards,
            serial_us: serial_s * 1e6,
            spawn_us: spawn_s * 1e6,
            pool_us: pool_s * 1e6,
        });
        println!();
    }

    // ---- Part B: event-loop soak at 5k ports / 100k+ flows -------------
    let soak_ports = 5000usize;
    let target_flows = std::env::var("PHILAE_SOAK_FLOWS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(100_000);
    let mut soak_coflows = 400usize;
    let trace = loop {
        let t = TraceSpec::fb_like(soak_ports, soak_coflows).seed(7).generate();
        if t.flows.len() >= target_flows {
            break t;
        }
        soak_coflows *= 2;
    };
    println!(
        "soak: {} ports, {} coflows, {} concurrent flows (target {target_flows})",
        soak_ports,
        trace.coflows.len(),
        trace.flows.len()
    );
    let svc = ServiceConfig {
        kind: SchedulerKind::Philae,
        sched: cfg,
        alloc_shards: shards,
        ..ServiceConfig::default()
    };
    let report = run_soak(&trace, &svc).expect("soak run");
    let events_per_sec = report.update_msgs as f64 / report.wall_seconds.max(1e-9);
    println!(
        "  {} completion events in {:.2}s wall -> {:.0} events/sec sustained",
        report.update_msgs, report.wall_seconds, events_per_sec
    );
    println!(
        "  reallocations: {} | latency ms p50 {:.3} / p99 {:.3} / p999 {:.3} | sched bufs recycled {} | register bufs recycled {}",
        report.rate_calcs,
        report.realloc_p50 * 1e3,
        report.realloc_p99 * 1e3,
        report.realloc_p999 * 1e3,
        report.sched_bufs_reused,
        report.register_bufs_reused,
    );
    assert_eq!(
        report.ccts.iter().filter(|c| c.is_finite()).count(),
        trace.coflows.len(),
        "soak must complete every coflow"
    );
    // steady-state registration must ride the boomerang buffer pool: the
    // feeder awaits each reply and the coordinator recycles the consumed
    // record before replying, so only the first take can be fresh
    assert!(
        report.register_bufs_reused >= trace.coflows.len() as u64 - 1,
        "register path fell back to fresh buffers: {} reused of {} registrations",
        report.register_bufs_reused,
        trace.coflows.len()
    );

    // ---- JSON ----------------------------------------------------------
    let mut json = String::from("{\n  \"bench\": \"service\",\n  \"iters\": ");
    json.push_str(&iters.to_string());
    json.push_str(",\n  \"alloc\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"ports\": {}, \"shards\": {}, \"serial_us\": {:.3}, \"spawn_us\": {:.3}, \
             \"pool_us\": {:.3},\n      \"pool_entry_speedup_vs_spawn\": {:.4}, \
             \"pool_speedup_vs_serial\": {:.4}}}{}\n",
            r.ports,
            r.shards,
            r.serial_us,
            r.spawn_us,
            r.pool_us,
            r.spawn_us / r.pool_us.max(1e-9),
            r.serial_us / r.pool_us.max(1e-9),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"soak\": {{\"ports\": {}, \"coflows\": {}, \"flows\": {}, \"events\": {}, \
         \"wall_seconds\": {:.3},\n    \"events_per_sec\": {:.1}, \"rate_calcs\": {}, \
         \"realloc_p50_ms\": {:.4}, \"realloc_p99_ms\": {:.4}, \"realloc_p999_ms\": {:.4}, \
         \"sched_bufs_reused\": {}, \"register_bufs_reused\": {}}}\n",
        soak_ports,
        trace.coflows.len(),
        trace.flows.len(),
        report.update_msgs,
        report.wall_seconds,
        events_per_sec,
        report.rate_calcs,
        report.realloc_p50 * 1e3,
        report.realloc_p99 * 1e3,
        report.realloc_p999 * 1e3,
        report.sched_bufs_reused,
        report.register_bufs_reused,
    ));
    json.push_str("}\n");
    common::write_json("BENCH_service.json", &json);
}
