//! Bench for the **§2.2 error-correction experiment**: default Philae vs
//! the three bootstrap-LCB variants, all against Aalo on the same trace.
//!
//! `cargo bench --bench bench_errcorr`

mod common;

use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::metrics::{percentile, speedups};
use philae::sim::Simulation;
use philae::trace::TraceSpec;

fn main() {
    common::banner("errcorr", "§2.2 error-correction variants vs Aalo");
    let cfg = SchedulerConfig::default();
    let trace = TraceSpec::fb_like(150, 526)
        .with_load_factor(4.0)
        .seed(42)
        .generate();
    let aalo = Simulation::run(&trace, SchedulerKind::Aalo, &cfg);

    println!("paper: default 1.51x | LCB 1.33x | one-round 1.27x | multi-round 0.95x (avg)");
    println!(
        "{:>14} {:>10} {:>8} {:>8}",
        "variant", "avg-CCT", "P50", "P90"
    );
    for (label, kind) in [
        ("default", SchedulerKind::Philae),
        ("lcb", SchedulerKind::PhilaeLcb),
        ("one-round", SchedulerKind::PhilaeEc1),
        ("multi-round", SchedulerKind::PhilaeEcMulti),
    ] {
        let r = Simulation::run(&trace, kind, &cfg);
        let sp = speedups(&aalo.ccts, &r.ccts);
        println!(
            "{label:>14} {:>9.2}x {:>7.2}x {:>7.2}x",
            aalo.avg_cct() / r.avg_cct(),
            percentile(&sp, 50.0),
            percentile(&sp, 90.0)
        );
    }

    // Bootstrap micro-bench (the L1 kernel's native mirror).
    let samples: Vec<f64> = (0..10).map(|i| 1e6 * (i + 1) as f64).collect();
    let (min_s, _) = common::time_it(5, || {
        let mut acc = 0.0;
        for cid in 0..1000u64 {
            let (m, s) = philae::coordinator::errcorr::bootstrap(&samples, 100, cid);
            acc += m + s;
        }
        acc
    });
    println!(
        "\nnative bootstrap (100 resamples × 10 pilots): {:.1} µs/coflow",
        min_s / 1000.0 * 1e6
    );
}
