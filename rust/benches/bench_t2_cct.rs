//! Bench for **Table 2** (and the CCT-speedup CDF figure): end-to-end
//! Philae-vs-Aalo CCT comparison on the FB-like trace, full and wide-only,
//! with simulation wall-time measurements.
//!
//! `cargo bench --bench bench_t2_cct`

mod common;

use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::metrics::SpeedupRow;
use philae::sim::{SimConfig, Simulation};
use philae::trace::TraceSpec;

fn main() {
    common::banner("t2_cct", "Table 2: CCT improvement Philae vs Aalo");
    let cfg = SchedulerConfig::default();
    let trace = TraceSpec::fb_like(150, 526)
        .with_load_factor(4.0)
        .seed(42)
        .generate();

    let (aalo, philae) = {
        let a = Simulation::run(&trace, SchedulerKind::Aalo, &cfg);
        let p = Simulation::run(&trace, SchedulerKind::Philae, &cfg);
        (a, p)
    };
    let row = SpeedupRow::from_ccts(&aalo.ccts, &philae.ccts);
    println!("paper:    FB trace  P50 1.63x P90 8.00x avg 1.50x");
    println!("measured: FB-like   {row}");

    let wide = trace.wide_only();
    let aw = Simulation::run(&wide, SchedulerKind::Aalo, &cfg);
    let pw = Simulation::run(&wide, SchedulerKind::Philae, &cfg);
    println!("paper:    wide-only P50 1.05x P90 2.14x avg 1.49x");
    println!("measured: wide-only {}", SpeedupRow::from_ccts(&aw.ccts, &pw.ccts));

    // Scenario diversity: the same workload on a mixed 1/10/40 Gbps fabric
    // (no paper counterpart — heterogeneous clusters are a robustness
    // check: the speedup must survive NIC-generation skew).
    let mixed_spec = TraceSpec::mixed_rate(150, 526);
    let mixed_trace = mixed_spec.clone().with_load_factor(4.0).seed(42).generate();
    let mixed_cfg = SimConfig { fabric: Some(mixed_spec.fabric()), ..SimConfig::default() };
    let mut am = SchedulerKind::Aalo.build(&mixed_trace, &cfg);
    let amr = Simulation::run_with(&mixed_trace, am.as_mut(), &cfg, &mixed_cfg);
    let mut pm = SchedulerKind::Philae.build(&mixed_trace, &cfg);
    let pmr = Simulation::run_with(&mixed_trace, pm.as_mut(), &cfg, &mixed_cfg);
    println!(
        "measured: mixed-1/10/40-gbps {}",
        SpeedupRow::from_ccts(&amr.ccts, &pmr.ccts)
    );

    // Simulation throughput (perf tracking for §Perf).
    let (min_s, mean_s) = common::time_it(3, || {
        Simulation::run(&trace, SchedulerKind::Philae, &cfg).avg_cct()
    });
    println!(
        "sim wall time (philae, {} flows): min {:.2}s mean {:.2}s ({:.0}k flows/s)",
        trace.flows.len(),
        min_s,
        mean_s,
        trace.flows.len() as f64 / min_s / 1e3
    );
    let (min_a, _) = common::time_it(3, || {
        Simulation::run(&trace, SchedulerKind::Aalo, &cfg).avg_cct()
    });
    println!("sim wall time (aalo): min {min_a:.2}s");
}
