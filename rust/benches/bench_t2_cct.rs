//! Bench for **Table 2** (and the CCT-speedup CDF figure): end-to-end
//! Philae-vs-Aalo CCT comparison on the FB-like trace, full and wide-only,
//! with simulation wall-time measurements — plus per-scheduler
//! **optimality gaps** against the offline SRPT-relaxation lower bound
//! (docs/BENCHMARKS.md) and a streamed-vs-materialized parity check.
//!
//! Emits machine-readable `BENCH_t2_cct.json` at the repo root;
//! `bench_gate` tracks the gap ceilings against `ci/bench_baseline.json`.
//!
//! `cargo bench --bench bench_t2_cct`

mod common;

use philae::analysis::{cct_lower_bound_default, optimality_gap};
use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::metrics::SpeedupRow;
use philae::sim::{SimConfig, Simulation};
use philae::trace::TraceSpec;

fn main() {
    common::banner("t2_cct", "Table 2: CCT improvement Philae vs Aalo");
    let cfg = SchedulerConfig::default();
    let trace = TraceSpec::fb_like(150, 526)
        .with_load_factor(4.0)
        .seed(42)
        .generate();

    let (aalo, philae) = {
        let a = Simulation::run(&trace, SchedulerKind::Aalo, &cfg);
        let p = Simulation::run(&trace, SchedulerKind::Philae, &cfg);
        (a, p)
    };
    let row = SpeedupRow::from_ccts(&aalo.ccts, &philae.ccts);
    println!("paper:    FB trace  P50 1.63x P90 8.00x avg 1.50x");
    println!("measured: FB-like   {row}");

    let wide = trace.wide_only();
    let aw = Simulation::run(&wide, SchedulerKind::Aalo, &cfg);
    let pw = Simulation::run(&wide, SchedulerKind::Philae, &cfg);
    println!("paper:    wide-only P50 1.05x P90 2.14x avg 1.49x");
    println!("measured: wide-only {}", SpeedupRow::from_ccts(&aw.ccts, &pw.ccts));

    // Scenario diversity: the same workload on a mixed 1/10/40 Gbps fabric
    // (no paper counterpart — heterogeneous clusters are a robustness
    // check: the speedup must survive NIC-generation skew).
    let mixed_spec = TraceSpec::mixed_rate(150, 526);
    let mixed_trace = mixed_spec.clone().with_load_factor(4.0).seed(42).generate();
    let mixed_cfg = SimConfig { fabric: Some(mixed_spec.fabric()), ..SimConfig::default() };
    let mut am = SchedulerKind::Aalo.build(&mixed_trace, &cfg);
    let amr = Simulation::run_with(&mixed_trace, am.as_mut(), &cfg, &mixed_cfg);
    let mut pm = SchedulerKind::Philae.build(&mixed_trace, &cfg);
    let pmr = Simulation::run_with(&mixed_trace, pm.as_mut(), &cfg, &mixed_cfg);
    println!(
        "measured: mixed-1/10/40-gbps {}",
        SpeedupRow::from_ccts(&amr.ccts, &pmr.ccts)
    );

    // Simulation throughput (perf tracking for §Perf).
    let (min_s, mean_s) = common::time_it(3, || {
        Simulation::run(&trace, SchedulerKind::Philae, &cfg).avg_cct()
    });
    println!(
        "sim wall time (philae, {} flows): min {:.2}s mean {:.2}s ({:.0}k flows/s)",
        trace.flows.len(),
        min_s,
        mean_s,
        trace.flows.len() as f64 / min_s / 1e3
    );
    let (min_a, _) = common::time_it(3, || {
        Simulation::run(&trace, SchedulerKind::Aalo, &cfg).avg_cct()
    });
    println!("sim wall time (aalo): min {min_a:.2}s");

    // Optimality gaps: every registered scheduler against the offline
    // SRPT-relaxation lower bound — absolute floors, not just ratios
    // between schedulers, so a regression that slows *every* policy at
    // once still trips the gate.
    let lb = cct_lower_bound_default(&trace);
    println!("\noptimality gap vs offline lower bound (avg CCT LB {:.3}s):", lb.avg_cct());
    let mut gaps: Vec<(&str, f64, f64)> = Vec::new();
    for &kind in SchedulerKind::all() {
        let r = Simulation::run(&trace, kind, &cfg);
        let gap = optimality_gap(r.avg_cct(), lb.avg_cct());
        println!(
            "  {:>16}: avg CCT {:>7.3}s | gap {:>6.1}%",
            kind.as_str(),
            r.avg_cct(),
            100.0 * gap
        );
        gaps.push((kind.as_str(), r.avg_cct(), gap));
    }

    // Streamed-engine parity: the same spec driven through the
    // bounded-memory arrival stream must reproduce the materialized run
    // bit-for-bit (Philae is event-triggered, so no wall-clock coupling).
    let spec = TraceSpec::fb_like(150, 526).with_load_factor(4.0).seed(42);
    let mut stream = spec.stream();
    let streamed =
        Simulation::run_stream(&mut stream, SchedulerKind::Philae, &cfg, &SimConfig::default());
    assert_eq!(streamed.ccts.len(), philae.ccts.len(), "streamed coflow count");
    for (i, (a, b)) in streamed.ccts.iter().zip(philae.ccts.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "streamed CCT diverged at coflow {i}");
    }
    println!(
        "\nstreamed == materialized (philae): {} coflows bit-identical | peak active flows {}",
        streamed.ccts.len(),
        streamed.peak_active_flows
    );

    let mut json = String::from("{\n  \"bench\": \"t2_cct\",\n");
    json.push_str(&format!(
        "  \"speedup\": {{\"full_avg\": {:.4}, \"wide_avg\": {:.4}, \"mixed_avg\": {:.4}}},\n",
        aalo.avg_cct() / philae.avg_cct(),
        aw.avg_cct() / pw.avg_cct(),
        amr.avg_cct() / pmr.avg_cct()
    ));
    json.push_str(&format!("  \"lb_avg_cct_s\": {:.6},\n  \"gap\": {{", lb.avg_cct()));
    for (i, (name, _, gap)) in gaps.iter().enumerate() {
        json.push_str(&format!(
            "\"{name}\": {gap:.4}{}",
            if i + 1 < gaps.len() { ", " } else { "" }
        ));
    }
    json.push_str("},\n  \"avg_cct_s\": {");
    for (i, (name, avg, _)) in gaps.iter().enumerate() {
        json.push_str(&format!(
            "\"{name}\": {avg:.6}{}",
            if i + 1 < gaps.len() { ", " } else { "" }
        ));
    }
    json.push_str(&format!(
        "}},\n  \"stream\": {{\"bit_identical\": true, \"peak_active_flows\": {}}}\n}}\n",
        streamed.peak_active_flows
    ));
    common::write_json("BENCH_t2_cct.json", &json);
}
