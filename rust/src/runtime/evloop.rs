//! Event-loop primitives for the long-lived service runtime.
//!
//! The live coordinator (`service::coordinator`) is a daemon: it ingests
//! agent messages and registration ops from an mpsc channel while firing a
//! wall-clock interval tick for checkpoints, watchdogs, and reconciliation.
//! This module factors that shape out of the coordinator so it can be unit
//! tested without a fabric:
//!
//! - [`EventLoop`] wraps an `mpsc::Receiver` with a deadline-driven tick:
//!   `poll()` blocks with `recv_timeout` until either an event arrives
//!   ([`Wake::Event`]), the next tick deadline passes ([`Wake::Tick`]), or
//!   every sender is gone ([`Wake::Closed`]). Ticks advance by a fixed
//!   period from the previous deadline (not from "now"), so a slow event
//!   burst cannot starve the interval work — the loop catches up one tick
//!   per poll until the deadline is ahead of the clock again.
//! - [`BufferPool`] is a trivial free-list for heap-backed values (the
//!   boomerang `free_reaction_sets` idiom): `take()` pops a recycled value
//!   or makes a fresh default, `put()` returns one. The coordinator pools
//!   per-agent schedule vectors so steady-state reallocation does not
//!   allocate.
//! - [`recycler`] builds the return path for buffers handed to other
//!   threads: agents push consumed schedule buffers into a
//!   [`RecycleSender`] and the coordinator drains the matching
//!   [`RecycleBin`] back into its [`BufferPool`] each cycle. Sends never
//!   block and ignore a closed bin (the buffer is simply dropped).
//!
//! None of this is async: the service is a handful of OS threads with
//! blocking channels, and the loop's only clock is `Instant`.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::{Duration, Instant};

/// What woke the loop: an event, the interval tick, or channel closure.
#[derive(Debug)]
pub enum Wake<T> {
    /// An event arrived before the tick deadline.
    Event(T),
    /// The tick deadline passed (possibly while waiting for an event).
    Tick,
    /// All senders dropped and the queue is drained; the loop is done.
    Closed,
}

/// A blocking receive loop with a fixed-period wall-clock tick.
#[derive(Debug)]
pub struct EventLoop<T> {
    rx: Receiver<T>,
    period: Duration,
    next_tick: Instant,
    events: u64,
    ticks: u64,
}

impl<T> EventLoop<T> {
    /// Wrap `rx` with a tick every `period`, the first one `period` from now.
    pub fn new(rx: Receiver<T>, period: Duration) -> Self {
        EventLoop { rx, period, next_tick: Instant::now() + period, events: 0, ticks: 0 }
    }

    /// Block until the next event, tick, or closure.
    ///
    /// The tick deadline is checked first so interval work cannot be
    /// starved by a saturated queue; when a `recv_timeout` expires, the
    /// deadline advances by one `period` from its previous value.
    pub fn poll(&mut self) -> Wake<T> {
        let now = Instant::now();
        if now >= self.next_tick {
            self.next_tick += self.period;
            self.ticks += 1;
            return Wake::Tick;
        }
        match self.rx.recv_timeout(self.next_tick - now) {
            Ok(ev) => {
                self.events += 1;
                Wake::Event(ev)
            }
            Err(RecvTimeoutError::Timeout) => {
                self.next_tick += self.period;
                self.ticks += 1;
                Wake::Tick
            }
            Err(RecvTimeoutError::Disconnected) => Wake::Closed,
        }
    }

    /// Non-blocking drain step: the next queued event, if any.
    ///
    /// Used after a `poll()` wake to batch-drain the queue before doing
    /// per-cycle work. Returns `None` both when the queue is empty and
    /// when it is closed — `poll()` reports closure.
    pub fn try_next(&mut self) -> Option<T> {
        match self.rx.try_recv() {
            Ok(ev) => {
                self.events += 1;
                Some(ev)
            }
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }

    /// Current tick period.
    pub fn period(&self) -> Duration {
        self.period
    }

    /// Retarget the tick cadence (the live service's adaptive δ). The
    /// next deadline is re-anchored one new period from **now**: a
    /// stretch takes effect immediately instead of letting an
    /// already-late deadline fire a burst of catch-up ticks at the old
    /// cadence, and a shrink cannot schedule a deadline in the past.
    pub fn set_period(&mut self, period: Duration) {
        self.period = period;
        self.next_tick = Instant::now() + period;
    }

    /// Events delivered so far (via `poll` and `try_next`).
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Ticks fired so far.
    pub fn ticks(&self) -> u64 {
        self.ticks
    }
}

/// A free-list of reusable heap-backed values.
///
/// `take()` prefers a recycled value (counted in `reused`) over a fresh
/// `T::default()` (counted in `fresh`). Callers are responsible for
/// clearing whatever state they care about — the pool hands values back
/// as they were `put()`.
#[derive(Debug)]
pub struct BufferPool<T: Default> {
    free: Vec<T>,
    reused: u64,
    fresh: u64,
}

impl<T: Default> Default for BufferPool<T> {
    fn default() -> Self {
        BufferPool { free: Vec::new(), reused: 0, fresh: 0 }
    }
}

impl<T: Default> BufferPool<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pop a recycled value, or default-construct one.
    pub fn take(&mut self) -> T {
        match self.free.pop() {
            Some(v) => {
                self.reused += 1;
                v
            }
            None => {
                self.fresh += 1;
                T::default()
            }
        }
    }

    /// Return a value to the free-list.
    pub fn put(&mut self, v: T) {
        self.free.push(v);
    }

    /// How many `take()` calls were satisfied from the free-list.
    pub fn reused(&self) -> u64 {
        self.reused
    }

    /// How many `take()` calls had to default-construct.
    pub fn fresh(&self) -> u64 {
        self.fresh
    }
}

/// The producer half of a buffer return path; clone one per consumer
/// thread. Sends never block and never fail visibly — if the bin is gone
/// the buffer is dropped, which is always correct (just not recycled).
#[derive(Debug, Clone)]
pub struct RecycleSender<T> {
    tx: Sender<T>,
}

impl<T> RecycleSender<T> {
    /// Hand a consumed buffer back for reuse.
    pub fn give(&self, v: T) {
        let _ = self.tx.send(v);
    }
}

/// The consumer half: drained by the owning loop into its [`BufferPool`].
#[derive(Debug)]
pub struct RecycleBin<T> {
    rx: Receiver<T>,
}

impl<T: Default> RecycleBin<T> {
    /// Move every boomeranged buffer into `pool`; returns how many.
    pub fn drain_into(&self, pool: &mut BufferPool<T>) -> usize {
        let mut n = 0;
        while let Ok(v) = self.rx.try_recv() {
            pool.put(v);
            n += 1;
        }
        n
    }
}

/// Build a buffer return path: clone the sender into consumer threads,
/// keep the bin on the owning loop.
pub fn recycler<T>() -> (RecycleSender<T>, RecycleBin<T>) {
    let (tx, rx) = mpsc::channel();
    (RecycleSender { tx }, RecycleBin { rx })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn poll_delivers_events_then_closes() {
        let (tx, rx) = mpsc::channel();
        let mut lp = EventLoop::new(rx, Duration::from_secs(60));
        tx.send(1u32).unwrap();
        tx.send(2u32).unwrap();
        drop(tx);
        match lp.poll() {
            Wake::Event(v) => assert_eq!(v, 1),
            other => panic!("expected event, got {other:?}"),
        }
        assert_eq!(lp.try_next(), Some(2));
        assert!(lp.try_next().is_none());
        assert!(matches!(lp.poll(), Wake::Closed));
        assert_eq!(lp.events(), 2);
    }

    #[test]
    fn poll_ticks_on_idle_queue() {
        let (tx, rx) = mpsc::channel::<u32>();
        let mut lp = EventLoop::new(rx, Duration::from_millis(5));
        assert!(matches!(lp.poll(), Wake::Tick));
        assert!(matches!(lp.poll(), Wake::Tick));
        assert!(lp.ticks() >= 2);
        drop(tx);
    }

    #[test]
    fn tick_fires_even_under_event_pressure() {
        // a sender that never stops: the deadline check at the top of
        // poll() must still let ticks through.
        let (tx, rx) = mpsc::channel();
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stop2 = std::sync::Arc::clone(&stop);
        let feeder = thread::spawn(move || {
            while !stop2.load(std::sync::atomic::Ordering::Relaxed) {
                if tx.send(0u32).is_err() {
                    break;
                }
            }
        });
        let mut lp = EventLoop::new(rx, Duration::from_millis(2));
        let deadline = Instant::now() + Duration::from_millis(500);
        while lp.ticks() == 0 && Instant::now() < deadline {
            let _ = lp.poll();
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        assert!(lp.ticks() >= 1, "tick starved by event stream");
        assert!(lp.events() > 0);
        drop(lp);
        feeder.join().unwrap();
    }

    #[test]
    fn set_period_retargets_tick() {
        let (tx, rx) = mpsc::channel::<u32>();
        let mut lp = EventLoop::new(rx, Duration::from_secs(60));
        assert_eq!(lp.period(), Duration::from_secs(60));
        // shrinking re-anchors the deadline from now: the next poll ticks
        // within milliseconds instead of a minute out
        lp.set_period(Duration::from_millis(2));
        assert!(matches!(lp.poll(), Wake::Tick));
        assert_eq!(lp.period(), Duration::from_millis(2));
        drop(tx);
    }

    #[test]
    fn buffer_pool_recycles() {
        let mut pool: BufferPool<Vec<u32>> = BufferPool::new();
        let mut a = pool.take();
        a.push(7);
        pool.put(a);
        let b = pool.take();
        // pooled values come back as-is; callers clear what they reuse
        assert_eq!(b, vec![7]);
        assert_eq!(pool.reused(), 1);
        assert_eq!(pool.fresh(), 1);
    }

    #[test]
    fn recycler_boomerangs_buffers_across_threads() {
        let (tx, bin) = recycler::<Vec<u32>>();
        let t = thread::spawn(move || {
            tx.give(vec![1, 2, 3]);
            tx.give(Vec::new());
        });
        t.join().unwrap();
        let mut pool = BufferPool::new();
        assert_eq!(bin.drain_into(&mut pool), 2);
        let _ = pool.take();
        let _ = pool.take();
        assert_eq!(pool.reused(), 2);
        assert_eq!(pool.fresh(), 0);
    }

    #[test]
    fn give_after_bin_drop_is_silent() {
        let (tx, bin) = recycler::<Vec<u32>>();
        drop(bin);
        tx.give(vec![1]); // must not panic
    }
}
