//! PJRT runtime: load the AOT artifacts and run them from the rust hot
//! path. Python never executes at scheduling time — `make artifacts` lowers
//! the JAX/Pallas graphs to HLO text once; this module compiles them on the
//! PJRT CPU client at startup and exposes typed entry points.
//!
//! The native fallback (`native_*` functions) implements the identical math
//! in rust so the simulator and tests run without artifacts; parity between
//! the two paths is asserted in `rust/tests/runtime_parity.rs`.

pub mod evloop;

#[cfg(feature = "pjrt")]
mod engine;
/// Without the `pjrt` feature (no `xla` crate / XLA extension library),
/// the engine is a stub whose `load` always fails — callers fall back to
/// the `native_*` path below.
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
mod engine;
mod features;

pub use engine::{Engine, ScorerOutput};
pub use features::{BatchFeatures, ShapeManifest};

use crate::Bytes;

/// Native mirror of the L1 estimator kernel: masked mean × nflows.
pub fn native_estimate(sizes: &[Bytes], nflows: f64) -> f64 {
    if sizes.is_empty() {
        return 0.0;
    }
    let mean = sizes.iter().sum::<f64>() / sizes.len() as f64;
    mean * nflows
}

/// Native mirror of the L1 contention kernel for one occupancy matrix:
/// `contention[c] = Σ_{c'≠c} |ports(c) ∩ ports(c')| / |ports(c)|`.
pub fn native_contention(occ: &[Vec<f32>]) -> Vec<f32> {
    let c = occ.len();
    let mut out = vec![0.0f32; c];
    for i in 0..c {
        let width: f32 = occ[i].iter().sum();
        if width <= 0.0 {
            continue;
        }
        let mut total = 0.0f32;
        for j in 0..c {
            if i == j {
                continue;
            }
            total += occ[i]
                .iter()
                .zip(occ[j].iter())
                .map(|(a, b)| a * b)
                .sum::<f32>();
        }
        out[i] = total / width.max(1.0);
    }
    out
}

/// Native mirror of the L2 score composition.
pub fn native_score(est: f64, done: f64, contention: f64, weight: f64) -> f64 {
    (est - done).max(0.0) * (1.0 + weight * contention)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_estimate_mean_times_n() {
        assert_eq!(native_estimate(&[10.0, 20.0, 30.0], 100.0), 2000.0);
        assert_eq!(native_estimate(&[], 100.0), 0.0);
    }

    #[test]
    fn native_contention_pairwise() {
        // coflow 0 on ports {0,1,2,3}, coflow 1 on {2,3,4,5}: overlap 2/4
        let mut occ = vec![vec![0.0f32; 8]; 2];
        for p in 0..4 {
            occ[0][p] = 1.0;
        }
        for p in 2..6 {
            occ[1][p] = 1.0;
        }
        let c = native_contention(&occ);
        assert_eq!(c, vec![0.5, 0.5]);
    }

    #[test]
    fn native_score_clamps() {
        assert_eq!(native_score(10.0, 100.0, 1.0, 0.5), 0.0);
        assert_eq!(native_score(100.0, 0.0, 2.0, 0.5), 200.0);
    }
}
