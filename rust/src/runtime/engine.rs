//! The PJRT engine: compile `artifacts/*.hlo.txt` once, execute per batch.

use super::features::{BatchFeatures, ShapeManifest};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Outputs of one scorer execution, trimmed to the live rows.
#[derive(Debug, Clone, PartialEq)]
pub struct ScorerOutput {
    /// Priority score (lower = sooner).
    pub score: Vec<f32>,
    /// Size estimate (mean × nflows).
    pub est: Vec<f32>,
    /// Bootstrap lower-confidence-bound estimate.
    pub lcb: Vec<f32>,
    /// Per-coflow contention.
    pub contention: Vec<f32>,
}

/// Compiled AOT artifacts on a PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    scorer: xla::PjRtLoadedExecutable,
    estimator: xla::PjRtLoadedExecutable,
    contention: xla::PjRtLoadedExecutable,
    pub manifest: ShapeManifest,
    dir: PathBuf,
}

impl Engine {
    /// Load and compile all artifacts from `dir` (default `artifacts/`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ShapeManifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(anyhow::Error::msg)?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(anyhow::Error::msg)
            .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(anyhow::Error::msg)
                .with_context(|| format!("compiling {name}"))
        };
        Ok(Engine {
            scorer: compile("scorer")?,
            estimator: compile("estimator")?,
            contention: compile("contention")?,
            client,
            manifest,
            dir,
        })
    }

    /// PJRT platform (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact directory this engine was loaded from.
    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    fn lit2(data: &[f32], d0: usize, d1: usize) -> Result<xla::Literal> {
        xla::Literal::vec1(data)
            .reshape(&[d0 as i64, d1 as i64])
            .map_err(anyhow::Error::msg)
    }

    fn lit1(data: &[f32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    /// Run the full scorer graph on a packed batch.
    pub fn score(&self, batch: &BatchFeatures, weight: f32) -> Result<ScorerOutput> {
        let (c, m, b, p) = (batch.c, batch.m, batch.b, batch.p);
        let sizes = Self::lit2(&batch.sizes, c, m)?;
        let mask = Self::lit2(&batch.mask, c, m)?;
        let nflows = Self::lit1(&batch.nflows);
        let w = xla::Literal::vec1(&batch.w)
            .reshape(&[c as i64, b as i64, m as i64])
            .map_err(anyhow::Error::msg)?;
        let done = Self::lit1(&batch.done);
        let occ = Self::lit2(&batch.occ, c, p)?;
        let weight = xla::Literal::scalar(weight);

        let result = self
            .scorer
            .execute::<xla::Literal>(&[sizes, mask, nflows, w, done, occ, weight])
            .map_err(anyhow::Error::msg)?[0][0]
            .to_literal_sync()
            .map_err(anyhow::Error::msg)?;
        let mut parts = result.to_tuple().map_err(anyhow::Error::msg)?;
        anyhow::ensure!(parts.len() == 4, "scorer returned {} outputs", parts.len());
        let contention = parts.pop().unwrap().to_vec::<f32>().map_err(anyhow::Error::msg)?;
        let lcb = parts.pop().unwrap().to_vec::<f32>().map_err(anyhow::Error::msg)?;
        let est = parts.pop().unwrap().to_vec::<f32>().map_err(anyhow::Error::msg)?;
        let score = parts.pop().unwrap().to_vec::<f32>().map_err(anyhow::Error::msg)?;
        let live = batch.live;
        Ok(ScorerOutput {
            score: score[..live].to_vec(),
            est: est[..live].to_vec(),
            lcb: lcb[..live].to_vec(),
            contention: contention[..live].to_vec(),
        })
    }

    /// Run only the estimator artifact: returns (est, lcb), trimmed.
    pub fn estimate(&self, batch: &BatchFeatures) -> Result<(Vec<f32>, Vec<f32>)> {
        let (c, m, b) = (batch.c, batch.m, batch.b);
        let sizes = Self::lit2(&batch.sizes, c, m)?;
        let mask = Self::lit2(&batch.mask, c, m)?;
        let nflows = Self::lit1(&batch.nflows);
        let w = xla::Literal::vec1(&batch.w)
            .reshape(&[c as i64, b as i64, m as i64])
            .map_err(anyhow::Error::msg)?;
        let result = self
            .estimator
            .execute::<xla::Literal>(&[sizes, mask, nflows, w])
            .map_err(anyhow::Error::msg)?[0][0]
            .to_literal_sync()
            .map_err(anyhow::Error::msg)?;
        let (est, lcb) = result.to_tuple2().map_err(anyhow::Error::msg)?;
        let est = est.to_vec::<f32>().map_err(anyhow::Error::msg)?;
        let lcb = lcb.to_vec::<f32>().map_err(anyhow::Error::msg)?;
        Ok((est[..batch.live].to_vec(), lcb[..batch.live].to_vec()))
    }

    /// Run only the contention artifact, trimmed to live rows.
    pub fn contention(&self, batch: &BatchFeatures) -> Result<Vec<f32>> {
        let occ = Self::lit2(&batch.occ, batch.c, batch.p)?;
        let result = self
            .contention
            .execute::<xla::Literal>(&[occ])
            .map_err(anyhow::Error::msg)?[0][0]
            .to_literal_sync()
            .map_err(anyhow::Error::msg)?;
        let out = result.to_tuple1().map_err(anyhow::Error::msg)?;
        let v = out.to_vec::<f32>().map_err(anyhow::Error::msg)?;
        Ok(v[..batch.live].to_vec())
    }
}
