//! Stub PJRT engine, compiled when the `pjrt` feature is off (no `xla`
//! crate available — e.g. vanilla CI runners without the XLA extension
//! library). `load` always fails with a clear message, so every caller
//! takes its existing missing-artifacts fallback: the simulator and the
//! service score through the `native_*` mirrors instead.

use super::features::ShapeManifest;
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

/// Outputs of one scorer execution, trimmed to the live rows (same shape
/// as the real engine's).
#[derive(Debug, Clone, PartialEq)]
pub struct ScorerOutput {
    /// Priority score (lower = sooner).
    pub score: Vec<f32>,
    /// Size estimate (mean × nflows).
    pub est: Vec<f32>,
    /// Bootstrap lower-confidence-bound estimate.
    pub lcb: Vec<f32>,
    /// Per-coflow contention.
    pub contention: Vec<f32>,
}

/// Never constructible: [`Engine::load`] always errors without `pjrt`.
pub struct Engine {
    pub manifest: ShapeManifest,
    dir: PathBuf,
}

impl Engine {
    pub fn load(_dir: impl AsRef<Path>) -> Result<Self> {
        bail!(
            "PJRT engine unavailable: this binary was built without the \
             `pjrt` feature (no `xla` crate); rebuild with \
             `cargo build --features pjrt` on an image that carries the \
             XLA extension library"
        );
    }

    /// PJRT platform (diagnostics).
    pub fn platform(&self) -> String {
        "stub".into()
    }

    /// Artifact directory this engine was loaded from.
    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn score(
        &self,
        _batch: &super::features::BatchFeatures,
        _weight: f32,
    ) -> Result<ScorerOutput> {
        bail!("PJRT engine unavailable (built without the `pjrt` feature)");
    }

    pub fn estimate(
        &self,
        _batch: &super::features::BatchFeatures,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        bail!("PJRT engine unavailable (built without the `pjrt` feature)");
    }

    pub fn contention(&self, _batch: &super::features::BatchFeatures) -> Result<Vec<f32>> {
        bail!("PJRT engine unavailable (built without the `pjrt` feature)");
    }
}
