//! Feature marshalling: pack per-coflow learning state into the padded
//! tensors the AOT artifacts expect, and the shape manifest emitted by
//! `python -m compile.aot`.

use anyhow::{bail, Context, Result};
use crate::util::{JsonValue, Rng};
use std::path::Path;

/// `artifacts/manifest.json` — the fixed AOT shapes.
#[derive(Debug, Clone)]
pub struct ShapeManifest {
    pub c: usize,
    pub m: usize,
    pub b: usize,
    pub p: usize,
    pub lcb_sigmas: f64,
    pub format: String,
}

impl ShapeManifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = JsonValue::parse(text).map_err(anyhow::Error::msg)?;
        let field = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(|x| x.as_usize())
                .with_context(|| format!("manifest missing integer field {k:?}"))
        };
        let m = ShapeManifest {
            c: field("C")?,
            m: field("M")?,
            b: field("B")?,
            p: field("P")?,
            lcb_sigmas: v
                .get("lcb_sigmas")
                .and_then(|x| x.as_f64())
                .context("manifest missing lcb_sigmas")?,
            format: v
                .get("format")
                .and_then(|x| x.as_str())
                .context("manifest missing format")?
                .to_string(),
        };
        if m.format != "hlo-text" {
            bail!("unexpected artifact format {:?}", m.format);
        }
        Ok(m)
    }
}

/// One scoring batch, padded to the manifest shapes.
#[derive(Debug, Clone)]
pub struct BatchFeatures {
    pub c: usize,
    pub m: usize,
    pub b: usize,
    pub p: usize,
    /// Row-major [C, M].
    pub sizes: Vec<f32>,
    /// Row-major [C, M].
    pub mask: Vec<f32>,
    /// [C].
    pub nflows: Vec<f32>,
    /// Row-major [C, B, M] bootstrap resample weights.
    pub w: Vec<f32>,
    /// [C].
    pub done: Vec<f32>,
    /// Row-major [C, P] occupancy.
    pub occ: Vec<f32>,
    /// Number of real (non-padding) coflow rows.
    pub live: usize,
}

impl BatchFeatures {
    pub fn new(manifest: &ShapeManifest) -> Self {
        BatchFeatures {
            c: manifest.c,
            m: manifest.m,
            b: manifest.b,
            p: manifest.p,
            sizes: vec![0.0; manifest.c * manifest.m],
            mask: vec![0.0; manifest.c * manifest.m],
            nflows: vec![1.0; manifest.c],
            w: vec![0.0; manifest.c * manifest.b * manifest.m],
            done: vec![0.0; manifest.c],
            occ: vec![0.0; manifest.c * manifest.p],
            live: 0,
        }
    }

    /// Fill row `row` for one coflow. `pilot_sizes` is truncated at `M`;
    /// `ports` are the coflow's occupied port directions encoded as
    /// `port` (uplink) and `P/2 + port` (downlink) indices.
    pub fn set_row(
        &mut self,
        row: usize,
        pilot_sizes: &[f64],
        nflows: usize,
        done_bytes: f64,
        ports: &[usize],
        boot_seed: u64,
    ) {
        assert!(row < self.c, "batch row {row} out of range");
        let m_c = pilot_sizes.len().min(self.m);
        for j in 0..self.m {
            let idx = row * self.m + j;
            if j < m_c {
                self.sizes[idx] = pilot_sizes[j] as f32;
                self.mask[idx] = 1.0;
            } else {
                self.sizes[idx] = 0.0;
                self.mask[idx] = 0.0;
            }
        }
        self.nflows[row] = nflows as f32;
        self.done[row] = done_bytes as f32;
        for x in &mut self.occ[row * self.p..(row + 1) * self.p] {
            *x = 0.0;
        }
        for &pt in ports {
            if pt < self.p {
                self.occ[row * self.p + pt] = 1.0;
            }
        }
        // Bootstrap weights: counts/m over the valid slots, deterministic
        // from the seed (the same SmallRng stream errcorr::bootstrap uses).
        let wrow = &mut self.w[row * self.b * self.m..(row + 1) * self.b * self.m];
        for x in wrow.iter_mut() {
            *x = 0.0;
        }
        if m_c > 0 {
            let mut rng = Rng::seed_from_u64(boot_seed);
            for bi in 0..self.b {
                for _ in 0..m_c {
                    let k = rng.below(m_c);
                    wrow[bi * self.m + k] += 1.0 / m_c as f32;
                }
            }
        }
        self.live = self.live.max(row + 1);
    }

    /// Reset to an all-padding batch (reuse the allocation).
    pub fn clear(&mut self) {
        self.sizes.iter_mut().for_each(|x| *x = 0.0);
        self.mask.iter_mut().for_each(|x| *x = 0.0);
        self.nflows.iter_mut().for_each(|x| *x = 1.0);
        self.w.iter_mut().for_each(|x| *x = 0.0);
        self.done.iter_mut().for_each(|x| *x = 0.0);
        self.occ.iter_mut().for_each(|x| *x = 0.0);
        self.live = 0;
    }

    /// The occupancy matrix as rows (for the native contention fallback).
    pub fn occ_rows(&self) -> Vec<Vec<f32>> {
        (0..self.live)
            .map(|r| self.occ[r * self.p..(r + 1) * self.p].to_vec())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> ShapeManifest {
        ShapeManifest {
            c: 8,
            m: 4,
            b: 10,
            p: 16,
            lcb_sigmas: 3.0,
            format: "hlo-text".into(),
        }
    }

    #[test]
    fn set_row_packs_and_masks() {
        let mut b = BatchFeatures::new(&manifest());
        b.set_row(2, &[10.0, 20.0], 100, 5.0, &[1, 8 + 3], 42);
        assert_eq!(b.sizes[2 * 4], 10.0);
        assert_eq!(b.sizes[2 * 4 + 1], 20.0);
        assert_eq!(b.mask[2 * 4..2 * 4 + 4], [1.0, 1.0, 0.0, 0.0]);
        assert_eq!(b.nflows[2], 100.0);
        assert_eq!(b.done[2], 5.0);
        assert_eq!(b.occ[2 * 16 + 1], 1.0);
        assert_eq!(b.occ[2 * 16 + 11], 1.0);
        assert_eq!(b.live, 3);
        // W rows sum to 1 per resample
        for bi in 0..10 {
            let s: f32 = b.w[(2 * 10 + bi) * 4..(2 * 10 + bi) * 4 + 4].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn truncates_excess_pilots() {
        let mut b = BatchFeatures::new(&manifest());
        b.set_row(0, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 10, 0.0, &[], 1);
        let mask: f32 = b.mask[0..4].iter().sum();
        assert_eq!(mask, 4.0);
    }

    #[test]
    fn clear_resets_live() {
        let mut b = BatchFeatures::new(&manifest());
        b.set_row(5, &[1.0], 1, 0.0, &[0], 9);
        b.clear();
        assert_eq!(b.live, 0);
        assert!(b.w.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn deterministic_w_given_seed() {
        let mut a = BatchFeatures::new(&manifest());
        let mut b = BatchFeatures::new(&manifest());
        a.set_row(0, &[1.0, 2.0, 3.0], 5, 0.0, &[], 77);
        b.set_row(0, &[1.0, 2.0, 3.0], 5, 0.0, &[], 77);
        assert_eq!(a.w, b.w);
    }
}
