//! The live coordinator service: an OS thread for the global coordinator
//! and one local-agent thread per port, exchanging the §3 message
//! vocabulary over channels. Unlike the discrete-event simulator (which
//! *models* message costs), this mode **measures** the coordinator's
//! per-interval phases — update-receive, rate-calculation, new-rate-send —
//! in wall-clock time, which is how Tables 3 and 4 were produced on the
//! paper's testbed.
//!
//! The service also exercises the full three-layer stack: with
//! [`ServiceConfig::engine_dir`] set, Philae's scoring runs through the AOT
//! PJRT artifacts (L2 scorer composed of the L1 Pallas kernels) instead of
//! the native fallback.

mod coordinator;
mod ops;

pub use coordinator::{
    run_service, run_soak, Input, ServiceConfig, ServiceReport, SERVICE_RECONCILE_INTERVALS,
};
pub use ops::{CoflowOp, OpsHandle};
