//! Coflow operations API (§3): the coordinator runs independently from any
//! compute framework and exposes `register` / `deregister` / `update`.
//! Frameworks drive it through an [`OpsHandle`]; the trace replayer in
//! `coordinator.rs` is just one such client.

use crate::runtime::evloop::RecycleSender;
use crate::trace::TraceRecord;
use crate::CoflowId;
use std::sync::mpsc;

/// One coflow operation.
#[derive(Debug)]
pub enum CoflowOp {
    /// Register a new coflow; replies with the dense id assigned.
    Register {
        record: TraceRecord,
        reply: mpsc::SyncSender<CoflowId>,
        /// When set, the coordinator hands the consumed `record` (cleared)
        /// back through this path so a high-rate registrar can recycle
        /// buffers via a [`crate::runtime::evloop::BufferPool`] instead of
        /// allocating fresh mapper/reducer vectors per registration.
        recycle: Option<RecycleSender<TraceRecord>>,
    },
    /// Remove a coflow (job exit / kill): its unfinished flows are dropped.
    Deregister { coflow: CoflowId },
    /// Structure change (task migration, restart): replace the unfinished
    /// part of the coflow with the new record's flows.
    Update {
        coflow: CoflowId,
        record: TraceRecord,
    },
    /// Finish the run: no more operations will arrive.
    Seal,
}

/// Client handle to the coordinator's ops endpoint.
#[derive(Clone)]
pub struct OpsHandle {
    pub(crate) tx: mpsc::Sender<super::coordinator::Input>,
}

impl OpsHandle {
    /// Register a coflow and await its id.
    pub fn register(&self, record: TraceRecord) -> Option<CoflowId> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(super::coordinator::Input::Op(CoflowOp::Register {
                record,
                reply,
                recycle: None,
            }))
            .ok()?;
        rx.recv().ok()
    }

    pub fn deregister(&self, coflow: CoflowId) {
        let _ = self
            .tx
            .send(super::coordinator::Input::Op(CoflowOp::Deregister { coflow }));
    }

    pub fn update(&self, coflow: CoflowId, record: TraceRecord) {
        let _ = self
            .tx
            .send(super::coordinator::Input::Op(CoflowOp::Update { coflow, record }));
    }

    pub fn seal(&self) {
        let _ = self.tx.send(super::coordinator::Input::Op(CoflowOp::Seal));
    }
}
