//! The coordinator thread, the agent threads, and the trace replayer.
//!
//! The coordinator uses **batched admission**: every wake-up drains the
//! whole input queue — coflow registrations, teardown ops, and agent
//! completion reports alike — applies all of them to the world, and then
//! runs **one** order repair + rate allocation for the burst (previously
//! each registration triggered its own reallocation). Allocation itself
//! can run the port-sharded parallel pipeline via
//! [`ServiceConfig::alloc_shards`].

use super::ops::{CoflowOp, OpsHandle};
use crate::agents::{AgentMsg, AgentSim, CoordMsg};
use crate::coflow::{CoflowPhase, CoflowState, FlowState};
use crate::coordinator::{
    philae::{CompletionOutcome, PhilaeCore},
    rate, AaloScheduler, Plan, Scheduler, SchedulerConfig, SchedulerKind, World,
};
use crate::fabric::{Fabric, PortLoad};
use crate::metrics::{IntervalStats, RunningStat};
use crate::runtime::{BatchFeatures, Engine};
use crate::trace::{Trace, TraceRecord};
use crate::{CoflowId, FlowId, PortId, Time};
use anyhow::Result;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

/// Everything the coordinator thread receives, merged onto one channel
/// (std mpsc has no select).
#[derive(Debug)]
pub enum Input {
    Op(CoflowOp),
    Agent(AgentMsg),
}

/// Configuration of a live service run.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub kind: SchedulerKind,
    pub sched: SchedulerConfig,
    /// Simulated seconds per wall second (trace replay acceleration).
    pub time_scale: f64,
    /// Coordinator scheduling interval in wall time (the paper's δ).
    pub delta_wall: Duration,
    /// Load AOT artifacts from here and score through PJRT (Philae only).
    pub engine_dir: Option<PathBuf>,
    /// Port line rate in bytes per *simulated* second.
    pub port_rate: f64,
    /// Worker shards for `rate::allocate_into` (0/1 = serial; the sharded
    /// pipeline is bit-identical and pays off on multi-thousand port
    /// fabrics).
    pub alloc_shards: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            kind: SchedulerKind::Philae,
            sched: SchedulerConfig::default(),
            time_scale: 20.0,
            delta_wall: Duration::from_millis(8),
            engine_dir: None,
            port_rate: crate::GBPS,
            alloc_shards: 1,
        }
    }
}

/// Measured outcome of a service run (Tables 3/4 in wall time).
#[derive(Debug, Clone)]
pub struct ServiceReport {
    pub scheduler: String,
    /// Per-coflow CCT in *simulated* seconds.
    pub ccts: Vec<Time>,
    pub makespan: Time,
    pub intervals: IntervalStats,
    /// Measured per-interval phase times (seconds, wall).
    pub rate_calc: RunningStat,
    pub rate_send: RunningStat,
    pub update_recv: RunningStat,
    pub rate_msgs: u64,
    pub update_msgs: u64,
    pub rate_calcs: u64,
    /// Fraction of intervals whose coordinator work exceeded δ.
    pub missed_fraction: f64,
    /// Fraction of intervals with no rate flush at all.
    pub idle_rate_fraction: f64,
    /// Whether scoring ran through the PJRT engine.
    pub used_engine: bool,
    pub wall_seconds: f64,
}

impl ServiceReport {
    pub fn avg_cct(&self) -> f64 {
        crate::metrics::mean(&self.ccts)
    }
}

/// Run `trace` through the live coordinator + agents; returns when every
/// coflow has completed.
pub fn run_service(trace: &Trace, cfg: &ServiceConfig) -> Result<ServiceReport> {
    let (input_tx, input_rx) = mpsc::channel::<Input>();
    let handle = OpsHandle { tx: input_tx.clone() };

    // Trace replayer: registers coflows at scaled arrival times.
    let records: Vec<TraceRecord> = trace
        .coflows
        .iter()
        .map(|c| {
            let mut per_red: HashMap<PortId, f64> = HashMap::new();
            for &f in &c.flows {
                *per_red.entry(trace.flows[f].dst).or_insert(0.0) += trace.flows[f].size;
            }
            let mut reducers: Vec<(usize, f64)> = per_red.into_iter().collect();
            reducers.sort_unstable_by(|a, b| a.0.cmp(&b.0));
            TraceRecord {
                external_id: c.external_id,
                arrival: c.arrival,
                mappers: c.senders.clone(),
                reducers,
            }
        })
        .collect();
    let time_scale = cfg.time_scale;
    let replayer = thread::spawn(move || {
        let start = Instant::now();
        for rec in records {
            let due = Duration::from_secs_f64(rec.arrival / time_scale);
            let elapsed = start.elapsed();
            if due > elapsed {
                thread::sleep(due - elapsed);
            }
            let _ = handle.register(rec);
        }
        handle.seal();
    });

    let report = Coordinator::new(trace.num_ports, cfg, input_tx)?.run(input_rx);
    let _ = replayer.join();
    report
}

struct AgentHandle {
    tx: mpsc::Sender<CoordMsg>,
}

/// What a drained input batch requires of the coordinator afterwards.
#[derive(Debug, Clone, Copy, Default)]
struct DrainOutcome {
    /// Something changed that affects rates (event-triggered policies
    /// reallocate; periodic ones wait for their tick).
    need_realloc: bool,
    /// Reallocate regardless of policy (explicit coflow teardown must free
    /// its rates immediately rather than at the next tick).
    force_realloc: bool,
}

impl DrainOutcome {
    fn merge(self, other: DrainOutcome) -> DrainOutcome {
        DrainOutcome {
            need_realloc: self.need_realloc || other.need_realloc,
            force_realloc: self.force_realloc || other.force_realloc,
        }
    }
}

struct Coordinator {
    cfg: ServiceConfig,
    world: World,
    philae: Option<PhilaeCore>,
    aalo: Option<AaloScheduler>,
    engine: Option<Engine>,
    batch: Option<BatchFeatures>,
    agents: Vec<AgentHandle>,
    input_tx: mpsc::Sender<Input>,
    agent_threads: Vec<thread::JoinHandle<()>>,
    port_refs: Vec<Vec<(PortId, usize)>>, // per coflow: (src port, active refs)
    port_refs_down: Vec<Vec<(PortId, usize)>>,
    /// Reused scheduling plan (see `Scheduler::order_into`).
    plan: Plan,
    /// Reused allocation workspace shared with the simulator's hot path.
    scratch: rate::AllocScratch,
    last_rates: HashMap<FlowId, f64>,
    /// Cached PJRT scores; refreshed only when the estimated set changes
    /// (new estimate / coflow completion / arrival), not per event — one
    /// scorer batch costs ~ms, reallocs happen per completion report.
    cached_scores: HashMap<CoflowId, f64>,
    scores_dirty: bool,
    sealed: bool,
    seq: u64,
    start: Instant,
    // measured accounting
    stats: IntervalStats,
    rate_calc: RunningStat,
    rate_send: RunningStat,
    update_recv: RunningStat,
    iv_calc: f64,
    iv_send: f64,
    iv_recv: f64,
    iv_updates: u64,
    iv_rate_msgs: u64,
    iv_rate_calcs: u64,
    rate_msgs: u64,
    update_msgs: u64,
    rate_calcs: u64,
}

impl Coordinator {
    fn new(num_ports: usize, cfg: &ServiceConfig, input_tx: mpsc::Sender<Input>) -> Result<Self> {
        let engine = match (&cfg.engine_dir, cfg.kind) {
            (Some(dir), SchedulerKind::Philae) => Some(Engine::load(dir)?),
            _ => None,
        };
        let batch = engine.as_ref().map(|e| BatchFeatures::new(&e.manifest));
        let world = World {
            now: 0.0,
            flows: Vec::new(),
            coflows: Vec::new(),
            fabric: Fabric::homogeneous(num_ports, cfg.port_rate),
            load: PortLoad::new(num_ports),
            active: Vec::new(),
        };
        let philae = matches!(cfg.kind, SchedulerKind::Philae)
            .then(|| PhilaeCore::new(cfg.sched.clone()));
        let aalo =
            matches!(cfg.kind, SchedulerKind::Aalo).then(|| AaloScheduler::new(cfg.sched.clone()));
        anyhow::ensure!(
            philae.is_some() || aalo.is_some(),
            "service mode supports philae and aalo (got {:?})",
            cfg.kind
        );
        Ok(Coordinator {
            cfg: cfg.clone(),
            world,
            philae,
            aalo,
            engine,
            batch,
            agents: Vec::new(),
            input_tx,
            agent_threads: Vec::new(),
            port_refs: Vec::new(),
            port_refs_down: Vec::new(),
            plan: Plan::default(),
            scratch: {
                let mut s = rate::AllocScratch::new();
                s.set_shards(cfg.alloc_shards);
                s
            },
            last_rates: HashMap::new(),
            cached_scores: HashMap::new(),
            scores_dirty: true,
            sealed: false,
            seq: 0,
            start: Instant::now(),
            stats: IntervalStats::default(),
            rate_calc: RunningStat::default(),
            rate_send: RunningStat::default(),
            update_recv: RunningStat::default(),
            iv_calc: 0.0,
            iv_send: 0.0,
            iv_recv: 0.0,
            iv_updates: 0,
            iv_rate_msgs: 0,
            iv_rate_calcs: 0,
            rate_msgs: 0,
            update_msgs: 0,
            rate_calcs: 0,
        })
    }

    fn spawn_agents(&mut self) {
        let n = self.world.fabric.num_ports;
        let aalo_updates = self.aalo.is_some();
        for port in 0..n {
            let (tx, rx) = mpsc::channel::<CoordMsg>();
            let up = self.input_tx.clone();
            let scale = self.cfg.time_scale;
            let delta = self.cfg.delta_wall;
            let th = thread::spawn(move || {
                let mut sim = AgentSim::new(port);
                let start = Instant::now();
                let mut last = Instant::now();
                let mut next_tick = Instant::now() + delta;
                loop {
                    let now = Instant::now();
                    let mut wait = Duration::from_millis(200);
                    if let Some(s) = sim.next_completion() {
                        wait = wait.min(Duration::from_secs_f64((s / scale).max(0.0)));
                    }
                    if aalo_updates {
                        wait = wait.min(next_tick.saturating_duration_since(now));
                    }
                    let msg = rx.recv_timeout(wait);
                    // advance local flows to 'now' first, reporting completions
                    let dt = last.elapsed().as_secs_f64() * scale;
                    last = Instant::now();
                    let sim_now = start.elapsed().as_secs_f64() * scale;
                    for m in sim.advance(dt, sim_now) {
                        let _ = up.send(Input::Agent(m));
                    }
                    match msg {
                        Ok(CoordMsg::AddFlow { flow, coflow, size, pilot }) => {
                            sim.add_flow(flow, coflow, size, pilot);
                        }
                        Ok(CoordMsg::NewSchedule { rates }) => {
                            sim.apply_schedule(&rates);
                        }
                        Ok(CoordMsg::Shutdown) => break,
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                    if aalo_updates && Instant::now() >= next_tick {
                        if sim.active_flows() > 0 {
                            for m in sim.byte_updates() {
                                let _ = up.send(Input::Agent(m));
                            }
                        }
                        next_tick += delta;
                    }
                }
            });
            self.agents.push(AgentHandle { tx });
            self.agent_threads.push(th);
        }
    }

    fn run(mut self, input_rx: mpsc::Receiver<Input>) -> Result<ServiceReport> {
        self.spawn_agents();
        let mut next_tick = Instant::now() + self.cfg.delta_wall;

        loop {
            if self.sealed && self.world.active.is_empty() && !self.world.coflows.is_empty() {
                break;
            }
            let wait = next_tick.saturating_duration_since(Instant::now());
            match input_rx.recv_timeout(wait) {
                // Batched admission: drain *everything* queued — coflow ops
                // (register/deregister/update) and agent messages alike —
                // into one batch, then pay a single order repair +
                // allocation for the whole burst instead of one
                // reallocation per admit.
                Ok(first) => {
                    let t0 = Instant::now();
                    let mut outcome = self.handle_input(first);
                    while let Ok(next) = input_rx.try_recv() {
                        outcome = outcome.merge(self.handle_input(next));
                    }
                    self.iv_recv += t0.elapsed().as_secs_f64();
                    // Philae reallocates on any event; periodic (Aalo)
                    // pipelines flush at the δ tick, except for explicit
                    // coflow teardown, which frees rates immediately.
                    if (outcome.need_realloc && self.philae.is_some()) || outcome.force_realloc {
                        self.reallocate();
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            if Instant::now() >= next_tick {
                self.on_interval();
                next_tick += self.cfg.delta_wall;
            }
        }

        for a in &self.agents {
            let _ = a.tx.send(CoordMsg::Shutdown);
        }
        for th in self.agent_threads.drain(..) {
            let _ = th.join();
        }
        let ccts: Vec<Time> = self
            .world
            .coflows
            .iter()
            .map(|c| c.cct().unwrap_or(f64::NAN))
            .collect();
        Ok(ServiceReport {
            scheduler: if self.philae.is_some() {
                "philae".into()
            } else {
                "aalo".into()
            },
            ccts,
            makespan: self.start.elapsed().as_secs_f64() * self.cfg.time_scale,
            missed_fraction: self.stats.missed_fraction(),
            idle_rate_fraction: self.stats.idle_rate_fraction(),
            intervals: self.stats,
            rate_calc: self.rate_calc,
            rate_send: self.rate_send,
            update_recv: self.update_recv,
            rate_msgs: self.rate_msgs,
            update_msgs: self.update_msgs,
            rate_calcs: self.rate_calcs,
            used_engine: self.engine.is_some(),
            wall_seconds: self.start.elapsed().as_secs_f64(),
        })
    }

    /// δ interval boundary: Aalo's periodic pipeline; interval accounting
    /// for everyone.
    fn on_interval(&mut self) {
        if self.aalo.is_some() {
            if !self.world.active.is_empty() {
                let mut aalo = self.aalo.take().unwrap();
                aalo.on_tick(&mut self.world);
                self.aalo = Some(aalo);
                self.reallocate(); // Aalo flushes rates every interval
            }
        }
        let busy =
            !self.world.active.is_empty() || self.iv_rate_calcs > 0 || self.iv_updates > 0;
        if busy {
            self.rate_calc.push(self.iv_calc);
            self.rate_send.push(self.iv_send);
            self.update_recv.push(self.iv_recv);
            self.stats.push_interval(
                self.cfg.delta_wall.as_secs_f64(),
                self.iv_calc,
                self.iv_send,
                self.iv_recv,
                self.iv_updates,
                self.iv_rate_msgs,
                self.iv_rate_calcs,
            );
        }
        self.iv_calc = 0.0;
        self.iv_send = 0.0;
        self.iv_recv = 0.0;
        self.iv_updates = 0;
        self.iv_rate_msgs = 0;
        self.iv_rate_calcs = 0;
    }

    fn sim_now(&self) -> Time {
        self.start.elapsed().as_secs_f64() * self.cfg.time_scale
    }

    /// Register a coflow: extend the world, notify src agents, run the
    /// scheduler's arrival hook.
    fn register(&mut self, rec: &TraceRecord) -> CoflowId {
        let cid = self.world.coflows.len();
        let now = self.sim_now();
        let mut flow_ids = Vec::new();
        let mut total = 0.0;
        for &(dst, reducer_bytes) in &rec.reducers {
            let per_flow = reducer_bytes / rec.mappers.len() as f64;
            for &src in &rec.mappers {
                let fid = self.world.flows.len();
                self.world
                    .flows
                    .push(FlowState::new(fid, cid, src, dst, per_flow));
                flow_ids.push(fid);
                total += per_flow;
            }
        }
        let mut c = CoflowState::new(cid, now, flow_ids.clone(), total, self.seq);
        self.seq += 1;
        c.phase = CoflowPhase::Running;
        c.senders = rec.mappers.clone();
        c.senders.sort_unstable();
        c.senders.dedup();
        c.receivers = rec.reducers.iter().map(|&(p, _)| p).collect();
        c.receivers.sort_unstable();
        c.receivers.dedup();
        for (i, &fid) in c.active_list.iter().enumerate() {
            self.world.flows[fid].active_pos = i;
        }
        self.world.coflows.push(c);
        self.world.active.push(cid);

        // port refs + load
        let mut up: Vec<(PortId, usize)> = Vec::new();
        let mut down: Vec<(PortId, usize)> = Vec::new();
        for &f in &flow_ids {
            let fl = self.world.flows[f];
            self.world.load.up_bytes[fl.src] += fl.size;
            self.world.load.down_bytes[fl.dst] += fl.size;
            match up.iter_mut().find(|(p, _)| *p == fl.src) {
                Some(e) => e.1 += 1,
                None => up.push((fl.src, 1)),
            }
            match down.iter_mut().find(|(p, _)| *p == fl.dst) {
                Some(e) => e.1 += 1,
                None => down.push((fl.dst, 1)),
            }
        }
        for &(p, _) in &up {
            self.world.load.occupy_up(p);
        }
        for &(p, _) in &down {
            self.world.load.occupy_down(p);
        }
        self.port_refs.push(up);
        self.port_refs_down.push(down);

        self.scores_dirty = true;
        // scheduler arrival hooks (Philae marks pilots here)
        if let Some(mut ph) = self.philae.take() {
            ph.handle_arrival(cid, &mut self.world);
            self.philae = Some(ph);
        }
        if let Some(mut aalo) = self.aalo.take() {
            aalo.on_arrival(cid, &mut self.world);
            self.aalo = Some(aalo);
        }

        // ship flows to their src agents
        for &f in &flow_ids {
            let fl = self.world.flows[f];
            let _ = self.agents[fl.src].tx.send(CoordMsg::AddFlow {
                flow: f,
                coflow: cid,
                size: fl.size,
                pilot: fl.pilot,
            });
        }
        cid
    }

    /// Deregister: drop unfinished flows and release port state.
    fn deregister(&mut self, cid: CoflowId) {
        if cid >= self.world.coflows.len() || self.world.coflows[cid].done() {
            return;
        }
        let now = self.sim_now();
        let flow_ids = self.world.coflows[cid].flows.clone();
        for f in flow_ids {
            if !self.world.flows[f].done() {
                self.world.flows[f].finished_at = Some(now);
                self.last_rates.remove(&f);
                let fl = self.world.flows[f];
                self.world.load.up_bytes[fl.src] =
                    (self.world.load.up_bytes[fl.src] - fl.size).max(0.0);
                self.world.load.down_bytes[fl.dst] =
                    (self.world.load.down_bytes[fl.dst] - fl.size).max(0.0);
            }
        }
        for i in 0..self.port_refs[cid].len() {
            let (p, n) = self.port_refs[cid][i];
            if n > 0 {
                self.world.load.release_up(p);
            }
        }
        for i in 0..self.port_refs_down[cid].len() {
            let (p, n) = self.port_refs_down[cid][i];
            if n > 0 {
                self.world.load.release_down(p);
            }
        }
        self.port_refs[cid].clear();
        self.port_refs_down[cid].clear();
        let c = &mut self.world.coflows[cid];
        c.active_flows = 0;
        c.active_list.clear();
        c.finished_at = Some(now);
        c.phase = CoflowPhase::Done;
        self.world.active.retain(|&x| x != cid);
    }

    /// Apply one queued input to the world. Part of the batched-admission
    /// drain: no reallocation happens here — the caller reallocates once
    /// after the whole queue is drained.
    fn handle_input(&mut self, input: Input) -> DrainOutcome {
        match input {
            Input::Op(op) => match op {
                CoflowOp::Register { record, reply } => {
                    let cid = self.register(&record);
                    let _ = reply.send(cid);
                    DrainOutcome { need_realloc: true, force_realloc: false }
                }
                CoflowOp::Deregister { coflow } => {
                    self.deregister(coflow);
                    DrainOutcome { need_realloc: true, force_realloc: true }
                }
                CoflowOp::Update { coflow, record } => {
                    self.deregister(coflow);
                    let _ = self.register(&record);
                    DrainOutcome { need_realloc: true, force_realloc: true }
                }
                CoflowOp::Seal => {
                    self.sealed = true;
                    DrainOutcome::default()
                }
            },
            Input::Agent(msg) => DrainOutcome {
                need_realloc: self.handle_agent_msg(msg),
                force_realloc: false,
            },
        }
    }

    /// Returns true if the message warrants an (event-triggered) realloc.
    fn handle_agent_msg(&mut self, msg: AgentMsg) -> bool {
        match msg {
            AgentMsg::FlowComplete { flow, coflow, size, .. } => {
                self.iv_updates += 1;
                self.update_msgs += 1;
                if flow >= self.world.flows.len() || self.world.flows[flow].done() {
                    return false;
                }
                let now = self.sim_now();
                {
                    let fl = &mut self.world.flows[flow];
                    fl.sent = fl.size;
                    fl.rate = 0.0;
                    fl.finished_at = Some(now);
                }
                self.last_rates.remove(&flow);
                let fl = self.world.flows[flow];
                self.world.load.up_bytes[fl.src] =
                    (self.world.load.up_bytes[fl.src] - size).max(0.0);
                self.world.load.down_bytes[fl.dst] =
                    (self.world.load.down_bytes[fl.dst] - size).max(0.0);
                let mut freed_up = false;
                if let Some(e) = self.port_refs[coflow].iter_mut().find(|(p, _)| *p == fl.src) {
                    e.1 = e.1.saturating_sub(1);
                    freed_up = e.1 == 0;
                }
                if freed_up {
                    self.world.load.release_up(fl.src);
                }
                let mut freed_down = false;
                if let Some(e) = self.port_refs_down[coflow]
                    .iter_mut()
                    .find(|(p, _)| *p == fl.dst)
                {
                    e.1 = e.1.saturating_sub(1);
                    freed_down = e.1 == 0;
                }
                if freed_down {
                    self.world.load.release_down(fl.dst);
                }
                // learning hooks (Philae's sampling state machine)
                if let Some(mut ph) = self.philae.take() {
                    if let CompletionOutcome::SampleComplete(samples) =
                        ph.record_completion(flow, &mut self.world)
                    {
                        let n = self.world.coflows[coflow].flows.len();
                        let est = self.engine_estimate(&samples, n, coflow);
                        self.world.coflows[coflow].est_size = Some(est);
                        self.world.coflows[coflow].phase = CoflowPhase::Running;
                        self.scores_dirty = true;
                    }
                    self.philae = Some(ph);
                }
                let pos = self.world.flows[flow].active_pos;
                {
                    let c = &mut self.world.coflows[coflow];
                    if pos < c.active_list.len() && c.active_list[pos] == flow {
                        c.active_list.swap_remove(pos);
                        if pos < c.active_list.len() {
                            let moved = c.active_list[pos];
                            self.world.flows[moved].active_pos = pos;
                        }
                    } else if let Some(i) = c.active_list.iter().position(|&x| x == flow) {
                        c.active_list.swap_remove(i);
                        if i < c.active_list.len() {
                            let moved = c.active_list[i];
                            self.world.flows[moved].active_pos = i;
                        }
                    }
                }
                let c = &mut self.world.coflows[coflow];
                c.active_flows = c.active_flows.saturating_sub(1);
                if size > c.max_finished_flow {
                    c.max_finished_flow = size;
                }
                if c.active_flows == 0 && c.finished_at.is_none() {
                    c.finished_at = Some(now);
                    c.phase = CoflowPhase::Done;
                    self.world.active.retain(|&x| x != coflow);
                    self.scores_dirty = true;
                }
                true
            }
            AgentMsg::ByteUpdate { coflow, bytes_sent, .. } => {
                self.iv_updates += 1;
                self.update_msgs += 1;
                if coflow < self.world.coflows.len() {
                    // Each agent reports its local share; the coordinator's
                    // view is the running max of partial aggregates (an
                    // under-estimate between intervals, exactly like Aalo's
                    // stale view).
                    let c = &mut self.world.coflows[coflow];
                    c.bytes_sent = c.bytes_sent.max(bytes_sent);
                }
                false
            }
        }
    }

    /// Size estimation, through PJRT when the engine is loaded.
    fn engine_estimate(&mut self, samples: &[f64], nflows: usize, cid: CoflowId) -> f64 {
        if let (Some(engine), Some(batch)) = (self.engine.as_ref(), self.batch.as_mut()) {
            batch.clear();
            batch.set_row(
                0,
                samples,
                nflows,
                0.0,
                &[],
                self.cfg.sched.bootstrap_seed ^ cid as u64,
            );
            if let Ok((est, _lcb)) = engine.estimate(batch) {
                if let Some(&e) = est.first() {
                    return e as f64;
                }
            }
        }
        crate::runtime::native_estimate(samples, nflows as f64)
    }

    /// Compute the priority order (through the PJRT scorer when loaded),
    /// allocate rates, and push per-agent schedules. Shares the incremental
    /// order path and the [`rate::AllocScratch`] workspace with the
    /// simulator's hot loop — the coordinator thread allocates nothing per
    /// event in the native-scoring steady state.
    fn reallocate(&mut self) {
        let t0 = Instant::now();
        if self.philae.is_some() {
            if self.engine.is_some() {
                if self.scores_dirty {
                    self.cached_scores = self.engine_scores();
                    self.scores_dirty = false;
                }
                self.philae.as_ref().unwrap().order_with_scores_into(
                    &self.world,
                    &self.cached_scores,
                    &mut self.plan,
                );
            } else {
                let mut ph = self.philae.take().unwrap();
                ph.order_into(&self.world, &mut self.plan);
                self.philae = Some(ph);
            }
        } else if let Some(mut aalo) = self.aalo.take() {
            aalo.order_into(&self.world, &mut self.plan);
            self.aalo = Some(aalo);
        } else {
            self.plan.clear();
        }
        rate::allocate_into(
            &self.world.fabric,
            &self.world.flows,
            &self.world.coflows,
            &self.plan,
            &mut self.scratch,
        );
        let calc = t0.elapsed().as_secs_f64();
        self.iv_calc += calc;
        self.iv_rate_calcs += 1;
        self.rate_calcs += 1;

        // diff against last flushed rates, group by src agent — lookups go
        // through the scratch's stamped grant table, so no per-call rate map
        // is built
        let t1 = Instant::now();
        let mut dirty_agents: Vec<PortId> = Vec::new();
        for &(f, r) in self.scratch.grants() {
            let prev = self.last_rates.get(&f).copied().unwrap_or(0.0);
            if (prev - r).abs() > crate::EPS {
                let a = self.world.flows[f].src;
                if !dirty_agents.contains(&a) {
                    dirty_agents.push(a);
                }
            }
        }
        for (&f, _) in self.last_rates.iter() {
            if !self.scratch.was_granted(f) && !self.world.flows[f].done() {
                let a = self.world.flows[f].src;
                if !dirty_agents.contains(&a) {
                    dirty_agents.push(a);
                }
            }
        }
        // a schedule message carries *all* rates for that agent so "comply
        // with the last schedule" stays consistent
        for &agent in &dirty_agents {
            let rates: Vec<(FlowId, f64)> = self
                .scratch
                .grants()
                .iter()
                .filter(|&&(f, _)| self.world.flows[f].src == agent)
                .copied()
                .collect();
            let _ = self.agents[agent].tx.send(CoordMsg::NewSchedule { rates });
            self.iv_rate_msgs += 1;
            self.rate_msgs += 1;
        }
        self.last_rates.clear();
        self.last_rates
            .extend(self.scratch.grants().iter().copied());
        self.iv_send += t1.elapsed().as_secs_f64();
    }

    /// Batch the scheduled coflows through the PJRT scorer.
    fn engine_scores(&mut self) -> HashMap<CoflowId, f64> {
        let mut out = HashMap::new();
        let (engine, batch, philae) = match (
            self.engine.as_ref(),
            self.batch.as_mut(),
            self.philae.as_ref(),
        ) {
            (Some(e), Some(b), Some(p)) => (e, b, p),
            _ => return out,
        };
        let half_p = batch.p / 2;
        let cands: Vec<CoflowId> = self
            .world
            .active
            .iter()
            .copied()
            .filter(|&cid| {
                self.world.coflows[cid].phase == CoflowPhase::Running
                    && self.world.coflows[cid].est_size.is_some()
            })
            .collect();
        for chunk in cands.chunks(batch.c) {
            batch.clear();
            for (row, &cid) in chunk.iter().enumerate() {
                let mut ports: Vec<usize> = Vec::new();
                for &(p, n) in &self.port_refs[cid] {
                    if n > 0 {
                        ports.push(p.min(half_p - 1));
                    }
                }
                for &(p, n) in &self.port_refs_down[cid] {
                    if n > 0 {
                        ports.push(half_p + p.min(half_p - 1));
                    }
                }
                batch.set_row(
                    row,
                    philae.pilot_sizes(cid),
                    self.world.coflows[cid].flows.len(),
                    philae.done_bytes(cid),
                    &ports,
                    self.cfg.sched.bootstrap_seed ^ cid as u64,
                );
            }
            if let Ok(res) = engine.score(batch, self.cfg.sched.contention_weight as f32) {
                for (i, &cid) in chunk.iter().enumerate() {
                    out.insert(cid, res.score[i] as f64);
                }
            }
        }
        out
    }
}
