//! The coordinator thread(s), the agent threads, and the trace replayer.
//!
//! The coordinator uses **batched admission**: every wake-up drains the
//! whole input channel — coflow registrations, teardown ops, and agent
//! completion reports alike — routes each item to its owning **coordinator
//! shard**'s input queue, and then runs a single drain-then-reallocate
//! cycle per shard: all of a shard's queued reports are applied to the
//! world first, then that shard pays **one** order repair + rate
//! allocation for the burst. Allocation itself can run the port-sharded
//! parallel pipeline via [`ServiceConfig::alloc_shards`] — and the
//! scratch's persistent worker pool (`coordinator/rate.rs`) means those
//! workers are parked threads woken per allocation, not per-call spawns.
//!
//! ## Event-loop runtime
//!
//! The run loop is an [`EventLoop`] over the merged input channel
//! (`runtime/evloop.rs`): `poll()` blocks until the next input or the δ
//! tick deadline, whichever comes first, and the tick deadline is checked
//! before the receive so a saturated queue can never starve interval work
//! (checkpoints, watchdog sweeps, reconciliation). Steady-state
//! reallocation is allocation-free end to end: per-agent schedule vectors
//! come from a [`BufferPool`] free-list, ride to the agent inside
//! `CoordMsg::NewSchedule`, and boomerang back through a [`recycler`]
//! return channel once the agent has applied them — the
//! `free_reaction_sets` idiom, extended across threads. Per-reallocation
//! wall latency is sampled into the final report's p50/p99
//! ([`ServiceReport::realloc_p50`], [`ServiceReport::realloc_p99`]).
//!
//! ## Multi-coordinator sharding ([`ServiceConfig::coordinators`])
//!
//! With K > 1 the service runs K independent scheduler instances
//! (Philae's sampling core or Aalo's queue machine), mirroring
//! `coordinator/cluster.rs`: a SplitMix64 router assigns each registered
//! coflow to a home shard, every shard schedules only its own coflows over
//! a **leased** per-port capacity slice, and a periodic reconciliation
//! round (every [`SERVICE_RECONCILE_INTERVALS`] δ intervals) rebalances the
//! leases by demand-weighted water-filling
//! ([`crate::coordinator::cluster::water_fill_port`]) and migrates coflows
//! away from saturated shards (Philae rebuilds the sampling state from
//! completed-flow facts via `PhilaeCore::adopt`; Aalo keeps the queue the
//! coflow earned). Per-port lease sums always equal the fabric capacity,
//! so the union of the K allocations stays feasible. A schedule message to
//! an agent carries that agent's rates across *all* shards, so "comply
//! with the last schedule" can never stall another shard's flows.
//! `coordinators == 1` is the classic single-coordinator service.
//!
//! ## Crash-failover and the agent-loss watchdog
//!
//! The paper's split between a soft-state coordinator and dumb agents
//! (§3: switches carry no coflow state, the coordinator re-derives
//! everything from completion reports) makes the coordinator restartable
//! by design. The supervisor leg of this module exercises that claim
//! live: every [`ServiceConfig::checkpoint_every`] δ intervals each shard
//! seals its durable scheduling facts through `coordinator/recovery.rs`
//! (kept in memory, and persisted with atomic write-then-rename under
//! [`ServiceConfig::checkpoint_dir`]); every
//! [`ServiceConfig::chaos_kill_every`] intervals a random shard's
//! *scheduler* is discarded and rebuilt — Philae re-adopts its sampling
//! facts from the surviving world, generic kinds run the stale-merge
//! restore (dcoflow re-asserts checkpointed admission certificates).
//! Leases, coflow ownership, flushed-rate memory, and the shard's queued
//! input all survive; the queue simply replays through the ordinary
//! drain cycle, and agents keep moving bytes at their last complied
//! schedule throughout. Symmetrically,
//! [`ServiceConfig::agent_miss_intervals`] arms an agent-loss watchdog:
//! a port whose agent stops reporting while it still has pending demand
//! ages out of the plan (its capacity is masked from every allocation)
//! and is restored the moment a message from it reappears.
//!
//! ## Scheduler surface
//!
//! The service accepts the **full scheduler registry**
//! ([`SchedulerKind::all`]), not just philae/aalo. Philae keeps its
//! dedicated path (the sampling core is driven directly so the PJRT
//! scorer can batch features); every other kind — aalo, sebf, scf, fifo,
//! saath, the error-correction variants, and the deadline-aware `dcoflow`
//! — runs through the generic [`Scheduler`] trait: arrival/completion
//! hooks against the shard's partition view, `order_into` for the plan,
//! and a per-δ `on_tick` when the policy is periodic
//! ([`Scheduler::tick_interval`], which also gates the agents' periodic
//! byte updates). Clairvoyant kinds (sebf/scf) build their oracle tables
//! from the replayed trace, which registers coflows in trace order;
//! coflows registered dynamically beyond the trace (ops channel) fall
//! back to world-derived keys. Per-coflow SLO deadlines ride along: a
//! registered record's deadline allowance (`deadline − arrival`) is
//! re-anchored to the service clock, and the final report carries
//! [`crate::metrics::DeadlineStats`].

use super::ops::{CoflowOp, OpsHandle};
use crate::agents::{AgentMsg, AgentSim, CoordMsg};
use crate::coflow::{CoflowPhase, CoflowState, FlowState};
use crate::coordinator::{
    cluster,
    philae::{CompletionOutcome, PhilaeCore},
    rate, recovery, AdmissionStats, Plan, Scheduler, SchedulerConfig, SchedulerKind, World,
};
use crate::fabric::{Fabric, PortLoad};
use crate::metrics::{DeadlineStats, IntervalStats, RunningStat};
use crate::obs::{self, EventKind, ObsPlane, ObsSnapshot};
use crate::runtime::evloop::{recycler, BufferPool, EventLoop, RecycleBin, RecycleSender, Wake};
use crate::runtime::{BatchFeatures, Engine};
use crate::trace::{Trace, TraceRecord};
use crate::util::{JsonValue, Rng};
use crate::{CoflowId, FlowId, PortId, Time};
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

/// Owner sentinel: coflow not (or no longer) assigned to a shard.
const NO_OWNER: u32 = u32::MAX;

/// Reconciliation period of the live service, in δ intervals (K > 1 only).
pub const SERVICE_RECONCILE_INTERVALS: u64 = 8;

/// Lease floor fraction (see `coordinator/cluster.rs`): a shard is never
/// leased less than this equal-split slice of a port, so arrivals between
/// reconciliations cannot starve.
const LEASE_FLOOR_FRAC: f64 = 0.05;

/// Migration bounds per reconciliation round (match the sim cluster).
const MAX_MIGRATIONS_PER_ROUND: usize = 4;
const IMBALANCE_THRESHOLD: f64 = 1.5;

/// Auto-tuned agent-loss watchdog ([`ServiceConfig::agent_miss_auto`]):
/// a port is declared missing after this many multiples of its observed
/// EWMA inter-report gap…
const AUTO_MISS_MULT: f64 = 8.0;
/// …but never sooner than this many δ intervals (guards against a port
/// whose cadence estimate collapsed during a chatty burst).
const AUTO_MISS_FLOOR: u64 = 8;
/// EWMA smoothing for per-port inter-report gaps.
const AUTO_MISS_EWMA_ALPHA: f64 = 0.25;

/// Miss threshold (δ intervals) derived from a port's EWMA inter-report
/// gap: `max(⌈AUTO_MISS_MULT × ewma⌉, AUTO_MISS_FLOOR)`.
fn auto_miss_threshold(gap_ewma: f64) -> u64 {
    ((AUTO_MISS_MULT * gap_ewma).ceil() as u64).max(AUTO_MISS_FLOOR)
}

/// Everything the coordinator thread receives, merged onto one channel
/// (std mpsc has no select).
#[derive(Debug)]
pub enum Input {
    Op(CoflowOp),
    Agent(AgentMsg),
}

/// Configuration of a live service run.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub kind: SchedulerKind,
    pub sched: SchedulerConfig,
    /// Simulated seconds per wall second (trace replay acceleration).
    pub time_scale: f64,
    /// Coordinator scheduling interval in wall time (the paper's δ).
    pub delta_wall: Duration,
    /// Load AOT artifacts from here and score through PJRT (Philae only).
    pub engine_dir: Option<PathBuf>,
    /// Port line rate in bytes per *simulated* second.
    pub port_rate: f64,
    /// Worker shards for `rate::allocate_into` (0/1 = serial; the sharded
    /// pipeline is bit-identical and pays off on multi-thousand port
    /// fabrics).
    pub alloc_shards: usize,
    /// Coordinator shards K (module docs); 0/1 = single coordinator.
    pub coordinators: usize,
    /// Supervisor checkpoint period in δ intervals (0 = never). Each shard
    /// seals its durable scheduling facts (`coordinator/recovery.rs`); the
    /// supervisor keeps the latest seal in memory and, when
    /// [`ServiceConfig::checkpoint_dir`] is set, persists it with an
    /// atomic write-then-rename.
    pub checkpoint_every: u64,
    /// Chaos: kill-and-restore a uniformly random coordinator shard's
    /// scheduler every this many δ intervals (0 = never). Only the
    /// coordinator brain dies — agent threads, the world record, leases,
    /// ownership, and each shard's queued input survive and replay.
    pub chaos_kill_every: u64,
    /// Directory for persisted checkpoints (`shard_<s>.ckpt`); `None`
    /// keeps them in memory only.
    pub checkpoint_dir: Option<PathBuf>,
    /// Agent-loss watchdog: a port whose agent has not reported for this
    /// many δ intervals while the port still has pending demand ages out
    /// of the plan — its capacity is masked from every shard's allocation
    /// until the agent reappears. 0 disables the flat threshold (the
    /// default: event-triggered policies have legitimately long quiet
    /// periods, so a flat threshold must be chosen against the workload).
    /// When set alongside [`ServiceConfig::agent_miss_auto`], this value
    /// wins — the flag is the operator override.
    pub agent_miss_intervals: u64,
    /// Auto-tuned agent-loss watchdog: derive each port's miss threshold
    /// from the observed cadence of its own reports (an EWMA of
    /// inter-report gaps, aged out after [`AUTO_MISS_MULT`] missed gaps,
    /// floored at [`AUTO_MISS_FLOOR`] intervals). A port that has never
    /// reported has no cadence and is never aged out, and a port is only
    /// aged while holding a rate grant newer than its last report —
    /// starved ports are legitimately quiet and stay unmasked. Ignored
    /// when [`ServiceConfig::agent_miss_intervals`] is non-zero.
    pub agent_miss_auto: bool,
    /// Flight-recorder ring capacity per shard (events; 0 disables the
    /// observability plane entirely — the report's `obs` stays `None` and
    /// no event payloads are built). See `obs::ObsPlane`.
    pub obs_events: usize,
    /// Durable streaming archive for the flight recorder (needs
    /// `obs_events` > 0): a background spooler drains the rings into
    /// checksummed segment files under the configured directory, once per
    /// δ interval (see `obs/archive.rs`).
    pub archive: Option<obs::ArchiveConfig>,
    /// Adaptive δ ceiling (`--tick-max`): when set, the live tick period
    /// stretches in ×1.5 steps while measured interval pressure (realloc
    /// p99 or last interval's busy time) crowds the current period, never
    /// past this bound, and relaxes back toward `delta_wall` when
    /// pressure subsides. Every retarget is recorded as a
    /// [`EventKind::TickAdjust`] event. `None` = fixed cadence.
    pub tick_max: Option<Duration>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            kind: SchedulerKind::Philae,
            sched: SchedulerConfig::default(),
            time_scale: 20.0,
            delta_wall: Duration::from_millis(8),
            engine_dir: None,
            port_rate: crate::GBPS,
            alloc_shards: rate::env_test_shards(),
            coordinators: 1,
            checkpoint_every: 0,
            chaos_kill_every: 0,
            checkpoint_dir: None,
            agent_miss_intervals: 0,
            agent_miss_auto: false,
            obs_events: 0,
            archive: None,
            tick_max: None,
        }
    }
}

/// Measured outcome of a service run (Tables 3/4 in wall time).
#[derive(Debug, Clone)]
pub struct ServiceReport {
    pub scheduler: String,
    /// Per-coflow CCT in *simulated* seconds.
    pub ccts: Vec<Time>,
    pub makespan: Time,
    pub intervals: IntervalStats,
    /// Measured per-interval phase times (seconds, wall).
    pub rate_calc: RunningStat,
    pub rate_send: RunningStat,
    pub update_recv: RunningStat,
    pub rate_msgs: u64,
    pub update_msgs: u64,
    pub rate_calcs: u64,
    /// Fraction of intervals whose coordinator work exceeded δ.
    pub missed_fraction: f64,
    /// Fraction of intervals with no rate flush at all.
    pub idle_rate_fraction: f64,
    /// Whether scoring ran through the PJRT engine.
    pub used_engine: bool,
    pub wall_seconds: f64,
    /// Coflow migrations between coordinator shards (K > 1 only).
    pub migrations: u64,
    /// Reconciliation rounds performed (K > 1 only).
    pub reconciliations: u64,
    /// SLO accounting (met ratio, goodput, admission counters); vacuous
    /// on deadline-free workloads.
    pub deadline: DeadlineStats,
    /// Supervisor checkpoints sealed (all shards combined).
    pub checkpoints_written: u64,
    /// Chaos shard kills injected.
    pub crashes_injected: u64,
    /// Shard schedulers rebuilt after a kill.
    pub recoveries: u64,
    /// Wall seconds per recovery (scheduler rebuild + first reallocation).
    pub recovery_wall: RunningStat,
    /// Ports aged out of the plan by the agent-loss watchdog.
    pub ports_aged_out: u64,
    /// Aged-out ports whose agent reappeared and was restored.
    pub ports_restored: u64,
    /// Shard schedulers restored from on-disk checkpoints at startup.
    pub restored_shards: u64,
    /// Median per-reallocation wall latency (seconds).
    pub realloc_p50: f64,
    /// 99th-percentile per-reallocation wall latency (seconds).
    pub realloc_p99: f64,
    /// 99.9th-percentile per-reallocation wall latency (seconds). The
    /// percentiles come from an uncapped log-bucketed histogram
    /// (`obs::LogHistogram`), so the tail is exact-rank over *every*
    /// reallocation of the run, not a capped sample.
    pub realloc_p999: f64,
    /// Schedule buffers served from the recycled free-list rather than
    /// freshly allocated (the event-loop runtime's boomerang pool).
    pub sched_bufs_reused: u64,
    /// Registration record buffers the soak feeder served from its
    /// recycled pool instead of allocating fresh (see [`run_soak`];
    /// always 0 for [`run_service`], whose replayer registers at trace
    /// cadence where allocation is off the hot path).
    pub register_bufs_reused: u64,
    /// Adaptive-δ retargets performed ([`ServiceConfig::tick_max`]);
    /// 0 on fixed-cadence runs.
    pub tick_adjusts: u64,
    /// Metrics + flight-recorder snapshot when
    /// [`ServiceConfig::obs_events`] > 0.
    pub obs: Option<ObsSnapshot>,
}

impl ServiceReport {
    pub fn avg_cct(&self) -> f64 {
        crate::metrics::mean(&self.ccts)
    }
}

/// Registration records for `trace`, in trace (arrival) order, each with
/// its reducers sorted by port — the exact shape `Coordinator::register`
/// consumes, so flow-id assignment is deterministic.
fn trace_records(trace: &Trace) -> Vec<TraceRecord> {
    trace
        .coflows
        .iter()
        .map(|c| {
            let mut per_red: HashMap<PortId, f64> = HashMap::new();
            for &f in &c.flows {
                *per_red.entry(trace.flows[f].dst).or_insert(0.0) += trace.flows[f].size;
            }
            let mut reducers: Vec<(usize, f64)> = per_red.into_iter().collect();
            reducers.sort_unstable_by(|a, b| a.0.cmp(&b.0));
            TraceRecord {
                external_id: c.external_id,
                arrival: c.arrival,
                deadline: c.deadline,
                mappers: c.senders.clone(),
                reducers,
            }
        })
        .collect()
}

/// Run `trace` through the live coordinator + agents; returns when every
/// coflow has completed.
pub fn run_service(trace: &Trace, cfg: &ServiceConfig) -> Result<ServiceReport> {
    let (input_tx, input_rx) = mpsc::channel::<Input>();
    let handle = OpsHandle { tx: input_tx.clone() };

    // Trace replayer: registers coflows at scaled arrival times.
    let records = trace_records(trace);
    let time_scale = cfg.time_scale;
    let replayer = thread::spawn(move || {
        let start = Instant::now();
        for rec in records {
            let due = Duration::from_secs_f64(rec.arrival / time_scale);
            let elapsed = start.elapsed();
            if due > elapsed {
                thread::sleep(due - elapsed);
            }
            let _ = handle.register(rec);
        }
        handle.seal();
    });

    let mut coord = Coordinator::new(trace, cfg, input_tx)?;
    coord.spawn_agents();
    let report = coord.run(input_rx);
    let _ = replayer.join();
    report
}

/// Headless soak harness for `benches/bench_service.rs`: drive the full
/// coordinator runtime — registration, sharded allocation, schedule
/// diffing, checkpoints — at maximum event rate, with the physical side
/// stubbed out. Agents are **null sinks** (channels whose receivers are
/// dropped, so every schedule send is a no-op), and a feeder thread
/// replaces both the replayer and the agent sims: it registers every
/// coflow up front, then streams synthesized `FlowComplete` reports
/// round-robin across coflows — the worst case for the coordinator, since
/// every report belongs to a different coflow than the last — and finally
/// seals. The returned report's `update_msgs` over `wall_seconds` is the
/// sustained event rate; `realloc_p50`/`realloc_p99`/`realloc_p999` are
/// the reallocation latency tail under that pressure.
///
/// The feeder mirrors `Coordinator::register`'s deterministic flow-id
/// layout (registration order × reducers-sorted-by-port × mappers), so
/// its synthesized reports name real flows.
///
/// Registration buffers ride the boomerang pool: each [`TraceRecord`]
/// shipped in a [`CoflowOp::Register`] carries a recycle path, the
/// coordinator hands the consumed record back *before* replying, and the
/// feeder awaits the reply — so from the second registration on, every
/// record is served from the pool ([`ServiceReport::register_bufs_reused`]
/// counts the reuses; the steady state allocates nothing).
pub fn run_soak(trace: &Trace, cfg: &ServiceConfig) -> Result<ServiceReport> {
    let (input_tx, input_rx) = mpsc::channel::<Input>();
    let records = trace_records(trace);
    let feeder_tx = input_tx.clone();
    let (reg_recycle_tx, reg_bin) = recycler::<TraceRecord>();
    let feeder = thread::spawn(move || -> u64 {
        let mut pool: BufferPool<TraceRecord> = BufferPool::new();
        // (flow id, size, src agent) per coflow, in coordinator fid order
        let mut flows: Vec<Vec<(FlowId, f64, PortId)>> = Vec::with_capacity(records.len());
        let mut fid = 0usize;
        for rec in &records {
            let mut of_coflow = Vec::new();
            for &(_dst, reducer_bytes) in &rec.reducers {
                let per_flow = reducer_bytes / rec.mappers.len() as f64;
                for &src in &rec.mappers {
                    of_coflow.push((fid, per_flow, src));
                    fid += 1;
                }
            }
            flows.push(of_coflow);
            // refill the record from the pool, not a clone: consumed
            // buffers boomerang back through `reg_bin`
            reg_bin.drain_into(&mut pool);
            let mut buf = pool.take();
            buf.external_id = rec.external_id;
            buf.arrival = rec.arrival;
            buf.deadline = rec.deadline;
            buf.mappers.clear();
            buf.mappers.extend_from_slice(&rec.mappers);
            buf.reducers.clear();
            buf.reducers.extend_from_slice(&rec.reducers);
            let (reply, reply_rx) = mpsc::sync_channel::<CoflowId>(1);
            if feeder_tx
                .send(Input::Op(CoflowOp::Register {
                    record: buf,
                    reply,
                    recycle: Some(reg_recycle_tx.clone()),
                }))
                .is_err()
            {
                return pool.reused();
            }
            // the coordinator recycles before replying, so the next
            // `drain_into` is guaranteed to reclaim this buffer
            if reply_rx.recv().is_err() {
                return pool.reused();
            }
        }
        let mut cursor = vec![0usize; flows.len()];
        loop {
            let mut any = false;
            for (cid, of_coflow) in flows.iter().enumerate() {
                if cursor[cid] < of_coflow.len() {
                    let (flow, size, agent) = of_coflow[cursor[cid]];
                    cursor[cid] += 1;
                    any = true;
                    let msg = AgentMsg::FlowComplete {
                        agent,
                        flow,
                        coflow: cid,
                        size,
                        pilot: false,
                        at: 0.0,
                    };
                    if feeder_tx.send(Input::Agent(msg)).is_err() {
                        return pool.reused();
                    }
                }
            }
            if !any {
                break;
            }
        }
        let _ = feeder_tx.send(Input::Op(CoflowOp::Seal));
        pool.reused()
    });

    let mut coord = Coordinator::new(trace, cfg, input_tx)?;
    coord.install_null_agents();
    let report = coord.run(input_rx);
    let reused = feeder.join().unwrap_or(0);
    report.map(|mut r| {
        r.register_bufs_reused = reused;
        r
    })
}

struct AgentHandle {
    tx: mpsc::Sender<CoordMsg>,
}

/// Live-service observability: the shared plane plus the dense metric
/// handles resolved once at startup (`obs::Registry` find-or-create).
/// Pure observer — nothing here is ever read back into scheduling.
struct SvcObs {
    plane: ObsPlane,
    /// How late each δ tick fired vs. the configured cadence (seconds).
    g_tick_lag: obs::GaugeId,
    /// Inputs drained per event wake (queue pressure at the coordinator).
    g_queue_depth: obs::GaugeId,
    /// Per-shard leased-uplink utilization, set at each reallocation.
    g_lease_util: Vec<obs::GaugeId>,
    c_migrations: obs::CounterId,
    c_reconciliations: obs::CounterId,
    /// Adaptive-δ retargets ([`ServiceConfig::tick_max`]).
    c_tick_adjusts: obs::CounterId,
    /// Current tick period (seconds) after adaptive retargeting.
    g_tick_period: obs::GaugeId,
    /// Mirror of the always-on realloc latency histogram, exported in the
    /// snapshot registry as `svc.realloc_ns`.
    h_realloc: obs::HistId,
    /// Durable segment spool ([`ServiceConfig::archive`]); drained once
    /// per δ interval, finalized into [`ObsSnapshot::archive`].
    archive: Option<obs::ArchiveSpool>,
}

impl SvcObs {
    /// Copy every ring tail pushed since the last call into the archive
    /// spool (no-op when the archive is off).
    fn drain_archive(&mut self) {
        if let Some(spool) = self.archive.as_mut() {
            spool.drain(&self.plane);
        }
    }
}

/// One live coordinator shard: its scheduler instance, owned coflows,
/// capacity lease, input queue, and reusable scheduling workspace.
struct SvcShard {
    /// Philae's dedicated path: the sampling core driven directly (PJRT
    /// feature batching needs core access the trait doesn't expose).
    philae: Option<PhilaeCore>,
    /// Every other registry kind, driven through the [`Scheduler`] trait.
    generic: Option<Box<dyn Scheduler>>,
    /// Owned coflows in admission order (swapped into `world.active`
    /// around every scheduler call).
    active: Vec<CoflowId>,
    /// Leased per-port capacity slice (Σ over shards == fabric per port).
    lease: Fabric,
    /// Queued agent messages awaiting this shard's drain cycle.
    pending: VecDeque<AgentMsg>,
    /// Reused scheduling plan (see `Scheduler::order_into`).
    plan: Plan,
    /// Reused allocation workspace shared with the simulator's hot path.
    scratch: rate::AllocScratch,
    /// Last rates this shard flushed, for the per-agent schedule diff.
    last_rates: HashMap<FlowId, f64>,
    /// Observed remaining-bytes demand per port (reconciliation scratch).
    demand_up: Vec<f64>,
    demand_down: Vec<f64>,
    /// Something changed that affects this shard's rates.
    need_realloc: bool,
    /// Reallocate regardless of policy (explicit teardown frees rates now).
    force_realloc: bool,
}

struct Coordinator {
    cfg: ServiceConfig,
    world: World,
    shards: Vec<SvcShard>,
    /// Coflow → owning shard (`NO_OWNER` = unassigned / completed).
    owner: Vec<u32>,
    engine: Option<Engine>,
    batch: Option<BatchFeatures>,
    agents: Vec<AgentHandle>,
    input_tx: mpsc::Sender<Input>,
    agent_threads: Vec<thread::JoinHandle<()>>,
    port_refs: Vec<Vec<(PortId, usize)>>, // per coflow: (src port, active refs)
    port_refs_down: Vec<Vec<(PortId, usize)>>,
    /// Cached PJRT scores; refreshed only when the estimated set changes
    /// (new estimate / coflow completion / arrival), not per event — one
    /// scorer batch costs ~ms, reallocs happen per completion report.
    cached_scores: HashMap<CoflowId, f64>,
    scores_dirty: bool,
    sealed: bool,
    seq: u64,
    start: Instant,
    leases_ready: bool,
    intervals_seen: u64,
    migrations: u64,
    reconciliations: u64,
    /// Reused water-fill workspaces (see `coordinator/cluster.rs`).
    wf_demand: Vec<f64>,
    wf_out: Vec<f64>,
    wf_scratch: Vec<(f64, usize)>,
    demand_total: Vec<f64>,
    // crash-failover supervisor (ServiceConfig::{checkpoint_every,
    // chaos_kill_every}); trace copy kept only when either is armed, so
    // a killed generic scheduler can be rebuilt mid-run
    trace_copy: Option<Trace>,
    last_ckpts: Vec<Option<String>>,
    chaos_rng: Rng,
    checkpoints_written: u64,
    crashes_injected: u64,
    recoveries: u64,
    recovery_wall: RunningStat,
    // agent-loss watchdog (ServiceConfig::{agent_miss_intervals,
    // agent_miss_auto})
    port_last_seen: Vec<u64>,
    port_alive: Vec<bool>,
    dead_ports: usize,
    masked_lease: Fabric,
    ports_aged_out: u64,
    ports_restored: u64,
    /// EWMA of per-port inter-report gaps (δ intervals); 0 = never heard.
    gap_ewma: Vec<f64>,
    /// Last interval at which a port's flows held a nonzero rate grant.
    /// Auto aging requires a grant *newer than the port's last report*:
    /// silence while holding capacity is the black-hole signature, whereas
    /// a starved port (granted nothing) is legitimately quiet and must
    /// never be aged — masking it would deadlock its flows.
    port_rate_stamp: Vec<u64>,
    /// Shards restored from on-disk checkpoints at startup.
    restored_shards: u64,
    // event-loop runtime: recycled schedule buffers + reused diff scratch
    sched_bufs: BufferPool<Vec<(FlowId, f64)>>,
    recycle_tx: RecycleSender<Vec<(FlowId, f64)>>,
    recycle_bin: RecycleBin<Vec<(FlowId, f64)>>,
    dirty_agents: Vec<PortId>,
    per_agent: HashMap<PortId, Vec<(FlowId, f64)>>,
    /// Per-reallocation wall latencies, log-bucketed. Always on (feeds the
    /// report's `realloc_p50/p99/p999`): a record is two array increments,
    /// and unlike the capped sampler it predates, memory is fixed while
    /// the tail rank stays exact over every reallocation of a soak.
    calc_hist: obs::LogHistogram,
    /// Metrics + flight recorder ([`ServiceConfig::obs_events`] > 0).
    obs: Option<SvcObs>,
    /// Wall instant of the previous δ tick (tick-lag gauge).
    last_tick: Instant,
    /// Adaptive-δ retargets performed ([`ServiceConfig::tick_max`]).
    tick_adjusts: u64,
    /// Coordinator busy seconds (calc + send + recv) over the interval
    /// that just closed — the adaptive δ's second pressure signal beside
    /// the realloc p99.
    last_interval_busy: f64,
    // measured accounting
    stats: IntervalStats,
    rate_calc: RunningStat,
    rate_send: RunningStat,
    update_recv: RunningStat,
    iv_calc: f64,
    iv_send: f64,
    iv_recv: f64,
    iv_updates: u64,
    iv_rate_msgs: u64,
    iv_rate_calcs: u64,
    rate_msgs: u64,
    update_msgs: u64,
    rate_calcs: u64,
}

impl Coordinator {
    fn new(trace: &Trace, cfg: &ServiceConfig, input_tx: mpsc::Sender<Input>) -> Result<Self> {
        let num_ports = trace.num_ports;
        let engine = match (&cfg.engine_dir, cfg.kind) {
            (Some(dir), SchedulerKind::Philae) => Some(Engine::load(dir)?),
            _ => None,
        };
        let batch = engine.as_ref().map(|e| BatchFeatures::new(&e.manifest));
        let (recycle_tx, recycle_bin) = recycler();
        let world = World {
            now: 0.0,
            flows: Vec::new(),
            coflows: Vec::new(),
            fabric: Fabric::homogeneous(num_ports, cfg.port_rate),
            load: PortLoad::new(num_ports),
            active: Vec::new(),
        };
        let is_philae = matches!(cfg.kind, SchedulerKind::Philae);
        let k = cfg.coordinators.max(1);
        let obs = if cfg.obs_events > 0 {
            let mut plane = ObsPlane::new(cfg.obs_events);
            let archive = match cfg.archive.clone() {
                Some(a) => Some(obs::ArchiveSpool::new(a)?),
                None => None,
            };
            Some(SvcObs {
                g_tick_lag: plane.reg.gauge("svc.tick_lag_s"),
                g_queue_depth: plane.reg.gauge("svc.input_queue_depth"),
                g_lease_util: (0..k)
                    .map(|s| plane.reg.gauge(&format!("svc.lease_util.{s}")))
                    .collect(),
                c_migrations: plane.reg.counter("svc.migrations"),
                c_reconciliations: plane.reg.counter("svc.reconciliations"),
                c_tick_adjusts: plane.reg.counter("svc.tick_adjusts"),
                g_tick_period: plane.reg.gauge("svc.tick_period_s"),
                h_realloc: plane.reg.hist("svc.realloc_ns"),
                archive,
                plane,
            })
        } else {
            None
        };
        let shards: Vec<SvcShard> = (0..k)
            .map(|_| SvcShard {
                philae: is_philae.then(|| PhilaeCore::new(cfg.sched.clone())),
                generic: (!is_philae).then(|| cfg.kind.build(trace, &cfg.sched)),
                active: Vec::new(),
                lease: Fabric {
                    num_ports: 0,
                    up_capacity: Vec::new(),
                    down_capacity: Vec::new(),
                },
                pending: VecDeque::new(),
                plan: Plan::default(),
                scratch: {
                    let mut s = rate::AllocScratch::new();
                    s.set_shards(cfg.alloc_shards);
                    s
                },
                last_rates: HashMap::new(),
                demand_up: Vec::new(),
                demand_down: Vec::new(),
                need_realloc: false,
                force_realloc: false,
            })
            .collect();
        let mut coord = Coordinator {
            cfg: cfg.clone(),
            world,
            shards,
            owner: Vec::new(),
            engine,
            batch,
            agents: Vec::new(),
            input_tx,
            agent_threads: Vec::new(),
            port_refs: Vec::new(),
            port_refs_down: Vec::new(),
            cached_scores: HashMap::new(),
            scores_dirty: true,
            sealed: false,
            seq: 0,
            start: Instant::now(),
            leases_ready: false,
            intervals_seen: 0,
            migrations: 0,
            reconciliations: 0,
            wf_demand: vec![0.0; k],
            wf_out: vec![0.0; k],
            wf_scratch: Vec::with_capacity(k),
            demand_total: vec![0.0; k],
            trace_copy: (cfg.checkpoint_every > 0 || cfg.chaos_kill_every > 0)
                .then(|| trace.clone()),
            last_ckpts: vec![None; k],
            chaos_rng: Rng::seed_from_u64(cfg.sched.dynamics_seed.wrapping_add(0xC4A05)),
            checkpoints_written: 0,
            crashes_injected: 0,
            recoveries: 0,
            recovery_wall: RunningStat::default(),
            port_last_seen: vec![0; num_ports],
            port_alive: vec![true; num_ports],
            dead_ports: 0,
            masked_lease: Fabric {
                num_ports: 0,
                up_capacity: Vec::new(),
                down_capacity: Vec::new(),
            },
            ports_aged_out: 0,
            ports_restored: 0,
            gap_ewma: vec![0.0; num_ports],
            port_rate_stamp: vec![0; num_ports],
            restored_shards: 0,
            sched_bufs: BufferPool::new(),
            recycle_tx,
            recycle_bin,
            dirty_agents: Vec::new(),
            per_agent: HashMap::new(),
            calc_hist: obs::LogHistogram::new(),
            obs,
            last_tick: Instant::now(),
            tick_adjusts: 0,
            last_interval_busy: 0.0,
            stats: IntervalStats::default(),
            rate_calc: RunningStat::default(),
            rate_send: RunningStat::default(),
            update_recv: RunningStat::default(),
            iv_calc: 0.0,
            iv_send: 0.0,
            iv_recv: 0.0,
            iv_updates: 0,
            iv_rate_msgs: 0,
            iv_rate_calcs: 0,
            rate_msgs: 0,
            update_msgs: 0,
            rate_calcs: 0,
        };
        coord.restore_from_disk(trace);
        Ok(coord)
    }

    /// Restore-from-disk on service start: consume any `shard_<s>.ckpt`
    /// seals a previous incarnation left under
    /// [`ServiceConfig::checkpoint_dir`] *before* accepting input. Generic
    /// kinds rebuild their scheduler through the stale-merge restore
    /// against the still-empty world (dcoflow re-asserts its sealed
    /// admission certificates as coflows re-register); Philae validates
    /// the seal and keeps it as the supervisor's working copy — its
    /// sampling state is re-derived from live reports by design. Missing,
    /// corrupt, or wrong-kind files are skipped: a fresh start must never
    /// be blocked by a stale directory.
    fn restore_from_disk(&mut self, trace: &Trace) {
        let Some(dir) = self.cfg.checkpoint_dir.clone() else {
            return;
        };
        for s in 0..self.shards.len() {
            let path = dir.join(format!("shard_{s}.ckpt"));
            let Ok(sealed) = std::fs::read_to_string(&path) else {
                continue;
            };
            let Ok(payload) = recovery::unseal(&sealed) else {
                continue;
            };
            if payload.get("kind").and_then(|v| v.as_str()) != Some(self.cfg.kind.as_str()) {
                continue; // checkpoint from a differently-configured service
            }
            if self.shards[s].generic.is_some() {
                let sh = &mut self.shards[s];
                std::mem::swap(&mut self.world.active, &mut sh.active);
                let restored = recovery::restore_scheduler(
                    &payload,
                    trace,
                    &self.cfg.sched,
                    &mut self.world,
                    false,
                );
                std::mem::swap(&mut self.world.active, &mut sh.active);
                match restored {
                    Ok(g) => sh.generic = Some(g),
                    Err(_) => continue,
                }
            }
            self.last_ckpts[s] = Some(sealed);
            self.restored_shards += 1;
        }
    }

    /// Whether the configured policy runs a periodic δ pipeline (Aalo):
    /// drives both the agents' byte updates and the per-interval tick.
    fn periodic_pipeline(&self) -> bool {
        match self.shards[0].generic.as_ref() {
            Some(g) => g.tick_interval().is_some(),
            None => false,
        }
    }

    /// Event-triggered shards (Philae, and every generic kind without a δ
    /// tick) reallocate on any queued event; periodic ones flush at the
    /// tick.
    fn event_triggered(&self, s: usize) -> bool {
        match (&self.shards[s].philae, &self.shards[s].generic) {
            (Some(_), _) => true,
            (_, Some(g)) => g.tick_interval().is_none(),
            _ => false,
        }
    }

    fn spawn_agents(&mut self) {
        let n = self.world.fabric.num_ports;
        let periodic_updates = self.periodic_pipeline();
        for port in 0..n {
            let (tx, rx) = mpsc::channel::<CoordMsg>();
            let up = self.input_tx.clone();
            let recycle = self.recycle_tx.clone();
            let scale = self.cfg.time_scale;
            let delta = self.cfg.delta_wall;
            let th = thread::spawn(move || {
                let mut sim = AgentSim::new(port);
                let start = Instant::now();
                let mut last = Instant::now();
                let mut next_tick = Instant::now() + delta;
                loop {
                    let now = Instant::now();
                    let mut wait = Duration::from_millis(200);
                    if let Some(s) = sim.next_completion() {
                        wait = wait.min(Duration::from_secs_f64((s / scale).max(0.0)));
                    }
                    if periodic_updates {
                        wait = wait.min(next_tick.saturating_duration_since(now));
                    }
                    let msg = rx.recv_timeout(wait);
                    // advance local flows to 'now' first, reporting completions
                    let dt = last.elapsed().as_secs_f64() * scale;
                    last = Instant::now();
                    let sim_now = start.elapsed().as_secs_f64() * scale;
                    for m in sim.advance(dt, sim_now) {
                        let _ = up.send(Input::Agent(m));
                    }
                    match msg {
                        Ok(CoordMsg::AddFlow { flow, coflow, size, pilot }) => {
                            sim.add_flow(flow, coflow, size, pilot);
                        }
                        Ok(CoordMsg::NewSchedule { rates }) => {
                            sim.apply_schedule(&rates);
                            // boomerang the consumed buffer back to the
                            // coordinator's free-list
                            recycle.give(rates);
                        }
                        Ok(CoordMsg::Shutdown) => break,
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                    if periodic_updates && Instant::now() >= next_tick {
                        if sim.active_flows() > 0 {
                            for m in sim.byte_updates() {
                                let _ = up.send(Input::Agent(m));
                            }
                        }
                        next_tick += delta;
                    }
                }
            });
            self.agents.push(AgentHandle { tx });
            self.agent_threads.push(th);
        }
    }

    /// Null agents for the headless soak harness ([`run_soak`]): every
    /// `CoordMsg` sink is a channel whose receiver is immediately dropped,
    /// so schedule and flow shipments are no-ops (all sends in this module
    /// already tolerate a closed channel). No agent threads exist to join
    /// at shutdown.
    fn install_null_agents(&mut self) {
        for _ in 0..self.world.fabric.num_ports {
            let (tx, _rx) = mpsc::channel::<CoordMsg>();
            self.agents.push(AgentHandle { tx });
        }
    }

    fn run(mut self, input_rx: mpsc::Receiver<Input>) -> Result<ServiceReport> {
        let mut lp = EventLoop::new(input_rx, self.cfg.delta_wall);
        loop {
            if self.sealed && self.world.active.is_empty() && !self.world.coflows.is_empty() {
                break;
            }
            match lp.poll() {
                // Batched admission: drain *everything* queued. Coflow ops
                // apply immediately (they change the world's shape); agent
                // messages are routed to their owning shard's input queue.
                // Then each shard runs one drain-then-reallocate cycle for
                // the whole burst instead of one reallocation per report.
                Wake::Event(first) => {
                    let t0 = Instant::now();
                    let mut depth = 1u64;
                    self.route_input(first);
                    while let Some(next) = lp.try_next() {
                        depth += 1;
                        self.route_input(next);
                    }
                    if let Some(o) = self.obs.as_mut() {
                        o.plane.reg.set_gauge(o.g_queue_depth, depth as f64);
                    }
                    // single drain cycle per shard
                    for s in 0..self.shards.len() {
                        loop {
                            let Some(msg) = self.shards[s].pending.pop_front() else {
                                break;
                            };
                            if self.handle_agent_msg(s, msg) {
                                self.shards[s].need_realloc = true;
                            }
                        }
                    }
                    self.iv_recv += t0.elapsed().as_secs_f64();
                    // Event-triggered policies (Philae, and every generic
                    // kind without a tick interval) reallocate on any
                    // event; periodic (Aalo) pipelines flush at the δ
                    // tick, except for explicit coflow teardown, which
                    // frees rates immediately.
                    for s in 0..self.shards.len() {
                        let go = (self.shards[s].need_realloc && self.event_triggered(s))
                            || self.shards[s].force_realloc;
                        self.shards[s].need_realloc = false;
                        self.shards[s].force_realloc = false;
                        if go {
                            self.reallocate_shard(s);
                        }
                    }
                }
                // the deadline is checked before the receive, so a
                // saturated queue cannot starve interval work
                Wake::Tick => {
                    if let Some(o) = self.obs.as_mut() {
                        // lag vs the *live* cadence: after an adaptive
                        // stretch, lateness is measured against the
                        // stretched period, not the configured floor
                        let lag =
                            self.last_tick.elapsed().as_secs_f64() - lp.period().as_secs_f64();
                        o.plane.reg.set_gauge(o.g_tick_lag, lag.max(0.0));
                    }
                    self.last_tick = Instant::now();
                    self.on_interval();
                    self.adapt_tick(&mut lp);
                    if let Some(o) = self.obs.as_mut() {
                        o.drain_archive();
                    }
                }
                Wake::Closed => break,
            }
        }

        for a in &self.agents {
            let _ = a.tx.send(CoordMsg::Shutdown);
        }
        for th in self.agent_threads.drain(..) {
            let _ = th.join();
        }
        let ccts: Vec<Time> = self
            .world
            .coflows
            .iter()
            .map(|c| c.cct().unwrap_or(f64::NAN))
            .collect();
        let mut deadline = DeadlineStats::default();
        for c in &self.world.coflows {
            deadline.record(c.deadline, c.finished_at, c.total_bytes);
        }
        {
            let mut adm = AdmissionStats::default();
            let mut any = false;
            for sh in &self.shards {
                if let Some(a) = sh.generic.as_ref().and_then(|g| g.admission_stats()) {
                    adm.merge(&a);
                    any = true;
                }
            }
            if any {
                deadline.admitted = adm.admitted;
                deadline.rejected = adm.rejected;
                deadline.expired = adm.expired;
            }
        }
        let obs_snapshot = self.obs.take().map(|mut o| {
            // final drain catches events emitted since the last tick,
            // then the spool flushes, joins its writer, and reports
            o.drain_archive();
            let archive = o.archive.take().map(|spool| spool.finalize());
            let mut snap = o.plane.snapshot();
            snap.archive = archive;
            snap
        });
        Ok(ServiceReport {
            scheduler: if self.shards[0].philae.is_some() {
                "philae".into()
            } else {
                self.shards[0]
                    .generic
                    .as_ref()
                    .map(|g| g.name())
                    .unwrap_or_else(|| "unknown".into())
            },
            ccts,
            makespan: self.start.elapsed().as_secs_f64() * self.cfg.time_scale,
            missed_fraction: self.stats.missed_fraction(),
            idle_rate_fraction: self.stats.idle_rate_fraction(),
            intervals: self.stats,
            rate_calc: self.rate_calc,
            rate_send: self.rate_send,
            update_recv: self.update_recv,
            rate_msgs: self.rate_msgs,
            update_msgs: self.update_msgs,
            rate_calcs: self.rate_calcs,
            used_engine: self.engine.is_some(),
            wall_seconds: self.start.elapsed().as_secs_f64(),
            migrations: self.migrations,
            reconciliations: self.reconciliations,
            deadline,
            checkpoints_written: self.checkpoints_written,
            crashes_injected: self.crashes_injected,
            recoveries: self.recoveries,
            recovery_wall: self.recovery_wall,
            ports_aged_out: self.ports_aged_out,
            ports_restored: self.ports_restored,
            restored_shards: self.restored_shards,
            realloc_p50: self.calc_hist.percentile_secs(0.50),
            realloc_p99: self.calc_hist.percentile_secs(0.99),
            realloc_p999: self.calc_hist.percentile_secs(0.999),
            sched_bufs_reused: self.sched_bufs.reused(),
            register_bufs_reused: 0, // patched by `run_soak` post-join
            tick_adjusts: self.tick_adjusts,
            obs: obs_snapshot,
        })
    }

    /// Apply one queued input: coflow ops immediately, agent messages onto
    /// the owning shard's queue (drained by the per-shard cycle).
    fn route_input(&mut self, input: Input) {
        match input {
            Input::Op(op) => match op {
                CoflowOp::Register { record, reply, recycle } => {
                    let cid = self.register(&record);
                    // boomerang the consumed record *before* replying: a
                    // registrar that awaits the reply is then guaranteed
                    // to find this buffer in its pool on the next take
                    if let Some(r) = recycle {
                        let mut rec = record;
                        rec.mappers.clear();
                        rec.reducers.clear();
                        r.give(rec);
                    }
                    let _ = reply.send(cid);
                    let s = self.owner[cid] as usize;
                    self.shards[s].need_realloc = true;
                }
                CoflowOp::Deregister { coflow } => {
                    let s = self.owner_of(coflow);
                    self.deregister(coflow);
                    if let Some(s) = s {
                        self.shards[s].need_realloc = true;
                        self.shards[s].force_realloc = true;
                    }
                }
                CoflowOp::Update { coflow, record } => {
                    let s_old = self.owner_of(coflow);
                    self.deregister(coflow);
                    let cid = self.register(&record);
                    let s_new = self.owner[cid] as usize;
                    self.shards[s_new].need_realloc = true;
                    self.shards[s_new].force_realloc = true;
                    if let Some(s) = s_old {
                        self.shards[s].need_realloc = true;
                        self.shards[s].force_realloc = true;
                    }
                }
                CoflowOp::Seal => {
                    self.sealed = true;
                }
            },
            Input::Agent(msg) => {
                let (agent, coflow) = match &msg {
                    AgentMsg::FlowComplete { agent, coflow, .. } => (*agent, *coflow),
                    AgentMsg::ByteUpdate { agent, coflow, .. } => (*agent, *coflow),
                };
                self.note_agent(agent);
                // late messages for completed/deregistered coflows route to
                // shard 0 — they are counted and dropped by the handler
                let s = self.owner_of(coflow).unwrap_or(0);
                self.shards[s].pending.push_back(msg);
            }
        }
    }

    fn owner_of(&self, cid: CoflowId) -> Option<usize> {
        match self.owner.get(cid).copied() {
            Some(o) if o != NO_OWNER => Some(o as usize),
            _ => None,
        }
    }

    /// δ interval boundary: Aalo's periodic pipeline per shard, periodic
    /// cross-shard reconciliation, interval accounting for everyone.
    fn on_interval(&mut self) {
        self.intervals_seen += 1;
        self.touch_clock();
        if self.cfg.checkpoint_every > 0
            && self.intervals_seen % self.cfg.checkpoint_every == 0
            && !self.world.coflows.is_empty()
        {
            self.checkpoint_shards();
        }
        if self.cfg.chaos_kill_every > 0
            && self.intervals_seen % self.cfg.chaos_kill_every == 0
            && !self.world.active.is_empty()
        {
            let s = (self.chaos_rng.next_u64() % self.shards.len() as u64) as usize;
            self.kill_restore_shard(s);
        }
        if self.cfg.agent_miss_intervals > 0 || self.cfg.agent_miss_auto {
            self.sweep_agent_watchdog();
        }
        if self.shards.len() > 1
            && self.intervals_seen % SERVICE_RECONCILE_INTERVALS == 0
            && !self.world.active.is_empty()
        {
            self.reconcile();
            // leases moved: every shard's last allocation is stale
            for s in 0..self.shards.len() {
                self.reallocate_shard(s);
            }
        }
        if self.periodic_pipeline() {
            for s in 0..self.shards.len() {
                if self.shards[s].active.is_empty() {
                    continue;
                }
                {
                    let sh = &mut self.shards[s];
                    std::mem::swap(&mut self.world.active, &mut sh.active);
                    if let Some(g) = sh.generic.as_mut() {
                        g.on_tick(&mut self.world);
                    }
                    std::mem::swap(&mut self.world.active, &mut sh.active);
                }
                self.reallocate_shard(s); // periodic policies flush every δ
            }
        }
        let busy =
            !self.world.active.is_empty() || self.iv_rate_calcs > 0 || self.iv_updates > 0;
        if busy {
            self.rate_calc.push(self.iv_calc);
            self.rate_send.push(self.iv_send);
            self.update_recv.push(self.iv_recv);
            self.stats.push_interval(
                self.cfg.delta_wall.as_secs_f64(),
                self.iv_calc,
                self.iv_send,
                self.iv_recv,
                self.iv_updates,
                self.iv_rate_msgs,
                self.iv_rate_calcs,
            );
        }
        self.last_interval_busy = self.iv_calc + self.iv_send + self.iv_recv;
        self.iv_calc = 0.0;
        self.iv_send = 0.0;
        self.iv_recv = 0.0;
        self.iv_updates = 0;
        self.iv_rate_msgs = 0;
        self.iv_rate_calcs = 0;
    }

    /// Adaptive δ ([`ServiceConfig::tick_max`]; ROADMAP items 1a and 6d):
    /// compare measured coordinator pressure — the larger of the realloc
    /// p99 and the closed interval's busy seconds — against the *live*
    /// tick period. Pressure crowding the period (> 70%) stretches it
    /// ×1.5 (capped at `tick_max`); comfortable slack (< 20%) relaxes it
    /// ÷1.5 (floored at the configured `delta_wall`). Each retarget
    /// re-anchors the deadline ([`EventLoop::set_period`]) and is
    /// recorded as a [`EventKind::TickAdjust`] event (`a` = new period
    /// ns, `b` = previous), so post-hoc analysis can line δ changes up
    /// with the lag and latency series.
    fn adapt_tick(&mut self, lp: &mut EventLoop<Input>) {
        let Some(tick_max) = self.cfg.tick_max else { return };
        let period = lp.period().as_secs_f64();
        let floor = self.cfg.delta_wall.as_secs_f64();
        let ceil = tick_max.as_secs_f64().max(floor);
        let pressure = self.calc_hist.percentile_secs(0.99).max(self.last_interval_busy);
        let new = if pressure > 0.7 * period {
            (period * 1.5).min(ceil)
        } else if pressure < 0.2 * period {
            (period / 1.5).max(floor)
        } else {
            return;
        };
        if (new - period).abs() < 1e-9 {
            return; // already pinned at the floor or ceiling
        }
        lp.set_period(Duration::from_secs_f64(new));
        self.tick_adjusts += 1;
        if let Some(o) = self.obs.as_mut() {
            o.plane.reg.inc(o.c_tick_adjusts, 1);
            o.plane.reg.set_gauge(o.g_tick_period, new);
        }
        self.obs_emit(
            0,
            EventKind::TickAdjust,
            obs::NO_COFLOW,
            (new * 1e9).round() as u64,
            (period * 1e9).round() as u64,
        );
    }

    fn sim_now(&self) -> Time {
        self.start.elapsed().as_secs_f64() * self.cfg.time_scale
    }

    /// Record one lifecycle event, stamped with both clocks (`t` in
    /// simulated seconds, `wall_ns` since service start). One branch when
    /// the plane is off — no payload is built.
    #[inline]
    fn obs_emit(&mut self, shard: u32, kind: EventKind, coflow: u64, a: u64, b: u64) {
        let Some(o) = self.obs.as_mut() else { return };
        let el = self.start.elapsed();
        o.plane.emit(
            el.as_secs_f64() * self.cfg.time_scale,
            el.as_nanos() as u64,
            shard,
            kind,
            coflow,
            a,
            b,
        );
    }

    /// Advance the world's simulated clock to the service clock. Scheduler
    /// hooks read `world.now` (Philae's aging lane, dcoflow's admission
    /// slack and expiry sweep), so it must track `sim_now()` — a frozen
    /// clock would make every deadline look infinitely far away.
    fn touch_clock(&mut self) {
        self.world.now = self.sim_now();
    }

    /// Seal every shard's durable scheduling facts (the supervisor's
    /// periodic checkpoint). The latest seal per shard stays in memory —
    /// the supervisor's working copy — and is additionally persisted with
    /// an atomic write-then-rename when [`ServiceConfig::checkpoint_dir`]
    /// is set, so an external restart never observes a torn file. A disk
    /// write failure is tolerated: the in-memory copy stays authoritative.
    fn checkpoint_shards(&mut self) {
        for s in 0..self.shards.len() {
            let sh = &mut self.shards[s];
            std::mem::swap(&mut self.world.active, &mut sh.active);
            let state = match (&sh.philae, &sh.generic) {
                (Some(ph), _) => ph.export_state(),
                (_, Some(g)) => g.export_state(),
                _ => JsonValue::Null,
            };
            let payload = recovery::checkpoint_with_state(self.cfg.kind, state, &self.world);
            std::mem::swap(&mut self.world.active, &mut sh.active);
            let sealed = recovery::seal(payload);
            if let Some(dir) = &self.cfg.checkpoint_dir {
                let _ = std::fs::create_dir_all(dir);
                let _ = recovery::write_atomic(&dir.join(format!("shard_{s}.ckpt")), &sealed);
            }
            self.last_ckpts[s] = Some(sealed);
            self.checkpoints_written += 1;
            self.obs_emit(s as u32, EventKind::Checkpoint, obs::NO_COFLOW, self.checkpoints_written, 0);
        }
    }

    /// Chaos kill: discard shard `s`'s scheduler and rebuild it against
    /// the surviving world. Philae's dedicated path re-adopts sampling
    /// facts per coflow (its stale checkpoint import is deliberately a
    /// no-op — see `philae.rs`); generic kinds run the stale-merge
    /// restore of the shard's last checkpoint, which re-asserts dcoflow
    /// admission certificates sealed before the crash. Leases, coflow
    /// ownership, flushed-rate memory, and the shard's queued input are
    /// untouched — the queue replays through the ordinary drain cycle, so
    /// no report is lost — and agent threads are never killed: flows keep
    /// moving at the last complied schedule for the whole failover, which
    /// is the paper's case for dumb agents and a soft-state coordinator.
    fn kill_restore_shard(&mut self, s: usize) {
        let t0 = Instant::now();
        self.crashes_injected += 1;
        self.touch_clock();
        if self.shards[s].philae.is_some() {
            let mut core = PhilaeCore::new(self.cfg.sched.clone());
            let mut completed: Vec<(CoflowId, Vec<f64>)> = Vec::new();
            {
                let sh = &mut self.shards[s];
                std::mem::swap(&mut self.world.active, &mut sh.active);
                for i in 0..self.world.active.len() {
                    let cid = self.world.active[i];
                    if self.world.coflows[cid].done() {
                        continue;
                    }
                    if let Some(samples) = core.adopt(cid, &self.world) {
                        completed.push((cid, samples));
                    }
                }
                std::mem::swap(&mut self.world.active, &mut sh.active);
                sh.philae = Some(core);
            }
            for (cid, samples) in completed {
                // the sample finished while its last report was in flight
                // at crash time — estimate now (mirrors `migrate`)
                let n = self.world.coflows[cid].flows.len();
                let est = self.engine_estimate(&samples, n, cid);
                self.world.coflows[cid].est_size = Some(est);
                if self.world.coflows[cid].finished_at.is_none() {
                    self.world.coflows[cid].phase = CoflowPhase::Running;
                }
            }
            self.scores_dirty = true;
        } else {
            let trace = self.trace_copy.take().expect("chaos armed without a trace copy");
            let payload = match self.last_ckpts[s].as_deref().map(recovery::unseal) {
                Some(Ok(p)) => p,
                // crash before the first checkpoint: a minimal payload
                // drives the same restore path with only the attach rebuild
                _ => {
                    let mut p = std::collections::BTreeMap::new();
                    p.insert(
                        "kind".to_string(),
                        JsonValue::String(self.cfg.kind.as_str().to_string()),
                    );
                    p.insert("sched".to_string(), JsonValue::Null);
                    p.insert("coflows".to_string(), JsonValue::Array(Vec::new()));
                    JsonValue::Object(p)
                }
            };
            let sh = &mut self.shards[s];
            std::mem::swap(&mut self.world.active, &mut sh.active);
            let restored = recovery::restore_scheduler(
                &payload,
                &trace,
                &self.cfg.sched,
                &mut self.world,
                false,
            );
            std::mem::swap(&mut self.world.active, &mut sh.active);
            sh.generic = Some(restored.expect("restore from a self-sealed checkpoint"));
            self.trace_copy = Some(trace);
        }
        self.reallocate_shard(s);
        self.recoveries += 1;
        let rec_wall = t0.elapsed();
        self.recovery_wall.push(rec_wall.as_secs_f64());
        // b = recovery wall time in ns (renders as a span in the Chrome
        // trace export)
        self.obs_emit(
            s as u32,
            EventKind::Restore,
            obs::NO_COFLOW,
            self.recoveries,
            rec_wall.as_nanos() as u64,
        );
    }

    /// Watchdog bookkeeping: any message from a port proves its agent
    /// alive; a previously aged-out port rejoins the plan immediately.
    /// The port's report cadence (EWMA of inter-report gaps, in δ
    /// intervals) feeds the auto-tuned miss threshold
    /// ([`ServiceConfig::agent_miss_auto`]). Same-interval bursts do not
    /// drag the estimate toward zero — only whole-interval gaps count.
    fn note_agent(&mut self, port: PortId) {
        if port >= self.port_last_seen.len() {
            return;
        }
        let gap = self.intervals_seen.saturating_sub(self.port_last_seen[port]) as f64;
        if self.gap_ewma[port] == 0.0 {
            // first report establishes the cadence baseline
            self.gap_ewma[port] = gap.max(1.0);
        } else if gap > 0.0 {
            self.gap_ewma[port] = AUTO_MISS_EWMA_ALPHA * gap
                + (1.0 - AUTO_MISS_EWMA_ALPHA) * self.gap_ewma[port];
        }
        self.port_last_seen[port] = self.intervals_seen;
        if !self.port_alive[port] {
            self.port_alive[port] = true;
            self.dead_ports -= 1;
            self.ports_restored += 1;
            for sh in &mut self.shards {
                sh.force_realloc = true;
            }
            self.obs_emit(0, EventKind::AgentReturn, obs::NO_COFLOW, port as u64, 0);
        }
    }

    /// Age out ports whose agent has stopped reporting: past the miss
    /// threshold, a port that still has pending demand is masked out of
    /// every shard's allocation until its agent reappears. Masking frees
    /// nothing physically — it stops the allocator from parking rate
    /// certificates on a black hole, letting competing coflows use their
    /// other ports' capacity. The threshold is the flat operator override
    /// ([`ServiceConfig::agent_miss_intervals`]) when set; otherwise it is
    /// derived per port from the observed report cadence
    /// ([`auto_miss_threshold`] over the EWMA inter-report gap), and a
    /// port that has never reported is never aged out.
    ///
    /// Auto mode additionally requires the port to hold a rate grant
    /// *newer than its last report*: silence while holding capacity is
    /// the black-hole signature, whereas a starved port — granted
    /// nothing, so with nothing to complete — is legitimately quiet and
    /// masking it would stall its flows for good.
    fn sweep_agent_watchdog(&mut self) {
        let mut changed = false;
        for p in 0..self.world.fabric.num_ports {
            if !self.port_alive[p] {
                continue;
            }
            let threshold = if self.cfg.agent_miss_intervals > 0 {
                self.cfg.agent_miss_intervals
            } else if self.gap_ewma[p] > 0.0 && self.port_rate_stamp[p] > self.port_last_seen[p] {
                auto_miss_threshold(self.gap_ewma[p])
            } else {
                // auto mode: no cadence observed yet, or no grant newer
                // than the last report (starved ports stay unmasked)
                continue;
            };
            let idle = self.intervals_seen.saturating_sub(self.port_last_seen[p]);
            if idle > threshold && self.world.load.up_bytes[p] > 0.0 {
                self.port_alive[p] = false;
                self.dead_ports += 1;
                self.ports_aged_out += 1;
                changed = true;
                self.obs_emit(0, EventKind::AgentAgeOut, obs::NO_COFLOW, p as u64, idle);
            }
        }
        if changed {
            for s in 0..self.shards.len() {
                self.reallocate_shard(s);
            }
        }
    }

    /// Initialize the per-shard leases to an exact equal split (K=1: the
    /// whole fabric). Demand-weighted rebalancing happens at reconcile.
    fn ensure_leases(&mut self) {
        if self.leases_ready {
            return;
        }
        let k = self.shards.len();
        let np = self.world.fabric.num_ports;
        for sh in &mut self.shards {
            sh.lease.num_ports = np;
            sh.lease.up_capacity.clear();
            sh.lease.up_capacity.resize(np, 0.0);
            sh.lease.down_capacity.clear();
            sh.lease.down_capacity.resize(np, 0.0);
        }
        self.wf_demand[..k].fill(0.0);
        for p in 0..np {
            cluster::water_fill_port(
                self.world.fabric.up_capacity[p],
                &self.wf_demand[..k],
                LEASE_FLOOR_FRAC,
                &mut self.wf_out[..k],
                &mut self.wf_scratch,
            );
            for s in 0..k {
                self.shards[s].lease.up_capacity[p] = self.wf_out[s];
            }
            cluster::water_fill_port(
                self.world.fabric.down_capacity[p],
                &self.wf_demand[..k],
                LEASE_FLOOR_FRAC,
                &mut self.wf_out[..k],
                &mut self.wf_scratch,
            );
            for s in 0..k {
                self.shards[s].lease.down_capacity[p] = self.wf_out[s];
            }
        }
        self.leases_ready = true;
    }

    /// Register a coflow: extend the world, assign a home shard, notify src
    /// agents, run the shard scheduler's arrival hook.
    fn register(&mut self, rec: &TraceRecord) -> CoflowId {
        let cid = self.world.coflows.len();
        self.touch_clock();
        let now = self.world.now;
        let mut flow_ids = Vec::new();
        let mut total = 0.0;
        for &(dst, reducer_bytes) in &rec.reducers {
            let per_flow = reducer_bytes / rec.mappers.len() as f64;
            for &src in &rec.mappers {
                let fid = self.world.flows.len();
                self.world
                    .flows
                    .push(FlowState::new(fid, cid, src, dst, per_flow));
                flow_ids.push(fid);
                total += per_flow;
            }
        }
        let mut c = CoflowState::new(cid, now, flow_ids.clone(), total, self.seq);
        self.seq += 1;
        c.phase = CoflowPhase::Running;
        // re-anchor the record's deadline allowance to the service clock
        c.deadline = rec.deadline.map(|d| now + (d - rec.arrival).max(0.0));
        c.senders = rec.mappers.clone();
        c.senders.sort_unstable();
        c.senders.dedup();
        c.receivers = rec.reducers.iter().map(|&(p, _)| p).collect();
        c.receivers.sort_unstable();
        c.receivers.dedup();
        // clairvoyant bottleneck bound — same math as the sim world
        // builders, so SEBF keys match across the serve and sim surfaces
        let mut up_b: Vec<(PortId, f64)> = Vec::new();
        let mut down_b: Vec<(PortId, f64)> = Vec::new();
        for &f in &flow_ids {
            let fl = self.world.flows[f];
            match up_b.iter_mut().find(|(p, _)| *p == fl.src) {
                Some(e) => e.1 += fl.size,
                None => up_b.push((fl.src, fl.size)),
            }
            match down_b.iter_mut().find(|(p, _)| *p == fl.dst) {
                Some(e) => e.1 += fl.size,
                None => down_b.push((fl.dst, fl.size)),
            }
        }
        let mut bn = 0.0f64;
        for &(_, b) in &up_b {
            bn = bn.max(b);
        }
        for &(_, b) in &down_b {
            bn = bn.max(b);
        }
        c.bottleneck_bytes = bn;
        for (i, &fid) in c.active_list.iter().enumerate() {
            self.world.flows[fid].active_pos = i;
        }
        self.world.coflows.push(c);
        self.world.active.push(cid);

        // shard assignment (hash router, same as the sim cluster)
        let k = self.shards.len();
        let s = (cluster::route_hash(cid) % k as u64) as usize;
        if self.owner.len() <= cid {
            self.owner.resize(cid + 1, NO_OWNER);
        }
        self.owner[cid] = s as u32;
        self.shards[s].active.push(cid);

        // port refs + load
        let mut up: Vec<(PortId, usize)> = Vec::new();
        let mut down: Vec<(PortId, usize)> = Vec::new();
        for &f in &flow_ids {
            let fl = self.world.flows[f];
            self.world.load.up_bytes[fl.src] += fl.size;
            self.world.load.down_bytes[fl.dst] += fl.size;
            match up.iter_mut().find(|(p, _)| *p == fl.src) {
                Some(e) => e.1 += 1,
                None => up.push((fl.src, 1)),
            }
            match down.iter_mut().find(|(p, _)| *p == fl.dst) {
                Some(e) => e.1 += 1,
                None => down.push((fl.dst, 1)),
            }
        }
        for &(p, _) in &up {
            self.world.load.occupy_up(p);
        }
        for &(p, _) in &down {
            self.world.load.occupy_down(p);
        }
        self.port_refs.push(up);
        self.port_refs_down.push(down);

        self.scores_dirty = true;
        // shard scheduler arrival hooks (Philae marks pilots, dcoflow runs
        // its admission test here), run against the shard's partition view
        {
            let sh = &mut self.shards[s];
            std::mem::swap(&mut self.world.active, &mut sh.active);
            if let Some(ph) = sh.philae.as_mut() {
                ph.handle_arrival(cid, &mut self.world);
            }
            if let Some(g) = sh.generic.as_mut() {
                g.on_arrival(cid, &mut self.world);
            }
            std::mem::swap(&mut self.world.active, &mut sh.active);
        }

        // ship flows to their src agents
        for &f in &flow_ids {
            let fl = self.world.flows[f];
            let _ = self.agents[fl.src].tx.send(CoordMsg::AddFlow {
                flow: f,
                coflow: cid,
                size: fl.size,
                pilot: fl.pilot,
            });
        }
        let nflows = self.world.coflows[cid].flows.len() as u64;
        self.obs_emit(s as u32, EventKind::Arrival, cid as u64, nflows, 0);
        cid
    }

    /// Deregister: drop unfinished flows and release port state.
    fn deregister(&mut self, cid: CoflowId) {
        if cid >= self.world.coflows.len() || self.world.coflows[cid].done() {
            return;
        }
        self.touch_clock();
        let now = self.world.now;
        let flow_ids = self.world.coflows[cid].flows.clone();
        for f in flow_ids {
            if !self.world.flows[f].done() {
                self.world.flows[f].finished_at = Some(now);
                for sh in &mut self.shards {
                    sh.last_rates.remove(&f);
                }
                let fl = self.world.flows[f];
                self.world.load.up_bytes[fl.src] =
                    (self.world.load.up_bytes[fl.src] - fl.size).max(0.0);
                self.world.load.down_bytes[fl.dst] =
                    (self.world.load.down_bytes[fl.dst] - fl.size).max(0.0);
            }
        }
        for i in 0..self.port_refs[cid].len() {
            let (p, n) = self.port_refs[cid][i];
            if n > 0 {
                self.world.load.release_up(p);
            }
        }
        for i in 0..self.port_refs_down[cid].len() {
            let (p, n) = self.port_refs_down[cid][i];
            if n > 0 {
                self.world.load.release_down(p);
            }
        }
        self.port_refs[cid].clear();
        self.port_refs_down[cid].clear();
        let c = &mut self.world.coflows[cid];
        c.active_flows = 0;
        c.active_list.clear();
        c.finished_at = Some(now);
        c.phase = CoflowPhase::Done;
        self.world.active.retain(|&x| x != cid);
        if let Some(s) = self.owner_of(cid) {
            self.shards[s].active.retain(|&x| x != cid);
            self.owner[cid] = NO_OWNER;
            // let the owning scheduler drop per-coflow state (dcoflow
            // releases its reservation here)
            let sh = &mut self.shards[s];
            std::mem::swap(&mut self.world.active, &mut sh.active);
            if let Some(g) = sh.generic.as_mut() {
                g.on_coflow_detach(cid, &mut self.world);
            }
            std::mem::swap(&mut self.world.active, &mut sh.active);
        }
    }

    /// Apply one agent message to the world (shard `s` owns the coflow).
    /// Returns true if it warrants an (event-triggered) realloc.
    fn handle_agent_msg(&mut self, s: usize, msg: AgentMsg) -> bool {
        match msg {
            AgentMsg::FlowComplete { flow, coflow, size, .. } => {
                self.iv_updates += 1;
                self.update_msgs += 1;
                if flow >= self.world.flows.len() || self.world.flows[flow].done() {
                    return false;
                }
                self.touch_clock();
                let now = self.world.now;
                {
                    let fl = &mut self.world.flows[flow];
                    fl.sent = fl.size;
                    fl.rate = 0.0;
                    fl.finished_at = Some(now);
                }
                self.shards[s].last_rates.remove(&flow);
                let fl = self.world.flows[flow];
                self.world.load.up_bytes[fl.src] =
                    (self.world.load.up_bytes[fl.src] - size).max(0.0);
                self.world.load.down_bytes[fl.dst] =
                    (self.world.load.down_bytes[fl.dst] - size).max(0.0);
                let mut freed_up = false;
                if let Some(e) = self.port_refs[coflow].iter_mut().find(|(p, _)| *p == fl.src) {
                    e.1 = e.1.saturating_sub(1);
                    freed_up = e.1 == 0;
                }
                if freed_up {
                    self.world.load.release_up(fl.src);
                }
                let mut freed_down = false;
                if let Some(e) = self.port_refs_down[coflow]
                    .iter_mut()
                    .find(|(p, _)| *p == fl.dst)
                {
                    e.1 = e.1.saturating_sub(1);
                    freed_down = e.1 == 0;
                }
                if freed_down {
                    self.world.load.release_down(fl.dst);
                }
                // learning hooks (Philae's sampling state machine)
                if let Some(mut ph) = self.shards[s].philae.take() {
                    if let CompletionOutcome::SampleComplete(samples) =
                        ph.record_completion(flow, &mut self.world)
                    {
                        let n = self.world.coflows[coflow].flows.len();
                        let est = self.engine_estimate(&samples, n, coflow);
                        self.world.coflows[coflow].est_size = Some(est);
                        self.world.coflows[coflow].phase = CoflowPhase::Running;
                        self.scores_dirty = true;
                        self.obs_emit(
                            s as u32,
                            EventKind::Estimate,
                            coflow as u64,
                            est.max(0.0) as u64,
                            0,
                        );
                        // phase code 1 = Running (matches the sim engine's
                        // CoflowPhase discriminants)
                        self.obs_emit(s as u32, EventKind::Phase, coflow as u64, 1, 0);
                    }
                    self.shards[s].philae = Some(ph);
                }
                let pos = self.world.flows[flow].active_pos;
                {
                    let c = &mut self.world.coflows[coflow];
                    if pos < c.active_list.len() && c.active_list[pos] == flow {
                        c.active_list.swap_remove(pos);
                        if pos < c.active_list.len() {
                            let moved = c.active_list[pos];
                            self.world.flows[moved].active_pos = pos;
                        }
                    } else if let Some(i) = c.active_list.iter().position(|&x| x == flow) {
                        c.active_list.swap_remove(i);
                        if i < c.active_list.len() {
                            let moved = c.active_list[i];
                            self.world.flows[moved].active_pos = i;
                        }
                    }
                }
                let mut coflow_finished = false;
                {
                    let c = &mut self.world.coflows[coflow];
                    c.active_flows = c.active_flows.saturating_sub(1);
                    if size > c.max_finished_flow {
                        c.max_finished_flow = size;
                    }
                    if c.active_flows == 0 && c.finished_at.is_none() {
                        c.finished_at = Some(now);
                        c.phase = CoflowPhase::Done;
                        coflow_finished = true;
                    }
                }
                if coflow_finished {
                    self.world.active.retain(|&x| x != coflow);
                    if let Some(o) = self.owner_of(coflow) {
                        self.shards[o].active.retain(|&x| x != coflow);
                        self.owner[coflow] = NO_OWNER;
                    }
                    self.scores_dirty = true;
                }
                // generic-scheduler hooks, mirroring the sim engine's
                // order: the report (and the coflow-completion event when
                // this was the last flow) lands after all physical
                // bookkeeping, against the shard's partition view
                {
                    let sh = &mut self.shards[s];
                    if sh.generic.is_some() {
                        std::mem::swap(&mut self.world.active, &mut sh.active);
                        if let Some(g) = sh.generic.as_mut() {
                            g.on_flow_complete(flow, &mut self.world);
                            if coflow_finished {
                                g.on_coflow_complete(coflow, &mut self.world);
                            }
                        }
                        std::mem::swap(&mut self.world.active, &mut sh.active);
                    }
                }
                if self.obs.is_some() {
                    self.obs_emit(
                        s as u32,
                        EventKind::FlowComplete,
                        coflow as u64,
                        flow as u64,
                        size.max(0.0) as u64,
                    );
                    if coflow_finished {
                        let total = self.world.coflows[coflow].total_bytes.max(0.0) as u64;
                        self.obs_emit(s as u32, EventKind::CoflowComplete, coflow as u64, 0, total);
                    }
                }
                true
            }
            AgentMsg::ByteUpdate { coflow, bytes_sent, .. } => {
                self.iv_updates += 1;
                self.update_msgs += 1;
                if coflow < self.world.coflows.len() {
                    // Each agent reports its local share; the coordinator's
                    // view is the running max of partial aggregates (an
                    // under-estimate between intervals, exactly like Aalo's
                    // stale view).
                    let c = &mut self.world.coflows[coflow];
                    c.bytes_sent = c.bytes_sent.max(bytes_sent);
                }
                false
            }
        }
    }

    /// Size estimation, through PJRT when the engine is loaded.
    fn engine_estimate(&mut self, samples: &[f64], nflows: usize, cid: CoflowId) -> f64 {
        if let (Some(engine), Some(batch)) = (self.engine.as_ref(), self.batch.as_mut()) {
            batch.clear();
            batch.set_row(
                0,
                samples,
                nflows,
                0.0,
                &[],
                self.cfg.sched.bootstrap_seed ^ cid as u64,
            );
            if let Ok((est, _lcb)) = engine.estimate(batch) {
                if let Some(&e) = est.first() {
                    return e as f64;
                }
            }
        }
        crate::runtime::native_estimate(samples, nflows as f64)
    }

    /// Compute shard `s`'s priority order (through the PJRT scorer when
    /// loaded), allocate rates against its lease, and push per-agent
    /// schedules. Shares the incremental order path and the
    /// [`rate::AllocScratch`] workspace with the simulator's hot loop.
    fn reallocate_shard(&mut self, s: usize) {
        self.ensure_leases();
        self.touch_clock();
        let t0 = Instant::now();
        if self.shards[s].philae.is_some() && self.engine.is_some() && self.scores_dirty {
            self.cached_scores = self.engine_scores();
            self.scores_dirty = false;
        }
        {
            let sh = &mut self.shards[s];
            std::mem::swap(&mut self.world.active, &mut sh.active);
            if let Some(ph) = sh.philae.as_mut() {
                if self.engine.is_some() {
                    ph.order_with_scores_into(&self.world, &self.cached_scores, &mut sh.plan);
                } else {
                    ph.order_into(&self.world, &mut sh.plan);
                }
            } else if let Some(g) = sh.generic.as_mut() {
                g.order_into(&self.world, &mut sh.plan);
            } else {
                sh.plan.clear();
            }
            std::mem::swap(&mut self.world.active, &mut sh.active);
            // agent-loss masking: an aged-out port contributes no capacity
            let lease: &Fabric = if self.dead_ports > 0 {
                self.masked_lease.num_ports = sh.lease.num_ports;
                self.masked_lease.up_capacity.clear();
                self.masked_lease.up_capacity.extend_from_slice(&sh.lease.up_capacity);
                self.masked_lease.down_capacity.clear();
                self.masked_lease.down_capacity.extend_from_slice(&sh.lease.down_capacity);
                for p in 0..self.masked_lease.num_ports {
                    if !self.port_alive[p] {
                        self.masked_lease.up_capacity[p] = 0.0;
                        self.masked_lease.down_capacity[p] = 0.0;
                    }
                }
                &self.masked_lease
            } else {
                &sh.lease
            };
            rate::allocate_into(
                lease,
                &self.world.flows,
                &self.world.coflows,
                &sh.plan,
                &mut sh.scratch,
            );
        }
        let calc = t0.elapsed().as_secs_f64();
        self.iv_calc += calc;
        self.iv_rate_calcs += 1;
        self.rate_calcs += 1;
        self.calc_hist.record_secs(calc);
        if let Some(o) = self.obs.as_mut() {
            o.plane.reg.observe_secs(o.h_realloc, calc);
        }

        // diff this shard's grants against its last flushed rates to find
        // the agents whose schedule changed (reused scratch vec — the
        // steady state of this whole send path is allocation-free)
        let t1 = Instant::now();
        self.dirty_agents.clear();
        {
            let sh = &self.shards[s];
            for &(f, r) in sh.scratch.grants() {
                let prev = sh.last_rates.get(&f).copied().unwrap_or(0.0);
                if (prev - r).abs() > crate::EPS {
                    let a = self.world.flows[f].src;
                    if !self.dirty_agents.contains(&a) {
                        self.dirty_agents.push(a);
                    }
                }
            }
            for (&f, _) in sh.last_rates.iter() {
                if !sh.scratch.was_granted(f) && !self.world.flows[f].done() {
                    let a = self.world.flows[f].src;
                    if !self.dirty_agents.contains(&a) {
                        self.dirty_agents.push(a);
                    }
                }
            }
        }
        // a schedule message carries *all* rates for that agent — across
        // every shard's latest allocation — so "comply with the last
        // schedule" stays consistent and never stalls another shard's
        // flows. Only the coflow's *current* owner contributes a flow's
        // rate: after a migration the old owner's scratch still lists the
        // flow until its next recompute, and a stale duplicate would
        // otherwise win at the agent (last entry applies). One pass over
        // all shards' grants buckets them by agent (O(grants), not
        // O(dirty_agents × grants)). The per-agent vectors come from the
        // recycled free-list: agents boomerang consumed buffers back
        // through `recycle_tx` and we reclaim them here, so sustained
        // reallocation churns zero heap.
        self.recycle_bin.drain_into(&mut self.sched_bufs);
        for i in 0..self.dirty_agents.len() {
            let agent = self.dirty_agents[i];
            let mut buf = self.sched_bufs.take();
            buf.clear();
            self.per_agent.insert(agent, buf);
        }
        for (si, sh) in self.shards.iter().enumerate() {
            for &(f, r) in sh.scratch.grants() {
                let fl = &self.world.flows[f];
                if fl.done() || self.owner_of(fl.coflow) != Some(si) {
                    continue;
                }
                if let Some(rates) = self.per_agent.get_mut(&fl.src) {
                    rates.push((f, r));
                }
            }
        }
        for i in 0..self.dirty_agents.len() {
            let agent = self.dirty_agents[i];
            let rates = self.per_agent.remove(&agent).unwrap_or_default();
            let _ = self.agents[agent].tx.send(CoordMsg::NewSchedule { rates });
            self.iv_rate_msgs += 1;
            self.rate_msgs += 1;
        }
        let mut granted = 0.0f64;
        {
            let sh = &mut self.shards[s];
            sh.last_rates.clear();
            for &(f, r) in sh.scratch.grants() {
                sh.last_rates.insert(f, r);
                granted += r;
                if r > 0.0 {
                    self.port_rate_stamp[self.world.flows[f].src] = self.intervals_seen;
                }
            }
        }
        if let Some(o) = self.obs.as_mut() {
            // granted rate over leased uplink capacity: a starved or idle
            // shard reads ~0, a saturated lease reads ~1
            let cap: f64 = self.shards[s].lease.up_capacity.iter().sum();
            let util = if cap > 0.0 { granted / cap } else { 0.0 };
            let id = o.g_lease_util[s];
            o.plane.reg.set_gauge(id, util);
        }
        self.iv_send += t1.elapsed().as_secs_f64();
    }

    /// Cross-shard reconciliation (K > 1): observe per-shard demand,
    /// migrate coflows away from saturated shards, and water-fill the
    /// capacity leases (see `coordinator/cluster.rs` — same policy and
    /// tie-breaks as the simulator's cluster).
    fn reconcile(&mut self) {
        let k = self.shards.len();
        let np = self.world.fabric.num_ports;
        self.ensure_leases();
        for s in 0..k {
            let sh = &mut self.shards[s];
            if sh.demand_up.len() < np {
                sh.demand_up.resize(np, 0.0);
                sh.demand_down.resize(np, 0.0);
            }
            sh.demand_up[..np].fill(0.0);
            sh.demand_down[..np].fill(0.0);
            let mut total = 0.0;
            for i in 0..sh.active.len() {
                let cid = sh.active[i];
                let c = &self.world.coflows[cid];
                if c.done() {
                    continue;
                }
                for &f in &c.active_list {
                    let fl = &self.world.flows[f];
                    let rem = fl.remaining();
                    sh.demand_up[fl.src] += rem;
                    sh.demand_down[fl.dst] += rem;
                    total += rem;
                }
            }
            self.demand_total[s] = total;
        }
        // migrate while the heaviest shard saturates its share
        let mut moves = 0;
        while moves < MAX_MIGRATIONS_PER_ROUND {
            let mut smax = 0;
            let mut smin = 0;
            for s in 1..k {
                if self.demand_total[s] > self.demand_total[smax] {
                    smax = s;
                }
                if self.demand_total[s] < self.demand_total[smin] {
                    smin = s;
                }
            }
            let mean = self.demand_total[..k].iter().sum::<f64>() / k as f64;
            if smax == smin
                || self.shards[smax].active.len() < 2
                || self.demand_total[smax] <= IMBALANCE_THRESHOLD * mean
            {
                break;
            }
            let mut victim: Option<(f64, CoflowId)> = None;
            for i in 0..self.shards[smax].active.len() {
                let cid = self.shards[smax].active[i];
                let c = &self.world.coflows[cid];
                if c.done() {
                    continue;
                }
                let rem: f64 = c
                    .active_list
                    .iter()
                    .map(|&f| self.world.flows[f].remaining())
                    .sum();
                if rem <= 0.0 {
                    continue;
                }
                let take = match victim {
                    None => true,
                    Some((vr, vc)) => rem < vr || (rem == vr && cid < vc),
                };
                if take {
                    victim = Some((rem, cid));
                }
            }
            let Some((rem, cid)) = victim else { break };
            self.migrate(cid, smax, smin);
            self.demand_total[smax] -= rem;
            self.demand_total[smin] += rem;
            moves += 1;
        }
        // water-fill the leases from the (post-migration) demand
        for p in 0..np {
            for s in 0..k {
                self.wf_demand[s] = self.shards[s].demand_up[p];
            }
            cluster::water_fill_port(
                self.world.fabric.up_capacity[p],
                &self.wf_demand[..k],
                LEASE_FLOOR_FRAC,
                &mut self.wf_out[..k],
                &mut self.wf_scratch,
            );
            for s in 0..k {
                self.shards[s].lease.up_capacity[p] = self.wf_out[s];
            }
            for s in 0..k {
                self.wf_demand[s] = self.shards[s].demand_down[p];
            }
            cluster::water_fill_port(
                self.world.fabric.down_capacity[p],
                &self.wf_demand[..k],
                LEASE_FLOOR_FRAC,
                &mut self.wf_out[..k],
                &mut self.wf_scratch,
            );
            for s in 0..k {
                self.shards[s].lease.down_capacity[p] = self.wf_out[s];
            }
        }
        self.reconciliations += 1;
        self.obs_emit(0, EventKind::LeaseReconcile, obs::NO_COFLOW, k as u64, 0);
        if let Some(o) = self.obs.as_mut() {
            o.plane.reg.inc(o.c_reconciliations, 1);
        }
    }

    /// Move `cid` from shard `from` to shard `to`: ownership, queued
    /// demand, flushed-rate bookkeeping, and the scheduler attach hook
    /// (Philae rebuilds its sampling state from completed-flow facts;
    /// Aalo keeps the coflow's earned queue and seen bytes).
    fn migrate(&mut self, cid: CoflowId, from: usize, to: usize) {
        debug_assert_ne!(from, to);
        // hand the coflow's per-port demand to the receiver
        for i in 0..self.world.coflows[cid].active_list.len() {
            let f = self.world.coflows[cid].active_list[i];
            let fl = self.world.flows[f];
            let rem = fl.remaining();
            self.shards[from].demand_up[fl.src] =
                (self.shards[from].demand_up[fl.src] - rem).max(0.0);
            self.shards[from].demand_down[fl.dst] =
                (self.shards[from].demand_down[fl.dst] - rem).max(0.0);
            self.shards[to].demand_up[fl.src] += rem;
            self.shards[to].demand_down[fl.dst] += rem;
        }
        // flushed-rate entries travel with the coflow so neither shard's
        // next diff spuriously stalls or restarts its flows
        let flow_ids = self.world.coflows[cid].flows.clone();
        for f in flow_ids {
            if let Some(r) = self.shards[from].last_rates.remove(&f) {
                self.shards[to].last_rates.insert(f, r);
            }
        }
        self.shards[from].active.retain(|&x| x != cid);
        // detach hook on the source (its view no longer contains cid):
        // dcoflow hands its reservation back, Aalo/others are a no-op
        {
            let sh = &mut self.shards[from];
            if sh.generic.is_some() {
                std::mem::swap(&mut self.world.active, &mut sh.active);
                if let Some(g) = sh.generic.as_mut() {
                    g.on_coflow_detach(cid, &mut self.world);
                }
                std::mem::swap(&mut self.world.active, &mut sh.active);
            }
        }
        self.owner[cid] = to as u32;
        self.shards[to].active.push(cid);
        let mut completed_sample: Option<Vec<f64>> = None;
        {
            let sh = &mut self.shards[to];
            std::mem::swap(&mut self.world.active, &mut sh.active);
            if let Some(ph) = sh.philae.as_mut() {
                completed_sample = ph.adopt(cid, &self.world);
            }
            if let Some(g) = sh.generic.as_mut() {
                g.on_coflow_attach(cid, &mut self.world);
            }
            std::mem::swap(&mut self.world.active, &mut sh.active);
        }
        if let Some(samples) = completed_sample {
            // the sample completed while its last report was in flight at
            // migration time (see `PhilaeCore::adopt`): estimate now
            let n = self.world.coflows[cid].flows.len();
            let est = self.engine_estimate(&samples, n, cid);
            self.world.coflows[cid].est_size = Some(est);
            if self.world.coflows[cid].finished_at.is_none() {
                self.world.coflows[cid].phase = CoflowPhase::Running;
            }
            self.scores_dirty = true;
        }
        self.migrations += 1;
        self.obs_emit(from as u32, EventKind::Migration, cid as u64, from as u64, to as u64);
        if let Some(o) = self.obs.as_mut() {
            o.plane.reg.inc(o.c_migrations, 1);
        }
    }

    /// Batch the scheduled coflows through the PJRT scorer. Each coflow's
    /// sampling features come from its owning shard's Philae core.
    fn engine_scores(&mut self) -> HashMap<CoflowId, f64> {
        let mut out = HashMap::new();
        let (engine, batch) = match (self.engine.as_ref(), self.batch.as_mut()) {
            (Some(e), Some(b)) => (e, b),
            _ => return out,
        };
        if self.shards[0].philae.is_none() {
            return out;
        }
        let half_p = batch.p / 2;
        let cands: Vec<CoflowId> = self
            .world
            .active
            .iter()
            .copied()
            .filter(|&cid| {
                self.world.coflows[cid].phase == CoflowPhase::Running
                    && self.world.coflows[cid].est_size.is_some()
            })
            .collect();
        for chunk in cands.chunks(batch.c) {
            batch.clear();
            for (row, &cid) in chunk.iter().enumerate() {
                let mut ports: Vec<usize> = Vec::new();
                for &(p, n) in &self.port_refs[cid] {
                    if n > 0 {
                        ports.push(p.min(half_p - 1));
                    }
                }
                for &(p, n) in &self.port_refs_down[cid] {
                    if n > 0 {
                        ports.push(half_p + p.min(half_p - 1));
                    }
                }
                let owner = self.owner.get(cid).copied().unwrap_or(NO_OWNER);
                let shard = if owner == NO_OWNER { 0 } else { owner as usize };
                let philae = self.shards[shard].philae.as_ref().expect("philae shards");
                batch.set_row(
                    row,
                    philae.pilot_sizes(cid),
                    self.world.coflows[cid].flows.len(),
                    philae.done_bytes(cid),
                    &ports,
                    self.cfg.sched.bootstrap_seed ^ cid as u64,
                );
            }
            if let Ok(res) = engine.score(batch, self.cfg.sched.contention_weight as f32) {
                for (i, &cid) in chunk.iter().enumerate() {
                    out.insert(cid, res.score[i] as f64);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_miss_threshold_scales_with_cadence() {
        // never below the floor, even for chatty ports
        assert_eq!(auto_miss_threshold(0.1), AUTO_MISS_FLOOR);
        assert_eq!(auto_miss_threshold(1.0), AUTO_MISS_FLOOR);
        // a port reporting every ~4 intervals is missed after ~32
        assert_eq!(auto_miss_threshold(4.0), 32);
        // ceil: fractional cadences round up, never down
        assert_eq!(auto_miss_threshold(4.1), 33);
    }
}
