//! Runtime lifecycle state for flows and coflows inside the simulator and
//! the coordinator service.

use crate::{Bytes, CoflowId, FlowId, PortId, Time, EPS};

/// Where a coflow is in the Philae pipeline. Aalo-style schedulers only use
/// `Running`/`Done`; Philae walks `Piloting → Running → Done`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoflowPhase {
    /// Pilot flows dispatched, size estimate pending.
    Piloting,
    /// Size estimated (or not needed); all flows eligible.
    Running,
    /// All flows finished.
    Done,
}

/// Mutable per-flow state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlowState {
    pub id: FlowId,
    pub coflow: CoflowId,
    pub src: PortId,
    pub dst: PortId,
    pub size: Bytes,
    /// Bytes transferred so far.
    pub sent: Bytes,
    /// Current allocated rate in bytes/sec (0 when unscheduled).
    pub rate: f64,
    /// Chosen as a pilot flow by Philae.
    pub pilot: bool,
    /// Completion time, set once.
    pub finished_at: Option<Time>,
    /// Position inside the owning coflow's `active_list` (engine-maintained,
    /// O(1) swap-removal on completion).
    pub active_pos: usize,
    /// Transient mark owned by `rate::apply_grants` — lets the allocator
    /// distinguish granted from stalled flows in a single pass without a
    /// per-call lookup table. Always `false` outside that call.
    pub alloc_mark: bool,
    /// Stable creation sequence: monotone across the run even when flow
    /// *slots* (ids) are recycled by the streaming engine. Event tie-breaks
    /// key on this, never on `id`, so slot recycling stays bit-identical
    /// to the materialized path (where `seq == id`).
    pub seq: u64,
}

impl FlowState {
    pub fn new(id: FlowId, coflow: CoflowId, src: PortId, dst: PortId, size: Bytes) -> Self {
        FlowState {
            id,
            coflow,
            src,
            dst,
            size,
            sent: 0.0,
            rate: 0.0,
            pilot: false,
            finished_at: None,
            active_pos: 0,
            alloc_mark: false,
            seq: id as u64,
        }
    }

    pub fn remaining(&self) -> Bytes {
        (self.size - self.sent).max(0.0)
    }

    pub fn done(&self) -> bool {
        self.finished_at.is_some() || self.remaining() <= EPS
    }

    /// Advance the flow by `dt` seconds at its current rate; returns `true`
    /// if the flow just completed (caller stamps `finished_at`).
    pub fn advance(&mut self, dt: Time) -> bool {
        if self.done() || self.rate <= 0.0 {
            return false;
        }
        self.sent = (self.sent + self.rate * dt).min(self.size);
        self.remaining() <= EPS
    }

    /// Seconds until completion at the current rate (`None` if stalled).
    pub fn eta(&self) -> Option<Time> {
        if self.done() {
            return Some(0.0);
        }
        if self.rate <= 0.0 {
            None
        } else {
            Some(self.remaining() / self.rate)
        }
    }
}

/// Mutable per-coflow state.
#[derive(Debug, Clone)]
pub struct CoflowState {
    pub id: CoflowId,
    pub arrival: Time,
    /// Optional completion deadline (absolute seconds). Carried from the
    /// trace's SLO column; deadline-aware schedulers (EDF keys, DCoflow
    /// admission) read it, deadline-blind ones ignore it entirely.
    pub deadline: Option<Time>,
    pub phase: CoflowPhase,
    /// Flow ids of this coflow.
    pub flows: Vec<FlowId>,
    /// Unfinished flow ids (engine-maintained; iteration set for the rate
    /// allocator — avoids rescanning finished flows of wide coflows).
    pub active_list: Vec<FlowId>,
    /// Distinct sender ports (static).
    pub senders: Vec<crate::PortId>,
    /// Distinct receiver ports (static).
    pub receivers: Vec<crate::PortId>,
    /// Pilot flow ids (Philae only).
    pub pilots: Vec<FlowId>,
    /// Number of flows not yet finished.
    pub active_flows: usize,
    /// Estimated total size in bytes (Philae: width × mean pilot size);
    /// clairvoyant schedulers stash the oracle value here.
    pub est_size: Option<Bytes>,
    /// Total bytes sent so far across all flows (Aalo's queue-transition
    /// "length"; also used for remaining-size scores).
    pub bytes_sent: Bytes,
    /// Total bytes of the coflow (for remaining computations *after*
    /// estimation — Philae uses est_size, oracles use the true value).
    pub total_bytes: Bytes,
    /// Clairvoyant bottleneck bound in bytes: max over the coflow's ports
    /// of the bytes it moves through that port. Filled by the world
    /// builders and the streaming admitter (`0.0` in hand-built worlds —
    /// SEBF falls back to `total_bytes`).
    pub bottleneck_bytes: Bytes,
    /// Longest finished flow so far (Saath transition metric).
    pub max_finished_flow: Bytes,
    /// Completion time.
    pub finished_at: Option<Time>,
    /// Aalo: current priority queue index.
    pub queue: usize,
    /// Monotone FIFO sequence for intra-queue ordering.
    pub seq: u64,
}

impl CoflowState {
    pub fn new(id: CoflowId, arrival: Time, flows: Vec<FlowId>, total_bytes: Bytes, seq: u64) -> Self {
        let n = flows.len();
        CoflowState {
            id,
            arrival,
            deadline: None,
            phase: CoflowPhase::Running,
            active_list: flows.clone(),
            flows,
            senders: Vec::new(),
            receivers: Vec::new(),
            pilots: Vec::new(),
            active_flows: n,
            est_size: None,
            bytes_sent: 0.0,
            total_bytes,
            bottleneck_bytes: 0.0,
            max_finished_flow: 0.0,
            finished_at: None,
            queue: 0,
            seq,
        }
    }

    pub fn done(&self) -> bool {
        self.finished_at.is_some()
    }

    /// Estimated remaining bytes: estimate (if any) minus bytes already
    /// sent, floored at zero. Falls back to "unknown" (None) pre-estimate.
    pub fn est_remaining(&self) -> Option<Bytes> {
        self.est_size.map(|e| (e - self.bytes_sent).max(0.0))
    }

    /// CCT if finished.
    pub fn cct(&self) -> Option<Time> {
        self.finished_at.map(|t| t - self.arrival)
    }

    /// SLO outcome: `None` for best-effort coflows, `Some(true)` iff the
    /// coflow finished by its deadline (unfinished counts as missed).
    pub fn met_deadline(&self) -> Option<bool> {
        self.deadline
            .map(|d| self.finished_at.is_some_and(|t| t <= d + EPS))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flow_advance_and_eta() {
        let mut f = FlowState::new(0, 0, 0, 1, 100.0);
        assert_eq!(f.eta(), None); // stalled at rate 0
        f.rate = 10.0;
        assert_eq!(f.eta(), Some(10.0));
        assert!(!f.advance(5.0));
        assert_eq!(f.sent, 50.0);
        assert!(f.advance(5.0)); // completes exactly
        assert!(f.remaining() <= EPS);
    }

    #[test]
    fn flow_never_oversends() {
        let mut f = FlowState::new(0, 0, 0, 1, 10.0);
        f.rate = 100.0;
        f.advance(1.0);
        assert_eq!(f.sent, 10.0);
    }

    #[test]
    fn coflow_est_remaining() {
        let mut c = CoflowState::new(0, 0.0, vec![0, 1], 100.0, 0);
        assert_eq!(c.est_remaining(), None);
        c.est_size = Some(80.0);
        c.bytes_sent = 30.0;
        assert_eq!(c.est_remaining(), Some(50.0));
        c.bytes_sent = 200.0; // estimate undershoot: clamp at 0
        assert_eq!(c.est_remaining(), Some(0.0));
    }

    #[test]
    fn deadline_outcome() {
        let mut c = CoflowState::new(0, 1.0, vec![0], 10.0, 0);
        assert_eq!(c.met_deadline(), None); // best-effort
        c.deadline = Some(3.0);
        assert_eq!(c.met_deadline(), Some(false)); // unfinished = missed
        c.finished_at = Some(2.5);
        assert_eq!(c.met_deadline(), Some(true));
        c.finished_at = Some(3.5);
        assert_eq!(c.met_deadline(), Some(false));
    }
}
