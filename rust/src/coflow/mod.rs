//! Coflow and flow data model.
//!
//! A **coflow** is a set of flows between cluster ports that accomplish a
//! common task (e.g. the shuffle of one map-reduce job); its completion time
//! (CCT) is the span from the arrival of its first flow to the completion of
//! its last. The model here mirrors the paper's §1: ports are uplink/downlink
//! pairs on a non-blocking switch, flows are (src, dst, size) with no
//! in-network contention.

mod lifecycle;

pub use lifecycle::{CoflowPhase, CoflowState, FlowState};

use crate::{Bytes, CoflowId, FlowId, PortId, Time};

/// An immutable flow description from the trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlowSpec {
    /// Global flow id (dense across the trace).
    pub id: FlowId,
    /// Owning coflow.
    pub coflow: CoflowId,
    /// Sender port (mapper side).
    pub src: PortId,
    /// Receiver port (reducer side).
    pub dst: PortId,
    /// Flow length in bytes.
    pub size: Bytes,
}

/// An immutable coflow description from the trace.
#[derive(Debug, Clone, PartialEq)]
pub struct CoflowSpec {
    /// Dense coflow id.
    pub id: CoflowId,
    /// External id from the trace file (e.g. FB trace job id).
    pub external_id: u64,
    /// Arrival time (seconds).
    pub arrival: Time,
    /// Optional completion deadline (absolute seconds, same clock as
    /// `arrival`) — the SLO the deadline workload family schedules
    /// against. `None` = best-effort coflow.
    pub deadline: Option<Time>,
    /// Flow ids (dense range into the trace flow table).
    pub flows: Vec<FlowId>,
    /// Distinct sender ports.
    pub senders: Vec<PortId>,
    /// Distinct receiver ports.
    pub receivers: Vec<PortId>,
}

impl CoflowSpec {
    /// Number of constituent flows — the coflow's *spatial dimension* the
    /// paper's sampling idea exploits.
    pub fn num_flows(&self) -> usize {
        self.flows.len()
    }

    /// Width as the paper uses it for the wide-coflow filter: the number of
    /// distinct ports the coflow is present on.
    pub fn width(&self) -> usize {
        self.senders.len() + self.receivers.len()
    }

    /// `true` if the coflow touches more than one sender or receiver port —
    /// the “Wide-coflow-only” filter of Table 2.
    pub fn is_wide(&self) -> bool {
        self.senders.len() > 1 || self.receivers.len() > 1
    }
}

/// Aggregate facts about a coflow derivable from its spec (clairvoyant
/// schedulers use these; non-clairvoyant ones must not).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoflowOracle {
    /// Total bytes over all flows (the classic SCF “length”).
    pub total_bytes: Bytes,
    /// Longest single flow in bytes (Saath's queue-transition metric).
    pub max_flow: Bytes,
    /// Shortest single flow in bytes.
    pub min_flow: Bytes,
    /// Bottleneck bytes: max over ports of bytes the coflow must move
    /// through that port (Varys' SEBF effective-bottleneck metric).
    pub bottleneck_bytes: Bytes,
}

impl CoflowOracle {
    /// Compute oracle aggregates for `coflow` from the global flow table.
    pub fn compute(coflow: &CoflowSpec, flows: &[FlowSpec], num_ports: usize) -> Self {
        let mut total = 0.0;
        let mut max_flow: Bytes = 0.0;
        let mut min_flow: Bytes = f64::INFINITY;
        let mut up = vec![0.0f64; num_ports];
        let mut down = vec![0.0f64; num_ports];
        for &fid in &coflow.flows {
            let f = &flows[fid];
            total += f.size;
            max_flow = max_flow.max(f.size);
            min_flow = min_flow.min(f.size);
            up[f.src] += f.size;
            down[f.dst] += f.size;
        }
        let bottleneck = up
            .iter()
            .chain(down.iter())
            .cloned()
            .fold(0.0f64, f64::max);
        CoflowOracle {
            total_bytes: total,
            max_flow,
            min_flow: if min_flow.is_finite() { min_flow } else { 0.0 },
            bottleneck_bytes: bottleneck,
        }
    }

    /// Intra-coflow skew as the paper measures it (§2.2):
    /// `max flow length / min flow length`.
    pub fn skew(&self) -> f64 {
        if self.min_flow <= 0.0 {
            f64::INFINITY
        } else {
            self.max_flow / self.min_flow
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_flows() -> (CoflowSpec, Vec<FlowSpec>) {
        let flows = vec![
            FlowSpec { id: 0, coflow: 0, src: 0, dst: 2, size: 10.0 },
            FlowSpec { id: 1, coflow: 0, src: 1, dst: 2, size: 30.0 },
            FlowSpec { id: 2, coflow: 0, src: 0, dst: 3, size: 20.0 },
        ];
        let spec = CoflowSpec {
            id: 0,
            external_id: 0,
            arrival: 0.0,
            deadline: None,
            flows: vec![0, 1, 2],
            senders: vec![0, 1],
            receivers: vec![2, 3],
        };
        (spec, flows)
    }

    #[test]
    fn oracle_aggregates() {
        let (spec, flows) = mk_flows();
        let o = CoflowOracle::compute(&spec, &flows, 4);
        assert_eq!(o.total_bytes, 60.0);
        assert_eq!(o.max_flow, 30.0);
        assert_eq!(o.min_flow, 10.0);
        // port 2 downlink carries flows 0+1 = 40 bytes: the bottleneck.
        assert_eq!(o.bottleneck_bytes, 40.0);
        assert_eq!(o.skew(), 3.0);
    }

    #[test]
    fn width_and_wide_filter() {
        let (spec, _) = mk_flows();
        assert_eq!(spec.width(), 4);
        assert!(spec.is_wide());
        let narrow = CoflowSpec {
            senders: vec![0],
            receivers: vec![1],
            ..spec
        };
        assert!(!narrow.is_wide());
    }

    #[test]
    fn skew_degenerate_min_zero() {
        let o = CoflowOracle {
            total_bytes: 1.0,
            max_flow: 1.0,
            min_flow: 0.0,
            bottleneck_bytes: 1.0,
        };
        assert!(o.skew().is_infinite());
    }
}
