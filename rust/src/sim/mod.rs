//! Flow-level discrete-event simulator over the non-blocking fabric.
//!
//! The engine owns the [`World`] (flow/coflow state, port loads) and drives
//! a [`Scheduler`] with the paper's event vocabulary: coflow arrivals, flow
//! completion reports (optionally jittered/delayed — the network-error
//! model of Table 5), periodic δ ticks for PQ-based policies, and
//! reallocation requests. Between events every running flow progresses at
//! its last allocated rate — exactly the "local agents comply with the last
//! schedule until a new one arrives" semantics of §3.
//!
//! Coordinator costs are accounted per δ-interval (rate-calculation wall
//! time is *measured*, message costs use [`MessageCostModel`]) to
//! regenerate Tables 3/4/6.

mod engine;
mod heap;

pub use engine::{world_from_trace, world_with_fabric, SimConfig, SimResult, Simulation};
pub use heap::CompletionHeap;
