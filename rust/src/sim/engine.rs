//! The event loop.
//!
//! ## Hot-path architecture: scratch reuse + incremental ordering
//!
//! Coordinator compute is *simulated CCT* here: measured `order + allocate`
//! wall time feeds the §4.3 deadline model (a tick whose work exceeds δ is
//! skipped), so the reallocation path is engineered for zero steady-state
//! heap allocation:
//!
//! * [`Scheduler::order_into`] writes into one engine-owned, reused
//!   [`Plan`]; schedulers maintain their priority order incrementally
//!   (binary-search repair around the coflow whose key changed) instead of
//!   re-sorting all active coflows per event.
//! * [`rate::allocate_into`] runs against an engine-owned
//!   [`rate::AllocScratch`]: reusable capacity ledger, reused grants
//!   buffer, and epoch-stamped dense per-flow tables that replace the old
//!   per-event `HashMap`s and O(G²) grant dedup. With
//!   [`SimConfig::alloc_shards`] ≥ 2 the allocation runs through the
//!   port-sharded parallel pipeline (bit-identical results; see
//!   `coordinator/rate.rs`), whose S−1 helper threads are a **persistent
//!   pool owned by the scratch** — spawned lazily on the first sharded
//!   call, parked between allocations, woken per call, and joined when
//!   the scratch drops. Because frontends own their scratch across
//!   scheduler kill/restore cycles ([`RestoringCoord`] rebuilds only the
//!   scheduler), the pool survives restores too — restarting the brain
//!   never respawns allocation workers.
//! * The engine's own bookkeeping (`running` set, per-coflow `rate_sum`
//!   integrator) uses the same pattern: swap buffers plus an epoch-stamped
//!   dirty list, cleared in O(changed) rather than O(total).
//!
//! ## Batched admission
//!
//! All events that fall on one instant — arrivals, flow-completion
//! reports, the δ tick — are coalesced into a single reused
//! [`EventBatch`]: the engine applies every physical state update first
//! (admission bookkeeping, flow/coflow completion, port releases), then
//! delivers the whole batch through one [`Scheduler::on_batch`] call and
//! pays **one** order repair plus **one** allocation for it. The §4.3
//! deadline model therefore charges a burst of simultaneous events as one
//! rate calculation — the per-event regime (one reallocation per admit) is
//! kept behind [`SimConfig::per_event_admission`], and
//! `rust/tests/cct_equivalence.rs` pins the two modes to bit-identical
//! CCTs on the FB-like scenarios (with and without report jitter).
//!
//! Semantics of a batch: its events are *simultaneous*, so hooks observe
//! the world with **all** of the instant's physical updates applied,
//! whereas per-event mode imposes one specific interleaving (hooks between
//! updates). The two can differ only when an arrival coincides with a
//! completion within the same EPS instant, or two coflows arrive at the
//! exact same timestamp, *and* the scheduler's hook reads cross-coflow
//! state such as `PortLoad` (Philae's pilot placement). Arrival times are
//! continuous, so such coincidences are measure-zero in generated traces —
//! the equivalence tests pin seeds where none occur; completion ties
//! (common, since sibling flows share sizes and rates) are exactly
//! reproduced because completion hooks read only flow-local and
//! scheduler-internal state.
//!
//! ## Two unrelated "deadlines"
//!
//! This file talks about deadlines in two senses that must not be
//! conflated. The **§4.3 deadline model** below is about *coordinator tick
//! latency*: a periodic coordinator whose per-interval work exceeds δ
//! overruns into the next interval and skips ticks (how Aalo degrades at
//! scale, Table 4). **Per-coflow SLO deadlines** are a property of the
//! workload ([`crate::coflow::CoflowState::deadline`], carried from the
//! trace's optional deadline column): completion targets that
//! deadline-aware scheduling (`coordinator/dcoflow.rs`,
//! [`crate::coordinator::DeadlineMode`]) optimizes for and
//! [`SimResult::deadline`] ([`crate::metrics::DeadlineStats`]) accounts.
//! The engine itself treats SLO deadlines as pure metadata — it never
//! gates progress on them.
//!
//! ## Completion events
//!
//! Scheduled completions live in an indexed min-heap
//! ([`crate::sim::CompletionHeap`]): one entry per running flow, rate
//! changes *reschedule* in place and stalls *remove*, so the old
//! epoch-stamped lazy deletion (and its unbounded stale-entry growth plus
//! the `2·nf` up-front reservation) is gone entirely.
//!
//! [`SimConfig::full_recompute`] forces [`Scheduler::order_full_into`] — the
//! from-scratch oracle path — instead; `rust/tests/cct_equivalence.rs`
//! asserts the two produce bit-identical per-coflow CCTs.
//!
//! ## Coordinator frontends (multi-coordinator sharding)
//!
//! The loop itself is generic over a [`CoordFrontend`]: the classic path is
//! `SingleCoord` (one scheduler plus the frontend-owned reused plan and
//! allocation scratch — the zero-allocation hot path, unchanged), and
//! [`Simulation::run_cluster`] drives the same loop through a
//! [`CoordinatorCluster`] that partitions coflows across
//! [`SimConfig::coordinators`] shards with leased per-port capacity and
//! periodic demand-weighted reconciliation (`coordinator/cluster.rs`). K=1
//! through the cluster is a pass-through pinned bit-identical to
//! `SingleCoord` by the equivalence suite.

use super::heap::CompletionHeap;
use crate::coordinator::{
    rate, AdmissionStats, CoordinatorCluster, EventBatch, Plan, Reaction, Scheduler,
    SchedulerConfig, SchedulerKind, World,
};
use crate::coflow::{CoflowState, FlowState};
use crate::fabric::{Fabric, PortLoad};
use crate::metrics::{DeadlineStats, IntervalStats, MessageCostModel, RunningStat};
use crate::obs::{self, EventKind, ObsPlane, ObsSnapshot};
use crate::trace::{ArrivalStream, CoflowArrival, Trace};
use crate::{CoflowId, FlowId, Time, EPS};
use crate::util::Rng;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

/// Simulator knobs beyond the scheduler's own config.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Port line rate (bytes/sec).
    pub port_rate: f64,
    /// Accounting interval for Tables 3/4 (defaults to the scheduler δ).
    pub account_delta: Option<Time>,
    /// Message cost model for the simulated coordinator.
    pub costs: MessageCostModel,
    /// Hard cap on simulated seconds (safety net; 0 = unlimited).
    pub max_sim_time: Time,
    /// Route every reallocation through [`Scheduler::order_full_into`]
    /// (the from-scratch oracle) instead of the incremental
    /// [`Scheduler::order_into`]. Slower; exists so equivalence tests can
    /// pin the incremental engine to the reference behavior bit-for-bit.
    pub full_recompute: bool,
    /// Deliver events one hook call at a time (the legacy per-event
    /// admission regime) instead of coalescing same-instant events into one
    /// [`EventBatch`]. Exists so equivalence tests can pin batched
    /// admission to the per-event behavior; leave `false` on hot paths.
    pub per_event_admission: bool,
    /// Worker shards for [`rate::allocate_into`]; `0`/`1` = serial. The
    /// sharded pipeline is bit-identical and pays off on multi-thousand
    /// port fabrics (see `benches/bench_shard.rs`).
    pub alloc_shards: usize,
    /// Coordinator shards K for the multi-coordinator cluster path
    /// ([`Simulation::run_cluster`]): active coflows are partitioned across
    /// K independent coordinator instances, each scheduling over a leased
    /// per-port capacity slice with periodic demand-weighted reconciliation
    /// (see `coordinator/cluster.rs`). `0`/`1` = the single-coordinator
    /// path; K=1 through the cluster is bit-identical to it.
    pub coordinators: usize,
    /// Fabric override (e.g. [`Fabric::heterogeneous`] mixed-NIC
    /// clusters); `None` = homogeneous at `port_rate`. Must cover exactly
    /// the trace's port count.
    pub fabric: Option<Fabric>,
    /// Flight-recorder ring capacity in events per shard (`0` = the
    /// default: observability off — no recorder, no registry, and the
    /// engine's obs hooks reduce to one `Option` branch). When on,
    /// [`SimResult::obs`] carries the merged [`ObsSnapshot`]. Scheduling
    /// decisions are never read from obs state, so CCTs are bit-identical
    /// either way (pinned in `tests/cct_equivalence.rs`).
    pub obs_events: usize,
    /// Durable streaming archive (`obs/archive.rs`): when set (and
    /// `obs_events` > 0), a background spooler drains the rings into
    /// checksummed segment files under the configured directory, so the
    /// full event log survives runs far larger than any ring cap. Same
    /// bit-identity guarantee as the rings — the spool only reads.
    pub archive: Option<obs::ArchiveConfig>,
    /// Per-port utilization heatmap time bins (`0` = off; needs
    /// `obs_events` > 0). [`SimResult::obs`] then carries the
    /// [`crate::obs::Heatmap`] port×time byte matrix.
    pub heatmap_bins: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            port_rate: crate::GBPS,
            account_delta: None,
            costs: MessageCostModel::default(),
            max_sim_time: 0.0,
            full_recompute: false,
            per_event_admission: false,
            // PHILAE_TEST_SHARDS lets the CI matrix drive every sim-backed
            // test through the sharded allocator (bit-identical by design).
            alloc_shards: rate::env_test_shards(),
            coordinators: 1,
            fabric: None,
            obs_events: 0,
            archive: None,
            heatmap_bins: 0,
        }
    }
}

/// Outcome of one simulated run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub scheduler: String,
    /// Per-coflow CCT in seconds (same indexing as the trace).
    pub ccts: Vec<Time>,
    /// Simulated makespan.
    pub makespan: Time,
    /// Per-interval coordinator cost accounting.
    pub intervals: IntervalStats,
    /// Totals.
    pub rate_calcs: u64,
    pub rate_msgs: u64,
    pub update_msgs: u64,
    /// Measured wall-clock seconds spent inside order+allocate.
    pub rate_calc_wall_s: f64,
    /// Peak working set (Table 6 proxies).
    pub peak_active_coflows: usize,
    pub peak_active_flows: usize,
    /// Flow slots ever allocated (`world.flows.len()` at exit). On the
    /// materialized path this is the trace's flow count; on the streaming
    /// path retirement recycles slots, so it stays near the peak
    /// *concurrent* width no matter how long the arrival stream runs —
    /// the memory-boundedness witness.
    pub flow_slots: usize,
    /// Mean active agents reporting per interval.
    pub updates_per_interval: RunningStat,
    /// Wall-clock seconds the whole simulation took.
    pub sim_wall_s: f64,
    /// SLO accounting (met ratio, goodput, admission counters); vacuous
    /// (`with_deadline == 0`, met ratio 1.0) on deadline-free traces.
    pub deadline: DeadlineStats,
    /// Merged observability snapshot (metrics registry + flight-recorder
    /// event log); `None` unless [`SimConfig::obs_events`] > 0.
    pub obs: Option<ObsSnapshot>,
}

impl SimResult {
    pub fn avg_cct(&self) -> f64 {
        crate::metrics::mean(&self.ccts)
    }

    /// Coordinator busy seconds: measured calc + modelled messaging.
    pub fn coordinator_busy_s(&self, costs: &MessageCostModel) -> f64 {
        self.rate_calc_wall_s
            + self.rate_msgs as f64 * costs.send_per_msg
            + self.update_msgs as f64 * costs.recv_per_msg
    }
}

/// Build the initial [`World`] for a trace (exposed for scheduler unit
/// tests).
pub fn world_from_trace(trace: &Trace) -> World {
    world_with_fabric(trace, Fabric::homogeneous(trace.num_ports, crate::GBPS))
}

/// Build the initial [`World`] with an explicit (possibly heterogeneous)
/// fabric; its port count must match the trace.
pub fn world_with_fabric(trace: &Trace, fabric: Fabric) -> World {
    assert_eq!(
        fabric.num_ports, trace.num_ports,
        "fabric port count must match the trace"
    );
    let mut flows: Vec<FlowState> = trace
        .flows
        .iter()
        .map(|f| FlowState::new(f.id, f.coflow, f.src, f.dst, f.size))
        .collect();
    // per-port scratch for the clairvoyant bottleneck bound (same math as
    // `CoflowOracle::compute`, O(touched) reset per coflow)
    let mut up = vec![0.0f64; trace.num_ports];
    let mut down = vec![0.0f64; trace.num_ports];
    let mut touched: Vec<usize> = Vec::new();
    let coflows: Vec<CoflowState> = trace
        .coflows
        .iter()
        .map(|c| {
            let total: f64 = c.flows.iter().map(|&f| trace.flows[f].size).sum();
            let mut st = CoflowState::new(c.id, c.arrival, c.flows.clone(), total, c.id as u64);
            st.deadline = c.deadline;
            st.senders = c.senders.clone();
            st.receivers = c.receivers.clone();
            for (i, &fid) in st.active_list.iter().enumerate() {
                flows[fid].active_pos = i;
            }
            let mut bn = 0.0f64;
            for &fid in &c.flows {
                let f = &trace.flows[fid];
                if up[f.src] == 0.0 {
                    touched.push(f.src);
                }
                if down[f.dst] == 0.0 {
                    touched.push(f.dst);
                }
                up[f.src] += f.size;
                down[f.dst] += f.size;
            }
            for &p in &touched {
                bn = bn.max(up[p]).max(down[p]);
                up[p] = 0.0;
                down[p] = 0.0;
            }
            touched.clear();
            st.bottleneck_bytes = bn;
            st
        })
        .collect();
    World {
        now: 0.0,
        flows,
        coflows,
        fabric,
        load: PortLoad::new(trace.num_ports),
        active: Vec::new(),
    }
}

/// The engine's view of "the coordinator side": either one scheduler
/// driving a frontend-owned reused plan/scratch pair ([`SingleCoord`], the
/// pre-cluster hot path verbatim), or a [`CoordinatorCluster`] of K shards.
/// Both consume the same event vocabulary and expose the grants of the
/// last [`compute`](CoordFrontend::compute) round for the engine to apply.
pub(crate) trait CoordFrontend {
    fn name(&self) -> String;
    fn tick_interval(&self) -> Option<Time>;
    fn on_arrival(&mut self, cid: CoflowId, world: &mut World) -> Reaction;
    fn on_flow_complete(&mut self, fid: FlowId, world: &mut World) -> Reaction;
    fn on_coflow_complete(&mut self, cid: CoflowId, world: &mut World) -> Reaction;
    fn on_tick(&mut self, world: &mut World) -> Reaction;
    fn on_batch(&mut self, batch: &EventBatch, world: &mut World) -> Reaction;
    /// Recompute the schedule (order + allocation); `full` selects the
    /// from-scratch oracle ordering.
    fn compute(&mut self, world: &mut World, full: bool);
    /// `(flow, rate)` grants of the last compute round.
    fn grants(&self) -> &[(FlowId, f64)];
    /// Whether `fid` holds a grant from the last compute round.
    fn was_granted(&self, fid: FlowId) -> bool;
    /// Admission-control counters (deadline-aware schedulers only).
    fn admission_stats(&self) -> Option<AdmissionStats>;
    /// Tell the frontend whether to buffer coordination-plane lifecycle
    /// events (migration, reconciliation, checkpoint/restore) for the
    /// engine's flight recorder. Default: ignore (frontends without a
    /// coordination plane have nothing to report).
    fn set_obs(&mut self, _on: bool) {}
    /// Drain buffered `(shard, kind, coflow, a, b)` events into `out`;
    /// the engine stamps time and sequence. Default: nothing buffered.
    fn drain_obs(&mut self, _out: &mut Vec<obs::PendingEvent>) {}
}

/// Single-coordinator frontend: one scheduler, one reused plan, one reused
/// allocation scratch — exactly the engine-owned buffers of the
/// zero-allocation hot path, now living beside the scheduler they serve.
struct SingleCoord<'a> {
    sched: &'a mut dyn Scheduler,
    plan: Plan,
    scratch: rate::AllocScratch,
}

impl CoordFrontend for SingleCoord<'_> {
    fn name(&self) -> String {
        self.sched.name()
    }

    fn tick_interval(&self) -> Option<Time> {
        self.sched.tick_interval()
    }

    fn on_arrival(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.sched.on_arrival(cid, world)
    }

    fn on_flow_complete(&mut self, fid: FlowId, world: &mut World) -> Reaction {
        self.sched.on_flow_complete(fid, world)
    }

    fn on_coflow_complete(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.sched.on_coflow_complete(cid, world)
    }

    fn on_tick(&mut self, world: &mut World) -> Reaction {
        self.sched.on_tick(world)
    }

    fn on_batch(&mut self, batch: &EventBatch, world: &mut World) -> Reaction {
        self.sched.on_batch(batch, world)
    }

    fn compute(&mut self, world: &mut World, full: bool) {
        if full {
            self.sched.order_full_into(world, &mut self.plan);
        } else {
            self.sched.order_into(world, &mut self.plan);
        }
        rate::allocate_into(
            &world.fabric,
            &world.flows,
            &world.coflows,
            &self.plan,
            &mut self.scratch,
        );
    }

    fn grants(&self) -> &[(FlowId, f64)] {
        self.scratch.grants()
    }

    fn was_granted(&self, fid: FlowId) -> bool {
        self.scratch.was_granted(fid)
    }

    fn admission_stats(&self) -> Option<AdmissionStats> {
        self.sched.admission_stats()
    }
}

/// The K-shard cluster drives the same engine loop (see
/// `coordinator/cluster.rs`; K=1 is a bit-identical pass-through).
impl CoordFrontend for CoordinatorCluster {
    fn name(&self) -> String {
        CoordinatorCluster::name(self)
    }

    fn tick_interval(&self) -> Option<Time> {
        CoordinatorCluster::tick_interval(self)
    }

    fn on_arrival(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        CoordinatorCluster::on_arrival(self, cid, world)
    }

    fn on_flow_complete(&mut self, fid: FlowId, world: &mut World) -> Reaction {
        CoordinatorCluster::on_flow_complete(self, fid, world)
    }

    fn on_coflow_complete(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        CoordinatorCluster::on_coflow_complete(self, cid, world)
    }

    fn on_tick(&mut self, world: &mut World) -> Reaction {
        CoordinatorCluster::on_tick(self, world)
    }

    fn on_batch(&mut self, batch: &EventBatch, world: &mut World) -> Reaction {
        CoordinatorCluster::on_batch(self, batch, world)
    }

    fn compute(&mut self, world: &mut World, full: bool) {
        CoordinatorCluster::compute(self, world, full)
    }

    fn grants(&self) -> &[(FlowId, f64)] {
        CoordinatorCluster::grants(self)
    }

    fn was_granted(&self, fid: FlowId) -> bool {
        CoordinatorCluster::was_granted(self, fid)
    }

    fn admission_stats(&self) -> Option<AdmissionStats> {
        CoordinatorCluster::admission_stats(self)
    }

    fn set_obs(&mut self, on: bool) {
        CoordinatorCluster::set_obs(self, on)
    }

    fn drain_obs(&mut self, out: &mut Vec<obs::PendingEvent>) {
        CoordinatorCluster::drain_obs(self, out)
    }
}

/// Crash-injection frontend (`coordinator/recovery.rs`): a
/// [`SingleCoord`] whose scheduler is killed and restored from a
/// fresh sealed checkpoint every `every`-th event delivery. The crash
/// model: the coordinator's *brain* (the scheduler and its learned state)
/// is lost, while the physical world — agents, in-flight transfers, the
/// engine's queues — survives. Each cycle runs the full production path:
/// `checkpoint_scheduler → seal → unseal` (checksum verify) →
/// [`crate::coordinator::restore_scheduler`] with `exact = true`, so
/// `tests/chaos_recovery.rs` can pin that a restore at **any** event
/// boundary leaves the run bit-identical to the uninterrupted one.
struct RestoringCoord<'a> {
    trace: &'a Trace,
    cfg: &'a SchedulerConfig,
    kind: SchedulerKind,
    sched: Box<dyn Scheduler>,
    plan: Plan,
    scratch: rate::AllocScratch,
    /// Crash every N-th event delivery (0 = never).
    every: u64,
    events: u64,
    restores: u64,
    obs_on: bool,
    obs_pending: Vec<obs::PendingEvent>,
}

impl RestoringCoord<'_> {
    /// Count one event delivery; on every `every`-th, kill the scheduler
    /// and rebuild it from a freshly sealed checkpoint **before** the
    /// event is delivered (the restored coordinator must handle it).
    fn maybe_crash(&mut self, world: &mut World) {
        use crate::coordinator::{checkpoint_scheduler, restore_scheduler, seal, unseal};
        self.events += 1;
        if self.every == 0 || self.events % self.every != 0 {
            return;
        }
        let payload = checkpoint_scheduler(self.kind, self.sched.as_ref(), world);
        let sealed = seal(payload);
        let payload = unseal(&sealed).expect("fresh checkpoint must pass verification");
        self.sched = restore_scheduler(&payload, self.trace, self.cfg, world, true)
            .expect("restore from a verified checkpoint");
        self.restores += 1;
        if self.obs_on {
            self.obs_pending
                .push((0, EventKind::Checkpoint, obs::NO_COFLOW, self.restores, 0));
            self.obs_pending
                .push((0, EventKind::Restore, obs::NO_COFLOW, self.restores, 0));
        }
    }
}

impl CoordFrontend for RestoringCoord<'_> {
    fn name(&self) -> String {
        self.sched.name()
    }

    fn tick_interval(&self) -> Option<Time> {
        self.sched.tick_interval()
    }

    fn on_arrival(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.maybe_crash(world);
        self.sched.on_arrival(cid, world)
    }

    fn on_flow_complete(&mut self, fid: FlowId, world: &mut World) -> Reaction {
        self.maybe_crash(world);
        self.sched.on_flow_complete(fid, world)
    }

    fn on_coflow_complete(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.maybe_crash(world);
        self.sched.on_coflow_complete(cid, world)
    }

    fn on_tick(&mut self, world: &mut World) -> Reaction {
        self.maybe_crash(world);
        self.sched.on_tick(world)
    }

    fn on_batch(&mut self, batch: &EventBatch, world: &mut World) -> Reaction {
        self.maybe_crash(world);
        self.sched.on_batch(batch, world)
    }

    fn compute(&mut self, world: &mut World, full: bool) {
        if full {
            self.sched.order_full_into(world, &mut self.plan);
        } else {
            self.sched.order_into(world, &mut self.plan);
        }
        rate::allocate_into(
            &world.fabric,
            &world.flows,
            &world.coflows,
            &self.plan,
            &mut self.scratch,
        );
    }

    fn grants(&self) -> &[(FlowId, f64)] {
        self.scratch.grants()
    }

    fn was_granted(&self, fid: FlowId) -> bool {
        self.scratch.was_granted(fid)
    }

    fn admission_stats(&self) -> Option<AdmissionStats> {
        self.sched.admission_stats()
    }

    fn set_obs(&mut self, on: bool) {
        self.obs_on = on;
    }

    fn drain_obs(&mut self, out: &mut Vec<obs::PendingEvent>) {
        out.append(&mut self.obs_pending);
    }
}

/// Min-heap entry of the delayed-report queue: (report time, stable flow
/// seq, flow). The tie-break keys on the flow's creation sequence — not
/// its id — so streaming slot recycling keeps replay order identical to
/// the materialized path (where `seq == id`).
#[derive(PartialEq)]
struct Ev(Time, u64, FlowId);
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

/// Per-coflow port occupancy refcounts, to detect port-freeing and keep
/// `PortLoad::{up,down}_coflows` exact.
struct PortRefs {
    up: Vec<(usize, usize)>,
    down: Vec<(usize, usize)>,
}

pub struct Simulation;

impl Simulation {
    /// Run `trace` under scheduler `kind` with the paper-default sim config.
    pub fn run(trace: &Trace, kind: SchedulerKind, cfg: &SchedulerConfig) -> SimResult {
        let mut sched = kind.build(trace, cfg);
        Self::run_with(trace, sched.as_mut(), cfg, &SimConfig::default())
    }

    /// Full-control entry point (single coordinator).
    pub fn run_with(
        trace: &Trace,
        sched: &mut dyn Scheduler,
        cfg: &SchedulerConfig,
        sim_cfg: &SimConfig,
    ) -> SimResult {
        let mut front = SingleCoord {
            sched,
            plan: Plan::default(),
            scratch: {
                let mut s = rate::AllocScratch::new();
                s.set_shards(sim_cfg.alloc_shards);
                s
            },
        };
        Engine::new(trace, cfg, sim_cfg).run(&mut front)
    }

    /// Run through the multi-coordinator cluster with
    /// K = [`SimConfig::coordinators`] shards of `kind`. K=1 is pinned
    /// bit-identical to [`Simulation::run_with`] by
    /// `rust/tests/cct_equivalence.rs`.
    pub fn run_cluster(
        trace: &Trace,
        kind: SchedulerKind,
        cfg: &SchedulerConfig,
        sim_cfg: &SimConfig,
    ) -> SimResult {
        let mut cluster = CoordinatorCluster::with_coordinators(
            sim_cfg.coordinators.max(1),
            kind,
            trace,
            cfg,
        );
        Self::run_with_cluster(trace, &mut cluster, cfg, sim_cfg)
    }

    /// Cluster entry point with a caller-built [`CoordinatorCluster`]
    /// (custom [`crate::coordinator::ClusterConfig`] — reconciliation
    /// period, migration bounds, invariant validation). The cluster's own
    /// shard count is used; [`SimConfig::coordinators`] is ignored here.
    pub fn run_with_cluster(
        trace: &Trace,
        cluster: &mut CoordinatorCluster,
        cfg: &SchedulerConfig,
        sim_cfg: &SimConfig,
    ) -> SimResult {
        cluster.set_alloc_shards(sim_cfg.alloc_shards);
        Engine::new(trace, cfg, sim_cfg).run(cluster)
    }

    /// Run with crash injection: the coordinator is killed and restored
    /// from a freshly sealed checkpoint before every `every`-th event
    /// delivery (`every = 0` → never, identical to [`Simulation::run`]).
    /// Returns the result plus the number of restores performed, so tests
    /// can assert non-vacuity. The restore is `exact` — see
    /// `coordinator/recovery.rs` — and `tests/chaos_recovery.rs` pins the
    /// outcome bit-identical to the uninterrupted run for all scheduler
    /// kinds.
    pub fn run_with_restore(
        trace: &Trace,
        kind: SchedulerKind,
        cfg: &SchedulerConfig,
        sim_cfg: &SimConfig,
        every: u64,
    ) -> (SimResult, u64) {
        let mut front = RestoringCoord {
            trace,
            cfg,
            kind,
            sched: kind.build(trace, cfg),
            plan: Plan::default(),
            scratch: {
                let mut s = rate::AllocScratch::new();
                s.set_shards(sim_cfg.alloc_shards);
                s
            },
            every,
            events: 0,
            restores: 0,
            obs_on: false,
            obs_pending: Vec::new(),
        };
        let result = Engine::new(trace, cfg, sim_cfg).run(&mut front);
        (result, front.restores)
    }

    /// Streaming entry point: drive the engine from an [`ArrivalStream`]
    /// without materializing the workload. Coflows are admitted as
    /// simulated time reaches them and their heavy state is reclaimed
    /// after completion, so resident memory tracks the *concurrent*
    /// population — million-coflow runs fit in a test-runner footprint.
    /// On arrival-sorted sources (everything [`crate::trace::TraceSpec`]
    /// generates, and [`crate::trace::TraceStream`] over generated
    /// traces) the result is bit-identical to the materialized
    /// [`Simulation::run`]; `rust/tests/streaming_equivalence.rs` pins
    /// this for every scheduler kind.
    pub fn run_stream(
        stream: &mut dyn ArrivalStream,
        kind: SchedulerKind,
        cfg: &SchedulerConfig,
        sim_cfg: &SimConfig,
    ) -> SimResult {
        // Schedulers are built against an empty stub trace: every kind
        // derives its per-coflow state from the world at admission time
        // (the clairvoyant kinds read `CoflowState::{bottleneck_bytes,
        // total_bytes}`), so construction needs only the port count.
        let stub = Trace {
            num_ports: stream.num_ports(),
            coflows: Vec::new(),
            flows: Vec::new(),
        };
        let mut sched = kind.build(&stub, cfg);
        Self::run_stream_with(stream, sched.as_mut(), cfg, sim_cfg)
    }

    /// Streaming counterpart of [`Simulation::run_with`] — caller-built
    /// scheduler, full [`SimConfig`] control.
    pub fn run_stream_with(
        stream: &mut dyn ArrivalStream,
        sched: &mut dyn Scheduler,
        cfg: &SchedulerConfig,
        sim_cfg: &SimConfig,
    ) -> SimResult {
        let mut front = SingleCoord {
            sched,
            plan: Plan::default(),
            scratch: {
                let mut s = rate::AllocScratch::new();
                s.set_shards(sim_cfg.alloc_shards);
                s
            },
        };
        Engine::new_streaming(stream.num_ports(), cfg, sim_cfg).run_streaming(&mut front, stream)
    }

    /// Streaming counterpart of [`Simulation::run_cluster`]: the same
    /// bounded-memory arrival path through the K-shard
    /// [`CoordinatorCluster`] frontend (K = [`SimConfig::coordinators`]).
    pub fn run_stream_cluster(
        stream: &mut dyn ArrivalStream,
        kind: SchedulerKind,
        cfg: &SchedulerConfig,
        sim_cfg: &SimConfig,
    ) -> SimResult {
        let stub = Trace {
            num_ports: stream.num_ports(),
            coflows: Vec::new(),
            flows: Vec::new(),
        };
        let mut cluster = CoordinatorCluster::with_coordinators(
            sim_cfg.coordinators.max(1),
            kind,
            &stub,
            cfg,
        );
        cluster.set_alloc_shards(sim_cfg.alloc_shards);
        Engine::new_streaming(stream.num_ports(), cfg, sim_cfg).run_streaming(&mut cluster, stream)
    }
}

struct Engine {
    world: World,
    /// Arrival order (by time) of coflow ids.
    arrivals: Vec<(Time, CoflowId)>,
    next_arrival: usize,
    /// Scheduled flow completions: one indexed entry per running flow
    /// (reschedule on rate change, remove on stall — no stale entries).
    completions: CompletionHeap,
    /// Delayed completion *reports* (jitter model): (report time, flow).
    reports: BinaryHeap<Reverse<Ev>>,
    /// Same-instant events coalesced for one `Scheduler::on_batch` call
    /// (reused buffers; see the module docs).
    batch: EventBatch,
    /// Deliver events per hook call instead (equivalence testing).
    per_event: bool,
    /// Reused buffer of flows that physically completed this instant.
    completed: Vec<FlowId>,
    /// Flows currently holding a non-zero rate.
    running: Vec<FlowId>,
    /// Spare buffer swapped with `running` on each reallocation so the new
    /// running set is built without allocating.
    running_spare: Vec<FlowId>,
    /// Per-coflow sum of allocated rates (progress integration).
    rate_sum: Vec<f64>,
    /// Coflows whose `rate_sum` must be rebuilt this round (reused buffer).
    rate_dirty: Vec<CoflowId>,
    /// Epoch-stamped membership for `rate_dirty` (O(1) dedup, no clearing).
    rate_dirty_stamp: Vec<u64>,
    rate_dirty_epoch: u64,
    /// Use the from-scratch oracle order path (equivalence testing).
    full_recompute: bool,
    port_refs: Vec<Option<PortRefs>>,
    /// Completion reports queued but not yet delivered, per coflow.
    reports_pending: Vec<usize>,
    /// Coflow-completion event already delivered.
    coflow_delivered: Vec<bool>,
    /// Ports with at least one active flow endpoint (agents that report).
    active_agents: usize,
    port_active: Vec<u32>,
    // accounting
    delta_acct: Time,
    interval_idx: u64,
    iv_rate_calc_s: f64,
    iv_updates: u64,
    iv_rate_msgs: u64,
    iv_rate_calcs: u64,
    stats: IntervalStats,
    totals: Totals,
    jitter: Time,
    rng: Rng,
    max_sim_time: Time,
    costs: MessageCostModel,
    // ---- streaming mode (bounded-memory trace ingestion) ----
    /// `true` when driven by an [`ArrivalStream`] instead of a
    /// pre-materialized arrival list.
    streaming: bool,
    /// The next not-yet-admitted arrival pulled from the stream (reused
    /// buffer; valid only while `has_pending`).
    pending: CoflowArrival,
    has_pending: bool,
    /// LIFO free list of recycled flow slots (streaming only): a finished
    /// coflow's flow slots are reused by later admissions so the flow table
    /// stays bounded by the *live* flow count, not the run total.
    flow_free: Vec<FlowId>,
    /// Global monotone flow creation counter — the stable event tie-break
    /// (`FlowState::seq`) handed to recycled slots.
    flow_seq: u64,
    /// Coflows whose heavy per-flow state is reclaimed at the end of the
    /// current loop iteration (after the reallocation consumed the batch).
    retire_pending: Vec<CoflowId>,
    /// Per-port scratch for the streaming admitter's bottleneck bound
    /// (same shape as `world_with_fabric`).
    bn_up: Vec<f64>,
    bn_down: Vec<f64>,
    bn_touched: Vec<usize>,
    /// Observability plane ([`SimConfig::obs_events`] > 0); boxed so the
    /// disabled path carries one pointer-sized `Option` and a single
    /// branch per hook site.
    obs: Option<Box<EngineObs>>,
}

/// Engine-side observability state. The shadow tables remember the last
/// observed phase / estimate / queue / rate verdict per coflow, so the
/// per-instant scan emits *transitions* rather than state dumps. Pure
/// observer: nothing here is ever read back into scheduling decisions
/// (the disabled-obs bit-identity pin in `tests/cct_equivalence.rs`
/// depends on that).
struct EngineObs {
    plane: ObsPlane,
    /// Last seen phase (0 piloting / 1 running / 2 done; 255 = unseen).
    phase_seen: Vec<u8>,
    /// Estimate event already emitted for this coflow.
    est_seen: Vec<bool>,
    /// Last rate verdict: 0 unknown, 1 scheduled, 2 starved.
    sched_seen: Vec<u8>,
    /// Last seen priority queue (`u64::MAX` = unseen).
    queue_seen: Vec<u64>,
    /// Reused drain buffer for frontend coordination-plane events.
    pending: Vec<obs::PendingEvent>,
    /// Admission counters at the last scan (delta detection).
    adm_admitted: u64,
    adm_rejected: u64,
    adm_expired: u64,
    /// Registry handle for the full-fidelity realloc latency histogram.
    calc_hist: obs::HistId,
    /// Durable segment spool ([`SimConfig::archive`]); drained once per
    /// engine instant, finalized into [`ObsSnapshot::archive`].
    archive: Option<obs::ArchiveSpool>,
    /// Per-port utilization matrix ([`SimConfig::heatmap_bins`]), fed
    /// `rate × dt` bytes from the analytic advance step.
    heatmap: Option<obs::Heatmap>,
}

impl EngineObs {
    /// Copy every ring tail pushed since the last call into the archive
    /// spool (no-op when the archive is off).
    fn drain_archive(&mut self) {
        if let Some(spool) = self.archive.as_mut() {
            spool.drain(&self.plane);
        }
    }
}

#[derive(Default)]
struct Totals {
    rate_calcs: u64,
    rate_msgs: u64,
    update_msgs: u64,
    rate_calc_wall_s: f64,
    peak_active_coflows: usize,
    peak_active_flows: usize,
    active_flows: usize,
}

impl Engine {
    fn new(trace: &Trace, cfg: &SchedulerConfig, sim_cfg: &SimConfig) -> Self {
        let fabric = sim_cfg
            .fabric
            .clone()
            .unwrap_or_else(|| Fabric::homogeneous(trace.num_ports, sim_cfg.port_rate));
        let world = world_with_fabric(trace, fabric);
        let mut arrivals: Vec<(Time, CoflowId)> =
            trace.coflows.iter().map(|c| (c.arrival, c.id)).collect();
        arrivals.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        Self::from_world(world, arrivals, cfg, sim_cfg, false)
    }

    /// Streaming constructor: an empty world over `num_ports` ports. Coflows
    /// materialize one at a time via [`Engine::admit_pending`] as the
    /// [`ArrivalStream`] reaches them, and retire after completion — resident
    /// state tracks the concurrent population, not the trace length.
    fn new_streaming(num_ports: usize, cfg: &SchedulerConfig, sim_cfg: &SimConfig) -> Self {
        let fabric = sim_cfg
            .fabric
            .clone()
            .unwrap_or_else(|| Fabric::homogeneous(num_ports, sim_cfg.port_rate));
        assert_eq!(
            fabric.num_ports, num_ports,
            "fabric port count must match the stream"
        );
        let world = World {
            now: 0.0,
            flows: Vec::new(),
            coflows: Vec::new(),
            fabric,
            load: PortLoad::new(num_ports),
            active: Vec::new(),
        };
        Self::from_world(world, Vec::new(), cfg, sim_cfg, true)
    }

    fn from_world(
        world: World,
        arrivals: Vec<(Time, CoflowId)>,
        cfg: &SchedulerConfig,
        sim_cfg: &SimConfig,
        streaming: bool,
    ) -> Self {
        let nf = world.flows.len();
        let nc = world.coflows.len();
        let np = world.fabric.num_ports;
        // captured before `world` moves into the struct literal below
        let (fab_up_cap, fab_down_cap) = if sim_cfg.obs_events > 0 && sim_cfg.heatmap_bins > 0 {
            (world.fabric.up_capacity.clone(), world.fabric.down_capacity.clone())
        } else {
            (Vec::new(), Vec::new())
        };
        Engine {
            world,
            arrivals,
            next_arrival: 0,
            // Bounded at one live entry per running flow — no 2·nf slack
            // for stale entries needed anymore.
            completions: CompletionHeap::with_flow_capacity(nf),
            reports: BinaryHeap::with_capacity(64),
            batch: EventBatch::default(),
            per_event: sim_cfg.per_event_admission,
            completed: Vec::new(),
            running: Vec::new(),
            running_spare: Vec::new(),
            rate_sum: vec![0.0; nc],
            rate_dirty: Vec::with_capacity(nc),
            rate_dirty_stamp: vec![0; nc],
            rate_dirty_epoch: 0,
            full_recompute: sim_cfg.full_recompute,
            port_refs: (0..nc).map(|_| None).collect(),
            reports_pending: vec![0; nc],
            coflow_delivered: vec![false; nc],
            active_agents: 0,
            port_active: vec![0; np],
            delta_acct: sim_cfg.account_delta.unwrap_or(cfg.delta),
            interval_idx: 0,
            iv_rate_calc_s: 0.0,
            iv_updates: 0,
            iv_rate_msgs: 0,
            iv_rate_calcs: 0,
            stats: IntervalStats::default(),
            totals: Totals::default(),
            jitter: cfg.report_jitter,
            rng: Rng::seed_from_u64(cfg.dynamics_seed.wrapping_add(0xDEAD_BEEF)),
            max_sim_time: sim_cfg.max_sim_time,
            costs: sim_cfg.costs,
            streaming,
            pending: CoflowArrival::default(),
            has_pending: false,
            flow_free: Vec::new(),
            flow_seq: nf as u64,
            retire_pending: Vec::new(),
            bn_up: if streaming { vec![0.0; np] } else { Vec::new() },
            bn_down: if streaming { vec![0.0; np] } else { Vec::new() },
            bn_touched: Vec::new(),
            obs: if sim_cfg.obs_events > 0 {
                let mut plane = ObsPlane::new(sim_cfg.obs_events);
                let calc_hist = plane.reg.hist("sim.calc_ns");
                let archive = sim_cfg.archive.clone().map(|a| {
                    obs::ArchiveSpool::new(a).expect("create obs archive directory")
                });
                let heatmap = (sim_cfg.heatmap_bins > 0).then(|| {
                    // 0.25 s initial bins resolve short runs; long runs
                    // fold the width upward as the horizon grows
                    obs::Heatmap::new(sim_cfg.heatmap_bins, 0.25, fab_up_cap, fab_down_cap)
                });
                Some(Box::new(EngineObs {
                    plane,
                    phase_seen: vec![u8::MAX; nc],
                    est_seen: vec![false; nc],
                    sched_seen: vec![0; nc],
                    queue_seen: vec![u64::MAX; nc],
                    pending: Vec::new(),
                    adm_admitted: 0,
                    adm_rejected: 0,
                    adm_expired: 0,
                    calc_hist,
                    archive,
                    heatmap,
                }))
            } else {
                None
            },
        }
    }

    fn run<F: CoordFrontend>(self, front: &mut F) -> SimResult {
        self.run_inner(front, None)
    }

    /// Drive the loop from an [`ArrivalStream`]: prime the pending-arrival
    /// buffer, then run with the stream as the arrival source.
    fn run_streaming<F: CoordFrontend>(
        mut self,
        front: &mut F,
        stream: &mut dyn ArrivalStream,
    ) -> SimResult {
        self.has_pending = stream.next_arrival(&mut self.pending);
        self.run_inner(front, Some(stream))
    }

    fn run_inner<F: CoordFrontend>(
        mut self,
        front: &mut F,
        mut stream: Option<&mut dyn ArrivalStream>,
    ) -> SimResult {
        let wall_start = Instant::now();
        front.set_obs(self.obs.is_some());
        let tick = front.tick_interval();
        let mut next_tick: Option<Time> = None;

        loop {
            // ---- pick the next event time ----
            let mut t_next = f64::INFINITY;
            if self.next_arrival < self.arrivals.len() {
                t_next = t_next.min(self.arrivals[self.next_arrival].0);
            }
            if self.has_pending {
                t_next = t_next.min(self.pending.arrival);
            }
            if let Some((t, _, _)) = self.completions.peek() {
                t_next = t_next.min(t);
            }
            if let Some(Reverse(Ev(t, _, _))) = self.reports.peek() {
                t_next = t_next.min(*t);
            }
            if let Some(nt) = next_tick {
                if !self.world.active.is_empty() {
                    t_next = t_next.min(nt);
                }
            }
            if !t_next.is_finite() {
                break; // no arrivals, no completions, no reports left
            }
            if self.max_sim_time > 0.0 && t_next > self.max_sim_time {
                break;
            }

            // ---- advance to t_next ----
            self.advance_to(t_next);

            // ---- interval accounting boundary ----
            self.roll_intervals();

            // Everything due at this instant is either dispatched through
            // the per-event hooks (legacy mode) or collected into the
            // reused batch and delivered via one `on_batch` call below.
            let mut reaction = Reaction::None;
            self.batch.clear();

            // ---- arrivals ----
            while self.next_arrival < self.arrivals.len()
                && self.arrivals[self.next_arrival].0 <= self.world.now + EPS
            {
                let (_, cid) = self.arrivals[self.next_arrival];
                self.next_arrival += 1;
                self.admit(cid);
                if self.per_event {
                    reaction = reaction.merge(front.on_arrival(cid, &mut self.world));
                } else {
                    self.batch.arrivals.push(cid);
                }
                if next_tick.is_none() {
                    if let Some(iv) = tick {
                        next_tick = Some(self.world.now + iv);
                    }
                }
            }

            // ---- streaming arrivals ----
            while self.has_pending && self.pending.arrival <= self.world.now + EPS {
                let cid = self.admit_pending();
                let prev = self.pending.arrival;
                self.has_pending = match stream.as_mut() {
                    Some(s) => s.next_arrival(&mut self.pending),
                    None => false,
                };
                debug_assert!(
                    !self.has_pending || self.pending.arrival >= prev,
                    "arrival stream must be non-decreasing"
                );
                if self.per_event {
                    reaction = reaction.merge(front.on_arrival(cid, &mut self.world));
                } else {
                    self.batch.arrivals.push(cid);
                }
                if next_tick.is_none() {
                    if let Some(iv) = tick {
                        next_tick = Some(self.world.now + iv);
                    }
                }
            }

            // ---- physical flow completions ----
            // NB: fire on the scheduled time even if the flow crossed the
            // EPS completion threshold early by float slop — the event is
            // what stamps `finished_at`.
            self.completed.clear();
            while let Some((t, _, f)) = self.completions.peek() {
                if t <= self.world.now + EPS {
                    self.completions.pop();
                    debug_assert!(self.world.flows[f].finished_at.is_none());
                    self.completed.push(f);
                } else {
                    break;
                }
            }
            for idx in 0..self.completed.len() {
                let f = self.completed[idx];
                self.complete_flow(f);
                let cid = self.world.flows[f].coflow;
                self.reports_pending[cid] += 1;
                if self.jitter > 0.0 {
                    let d: f64 = self.rng.uniform(0.0, self.jitter);
                    let seq = self.world.flows[f].seq;
                    self.reports.push(Reverse(Ev(self.world.now + d, seq, f)));
                } else if self.per_event {
                    reaction = reaction.merge(self.deliver_report(f, front));
                } else {
                    self.queue_report(f);
                }
            }

            // ---- delayed completion reports ----
            while let Some(Reverse(Ev(t, _, f))) = self.reports.peek() {
                if *t <= self.world.now + EPS {
                    let f = *f;
                    self.reports.pop();
                    if self.per_event {
                        reaction = reaction.merge(self.deliver_report(f, front));
                    } else {
                        self.queue_report(f);
                    }
                } else {
                    break;
                }
            }

            // ---- periodic tick ----
            let mut ticked = false;
            let mut tick_updates = 0u64;
            if let (Some(iv), Some(nt)) = (tick, next_tick) {
                if self.world.now + EPS >= nt && !self.world.active.is_empty() {
                    // the tick ingests one update per active agent (port)
                    tick_updates = self.active_agents as u64;
                    self.iv_updates += tick_updates;
                    self.totals.update_msgs += tick_updates;
                    if self.per_event {
                        reaction = reaction.merge(front.on_tick(&mut self.world));
                    } else {
                        self.batch.tick = true;
                    }
                    ticked = true;
                    let mut t = nt;
                    while t <= self.world.now + EPS {
                        t += iv;
                    }
                    next_tick = Some(t);
                }
                if self.world.active.is_empty() {
                    next_tick = Some(self.world.now + iv);
                }
            }

            // ---- batched delivery: one scheduler call per instant ----
            if !self.per_event && !self.batch.is_empty() {
                // move the batch out for the call, then hand the buffers
                // back for reuse (no allocation either way)
                let batch = std::mem::take(&mut self.batch);
                reaction = reaction.merge(front.on_batch(&batch, &mut self.world));
                self.batch = batch;
            }

            // ---- reallocate ----
            if reaction == Reaction::Reallocate {
                let (calc_s, changed) = self.reallocate(front);
                if let Some(o) = self.obs.as_mut() {
                    o.plane.reg.observe_secs(o.calc_hist, calc_s);
                }
                // Deadline model (§4.3): if this tick's coordinator work —
                // ingesting updates, recalculating, pushing new rates —
                // exceeds δ, the coordinator overruns into the next interval
                // and agents keep executing the outdated schedule: skip one
                // tick. This is how Aalo degrades at scale (Table 4).
                if ticked {
                    let work = calc_s
                        + tick_updates as f64 * self.costs.recv_per_msg
                        + changed as f64 * self.costs.send_per_msg;
                    if work > self.delta_acct {
                        if let (Some(iv), Some(nt)) = (tick, next_tick) {
                            next_tick = Some(nt + iv * (work / self.delta_acct).floor());
                        }
                    }
                }
            }

            // ---- observability: transition scan + frontend drain ----
            // After the instant's reallocation so the scan sees settled
            // rates; pure observation, never feeds back into scheduling.
            if self.obs.is_some() {
                self.obs_scan(front);
            }

            // ---- streaming retirement ----
            // Reclaim heavy state of coflows whose completion was fully
            // delivered this instant — after the reallocation, so no hook
            // or allocator sees a retired coflow mid-round.
            if self.streaming && !self.retire_pending.is_empty() {
                self.retire_done();
            }
        }

        // close the final interval
        self.roll_intervals();

        let ccts: Vec<Time> = self
            .world
            .coflows
            .iter()
            .map(|c| c.cct().unwrap_or(f64::NAN))
            .collect();
        let mut deadline = DeadlineStats::default();
        for c in &self.world.coflows {
            deadline.record(c.deadline, c.finished_at, c.total_bytes);
        }
        if let Some(a) = front.admission_stats() {
            deadline.admitted = a.admitted;
            deadline.rejected = a.rejected;
            deadline.expired = a.expired;
        }
        let obs = self.obs.take().map(|mut o| {
            let id = o.plane.reg.counter("sim.rate_calcs");
            o.plane.reg.inc(id, self.totals.rate_calcs);
            let id = o.plane.reg.counter("sim.rate_msgs");
            o.plane.reg.inc(id, self.totals.rate_msgs);
            let id = o.plane.reg.counter("sim.update_msgs");
            o.plane.reg.inc(id, self.totals.update_msgs);
            // last drain catches events emitted after the final instant's
            // scan (none today, but the ordering is load-bearing), then
            // the spool flushes, joins its writer, and reports accounting
            o.drain_archive();
            let archive = o.archive.take().map(|spool| spool.finalize());
            let heatmap = o.heatmap.take();
            let mut snap = o.plane.snapshot();
            snap.archive = archive;
            snap.heatmap = heatmap;
            snap
        });
        SimResult {
            scheduler: front.name(),
            ccts,
            makespan: self.world.now,
            intervals: self.stats.clone(),
            rate_calcs: self.totals.rate_calcs,
            rate_msgs: self.totals.rate_msgs,
            update_msgs: self.totals.update_msgs,
            rate_calc_wall_s: self.totals.rate_calc_wall_s,
            peak_active_coflows: self.totals.peak_active_coflows,
            peak_active_flows: self.totals.peak_active_flows,
            flow_slots: self.world.flows.len(),
            updates_per_interval: self.stats.updates_per_interval.clone(),
            sim_wall_s: wall_start.elapsed().as_secs_f64(),
            deadline,
            obs,
        }
    }

    /// Once per engine instant (obs enabled): drain coordination-plane
    /// events buffered by the frontend, diff the admission counters, and
    /// scan the active set for phase / estimate / queue / rate-verdict
    /// transitions against the shadow tables. Read-only with respect to
    /// the world and the scheduler.
    fn obs_scan<F: CoordFrontend>(&mut self, front: &mut F) {
        let now = self.world.now;
        // coordination-plane events (migrations, reconciliations,
        // checkpoint/restore) buffered since the last drain
        let mut pending = match self.obs.as_mut() {
            Some(o) => std::mem::take(&mut o.pending),
            None => return,
        };
        front.drain_obs(&mut pending);
        let adm = front.admission_stats();
        let o = self.obs.as_mut().expect("obs checked by caller");
        for &(shard, kind, coflow, a, b) in &pending {
            o.plane.emit(now, 0, shard, kind, coflow, a, b);
        }
        pending.clear();
        o.pending = pending;
        // admission verdicts (deadline-aware schedulers): counter deltas
        if let Some(st) = adm {
            let da = st.admitted.saturating_sub(o.adm_admitted);
            let dr = st.rejected.saturating_sub(o.adm_rejected);
            let de = st.expired.saturating_sub(o.adm_expired);
            if da > 0 || dr > 0 {
                o.plane
                    .emit(now, 0, 0, EventKind::AdmissionVerdict, obs::NO_COFLOW, da, dr);
            }
            if de > 0 {
                o.plane
                    .emit(now, 0, 0, EventKind::AdmissionExpiry, obs::NO_COFLOW, de, 0);
            }
            o.adm_admitted = st.admitted;
            o.adm_rejected = st.rejected;
            o.adm_expired = st.expired;
        }
        for i in 0..self.world.active.len() {
            let cid = self.world.active[i];
            let c = &self.world.coflows[cid];
            let phase = match c.phase {
                crate::coflow::CoflowPhase::Piloting => 0u8,
                crate::coflow::CoflowPhase::Running => 1,
                crate::coflow::CoflowPhase::Done => 2,
            };
            if o.phase_seen[cid] == u8::MAX {
                // first observation; Arrival is already logged, so the only
                // interesting birth fact is pilot sampling starting
                if phase == 0 && !c.pilots.is_empty() {
                    o.plane.emit(
                        now,
                        0,
                        0,
                        EventKind::PilotStart,
                        cid as u64,
                        c.pilots.len() as u64,
                        0,
                    );
                }
                o.phase_seen[cid] = phase;
            } else if o.phase_seen[cid] != phase {
                o.plane
                    .emit(now, 0, 0, EventKind::Phase, cid as u64, phase as u64, 0);
                o.phase_seen[cid] = phase;
            }
            if !o.est_seen[cid] {
                if let Some(est) = c.est_size {
                    o.plane.emit(
                        now,
                        0,
                        0,
                        EventKind::Estimate,
                        cid as u64,
                        est.max(0.0) as u64,
                        0,
                    );
                    o.est_seen[cid] = true;
                }
            }
            let q = c.queue as u64;
            if o.queue_seen[cid] == u64::MAX {
                o.queue_seen[cid] = q;
            } else if o.queue_seen[cid] != q {
                o.plane
                    .emit(now, 0, 0, EventKind::QueueChange, cid as u64, q, o.queue_seen[cid]);
                o.queue_seen[cid] = q;
            }
            let verdict = if self.rate_sum[cid] > 0.0 { 1u8 } else { 2u8 };
            if o.sched_seen[cid] != verdict {
                let kind = if verdict == 1 {
                    EventKind::Scheduled
                } else {
                    EventKind::Starved
                };
                o.plane.emit(now, 0, 0, kind, cid as u64, 0, 0);
                o.sched_seen[cid] = verdict;
            }
        }
        // spool this instant's ring tails to the durable archive (after
        // every emit above, so a drain never splits an instant)
        o.drain_archive();
    }

    /// Integrate flow progress up to `t`.
    fn advance_to(&mut self, t: Time) {
        let dt = t - self.world.now;
        if dt > 0.0 {
            for &f in &self.running {
                self.world.flows[f].advance(dt);
            }
            for &cid in &self.world.active {
                self.world.coflows[cid].bytes_sent += self.rate_sum[cid] * dt;
            }
            // per-port heatmap: the analytic step knows every running
            // flow's rate over [now, t), so rate × dt bytes attribute to
            // src (up) and dst (down) exactly — no sampling involved
            if let Some(o) = self.obs.as_mut() {
                if let Some(hm) = o.heatmap.as_mut() {
                    let t0 = self.world.now;
                    for &f in &self.running {
                        let fl = &self.world.flows[f];
                        if fl.rate > 0.0 {
                            hm.add(fl.src, fl.dst, t0, t, fl.rate * dt);
                        }
                    }
                }
            }
        }
        self.world.now = t;
    }

    /// Admit a newly arrived coflow: activate it and register port loads.
    fn admit(&mut self, cid: CoflowId) {
        self.world.active.push(cid);
        let mut up: Vec<(usize, usize)> = Vec::new();
        let mut down: Vec<(usize, usize)> = Vec::new();
        // NB: loops over the coflow's flows; wide coflows are the big cost,
        // amortized once per coflow lifetime.
        let nflows = self.world.coflows[cid].flows.len();
        for i in 0..nflows {
            let f = self.world.coflows[cid].flows[i];
            let fl = self.world.flows[f];
            self.world.load.up_bytes[fl.src] += fl.size;
            self.world.load.down_bytes[fl.dst] += fl.size;
            match up.iter_mut().find(|(p, _)| *p == fl.src) {
                Some(e) => e.1 += 1,
                None => up.push((fl.src, 1)),
            }
            match down.iter_mut().find(|(p, _)| *p == fl.dst) {
                Some(e) => e.1 += 1,
                None => down.push((fl.dst, 1)),
            }
        }
        for &(p, _) in &up {
            self.world.load.occupy_up(p);
            self.mark_port_active(p);
        }
        for &(p, _) in &down {
            self.world.load.occupy_down(p);
            self.mark_port_active(p);
        }
        self.port_refs[cid] = Some(PortRefs { up, down });
        self.totals.active_flows += nflows;
        self.totals.peak_active_flows =
            self.totals.peak_active_flows.max(self.totals.active_flows);
        self.totals.peak_active_coflows =
            self.totals.peak_active_coflows.max(self.world.active.len());
        if let Some(o) = self.obs.as_mut() {
            o.plane.emit(
                self.world.now,
                0,
                0,
                EventKind::Arrival,
                cid as u64,
                nflows as u64,
                0,
            );
        }
    }

    /// Streaming admission: materialize the pending arrival into the world
    /// — dense coflow id (monotone, never recycled), flow slots recycled
    /// through the free list with a fresh global `seq` — then register it
    /// through the ordinary [`admit`](Self::admit) path. The identity
    /// assignment reproduces the materialized world exactly on
    /// arrival-sorted traces: coflow `k` of the trace becomes world coflow
    /// `k`, and because earlier coflows only *retire* (slots return LIFO)
    /// after completing, a fully-materialized run and a streamed run see
    /// the same `(seq, size, ports)` tuples everywhere the schedulers look.
    fn admit_pending(&mut self) -> CoflowId {
        let cid = self.world.coflows.len();
        let nflows = self.pending.flows.len();
        let mut flow_ids: Vec<FlowId> = Vec::with_capacity(nflows);
        let mut total = 0.0f64;
        for i in 0..nflows {
            let (src, dst, size) = self.pending.flows[i];
            total += size;
            let fid = match self.flow_free.pop() {
                Some(slot) => {
                    self.world.flows[slot] = FlowState::new(slot, cid, src, dst, size);
                    slot
                }
                None => {
                    let id = self.world.flows.len();
                    self.world.flows.push(FlowState::new(id, cid, src, dst, size));
                    id
                }
            };
            self.world.flows[fid].seq = self.flow_seq;
            self.flow_seq += 1;
            if self.bn_up[src] == 0.0 {
                self.bn_touched.push(src);
            }
            if self.bn_down[dst] == 0.0 {
                self.bn_touched.push(dst);
            }
            self.bn_up[src] += size;
            self.bn_down[dst] += size;
            flow_ids.push(fid);
        }
        // clairvoyant bottleneck bound — same math as `world_with_fabric`
        let mut bn = 0.0f64;
        for &p in &self.bn_touched {
            bn = bn.max(self.bn_up[p]).max(self.bn_down[p]);
            self.bn_up[p] = 0.0;
            self.bn_down[p] = 0.0;
        }
        self.bn_touched.clear();
        let mut st = CoflowState::new(cid, self.pending.arrival, flow_ids, total, cid as u64);
        st.deadline = self.pending.deadline;
        st.senders = self.pending.senders.clone();
        st.receivers = self.pending.receivers.clone();
        st.bottleneck_bytes = bn;
        for (i, &fid) in st.active_list.iter().enumerate() {
            self.world.flows[fid].active_pos = i;
        }
        self.world.coflows.push(st);
        // grow the engine's per-coflow tables in lockstep
        self.rate_sum.push(0.0);
        self.rate_dirty_stamp.push(0);
        self.port_refs.push(None);
        self.reports_pending.push(0);
        self.coflow_delivered.push(false);
        if let Some(o) = self.obs.as_mut() {
            o.phase_seen.push(u8::MAX);
            o.est_seen.push(false);
            o.sched_seen.push(0);
            o.queue_seen.push(u64::MAX);
        }
        self.admit(cid);
        cid
    }

    /// Reclaim the heavy per-coflow state of fully-delivered coflows
    /// (streaming only): flow slots return to the free list and the
    /// port/flow vectors are dropped. The scalar fields needed for the
    /// end-of-run accounting — `arrival`, `finished_at`, `deadline`,
    /// `total_bytes` — are retained, so `ccts` and [`DeadlineStats`] still
    /// cover every coflow of the run.
    fn retire_done(&mut self) {
        for idx in 0..self.retire_pending.len() {
            let cid = self.retire_pending[idx];
            debug_assert!(self.world.coflows[cid].done());
            let flows = std::mem::take(&mut self.world.coflows[cid].flows);
            self.flow_free.extend(flows);
            let c = &mut self.world.coflows[cid];
            c.active_list = Vec::new();
            c.senders = Vec::new();
            c.receivers = Vec::new();
            c.pilots = Vec::new();
            if let Some(o) = self.obs.as_mut() {
                o.plane
                    .emit(self.world.now, 0, 0, EventKind::Retire, cid as u64, 0, 0);
            }
        }
        self.retire_pending.clear();
    }

    fn mark_port_active(&mut self, p: usize) {
        if self.port_active[p] == 0 {
            self.active_agents += 1;
        }
        self.port_active[p] += 1;
    }

    fn unmark_port_active(&mut self, p: usize) {
        self.port_active[p] -= 1;
        if self.port_active[p] == 0 {
            self.active_agents -= 1;
        }
    }

    /// Physically complete a flow: stop it, free loads, maybe finish the
    /// coflow. (Scheduler notification happens separately — possibly
    /// delayed by the jitter model.)
    fn complete_flow(&mut self, f: FlowId) {
        let now = self.world.now;
        let old_rate = self.world.flows[f].rate;
        {
            let fl = &mut self.world.flows[f];
            fl.sent = fl.size;
            fl.rate = 0.0;
            fl.finished_at = Some(now);
        }
        self.completions.remove(f); // no-op when fired via pop()
        let fl = self.world.flows[f];
        let cid = fl.coflow;
        self.running.retain(|&x| x != f);
        // Keep the progress integrator exact between reallocations.
        self.rate_sum[cid] = (self.rate_sum[cid] - old_rate).max(0.0);
        self.world.load.up_bytes[fl.src] = (self.world.load.up_bytes[fl.src] - fl.size).max(0.0);
        self.world.load.down_bytes[fl.dst] =
            (self.world.load.down_bytes[fl.dst] - fl.size).max(0.0);
        // Port-freeing detection: when this coflow's last flow at a port
        // ends, the port's coflow occupancy drops (Philae's contention-
        // change trigger) and the agent-side mark from admit() is released.
        let mut freed_up = false;
        let mut freed_down = false;
        if let Some(refs) = self.port_refs[cid].as_mut() {
            if let Some(e) = refs.up.iter_mut().find(|(p, _)| *p == fl.src) {
                e.1 -= 1;
                freed_up = e.1 == 0;
            }
            if let Some(e) = refs.down.iter_mut().find(|(p, _)| *p == fl.dst) {
                e.1 -= 1;
                freed_down = e.1 == 0;
            }
        }
        if freed_up {
            self.world.load.release_up(fl.src);
            self.unmark_port_active(fl.src);
        }
        if freed_down {
            self.world.load.release_down(fl.dst);
            self.unmark_port_active(fl.dst);
        }
        self.totals.active_flows -= 1;

        // O(1) removal from the coflow's allocator iteration set.
        let pos = self.world.flows[f].active_pos;
        let c = &mut self.world.coflows[cid];
        c.active_list.swap_remove(pos);
        if pos < c.active_list.len() {
            let moved = c.active_list[pos];
            self.world.flows[moved].active_pos = pos;
        }
        let c = &mut self.world.coflows[cid];
        c.active_flows -= 1;
        if fl.size > c.max_finished_flow {
            c.max_finished_flow = fl.size;
        }
        let mut coflow_done = false;
        if c.active_flows == 0 && c.finished_at.is_none() {
            c.finished_at = Some(now);
            c.phase = crate::coflow::CoflowPhase::Done;
            self.world.active.retain(|&x| x != cid);
            self.port_refs[cid] = None;
            coflow_done = true;
        }
        if let Some(o) = self.obs.as_mut() {
            // flow seq (not id) so streaming slot recycling matches the
            // materialized event stream (`seq == id` there)
            o.plane.emit(
                now,
                0,
                0,
                EventKind::FlowComplete,
                cid as u64,
                fl.seq,
                fl.size.max(0.0) as u64,
            );
            if coflow_done {
                let total = self.world.coflows[cid].total_bytes.max(0.0) as u64;
                o.plane
                    .emit(now, 0, 0, EventKind::CoflowComplete, cid as u64, 0, total);
            }
        }
    }

    /// Deliver a (possibly delayed) completion report to the scheduler —
    /// the per-event admission path. Counts one agent→coordinator update
    /// message (Philae's only update type; Aalo additionally gets tick-time
    /// byte updates).
    fn deliver_report<F: CoordFrontend>(&mut self, f: FlowId, front: &mut F) -> Reaction {
        self.iv_updates += 1;
        self.totals.update_msgs += 1;
        let mut reaction = front.on_flow_complete(f, &mut self.world);
        let cid = self.world.flows[f].coflow;
        // Deliver the coflow-completion event exactly once — with the last
        // of its completion reports (under jitter these can be reordered).
        self.reports_pending[cid] -= 1;
        if self.world.coflows[cid].done()
            && self.reports_pending[cid] == 0
            && !self.coflow_delivered[cid]
        {
            self.coflow_delivered[cid] = true;
            if self.streaming {
                self.retire_pending.push(cid);
            }
            reaction = reaction.merge(front.on_coflow_complete(cid, &mut self.world));
        }
        reaction
    }

    /// Batched-admission counterpart of [`deliver_report`](Self::deliver_report):
    /// performs the identical engine bookkeeping (update accounting,
    /// exactly-once coflow completion) but queues the report into the batch
    /// instead of invoking the scheduler — `on_batch` replays the hooks in
    /// this same order afterwards.
    fn queue_report(&mut self, f: FlowId) {
        self.iv_updates += 1;
        self.totals.update_msgs += 1;
        let cid = self.world.flows[f].coflow;
        self.reports_pending[cid] -= 1;
        let coflow_done = self.world.coflows[cid].done()
            && self.reports_pending[cid] == 0
            && !self.coflow_delivered[cid];
        if coflow_done {
            self.coflow_delivered[cid] = true;
            if self.streaming {
                self.retire_pending.push(cid);
            }
        }
        self.batch.flow_reports.push((f, coflow_done));
    }

    /// Recompute the priority order and rates; measured as coordinator
    /// rate-calculation work. Returns (measured calc seconds, rate messages).
    ///
    /// Zero steady-state heap allocation: the plan, the allocation scratch,
    /// the running set, and the dirty list are all engine-owned reusable
    /// buffers (see the module docs).
    fn reallocate<F: CoordFrontend>(&mut self, front: &mut F) -> (f64, u64) {
        let t0 = Instant::now();
        front.compute(&mut self.world, self.full_recompute);
        let calc_s = t0.elapsed().as_secs_f64();
        self.totals.rate_calc_wall_s += calc_s;
        self.totals.rate_calcs += 1;
        self.iv_rate_calc_s += calc_s;
        self.iv_rate_calcs += 1;

        // Apply: zero flows that lost their rate, set granted ones, push
        // fresh completion events for changed rates. Coflows touched by
        // either the previous or the new running set land on the stamped
        // dirty list exactly once.
        let mut changed = 0u64;
        let now = self.world.now;
        self.rate_dirty_epoch += 1;
        let de = self.rate_dirty_epoch;
        for idx in 0..self.running.len() {
            let f = self.running[idx];
            let cid = self.world.flows[f].coflow;
            if self.rate_dirty_stamp[cid] != de {
                self.rate_dirty_stamp[cid] = de;
                self.rate_dirty.push(cid);
            }
            if !front.was_granted(f)
                && !self.world.flows[f].done()
                && self.world.flows[f].rate != 0.0
            {
                self.world.flows[f].rate = 0.0;
                self.completions.remove(f);
                changed += 1;
            }
        }
        // Rebuild the running set from the grants without allocating: the
        // spare buffer takes over as the new list.
        std::mem::swap(&mut self.running, &mut self.running_spare);
        self.running.clear();
        for idx in 0..front.grants().len() {
            let (f, r) = front.grants()[idx];
            let old_rate = self.world.flows[f].rate;
            if (old_rate - r).abs() > EPS {
                self.world.flows[f].rate = r;
                changed += 1;
                let due = now + self.world.flows[f].remaining() / r;
                let seq = self.world.flows[f].seq;
                self.completions.set(f, due, seq);
            }
            self.running.push(f);
            let cid = self.world.flows[f].coflow;
            if self.rate_dirty_stamp[cid] != de {
                self.rate_dirty_stamp[cid] = de;
                self.rate_dirty.push(cid);
            }
        }
        // Rebuild per-coflow rate sums for the touched coflows.
        for idx in 0..self.rate_dirty.len() {
            let cid = self.rate_dirty[idx];
            self.rate_sum[cid] = 0.0;
        }
        for &f in &self.running {
            let fl = &self.world.flows[f];
            self.rate_sum[fl.coflow] += fl.rate;
        }
        self.rate_dirty.clear();
        self.totals.rate_msgs += changed;
        self.iv_rate_msgs += changed;
        (calc_s, changed)
    }

    /// Close out accounting intervals up to `now`.
    fn roll_intervals(&mut self) {
        let idx = (self.world.now / self.delta_acct) as u64;
        if idx > self.interval_idx {
            // fold the interval that just ended (only if the cluster was
            // busy during it — idle intervals don't exist on the testbed)
            let busy = !self.world.active.is_empty()
                || self.iv_rate_calcs > 0
                || self.iv_updates > 0;
            if busy {
                let send_s = self.iv_rate_msgs as f64 * self.costs.send_per_msg;
                let recv_s = self.iv_updates as f64 * self.costs.recv_per_msg;
                self.stats.push_interval(
                    self.delta_acct,
                    self.iv_rate_calc_s,
                    send_s,
                    recv_s,
                    self.iv_updates,
                    self.iv_rate_msgs,
                    self.iv_rate_calcs,
                );
            }
            self.iv_rate_calc_s = 0.0;
            self.iv_updates = 0;
            self.iv_rate_msgs = 0;
            self.iv_rate_calcs = 0;
            self.interval_idx = idx;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Trace, TraceRecord, TraceSpec};
    use crate::{GBPS, MB};

    fn run(trace: &Trace, kind: SchedulerKind) -> SimResult {
        Simulation::run(trace, kind, &SchedulerConfig::default())
    }

    #[test]
    fn single_flow_cct_is_size_over_rate() {
        let trace = Trace::from_records(
            2,
            vec![TraceRecord::uniform(1, 0.0, vec![0], vec![1], 125.0)],
        );
        for &kind in &[SchedulerKind::Philae, SchedulerKind::Aalo, SchedulerKind::Fifo] {
            let res = run(&trace, kind);
            // 125 MB over 1 Gbps = 1 second
            assert!(
                (res.ccts[0] - 125.0 * MB / GBPS).abs() < 1e-6,
                "{kind:?}: cct={}",
                res.ccts[0]
            );
        }
    }

    #[test]
    fn all_coflows_complete_under_every_scheduler() {
        let trace = TraceSpec::tiny(8, 20).seed(3).generate();
        for &kind in SchedulerKind::all() {
            let res = run(&trace, kind);
            for (i, &cct) in res.ccts.iter().enumerate() {
                assert!(cct.is_finite() && cct > 0.0, "{kind:?}: coflow {i} never finished");
            }
        }
    }

    #[test]
    fn sequential_shared_port_is_sum_of_times() {
        // two 125 MB coflows sharing the same (0→1) pair: total 2 s of work
        let trace = Trace::from_records(
            2,
            vec![
                TraceRecord::uniform(1, 0.0, vec![0], vec![1], 125.0),
                TraceRecord::uniform(2, 0.0, vec![0], vec![1], 125.0),
            ],
        );
        let res = run(&trace, SchedulerKind::Scf);
        let mut ccts = res.ccts.clone();
        ccts.sort_by(f64::total_cmp);
        assert!((ccts[0] - 1.0).abs() < 1e-6, "first finisher {}", ccts[0]);
        assert!((ccts[1] - 2.0).abs() < 1e-6, "second finisher {}", ccts[1]);
        assert!((res.makespan - 2.0).abs() < 1e-6);
    }

    #[test]
    fn disjoint_coflows_run_in_parallel() {
        let trace = Trace::from_records(
            4,
            vec![
                TraceRecord::uniform(1, 0.0, vec![0], vec![1], 125.0),
                TraceRecord::uniform(2, 0.0, vec![2], vec![3], 125.0),
            ],
        );
        let res = run(&trace, SchedulerKind::Philae);
        assert!((res.makespan - 1.0).abs() < 1e-6, "makespan {}", res.makespan);
    }

    #[test]
    fn scf_oracle_beats_fifo_on_adversarial_order() {
        // big coflow arrives first, then many small ones on the same pair:
        // FIFO head-of-line blocks; SCF preempts.
        let mut records = vec![TraceRecord::uniform(1, 0.0, vec![0], vec![1], 1250.0)];
        for i in 0..10 {
            records.push(TraceRecord::uniform(
                2 + i,
                0.01,
                vec![0],
                vec![1],
                12.5,
            ));
        }
        let trace = Trace::from_records(2, records);
        let fifo = run(&trace, SchedulerKind::Fifo);
        let scf = run(&trace, SchedulerKind::Scf);
        assert!(
            scf.avg_cct() < fifo.avg_cct() / 2.0,
            "scf {} vs fifo {}",
            scf.avg_cct(),
            fifo.avg_cct()
        );
    }

    #[test]
    fn philae_estimates_sizes() {
        let trace = TraceSpec::tiny(8, 10).seed(1).generate();
        let cfg = SchedulerConfig::default();
        let mut sched = SchedulerKind::Philae.build(&trace, &cfg);
        let res = Simulation::run_with(&trace, sched.as_mut(), &cfg, &SimConfig::default());
        assert!(res.ccts.iter().all(|c| c.is_finite()));
        // Philae must have learned sizes: updates are only completions, so
        // update messages == number of flows.
        assert_eq!(res.update_msgs as usize, trace.flows.len());
    }

    #[test]
    fn aalo_receives_many_more_updates_than_philae() {
        let trace = TraceSpec::tiny(10, 30).seed(7).generate();
        let cfg = SchedulerConfig::default();
        let philae = Simulation::run(&trace, SchedulerKind::Philae, &cfg);
        let aalo = Simulation::run(&trace, SchedulerKind::Aalo, &cfg);
        assert!(
            aalo.update_msgs > 3 * philae.update_msgs,
            "aalo {} vs philae {}",
            aalo.update_msgs,
            philae.update_msgs
        );
    }

    #[test]
    fn work_conservation_no_idle_port_with_backlog() {
        // one coflow with two flows from the same src to two dsts: greedy
        // must run both? no — same uplink. Use two flows sharing nothing.
        let trace = Trace::from_records(
            4,
            vec![TraceRecord {
                external_id: 1,
                arrival: 0.0,
                deadline: None,
                mappers: vec![0, 1],
                reducers: vec![(2, 125.0e6), (3, 125.0e6)],
            }],
        );
        // 4 flows: (0,2),(1,2),(0,3),(1,3) each 62.5 MB; aggregate demand
        // saturates both uplinks: finish time = 125 MB/port / 1 Gbps = 1 s.
        let res = run(&trace, SchedulerKind::Philae);
        assert!((res.makespan - 1.0).abs() < 0.05, "makespan {}", res.makespan);
    }

    #[test]
    fn makespan_independent_of_scheduler_for_single_pair_backlog() {
        // Work conservation check: total service time on one contended pair
        // is invariant across schedulers.
        let records: Vec<TraceRecord> = (0..5)
            .map(|i| TraceRecord::uniform(i + 1, 0.0, vec![0], vec![1], 25.0))
            .collect();
        let trace = Trace::from_records(2, records);
        let expected = 5.0 * 25.0 * MB / GBPS;
        for &kind in SchedulerKind::all() {
            let res = run(&trace, kind);
            assert!(
                (res.makespan - expected).abs() < 1e-3,
                "{kind:?} makespan {} != {expected}",
                res.makespan
            );
        }
    }

    #[test]
    fn jitter_delays_learning_but_everything_finishes() {
        let trace = TraceSpec::tiny(8, 15).seed(11).generate();
        let mut cfg = SchedulerConfig::default();
        cfg.report_jitter = 0.05;
        cfg.dynamics_seed = 3;
        let res = Simulation::run(&trace, SchedulerKind::Philae, &cfg);
        assert!(res.ccts.iter().all(|c| c.is_finite() && *c > 0.0));
    }

    #[test]
    fn deterministic_repeat_runs() {
        let trace = TraceSpec::tiny(8, 20).seed(5).generate();
        let cfg = SchedulerConfig::default();
        let a = Simulation::run(&trace, SchedulerKind::Philae, &cfg);
        let b = Simulation::run(&trace, SchedulerKind::Philae, &cfg);
        assert_eq!(a.ccts, b.ccts);
        assert_eq!(a.rate_calcs, b.rate_calcs);
    }

    #[test]
    fn heterogeneous_fabric_scales_completion_times() {
        // same 125 MB flow on a 1 Gbps pair vs a 40 Gbps pair
        let trace = Trace::from_records(
            4,
            vec![
                TraceRecord::uniform(1, 0.0, vec![0], vec![1], 125.0),
                TraceRecord::uniform(2, 0.0, vec![2], vec![3], 125.0),
            ],
        );
        let fabric = Fabric::mixed_gbps(4, &[1.0, 1.0, 40.0, 40.0]);
        let cfg = SchedulerConfig::default();
        let sim_cfg = SimConfig { fabric: Some(fabric), ..SimConfig::default() };
        let mut sched = SchedulerKind::Philae.build(&trace, &cfg);
        let res = Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg);
        assert!((res.ccts[0] - 1.0).abs() < 1e-6, "1 Gbps cct {}", res.ccts[0]);
        assert!(
            (res.ccts[1] - 1.0 / 40.0).abs() < 1e-6,
            "40 Gbps cct {}",
            res.ccts[1]
        );
    }

    #[test]
    fn batched_and_per_event_admission_agree_on_tiny_trace() {
        let trace = TraceSpec::tiny(10, 25).seed(9).generate();
        let cfg = SchedulerConfig::default();
        for &kind in &[SchedulerKind::Philae, SchedulerKind::Aalo] {
            let base = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };
            let mut s1 = kind.build(&trace, &cfg);
            let batched = Simulation::run_with(&trace, s1.as_mut(), &cfg, &base);
            let per_event_cfg = SimConfig { per_event_admission: true, ..base };
            let mut s2 = kind.build(&trace, &cfg);
            let per_event = Simulation::run_with(&trace, s2.as_mut(), &cfg, &per_event_cfg);
            assert_eq!(batched.ccts, per_event.ccts, "{kind:?}");
            assert_eq!(batched.rate_calcs, per_event.rate_calcs, "{kind:?}");
            assert_eq!(batched.update_msgs, per_event.update_msgs, "{kind:?}");
        }
    }

    #[test]
    fn cluster_k1_run_matches_single_coordinator() {
        let trace = TraceSpec::tiny(10, 25).seed(9).generate();
        let cfg = SchedulerConfig::default();
        let base = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };
        for &kind in &[SchedulerKind::Philae, SchedulerKind::Aalo] {
            let mut s = kind.build(&trace, &cfg);
            let single = Simulation::run_with(&trace, s.as_mut(), &cfg, &base);
            let ccfg = SimConfig { coordinators: 1, ..base.clone() };
            let clustered = Simulation::run_cluster(&trace, kind, &cfg, &ccfg);
            assert_eq!(single.ccts, clustered.ccts, "{kind:?}");
            assert_eq!(single.rate_calcs, clustered.rate_calcs, "{kind:?}");
            assert_eq!(single.rate_msgs, clustered.rate_msgs, "{kind:?}");
        }
    }

    #[test]
    fn cluster_k2_completes_every_coflow() {
        let trace = TraceSpec::tiny(10, 25).seed(9).generate();
        let cfg = SchedulerConfig::default();
        for &kind in &[SchedulerKind::Philae, SchedulerKind::Aalo] {
            let ccfg = SimConfig {
                coordinators: 2,
                account_delta: Some(1e18),
                ..SimConfig::default()
            };
            let res = Simulation::run_cluster(&trace, kind, &cfg, &ccfg);
            for (i, &cct) in res.ccts.iter().enumerate() {
                assert!(cct.is_finite() && cct > 0.0, "{kind:?}: coflow {i} unfinished");
            }
        }
    }

    #[test]
    fn streamed_run_matches_materialized_run() {
        let spec = TraceSpec::tiny(8, 20).seed(3);
        let trace = spec.generate();
        let cfg = SchedulerConfig::default();
        let sim_cfg = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };
        for &kind in &[SchedulerKind::Philae, SchedulerKind::Fifo] {
            let mut s = kind.build(&trace, &cfg);
            let mat = Simulation::run_with(&trace, s.as_mut(), &cfg, &sim_cfg);
            let mut stream = spec.stream();
            let streamed = Simulation::run_stream(&mut stream, kind, &cfg, &sim_cfg);
            assert_eq!(mat.ccts, streamed.ccts, "{kind:?}");
            assert_eq!(mat.rate_calcs, streamed.rate_calcs, "{kind:?}");
            assert_eq!(mat.update_msgs, streamed.update_msgs, "{kind:?}");
        }
    }

    #[test]
    fn streamed_run_retires_flow_state() {
        // sequential single-pair coflows: the flow table must stay at the
        // concurrent high-water mark (1 slot), not the run total
        let records: Vec<TraceRecord> = (0..20)
            .map(|i| TraceRecord::uniform(i + 1, i as f64 * 2.0, vec![0], vec![1], 125.0))
            .collect();
        let trace = Trace::from_records(2, records);
        let mut stream = crate::trace::TraceStream::new(&trace);
        let cfg = SchedulerConfig::default();
        let res =
            Simulation::run_stream(&mut stream, SchedulerKind::Fifo, &cfg, &SimConfig::default());
        assert_eq!(res.ccts.len(), 20);
        assert!(res.ccts.iter().all(|c| c.is_finite()));
        assert_eq!(res.peak_active_flows, 1, "coflows must run sequentially");
        assert_eq!(res.flow_slots, 1, "retirement must recycle slots");
    }

    #[test]
    fn sharded_allocation_in_engine_matches_serial() {
        let trace = TraceSpec::tiny(12, 30).seed(4).generate();
        let cfg = SchedulerConfig::default();
        let base = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };
        let mut s1 = SchedulerKind::Philae.build(&trace, &cfg);
        let serial = Simulation::run_with(&trace, s1.as_mut(), &cfg, &base);
        for shards in [2usize, 4] {
            let sharded_cfg = SimConfig { alloc_shards: shards, ..base.clone() };
            let mut s2 = SchedulerKind::Philae.build(&trace, &cfg);
            let sharded = Simulation::run_with(&trace, s2.as_mut(), &cfg, &sharded_cfg);
            assert_eq!(serial.ccts, sharded.ccts, "S={shards}");
            assert_eq!(serial.rate_msgs, sharded.rate_msgs, "S={shards}");
        }
    }
}
