//! Position-indexed min-heap for flow-completion events.
//!
//! The engine's old completion queue was a plain `BinaryHeap` with
//! epoch-stamped lazy deletion: every rate change pushed a fresh entry and
//! left the stale one behind until it bubbled to the top, so the heap was
//! reserved at `2·nf` and could still grow past it under churn. This heap
//! keeps **at most one entry per flow** (a dense `flow → slot` position
//! map): a rate change *reschedules* the existing entry in place
//! (`O(log n)` sift) and a stall/completion *removes* it, so the live size
//! is bounded by the number of running flows and stale entries simply
//! cannot exist.
//!
//! Ordering is `(due time, key)` under `f64::total_cmp` — a total,
//! deterministic order, so event replay is bit-reproducible. The key is a
//! caller-supplied stable sequence (the flow's creation order), **not** the
//! flow id: the streaming engine recycles flow slots, so an id-based
//! tie-break would depend on allocation history. In materialized worlds
//! `key == id` and the historical order is unchanged.

use crate::{FlowId, Time};

/// Min-heap of `(due, key, flow)` with O(1) membership and O(log n)
/// insert/reschedule/remove. All storage is reused; `pos` grows once to the
/// flow-table size and the heap vector to the running-flow high-water mark.
#[derive(Debug, Clone, Default)]
pub struct CompletionHeap {
    heap: Vec<(Time, u64, FlowId)>,
    /// `flow → heap slot + 1`; 0 = not queued.
    pos: Vec<u32>,
}

impl CompletionHeap {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size the position map for `num_flows` flows so steady-state
    /// operation never reallocates it.
    pub fn with_flow_capacity(num_flows: usize) -> Self {
        CompletionHeap { heap: Vec::new(), pos: vec![0; num_flows] }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// `true` if `f` currently has a scheduled completion.
    pub fn contains(&self, f: FlowId) -> bool {
        self.pos.get(f).copied().unwrap_or(0) != 0
    }

    /// Earliest `(due, key, flow)` without removing it.
    #[inline]
    pub fn peek(&self) -> Option<(Time, u64, FlowId)> {
        self.heap.first().copied()
    }

    /// Remove and return the earliest `(due, key, flow)`.
    pub fn pop(&mut self) -> Option<(Time, u64, FlowId)> {
        let top = *self.heap.first()?;
        self.pos[top.2] = 0;
        let last = self.heap.pop().expect("non-empty");
        if !self.heap.is_empty() {
            self.heap[0] = last;
            self.pos[last.2] = 1;
            self.sift_down(0);
        }
        Some(top)
    }

    /// Schedule (or reschedule) flow `f` (stable tie-break `key`) to
    /// complete at `due`.
    pub fn set(&mut self, f: FlowId, due: Time, key: u64) {
        if f >= self.pos.len() {
            self.pos.resize(f + 1, 0);
        }
        let slot = self.pos[f];
        if slot == 0 {
            self.heap.push((due, key, f));
            let i = self.heap.len() - 1;
            self.pos[f] = i as u32 + 1;
            self.sift_up(i);
        } else {
            let i = slot as usize - 1;
            self.heap[i].0 = due;
            self.heap[i].1 = key;
            self.sift_up(i);
            self.sift_down(i);
        }
    }

    /// Drop flow `f`'s scheduled completion (no-op if absent).
    pub fn remove(&mut self, f: FlowId) {
        let slot = match self.pos.get(f) {
            Some(&s) if s != 0 => s as usize - 1,
            _ => return,
        };
        self.pos[f] = 0;
        let last = self.heap.pop().expect("non-empty: f was queued");
        if slot < self.heap.len() {
            self.heap[slot] = last;
            self.pos[last.2] = slot as u32 + 1;
            self.sift_up(slot);
            self.sift_down(slot);
        }
    }

    #[inline]
    fn less(a: (Time, u64, FlowId), b: (Time, u64, FlowId)) -> bool {
        a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)) == std::cmp::Ordering::Less
    }

    #[inline]
    fn swap(&mut self, a: usize, b: usize) {
        self.heap.swap(a, b);
        self.pos[self.heap[a].2] = a as u32 + 1;
        self.pos[self.heap[b].2] = b as u32 + 1;
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if Self::less(self.heap[i], self.heap[parent]) {
                self.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let l = 2 * i + 1;
            if l >= self.heap.len() {
                break;
            }
            let r = l + 1;
            let mut m = l;
            if r < self.heap.len() && Self::less(self.heap[r], self.heap[l]) {
                m = r;
            }
            if Self::less(self.heap[m], self.heap[i]) {
                self.swap(i, m);
                i = m;
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(h: &mut CompletionHeap) -> Vec<(Time, FlowId)> {
        let mut out = Vec::new();
        while let Some((t, _, f)) = h.pop() {
            out.push((t, f));
        }
        out
    }

    /// `set` with the materialized-world convention `key == id`.
    fn set_id(h: &mut CompletionHeap, f: FlowId, due: Time) {
        h.set(f, due, f as u64);
    }

    #[test]
    fn pops_in_time_then_key_order() {
        let mut h = CompletionHeap::new();
        set_id(&mut h, 2, 3.0);
        set_id(&mut h, 0, 1.0);
        set_id(&mut h, 1, 3.0);
        set_id(&mut h, 3, 2.0);
        assert_eq!(h.peek(), Some((1.0, 0, 0)));
        assert_eq!(drain(&mut h), vec![(1.0, 0), (2.0, 3), (3.0, 1), (3.0, 2)]);
        assert!(h.is_empty());
    }

    #[test]
    fn key_breaks_same_time_ties_not_id() {
        // recycled slots: flow slot 5 created *before* slot 1 (seq 10 < 20)
        let mut h = CompletionHeap::new();
        h.set(5, 1.0, 10);
        h.set(1, 1.0, 20);
        assert_eq!(h.pop(), Some((1.0, 10, 5)));
        assert_eq!(h.pop(), Some((1.0, 20, 1)));
    }

    #[test]
    fn set_reschedules_in_place() {
        let mut h = CompletionHeap::new();
        set_id(&mut h, 0, 5.0);
        set_id(&mut h, 1, 2.0);
        set_id(&mut h, 0, 1.0); // move earlier
        assert_eq!(h.len(), 2, "reschedule must not duplicate");
        assert_eq!(h.peek(), Some((1.0, 0, 0)));
        set_id(&mut h, 0, 9.0); // move later
        assert_eq!(h.len(), 2);
        assert_eq!(drain(&mut h), vec![(2.0, 1), (9.0, 0)]);
    }

    #[test]
    fn remove_is_exact_and_tolerant() {
        let mut h = CompletionHeap::with_flow_capacity(8);
        for f in 0..6 {
            set_id(&mut h, f, (6 - f) as f64);
        }
        h.remove(3);
        h.remove(3); // double remove: no-op
        h.remove(7); // never queued: no-op
        assert!(!h.contains(3));
        assert_eq!(h.len(), 5);
        let order = drain(&mut h);
        assert_eq!(order, vec![(1.0, 5), (2.0, 4), (4.0, 2), (5.0, 1), (6.0, 0)]);
    }

    #[test]
    fn randomized_against_reference_sort() {
        use crate::util::Rng;
        let mut rng = Rng::seed_from_u64(99);
        let mut h = CompletionHeap::new();
        let mut reference: Vec<(Time, FlowId)> = Vec::new();
        for step in 0..2000 {
            let f = rng.below(64);
            match rng.below(3) {
                0 | 1 => {
                    let t = rng.uniform(0.0, 100.0);
                    reference.retain(|e| e.1 != f);
                    reference.push((t, f));
                    set_id(&mut h, f, t);
                }
                _ => {
                    reference.retain(|e| e.1 != f);
                    h.remove(f);
                }
            }
            assert_eq!(h.len(), reference.len(), "step {step}");
        }
        reference.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        assert_eq!(drain(&mut h), reference);
    }
}
