//! Per-port utilization heatmap time-series.
//!
//! The paper's per-port evidence (skewed load, stragglers, the "did
//! sampling starve a port?" question) needs a port×time utilization
//! matrix, not end-of-run scalars. This is a *downsampled* one: time is
//! split into a fixed number of bins and each port accumulates the bytes
//! it moved (up = egress at the sender, down = ingress at the receiver)
//! per bin. Memory is `2 × ports × bins × 8` bytes regardless of run
//! length — when the run outgrows the current horizon, the bin width
//! doubles and adjacent bins fold together (pairwise sums), the same
//! trick streaming percentile sketches use: cheap, exact in total bytes,
//! and bounded forever.
//!
//! The engine feeds it from the analytic advance step (`advance_to`
//! knows every running flow's rate and the interval length, so
//! `rate × dt` bytes attribute to `[t0, t1)` with no extra bookkeeping),
//! which means bins are exact byte counts, not samples. Port capacities
//! are copied from the fabric at construction so utilization
//! (`bytes / (capacity × bin_width)`) exports without re-threading the
//! fabric through every reporting path.

use crate::util::JsonValue;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Default number of time bins (`SimConfig::heatmap_bins` overrides).
pub const DEFAULT_BINS: usize = 64;

/// Downsampled port×time byte matrix with fold-on-overflow binning.
#[derive(Debug, Clone)]
pub struct Heatmap {
    ports: usize,
    bins: usize,
    /// Seconds per bin; doubles when the horizon is outgrown.
    bin_w: f64,
    /// Bytes sent upward (egress) per `[port][bin]`, flattened.
    up: Vec<f64>,
    /// Bytes received downward (ingress) per `[port][bin]`, flattened.
    down: Vec<f64>,
    /// Per-port capacities (bytes/sec), copied from the fabric.
    up_cap: Vec<f64>,
    down_cap: Vec<f64>,
    /// Number of fold-in-half compactions performed.
    folds: u32,
}

impl Heatmap {
    /// `bins` time bins starting `initial_bin_w` seconds wide; capacities
    /// are the fabric's per-port rates (bytes/sec).
    pub fn new(bins: usize, initial_bin_w: f64, up_cap: Vec<f64>, down_cap: Vec<f64>) -> Self {
        let ports = up_cap.len().max(down_cap.len());
        let bins = bins.max(2);
        Heatmap {
            ports,
            bins,
            bin_w: if initial_bin_w > 0.0 { initial_bin_w } else { 1.0 },
            up: vec![0.0; ports * bins],
            down: vec![0.0; ports * bins],
            up_cap,
            down_cap,
            folds: 0,
        }
    }

    pub fn ports(&self) -> usize {
        self.ports
    }

    pub fn bins(&self) -> usize {
        self.bins
    }

    /// Current bin width in seconds.
    pub fn bin_width(&self) -> f64 {
        self.bin_w
    }

    /// How many times the horizon doubled.
    pub fn folds(&self) -> u32 {
        self.folds
    }

    /// Double the bin width: bins (2i, 2i+1) fold into bin i, the upper
    /// half zeroes out.
    fn fold(&mut self) {
        for m in [&mut self.up, &mut self.down] {
            for p in 0..self.ports {
                let row = p * self.bins;
                for i in 0..self.bins / 2 {
                    m[row + i] = m[row + 2 * i] + m[row + 2 * i + 1];
                }
                for i in self.bins / 2..self.bins {
                    m[row + i] = 0.0;
                }
            }
        }
        self.bin_w *= 2.0;
        self.folds += 1;
    }

    /// Attribute `bytes` moved from `src` (up) to `dst` (down) over
    /// `[t0, t1)`, spread proportionally across the bins the interval
    /// overlaps. Grows the horizon (by folding) until `t1` fits.
    pub fn add(&mut self, src: usize, dst: usize, t0: f64, t1: f64, bytes: f64) {
        if bytes <= 0.0 || t1 <= t0 || src >= self.ports || dst >= self.ports {
            return;
        }
        while t1 >= self.bin_w * self.bins as f64 {
            self.fold();
        }
        let span = t1 - t0;
        let first = (t0 / self.bin_w).floor() as usize;
        let last = ((t1 / self.bin_w).ceil() as usize).min(self.bins).max(first + 1);
        for b in first..last {
            let lo = (b as f64 * self.bin_w).max(t0);
            let hi = ((b + 1) as f64 * self.bin_w).min(t1);
            if hi <= lo {
                continue;
            }
            let share = bytes * (hi - lo) / span;
            self.up[src * self.bins + b] += share;
            self.down[dst * self.bins + b] += share;
        }
    }

    fn cap(&self, port: usize, up: bool) -> f64 {
        let v = if up { &self.up_cap } else { &self.down_cap };
        v.get(port).copied().unwrap_or(0.0)
    }

    /// Utilization of one cell: bytes / (capacity × bin width); 0 when
    /// the capacity is unknown.
    fn util(&self, port: usize, bin: usize, up: bool) -> f64 {
        let cap = self.cap(port, up);
        if cap <= 0.0 {
            return 0.0;
        }
        let m = if up { &self.up } else { &self.down };
        m[port * self.bins + bin] / (cap * self.bin_w)
    }

    /// CSV export: `port,dir,bin,t_start,t_end,bytes,utilization`, one
    /// row per non-empty cell (zero cells omitted — sparse runs stay
    /// small).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("port,dir,bin,t_start,t_end,bytes,utilization\n");
        for p in 0..self.ports {
            for (dir, up) in [("up", true), ("down", false)] {
                let m = if up { &self.up } else { &self.down };
                for b in 0..self.bins {
                    let bytes = m[p * self.bins + b];
                    if bytes <= 0.0 {
                        continue;
                    }
                    let _ = writeln!(
                        out,
                        "{},{},{},{},{},{},{}",
                        p,
                        dir,
                        b,
                        b as f64 * self.bin_w,
                        (b + 1) as f64 * self.bin_w,
                        bytes,
                        self.util(p, b, up),
                    );
                }
            }
        }
        out
    }

    /// JSON export (`philae.obs.heatmap.v1`): bin geometry, per-port
    /// capacities, and the dense up/down byte matrices (row per port).
    pub fn to_json(&self) -> JsonValue {
        let matrix = |m: &Vec<f64>| {
            JsonValue::Array(
                (0..self.ports)
                    .map(|p| {
                        JsonValue::Array(
                            m[p * self.bins..(p + 1) * self.bins]
                                .iter()
                                .map(|&v| JsonValue::Number(v))
                                .collect(),
                        )
                    })
                    .collect(),
            )
        };
        let caps = |v: &Vec<f64>| {
            JsonValue::Array(v.iter().map(|&c| JsonValue::Number(c)).collect())
        };
        let mut root = BTreeMap::new();
        root.insert("schema".into(), JsonValue::String("philae.obs.heatmap.v1".into()));
        root.insert("ports".into(), JsonValue::Number(self.ports as f64));
        root.insert("bins".into(), JsonValue::Number(self.bins as f64));
        root.insert("bin_width_s".into(), JsonValue::Number(self.bin_w));
        root.insert("folds".into(), JsonValue::Number(self.folds as f64));
        root.insert("up_capacity".into(), caps(&self.up_cap));
        root.insert("down_capacity".into(), caps(&self.down_cap));
        root.insert("up_bytes".into(), matrix(&self.up));
        root.insert("down_bytes".into(), matrix(&self.down));
        JsonValue::Object(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn total(m: &Heatmap) -> (f64, f64) {
        (m.up.iter().sum(), m.down.iter().sum())
    }

    #[test]
    fn bytes_split_proportionally_across_bins() {
        let mut h = Heatmap::new(4, 1.0, vec![100.0; 2], vec![100.0; 2]);
        // 100 bytes over [0.5, 2.5): 25% in bin 0, 50% in bin 1, 25% in bin 2
        h.add(0, 1, 0.5, 2.5, 100.0);
        assert!((h.up[0] - 25.0).abs() < 1e-9);
        assert!((h.up[1] - 50.0).abs() < 1e-9);
        assert!((h.up[2] - 25.0).abs() < 1e-9);
        // dst mirrors into its down row (port 1, bin 1)
        assert!((h.down[h.bins + 1] - 50.0).abs() < 1e-9);
        let (u, d) = total(&h);
        assert!((u - 100.0).abs() < 1e-9 && (d - 100.0).abs() < 1e-9);
        // bin 1 at 50 B/s against 100 B/s capacity: 50% utilization
        assert!((h.util(0, 1, true) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn fold_preserves_totals_and_extends_horizon() {
        let mut h = Heatmap::new(4, 1.0, vec![10.0], vec![10.0]);
        h.add(0, 0, 0.0, 4.0, 40.0); // fills the initial 4 s horizon
        assert_eq!(h.folds(), 1, "t1 == horizon forces one fold");
        h.add(0, 0, 6.5, 7.5, 8.0); // fits the doubled 8 s horizon
        assert_eq!(h.bin_width(), 2.0);
        let (u, _) = total(&h);
        assert!((u - 48.0).abs() < 1e-9, "folding never loses bytes");
        // the late transfer landed past the folded-down prefix
        assert!(h.up[3] > 0.0);
    }

    #[test]
    fn exports_are_well_formed() {
        let mut h = Heatmap::new(8, 0.5, vec![1e9; 3], vec![1e9; 3]);
        h.add(2, 0, 0.0, 1.0, 5e8);
        let csv = h.to_csv();
        assert!(csv.starts_with("port,dir,bin,t_start,t_end,bytes,utilization\n"));
        // 2 bins × (one up row for port 2 + one down row for port 0)
        assert_eq!(csv.lines().count(), 5);
        let json = h.to_json().to_string();
        let v = JsonValue::parse(&json).expect("self-produced JSON parses");
        assert_eq!(v.get("schema").and_then(|s| s.as_str()), Some("philae.obs.heatmap.v1"));
        assert_eq!(v.get("ports").and_then(|n| n.as_f64()), Some(3.0));
    }

    #[test]
    fn out_of_range_ports_and_empty_intervals_are_ignored() {
        let mut h = Heatmap::new(4, 1.0, vec![1.0], vec![1.0]);
        h.add(5, 0, 0.0, 1.0, 10.0); // src out of range
        h.add(0, 0, 2.0, 2.0, 10.0); // zero-length interval
        h.add(0, 0, 0.0, 1.0, 0.0); // zero bytes
        let (u, d) = total(&h);
        assert_eq!((u, d), (0.0, 0.0));
    }
}
