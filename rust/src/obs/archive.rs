//! Durable streaming archive for the flight recorder.
//!
//! The per-shard rings (`obs/mod.rs`) answer "what just happened" from
//! bounded memory, but the paper's headline claims are *distribution*
//! claims: a million-coflow study needs the full event log, which a ring
//! of any sane cap drops. This module streams the rings to disk during
//! the run without touching the record hot path:
//!
//! * [`ArchiveSpool`] — the producer side, owned next to the `ObsPlane`
//!   (engine or live service). Each drain copies only the ring **tail**
//!   pushed since the previous drain (`Recorder::pushed` cursor +
//!   `Recorder::extend_tail_into`, O(new events)) into a batch buffer;
//!   full buffers ship to a background spooler thread over a channel and
//!   boomerang back through the `runtime/evloop.rs`
//!   [`BufferPool`]/[`RecycleSender`] free-list, so the steady state
//!   allocates nothing. Backpressure is explicit and non-blocking: with
//!   [`ArchiveConfig::max_outstanding`] buffers in flight the spool
//!   *drops* (counted), it never stalls the simulation.
//! * The spooler thread writes length-prefixed, FNV-1a-checksummed
//!   records into rotated segment files (`seg_NNNNNN.philarc`), each
//!   opened with an 8-byte magic. A record is
//!   `[u32 LE payload_len][payload][u64 LE fnv1a64(payload)]` where the
//!   payload is N fixed-layout 53-byte little-endian events.
//! * [`ArchiveReader`] replays a segment directory back into the same
//!   time-ordered event log a snapshot exports. A **truncated tail**
//!   (crash mid-write) is tolerated — the stream up to the torn record
//!   is kept and the loss is counted — while a *complete* record whose
//!   checksum mismatches is a hard error: truncation is expected,
//!   bit-rot is not.
//!
//! Accounting invariant, checked end to end:
//! `spooled == kept + dropped_ring + dropped_spool`, where `spooled` is
//! every ring push the spool observed, `kept` is what reached disk,
//! `dropped_ring` was evicted by ring wraparound between drains, and
//! `dropped_spool` absorbs backpressure drops plus anything lost to I/O
//! errors. The stats are also published as `archive.json` next to the
//! segments so offline tools see the same numbers.

use super::{Event, EventKind, ObsPlane, ObsSnapshot, Registry};
use crate::coordinator::recovery::fnv1a64;
use crate::runtime::evloop::{recycler, BufferPool, RecycleBin, RecycleSender};
use crate::util::JsonValue;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fs::{self, File};
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;

/// Serialized size of one [`Event`]: t(8) wall_ns(8) seq(8) shard(4)
/// kind(1) coflow(8) a(8) b(8).
pub const EVENT_BYTES: usize = 53;

/// Segment file header — bumped only on incompatible layout changes.
const MAGIC: &[u8; 8] = b"PHILARC1";

/// Segment filename prefix/suffix (`seg_000000.philarc`, sorted replay).
const SEG_PREFIX: &str = "seg_";
const SEG_SUFFIX: &str = ".philarc";

/// Configuration of the durable archive.
#[derive(Debug, Clone)]
pub struct ArchiveConfig {
    /// Directory receiving `seg_NNNNNN.philarc` + `archive.json`.
    pub dir: PathBuf,
    /// Rotate to a new segment once the current one reaches this size.
    pub segment_bytes: u64,
    /// Ship a buffer to the spooler once it holds this many events.
    pub flush_events: usize,
    /// Buffers in flight to the spooler before the spool drops instead
    /// of growing (explicit, non-blocking backpressure).
    pub max_outstanding: usize,
}

impl ArchiveConfig {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        ArchiveConfig {
            dir: dir.into(),
            segment_bytes: 8 * 1024 * 1024,
            flush_events: 4096,
            max_outstanding: 8,
        }
    }
}

/// End-of-run archive accounting (`ObsSnapshot::archive`, `archive.json`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArchiveStats {
    /// Ring pushes the spool observed (its share of `recorded`).
    pub spooled: u64,
    /// Events durably written to segments.
    pub kept: u64,
    /// Evicted by ring wraparound before a drain could copy them.
    pub dropped_ring: u64,
    /// Dropped by spool backpressure, I/O failure, or a dead spooler.
    pub dropped_spool: u64,
    /// Segment files written.
    pub segments: u64,
    /// Total bytes written (magic + records).
    pub bytes: u64,
    /// Failed segment I/O operations (each also surfaces in
    /// `dropped_spool` through the accounting residual).
    pub io_errors: u64,
}

impl ArchiveStats {
    pub fn to_json(&self) -> JsonValue {
        let mut o = BTreeMap::new();
        o.insert("spooled".into(), JsonValue::Number(self.spooled as f64));
        o.insert("kept".into(), JsonValue::Number(self.kept as f64));
        o.insert("dropped_ring".into(), JsonValue::Number(self.dropped_ring as f64));
        o.insert("dropped_spool".into(), JsonValue::Number(self.dropped_spool as f64));
        o.insert("segments".into(), JsonValue::Number(self.segments as f64));
        o.insert("bytes".into(), JsonValue::Number(self.bytes as f64));
        o.insert("io_errors".into(), JsonValue::Number(self.io_errors as f64));
        JsonValue::Object(o)
    }

    fn field(v: &JsonValue, name: &str) -> u64 {
        v.get(name).and_then(|n| n.as_f64()).unwrap_or(0.0) as u64
    }

    pub fn from_json(v: &JsonValue) -> ArchiveStats {
        ArchiveStats {
            spooled: Self::field(v, "spooled"),
            kept: Self::field(v, "kept"),
            dropped_ring: Self::field(v, "dropped_ring"),
            dropped_spool: Self::field(v, "dropped_spool"),
            segments: Self::field(v, "segments"),
            bytes: Self::field(v, "bytes"),
            io_errors: Self::field(v, "io_errors"),
        }
    }
}

fn encode_event(e: &Event, out: &mut Vec<u8>) {
    out.extend_from_slice(&e.t.to_bits().to_le_bytes());
    out.extend_from_slice(&e.wall_ns.to_le_bytes());
    out.extend_from_slice(&e.seq.to_le_bytes());
    out.extend_from_slice(&e.shard.to_le_bytes());
    out.push(e.kind.code());
    out.extend_from_slice(&e.coflow.to_le_bytes());
    out.extend_from_slice(&e.a.to_le_bytes());
    out.extend_from_slice(&e.b.to_le_bytes());
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b.try_into().expect("8-byte slice"))
}

/// Decode one 53-byte event; `None` for an event kind from a newer build.
fn decode_event(b: &[u8]) -> Option<Event> {
    debug_assert_eq!(b.len(), EVENT_BYTES);
    Some(Event {
        t: f64::from_bits(le_u64(&b[0..8])),
        wall_ns: le_u64(&b[8..16]),
        seq: le_u64(&b[16..24]),
        shard: u32::from_le_bytes(b[24..28].try_into().expect("4-byte slice")),
        kind: EventKind::from_code(b[28])?,
        coflow: le_u64(&b[29..37]),
        a: le_u64(&b[37..45]),
        b: le_u64(&b[45..53]),
    })
}

/// What the spooler thread hands back at join time.
#[derive(Debug, Clone, Copy, Default)]
struct WriterTotals {
    kept: u64,
    segments: u64,
    bytes: u64,
    io_errors: u64,
}

/// The spooler thread's segment writer: rotation + framing + checksums.
struct SegmentWriter {
    dir: PathBuf,
    segment_bytes: u64,
    file: Option<BufWriter<File>>,
    next_seg: u64,
    bytes_in_seg: u64,
    scratch: Vec<u8>,
    totals: WriterTotals,
}

impl SegmentWriter {
    fn new(dir: PathBuf, segment_bytes: u64) -> Self {
        SegmentWriter {
            dir,
            segment_bytes: segment_bytes.max(1024),
            file: None,
            next_seg: 0,
            bytes_in_seg: 0,
            scratch: Vec::new(),
            totals: WriterTotals::default(),
        }
    }

    fn open_segment(&mut self) -> std::io::Result<()> {
        let name = format!("{SEG_PREFIX}{:06}{SEG_SUFFIX}", self.next_seg);
        let mut f = BufWriter::new(File::create(self.dir.join(name))?);
        f.write_all(MAGIC)?;
        self.next_seg += 1;
        self.bytes_in_seg = MAGIC.len() as u64;
        self.totals.segments += 1;
        self.totals.bytes += MAGIC.len() as u64;
        self.file = Some(f);
        Ok(())
    }

    /// Emit the scratch payload as one framed record, rotating first if
    /// it would overflow the current segment.
    fn write_record(&mut self, rotate: bool) -> std::io::Result<()> {
        if rotate {
            if let Some(mut f) = self.file.take() {
                f.flush()?;
            }
            self.open_segment()?;
        }
        let f = self.file.as_mut().expect("segment opened above");
        f.write_all(&(self.scratch.len() as u32).to_le_bytes())?;
        f.write_all(&self.scratch)?;
        f.write_all(&fnv1a64(&self.scratch).to_le_bytes())?;
        Ok(())
    }

    /// Write one record holding `events`; on I/O failure the batch is
    /// dropped (counted) and the current segment abandoned so the next
    /// batch starts clean.
    fn write_batch(&mut self, events: &[Event]) {
        if events.is_empty() {
            return;
        }
        self.scratch.clear();
        for e in events {
            encode_event(e, &mut self.scratch);
        }
        let record_len = 4 + self.scratch.len() as u64 + 8;
        let rotate = match &self.file {
            None => true,
            Some(_) => self.bytes_in_seg + record_len > self.segment_bytes,
        };
        match self.write_record(rotate) {
            Ok(()) => {
                self.totals.kept += events.len() as u64;
                self.totals.bytes += record_len;
                self.bytes_in_seg += record_len;
            }
            Err(_) => {
                self.totals.io_errors += 1;
                self.file = None;
            }
        }
    }

    fn finish(mut self) -> WriterTotals {
        if let Some(mut f) = self.file.take() {
            if f.flush().is_err() {
                self.totals.io_errors += 1;
            }
        }
        self.totals
    }
}

fn spooler_loop(
    rx: mpsc::Receiver<Vec<Event>>,
    give: RecycleSender<Vec<Event>>,
    dir: PathBuf,
    segment_bytes: u64,
) -> WriterTotals {
    let mut w = SegmentWriter::new(dir, segment_bytes);
    while let Ok(mut buf) = rx.recv() {
        w.write_batch(&buf);
        buf.clear();
        give.give(buf); // boomerang: the hot side reuses this allocation
    }
    w.finish()
}

/// Producer side of the archive: drains the plane's rings into batch
/// buffers and ships them to the background spooler. Lives *next to* the
/// `ObsPlane` (engine/service obs state), not inside it, so the plane
/// stays `Clone`.
#[derive(Debug)]
pub struct ArchiveSpool {
    cfg: ArchiveConfig,
    pool: BufferPool<Vec<Event>>,
    bin: RecycleBin<Vec<Event>>,
    tx: Option<mpsc::Sender<Vec<Event>>>,
    writer: Option<thread::JoinHandle<WriterTotals>>,
    cur: Vec<Event>,
    outstanding: usize,
    /// Per-ring `Recorder::pushed` cursor at the previous drain.
    prev_pushed: Vec<u64>,
    spooled: u64,
    dropped_ring: u64,
    dropped_spool: u64,
}

impl ArchiveSpool {
    /// Create the archive directory and start the spooler thread.
    pub fn new(cfg: ArchiveConfig) -> std::io::Result<Self> {
        fs::create_dir_all(&cfg.dir)?;
        let (give, bin) = recycler::<Vec<Event>>();
        let (tx, rx) = mpsc::channel::<Vec<Event>>();
        let dir = cfg.dir.clone();
        let segment_bytes = cfg.segment_bytes;
        let writer = thread::Builder::new()
            .name("obs-archive".into())
            .spawn(move || spooler_loop(rx, give, dir, segment_bytes))?;
        let flush = cfg.flush_events.max(1);
        Ok(ArchiveSpool {
            cfg,
            pool: BufferPool::new(),
            bin,
            tx: Some(tx),
            writer: Some(writer),
            cur: Vec::with_capacity(flush),
            outstanding: 0,
            prev_pushed: Vec::new(),
            spooled: 0,
            dropped_ring: 0,
            dropped_spool: 0,
        })
    }

    /// Copy every ring's un-spooled tail into the batch buffer —
    /// non-destructive and O(events pushed since the last drain). Call
    /// at a cadence faster than a ring wraps (the engine drains per
    /// instant, the service per δ interval); anything a ring evicted
    /// between drains is counted into `dropped_ring`.
    pub fn drain(&mut self, plane: &ObsPlane) {
        let rings = plane.rings();
        if self.prev_pushed.len() < rings.len() {
            self.prev_pushed.resize(rings.len(), 0);
        }
        for (i, r) in rings.iter().enumerate() {
            let pushed = r.pushed();
            let delta = pushed - self.prev_pushed[i];
            if delta == 0 {
                continue;
            }
            self.prev_pushed[i] = pushed;
            self.spooled += delta;
            let take = (delta as usize).min(r.len());
            self.dropped_ring += delta - take as u64;
            r.extend_tail_into(take, &mut self.cur);
            if self.cur.len() >= self.cfg.flush_events {
                self.flush();
            }
        }
    }

    /// Ship the current batch to the spooler; drops (counted) instead of
    /// blocking when the in-flight buffer cap is hit.
    fn flush(&mut self) {
        if self.cur.is_empty() {
            return;
        }
        self.outstanding -= self.bin.drain_into(&mut self.pool);
        let Some(tx) = self.tx.as_ref() else {
            self.dropped_spool += self.cur.len() as u64;
            self.cur.clear();
            return;
        };
        if self.outstanding >= self.cfg.max_outstanding {
            self.dropped_spool += self.cur.len() as u64;
            self.cur.clear();
            return;
        }
        let mut buf = self.pool.take();
        buf.clear();
        std::mem::swap(&mut buf, &mut self.cur);
        let n = buf.len() as u64;
        match tx.send(buf) {
            Ok(()) => self.outstanding += 1,
            Err(_) => self.dropped_spool += n, // spooler died; keep counting
        }
    }

    /// Batch buffers served from the boomerang free-list (tests/benches).
    pub fn bufs_reused(&self) -> u64 {
        self.pool.reused()
    }

    /// Flush, stop the spooler, and publish `archive.json`. Returns the
    /// final accounting (`spooled == kept + dropped_ring + dropped_spool`
    /// by construction).
    pub fn finalize(mut self) -> ArchiveStats {
        self.flush();
        drop(self.tx.take()); // closes the channel; the spooler drains and exits
        let totals = self
            .writer
            .take()
            .and_then(|h| h.join().ok())
            .unwrap_or_default();
        let stats = ArchiveStats {
            spooled: self.spooled,
            kept: totals.kept,
            dropped_ring: self.dropped_ring,
            // residual, not the live counter: also absorbs I/O-failed
            // batches and a dead spooler, keeping the invariant exact
            dropped_spool: self
                .spooled
                .saturating_sub(self.dropped_ring)
                .saturating_sub(totals.kept),
            segments: totals.segments,
            bytes: totals.bytes,
            io_errors: totals.io_errors,
        };
        let mut doc = BTreeMap::new();
        doc.insert("schema".into(), JsonValue::String("philae.obs.archive.v1".into()));
        doc.insert("event_bytes".into(), JsonValue::Number(EVENT_BYTES as f64));
        doc.insert("stats".into(), stats.to_json());
        let _ = fs::write(
            self.cfg.dir.join("archive.json"),
            JsonValue::Object(doc).to_string(),
        );
        stats
    }
}

/// What a directory replay recovered.
#[derive(Debug, Clone, Default)]
pub struct ReadOutcome {
    /// Events in `(t, seq)` order — the snapshot's representation.
    pub events: Vec<Event>,
    /// Segment files replayed.
    pub segments: u64,
    /// Torn tail records tolerated (crash mid-write).
    pub truncated: u64,
    /// Events skipped because their kind code postdates this build.
    pub unknown_kinds: u64,
    /// Bytes consumed across all segments.
    pub bytes: u64,
    /// `archive.json` stats, when present and parseable.
    pub stats: Option<ArchiveStats>,
}

impl ReadOutcome {
    /// Human-readable `philae obs <dir>` summary.
    pub fn summary(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "archive: {} events from {} segment(s), {} bytes",
            self.events.len(),
            self.segments,
            self.bytes
        );
        if self.truncated > 0 {
            let _ = writeln!(out, "  truncated tail records tolerated: {}", self.truncated);
        }
        if self.unknown_kinds > 0 {
            let _ = writeln!(out, "  events with unknown kind skipped: {}", self.unknown_kinds);
        }
        if let (Some(first), Some(last)) = (self.events.first(), self.events.last()) {
            let _ = writeln!(out, "  t span: {:.6}s – {:.6}s", first.t, last.t);
        }
        if let Some(s) = &self.stats {
            let _ = writeln!(
                out,
                "  spooled {} = kept {} + dropped_ring {} + dropped_spool {} (io_errors {})",
                s.spooled, s.kept, s.dropped_ring, s.dropped_spool, s.io_errors
            );
        }
        let mut counts = [0u64; 32];
        for e in &self.events {
            counts[e.kind.code() as usize] += 1;
        }
        for k in EventKind::all() {
            let c = counts[k.code() as usize];
            if c > 0 {
                let _ = writeln!(out, "  {:<18} {}", k.as_str(), c);
            }
        }
        out
    }
}

/// Offline replay of an archive directory.
pub struct ArchiveReader;

impl ArchiveReader {
    /// Replay every segment under `dir` (sorted by name). Torn tails are
    /// tolerated and counted; a checksum mismatch on a *complete* record
    /// is a hard error.
    pub fn read_dir(dir: &Path) -> Result<ReadOutcome> {
        let mut segs: Vec<PathBuf> = fs::read_dir(dir)
            .with_context(|| format!("open archive dir {}", dir.display()))?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with(SEG_PREFIX) && n.ends_with(SEG_SUFFIX))
                    .unwrap_or(false)
            })
            .collect();
        segs.sort();
        let mut out = ReadOutcome::default();
        for path in &segs {
            let data = fs::read(path)
                .with_context(|| format!("read archive segment {}", path.display()))?;
            out.segments += 1;
            out.bytes += data.len() as u64;
            if data.len() < MAGIC.len() {
                out.truncated += 1; // crash right after create
                continue;
            }
            if &data[..MAGIC.len()] != MAGIC {
                bail!("{}: not a philae archive segment (bad magic)", path.display());
            }
            let mut off = MAGIC.len();
            while off < data.len() {
                if data.len() - off < 4 {
                    out.truncated += 1; // torn length prefix
                    break;
                }
                let len =
                    u32::from_le_bytes(data[off..off + 4].try_into().expect("4 bytes")) as usize;
                if data.len() - off < 4 + len + 8 {
                    out.truncated += 1; // torn payload/checksum
                    break;
                }
                let payload = &data[off + 4..off + 4 + len];
                let claimed = le_u64(&data[off + 4 + len..off + 4 + len + 8]);
                if fnv1a64(payload) != claimed {
                    bail!(
                        "{}: record at byte {} failed its checksum — segment corrupt",
                        path.display(),
                        off
                    );
                }
                if len % EVENT_BYTES != 0 {
                    bail!(
                        "{}: record at byte {} has non-event-aligned length {}",
                        path.display(),
                        off,
                        len
                    );
                }
                for chunk in payload.chunks_exact(EVENT_BYTES) {
                    match decode_event(chunk) {
                        Some(e) => out.events.push(e),
                        None => out.unknown_kinds += 1,
                    }
                }
                off += 4 + len + 8;
            }
        }
        // the snapshot's total order
        out.events
            .sort_by(|x, y| x.t.total_cmp(&y.t).then(x.seq.cmp(&y.seq)));
        if let Ok(text) = fs::read_to_string(dir.join("archive.json")) {
            if let Ok(v) = JsonValue::parse(&text) {
                out.stats = v.get("stats").map(ArchiveStats::from_json);
            }
        }
        Ok(out)
    }

    /// Replay `dir` into an [`ObsSnapshot`], so every ring export — CSV,
    /// Chrome trace, `explain`/`explain_all` — works from disk unchanged.
    pub fn snapshot(dir: &Path) -> Result<ObsSnapshot> {
        let out = Self::read_dir(dir)?;
        let recorded = out.events.len() as u64;
        Ok(ObsSnapshot {
            registry: Registry::default(),
            events: out.events,
            dropped: 0,
            recorded,
            archive: out.stats,
            heatmap: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::NO_COFLOW;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("philae_arc_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn plane_with_events(n: u64, shards: u32, ring: usize) -> ObsPlane {
        let mut p = ObsPlane::new(ring);
        for i in 0..n {
            p.emit(
                i as f64 * 0.5,
                i * 10,
                (i % shards as u64) as u32,
                EventKind::all()[(i % EventKind::all().len() as u64) as usize],
                if i % 7 == 0 { NO_COFLOW } else { i },
                i * 3,
                i * 5,
            );
        }
        p
    }

    #[test]
    fn event_encoding_roundtrips_every_kind() {
        for k in EventKind::all() {
            let e = Event {
                t: -1.25,
                wall_ns: 42,
                seq: u64::MAX - 1,
                shard: 3,
                kind: *k,
                coflow: NO_COFLOW,
                a: 7,
                b: u64::MAX,
            };
            let mut buf = Vec::new();
            encode_event(&e, &mut buf);
            assert_eq!(buf.len(), EVENT_BYTES);
            assert_eq!(decode_event(&buf), Some(e));
            assert_eq!(EventKind::from_code(k.code()), Some(*k));
        }
        assert_eq!(EventKind::from_code(200), None);
    }

    #[test]
    fn spool_roundtrip_matches_snapshot_on_drop_free_run() {
        let dir = tmp_dir("roundtrip");
        let plane = {
            let p = plane_with_events(500, 3, 1 << 12);
            let mut cfg = ArchiveConfig::new(&dir);
            cfg.flush_events = 64;
            let mut spool = ArchiveSpool::new(cfg).expect("spool");
            spool.drain(&p);
            let stats = spool.finalize();
            assert_eq!(stats.spooled, 500);
            assert_eq!(stats.kept, 500);
            assert_eq!(stats.dropped_ring, 0);
            assert_eq!(stats.dropped_spool, 0);
            assert_eq!(stats.io_errors, 0);
            assert_eq!(stats.spooled, stats.kept + stats.dropped_ring + stats.dropped_spool);
            p
        };
        let snap = plane.snapshot();
        let replay = ArchiveReader::snapshot(&dir).expect("replay");
        assert_eq!(replay.events, snap.events, "archived log == ring log");
        assert_eq!(replay.archive.expect("stats attached").kept, 500);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn incremental_drains_spool_each_tail_once() {
        let dir = tmp_dir("incr");
        let mut p = ObsPlane::new(1 << 10);
        let mut cfg = ArchiveConfig::new(&dir);
        cfg.flush_events = 16;
        let mut spool = ArchiveSpool::new(cfg).expect("spool");
        for i in 0..300u64 {
            p.emit(i as f64, 0, 0, EventKind::Arrival, i, 1, 0);
            if i % 7 == 0 {
                spool.drain(&p);
            }
        }
        spool.drain(&p);
        let stats = spool.finalize();
        assert_eq!(stats.spooled, 300);
        assert_eq!(stats.kept, 300, "every event spooled exactly once");
        let replay = ArchiveReader::read_dir(&dir).expect("replay");
        assert_eq!(replay.events.len(), 300);
        let seqs: Vec<u64> = replay.events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, (0..300).collect::<Vec<_>>(), "no duplicates, no gaps");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn ring_wrap_between_drains_is_counted_not_silent() {
        let dir = tmp_dir("ringdrop");
        let mut p = ObsPlane::new(8); // tiny ring
        let spool_cfg = ArchiveConfig::new(&dir);
        let mut spool = ArchiveSpool::new(spool_cfg).expect("spool");
        for i in 0..100u64 {
            p.emit(i as f64, 0, 0, EventKind::Arrival, i, 0, 0);
        }
        spool.drain(&p); // 100 pushed, only the newest 8 retained
        let stats = spool.finalize();
        assert_eq!(stats.spooled, 100);
        assert_eq!(stats.kept, 8);
        assert_eq!(stats.dropped_ring, 92);
        assert_eq!(stats.spooled, stats.kept + stats.dropped_ring + stats.dropped_spool);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn segments_rotate_at_the_byte_threshold() {
        let dir = tmp_dir("rotate");
        let mut cfg = ArchiveConfig::new(&dir);
        cfg.segment_bytes = 2048; // floor is 1024; a few records per segment
        cfg.flush_events = 8;
        let p = plane_with_events(400, 1, 1 << 12);
        let mut spool = ArchiveSpool::new(cfg).expect("spool");
        spool.drain(&p);
        let stats = spool.finalize();
        assert!(stats.segments > 1, "expected rotation, got {} segment(s)", stats.segments);
        let replay = ArchiveReader::read_dir(&dir).expect("replay");
        assert_eq!(replay.segments, stats.segments);
        assert_eq!(replay.events.len(), 400, "rotation loses nothing");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_tail_is_tolerated() {
        let dir = tmp_dir("trunc");
        let p = plane_with_events(200, 1, 1 << 12);
        let mut cfg = ArchiveConfig::new(&dir);
        cfg.flush_events = 50; // 4 records in one segment
        let mut spool = ArchiveSpool::new(cfg).expect("spool");
        spool.drain(&p);
        spool.finalize();
        // chop bytes off the last segment: a crash mid-write
        let seg = dir.join(format!("{SEG_PREFIX}000000{SEG_SUFFIX}"));
        let mut data = fs::read(&seg).expect("segment");
        data.truncate(data.len() - 20);
        fs::write(&seg, &data).expect("truncate");
        let replay = ArchiveReader::read_dir(&dir).expect("torn tail tolerated");
        assert_eq!(replay.truncated, 1);
        assert_eq!(replay.events.len(), 150, "only the torn record is lost");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn tampered_record_is_rejected() {
        let dir = tmp_dir("tamper");
        let p = plane_with_events(100, 1, 1 << 12);
        let mut spool = ArchiveSpool::new(ArchiveConfig::new(&dir)).expect("spool");
        spool.drain(&p);
        spool.finalize();
        let seg = dir.join(format!("{SEG_PREFIX}000000{SEG_SUFFIX}"));
        let mut data = fs::read(&seg).expect("segment");
        let mid = data.len() / 2;
        data[mid] ^= 0xFF; // bit-rot inside a complete record
        fs::write(&seg, &data).expect("tamper");
        let err = ArchiveReader::read_dir(&dir).expect_err("checksum must reject");
        assert!(err.to_string().contains("checksum"), "got: {err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_magic_is_rejected() {
        let dir = tmp_dir("magic");
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join(format!("{SEG_PREFIX}000000{SEG_SUFFIX}")), b"NOTANARC-extra")
            .unwrap();
        let err = ArchiveReader::read_dir(&dir).expect_err("magic must reject");
        assert!(err.to_string().contains("magic"), "got: {err}");
        let _ = fs::remove_dir_all(&dir);
    }
}
