//! Observability plane: metrics registry + flight recorder.
//!
//! The paper's argument is about overhead you cannot see in end-of-run CCT
//! scalars (§3: Aalo pays for size-learning in queue crossings and
//! coordinator↔agent chatter; Philae pays a pilot-sampling tax up front).
//! This module makes that time visible without taxing the paths it
//! observes:
//!
//! * [`Registry`] — counters, gauges, and log-bucketed [`LogHistogram`]s
//!   (HDR-style fixed 64×64 layout, exact p50/p90/p99/p999 tails, O(1)
//!   record, mergeable across shards/workers). Handles are dense indices,
//!   so the hot path is a single `Vec` index increment — no locks, no
//!   hashing.
//! * [`Recorder`] — a bounded ring buffer of typed lifecycle [`Event`]s
//!   (arrival, pilot start/estimate, queue transition, migration, lease
//!   reconciliation, checkpoint/restore, agent age-out/return, admission
//!   verdict/expiry, retirement), one ring per shard, oldest evicted
//!   first with a drop counter.
//! * [`ObsPlane`] — one registry + per-shard rings behind a monotone
//!   event sequence; the engine and the live service own at most one,
//!   wrapped in `Option` so the disabled state is a single branch.
//! * [`ObsSnapshot`] — the merged, time-ordered end-of-run view.
//!   Serializes to a stable JSON schema (`philae.obs.v1`), to Chrome
//!   trace-event JSON (load in Perfetto / `chrome://tracing`), to CSV,
//!   and answers the per-coflow timeline query behind `philae explain`:
//!   a CCT decomposed into waiting / sampling / scheduled / starved
//!   segments.
//!
//! Everything is in-crate (the offline image has no tracing/metrics
//! dependencies) and allocation-free on the record path once the rings
//! exist: `tests/zero_alloc.rs` pins `LogHistogram::record` and
//! `Recorder::push` at zero heap allocations.

pub mod archive;
pub mod heatmap;

pub use archive::{ArchiveConfig, ArchiveReader, ArchiveSpool, ArchiveStats};
pub use heatmap::Heatmap;

use crate::util::JsonValue;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Sub-buckets per power-of-two group (6 significant bits ⇒ ≤ 1/64
/// relative quantization error; values below 128 ns are exact).
const SUB: usize = 64;
/// Power-of-two groups (group 0 is the exact 0..64 range).
const GROUPS: usize = 64;

/// Log-bucketed latency histogram over `u64` nanoseconds.
///
/// Layout: group 0 holds values `0..64` exactly; group `g ≥ 1` holds
/// values whose most significant bit is `g + 5`, split into 64 linear
/// sub-buckets — the classic HDR shape with a fixed 64×64 table (32 KiB),
/// so `record` is two shifts and an add, and two histograms merge by
/// element-wise addition. Percentiles are nearest-rank over the bucket
/// counts, clamped to the recorded min/max so p0/p100 are exact.
#[derive(Clone)]
pub struct LogHistogram {
    counts: Box<[u64]>,
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for LogHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LogHistogram")
            .field("count", &self.count)
            .field("min", &self.min)
            .field("max", &self.max)
            .field("p50", &self.percentile(0.50))
            .field("p99", &self.percentile(0.99))
            .finish()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram {
            counts: vec![0u64; SUB * GROUPS].into_boxed_slice(),
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Bucket index of `v`: group = position of the highest set bit,
    /// sub-bucket = the next 6 bits.
    #[inline]
    fn index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros(); // ≥ 6 here
        let g = (msb - 5) as usize;
        let sub = ((v >> (msb - 6)) & 63) as usize;
        g * SUB + sub
    }

    /// Lower edge of bucket `i` (the reported representative value).
    #[inline]
    fn bucket_value(i: usize) -> u64 {
        let (g, sub) = (i / SUB, (i % SUB) as u64);
        if g == 0 {
            sub
        } else {
            (SUB as u64 + sub) << (g - 1)
        }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    /// Record a duration in seconds (stored as whole nanoseconds).
    #[inline]
    pub fn record_secs(&mut self, s: f64) {
        self.record((s.max(0.0) * 1e9).round() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean of recorded values (ns); 0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Nearest-rank percentile, `q ∈ [0, 1]`. Exact for values < 128;
    /// within 1/64 relative error above. p0 and p100 return the exact
    /// recorded min/max.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        if rank == self.count {
            return self.max;
        }
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Self::bucket_value(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Percentile converted back to seconds.
    pub fn percentile_secs(&self, q: f64) -> f64 {
        self.percentile(q) as f64 / 1e9
    }

    /// Element-wise merge (shard/worker → global roll-up).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    fn to_json(&self) -> JsonValue {
        let mut o = BTreeMap::new();
        o.insert("count".into(), JsonValue::Number(self.count as f64));
        o.insert(
            "min_ns".into(),
            JsonValue::Number(if self.count == 0 { 0.0 } else { self.min as f64 }),
        );
        o.insert("max_ns".into(), JsonValue::Number(self.max as f64));
        o.insert("mean_ns".into(), JsonValue::Number(self.mean()));
        for (name, q) in [("p50", 0.50), ("p90", 0.90), ("p99", 0.99), ("p999", 0.999)] {
            o.insert(format!("{name}_ns"), JsonValue::Number(self.percentile(q) as f64));
        }
        JsonValue::Object(o)
    }
}

/// Gauge: last written value plus the running maximum.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Gauge {
    pub last: f64,
    pub max: f64,
    /// Whether the gauge was ever written (distinguishes "0" from "unset").
    pub set: bool,
}

/// Dense handle into a [`Registry`] counter (O(1) hot-path increment).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterId(usize);
/// Dense handle into a [`Registry`] gauge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GaugeId(usize);
/// Dense handle into a [`Registry`] histogram.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistId(usize);

/// Per-shard/worker metrics registry. Handles are resolved once (by name,
/// at setup) and the hot path indexes a `Vec` — no locks, no hashing.
/// Shard registries merge by metric name at snapshot time.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    counters: Vec<(String, u64)>,
    gauges: Vec<(String, Gauge)>,
    hists: Vec<(String, LogHistogram)>,
}

impl Registry {
    /// Find-or-create a counter handle.
    pub fn counter(&mut self, name: &str) -> CounterId {
        if let Some(i) = self.counters.iter().position(|(n, _)| n == name) {
            return CounterId(i);
        }
        self.counters.push((name.to_string(), 0));
        CounterId(self.counters.len() - 1)
    }

    #[inline]
    pub fn inc(&mut self, id: CounterId, by: u64) {
        self.counters[id.0].1 += by;
    }

    pub fn counter_value(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    }

    /// Find-or-create a gauge handle.
    pub fn gauge(&mut self, name: &str) -> GaugeId {
        if let Some(i) = self.gauges.iter().position(|(n, _)| n == name) {
            return GaugeId(i);
        }
        self.gauges.push((name.to_string(), Gauge::default()));
        GaugeId(self.gauges.len() - 1)
    }

    #[inline]
    pub fn set_gauge(&mut self, id: GaugeId, v: f64) {
        let g = &mut self.gauges[id.0].1;
        g.last = v;
        if !g.set || v > g.max {
            g.max = v;
        }
        g.set = true;
    }

    pub fn gauge_value(&self, name: &str) -> Option<Gauge> {
        self.gauges.iter().find(|(n, _)| n == name).map(|(_, g)| *g)
    }

    /// Find-or-create a histogram handle.
    pub fn hist(&mut self, name: &str) -> HistId {
        if let Some(i) = self.hists.iter().position(|(n, _)| n == name) {
            return HistId(i);
        }
        self.hists.push((name.to_string(), LogHistogram::new()));
        HistId(self.hists.len() - 1)
    }

    #[inline]
    pub fn observe(&mut self, id: HistId, ns: u64) {
        self.hists[id.0].1.record(ns);
    }

    #[inline]
    pub fn observe_secs(&mut self, id: HistId, s: f64) {
        self.hists[id.0].1.record_secs(s);
    }

    pub fn hist_named(&self, name: &str) -> Option<&LogHistogram> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// Merge another registry by metric name: counters add, gauges keep
    /// the other's last write and the max of maxima, histograms merge
    /// bucket-wise.
    pub fn merge(&mut self, other: &Registry) {
        for (name, v) in &other.counters {
            let id = self.counter(name);
            self.inc(id, *v);
        }
        for (name, g) in &other.gauges {
            let id = self.gauge(name);
            let mine = &mut self.gauges[id.0].1;
            if g.set {
                mine.last = g.last;
                if !mine.set || g.max > mine.max {
                    mine.max = g.max;
                }
                mine.set = true;
            }
        }
        for (name, h) in &other.hists {
            let id = self.hist(name);
            self.hists[id.0].1.merge(h);
        }
    }

    pub fn to_json(&self) -> JsonValue {
        let mut counters = BTreeMap::new();
        for (n, v) in &self.counters {
            counters.insert(n.clone(), JsonValue::Number(*v as f64));
        }
        let mut gauges = BTreeMap::new();
        for (n, g) in &self.gauges {
            let mut o = BTreeMap::new();
            o.insert("last".into(), JsonValue::Number(g.last));
            o.insert("max".into(), JsonValue::Number(g.max));
            gauges.insert(n.clone(), JsonValue::Object(o));
        }
        let mut hists = BTreeMap::new();
        for (n, h) in &self.hists {
            hists.insert(n.clone(), h.to_json());
        }
        let mut root = BTreeMap::new();
        root.insert("counters".into(), JsonValue::Object(counters));
        root.insert("gauges".into(), JsonValue::Object(gauges));
        root.insert("histograms".into(), JsonValue::Object(hists));
        JsonValue::Object(root)
    }
}

/// `Event::coflow` value for events not tied to a coflow.
pub const NO_COFLOW: u64 = u64::MAX;

/// Typed lifecycle events — the flight recorder's vocabulary. The `a`/`b`
/// payload words are kind-specific (see `docs/OBSERVABILITY.md`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Coflow admitted. `a` = flow count.
    Arrival,
    /// Pilot sampling began (Philae). `a` = pilot flow count.
    PilotStart,
    /// Size estimate produced from completed samples. `a` = estimated bytes.
    Estimate,
    /// Coflow phase changed. `a` = new phase (0 piloting, 1 running, 2 done).
    Phase,
    /// Priority queue / lane changed (Aalo MLFQ, dcoflow lanes). `a` = new queue.
    QueueChange,
    /// Coflow started receiving rate (allocated > 0 after having none).
    Scheduled,
    /// Coflow stopped receiving rate while unfinished (preempted/backlogged).
    Starved,
    /// One flow physically finished. `a` = flow seq, `b` = bytes.
    FlowComplete,
    /// Last flow finished; CCT is closed. `b` = total bytes.
    CoflowComplete,
    /// Streaming retirement reclaimed the coflow's heavy state.
    Retire,
    /// Cluster moved the coflow between shards. `a` = from, `b` = to.
    Migration,
    /// Demand-weighted lease reconciliation ran. `a` = shard count.
    LeaseReconcile,
    /// Scheduler checkpoint sealed. `a` = checkpoint ordinal, `b` = wall ns.
    Checkpoint,
    /// Scheduler killed and restored from a checkpoint. `a` = restore
    /// ordinal, `b` = wall ns spent restoring.
    Restore,
    /// Agent watchdog masked a silent port out of the plan. `a` = port.
    AgentAgeOut,
    /// A previously aged-out port reported again and rejoined. `a` = port.
    AgentReturn,
    /// Deadline admission decided. `a` = admitted delta, `b` = rejected delta.
    AdmissionVerdict,
    /// Admission certificates expired. `a` = expired delta.
    AdmissionExpiry,
    /// The live service retargeted its δ tick (adaptive cadence).
    /// `a` = new period ns, `b` = previous period ns.
    TickAdjust,
}

impl EventKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::Arrival => "arrival",
            EventKind::PilotStart => "pilot_start",
            EventKind::Estimate => "estimate",
            EventKind::Phase => "phase",
            EventKind::QueueChange => "queue_change",
            EventKind::Scheduled => "scheduled",
            EventKind::Starved => "starved",
            EventKind::FlowComplete => "flow_complete",
            EventKind::CoflowComplete => "coflow_complete",
            EventKind::Retire => "retire",
            EventKind::Migration => "migration",
            EventKind::LeaseReconcile => "lease_reconcile",
            EventKind::Checkpoint => "checkpoint",
            EventKind::Restore => "restore",
            EventKind::AgentAgeOut => "agent_age_out",
            EventKind::AgentReturn => "agent_return",
            EventKind::AdmissionVerdict => "admission_verdict",
            EventKind::AdmissionExpiry => "admission_expiry",
            EventKind::TickAdjust => "tick_adjust",
        }
    }

    /// Every kind, in wire-code order (summaries, CLI filters).
    pub fn all() -> &'static [EventKind] {
        &[
            EventKind::Arrival,
            EventKind::PilotStart,
            EventKind::Estimate,
            EventKind::Phase,
            EventKind::QueueChange,
            EventKind::Scheduled,
            EventKind::Starved,
            EventKind::FlowComplete,
            EventKind::CoflowComplete,
            EventKind::Retire,
            EventKind::Migration,
            EventKind::LeaseReconcile,
            EventKind::Checkpoint,
            EventKind::Restore,
            EventKind::AgentAgeOut,
            EventKind::AgentReturn,
            EventKind::AdmissionVerdict,
            EventKind::AdmissionExpiry,
            EventKind::TickAdjust,
        ]
    }

    /// Stable on-disk code (`obs/archive.rs` segment records). Codes are
    /// append-only: a new kind takes the next free value, existing codes
    /// never change, so old archives stay readable.
    pub fn code(&self) -> u8 {
        match self {
            EventKind::Arrival => 0,
            EventKind::PilotStart => 1,
            EventKind::Estimate => 2,
            EventKind::Phase => 3,
            EventKind::QueueChange => 4,
            EventKind::Scheduled => 5,
            EventKind::Starved => 6,
            EventKind::FlowComplete => 7,
            EventKind::CoflowComplete => 8,
            EventKind::Retire => 9,
            EventKind::Migration => 10,
            EventKind::LeaseReconcile => 11,
            EventKind::Checkpoint => 12,
            EventKind::Restore => 13,
            EventKind::AgentAgeOut => 14,
            EventKind::AgentReturn => 15,
            EventKind::AdmissionVerdict => 16,
            EventKind::AdmissionExpiry => 17,
            EventKind::TickAdjust => 18,
        }
    }

    /// Inverse of [`EventKind::code`]; `None` for unknown codes (an
    /// archive written by a newer build).
    pub fn from_code(c: u8) -> Option<EventKind> {
        Self::all().get(c as usize).copied()
    }

    /// Parse the `as_str` spelling (CLI `--kind` filters).
    pub fn parse(s: &str) -> Option<EventKind> {
        Self::all().iter().copied().find(|k| k.as_str() == s)
    }
}

/// One recorded lifecycle event. Fixed-size and `Copy`, so the ring
/// buffer is a flat array and recording is a store.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Simulated time (seconds). In the live service this is the scaled
    /// service clock (`sim_now`).
    pub t: f64,
    /// Wall-clock nanoseconds since plane creation (0 in pure simulation).
    pub wall_ns: u64,
    /// Monotone sequence across the whole plane — the total order for
    /// same-instant events.
    pub seq: u64,
    /// Emitting shard (0 on single-coordinator paths).
    pub shard: u32,
    pub kind: EventKind,
    /// Subject coflow id, or [`NO_COFLOW`].
    pub coflow: u64,
    pub a: u64,
    pub b: u64,
}

/// Bounded ring of [`Event`]s: oldest entries are overwritten once the
/// ring is full, with an eviction counter so a snapshot is honest about
/// what it lost.
#[derive(Debug, Clone)]
pub struct Recorder {
    buf: Vec<Event>,
    cap: usize,
    head: usize,
    dropped: u64,
}

impl Recorder {
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Recorder { buf: Vec::with_capacity(cap), cap, head: 0, dropped: 0 }
    }

    #[inline]
    pub fn push(&mut self, e: Event) {
        if self.buf.len() < self.cap {
            self.buf.push(e);
        } else {
            self.buf[self.head] = e;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Total events ever pushed (retained + evicted) — the archive
    /// spool's per-ring drain cursor.
    pub fn pushed(&self) -> u64 {
        self.buf.len() as u64 + self.dropped
    }

    /// Append the retained events, oldest first.
    pub fn extend_into(&self, out: &mut Vec<Event>) {
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
    }

    /// Append the **newest** `n` retained events, oldest-first. The
    /// archive spool copies exactly the ring tail it has not spooled yet,
    /// so a drain is O(new events) regardless of ring size.
    pub fn extend_tail_into(&self, n: usize, out: &mut Vec<Event>) {
        let n = n.min(self.buf.len());
        // logical order is buf[head..] ++ buf[..head]; take its last n
        if n <= self.head {
            out.extend_from_slice(&self.buf[self.head - n..self.head]);
        } else {
            let from_first = n - self.head;
            out.extend_from_slice(&self.buf[self.buf.len() - from_first..]);
            out.extend_from_slice(&self.buf[..self.head]);
        }
    }
}

/// Events buffered by a coordinator frontend between engine drains:
/// `(shard, kind, coflow, a, b)` — the engine stamps time and sequence.
pub type PendingEvent = (u32, EventKind, u64, u64, u64);

/// Event consumer abstraction. [`NullSink`] is the disabled plane: every
/// call compiles to nothing, and `enabled()` lets emitters skip payload
/// construction entirely.
pub trait Sink {
    fn emit(&mut self, e: &Event);
    fn enabled(&self) -> bool {
        true
    }
}

/// The no-op sink: observability compiled away.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl Sink for NullSink {
    #[inline]
    fn emit(&mut self, _e: &Event) {}

    #[inline]
    fn enabled(&self) -> bool {
        false
    }
}

impl Sink for Recorder {
    #[inline]
    fn emit(&mut self, e: &Event) {
        self.push(*e);
    }
}

/// One registry + per-shard flight-recorder rings. Owned (at most once)
/// by the sim engine or the live coordinator; `Option<ObsPlane>` is the
/// on/off switch, so the disabled path costs one branch.
#[derive(Debug, Clone)]
pub struct ObsPlane {
    pub reg: Registry,
    rings: Vec<Recorder>,
    ring_cap: usize,
    seq: u64,
}

impl ObsPlane {
    /// `ring_cap` bounds each shard's ring (events beyond it evict the
    /// oldest).
    pub fn new(ring_cap: usize) -> Self {
        ObsPlane {
            reg: Registry::default(),
            rings: vec![Recorder::new(ring_cap)],
            ring_cap: ring_cap.max(1),
            seq: 0,
        }
    }

    /// Record one event; rings grow lazily per shard (amortized — the
    /// steady-state path is a ring store).
    #[inline]
    pub fn emit(
        &mut self,
        t: f64,
        wall_ns: u64,
        shard: u32,
        kind: EventKind,
        coflow: u64,
        a: u64,
        b: u64,
    ) {
        while self.rings.len() <= shard as usize {
            self.rings.push(Recorder::new(self.ring_cap));
        }
        let seq = self.seq;
        self.seq += 1;
        self.rings[shard as usize].push(Event { t, wall_ns, seq, shard, kind, coflow, a, b });
    }

    /// Total events ever recorded (including later-evicted ones).
    pub fn events_recorded(&self) -> u64 {
        self.seq
    }

    /// Read-only view of the per-shard rings — the archive spool's drain
    /// source (`obs/archive.rs`).
    pub fn rings(&self) -> &[Recorder] {
        &self.rings
    }

    /// Merge the shard rings into one time-ordered snapshot.
    pub fn snapshot(self) -> ObsSnapshot {
        let mut events: Vec<Event> = Vec::new();
        let mut dropped = 0u64;
        for r in &self.rings {
            r.extend_into(&mut events);
            dropped += r.dropped();
        }
        events.sort_by(|x, y| x.t.total_cmp(&y.t).then(x.seq.cmp(&y.seq)));
        ObsSnapshot {
            registry: self.reg,
            events,
            dropped,
            recorded: self.seq,
            archive: None,
            heatmap: None,
        }
    }
}

/// A CCT decomposed into where the time went.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentKind {
    /// Admitted, no rate yet, not sampling.
    Waiting,
    /// Pilot flows probing the coflow's size (Philae's learning tax).
    Sampling,
    /// Holding a non-zero aggregate rate.
    Scheduled,
    /// Lost all rate while unfinished (preempted / backlogged / masked).
    Starved,
}

impl SegmentKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            SegmentKind::Waiting => "waiting",
            SegmentKind::Sampling => "sampling",
            SegmentKind::Scheduled => "scheduled",
            SegmentKind::Starved => "starved",
        }
    }
}

/// One contiguous stretch of a coflow's lifetime in a single state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segment {
    pub kind: SegmentKind,
    pub start: f64,
    pub end: f64,
}

/// The `philae explain <cid>` answer: lifecycle segments of one coflow.
#[derive(Debug, Clone, PartialEq)]
pub struct CoflowTimeline {
    pub coflow: u64,
    pub arrival: f64,
    pub finished: Option<f64>,
    pub segments: Vec<Segment>,
}

impl CoflowTimeline {
    /// Total seconds spent in `kind`.
    pub fn total(&self, kind: SegmentKind) -> f64 {
        self.segments
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| s.end - s.start)
            .sum()
    }

    /// Human-readable per-coflow report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        match self.finished {
            Some(f) => {
                let _ = writeln!(
                    out,
                    "coflow {}: arrival t={:.6}s  completion t={:.6}s  cct {:.6}s",
                    self.coflow,
                    self.arrival,
                    f,
                    f - self.arrival
                );
            }
            None => {
                let _ = writeln!(
                    out,
                    "coflow {}: arrival t={:.6}s  (unfinished)",
                    self.coflow, self.arrival
                );
            }
        }
        let span: f64 = self.segments.iter().map(|s| s.end - s.start).sum();
        for s in &self.segments {
            let dur = s.end - s.start;
            let pct = if span > 0.0 { 100.0 * dur / span } else { 0.0 };
            let _ = writeln!(
                out,
                "  {:>12.6}s – {:<12.6}s  {:<9}  ({:.6}s, {:.1}%)",
                s.start,
                s.end,
                s.kind.as_str(),
                dur,
                pct
            );
        }
        let _ = writeln!(
            out,
            "  totals: waiting {:.6}s  sampling {:.6}s  scheduled {:.6}s  starved {:.6}s",
            self.total(SegmentKind::Waiting),
            self.total(SegmentKind::Sampling),
            self.total(SegmentKind::Scheduled),
            self.total(SegmentKind::Starved),
        );
        out
    }
}

/// Merged end-of-run observability state: the roll-up registry plus the
/// time-ordered surviving events.
#[derive(Debug, Clone)]
pub struct ObsSnapshot {
    pub registry: Registry,
    /// Time-ordered (then sequence-ordered) events that survived the rings.
    pub events: Vec<Event>,
    /// Events evicted by ring wraparound.
    pub dropped: u64,
    /// Events ever recorded (`events.len() + dropped`).
    pub recorded: u64,
    /// Durable-archive accounting when the spool was armed
    /// (`obs/archive.rs`); `None` on ring-only runs.
    pub archive: Option<ArchiveStats>,
    /// Per-port utilization heatmap when armed (`obs/heatmap.rs`).
    pub heatmap: Option<Heatmap>,
}

impl ObsSnapshot {
    /// Stable JSON schema (`philae.obs.v1`): registry + event log.
    pub fn to_json(&self) -> JsonValue {
        let mut root = BTreeMap::new();
        root.insert("schema".into(), JsonValue::String("philae.obs.v1".into()));
        root.insert("registry".into(), self.registry.to_json());
        let mut meta = BTreeMap::new();
        meta.insert("recorded".into(), JsonValue::Number(self.recorded as f64));
        meta.insert("kept".into(), JsonValue::Number(self.events.len() as f64));
        meta.insert("dropped".into(), JsonValue::Number(self.dropped as f64));
        root.insert("events".into(), JsonValue::Object(meta));
        if let Some(a) = &self.archive {
            root.insert("archive".into(), a.to_json());
        }
        let log: Vec<JsonValue> = self
            .events
            .iter()
            .map(|e| {
                let mut o = BTreeMap::new();
                o.insert("t".into(), JsonValue::Number(e.t));
                o.insert("wall_ns".into(), JsonValue::Number(e.wall_ns as f64));
                o.insert("seq".into(), JsonValue::Number(e.seq as f64));
                o.insert("shard".into(), JsonValue::Number(e.shard as f64));
                o.insert("kind".into(), JsonValue::String(e.kind.as_str().into()));
                if e.coflow != NO_COFLOW {
                    o.insert("coflow".into(), JsonValue::Number(e.coflow as f64));
                }
                o.insert("a".into(), JsonValue::Number(e.a as f64));
                o.insert("b".into(), JsonValue::Number(e.b as f64));
                JsonValue::Object(o)
            })
            .collect();
        root.insert("event_log".into(), JsonValue::Array(log));
        JsonValue::Object(root)
    }

    /// CSV export: one event per line, header included.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("seq,t,wall_ns,shard,kind,coflow,a,b\n");
        for e in &self.events {
            let cid = if e.coflow == NO_COFLOW {
                String::new()
            } else {
                e.coflow.to_string()
            };
            let _ = writeln!(
                out,
                "{},{},{},{},{},{},{},{}",
                e.seq,
                e.t,
                e.wall_ns,
                e.shard,
                e.kind.as_str(),
                cid,
                e.a,
                e.b
            );
        }
        out
    }

    /// Per-coflow timelines for every coflow with events in the log.
    pub fn timelines(&self) -> Vec<CoflowTimeline> {
        self.explain_all()
    }

    /// Fleet-wide CCT decomposition — every coflow's timeline in one
    /// pass, ordered by coflow id. The events are stably re-sorted by
    /// coflow (preserving the `(t, seq)` order within each) and the
    /// segment state machine runs once per contiguous chunk: O(n log n)
    /// total, where the per-coflow `explain` rescan would be
    /// O(n × coflows) — prohibitive on million-coflow archives.
    pub fn explain_all(&self) -> Vec<CoflowTimeline> {
        let mut by_coflow: Vec<&Event> =
            self.events.iter().filter(|e| e.coflow != NO_COFLOW).collect();
        by_coflow.sort_by(|a, b| a.coflow.cmp(&b.coflow)); // stable sort
        let last_t = self.events.last().map(|e| e.t).unwrap_or(0.0);
        let mut out = Vec::new();
        let mut i = 0;
        while i < by_coflow.len() {
            let cid = by_coflow[i].coflow;
            let mut j = i;
            while j < by_coflow.len() && by_coflow[j].coflow == cid {
                j += 1;
            }
            if let Some(tl) = explain_events(cid, by_coflow[i..j].iter().copied(), last_t) {
                out.push(tl);
            }
            i = j;
        }
        out
    }

    /// `philae explain --all` CSV: one row per coflow with the CCT and
    /// its waiting / sampling / scheduled / starved totals (seconds).
    /// `finished`/`cct` are empty for coflows still open in the log.
    pub fn explain_all_csv(&self) -> String {
        let mut out =
            String::from("coflow,arrival,finished,cct,waiting,sampling,scheduled,starved\n");
        for tl in self.explain_all() {
            let (fin, cct) = match tl.finished {
                Some(f) => (f.to_string(), (f - tl.arrival).to_string()),
                None => (String::new(), String::new()),
            };
            let _ = writeln!(
                out,
                "{},{},{},{},{},{},{},{}",
                tl.coflow,
                tl.arrival,
                fin,
                cct,
                tl.total(SegmentKind::Waiting),
                tl.total(SegmentKind::Sampling),
                tl.total(SegmentKind::Scheduled),
                tl.total(SegmentKind::Starved),
            );
        }
        out
    }

    /// The `philae explain <cid>` query: replay the coflow's events into
    /// waiting / sampling / scheduled / starved segments. `None` when the
    /// log holds no events for `cid` (e.g. evicted by ring wraparound).
    pub fn explain(&self, cid: u64) -> Option<CoflowTimeline> {
        let last_t = self.events.last().map(|e| e.t).unwrap_or(0.0);
        explain_events(cid, self.events.iter().filter(|e| e.coflow == cid), last_t)
    }

    /// Chrome trace-event JSON (load in Perfetto or `chrome://tracing`):
    /// per-coflow lifecycle segments as complete spans on pid 1 (tid =
    /// coflow id), coordination-plane events (migration, reconciliation,
    /// checkpoint/restore, agent watchdog, admission) on pid 0 (tid =
    /// shard). Timestamps are sim-time microseconds.
    pub fn chrome_trace_json(&self) -> String {
        let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
        let mut first = true;
        let mut push = |out: &mut String, first: &mut bool, ev: String| {
            if !*first {
                out.push(',');
            }
            *first = false;
            out.push_str(&ev);
        };
        for tl in self.timelines() {
            for s in &tl.segments {
                let dur_us = ((s.end - s.start) * 1e6).max(0.001);
                push(
                    &mut out,
                    &mut first,
                    format!(
                        "{{\"name\":\"{}\",\"cat\":\"coflow\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{},\"args\":{{\"coflow\":{}}}}}",
                        s.kind.as_str(),
                        s.start * 1e6,
                        dur_us,
                        tl.coflow,
                        tl.coflow
                    ),
                );
            }
        }
        for e in &self.events {
            let span = matches!(
                e.kind,
                EventKind::Migration | EventKind::Checkpoint | EventKind::Restore
            );
            let instant = matches!(
                e.kind,
                EventKind::LeaseReconcile
                    | EventKind::AgentAgeOut
                    | EventKind::AgentReturn
                    | EventKind::AdmissionVerdict
                    | EventKind::AdmissionExpiry
                    | EventKind::TickAdjust
            );
            if span {
                // wall duration (b, ns) when measured; 1 µs floor so the
                // span stays visible at sim-instant resolution
                let dur_us = (e.b as f64 / 1e3).max(1.0);
                push(
                    &mut out,
                    &mut first,
                    format!(
                        "{{\"name\":\"{}\",\"cat\":\"coordination\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":0,\"tid\":{},\"args\":{{\"coflow\":{},\"a\":{},\"b\":{}}}}}",
                        e.kind.as_str(),
                        e.t * 1e6,
                        dur_us,
                        e.shard,
                        e.coflow as i64,
                        e.a,
                        e.b
                    ),
                );
            } else if instant {
                push(
                    &mut out,
                    &mut first,
                    format!(
                        "{{\"name\":\"{}\",\"cat\":\"coordination\",\"ph\":\"i\",\"s\":\"g\",\"ts\":{},\"pid\":0,\"tid\":{},\"args\":{{\"a\":{},\"b\":{}}}}}",
                        e.kind.as_str(),
                        e.t * 1e6,
                        e.shard,
                        e.a,
                        e.b
                    ),
                );
            }
        }
        out.push_str("]}");
        out
    }
}

/// The segment state machine shared by [`ObsSnapshot::explain`] and
/// [`ObsSnapshot::explain_all`]: replay one coflow's events (in `(t, seq)`
/// order) into contiguous waiting / sampling / scheduled / starved
/// segments. `last_t` closes the open segment of an unfinished coflow at
/// the log's final event time.
fn explain_events<'a, I>(cid: u64, events: I, last_t: f64) -> Option<CoflowTimeline>
where
    I: IntoIterator<Item = &'a Event>,
{
    let mut sampling = false;
    // None until the first Scheduled/Starved verdict lands.
    let mut rate: Option<bool> = None;
    let label = |sampling: bool, rate: Option<bool>| -> SegmentKind {
        match (rate, sampling) {
            (Some(true), _) => SegmentKind::Scheduled,
            (_, true) => SegmentKind::Sampling,
            (Some(false), _) => SegmentKind::Starved,
            _ => SegmentKind::Waiting,
        }
    };
    let mut tl: Option<CoflowTimeline> = None;
    let mut seg_start = 0.0f64;
    let mut cur = SegmentKind::Waiting;
    for e in events {
        if tl.is_none() {
            // the first event opens the timeline (normally Arrival)
            tl = Some(CoflowTimeline {
                coflow: cid,
                arrival: e.t,
                finished: None,
                segments: Vec::new(),
            });
            seg_start = e.t;
        }
        match e.kind {
            EventKind::PilotStart => sampling = true,
            EventKind::Estimate => sampling = false,
            EventKind::Phase => sampling = e.a == 0,
            EventKind::Scheduled => rate = Some(true),
            EventKind::Starved => rate = Some(false),
            EventKind::CoflowComplete => {
                let tl = tl.as_mut().expect("timeline opened above");
                if e.t > seg_start {
                    tl.segments.push(Segment { kind: cur, start: seg_start, end: e.t });
                }
                tl.finished = Some(e.t);
                return Some(tl.clone());
            }
            _ => {}
        }
        let next = label(sampling, rate);
        if next != cur {
            let tl = tl.as_mut().expect("timeline opened above");
            if e.t > seg_start {
                tl.segments.push(Segment { kind: cur, start: seg_start, end: e.t });
            }
            seg_start = e.t;
            cur = next;
        }
    }
    // unfinished coflow: close the open segment at the last event time
    let mut tl = tl?;
    if last_t > seg_start {
        tl.segments.push(Segment { kind: cur, start: seg_start, end: last_t });
    }
    Some(tl)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_small_values_are_exact() {
        let mut h = LogHistogram::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        // nearest-rank on 100 samples of 1..=100: rank = ceil(q·100)
        assert_eq!(h.percentile(0.50), 50);
        assert_eq!(h.percentile(0.90), 90);
        assert_eq!(h.percentile(0.99), 99);
        assert_eq!(h.percentile(0.999), 100);
        assert_eq!(h.percentile(0.0), 1);
        assert_eq!(h.percentile(1.0), 100);
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_large_values_within_relative_error() {
        let mut h = LogHistogram::new();
        let vals: [u64; 5] = [1_000, 50_000, 1_000_000, 123_456_789, 9_876_543_210];
        for &v in &vals {
            h.record(v);
        }
        for (i, &v) in vals.iter().enumerate() {
            let q = (i as f64 + 1.0) / vals.len() as f64 - 1e-9;
            let got = h.percentile(q);
            let err = (got as f64 - v as f64).abs() / v as f64;
            assert!(err <= 1.0 / 64.0, "value {v}: got {got} (rel err {err})");
        }
        // extremes exact
        assert_eq!(h.percentile(0.0), 1_000);
        assert_eq!(h.percentile(1.0), 9_876_543_210);
    }

    #[test]
    fn histogram_merge_equals_combined_recording() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut all = LogHistogram::new();
        for v in 0..500u64 {
            let x = v * v * 31 + 7;
            if v % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
            all.record(x);
        }
        a.merge(&b);
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0] {
            assert_eq!(a.percentile(q), all.percentile(q), "q={q}");
        }
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn histogram_record_secs_roundtrip() {
        let mut h = LogHistogram::new();
        h.record_secs(0.000_25); // 250 µs
        let p = h.percentile_secs(0.5);
        assert!((p - 0.000_25).abs() / 0.000_25 <= 1.0 / 64.0, "got {p}");
    }

    #[test]
    fn ring_wraparound_keeps_newest() {
        let mut r = Recorder::new(4);
        for i in 0..6u64 {
            r.push(Event {
                t: i as f64,
                wall_ns: 0,
                seq: i,
                shard: 0,
                kind: EventKind::Arrival,
                coflow: i,
                a: 0,
                b: 0,
            });
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 2);
        let mut out = Vec::new();
        r.extend_into(&mut out);
        let seqs: Vec<u64> = out.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![2, 3, 4, 5], "oldest evicted, order preserved");
    }

    #[test]
    fn registry_merge_by_name() {
        let mut a = Registry::default();
        let c = a.counter("x");
        a.inc(c, 3);
        let g = a.gauge("depth");
        a.set_gauge(g, 2.0);
        a.set_gauge(g, 1.0);
        let mut b = Registry::default();
        let c2 = b.counter("x");
        b.inc(c2, 4);
        let c3 = b.counter("y");
        b.inc(c3, 1);
        let g2 = b.gauge("depth");
        b.set_gauge(g2, 5.0);
        a.merge(&b);
        assert_eq!(a.counter_value("x"), 7);
        assert_eq!(a.counter_value("y"), 1);
        let g = a.gauge_value("depth").unwrap();
        assert_eq!(g.last, 5.0);
        assert_eq!(g.max, 5.0);
    }

    #[test]
    fn null_sink_is_disabled() {
        let mut s = NullSink;
        assert!(!s.enabled());
        s.emit(&Event {
            t: 0.0,
            wall_ns: 0,
            seq: 0,
            shard: 0,
            kind: EventKind::Arrival,
            coflow: 0,
            a: 0,
            b: 0,
        });
        let mut r = Recorder::new(2);
        assert!(Sink::enabled(&r));
        r.emit(&Event {
            t: 0.0,
            wall_ns: 0,
            seq: 0,
            shard: 0,
            kind: EventKind::Arrival,
            coflow: 0,
            a: 0,
            b: 0,
        });
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn plane_snapshot_orders_across_shards() {
        let mut p = ObsPlane::new(16);
        p.emit(2.0, 0, 1, EventKind::Migration, 7, 1, 0);
        p.emit(1.0, 0, 0, EventKind::Arrival, 7, 1, 0);
        p.emit(2.0, 0, 0, EventKind::LeaseReconcile, NO_COFLOW, 2, 0);
        let snap = p.snapshot();
        assert_eq!(snap.recorded, 3);
        assert_eq!(snap.events.len(), 3);
        assert_eq!(snap.events[0].kind, EventKind::Arrival);
        // same t: plane sequence breaks the tie (Migration was emitted first)
        assert_eq!(snap.events[1].kind, EventKind::Migration);
        assert_eq!(snap.events[2].kind, EventKind::LeaseReconcile);
    }

    fn ev(t: f64, kind: EventKind, coflow: u64, a: u64) -> Event {
        Event { t, wall_ns: 0, seq: 0, shard: 0, kind, coflow, a, b: 0 }
    }

    #[test]
    fn explain_decomposes_lifecycle() {
        let mut events = vec![
            ev(1.0, EventKind::Arrival, 5, 4),
            ev(1.0, EventKind::PilotStart, 5, 1),
            ev(2.0, EventKind::Estimate, 5, 1000),
            ev(2.0, EventKind::Phase, 5, 1),
            ev(2.0, EventKind::Scheduled, 5, 0),
            ev(3.0, EventKind::Starved, 5, 0),
            ev(4.0, EventKind::Scheduled, 5, 0),
            ev(5.0, EventKind::CoflowComplete, 5, 0),
        ];
        for (i, e) in events.iter_mut().enumerate() {
            e.seq = i as u64;
        }
        let snap = ObsSnapshot {
            registry: Registry::default(),
            events,
            dropped: 0,
            recorded: 8,
            archive: None,
            heatmap: None,
        };
        let tl = snap.explain(5).expect("coflow 5 has events");
        assert_eq!(tl.arrival, 1.0);
        assert_eq!(tl.finished, Some(5.0));
        let kinds: Vec<SegmentKind> = tl.segments.iter().map(|s| s.kind).collect();
        assert_eq!(
            kinds,
            vec![
                SegmentKind::Sampling,
                SegmentKind::Scheduled,
                SegmentKind::Starved,
                SegmentKind::Scheduled
            ]
        );
        assert!((tl.total(SegmentKind::Sampling) - 1.0).abs() < 1e-12);
        assert!((tl.total(SegmentKind::Scheduled) - 2.0).abs() < 1e-12);
        assert!((tl.total(SegmentKind::Starved) - 1.0).abs() < 1e-12);
        assert!(snap.explain(99).is_none());
        // the rendered report mentions every state with its share
        let text = tl.render();
        assert!(text.contains("cct 4.0"));
        assert!(text.contains("sampling"));
        assert!(text.contains("starved"));
    }

    #[test]
    fn snapshot_json_is_parseable_and_stable() {
        let mut p = ObsPlane::new(8);
        let c = p.reg.counter("sim.rate_calcs");
        p.reg.inc(c, 42);
        let h = p.reg.hist("calc_ns");
        p.reg.observe(h, 100);
        p.emit(0.5, 0, 0, EventKind::Arrival, 1, 2, 0);
        let snap = p.snapshot();
        let json = snap.to_json().to_string();
        let v = JsonValue::parse(&json).expect("self-produced JSON parses");
        assert_eq!(
            v.get("schema").and_then(|s| s.as_str()),
            Some("philae.obs.v1")
        );
        let reg = v.get("registry").expect("registry");
        assert_eq!(
            reg.get("counters").and_then(|c| c.get("sim.rate_calcs")).and_then(|n| n.as_f64()),
            Some(42.0)
        );
        assert_eq!(
            v.get("events").and_then(|e| e.get("kept")).and_then(|n| n.as_f64()),
            Some(1.0)
        );
        // CSV + chrome exports stay well-formed
        let csv = snap.to_csv();
        assert!(csv.starts_with("seq,t,wall_ns,shard,kind,coflow,a,b\n"));
        assert_eq!(csv.lines().count(), 2);
        let chrome = snap.chrome_trace_json();
        assert!(JsonValue::parse(&chrome).is_ok(), "chrome trace must be valid JSON");
    }
}
