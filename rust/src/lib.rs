//! # Philae — sampling-based online coflow scheduling
//!
//! Reproduction of *“A Case for Sampling Based Learning Techniques in Coflow
//! Scheduling”* (Jajoo, Hu, Lin — CS.DC 2021; extended Philae, USENIX ATC'19).
//!
//! The crate is the **Layer-3 rust coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** — the coflow schedulers (Philae, Aalo, SEBF, SCF,
//!   FIFO, Saath-like, error-correction variants), the non-blocking-fabric
//!   flow simulator, the trace toolkit, the tokio coordinator service with
//!   local agents, and the metrics/analysis used to regenerate every table
//!   and figure of the paper.
//! * **L2 (python/compile/model.py)** — the JAX scoring graph (sampling
//!   estimator + bootstrap LCB + contention), AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the batched
//!   estimator and the MXU-friendly contention matmul.
//!
//! Python never runs on the scheduling path: `runtime::Engine` loads the
//! AOT artifacts via PJRT (`xla` crate) once at startup.
//!
//! ## Quickstart
//!
//! ```no_run
//! use philae::trace::TraceSpec;
//! use philae::sim::Simulation;
//! use philae::coordinator::{SchedulerKind, SchedulerConfig};
//!
//! let trace = TraceSpec::fb_like(150, 526).seed(7).generate();
//! let philae = Simulation::run(&trace, SchedulerKind::Philae, &SchedulerConfig::default());
//! let aalo = Simulation::run(&trace, SchedulerKind::Aalo, &SchedulerConfig::default());
//! println!("avg CCT speedup: {:.2}x", aalo.avg_cct() / philae.avg_cct());
//! ```

// CI runs clippy with `-D warnings` over --all-targets. The idiom
// allowances (explicit indexed loops for split borrows, many-knob config
// structs, …) live in Cargo.toml's `[lints.clippy]` table — the single
// source that also covers tests and benches.

pub mod agents;
pub mod analysis;
pub mod coflow;
pub mod coordinator;
pub mod fabric;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod trace;
pub mod util;

/// Simulation time in seconds.
pub type Time = f64;
/// Bytes (sizes, progress).
pub type Bytes = f64;
/// Network port index (a machine's uplink+downlink pair).
pub type PortId = usize;
/// Coflow identifier (dense index into the trace).
pub type CoflowId = usize;
/// Flow identifier (dense index, global across the trace).
pub type FlowId = usize;

/// 1 MB in bytes — trace flow sizes are specified in MB.
pub const MB: f64 = 1.0e6;
/// Default port line rate: 1 Gbps in bytes/sec (the paper's Azure NICs).
pub const GBPS: f64 = 125.0e6;
/// Epsilon for progress/size comparisons in the flow simulator.
pub const EPS: f64 = 1e-9;
