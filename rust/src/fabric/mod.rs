//! Non-blocking switch fabric model.
//!
//! The datacenter network is abstracted as one big non-blocking switch
//! (paper §1): every machine is a *port* with an uplink and a downlink of
//! fixed capacity, and ports are the only source of contention — the core
//! sustains any admitted traffic. A rate allocation is feasible iff for
//! every port the sum of flow rates sending from (resp. received at) it
//! stays within the uplink (downlink) capacity.

use crate::{Bytes, PortId, EPS, GBPS};

/// Static fabric description.
#[derive(Debug, Clone)]
pub struct Fabric {
    /// Number of ports (machines).
    pub num_ports: usize,
    /// Uplink capacity per port, bytes/sec.
    pub up_capacity: Vec<f64>,
    /// Downlink capacity per port, bytes/sec.
    pub down_capacity: Vec<f64>,
}

impl Fabric {
    /// Homogeneous fabric: `n` ports at `rate` bytes/sec each direction.
    pub fn homogeneous(n: usize, rate: f64) -> Self {
        Fabric {
            num_ports: n,
            up_capacity: vec![rate; n],
            down_capacity: vec![rate; n],
        }
    }

    /// The paper's testbed: 1 Gbps NICs.
    pub fn gbps(n: usize) -> Self {
        Self::homogeneous(n, GBPS)
    }

    /// Heterogeneous fabric from explicit per-port uplink/downlink
    /// capacities (bytes/sec). Models mixed-NIC clusters (e.g. 1/10/40
    /// Gbps generations side by side); ports with zero capacity are legal
    /// and simply never granted.
    pub fn heterogeneous(ups: Vec<f64>, downs: Vec<f64>) -> Self {
        assert_eq!(
            ups.len(),
            downs.len(),
            "uplink/downlink capacity vectors must cover the same ports"
        );
        Fabric {
            num_ports: ups.len(),
            up_capacity: ups,
            down_capacity: downs,
        }
    }

    /// Mixed-generation fabric: port `p` gets `gbps_cycle[p % len]` Gbps
    /// symmetric up/down. The deterministic cycling keeps scenarios
    /// reproducible without threading an RNG through fabric construction.
    pub fn mixed_gbps(n: usize, gbps_cycle: &[f64]) -> Self {
        assert!(!gbps_cycle.is_empty(), "need at least one line rate");
        let caps: Vec<f64> = (0..n).map(|p| gbps_cycle[p % gbps_cycle.len()] * GBPS).collect();
        Self::heterogeneous(caps.clone(), caps)
    }
}

/// A mutable view of remaining port capacity used while building one rate
/// allocation. Greedy allocators draw from it in priority order.
#[derive(Debug, Clone, Default)]
pub struct CapacityLedger {
    up: Vec<f64>,
    down: Vec<f64>,
}

impl CapacityLedger {
    pub fn new(fabric: &Fabric) -> Self {
        CapacityLedger {
            up: fabric.up_capacity.clone(),
            down: fabric.down_capacity.clone(),
        }
    }

    /// An empty ledger to be [`reset`](Self::reset) before first use — the
    /// allocation-free construction path for reusable scratch state.
    pub fn empty() -> Self {
        CapacityLedger { up: Vec::new(), down: Vec::new() }
    }

    /// Reload the residuals from `fabric`, reusing the existing buffers
    /// (allocates only if the port count grew).
    pub fn reset(&mut self, fabric: &Fabric) {
        self.up.clear();
        self.up.extend_from_slice(&fabric.up_capacity);
        self.down.clear();
        self.down.extend_from_slice(&fabric.down_capacity);
    }

    /// Residual rate available on the (src→dst) pair.
    #[inline]
    pub fn available(&self, src: PortId, dst: PortId) -> f64 {
        self.up[src].min(self.down[dst]).max(0.0)
    }

    /// Claim `rate` on the pair; clamps to the residual and returns what was
    /// actually granted.
    #[inline]
    pub fn claim(&mut self, src: PortId, dst: PortId, rate: f64) -> f64 {
        let granted = rate.min(self.available(src, dst)).max(0.0);
        self.up[src] -= granted;
        self.down[dst] -= granted;
        granted
    }

    /// Residual uplink at `p`.
    #[inline]
    pub fn up_left(&self, p: PortId) -> f64 {
        self.up[p].max(0.0)
    }

    /// Residual downlink at `p`.
    #[inline]
    pub fn down_left(&self, p: PortId) -> f64 {
        self.down[p].max(0.0)
    }

    /// `true` if the pair still has allocatable rate.
    #[inline]
    pub fn has_room(&self, src: PortId, dst: PortId) -> bool {
        self.available(src, dst) > EPS
    }
}

/// Per-port load bookkeeping used by Philae's *least-busy port* pilot
/// placement (§2.1) and by contention tracking: how many bytes are queued to
/// cross each uplink/downlink and how many distinct coflows occupy it.
#[derive(Debug, Clone, Default)]
pub struct PortLoad {
    /// Backlogged bytes per uplink.
    pub up_bytes: Vec<Bytes>,
    /// Backlogged bytes per downlink.
    pub down_bytes: Vec<Bytes>,
    /// Distinct active coflows per uplink.
    pub up_coflows: Vec<usize>,
    /// Distinct active coflows per downlink.
    pub down_coflows: Vec<usize>,
    /// Monotone counter bumped on every occupancy change (see the
    /// `occupy_*`/`release_*` methods). Schedulers cache contention-derived
    /// priority scores keyed on this epoch: while it is unchanged, no
    /// coflow's port-sharing picture has moved, so cached scores are exact.
    /// Mutate occupancy through the methods — writing the counters directly
    /// leaves stale caches behind.
    pub occ_epoch: u64,
}

impl PortLoad {
    pub fn new(num_ports: usize) -> Self {
        PortLoad {
            up_bytes: vec![0.0; num_ports],
            down_bytes: vec![0.0; num_ports],
            up_coflows: vec![0; num_ports],
            down_coflows: vec![0; num_ports],
            occ_epoch: 0,
        }
    }

    /// A coflow now occupies uplink `p`.
    #[inline]
    pub fn occupy_up(&mut self, p: PortId) {
        self.up_coflows[p] += 1;
        self.occ_epoch += 1;
    }

    /// A coflow now occupies downlink `p`.
    #[inline]
    pub fn occupy_down(&mut self, p: PortId) {
        self.down_coflows[p] += 1;
        self.occ_epoch += 1;
    }

    /// A coflow's last flow at uplink `p` finished.
    #[inline]
    pub fn release_up(&mut self, p: PortId) {
        self.up_coflows[p] = self.up_coflows[p].saturating_sub(1);
        self.occ_epoch += 1;
    }

    /// A coflow's last flow at downlink `p` finished.
    #[inline]
    pub fn release_down(&mut self, p: PortId) {
        self.down_coflows[p] = self.down_coflows[p].saturating_sub(1);
        self.occ_epoch += 1;
    }

    /// Combined busyness of the (src,dst) pair in backlogged bytes — the
    /// metric Philae minimizes when placing pilot flows so that piloting
    /// "only affects earlier finishing flows of other coflows".
    pub fn pair_busyness(&self, src: PortId, dst: PortId) -> Bytes {
        self.up_bytes[src] + self.down_bytes[dst]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_respects_capacity() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let mut l = CapacityLedger::new(&fabric);
        assert_eq!(l.claim(0, 1, 60.0), 60.0);
        assert_eq!(l.claim(0, 1, 60.0), 40.0); // clamped to residual
        assert_eq!(l.claim(0, 1, 1.0), 0.0);
        assert!(!l.has_room(0, 1));
    }

    #[test]
    fn ledger_couples_up_and_down() {
        let fabric = Fabric::homogeneous(3, 100.0);
        let mut l = CapacityLedger::new(&fabric);
        l.claim(0, 1, 100.0); // saturates up[0] and down[1]
        assert_eq!(l.available(0, 2), 0.0); // up[0] gone
        assert_eq!(l.available(2, 1), 0.0); // down[1] gone
        assert_eq!(l.available(2, 0), 100.0); // untouched pair
    }

    #[test]
    fn heterogeneous_pair_min() {
        let fabric = Fabric {
            num_ports: 2,
            up_capacity: vec![30.0, 100.0],
            down_capacity: vec![100.0, 50.0],
        };
        let l = CapacityLedger::new(&fabric);
        assert_eq!(l.available(0, 1), 30.0);
        assert_eq!(l.available(1, 0), 100.0);
    }

    #[test]
    fn ledger_reset_reuses_buffers() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let mut l = CapacityLedger::empty();
        l.reset(&fabric);
        assert_eq!(l.claim(0, 1, 30.0), 30.0);
        l.reset(&fabric);
        assert_eq!(l.available(0, 1), 100.0);
    }

    #[test]
    fn occupancy_methods_bump_epoch() {
        let mut load = PortLoad::new(2);
        assert_eq!(load.occ_epoch, 0);
        load.occupy_up(0);
        load.occupy_down(1);
        assert_eq!(load.up_coflows[0], 1);
        assert_eq!(load.down_coflows[1], 1);
        assert_eq!(load.occ_epoch, 2);
        load.release_up(0);
        load.release_down(1);
        assert_eq!(load.up_coflows[0], 0);
        assert_eq!(load.occ_epoch, 4);
        // saturating: double release stays at zero but still bumps
        load.release_up(0);
        assert_eq!(load.up_coflows[0], 0);
        assert_eq!(load.occ_epoch, 5);
    }

    #[test]
    fn heterogeneous_constructor() {
        let f = Fabric::heterogeneous(vec![10.0, 20.0], vec![30.0, 40.0]);
        assert_eq!(f.num_ports, 2);
        assert_eq!(f.up_capacity, vec![10.0, 20.0]);
        assert_eq!(f.down_capacity, vec![30.0, 40.0]);
    }

    #[test]
    #[should_panic]
    fn heterogeneous_rejects_mismatched_lengths() {
        Fabric::heterogeneous(vec![1.0], vec![1.0, 2.0]);
    }

    #[test]
    fn mixed_gbps_cycles_rates() {
        let f = Fabric::mixed_gbps(5, &[1.0, 10.0, 40.0]);
        assert_eq!(f.num_ports, 5);
        assert_eq!(f.up_capacity[0], crate::GBPS);
        assert_eq!(f.up_capacity[1], 10.0 * crate::GBPS);
        assert_eq!(f.up_capacity[2], 40.0 * crate::GBPS);
        assert_eq!(f.up_capacity[3], crate::GBPS);
        assert_eq!(f.up_capacity, f.down_capacity);
    }

    #[test]
    fn pair_busyness() {
        let mut load = PortLoad::new(2);
        load.up_bytes[0] = 5.0;
        load.down_bytes[1] = 7.0;
        assert_eq!(load.pair_busyness(0, 1), 12.0);
        assert_eq!(load.pair_busyness(1, 0), 0.0);
    }
}
