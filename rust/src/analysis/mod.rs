//! Analytical results of §2.2: the Hoeffding bound of Eq. (1) on the
//! relative CCT gap between sampling-based scheduling and perfect
//! knowledge, plus skew statistics used by the robustness experiments.

use crate::coflow::CoflowOracle;
use crate::trace::Trace;

pub mod lower_bound;
pub use lower_bound::{cct_lower_bound, cct_lower_bound_default, optimality_gap, CctLowerBound};

/// Parameters of the two-coflow setting of Eq. (1): coflow *i* has `c·nᵢ`
/// flows i.i.d. in `[aᵢ, bᵢ]` with mean `μᵢ`; `mᵢ` pilot flows are sampled.
/// WLOG `n₂μ₂ ≥ n₁μ₁`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TwoCoflowSetting {
    pub n1: f64,
    pub a1: f64,
    pub b1: f64,
    pub mu1: f64,
    pub m1: f64,
    pub n2: f64,
    pub a2: f64,
    pub b2: f64,
    pub mu2: f64,
    pub m2: f64,
}

impl TwoCoflowSetting {
    /// The right-hand side of Eq. (1): the asymptotic (c→∞) upper bound on
    /// `(T̃ᶜ − Tᶜ)/Tᶜ`.
    ///
    /// ```text
    /// 4·exp[ −2(n₂μ₂−n₁μ₁)² / (n₂(b₂−a₂)/√m₂ + n₁(b₁−a₁)/√m₁)² ]
    ///   · (n₂μ₂−n₁μ₁)/(n₂μ₂+2n₁μ₁)
    /// ```
    pub fn hoeffding_bound(&self) -> f64 {
        let gap = self.n2 * self.mu2 - self.n1 * self.mu1;
        debug_assert!(gap >= -1e-9, "requires n2*mu2 >= n1*mu1");
        let gap = gap.max(0.0);
        let denom = self.n2 * (self.b2 - self.a2) / self.m2.sqrt()
            + self.n1 * (self.b1 - self.a1) / self.m1.sqrt();
        let exp_term = if denom <= 0.0 {
            if gap > 0.0 {
                0.0
            } else {
                1.0
            }
        } else {
            (-2.0 * gap * gap / (denom * denom)).exp()
        };
        4.0 * exp_term * gap / (self.n2 * self.mu2 + 2.0 * self.n1 * self.mu1)
    }

    /// Symmetric uniform setting used in the skew sweep: both coflows have
    /// `n` flows in `[μ·(1−h), μ·(1+h)]` scaled so coflow 2 is `ratio`
    /// larger; `m` pilots each. `h ∈ [0,1)` controls skew.
    pub fn symmetric(n: f64, mu: f64, half_range: f64, size_ratio: f64, m: f64) -> Self {
        let (a1, b1) = (mu * (1.0 - half_range), mu * (1.0 + half_range));
        let mu2 = mu * size_ratio;
        let (a2, b2) = (mu2 * (1.0 - half_range), mu2 * (1.0 + half_range));
        TwoCoflowSetting {
            n1: n,
            a1,
            b1,
            mu1: mu,
            m1: m,
            n2: n,
            a2,
            b2,
            mu2,
            m2: m,
        }
    }
}

/// Distribution of intra-coflow skew (`max/min` flow length, §2.2) across
/// a trace, ignoring single-flow coflows and zero-size degenerates.
pub fn skew_distribution(trace: &Trace) -> Vec<f64> {
    let oracles: Vec<CoflowOracle> = trace.oracles();
    trace
        .coflows
        .iter()
        .zip(oracles.iter())
        .filter(|(c, _)| c.num_flows() > 1)
        .map(|(_, o)| o.skew())
        .filter(|s| s.is_finite())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_shrinks_with_more_pilots() {
        let few = TwoCoflowSetting::symmetric(100.0, 10.0, 0.9, 1.1, 1.0).hoeffding_bound();
        let many = TwoCoflowSetting::symmetric(100.0, 10.0, 0.9, 1.1, 25.0).hoeffding_bound();
        assert!(many < few, "more pilots must tighten the bound: {many} vs {few}");
    }

    #[test]
    fn bound_shrinks_as_skew_decreases() {
        let skewed = TwoCoflowSetting::symmetric(100.0, 10.0, 0.9, 1.2, 4.0).hoeffding_bound();
        let tight = TwoCoflowSetting::symmetric(100.0, 10.0, 0.1, 1.2, 4.0).hoeffding_bound();
        assert!(tight < skewed);
    }

    #[test]
    fn bound_small_at_both_extremes_of_size_gap() {
        // near-identical sizes: numerator → 0
        let near = TwoCoflowSetting::symmetric(100.0, 10.0, 0.5, 1.0001, 4.0).hoeffding_bound();
        // hugely different sizes: exponential → 0
        let far = TwoCoflowSetting::symmetric(100.0, 10.0, 0.5, 100.0, 4.0).hoeffding_bound();
        // the worst case sits in between
        let mid = TwoCoflowSetting::symmetric(100.0, 10.0, 0.5, 1.05, 4.0).hoeffding_bound();
        assert!(near < mid, "near={near} mid={mid}");
        assert!(far < mid, "far={far} mid={mid}");
    }

    #[test]
    fn bound_nonnegative_and_bounded() {
        for ratio in [1.0, 1.01, 1.5, 2.0, 10.0] {
            for h in [0.0, 0.3, 0.9] {
                for m in [1.0, 4.0, 16.0] {
                    let b = TwoCoflowSetting::symmetric(50.0, 5.0, h, ratio, m).hoeffding_bound();
                    assert!(b >= 0.0 && b <= 4.0, "bound {b} out of range");
                }
            }
        }
    }

    #[test]
    fn zero_range_perfect_estimate() {
        // no skew at all → exact estimate → bound is 0 when sizes differ...
        let s = TwoCoflowSetting::symmetric(10.0, 1.0, 0.0, 2.0, 1.0);
        assert_eq!(s.hoeffding_bound(), 0.0);
    }

    #[test]
    fn skew_distribution_of_trace() {
        let t = crate::trace::TraceSpec::fb_like(50, 60).seed(2).generate();
        let sk = skew_distribution(&t);
        assert!(!sk.is_empty());
        assert!(sk.iter().all(|&s| s >= 1.0));
    }
}
