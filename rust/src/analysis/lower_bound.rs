//! Offline lower bound on total (and average) CCT — the optimality-gap
//! oracle.
//!
//! Follows the single-machine relaxation used in the coflow-approximation
//! literature (Qiu–Stein–Zhong, arXiv 1603.07981): project the fabric onto
//! each port direction ("machine"), where coflow *j* needs
//! `w_{j,m} = bytes_{j,m} / cap_m` seconds of service after its release
//! `a_j`. Any feasible coflow schedule, restricted to machine *m*, is a
//! feasible preemptive single-machine schedule, and a coflow finishes no
//! earlier than its last byte through *m* — so the sum of CCTs over the
//! coflows touching *m* is at least the optimal `1|r_j, pmtn|ΣC_j` flow
//! time, which SRPT attains exactly. Coflows not touching *m* contribute
//! at least their ideal isolated CCT (their bottleneck seconds). The bound
//! is the best such relaxation over all `2·num_ports` machines:
//!
//! ```text
//! Σ_j cct_j ≥ max_m [ max(SRPT_m, Σ_{j∈S_m} ideal_j) + Σ_{j∉S_m} ideal_j ]
//! ```
//!
//! On instances whose contention is one shared port (e.g. two coflows on a
//! single src→dst pair) the relaxation is *tight*: SRPT on that port is the
//! optimum, so `bench_t2_cct`'s per-scheduler gaps are true distances from
//! optimal there, and honest floors everywhere else.

use crate::fabric::Fabric;
use crate::trace::Trace;
use crate::Time;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Min-heap entry: (remaining seconds, job index) under `total_cmp`.
#[derive(PartialEq)]
struct Job(f64, usize);
impl Eq for Job {}
impl PartialOrd for Job {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Job {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

/// Exact preemptive SRPT on one machine: jobs are `(release, work,
/// coflow)`, the return value is the optimal `Σ (C_j − r_j)` for
/// `1|r_j, pmtn|ΣC_j`.
fn srpt_total_flow_time(jobs: &mut [(Time, f64, usize)]) -> f64 {
    jobs.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.2.cmp(&b.2)));
    let n = jobs.len();
    let mut heap: BinaryHeap<Reverse<Job>> = BinaryHeap::with_capacity(n);
    let mut t = 0.0f64;
    let mut i = 0usize;
    let mut sum = 0.0f64;
    while i < n || !heap.is_empty() {
        if heap.is_empty() && t < jobs[i].0 {
            t = jobs[i].0;
        }
        while i < n && jobs[i].0 <= t {
            heap.push(Reverse(Job(jobs[i].1, i)));
            i += 1;
        }
        let Reverse(Job(rem, idx)) = heap.pop().expect("non-empty by loop guard");
        let next_release = if i < n { jobs[i].0 } else { f64::INFINITY };
        let finish = t + rem;
        if finish <= next_release {
            t = finish;
            sum += t - jobs[idx].0;
        } else {
            // preempt: a shorter job may arrive at the release instant
            heap.push(Reverse(Job(rem - (next_release - t), idx)));
            t = next_release;
        }
    }
    sum
}

/// The oracle's verdict for one trace/fabric pair.
#[derive(Debug, Clone)]
pub struct CctLowerBound {
    /// Per-coflow ideal isolated CCT in seconds (bottleneck bytes over the
    /// bottleneck port's capacity) — the per-coflow floor.
    pub ideal: Vec<Time>,
    /// Lower bound on `Σ_j cct_j` in seconds.
    pub total_cct: f64,
    /// Machine whose relaxation is binding (`p` = uplink of port p,
    /// `num_ports + p` = downlink of port p); `None` when the plain
    /// `Σ ideal` bound already dominates every machine.
    pub binding_machine: Option<usize>,
}

impl CctLowerBound {
    /// Lower bound on the average CCT.
    pub fn avg_cct(&self) -> f64 {
        if self.ideal.is_empty() {
            0.0
        } else {
            self.total_cct / self.ideal.len() as f64
        }
    }
}

/// Relative optimality gap of a measured average CCT against the oracle:
/// `measured / bound − 1` (≥ 0 for any real schedule up to float noise;
/// 0.0 when the bound is vacuous).
pub fn optimality_gap(measured_avg_cct: f64, bound_avg_cct: f64) -> f64 {
    if bound_avg_cct <= 0.0 {
        return 0.0;
    }
    measured_avg_cct / bound_avg_cct - 1.0
}

/// Compute the CCT lower bound for `trace` on `fabric` (must cover the
/// trace's ports). O(F) accumulation plus one SRPT run per touched
/// machine.
pub fn cct_lower_bound(trace: &Trace, fabric: &Fabric) -> CctLowerBound {
    assert_eq!(
        fabric.num_ports, trace.num_ports,
        "fabric port count must match the trace"
    );
    let np = trace.num_ports;
    let nc = trace.coflows.len();
    // machine m ∈ [0, np) = uplink of port m; m ∈ [np, 2np) = downlink
    let mut machine_jobs: Vec<Vec<(Time, f64, usize)>> = vec![Vec::new(); 2 * np];
    let mut ideal = vec![0.0f64; nc];
    let mut up = vec![0.0f64; np];
    let mut down = vec![0.0f64; np];
    let mut touched_up: Vec<usize> = Vec::new();
    let mut touched_down: Vec<usize> = Vec::new();
    for c in &trace.coflows {
        for &fid in &c.flows {
            let f = &trace.flows[fid];
            if up[f.src] == 0.0 {
                touched_up.push(f.src);
            }
            if down[f.dst] == 0.0 {
                touched_down.push(f.dst);
            }
            up[f.src] += f.size;
            down[f.dst] += f.size;
        }
        let mut best = 0.0f64;
        for &p in &touched_up {
            let w = up[p] / fabric.up_capacity[p].max(1.0);
            best = best.max(w);
            machine_jobs[p].push((c.arrival, w, c.id));
            up[p] = 0.0;
        }
        for &p in &touched_down {
            let w = down[p] / fabric.down_capacity[p].max(1.0);
            best = best.max(w);
            machine_jobs[np + p].push((c.arrival, w, c.id));
            down[p] = 0.0;
        }
        touched_up.clear();
        touched_down.clear();
        ideal[c.id] = best;
    }
    let sum_ideal: f64 = ideal.iter().sum();
    let mut total_cct = sum_ideal;
    let mut binding_machine = None;
    for (m, jobs) in machine_jobs.iter_mut().enumerate() {
        if jobs.len() < 2 {
            // one job: SRPT equals its work ≤ its ideal — cannot improve
            continue;
        }
        let ideal_on_m: f64 = jobs.iter().map(|&(_, _, cid)| ideal[cid]).sum();
        let srpt = srpt_total_flow_time(jobs);
        let bound = sum_ideal - ideal_on_m + srpt.max(ideal_on_m);
        if bound > total_cct {
            total_cct = bound;
            binding_machine = Some(m);
        }
    }
    CctLowerBound { ideal, total_cct, binding_machine }
}

/// Convenience: the bound on the paper-default homogeneous fabric.
pub fn cct_lower_bound_default(trace: &Trace) -> CctLowerBound {
    cct_lower_bound(trace, &Fabric::homogeneous(trace.num_ports, crate::GBPS))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{SchedulerConfig, SchedulerKind};
    use crate::sim::Simulation;
    use crate::trace::{TraceRecord, TraceSpec};

    #[test]
    fn srpt_matches_hand_solved_instances() {
        // two jobs released together: short first → flow times w1, w1+w2
        let mut jobs = vec![(0.0, 1.0, 0), (0.0, 3.0, 1)];
        assert!((srpt_total_flow_time(&mut jobs) - (1.0 + 4.0)).abs() < 1e-12);
        // preemption: long job starts, short job arrives and preempts
        let mut jobs = vec![(0.0, 10.0, 0), (1.0, 1.0, 1)];
        // short: 1→2 (flow 1); long: finishes at 11 (flow 11)
        assert!((srpt_total_flow_time(&mut jobs) - 12.0).abs() < 1e-12);
        // idle gap between releases
        let mut jobs = vec![(0.0, 1.0, 0), (5.0, 1.0, 1)];
        assert!((srpt_total_flow_time(&mut jobs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bound_is_exact_on_a_shared_port_pair() {
        // two 125 MB coflows on the same (0→1) pair: optimum is SCF —
        // ccts 1 s and 2 s — and the engine's SCF run attains it
        let trace = Trace::from_records(
            2,
            vec![
                TraceRecord::uniform(1, 0.0, vec![0], vec![1], 125.0),
                TraceRecord::uniform(2, 0.0, vec![0], vec![1], 125.0),
            ],
        );
        let lb = cct_lower_bound_default(&trace);
        assert!((lb.avg_cct() - 1.5).abs() < 1e-9, "lb {}", lb.avg_cct());
        let res = Simulation::run(&trace, SchedulerKind::Scf, &SchedulerConfig::default());
        let gap = optimality_gap(res.avg_cct(), lb.avg_cct());
        assert!(gap.abs() < 1e-6, "SCF should sit on the bound, gap {gap}");
    }

    #[test]
    fn bound_is_exact_on_disjoint_coflows() {
        // no contention: every coflow runs at its ideal
        let trace = Trace::from_records(
            4,
            vec![
                TraceRecord::uniform(1, 0.0, vec![0], vec![1], 125.0),
                TraceRecord::uniform(2, 0.0, vec![2], vec![3], 125.0),
            ],
        );
        let lb = cct_lower_bound_default(&trace);
        assert!((lb.avg_cct() - 1.0).abs() < 1e-9);
        assert_eq!(lb.binding_machine, None);
        let res = Simulation::run(&trace, SchedulerKind::Philae, &SchedulerConfig::default());
        assert!(optimality_gap(res.avg_cct(), lb.avg_cct()).abs() < 1e-6);
    }

    #[test]
    fn every_scheduler_sits_at_or_above_the_bound() {
        let trace = TraceSpec::fb_like(20, 40).seed(6).generate();
        let lb = cct_lower_bound_default(&trace);
        assert!(lb.avg_cct() > 0.0);
        let cfg = SchedulerConfig::default();
        for &kind in SchedulerKind::all() {
            let res = Simulation::run(&trace, kind, &cfg);
            let gap = optimality_gap(res.avg_cct(), lb.avg_cct());
            assert!(
                gap >= -1e-6,
                "{kind:?} beat the lower bound: gap {gap}, avg {}, lb {}",
                res.avg_cct(),
                lb.avg_cct()
            );
        }
    }

    #[test]
    fn machine_relaxation_tightens_over_sum_of_ideals() {
        // heavy contention on one port: the SRPT machine term must beat
        // the plain Σ ideal bound
        let records: Vec<TraceRecord> = (0..6)
            .map(|i| TraceRecord::uniform(i + 1, 0.0, vec![0], vec![1], 25.0))
            .collect();
        let trace = Trace::from_records(2, records);
        let lb = cct_lower_bound_default(&trace);
        let sum_ideal: f64 = lb.ideal.iter().sum();
        assert!(lb.total_cct > sum_ideal * 1.5, "total {} vs Σideal {sum_ideal}", lb.total_cct);
        assert!(lb.binding_machine.is_some());
    }
}
