//! Rate allocation: turn a priority order over coflows into per-flow rates.
//!
//! Greedy max-min in priority order: walk the coflows highest-priority
//! first (flows of one coflow contiguous — Saath's all-or-none) and grant
//! each unfinished flow the full residual `min(uplink(src), downlink(dst))`.
//! Properties:
//!
//! * **Feasible** — per-port rate sums never exceed capacity (the ledger
//!   clamps every claim).
//! * **Work-conserving** — lower-priority entries absorb whatever the
//!   higher-priority ones leave (Philae's unestimated non-pilot flows sit
//!   at the tail of the order and soak up leftovers).
//! * **Cheap** — every grant saturates at least one port direction, so at
//!   most `2·P` flows receive non-zero rate; the walk early-exits once all
//!   directions are saturated, and iterates each coflow's engine-maintained
//!   `active_list` so finished flows of wide coflows cost nothing.
//!
//! ## Scratch architecture (zero steady-state allocation)
//!
//! The hot path is [`allocate_into`] + [`apply_grants`], which perform **no
//! heap allocation in steady state**: every buffer lives in a caller-owned
//! [`AllocScratch`] that is grown once and reused for every subsequent
//! scheduling event. Concretely:
//!
//! * the [`CapacityLedger`] is reset in place from the fabric;
//! * the grants list is a reused `Vec` cleared per call;
//! * duplicate-grant merging (a flow granted in both the budgeted and the
//!   backfill pass) uses **epoch-stamped dense per-flow tables**
//!   (`grant_epoch`/`grant_slot`): bumping one counter invalidates the whole
//!   table in O(1), so nothing is cleared and no hash map is built;
//! * per-group port budgets are flattened `groups × ports` rows in two
//!   reused `Vec<f64>`s.
//!
//! [`allocate`] and [`apply`] remain as thin compatibility wrappers that
//! build the scratch per call; the simulator engine, the live service, and
//! the benches all thread a persistent scratch through instead.
//!
//! ## Sharded allocation pipeline (5k+ port fabrics)
//!
//! [`allocate_into`] is also a **port-sharded parallel pipeline**, selected
//! per scratch via [`AllocScratch::set_shards`]. The key observation is
//! that the serial greedy is a *per-port dependency chain*: a flow's grant
//! depends only on the residuals of its two ports, which depend only on the
//! grants of earlier-in-plan flows on those same ports. Any execution that
//! respects the per-port order — regardless of how flows interleave across
//! ports — reproduces the serial outcome **bit for bit**, because every
//! port residual is produced by the identical sequence of f64 operations.
//!
//! The pipeline exploits that in four phases:
//!
//! 1. **Emit + bucket** — the plan's runnable flows are emitted as ops in
//!    exactly the serial visit order, then a serial walk assigns each op a
//!    *DAG level* (`1 + max(level of the previous op on its src uplink, on
//!    its dst downlink)`). Ops in the same level touch pairwise-disjoint
//!    ports by construction. Each op is then bucketed by
//!    `(level, src-shard)`, where ports are partitioned into `S`
//!    contiguous shards. On the pooled path the emission itself runs in
//!    parallel: every worker emits a contiguous chunk of the plan's
//!    entries (pass-major) into a private buffer, and the caller
//!    concatenates the buffers pass-major in worker order — byte for byte
//!    the serial emission.
//! 2. **Grant (parallel)** — `S` workers sweep the levels in lockstep (a
//!    sense-reversing spin barrier per level). Worker `s` owns shard `s`'s
//!    slice of the capacity ledger: it grants every op whose src port lies
//!    in its shard — intra-shard flows touch only its own slice;
//!    cross-shard flows additionally debit the remote downlink, which is
//!    safe and exact because ports are disjoint within a level. Port
//!    residuals and group budgets live in f64-bit atomic tables.
//! 3. **Merge (serial, deterministic)** — a replay walk over the ops in
//!    original plan order rebuilds the canonical grants list (including
//!    the budgeted/backfill duplicate-grant merge), the `visited` counter,
//!    and the serial path's all-ports-saturated early exit, so every
//!    observable output is bit-identical to the serial path for **any**
//!    shard count.
//! 4. The stamped grant tables are filled as in the serial path, so
//!    [`AllocScratch::was_granted`]/[`AllocScratch::granted_rate`] work
//!    unchanged.
//!
//! `S = 1` (the default) bypasses the pipeline entirely and runs the
//! serial loop — there is no behavioral difference, only a wall-clock one.
//!
//! ## Persistent worker pool (pool lifecycle, wake protocol)
//!
//! The sharded path used to pay one `thread::scope` spawn per call; at
//! service event rates that entry cost dominates the zero-alloc fast path.
//! Each [`AllocScratch`] therefore owns a [`WorkerPool`]: `S − 1` parked
//! worker threads, created lazily on the first sharded call and reused for
//! every subsequent allocation. The wake protocol per round:
//!
//! 1. the caller sizes the barrier to the round's clamped shard count,
//!    arms the ack counter, and publishes a [`PoolJob`] (raw pointers to
//!    the scratch tables, plan, and world slices) by bumping a round
//!    counter under a mutex + condvar;
//! 2. caller and workers emit their op chunks, cross a barrier, the caller
//!    runs the serial bucket/sort/table-setup phase alone, and a second
//!    barrier crossing releases everyone into the level-lockstep grant
//!    sweep of phase 2;
//! 3. each worker acknowledges round completion on an atomic counter; the
//!    caller spins that counter to zero before returning, which is what
//!    keeps the job's raw pointers sound — no worker can touch the round's
//!    data after `allocate_into` returns.
//!
//! Workers beyond the round's clamped shard count sit the round out
//! without touching the barrier; a scratch whose shard count grows simply
//! spawns the missing workers. Dropping the scratch sets a shutdown flag,
//! wakes everyone, and joins the threads (**shutdown-on-drop** — the pool
//! never outlives its scratch). [`AllocScratch::set_spawn_workers`] keeps
//! the old spawn-per-call path selectable as the equivalence/bench
//! baseline; both paths are bit-identical to serial (see
//! `benches/bench_service.rs`, which gates the pool's entry cost against
//! the spawn baseline, and `benches/bench_shard.rs` for µs vs shard
//! count at 900/5000 ports).

use crate::coflow::{CoflowState, FlowState};
use crate::fabric::{CapacityLedger, Fabric};
use crate::{CoflowId, FlowId, EPS};
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Which of a coflow's flows an order entry admits — Philae's lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlowFilter {
    /// Every unfinished flow.
    All,
    /// Only the pilot flows (Philae's sampling lane).
    PilotsOnly,
    /// Only non-pilot flows (Philae's backfill lane).
    NonPilots,
}

/// One priority-order entry: a coflow, the lane filter to apply, and an
/// optional bandwidth group (Aalo-style queues with fixed weighted shares).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrderEntry {
    pub coflow: CoflowId,
    pub filter: FlowFilter,
    /// `Some(q)` assigns the entry to bandwidth group `q` (see
    /// [`Plan::group_weights`]); `None` means strict priority.
    pub group: Option<usize>,
}

impl OrderEntry {
    pub fn all(coflow: CoflowId) -> Self {
        OrderEntry { coflow, filter: FlowFilter::All, group: None }
    }

    pub fn pilots(coflow: CoflowId) -> Self {
        OrderEntry { coflow, filter: FlowFilter::PilotsOnly, group: None }
    }

    pub fn backfill(coflow: CoflowId) -> Self {
        OrderEntry { coflow, filter: FlowFilter::NonPilots, group: None }
    }

    pub fn grouped(coflow: CoflowId, group: usize) -> Self {
        OrderEntry { coflow, filter: FlowFilter::All, group: Some(group) }
    }
}

/// A full scheduling plan: the priority order plus the bandwidth weights of
/// any groups referenced by entries. Weights are normalized internally;
/// groups model Aalo/Saath's "each queue receives a fixed bandwidth share
/// at every port" semantics (paper §1.1). Strict-priority entries
/// (`group: None`) are unbudgeted.
///
/// Plans are designed to be **caller-owned and reused**: schedulers write
/// into an existing plan through [`Scheduler::order_into`]
/// (`crate::coordinator::Scheduler::order_into`), so the entry vector's
/// allocation is paid once per run, not once per scheduling event.
#[derive(Debug, Clone, Default)]
pub struct Plan {
    pub entries: Vec<OrderEntry>,
    pub group_weights: Vec<f64>,
}

impl Plan {
    /// Strict-priority plan over whole coflows.
    pub fn strict(coflows: impl IntoIterator<Item = CoflowId>) -> Self {
        Plan {
            entries: coflows.into_iter().map(OrderEntry::all).collect(),
            group_weights: Vec::new(),
        }
    }

    /// Empty the plan, keeping both buffers' capacity for reuse.
    pub fn clear(&mut self) {
        self.entries.clear();
        self.group_weights.clear();
    }
}

/// Result of one allocation pass.
#[derive(Debug, Clone, Default)]
pub struct Allocation {
    /// `(flow, rate)` for every flow granted a non-zero rate, in priority
    /// order. Flows not listed are implicitly stalled (rate 0).
    pub grants: Vec<(FlowId, f64)>,
    /// Number of flows inspected (profiling: walk cost).
    pub visited: usize,
}

impl Allocation {
    /// Total allocated rate (bytes/sec).
    pub fn total_rate(&self) -> f64 {
        self.grants.iter().map(|(_, r)| r).sum()
    }
}

/// Pass-1 (budgeted) ops carry this bit in [`ShardOp::entry`].
const BUDGETED_BIT: u32 = 1 << 31;

/// One emitted candidate flow of the sharded pipeline: the flow, its ports,
/// and the plan entry it was admitted under (high bit = budgeted pass).
#[derive(Debug, Clone, Copy, Default)]
struct ShardOp {
    fid: u32,
    src: u32,
    dst: u32,
    entry: u32,
}

/// Contiguous port → shard mapping (balanced, monotone).
#[inline]
fn port_shard(p: usize, nports: usize, shards: usize) -> usize {
    p * shards / nports
}

/// Default allocator worker-shard count for config defaults
/// ([`crate::sim::SimConfig`], [`crate::service::ServiceConfig`]):
/// `PHILAE_TEST_SHARDS` when set (the CI matrix leg uses it to drive the
/// whole test suite through the sharded pipeline), else 1 (serial). Safe to
/// override globally — results are bit-identical for every shard count.
pub fn env_test_shards() -> usize {
    std::env::var("PHILAE_TEST_SHARDS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// Sense-reversing spin barrier for the per-level lockstep of the shard
/// workers. Levels are short (one op per port at most), so spinning beats
/// a futex park/unpark by a wide margin. `total` is atomic so a persistent
/// pool can retarget the participant count between rounds (it is only ever
/// stored while every participant is parked, never mid-wait).
struct SpinBarrier {
    arrived: AtomicUsize,
    generation: AtomicUsize,
    total: AtomicUsize,
}

impl SpinBarrier {
    fn new(total: usize) -> Self {
        SpinBarrier {
            arrived: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
            total: AtomicUsize::new(total),
        }
    }

    /// Retarget the participant count. Only sound while the barrier is
    /// quiescent (no thread between `wait` entry and exit) — the pool
    /// guarantees that by setting it before publishing a round.
    fn set_total(&self, total: usize) {
        self.total.store(total, Ordering::Relaxed);
    }

    fn wait(&self) {
        let gen = self.generation.load(Ordering::Acquire);
        if self.arrived.fetch_add(1, Ordering::AcqRel) + 1 == self.total.load(Ordering::Relaxed) {
            self.arrived.store(0, Ordering::Relaxed);
            self.generation.store(gen.wrapping_add(1), Ordering::Release);
        } else {
            // Short pure spin (levels are tiny), then yield so a
            // descheduled peer doesn't cost a whole scheduling quantum.
            let mut spins = 0u32;
            while self.generation.load(Ordering::Acquire) == gen {
                if spins < 1 << 14 {
                    std::hint::spin_loop();
                    spins += 1;
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }
}

/// Reusable state of the sharded pipeline. All tables grow to the
/// high-water mark and are reused; the atomic f64-bit tables are the port
/// slices the shard workers share.
#[derive(Debug, Default)]
struct ShardState {
    /// Emitted ops in serial plan order (pass-major).
    ops: Vec<ShardOp>,
    /// Next free DAG level per uplink/downlink (reset per call).
    next_up: Vec<u32>,
    next_down: Vec<u32>,
    /// Per-op bucket key: `level * shards + src_shard`.
    keys: Vec<u32>,
    /// Counting-sort prefix table over the `(level, shard)` buckets
    /// (`bucket_start[b]..bucket_start[b+1]` indexes into `order`).
    bucket_start: Vec<u32>,
    bucket_cursor: Vec<u32>,
    /// Op indices sorted by `(level, src-shard, plan order)`.
    order: Vec<u32>,
    /// Port residuals / group budgets as f64 bits (workers share these).
    up_bits: Vec<AtomicU64>,
    down_bits: Vec<AtomicU64>,
    budget_up_bits: Vec<AtomicU64>,
    budget_down_bits: Vec<AtomicU64>,
    /// Per-op grant as f64 bits (0.0 = gated / nothing granted).
    grant_bits: Vec<AtomicU64>,
    /// Level count of the current round.
    levels: usize,
    /// Per-worker emission buffers of the pooled path (one slot per
    /// worker, grown to the shard-count high-water mark).
    emit: Vec<EmitBuf>,
}

/// One worker's private op-emission buffer (see [`emit_chunk`]). The
/// `UnsafeCell` hands worker `w` exclusive lock-free mutation of slot `w`
/// during the emission phase; distinct slots never alias, and the barrier
/// after emission publishes every buffer to the concatenating caller.
#[derive(Debug, Default)]
struct EmitBuf {
    ops: UnsafeCell<Vec<ShardOp>>,
    /// Index where the second (backfill) pass begins in `ops`.
    split: AtomicUsize,
}

// SAFETY: each round, slot `w` is mutated only by worker `w`, and all
// cross-thread reads happen after the emission barrier.
unsafe impl Sync for EmitBuf {}

/// Scratch state is transient per call, so a cloned scratch just starts
/// cold (atomics are not `Clone`).
impl Clone for ShardState {
    fn clone(&self) -> Self {
        ShardState::default()
    }
}

/// Grow an atomic f64-bit table to `n` slots.
fn grow_bits(v: &mut Vec<AtomicU64>, n: usize) {
    if v.len() < n {
        v.resize_with(n, || AtomicU64::new(0));
    }
}

/// Job descriptor for one pooled allocation round. Raw pointers stand in
/// for the borrows the long-lived worker threads cannot hold: they are
/// valid from publication until the caller observes every participant's
/// ack (`PoolShared::active` reaching 0), and workers dereference them
/// only between those two points.
#[derive(Clone, Copy)]
struct PoolJob {
    st: *const ShardState,
    plan: *const Plan,
    flows: *const FlowState,
    nflows: usize,
    coflows: *const CoflowState,
    ncoflows: usize,
    shards: usize,
    nports: usize,
    has_groups: bool,
}

// SAFETY: the pointers are dereferenced only inside a round, while the
// publishing `allocate_into` call keeps the pointees alive and blocks on
// the ack counter before returning (wake protocol in the module docs).
unsafe impl Send for PoolJob {}

impl PoolJob {
    /// Pre-first-round placeholder; never dereferenced (`shards == 0`
    /// makes every worker sit the round out).
    const fn empty() -> Self {
        PoolJob {
            st: std::ptr::null(),
            plan: std::ptr::null(),
            flows: std::ptr::null(),
            nflows: 0,
            coflows: std::ptr::null(),
            ncoflows: 0,
            shards: 0,
            nports: 0,
            has_groups: false,
        }
    }
}

/// Round gate of the wake protocol: bumping `round` under the lock
/// publishes a fresh job to the parked workers.
struct PoolGate {
    round: u64,
    job: PoolJob,
    shutdown: bool,
}

/// State shared between an [`AllocScratch`] and its parked workers.
struct PoolShared {
    gate: Mutex<PoolGate>,
    /// Wakes parked workers on a new round or on shutdown.
    cv: Condvar,
    /// Level-lockstep barrier, retargeted per round to the clamped shard
    /// count while every participant is parked.
    barrier: SpinBarrier,
    /// Participants still inside the current round. The caller spins this
    /// to 0 before returning, which is what makes [`PoolJob`]'s raw
    /// pointers sound.
    active: AtomicUsize,
}

/// Persistent worker pool of the sharded allocation pipeline (module
/// docs): `S − 1` parked threads created lazily on the first sharded call
/// and woken per allocation, replacing a `thread::scope` spawn per call.
/// Dropping the pool (with its owning scratch) sets the shutdown flag,
/// wakes everyone, and joins the threads.
#[derive(Default)]
struct WorkerPool {
    shared: Option<Arc<PoolShared>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Park-to-park worker body: wait for a round, run it (or sit it out
    /// when the clamped shard count excludes this worker), acknowledge,
    /// park again. Exits when the owning scratch drops.
    fn worker_main(shared: Arc<PoolShared>, idx: usize) {
        let mut last_round = 0u64;
        loop {
            let job = {
                let mut g = shared.gate.lock().unwrap();
                loop {
                    if g.shutdown {
                        return;
                    }
                    if g.round != last_round {
                        break;
                    }
                    g = shared.cv.wait(g).unwrap();
                }
                last_round = g.round;
                g.job
            };
            // caller is shard 0; pool worker `idx` is shard `idx + 1`
            let w = idx + 1;
            if w >= job.shards {
                continue; // clamped out of this round: no barrier, no ack
            }
            // SAFETY: PoolJob contract — the pointees stay alive until the
            // ack below, and the barrier protocol serializes all access.
            unsafe { pool_round(&job, w, &shared.barrier) };
            shared.active.fetch_sub(1, Ordering::Release);
        }
    }

    /// Ensure at least `n` parked workers exist (lazy first spawn, and
    /// growth when a scratch's shard count is raised later).
    fn ensure_workers(&mut self, n: usize) {
        if self.shared.is_none() {
            self.shared = Some(Arc::new(PoolShared {
                gate: Mutex::new(PoolGate {
                    round: 0,
                    job: PoolJob::empty(),
                    shutdown: false,
                }),
                cv: Condvar::new(),
                barrier: SpinBarrier::new(1),
                active: AtomicUsize::new(0),
            }));
        }
        let shared = self.shared.as_ref().unwrap();
        while self.handles.len() < n {
            let idx = self.handles.len();
            let sh = Arc::clone(shared);
            self.handles.push(thread::spawn(move || Self::worker_main(sh, idx)));
        }
    }
}

/// Pool threads are bound to one scratch; a cloned scratch starts with its
/// own (empty, lazily spawned) pool — the same cold-clone rule as
/// [`ShardState`].
impl Clone for WorkerPool {
    fn clone(&self) -> Self {
        WorkerPool::default()
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.handles.len())
            .finish()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        let Some(shared) = self.shared.take() else { return };
        {
            let mut g = shared.gate.lock().unwrap();
            g.shutdown = true;
            shared.cv.notify_all();
        }
        for th in self.handles.drain(..) {
            let _ = th.join();
        }
    }
}

/// One pooled worker's share of an allocation round: parallel op emission,
/// two barrier crossings bracketing the caller's serial bucket/sort/setup
/// window, then the level-lockstep grant sweep of phase 2.
///
/// # Safety
/// `job`'s pointers must be valid for the whole round, the emit slots must
/// be sized for `job.shards` (the caller grows them before publishing),
/// and the caller must confine its `*job.st` mutation to the window
/// between the two barriers (its serial phase), as `allocate_sharded_pooled`
/// does.
unsafe fn pool_round(job: &PoolJob, w: usize, barrier: &SpinBarrier) {
    {
        let st = &*job.st;
        let plan = &*job.plan;
        let flows = std::slice::from_raw_parts(job.flows, job.nflows);
        let coflows = std::slice::from_raw_parts(job.coflows, job.ncoflows);
        emit_chunk(st, plan, flows, coflows, w, job.shards, job.has_groups);
    }
    barrier.wait(); // emission done — caller concatenates + buckets
    barrier.wait(); // caller's serial phase done — tables are ready
    shard_worker(&*job.st, &*job.plan, w, job.shards, job.nports, barrier);
}

/// Reusable workspace for [`allocate_into`]/[`apply_grants`]. Construct once
/// (cheap, empty) and thread through every allocation; all internal tables
/// grow to the working-set high-water mark and are then reused without
/// further heap traffic.
#[derive(Debug, Clone, Default)]
pub struct AllocScratch {
    /// Residual port capacity, reset in place from the fabric per call.
    ledger: CapacityLedger,
    /// Current allocation round; stamps below are valid iff they equal it.
    epoch: u64,
    /// Per-flow stamp: `grant_epoch[f] == epoch` iff flow `f` holds a grant
    /// this round.
    grant_epoch: Vec<u64>,
    /// Per-flow index into `grants` (valid only when the stamp is current) —
    /// the O(1) replacement for the old `grants.iter_mut().find(...)` dedup.
    grant_slot: Vec<u32>,
    /// Flattened `groups × ports` pass-1 budgets.
    budget_up: Vec<f64>,
    budget_down: Vec<f64>,
    /// `(flow, rate)` output of the last [`allocate_into`], priority order.
    grants: Vec<(FlowId, f64)>,
    /// Flows inspected by the last [`allocate_into`].
    visited: usize,
    /// Worker shard count for [`allocate_into`]; 0/1 = serial path.
    shards: usize,
    /// Sharded-pipeline tables (unused while `shards <= 1`).
    shard: ShardState,
    /// Persistent parked workers for the pooled sharded path (module
    /// docs); lazily spawned on the first sharded call.
    pool: WorkerPool,
    /// Use per-call `thread::scope` spawns instead of the pool (the
    /// pre-pool baseline, kept for equivalence pins and benches).
    spawn_workers: bool,
}

impl AllocScratch {
    pub fn new() -> Self {
        AllocScratch { ledger: CapacityLedger::empty(), ..Default::default() }
    }

    /// Set the number of port shards (worker threads) [`allocate_into`]
    /// uses. `0`/`1` selects the serial path. Results are bit-identical for
    /// every setting (see the module docs); only wall time differs — the
    /// parallel path keeps `S − 1` persistent workers parked between calls
    /// and wins on large fabrics only. Raising the count later grows the
    /// pool; lowering it just benches the extra workers.
    pub fn set_shards(&mut self, shards: usize) {
        self.shards = shards;
    }

    /// Route the sharded path through per-call [`std::thread::scope`]
    /// spawns instead of the persistent pool — the pre-pool baseline, kept
    /// selectable so tests can pin the two bit-identical and benches can
    /// measure the pool's entry-cost win. Outputs are identical either
    /// way.
    pub fn set_spawn_workers(&mut self, spawn: bool) {
        self.spawn_workers = spawn;
    }

    /// Configured shard count (≥ 1).
    #[inline]
    pub fn shards(&self) -> usize {
        self.shards.max(1)
    }

    /// Grants of the last allocation round, in priority order.
    #[inline]
    pub fn grants(&self) -> &[(FlowId, f64)] {
        &self.grants
    }

    /// Flows inspected by the last allocation round.
    #[inline]
    pub fn visited(&self) -> usize {
        self.visited
    }

    /// Whether `fid` received a grant in the last allocation round.
    #[inline]
    pub fn was_granted(&self, fid: FlowId) -> bool {
        self.grant_epoch.get(fid).copied() == Some(self.epoch)
    }

    /// Rate granted to `fid` in the last round (0.0 if stalled).
    #[inline]
    pub fn granted_rate(&self, fid: FlowId) -> f64 {
        if self.was_granted(fid) {
            self.grants[self.grant_slot[fid] as usize].1
        } else {
            0.0
        }
    }

    /// Copy the last round out as an owned [`Allocation`] (compat shim).
    pub fn to_allocation(&self) -> Allocation {
        Allocation { grants: self.grants.clone(), visited: self.visited }
    }
}

/// Allocate rates for `plan` (entries highest priority first) against
/// `fabric`, writing the result into `scratch` (see
/// [`AllocScratch::grants`]). Zero heap allocation once the scratch tables
/// have reached their high-water size (serial path; the sharded path's
/// persistent workers are spawned once and woken per call).
///
/// Two passes when bandwidth groups are present: pass 1 walks entries in
/// priority order with each grouped claim capped by its group's per-port
/// budget (`weight × port capacity`); pass 2 backfills the leftovers in the
/// same priority order without budgets (work conservation). Group-free
/// plans collapse to the single greedy pass.
///
/// With [`AllocScratch::set_shards`] ≥ 2 the port-sharded parallel pipeline
/// runs instead; its results (grants, visited count, stamped grant tables)
/// are bit-identical to the serial path (module docs).
pub fn allocate_into(
    fabric: &Fabric,
    flows: &[FlowState],
    coflows: &[CoflowState],
    plan: &Plan,
    scratch: &mut AllocScratch,
) {
    scratch.epoch += 1;
    if scratch.grant_epoch.len() < flows.len() {
        scratch.grant_epoch.resize(flows.len(), 0);
        scratch.grant_slot.resize(flows.len(), 0);
    }
    scratch.ledger.reset(fabric);
    scratch.grants.clear();
    scratch.visited = 0;

    let has_groups = plan.entries.iter().any(|e| e.group.is_some())
        && plan.group_weights.iter().any(|&w| w > 0.0);

    // Clamp to the machine: more spinning workers than cores turns the
    // per-level barriers into scheduler-quantum stalls. Results are
    // bit-identical for every shard count, so clamping is free. The floor
    // of 2 keeps the parallel machinery exercisable (tests) even on
    // single-core boxes — the barrier's yield fallback bounds that cost.
    let shards = if scratch.shards >= 2 {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        scratch.shards.min(fabric.num_ports).min(hw.max(2))
    } else {
        1
    };
    if shards >= 2 && !plan.entries.is_empty() {
        allocate_sharded(fabric, flows, coflows, plan, scratch, has_groups, shards);
    } else {
        allocate_serial(fabric, flows, coflows, plan, scratch, has_groups);
    }
}

/// The serial greedy walk — the reference semantics every other path must
/// reproduce bit for bit.
fn allocate_serial(
    fabric: &Fabric,
    flows: &[FlowState],
    coflows: &[CoflowState],
    plan: &Plan,
    scratch: &mut AllocScratch,
    has_groups: bool,
) {
    let epoch = scratch.epoch;
    // Per-group per-port budgets (pass 1 only), flattened groups-major.
    let nports = fabric.num_ports;
    if has_groups {
        let wsum: f64 = plan.group_weights.iter().sum();
        let need = plan.group_weights.len() * nports;
        if scratch.budget_up.len() < need {
            scratch.budget_up.resize(need, 0.0);
            scratch.budget_down.resize(need, 0.0);
        }
        for (g, &w) in plan.group_weights.iter().enumerate() {
            let frac = w / wsum;
            for p in 0..nports {
                scratch.budget_up[g * nports + p] = fabric.up_capacity[p] * frac;
                scratch.budget_down[g * nports + p] = fabric.down_capacity[p] * frac;
            }
        }
    }

    let mut open_up = fabric.up_capacity.iter().filter(|&&c| c > EPS).count();
    let mut open_down = fabric.down_capacity.iter().filter(|&&c| c > EPS).count();
    let passes: &[bool] = if has_groups { &[true, false] } else { &[false] };

    for &budgeted in passes {
        if open_up == 0 || open_down == 0 {
            break;
        }
        'entries: for e in &plan.entries {
            for &fid in &coflows[e.coflow].active_list {
                if open_up == 0 || open_down == 0 {
                    break 'entries;
                }
                let f = &flows[fid];
                if f.done() {
                    continue;
                }
                match e.filter {
                    FlowFilter::All => {}
                    FlowFilter::PilotsOnly if !f.pilot => continue,
                    FlowFilter::NonPilots if f.pilot => continue,
                    _ => {}
                }
                scratch.visited += 1;
                let up_before = scratch.ledger.up_left(f.src) > EPS;
                let down_before = scratch.ledger.down_left(f.dst) > EPS;
                if !up_before || !down_before {
                    continue;
                }
                let want = if budgeted {
                    match e.group {
                        Some(g) => scratch.budget_up[g * nports + f.src]
                            .min(scratch.budget_down[g * nports + f.dst])
                            .max(0.0),
                        None => f64::INFINITY,
                    }
                } else {
                    f64::INFINITY
                };
                if want <= EPS {
                    continue;
                }
                let granted = scratch.ledger.claim(f.src, f.dst, want);
                if granted > EPS {
                    if scratch.grant_epoch[fid] == epoch {
                        scratch.grants[scratch.grant_slot[fid] as usize].1 += granted;
                    } else {
                        scratch.grant_epoch[fid] = epoch;
                        scratch.grant_slot[fid] = scratch.grants.len() as u32;
                        scratch.grants.push((fid, granted));
                    }
                    if budgeted {
                        if let Some(g) = e.group {
                            scratch.budget_up[g * nports + f.src] -= granted;
                            scratch.budget_down[g * nports + f.dst] -= granted;
                        }
                    }
                }
                if up_before && scratch.ledger.up_left(f.src) <= EPS {
                    open_up -= 1;
                }
                if down_before && scratch.ledger.down_left(f.dst) <= EPS {
                    open_down -= 1;
                }
            }
        }
    }
}

/// The port-sharded parallel pipeline (module docs): emit + bucket →
/// parallel level-lockstep grant → deterministic serial merge.
/// Bit-identical to [`allocate_serial`] for any shard count, on both the
/// pooled (default) and the spawn-per-call worker paths.
fn allocate_sharded(
    fabric: &Fabric,
    flows: &[FlowState],
    coflows: &[CoflowState],
    plan: &Plan,
    scratch: &mut AllocScratch,
    has_groups: bool,
    shards: usize,
) {
    if scratch.spawn_workers {
        allocate_sharded_spawn(fabric, flows, coflows, plan, scratch, has_groups, shards);
    } else {
        allocate_sharded_pooled(fabric, flows, coflows, plan, scratch, has_groups, shards);
    }
}

/// The pre-pool baseline: serial op emission, then `S` scoped workers
/// spawned per call. Kept selectable ([`AllocScratch::set_spawn_workers`])
/// as the bit-identity pin and the bench baseline for the pool's entry
/// cost.
fn allocate_sharded_spawn(
    fabric: &Fabric,
    flows: &[FlowState],
    coflows: &[CoflowState],
    plan: &Plan,
    scratch: &mut AllocScratch,
    has_groups: bool,
    shards: usize,
) {
    let nports = fabric.num_ports;
    let epoch = scratch.epoch;
    let passes: &[bool] = if has_groups { &[true, false] } else { &[false] };

    // ---- phase 1: emit — one serial walk of the plan emits the runnable
    // flows as ops, in exactly the order the serial path would visit them.
    let st = &mut scratch.shard;
    st.ops.clear();
    for &budgeted in passes {
        let pass_bit = if budgeted { BUDGETED_BIT } else { 0 };
        for (ei, e) in plan.entries.iter().enumerate() {
            for &fid in &coflows[e.coflow].active_list {
                let f = &flows[fid];
                if f.done() {
                    continue;
                }
                match e.filter {
                    FlowFilter::All => {}
                    FlowFilter::PilotsOnly if !f.pilot => continue,
                    FlowFilter::NonPilots if f.pilot => continue,
                    _ => {}
                }
                st.ops.push(ShardOp {
                    fid: fid as u32,
                    src: f.src as u32,
                    dst: f.dst as u32,
                    entry: ei as u32 | pass_bit,
                });
            }
        }
    }
    let nops = st.ops.len();
    if nops == 0 {
        return;
    }
    bucket_and_setup(st, fabric, plan, has_groups, shards);

    // ---- phase 2: parallel grant — S shard workers sweep the levels in
    // lockstep; every op's slot in grant_bits is written exactly once.
    {
        let st: &ShardState = st;
        let barrier = SpinBarrier::new(shards);
        std::thread::scope(|scope| {
            for w in 1..shards {
                let barrier = &barrier;
                scope.spawn(move || shard_worker(st, plan, w, shards, nports, barrier));
            }
            shard_worker(st, plan, 0, shards, nports, &barrier);
        });
    }

    merge_grants(fabric, scratch, epoch, nops);
}

/// The pooled sharded path (wake protocol in the module docs): one condvar
/// wake per allocation drives parallel op emission, the caller-serial
/// bucket/sort/setup window, and the level-lockstep grant sweep; the
/// caller then spins the ack counter to zero and merges.
fn allocate_sharded_pooled(
    fabric: &Fabric,
    flows: &[FlowState],
    coflows: &[CoflowState],
    plan: &Plan,
    scratch: &mut AllocScratch,
    has_groups: bool,
    shards: usize,
) {
    let nports = fabric.num_ports;
    let epoch = scratch.epoch;

    scratch.pool.ensure_workers(shards - 1);
    {
        let st = &mut scratch.shard;
        while st.emit.len() < shards {
            st.emit.push(EmitBuf::default());
        }
    }
    let shared = Arc::clone(scratch.pool.shared.as_ref().expect("pool just ensured"));
    // Quiescent between rounds (previous round's ack spin saw 0), so the
    // barrier can be retargeted and the ack counter re-armed safely.
    shared.barrier.set_total(shards);
    shared.active.store(shards - 1, Ordering::Release);
    let st_ptr: *mut ShardState = &mut scratch.shard;
    {
        let mut g = shared.gate.lock().unwrap();
        g.round = g.round.wrapping_add(1);
        g.job = PoolJob {
            st: st_ptr as *const ShardState,
            plan,
            flows: flows.as_ptr(),
            nflows: flows.len(),
            coflows: coflows.as_ptr(),
            ncoflows: coflows.len(),
            shards,
            nports,
            has_groups,
        };
        shared.cv.notify_all();
    }

    // The caller participates as shard 0. SAFETY (for every st_ptr deref
    // below): st_ptr derives from the exclusive &mut scratch borrow, and
    // the barrier protocol keeps caller and worker access disjoint —
    // workers touch only their own emit slot until the second barrier,
    // while the caller's &mut window sits between the barriers.
    emit_chunk(unsafe { &*st_ptr }, plan, flows, coflows, 0, shards, has_groups);
    shared.barrier.wait();

    // ---- serial window: deterministic pass-major concatenation in worker
    // order (byte-identical to the serial emission), then bucket + setup.
    let nops;
    {
        let st = unsafe { &mut *st_ptr };
        st.ops.clear();
        for pass in 0..2 {
            for wi in 0..shards {
                let split = st.emit[wi].split.load(Ordering::Acquire);
                // SAFETY: emission finished at the barrier above; workers
                // do not touch their slots again this round.
                let buf = unsafe { &*st.emit[wi].ops.get() };
                let seg = if pass == 0 { &buf[..split] } else { &buf[split..] };
                st.ops.extend_from_slice(seg);
            }
        }
        nops = st.ops.len();
        if nops == 0 {
            // still release the workers (they run a 0-level sweep)
            st.levels = 0;
        } else {
            bucket_and_setup(st, fabric, plan, has_groups, shards);
        }
    }
    shared.barrier.wait(); // release workers into the grant sweep

    shard_worker(unsafe { &*st_ptr }, plan, 0, shards, nports, &shared.barrier);

    // Wait for every worker's ack before touching the scratch again (and
    // before Drop or the next round could retarget the barrier).
    let mut spins = 0u32;
    while shared.active.load(Ordering::Acquire) != 0 {
        if spins < 1 << 14 {
            std::hint::spin_loop();
            spins += 1;
        } else {
            std::thread::yield_now();
        }
    }

    if nops == 0 {
        return;
    }
    merge_grants(fabric, scratch, epoch, nops);
}

/// Emit worker `w`'s contiguous chunk of the plan's entries — every pass,
/// pass-major — into its own [`EmitBuf`], recording where the second pass
/// begins. Concatenating the buffers pass-major in worker order
/// reproduces the serial emission order exactly.
fn emit_chunk(
    st: &ShardState,
    plan: &Plan,
    flows: &[FlowState],
    coflows: &[CoflowState],
    w: usize,
    shards: usize,
    has_groups: bool,
) {
    let n = plan.entries.len();
    let lo = n * w / shards;
    let hi = n * (w + 1) / shards;
    // SAFETY: slot `w` belongs to worker `w` alone this round (EmitBuf).
    let buf = unsafe { &mut *st.emit[w].ops.get() };
    buf.clear();
    let passes: &[bool] = if has_groups { &[true, false] } else { &[false] };
    let mut split = usize::MAX;
    for (pi, &budgeted) in passes.iter().enumerate() {
        if pi == 1 {
            split = buf.len();
        }
        let pass_bit = if budgeted { BUDGETED_BIT } else { 0 };
        for (off, e) in plan.entries[lo..hi].iter().enumerate() {
            let ei = (lo + off) as u32;
            for &fid in &coflows[e.coflow].active_list {
                let f = &flows[fid];
                if f.done() {
                    continue;
                }
                match e.filter {
                    FlowFilter::All => {}
                    FlowFilter::PilotsOnly if !f.pilot => continue,
                    FlowFilter::NonPilots if f.pilot => continue,
                    _ => {}
                }
                buf.push(ShardOp {
                    fid: fid as u32,
                    src: f.src as u32,
                    dst: f.dst as u32,
                    entry: ei | pass_bit,
                });
            }
        }
    }
    if split == usize::MAX {
        split = buf.len();
    }
    st.emit[w].split.store(split, Ordering::Release);
}

/// Phases 1b + 2-setup of the sharded pipeline, shared by the spawn and
/// pooled paths: DAG levels, the counting sort by `(level, src-shard)`,
/// and the shared residual/budget/grant tables.
fn bucket_and_setup(
    st: &mut ShardState,
    fabric: &Fabric,
    plan: &Plan,
    has_groups: bool,
    shards: usize,
) {
    let nports = fabric.num_ports;
    let nops = st.ops.len();

    // ---- phase 1b: DAG levels + counting sort by (level, src-shard).
    // Ops in one level touch pairwise-disjoint ports, so they can execute
    // concurrently without reordering any port's operation sequence.
    if st.next_up.len() < nports {
        st.next_up.resize(nports, 0);
        st.next_down.resize(nports, 0);
    }
    st.next_up[..nports].fill(0);
    st.next_down[..nports].fill(0);
    if st.keys.len() < nops {
        st.keys.resize(nops, 0);
    }
    let mut max_level = 0u32;
    for i in 0..nops {
        let op = st.ops[i];
        let (s, d) = (op.src as usize, op.dst as usize);
        let lvl = st.next_up[s].max(st.next_down[d]);
        st.next_up[s] = lvl + 1;
        st.next_down[d] = lvl + 1;
        max_level = max_level.max(lvl);
        st.keys[i] = lvl * shards as u32 + port_shard(s, nports, shards) as u32;
    }
    let levels = max_level as usize + 1;
    st.levels = levels;
    let nbuckets = levels * shards;
    if st.bucket_start.len() < nbuckets + 1 {
        st.bucket_start.resize(nbuckets + 1, 0);
        st.bucket_cursor.resize(nbuckets + 1, 0);
    }
    st.bucket_start[..nbuckets + 1].fill(0);
    for i in 0..nops {
        st.bucket_start[st.keys[i] as usize + 1] += 1;
    }
    for b in 0..nbuckets {
        st.bucket_start[b + 1] += st.bucket_start[b];
    }
    st.bucket_cursor[..nbuckets + 1].copy_from_slice(&st.bucket_start[..nbuckets + 1]);
    if st.order.len() < nops {
        st.order.resize(nops, 0);
    }
    for i in 0..nops {
        let k = st.keys[i] as usize;
        let pos = st.bucket_cursor[k] as usize;
        st.bucket_cursor[k] += 1;
        st.order[pos] = i as u32;
    }

    // ---- phase 2 setup: shared residual/budget tables as f64 bits.
    grow_bits(&mut st.up_bits, nports);
    grow_bits(&mut st.down_bits, nports);
    for p in 0..nports {
        st.up_bits[p].store(fabric.up_capacity[p].to_bits(), Ordering::Relaxed);
        st.down_bits[p].store(fabric.down_capacity[p].to_bits(), Ordering::Relaxed);
    }
    if has_groups {
        let wsum: f64 = plan.group_weights.iter().sum();
        let need = plan.group_weights.len() * nports;
        grow_bits(&mut st.budget_up_bits, need);
        grow_bits(&mut st.budget_down_bits, need);
        for (g, &w) in plan.group_weights.iter().enumerate() {
            let frac = w / wsum;
            for p in 0..nports {
                st.budget_up_bits[g * nports + p]
                    .store((fabric.up_capacity[p] * frac).to_bits(), Ordering::Relaxed);
                st.budget_down_bits[g * nports + p]
                    .store((fabric.down_capacity[p] * frac).to_bits(), Ordering::Relaxed);
            }
        }
    }
    grow_bits(&mut st.grant_bits, nops);
}

/// Phase 3 — deterministic merge (module docs): replay the ops in plan
/// order against the (freshly reset) ledger to rebuild the canonical
/// grants list, the visited count, and the serial early exit.
fn merge_grants(fabric: &Fabric, scratch: &mut AllocScratch, epoch: u64, nops: usize) {
    let mut open_up = fabric.up_capacity.iter().filter(|&&c| c > EPS).count();
    let mut open_down = fabric.down_capacity.iter().filter(|&&c| c > EPS).count();
    for i in 0..nops {
        if open_up == 0 || open_down == 0 {
            break;
        }
        scratch.visited += 1;
        let granted = f64::from_bits(scratch.shard.grant_bits[i].load(Ordering::Relaxed));
        if granted > EPS {
            let op = scratch.shard.ops[i];
            let (src, dst) = (op.src as usize, op.dst as usize);
            // same claim arithmetic as the serial path (granted ≤ residual
            // by construction, so the clamp is a bit-exact no-op)
            scratch.ledger.claim(src, dst, granted);
            let fid = op.fid as usize;
            if scratch.grant_epoch[fid] == epoch {
                scratch.grants[scratch.grant_slot[fid] as usize].1 += granted;
            } else {
                scratch.grant_epoch[fid] = epoch;
                scratch.grant_slot[fid] = scratch.grants.len() as u32;
                scratch.grants.push((fid, granted));
            }
            if scratch.ledger.up_left(src) <= EPS {
                open_up -= 1;
            }
            if scratch.ledger.down_left(dst) <= EPS {
                open_down -= 1;
            }
        }
    }
}

/// One shard worker of the parallel grant phase: processes, level by level,
/// the ops whose src port falls in shard `w`. Within a level all ports are
/// distinct across *all* ops, so the relaxed atomic loads/stores are
/// data-race-free by construction; the barrier publishes each level's
/// stores to the next.
fn shard_worker(
    st: &ShardState,
    plan: &Plan,
    w: usize,
    shards: usize,
    nports: usize,
    barrier: &SpinBarrier,
) {
    for lvl in 0..st.levels {
        let b = lvl * shards + w;
        let lo = st.bucket_start[b] as usize;
        let hi = st.bucket_start[b + 1] as usize;
        for &opi in &st.order[lo..hi] {
            let opi = opi as usize;
            let op = st.ops[opi];
            let (src, dst) = (op.src as usize, op.dst as usize);
            let up = f64::from_bits(st.up_bits[src].load(Ordering::Relaxed));
            let down = f64::from_bits(st.down_bits[dst].load(Ordering::Relaxed));
            // serial gate: both residual directions must exceed EPS
            if up.max(0.0) <= EPS || down.max(0.0) <= EPS {
                st.grant_bits[opi].store(0, Ordering::Relaxed);
                continue;
            }
            let budgeted = op.entry & BUDGETED_BIT != 0;
            let group = plan.entries[(op.entry & !BUDGETED_BIT) as usize].group;
            let want = if budgeted {
                match group {
                    Some(g) => {
                        let bu = f64::from_bits(
                            st.budget_up_bits[g * nports + src].load(Ordering::Relaxed),
                        );
                        let bd = f64::from_bits(
                            st.budget_down_bits[g * nports + dst].load(Ordering::Relaxed),
                        );
                        bu.min(bd).max(0.0)
                    }
                    None => f64::INFINITY,
                }
            } else {
                f64::INFINITY
            };
            if want <= EPS {
                st.grant_bits[opi].store(0, Ordering::Relaxed);
                continue;
            }
            // CapacityLedger::claim, bit for bit
            let available = up.min(down).max(0.0);
            let granted = want.min(available).max(0.0);
            st.up_bits[src].store((up - granted).to_bits(), Ordering::Relaxed);
            st.down_bits[dst].store((down - granted).to_bits(), Ordering::Relaxed);
            if granted > EPS && budgeted {
                if let Some(g) = group {
                    let bup = &st.budget_up_bits[g * nports + src];
                    let bu = f64::from_bits(bup.load(Ordering::Relaxed));
                    bup.store((bu - granted).to_bits(), Ordering::Relaxed);
                    let bdn = &st.budget_down_bits[g * nports + dst];
                    let bd = f64::from_bits(bdn.load(Ordering::Relaxed));
                    bdn.store((bd - granted).to_bits(), Ordering::Relaxed);
                }
            }
            st.grant_bits[opi].store(granted.to_bits(), Ordering::Relaxed);
        }
        barrier.wait();
    }
}

/// Compatibility wrapper: allocate with a fresh scratch and return an owned
/// [`Allocation`]. Prefer [`allocate_into`] with a persistent
/// [`AllocScratch`] on hot paths.
pub fn allocate(
    fabric: &Fabric,
    flows: &[FlowState],
    coflows: &[CoflowState],
    plan: &Plan,
) -> Allocation {
    let mut scratch = AllocScratch::new();
    allocate_into(fabric, flows, coflows, plan, &mut scratch);
    Allocation { grants: scratch.grants, visited: scratch.visited }
}

/// Apply a grants list to the flow table: set granted rates, zero every
/// other active rate of the ordered coflows. Returns the number of flows
/// whose rate changed (the count of `new rate` messages the coordinator
/// must push to agents — the Table 3 “New Rate Send” column).
///
/// Allocation-free: instead of a per-call lookup table, granted flows are
/// tagged in place via [`FlowState::alloc_mark`] (pass 1), the plan walk
/// zeroes untagged flows (pass 2), and the tags are cleared again (pass 3).
/// Only flows whose rate actually changed are written.
pub fn apply_grants(
    flows: &mut [FlowState],
    coflows: &[CoflowState],
    plan: &Plan,
    grants: &[(FlowId, f64)],
) -> usize {
    let mut changed = 0;
    for &(fid, r) in grants {
        let f = &mut flows[fid];
        if (f.rate - r).abs() > EPS {
            changed += 1;
            f.rate = r;
        }
        f.alloc_mark = true;
    }
    for e in &plan.entries {
        for &fid in &coflows[e.coflow].active_list {
            let f = &mut flows[fid];
            if !f.alloc_mark {
                if f.rate.abs() > EPS {
                    changed += 1;
                }
                f.rate = 0.0;
            }
        }
    }
    for &(fid, _) in grants {
        flows[fid].alloc_mark = false;
    }
    changed
}

/// Compatibility wrapper over [`apply_grants`] taking an [`Allocation`].
pub fn apply(
    flows: &mut [FlowState],
    coflows: &[CoflowState],
    plan: &Plan,
    alloc: &Allocation,
) -> usize {
    apply_grants(flows, coflows, plan, &alloc.grants)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::Fabric;

    fn setup(flow_defs: &[(usize, usize, f64)]) -> (Vec<FlowState>, Vec<CoflowState>) {
        // each flow is its own coflow for simple ordering tests
        let mut flows = Vec::new();
        let mut coflows = Vec::new();
        for (i, &(src, dst, size)) in flow_defs.iter().enumerate() {
            flows.push(FlowState::new(i, i, src, dst, size));
            coflows.push(CoflowState::new(i, 0.0, vec![i], size, i as u64));
        }
        (flows, coflows)
    }

    fn entries(n: usize) -> Plan {
        Plan::strict(0..n)
    }

    #[test]
    fn priority_order_wins() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        let alloc = allocate(&fabric, &flows, &coflows, &entries(2));
        assert_eq!(alloc.grants, vec![(0, 100.0)]);
    }

    #[test]
    fn grouped_entries_share_by_weight() {
        // two coflows on the same pair in different groups with weights
        // 2:1 → pass 1 splits the port 2/3 vs 1/3 (then pass 2 has nothing
        // left to backfill).
        let fabric = Fabric::homogeneous(2, 90.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        let plan = Plan {
            entries: vec![OrderEntry::grouped(0, 0), OrderEntry::grouped(1, 1)],
            group_weights: vec![2.0, 1.0],
        };
        let alloc = allocate(&fabric, &flows, &coflows, &plan);
        assert_eq!(alloc.grants, vec![(0, 60.0), (1, 30.0)]);
    }

    #[test]
    fn grouped_backfill_is_work_conserving() {
        // only group 1 has a runnable flow: pass 1 gives it its 1/3 share,
        // pass 2 tops it up to the full port — and the two grants must be
        // merged into one entry by the stamped dedup.
        let fabric = Fabric::homogeneous(2, 90.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0)]);
        let plan = Plan {
            entries: vec![OrderEntry::grouped(0, 1)],
            group_weights: vec![2.0, 1.0],
        };
        let alloc = allocate(&fabric, &flows, &coflows, &plan);
        assert_eq!(alloc.grants, vec![(0, 90.0)]);
    }

    #[test]
    fn work_conservation_backfill() {
        let fabric = Fabric::homogeneous(4, 100.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (2, 3, 10.0)]);
        let alloc = allocate(&fabric, &flows, &coflows, &entries(2));
        assert_eq!(alloc.grants.len(), 2);
        assert_eq!(alloc.total_rate(), 200.0);
    }

    #[test]
    fn no_port_oversubscription() {
        let fabric = Fabric::homogeneous(3, 100.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (0, 2, 10.0), (2, 1, 10.0)]);
        let alloc = allocate(&fabric, &flows, &coflows, &entries(3));
        let mut up = vec![0.0; 3];
        let mut down = vec![0.0; 3];
        for &(fid, r) in &alloc.grants {
            up[flows[fid].src] += r;
            down[flows[fid].dst] += r;
        }
        for p in 0..3 {
            assert!(up[p] <= 100.0 + 1e-9);
            assert!(down[p] <= 100.0 + 1e-9);
        }
        assert_eq!(alloc.grants, vec![(0, 100.0)]);
    }

    #[test]
    fn early_exit_on_saturation() {
        let fabric = Fabric::homogeneous(1, 100.0);
        let (flows, coflows) = setup(&(0..1000).map(|_| (0, 0, 1.0)).collect::<Vec<_>>());
        let alloc = allocate(&fabric, &flows, &coflows, &entries(1000));
        assert_eq!(alloc.grants.len(), 1);
        assert!(alloc.visited <= 2, "visited {} flows", alloc.visited);
    }

    #[test]
    fn skips_done_flows() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let (mut flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        flows[0].sent = 10.0;
        let alloc = allocate(&fabric, &flows, &coflows, &entries(2));
        assert_eq!(alloc.grants, vec![(1, 100.0)]);
    }

    #[test]
    fn pilot_lane_filters() {
        let fabric = Fabric::homogeneous(4, 100.0);
        let mut flows = vec![
            FlowState::new(0, 0, 0, 2, 10.0),
            FlowState::new(1, 0, 1, 3, 10.0),
        ];
        flows[0].pilot = true;
        let coflows = vec![CoflowState::new(0, 0.0, vec![0, 1], 20.0, 0)];
        let pilot_plan = Plan { entries: vec![OrderEntry::pilots(0)], group_weights: vec![] };
        let pilots = allocate(&fabric, &flows, &coflows, &pilot_plan);
        assert_eq!(pilots.grants, vec![(0, 100.0)]);
        let rest_plan = Plan { entries: vec![OrderEntry::backfill(0)], group_weights: vec![] };
        let rest = allocate(&fabric, &flows, &coflows, &rest_plan);
        assert_eq!(rest.grants, vec![(1, 100.0)]);
    }

    #[test]
    fn apply_counts_rate_changes() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let (mut flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        let order = entries(2);
        let alloc = allocate(&fabric, &flows, &coflows, &order);
        let changed = apply(&mut flows, &coflows, &order, &alloc);
        assert_eq!(changed, 1); // only flow 0 started
        assert_eq!(flows[0].rate, 100.0);
        assert_eq!(flows[1].rate, 0.0);
        assert!(flows.iter().all(|f| !f.alloc_mark), "marks must be cleared");
        // re-applying the identical allocation changes nothing
        let alloc2 = allocate(&fabric, &flows, &coflows, &order);
        let changed2 = apply(&mut flows, &coflows, &order, &alloc2);
        assert_eq!(changed2, 0);
    }

    #[test]
    fn scratch_reuse_matches_fresh_allocation() {
        let fabric = Fabric::homogeneous(4, 100.0);
        let (flows, coflows) =
            setup(&[(0, 1, 10.0), (0, 2, 10.0), (2, 3, 10.0), (3, 1, 10.0)]);
        let plan = entries(4);
        let mut scratch = AllocScratch::new();
        for _ in 0..3 {
            allocate_into(&fabric, &flows, &coflows, &plan, &mut scratch);
            let fresh = allocate(&fabric, &flows, &coflows, &plan);
            assert_eq!(scratch.grants(), &fresh.grants[..]);
            assert_eq!(scratch.visited(), fresh.visited);
        }
    }

    #[test]
    fn scratch_grant_queries() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        let mut scratch = AllocScratch::new();
        allocate_into(&fabric, &flows, &coflows, &entries(2), &mut scratch);
        assert!(scratch.was_granted(0));
        assert!(!scratch.was_granted(1));
        assert_eq!(scratch.granted_rate(0), 100.0);
        assert_eq!(scratch.granted_rate(1), 0.0);
        // next round invalidates the previous stamps wholesale
        let empty = Plan::default();
        allocate_into(&fabric, &flows, &coflows, &empty, &mut scratch);
        assert!(!scratch.was_granted(0));
        assert_eq!(scratch.grants().len(), 0);
    }

    /// Run `plan` through the serial path and through every shard count —
    /// on both the persistent-pool and the spawn-per-call worker paths —
    /// asserting bit-identical outputs (the in-module smoke version of
    /// `tests/shard_equivalence.rs`).
    fn assert_sharded_matches_serial(
        fabric: &Fabric,
        flows: &[FlowState],
        coflows: &[CoflowState],
        plan: &Plan,
    ) {
        let mut serial = AllocScratch::new();
        allocate_into(fabric, flows, coflows, plan, &mut serial);
        for s in [1usize, 2, 3, 4, 8] {
            for spawn in [false, true] {
                let mut sharded = AllocScratch::new();
                sharded.set_shards(s);
                sharded.set_spawn_workers(spawn);
                // twice: the reused tables (and the parked pool) must stay
                // exact across rounds
                for round in 0..2 {
                    allocate_into(fabric, flows, coflows, plan, &mut sharded);
                    assert_eq!(
                        sharded.grants().len(),
                        serial.grants().len(),
                        "S={s} spawn={spawn} round {round}: grant count"
                    );
                    for (a, b) in sharded.grants().iter().zip(serial.grants()) {
                        assert_eq!(a.0, b.0, "S={s} spawn={spawn}: flow id");
                        assert_eq!(
                            a.1.to_bits(),
                            b.1.to_bits(),
                            "S={s} spawn={spawn}: rate bits for flow {}",
                            a.0
                        );
                    }
                    assert_eq!(sharded.visited(), serial.visited(), "S={s} spawn={spawn}: visited");
                    for f in 0..flows.len() {
                        assert_eq!(
                            sharded.was_granted(f),
                            serial.was_granted(f),
                            "S={s} spawn={spawn}: flow {f}"
                        );
                        assert_eq!(
                            sharded.granted_rate(f).to_bits(),
                            serial.granted_rate(f).to_bits(),
                            "S={s} spawn={spawn}: rate of flow {f}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn pooled_scratch_survives_shard_count_changes() {
        // One scratch, shard count raised/lowered/toggled across calls:
        // the pool grows in place, sits excess workers out, and keeps
        // producing bit-identical grants the whole time.
        let fabric = Fabric::homogeneous(6, 100.0);
        let (flows, coflows) = setup(&[
            (0, 1, 10.0),
            (0, 2, 10.0),
            (2, 1, 10.0),
            (3, 4, 10.0),
            (5, 0, 10.0),
            (4, 5, 10.0),
        ]);
        let plan = entries(6);
        let mut serial = AllocScratch::new();
        allocate_into(&fabric, &flows, &coflows, &plan, &mut serial);
        let mut pooled = AllocScratch::new();
        for (i, &s) in [2usize, 8, 3, 1, 2, 4].iter().enumerate() {
            pooled.set_shards(s);
            pooled.set_spawn_workers(i == 3); // one spawn-path round mid-life
            allocate_into(&fabric, &flows, &coflows, &plan, &mut pooled);
            assert_eq!(pooled.grants().len(), serial.grants().len(), "call {i} (S={s})");
            for (a, b) in pooled.grants().iter().zip(serial.grants()) {
                assert_eq!(a.0, b.0, "call {i} (S={s}): flow id");
                assert_eq!(a.1.to_bits(), b.1.to_bits(), "call {i} (S={s}): rate bits");
            }
        }
    }

    #[test]
    fn cloned_scratch_pool_starts_cold_and_works() {
        let fabric = Fabric::homogeneous(4, 100.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (2, 3, 10.0), (1, 2, 10.0)]);
        let plan = entries(3);
        let mut warm = AllocScratch::new();
        warm.set_shards(4);
        allocate_into(&fabric, &flows, &coflows, &plan, &mut warm);
        let mut cloned = warm.clone();
        allocate_into(&fabric, &flows, &coflows, &plan, &mut cloned);
        assert_eq!(warm.grants(), cloned.grants());
    }

    #[test]
    fn sharded_matches_serial_strict_priority() {
        let fabric = Fabric::homogeneous(6, 100.0);
        let (flows, coflows) = setup(&[
            (0, 1, 10.0),
            (0, 2, 10.0),
            (2, 1, 10.0),
            (3, 4, 10.0),
            (5, 0, 10.0),
            (4, 5, 10.0),
        ]);
        assert_sharded_matches_serial(&fabric, &flows, &coflows, &entries(6));
    }

    #[test]
    fn sharded_matches_serial_with_groups_and_backfill() {
        let fabric = Fabric::homogeneous(4, 90.0);
        let (flows, coflows) =
            setup(&[(0, 1, 10.0), (0, 1, 10.0), (2, 3, 10.0), (1, 2, 10.0)]);
        let plan = Plan {
            entries: vec![
                OrderEntry::grouped(0, 0),
                OrderEntry::grouped(1, 1),
                OrderEntry::grouped(2, 0),
                OrderEntry::all(3),
            ],
            group_weights: vec![2.0, 1.0],
        };
        assert_sharded_matches_serial(&fabric, &flows, &coflows, &plan);
    }

    #[test]
    fn sharded_matches_serial_on_saturating_chain() {
        // 1000 flows hammering one pair: the early-exit/visited bookkeeping
        // must match the serial break behavior exactly.
        let fabric = Fabric::homogeneous(2, 100.0);
        let (flows, coflows) =
            setup(&(0..1000).map(|_| (0, 1, 1.0)).collect::<Vec<_>>());
        assert_sharded_matches_serial(&fabric, &flows, &coflows, &entries(1000));
    }

    #[test]
    fn sharded_handles_zero_capacity_ports() {
        let fabric = Fabric {
            num_ports: 4,
            up_capacity: vec![100.0, 0.0, 50.0, 100.0],
            down_capacity: vec![100.0, 100.0, 0.0, 25.0],
        };
        let (flows, coflows) = setup(&[
            (1, 0, 10.0), // dead uplink
            (0, 2, 10.0), // dead downlink
            (2, 3, 10.0),
            (3, 1, 10.0),
            (0, 3, 10.0),
        ]);
        assert_sharded_matches_serial(&fabric, &flows, &coflows, &entries(5));
    }

    #[test]
    fn apply_grants_zeroes_only_planned_flows() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let (mut flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        flows[1].rate = 55.0; // stale rate on the flow the plan covers
        let alloc = allocate(&fabric, &flows, &coflows, &entries(2));
        let changed = apply(&mut flows, &coflows, &entries(2), &alloc);
        assert_eq!(changed, 2); // flow 0 gained 100, flow 1 lost 55
        assert_eq!(flows[1].rate, 0.0);
    }
}
