//! Rate allocation: turn a priority order over coflows into per-flow rates.
//!
//! Greedy max-min in priority order: walk the coflows highest-priority
//! first (flows of one coflow contiguous — Saath's all-or-none) and grant
//! each unfinished flow the full residual `min(uplink(src), downlink(dst))`.
//! Properties:
//!
//! * **Feasible** — per-port rate sums never exceed capacity (the ledger
//!   clamps every claim).
//! * **Work-conserving** — lower-priority entries absorb whatever the
//!   higher-priority ones leave (Philae's unestimated non-pilot flows sit
//!   at the tail of the order and soak up leftovers).
//! * **Cheap** — every grant saturates at least one port direction, so at
//!   most `2·P` flows receive non-zero rate; the walk early-exits once all
//!   directions are saturated, and iterates each coflow's engine-maintained
//!   `active_list` so finished flows of wide coflows cost nothing.

use crate::coflow::{CoflowState, FlowState};
use crate::fabric::{CapacityLedger, Fabric};
use crate::{CoflowId, FlowId, EPS};

/// Which of a coflow's flows an order entry admits — Philae's lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlowFilter {
    /// Every unfinished flow.
    All,
    /// Only the pilot flows (Philae's sampling lane).
    PilotsOnly,
    /// Only non-pilot flows (Philae's backfill lane).
    NonPilots,
}

/// One priority-order entry: a coflow, the lane filter to apply, and an
/// optional bandwidth group (Aalo-style queues with fixed weighted shares).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrderEntry {
    pub coflow: CoflowId,
    pub filter: FlowFilter,
    /// `Some(q)` assigns the entry to bandwidth group `q` (see
    /// [`Plan::group_weights`]); `None` means strict priority.
    pub group: Option<usize>,
}

impl OrderEntry {
    pub fn all(coflow: CoflowId) -> Self {
        OrderEntry { coflow, filter: FlowFilter::All, group: None }
    }

    pub fn pilots(coflow: CoflowId) -> Self {
        OrderEntry { coflow, filter: FlowFilter::PilotsOnly, group: None }
    }

    pub fn backfill(coflow: CoflowId) -> Self {
        OrderEntry { coflow, filter: FlowFilter::NonPilots, group: None }
    }

    pub fn grouped(coflow: CoflowId, group: usize) -> Self {
        OrderEntry { coflow, filter: FlowFilter::All, group: Some(group) }
    }
}

/// A full scheduling plan: the priority order plus the bandwidth weights of
/// any groups referenced by entries. Weights are normalized internally;
/// groups model Aalo/Saath's "each queue receives a fixed bandwidth share
/// at every port" semantics (paper §1.1). Strict-priority entries
/// (`group: None`) are unbudgeted.
#[derive(Debug, Clone, Default)]
pub struct Plan {
    pub entries: Vec<OrderEntry>,
    pub group_weights: Vec<f64>,
}

impl Plan {
    /// Strict-priority plan over whole coflows.
    pub fn strict(coflows: impl IntoIterator<Item = CoflowId>) -> Self {
        Plan {
            entries: coflows.into_iter().map(OrderEntry::all).collect(),
            group_weights: Vec::new(),
        }
    }
}

/// Result of one allocation pass.
#[derive(Debug, Clone, Default)]
pub struct Allocation {
    /// `(flow, rate)` for every flow granted a non-zero rate, in priority
    /// order. Flows not listed are implicitly stalled (rate 0).
    pub grants: Vec<(FlowId, f64)>,
    /// Number of flows inspected (profiling: walk cost).
    pub visited: usize,
}

impl Allocation {
    /// Total allocated rate (bytes/sec).
    pub fn total_rate(&self) -> f64 {
        self.grants.iter().map(|(_, r)| r).sum()
    }
}

/// Allocate rates for `plan` (entries highest priority first) against
/// `fabric`.
///
/// Two passes when bandwidth groups are present: pass 1 walks entries in
/// priority order with each grouped claim capped by its group's per-port
/// budget (`weight × port capacity`); pass 2 backfills the leftovers in the
/// same priority order without budgets (work conservation). Group-free
/// plans collapse to the single greedy pass.
pub fn allocate(
    fabric: &Fabric,
    flows: &[FlowState],
    coflows: &[CoflowState],
    plan: &Plan,
) -> Allocation {
    let mut ledger = CapacityLedger::new(fabric);
    let mut grants: Vec<(FlowId, f64)> = Vec::with_capacity((2 * fabric.num_ports).min(1024));
    let mut visited = 0usize;
    let has_groups = plan.entries.iter().any(|e| e.group.is_some())
        && plan.group_weights.iter().any(|&w| w > 0.0);

    // Per-group per-port budgets (pass 1 only).
    let wsum: f64 = plan.group_weights.iter().sum();
    let mut budget_up: Vec<Vec<f64>> = Vec::new();
    let mut budget_down: Vec<Vec<f64>> = Vec::new();
    if has_groups {
        for &w in &plan.group_weights {
            let frac = w / wsum;
            budget_up.push(fabric.up_capacity.iter().map(|c| c * frac).collect());
            budget_down.push(fabric.down_capacity.iter().map(|c| c * frac).collect());
        }
    }

    let mut open_up = fabric.up_capacity.iter().filter(|&&c| c > EPS).count();
    let mut open_down = fabric.down_capacity.iter().filter(|&&c| c > EPS).count();
    let passes: &[bool] = if has_groups { &[true, false] } else { &[false] };

    for &budgeted in passes {
        if open_up == 0 || open_down == 0 {
            break;
        }
        'entries: for e in &plan.entries {
            for &fid in &coflows[e.coflow].active_list {
                if open_up == 0 || open_down == 0 {
                    break 'entries;
                }
                let f = &flows[fid];
                if f.done() {
                    continue;
                }
                match e.filter {
                    FlowFilter::All => {}
                    FlowFilter::PilotsOnly if !f.pilot => continue,
                    FlowFilter::NonPilots if f.pilot => continue,
                    _ => {}
                }
                visited += 1;
                let up_before = ledger.up_left(f.src) > EPS;
                let down_before = ledger.down_left(f.dst) > EPS;
                if !up_before || !down_before {
                    continue;
                }
                let want = if budgeted {
                    match e.group {
                        Some(g) => budget_up[g][f.src].min(budget_down[g][f.dst]).max(0.0),
                        None => f64::INFINITY,
                    }
                } else {
                    f64::INFINITY
                };
                if want <= EPS {
                    continue;
                }
                let granted = ledger.claim(f.src, f.dst, want);
                if granted > EPS {
                    match grants.iter_mut().find(|(id, _)| *id == fid) {
                        Some(g) => g.1 += granted,
                        None => grants.push((fid, granted)),
                    }
                    if budgeted {
                        if let Some(g) = e.group {
                            budget_up[g][f.src] -= granted;
                            budget_down[g][f.dst] -= granted;
                        }
                    }
                }
                if up_before && ledger.up_left(f.src) <= EPS {
                    open_up -= 1;
                }
                if down_before && ledger.down_left(f.dst) <= EPS {
                    open_down -= 1;
                }
            }
        }
    }
    Allocation { grants, visited }
}

/// Apply an allocation to the flow table: zero every active rate of the
/// ordered coflows, then set the granted rates. Returns the number of flows
/// whose rate changed (the count of `new rate` messages the coordinator
/// must push to agents — the Table 3 “New Rate Send” column).
pub fn apply(
    flows: &mut [FlowState],
    coflows: &[CoflowState],
    plan: &Plan,
    alloc: &Allocation,
) -> usize {
    let granted: std::collections::HashMap<FlowId, f64> =
        alloc.grants.iter().copied().collect();
    let mut changed = 0;
    for e in &plan.entries {
        for &fid in &coflows[e.coflow].active_list {
            let new = granted.get(&fid).copied().unwrap_or(0.0);
            if (flows[fid].rate - new).abs() > EPS {
                changed += 1;
            }
            flows[fid].rate = new;
        }
    }
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::Fabric;

    fn setup(flow_defs: &[(usize, usize, f64)]) -> (Vec<FlowState>, Vec<CoflowState>) {
        // each flow is its own coflow for simple ordering tests
        let mut flows = Vec::new();
        let mut coflows = Vec::new();
        for (i, &(src, dst, size)) in flow_defs.iter().enumerate() {
            flows.push(FlowState::new(i, i, src, dst, size));
            coflows.push(CoflowState::new(i, 0.0, vec![i], size, i as u64));
        }
        (flows, coflows)
    }

    fn entries(n: usize) -> Plan {
        Plan::strict(0..n)
    }

    #[test]
    fn priority_order_wins() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        let alloc = allocate(&fabric, &flows, &coflows, &entries(2));
        assert_eq!(alloc.grants, vec![(0, 100.0)]);
    }

    #[test]
    fn grouped_entries_share_by_weight() {
        // two coflows on the same pair in different groups with weights
        // 2:1 → pass 1 splits the port 2/3 vs 1/3 (then pass 2 has nothing
        // left to backfill).
        let fabric = Fabric::homogeneous(2, 90.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        let plan = Plan {
            entries: vec![OrderEntry::grouped(0, 0), OrderEntry::grouped(1, 1)],
            group_weights: vec![2.0, 1.0],
        };
        let alloc = allocate(&fabric, &flows, &coflows, &plan);
        assert_eq!(alloc.grants, vec![(0, 60.0), (1, 30.0)]);
    }

    #[test]
    fn grouped_backfill_is_work_conserving() {
        // only group 1 has a runnable flow: pass 1 gives it its 1/3 share,
        // pass 2 tops it up to the full port.
        let fabric = Fabric::homogeneous(2, 90.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0)]);
        let plan = Plan {
            entries: vec![OrderEntry::grouped(0, 1)],
            group_weights: vec![2.0, 1.0],
        };
        let alloc = allocate(&fabric, &flows, &coflows, &plan);
        assert_eq!(alloc.grants, vec![(0, 90.0)]);
    }

    #[test]
    fn work_conservation_backfill() {
        let fabric = Fabric::homogeneous(4, 100.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (2, 3, 10.0)]);
        let alloc = allocate(&fabric, &flows, &coflows, &entries(2));
        assert_eq!(alloc.grants.len(), 2);
        assert_eq!(alloc.total_rate(), 200.0);
    }

    #[test]
    fn no_port_oversubscription() {
        let fabric = Fabric::homogeneous(3, 100.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (0, 2, 10.0), (2, 1, 10.0)]);
        let alloc = allocate(&fabric, &flows, &coflows, &entries(3));
        let mut up = vec![0.0; 3];
        let mut down = vec![0.0; 3];
        for &(fid, r) in &alloc.grants {
            up[flows[fid].src] += r;
            down[flows[fid].dst] += r;
        }
        for p in 0..3 {
            assert!(up[p] <= 100.0 + 1e-9);
            assert!(down[p] <= 100.0 + 1e-9);
        }
        assert_eq!(alloc.grants, vec![(0, 100.0)]);
    }

    #[test]
    fn early_exit_on_saturation() {
        let fabric = Fabric::homogeneous(1, 100.0);
        let (flows, coflows) = setup(&(0..1000).map(|_| (0, 0, 1.0)).collect::<Vec<_>>());
        let alloc = allocate(&fabric, &flows, &coflows, &entries(1000));
        assert_eq!(alloc.grants.len(), 1);
        assert!(alloc.visited <= 2, "visited {} flows", alloc.visited);
    }

    #[test]
    fn skips_done_flows() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let (mut flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        flows[0].sent = 10.0;
        let alloc = allocate(&fabric, &flows, &coflows, &entries(2));
        assert_eq!(alloc.grants, vec![(1, 100.0)]);
    }

    #[test]
    fn pilot_lane_filters() {
        let fabric = Fabric::homogeneous(4, 100.0);
        let mut flows = vec![
            FlowState::new(0, 0, 0, 2, 10.0),
            FlowState::new(1, 0, 1, 3, 10.0),
        ];
        flows[0].pilot = true;
        let coflows = vec![CoflowState::new(0, 0.0, vec![0, 1], 20.0, 0)];
        let pilot_plan = Plan { entries: vec![OrderEntry::pilots(0)], group_weights: vec![] };
        let pilots = allocate(&fabric, &flows, &coflows, &pilot_plan);
        assert_eq!(pilots.grants, vec![(0, 100.0)]);
        let rest_plan = Plan { entries: vec![OrderEntry::backfill(0)], group_weights: vec![] };
        let rest = allocate(&fabric, &flows, &coflows, &rest_plan);
        assert_eq!(rest.grants, vec![(1, 100.0)]);
    }

    #[test]
    fn apply_counts_rate_changes() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let (mut flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        let order = entries(2);
        let alloc = allocate(&fabric, &flows, &coflows, &order);
        let changed = apply(&mut flows, &coflows, &order, &alloc);
        assert_eq!(changed, 1); // only flow 0 started
        assert_eq!(flows[0].rate, 100.0);
        assert_eq!(flows[1].rate, 0.0);
        // re-applying the identical allocation changes nothing
        let alloc2 = allocate(&fabric, &flows, &coflows, &order);
        let changed2 = apply(&mut flows, &coflows, &order, &alloc2);
        assert_eq!(changed2, 0);
    }
}
