//! Rate allocation: turn a priority order over coflows into per-flow rates.
//!
//! Greedy max-min in priority order: walk the coflows highest-priority
//! first (flows of one coflow contiguous — Saath's all-or-none) and grant
//! each unfinished flow the full residual `min(uplink(src), downlink(dst))`.
//! Properties:
//!
//! * **Feasible** — per-port rate sums never exceed capacity (the ledger
//!   clamps every claim).
//! * **Work-conserving** — lower-priority entries absorb whatever the
//!   higher-priority ones leave (Philae's unestimated non-pilot flows sit
//!   at the tail of the order and soak up leftovers).
//! * **Cheap** — every grant saturates at least one port direction, so at
//!   most `2·P` flows receive non-zero rate; the walk early-exits once all
//!   directions are saturated, and iterates each coflow's engine-maintained
//!   `active_list` so finished flows of wide coflows cost nothing.
//!
//! ## Scratch architecture (zero steady-state allocation)
//!
//! The hot path is [`allocate_into`] + [`apply_grants`], which perform **no
//! heap allocation in steady state**: every buffer lives in a caller-owned
//! [`AllocScratch`] that is grown once and reused for every subsequent
//! scheduling event. Concretely:
//!
//! * the [`CapacityLedger`] is reset in place from the fabric;
//! * the grants list is a reused `Vec` cleared per call;
//! * duplicate-grant merging (a flow granted in both the budgeted and the
//!   backfill pass) uses **epoch-stamped dense per-flow tables**
//!   (`grant_epoch`/`grant_slot`): bumping one counter invalidates the whole
//!   table in O(1), so nothing is cleared and no hash map is built;
//! * per-group port budgets are flattened `groups × ports` rows in two
//!   reused `Vec<f64>`s.
//!
//! [`allocate`] and [`apply`] remain as thin compatibility wrappers that
//! build the scratch per call; the simulator engine, the live service, and
//! the benches all thread a persistent scratch through instead.

use crate::coflow::{CoflowState, FlowState};
use crate::fabric::{CapacityLedger, Fabric};
use crate::{CoflowId, FlowId, EPS};

/// Which of a coflow's flows an order entry admits — Philae's lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlowFilter {
    /// Every unfinished flow.
    All,
    /// Only the pilot flows (Philae's sampling lane).
    PilotsOnly,
    /// Only non-pilot flows (Philae's backfill lane).
    NonPilots,
}

/// One priority-order entry: a coflow, the lane filter to apply, and an
/// optional bandwidth group (Aalo-style queues with fixed weighted shares).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrderEntry {
    pub coflow: CoflowId,
    pub filter: FlowFilter,
    /// `Some(q)` assigns the entry to bandwidth group `q` (see
    /// [`Plan::group_weights`]); `None` means strict priority.
    pub group: Option<usize>,
}

impl OrderEntry {
    pub fn all(coflow: CoflowId) -> Self {
        OrderEntry { coflow, filter: FlowFilter::All, group: None }
    }

    pub fn pilots(coflow: CoflowId) -> Self {
        OrderEntry { coflow, filter: FlowFilter::PilotsOnly, group: None }
    }

    pub fn backfill(coflow: CoflowId) -> Self {
        OrderEntry { coflow, filter: FlowFilter::NonPilots, group: None }
    }

    pub fn grouped(coflow: CoflowId, group: usize) -> Self {
        OrderEntry { coflow, filter: FlowFilter::All, group: Some(group) }
    }
}

/// A full scheduling plan: the priority order plus the bandwidth weights of
/// any groups referenced by entries. Weights are normalized internally;
/// groups model Aalo/Saath's "each queue receives a fixed bandwidth share
/// at every port" semantics (paper §1.1). Strict-priority entries
/// (`group: None`) are unbudgeted.
///
/// Plans are designed to be **caller-owned and reused**: schedulers write
/// into an existing plan through [`Scheduler::order_into`]
/// (`crate::coordinator::Scheduler::order_into`), so the entry vector's
/// allocation is paid once per run, not once per scheduling event.
#[derive(Debug, Clone, Default)]
pub struct Plan {
    pub entries: Vec<OrderEntry>,
    pub group_weights: Vec<f64>,
}

impl Plan {
    /// Strict-priority plan over whole coflows.
    pub fn strict(coflows: impl IntoIterator<Item = CoflowId>) -> Self {
        Plan {
            entries: coflows.into_iter().map(OrderEntry::all).collect(),
            group_weights: Vec::new(),
        }
    }

    /// Empty the plan, keeping both buffers' capacity for reuse.
    pub fn clear(&mut self) {
        self.entries.clear();
        self.group_weights.clear();
    }
}

/// Result of one allocation pass.
#[derive(Debug, Clone, Default)]
pub struct Allocation {
    /// `(flow, rate)` for every flow granted a non-zero rate, in priority
    /// order. Flows not listed are implicitly stalled (rate 0).
    pub grants: Vec<(FlowId, f64)>,
    /// Number of flows inspected (profiling: walk cost).
    pub visited: usize,
}

impl Allocation {
    /// Total allocated rate (bytes/sec).
    pub fn total_rate(&self) -> f64 {
        self.grants.iter().map(|(_, r)| r).sum()
    }
}

/// Reusable workspace for [`allocate_into`]/[`apply_grants`]. Construct once
/// (cheap, empty) and thread through every allocation; all internal tables
/// grow to the working-set high-water mark and are then reused without
/// further heap traffic.
#[derive(Debug, Clone, Default)]
pub struct AllocScratch {
    /// Residual port capacity, reset in place from the fabric per call.
    ledger: CapacityLedger,
    /// Current allocation round; stamps below are valid iff they equal it.
    epoch: u64,
    /// Per-flow stamp: `grant_epoch[f] == epoch` iff flow `f` holds a grant
    /// this round.
    grant_epoch: Vec<u64>,
    /// Per-flow index into `grants` (valid only when the stamp is current) —
    /// the O(1) replacement for the old `grants.iter_mut().find(...)` dedup.
    grant_slot: Vec<u32>,
    /// Flattened `groups × ports` pass-1 budgets.
    budget_up: Vec<f64>,
    budget_down: Vec<f64>,
    /// `(flow, rate)` output of the last [`allocate_into`], priority order.
    grants: Vec<(FlowId, f64)>,
    /// Flows inspected by the last [`allocate_into`].
    visited: usize,
}

impl AllocScratch {
    pub fn new() -> Self {
        AllocScratch { ledger: CapacityLedger::empty(), ..Default::default() }
    }

    /// Grants of the last allocation round, in priority order.
    #[inline]
    pub fn grants(&self) -> &[(FlowId, f64)] {
        &self.grants
    }

    /// Flows inspected by the last allocation round.
    #[inline]
    pub fn visited(&self) -> usize {
        self.visited
    }

    /// Whether `fid` received a grant in the last allocation round.
    #[inline]
    pub fn was_granted(&self, fid: FlowId) -> bool {
        self.grant_epoch.get(fid).copied() == Some(self.epoch)
    }

    /// Rate granted to `fid` in the last round (0.0 if stalled).
    #[inline]
    pub fn granted_rate(&self, fid: FlowId) -> f64 {
        if self.was_granted(fid) {
            self.grants[self.grant_slot[fid] as usize].1
        } else {
            0.0
        }
    }

    /// Copy the last round out as an owned [`Allocation`] (compat shim).
    pub fn to_allocation(&self) -> Allocation {
        Allocation { grants: self.grants.clone(), visited: self.visited }
    }
}

/// Allocate rates for `plan` (entries highest priority first) against
/// `fabric`, writing the result into `scratch` (see
/// [`AllocScratch::grants`]). Zero heap allocation once the scratch tables
/// have reached their high-water size.
///
/// Two passes when bandwidth groups are present: pass 1 walks entries in
/// priority order with each grouped claim capped by its group's per-port
/// budget (`weight × port capacity`); pass 2 backfills the leftovers in the
/// same priority order without budgets (work conservation). Group-free
/// plans collapse to the single greedy pass.
pub fn allocate_into(
    fabric: &Fabric,
    flows: &[FlowState],
    coflows: &[CoflowState],
    plan: &Plan,
    scratch: &mut AllocScratch,
) {
    scratch.epoch += 1;
    let epoch = scratch.epoch;
    if scratch.grant_epoch.len() < flows.len() {
        scratch.grant_epoch.resize(flows.len(), 0);
        scratch.grant_slot.resize(flows.len(), 0);
    }
    scratch.ledger.reset(fabric);
    scratch.grants.clear();
    scratch.visited = 0;

    let has_groups = plan.entries.iter().any(|e| e.group.is_some())
        && plan.group_weights.iter().any(|&w| w > 0.0);

    // Per-group per-port budgets (pass 1 only), flattened groups-major.
    let nports = fabric.num_ports;
    if has_groups {
        let wsum: f64 = plan.group_weights.iter().sum();
        let need = plan.group_weights.len() * nports;
        if scratch.budget_up.len() < need {
            scratch.budget_up.resize(need, 0.0);
            scratch.budget_down.resize(need, 0.0);
        }
        for (g, &w) in plan.group_weights.iter().enumerate() {
            let frac = w / wsum;
            for p in 0..nports {
                scratch.budget_up[g * nports + p] = fabric.up_capacity[p] * frac;
                scratch.budget_down[g * nports + p] = fabric.down_capacity[p] * frac;
            }
        }
    }

    let mut open_up = fabric.up_capacity.iter().filter(|&&c| c > EPS).count();
    let mut open_down = fabric.down_capacity.iter().filter(|&&c| c > EPS).count();
    let passes: &[bool] = if has_groups { &[true, false] } else { &[false] };

    for &budgeted in passes {
        if open_up == 0 || open_down == 0 {
            break;
        }
        'entries: for e in &plan.entries {
            for &fid in &coflows[e.coflow].active_list {
                if open_up == 0 || open_down == 0 {
                    break 'entries;
                }
                let f = &flows[fid];
                if f.done() {
                    continue;
                }
                match e.filter {
                    FlowFilter::All => {}
                    FlowFilter::PilotsOnly if !f.pilot => continue,
                    FlowFilter::NonPilots if f.pilot => continue,
                    _ => {}
                }
                scratch.visited += 1;
                let up_before = scratch.ledger.up_left(f.src) > EPS;
                let down_before = scratch.ledger.down_left(f.dst) > EPS;
                if !up_before || !down_before {
                    continue;
                }
                let want = if budgeted {
                    match e.group {
                        Some(g) => scratch.budget_up[g * nports + f.src]
                            .min(scratch.budget_down[g * nports + f.dst])
                            .max(0.0),
                        None => f64::INFINITY,
                    }
                } else {
                    f64::INFINITY
                };
                if want <= EPS {
                    continue;
                }
                let granted = scratch.ledger.claim(f.src, f.dst, want);
                if granted > EPS {
                    if scratch.grant_epoch[fid] == epoch {
                        scratch.grants[scratch.grant_slot[fid] as usize].1 += granted;
                    } else {
                        scratch.grant_epoch[fid] = epoch;
                        scratch.grant_slot[fid] = scratch.grants.len() as u32;
                        scratch.grants.push((fid, granted));
                    }
                    if budgeted {
                        if let Some(g) = e.group {
                            scratch.budget_up[g * nports + f.src] -= granted;
                            scratch.budget_down[g * nports + f.dst] -= granted;
                        }
                    }
                }
                if up_before && scratch.ledger.up_left(f.src) <= EPS {
                    open_up -= 1;
                }
                if down_before && scratch.ledger.down_left(f.dst) <= EPS {
                    open_down -= 1;
                }
            }
        }
    }
}

/// Compatibility wrapper: allocate with a fresh scratch and return an owned
/// [`Allocation`]. Prefer [`allocate_into`] with a persistent
/// [`AllocScratch`] on hot paths.
pub fn allocate(
    fabric: &Fabric,
    flows: &[FlowState],
    coflows: &[CoflowState],
    plan: &Plan,
) -> Allocation {
    let mut scratch = AllocScratch::new();
    allocate_into(fabric, flows, coflows, plan, &mut scratch);
    Allocation { grants: scratch.grants, visited: scratch.visited }
}

/// Apply a grants list to the flow table: set granted rates, zero every
/// other active rate of the ordered coflows. Returns the number of flows
/// whose rate changed (the count of `new rate` messages the coordinator
/// must push to agents — the Table 3 “New Rate Send” column).
///
/// Allocation-free: instead of a per-call lookup table, granted flows are
/// tagged in place via [`FlowState::alloc_mark`] (pass 1), the plan walk
/// zeroes untagged flows (pass 2), and the tags are cleared again (pass 3).
/// Only flows whose rate actually changed are written.
pub fn apply_grants(
    flows: &mut [FlowState],
    coflows: &[CoflowState],
    plan: &Plan,
    grants: &[(FlowId, f64)],
) -> usize {
    let mut changed = 0;
    for &(fid, r) in grants {
        let f = &mut flows[fid];
        if (f.rate - r).abs() > EPS {
            changed += 1;
            f.rate = r;
        }
        f.alloc_mark = true;
    }
    for e in &plan.entries {
        for &fid in &coflows[e.coflow].active_list {
            let f = &mut flows[fid];
            if !f.alloc_mark && f.rate.abs() > EPS {
                changed += 1;
                f.rate = 0.0;
            } else if !f.alloc_mark {
                f.rate = 0.0;
            }
        }
    }
    for &(fid, _) in grants {
        flows[fid].alloc_mark = false;
    }
    changed
}

/// Compatibility wrapper over [`apply_grants`] taking an [`Allocation`].
pub fn apply(
    flows: &mut [FlowState],
    coflows: &[CoflowState],
    plan: &Plan,
    alloc: &Allocation,
) -> usize {
    apply_grants(flows, coflows, plan, &alloc.grants)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::Fabric;

    fn setup(flow_defs: &[(usize, usize, f64)]) -> (Vec<FlowState>, Vec<CoflowState>) {
        // each flow is its own coflow for simple ordering tests
        let mut flows = Vec::new();
        let mut coflows = Vec::new();
        for (i, &(src, dst, size)) in flow_defs.iter().enumerate() {
            flows.push(FlowState::new(i, i, src, dst, size));
            coflows.push(CoflowState::new(i, 0.0, vec![i], size, i as u64));
        }
        (flows, coflows)
    }

    fn entries(n: usize) -> Plan {
        Plan::strict(0..n)
    }

    #[test]
    fn priority_order_wins() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        let alloc = allocate(&fabric, &flows, &coflows, &entries(2));
        assert_eq!(alloc.grants, vec![(0, 100.0)]);
    }

    #[test]
    fn grouped_entries_share_by_weight() {
        // two coflows on the same pair in different groups with weights
        // 2:1 → pass 1 splits the port 2/3 vs 1/3 (then pass 2 has nothing
        // left to backfill).
        let fabric = Fabric::homogeneous(2, 90.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        let plan = Plan {
            entries: vec![OrderEntry::grouped(0, 0), OrderEntry::grouped(1, 1)],
            group_weights: vec![2.0, 1.0],
        };
        let alloc = allocate(&fabric, &flows, &coflows, &plan);
        assert_eq!(alloc.grants, vec![(0, 60.0), (1, 30.0)]);
    }

    #[test]
    fn grouped_backfill_is_work_conserving() {
        // only group 1 has a runnable flow: pass 1 gives it its 1/3 share,
        // pass 2 tops it up to the full port — and the two grants must be
        // merged into one entry by the stamped dedup.
        let fabric = Fabric::homogeneous(2, 90.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0)]);
        let plan = Plan {
            entries: vec![OrderEntry::grouped(0, 1)],
            group_weights: vec![2.0, 1.0],
        };
        let alloc = allocate(&fabric, &flows, &coflows, &plan);
        assert_eq!(alloc.grants, vec![(0, 90.0)]);
    }

    #[test]
    fn work_conservation_backfill() {
        let fabric = Fabric::homogeneous(4, 100.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (2, 3, 10.0)]);
        let alloc = allocate(&fabric, &flows, &coflows, &entries(2));
        assert_eq!(alloc.grants.len(), 2);
        assert_eq!(alloc.total_rate(), 200.0);
    }

    #[test]
    fn no_port_oversubscription() {
        let fabric = Fabric::homogeneous(3, 100.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (0, 2, 10.0), (2, 1, 10.0)]);
        let alloc = allocate(&fabric, &flows, &coflows, &entries(3));
        let mut up = vec![0.0; 3];
        let mut down = vec![0.0; 3];
        for &(fid, r) in &alloc.grants {
            up[flows[fid].src] += r;
            down[flows[fid].dst] += r;
        }
        for p in 0..3 {
            assert!(up[p] <= 100.0 + 1e-9);
            assert!(down[p] <= 100.0 + 1e-9);
        }
        assert_eq!(alloc.grants, vec![(0, 100.0)]);
    }

    #[test]
    fn early_exit_on_saturation() {
        let fabric = Fabric::homogeneous(1, 100.0);
        let (flows, coflows) = setup(&(0..1000).map(|_| (0, 0, 1.0)).collect::<Vec<_>>());
        let alloc = allocate(&fabric, &flows, &coflows, &entries(1000));
        assert_eq!(alloc.grants.len(), 1);
        assert!(alloc.visited <= 2, "visited {} flows", alloc.visited);
    }

    #[test]
    fn skips_done_flows() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let (mut flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        flows[0].sent = 10.0;
        let alloc = allocate(&fabric, &flows, &coflows, &entries(2));
        assert_eq!(alloc.grants, vec![(1, 100.0)]);
    }

    #[test]
    fn pilot_lane_filters() {
        let fabric = Fabric::homogeneous(4, 100.0);
        let mut flows = vec![
            FlowState::new(0, 0, 0, 2, 10.0),
            FlowState::new(1, 0, 1, 3, 10.0),
        ];
        flows[0].pilot = true;
        let coflows = vec![CoflowState::new(0, 0.0, vec![0, 1], 20.0, 0)];
        let pilot_plan = Plan { entries: vec![OrderEntry::pilots(0)], group_weights: vec![] };
        let pilots = allocate(&fabric, &flows, &coflows, &pilot_plan);
        assert_eq!(pilots.grants, vec![(0, 100.0)]);
        let rest_plan = Plan { entries: vec![OrderEntry::backfill(0)], group_weights: vec![] };
        let rest = allocate(&fabric, &flows, &coflows, &rest_plan);
        assert_eq!(rest.grants, vec![(1, 100.0)]);
    }

    #[test]
    fn apply_counts_rate_changes() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let (mut flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        let order = entries(2);
        let alloc = allocate(&fabric, &flows, &coflows, &order);
        let changed = apply(&mut flows, &coflows, &order, &alloc);
        assert_eq!(changed, 1); // only flow 0 started
        assert_eq!(flows[0].rate, 100.0);
        assert_eq!(flows[1].rate, 0.0);
        assert!(flows.iter().all(|f| !f.alloc_mark), "marks must be cleared");
        // re-applying the identical allocation changes nothing
        let alloc2 = allocate(&fabric, &flows, &coflows, &order);
        let changed2 = apply(&mut flows, &coflows, &order, &alloc2);
        assert_eq!(changed2, 0);
    }

    #[test]
    fn scratch_reuse_matches_fresh_allocation() {
        let fabric = Fabric::homogeneous(4, 100.0);
        let (flows, coflows) =
            setup(&[(0, 1, 10.0), (0, 2, 10.0), (2, 3, 10.0), (3, 1, 10.0)]);
        let plan = entries(4);
        let mut scratch = AllocScratch::new();
        for _ in 0..3 {
            allocate_into(&fabric, &flows, &coflows, &plan, &mut scratch);
            let fresh = allocate(&fabric, &flows, &coflows, &plan);
            assert_eq!(scratch.grants(), &fresh.grants[..]);
            assert_eq!(scratch.visited(), fresh.visited);
        }
    }

    #[test]
    fn scratch_grant_queries() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let (flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        let mut scratch = AllocScratch::new();
        allocate_into(&fabric, &flows, &coflows, &entries(2), &mut scratch);
        assert!(scratch.was_granted(0));
        assert!(!scratch.was_granted(1));
        assert_eq!(scratch.granted_rate(0), 100.0);
        assert_eq!(scratch.granted_rate(1), 0.0);
        // next round invalidates the previous stamps wholesale
        let empty = Plan::default();
        allocate_into(&fabric, &flows, &coflows, &empty, &mut scratch);
        assert!(!scratch.was_granted(0));
        assert_eq!(scratch.grants().len(), 0);
    }

    #[test]
    fn apply_grants_zeroes_only_planned_flows() {
        let fabric = Fabric::homogeneous(2, 100.0);
        let (mut flows, coflows) = setup(&[(0, 1, 10.0), (0, 1, 10.0)]);
        flows[1].rate = 55.0; // stale rate on the flow the plan covers
        let alloc = allocate(&fabric, &flows, &coflows, &entries(2));
        let changed = apply(&mut flows, &coflows, &entries(2), &alloc);
        assert_eq!(changed, 2); // flow 0 gained 100, flow 1 lost 55
        assert_eq!(flows[1].rate, 0.0);
    }
}
