//! Clairvoyant Shortest-Effective-Bottleneck-First (Varys' inter-coflow
//! heuristic). Orders coflows by the remaining bytes of their most loaded
//! port — the quantity that lower-bounds the coflow's completion time on a
//! non-blocking fabric.

use super::{DeadlineMode, OrderEntry, Plan, Reaction, Scheduler, World};
use crate::trace::Trace;
use crate::{Bytes, CoflowId, FlowId};

pub struct SebfScheduler {
    bottleneck: Vec<Bytes>,
    total: Vec<Bytes>,
    /// SLO handling: `Secondary` uses the coflow deadline as a tie-break
    /// behind the bottleneck key (`Ignore`, the default, is deadline-blind).
    deadline_mode: DeadlineMode,
    /// Reused sort buffer — the SEBF key moves with every byte sent by
    /// every coflow, so there is no stable order to repair incrementally;
    /// the rebuild at least allocates nothing in steady state.
    scratch: Vec<(f64, f64, u64, CoflowId)>,
}

impl SebfScheduler {
    pub fn new(trace: &Trace) -> Self {
        let oracles = trace.oracles();
        SebfScheduler {
            bottleneck: oracles.iter().map(|o| o.bottleneck_bytes).collect(),
            total: oracles.iter().map(|o| o.total_bytes).collect(),
            deadline_mode: DeadlineMode::default(),
            scratch: Vec::new(),
        }
    }

    /// Builder-style [`DeadlineMode`] (default: `Ignore`).
    pub fn with_deadline_mode(mut self, mode: DeadlineMode) -> Self {
        self.deadline_mode = mode;
        self
    }

    /// Remaining effective bottleneck, approximated by scaling the static
    /// bottleneck with the coflow's remaining fraction (exact per-port
    /// tracking would cost O(width) per comparison; the approximation
    /// preserves the ordering for the uniform-progress case). Coflows
    /// registered after trace construction (live-service dynamic
    /// registrations) fall back to their total size as the bottleneck
    /// proxy.
    fn remaining_bottleneck(&self, cid: CoflowId, total: Bytes, sent: Bytes) -> f64 {
        if total <= 0.0 {
            return 0.0;
        }
        let bottleneck = self.bottleneck.get(cid).copied().unwrap_or(total);
        let frac_left = ((total - sent) / total).clamp(0.0, 1.0);
        bottleneck * frac_left
    }
}

impl Scheduler for SebfScheduler {
    fn name(&self) -> String {
        "sebf-oracle".into()
    }

    fn on_arrival(&mut self, _cid: CoflowId, _world: &mut World) -> Reaction {
        Reaction::Reallocate
    }

    fn on_flow_complete(&mut self, _fid: FlowId, _world: &mut World) -> Reaction {
        Reaction::Reallocate
    }

    fn order_into(&mut self, world: &World, plan: &mut Plan) {
        self.scratch.clear();
        for &cid in &world.active {
            let c = &world.coflows[cid];
            if c.done() {
                continue;
            }
            let total = self.total.get(cid).copied().unwrap_or(c.total_bytes);
            let dk = self.deadline_mode.key(c.deadline);
            let key = (self.remaining_bottleneck(cid, total, c.bytes_sent), dk, c.seq, cid);
            self.scratch.push(key);
        }
        self.scratch.sort_unstable_by(|a, b| {
            a.0.total_cmp(&b.0)
                .then(a.1.total_cmp(&b.1))
                .then(a.2.cmp(&b.2))
        });
        plan.clear();
        plan.entries
            .extend(self.scratch.iter().map(|&(_, _, _, cid)| OrderEntry::all(cid)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Trace, TraceRecord};

    #[test]
    fn bottleneck_beats_total_size_ordering() {
        // coflow 0: 4 flows of 10 MB spread over 4 distinct port pairs
        //   → total 40 MB but bottleneck only 10 MB.
        // coflow 1: 1 flow of 20 MB → total 20 MB, bottleneck 20 MB.
        // SCF (total) would favor coflow 1; SEBF favors coflow 0.
        let trace = Trace::from_records(
            8,
            vec![
                TraceRecord {
                    external_id: 1,
                    arrival: 0.0,
                    deadline: None,
                    mappers: vec![0, 1, 2, 3],
                    reducers: vec![(4, 10.0e6), (5, 10.0e6), (6, 10.0e6), (7, 10.0e6)],
                },
                TraceRecord {
                    external_id: 2,
                    arrival: 0.0,
                    deadline: None,
                    mappers: vec![0],
                    reducers: vec![(4, 20.0e6)],
                },
            ],
        );
        let oracles = trace.oracles();
        assert!(oracles[0].bottleneck_bytes < oracles[1].bottleneck_bytes);
        let mut s = SebfScheduler::new(&trace);
        let mut w = crate::sim::world_from_trace(&trace);
        w.active = vec![0, 1];
        let order = s.order(&w);
        assert_eq!(order.entries[0].coflow, 0);
    }
}
