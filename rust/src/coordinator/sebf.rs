//! Clairvoyant Shortest-Effective-Bottleneck-First (Varys' inter-coflow
//! heuristic). Orders coflows by the remaining bytes of their most loaded
//! port — the quantity that lower-bounds the coflow's completion time on a
//! non-blocking fabric.
//!
//! Keys come from the world itself ([`CoflowState::bottleneck_bytes`] and
//! [`CoflowState::total_bytes`], filled by the world builders and the
//! streaming admitter) rather than a trace-indexed oracle table, so the
//! scheduler needs no per-trace construction state and works unchanged on
//! the streaming engine path, where coflows materialize after build time.
//!
//! The order is maintained incrementally: the sorted entry list is carried
//! between calls, departed coflows are dropped and new actives appended,
//! and — because uniform progress moves every key but rarely *reorders*
//! them — the O(n log n) sort is skipped whenever an O(n) sortedness scan
//! shows the carried order still holds. The sorted output is a pure
//! function of the world (keys are recomputed fresh each call and made
//! unique by the coflow seq), so the carried state is self-healing:
//! a restored or freshly built scheduler converges on the identical plan
//! in one call.

use super::{DeadlineMode, OrderEntry, Plan, Reaction, Scheduler, World};
use crate::coflow::CoflowState;
use crate::trace::Trace;
use crate::{CoflowId, FlowId};

/// `(key, deadline key, seq, coflow)` — seq makes the tuple unique, so the
/// unstable sort is deterministic.
type Entry = (f64, f64, u64, CoflowId);

#[inline]
fn cmp_entry(a: &Entry, b: &Entry) -> std::cmp::Ordering {
    a.0.total_cmp(&b.0)
        .then(a.1.total_cmp(&b.1))
        .then(a.2.cmp(&b.2))
}

pub struct SebfScheduler {
    /// SLO handling: `Secondary` uses the coflow deadline as a tie-break
    /// behind the bottleneck key (`Ignore`, the default, is deadline-blind).
    deadline_mode: DeadlineMode,
    /// Sorted order carried across calls (keys refreshed per call).
    cached: Vec<Entry>,
    /// Epoch-stamped membership: `epoch` = active this round, `epoch + 1` =
    /// already carried in `cached`. The +2 stride keeps both values fresh
    /// without ever clearing the table.
    stamp: Vec<u64>,
    epoch: u64,
}

impl SebfScheduler {
    /// The trace parameter is kept for constructor-signature stability
    /// (checkpoint restore and [`super::SchedulerKind::build`] pass it);
    /// all scheduling state now comes from the world.
    pub fn new(_trace: &Trace) -> Self {
        SebfScheduler {
            deadline_mode: DeadlineMode::default(),
            cached: Vec::new(),
            stamp: Vec::new(),
            epoch: 0,
        }
    }

    /// Builder-style [`DeadlineMode`] (default: `Ignore`).
    pub fn with_deadline_mode(mut self, mode: DeadlineMode) -> Self {
        self.deadline_mode = mode;
        self
    }

    /// Remaining effective bottleneck, approximated by scaling the static
    /// bottleneck with the coflow's remaining fraction (exact per-port
    /// tracking would cost O(width) per comparison; the approximation
    /// preserves the ordering for the uniform-progress case). Worlds built
    /// by hand without a bottleneck bound (`bottleneck_bytes == 0`) fall
    /// back to the coflow's total size as the proxy.
    fn remaining_bottleneck(c: &CoflowState) -> f64 {
        let total = c.total_bytes;
        if total <= 0.0 {
            return 0.0;
        }
        let bottleneck = if c.bottleneck_bytes > 0.0 {
            c.bottleneck_bytes
        } else {
            total
        };
        let frac_left = ((total - c.bytes_sent) / total).clamp(0.0, 1.0);
        bottleneck * frac_left
    }
}

impl Scheduler for SebfScheduler {
    fn name(&self) -> String {
        "sebf-oracle".into()
    }

    fn on_arrival(&mut self, _cid: CoflowId, _world: &mut World) -> Reaction {
        Reaction::Reallocate
    }

    fn on_flow_complete(&mut self, _fid: FlowId, _world: &mut World) -> Reaction {
        Reaction::Reallocate
    }

    fn order_into(&mut self, world: &World, plan: &mut Plan) {
        self.epoch += 2;
        let e = self.epoch;
        if self.stamp.len() < world.coflows.len() {
            self.stamp.resize(world.coflows.len(), 0);
        }
        for &cid in &world.active {
            if !world.coflows[cid].done() {
                self.stamp[cid] = e;
            }
        }
        // refresh the carried entries' keys, dropping departed coflows
        let stamp = &mut self.stamp;
        let dm = &self.deadline_mode;
        self.cached.retain_mut(|entry| {
            let cid = entry.3;
            if stamp[cid] != e {
                return false;
            }
            let c = &world.coflows[cid];
            entry.0 = Self::remaining_bottleneck(c);
            entry.1 = dm.key(c.deadline);
            stamp[cid] = e + 1;
            true
        });
        // append coflows that became active since the last call
        for &cid in &world.active {
            if self.stamp[cid] == e {
                let c = &world.coflows[cid];
                self.cached.push((
                    Self::remaining_bottleneck(c),
                    self.deadline_mode.key(c.deadline),
                    c.seq,
                    cid,
                ));
                self.stamp[cid] = e + 1;
            }
        }
        // uniform progress shifts keys without reordering them most calls:
        // an O(n) check dodges the O(n log n) sort. Unstable sort is safe —
        // seq makes every tuple unique — and allocates nothing.
        let sorted = self
            .cached
            .windows(2)
            .all(|w| cmp_entry(&w[0], &w[1]) != std::cmp::Ordering::Greater);
        if !sorted {
            self.cached.sort_unstable_by(cmp_entry);
        }
        plan.clear();
        plan.entries
            .extend(self.cached.iter().map(|&(_, _, _, cid)| OrderEntry::all(cid)));
    }

    fn order_full_into(&mut self, world: &World, plan: &mut Plan) {
        // from-scratch oracle path: drop the carried order and rebuild —
        // same output by construction, exists for the equivalence pins
        self.cached.clear();
        self.order_into(world, plan);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Trace, TraceRecord};

    #[test]
    fn bottleneck_beats_total_size_ordering() {
        // coflow 0: 4 flows of 10 MB spread over 4 distinct port pairs
        //   → total 40 MB but bottleneck only 10 MB.
        // coflow 1: 1 flow of 20 MB → total 20 MB, bottleneck 20 MB.
        // SCF (total) would favor coflow 1; SEBF favors coflow 0.
        let trace = Trace::from_records(
            8,
            vec![
                TraceRecord {
                    external_id: 1,
                    arrival: 0.0,
                    deadline: None,
                    mappers: vec![0, 1, 2, 3],
                    reducers: vec![(4, 10.0e6), (5, 10.0e6), (6, 10.0e6), (7, 10.0e6)],
                },
                TraceRecord {
                    external_id: 2,
                    arrival: 0.0,
                    deadline: None,
                    mappers: vec![0],
                    reducers: vec![(4, 20.0e6)],
                },
            ],
        );
        let oracles = trace.oracles();
        assert!(oracles[0].bottleneck_bytes < oracles[1].bottleneck_bytes);
        let mut s = SebfScheduler::new(&trace);
        let mut w = crate::sim::world_from_trace(&trace);
        w.active = vec![0, 1];
        let order = s.order(&w);
        assert_eq!(order.entries[0].coflow, 0);
    }

    #[test]
    fn incremental_order_tracks_departures_and_arrivals() {
        let trace = Trace::from_records(
            6,
            vec![
                TraceRecord::uniform(1, 0.0, vec![0], vec![3], 30.0),
                TraceRecord::uniform(2, 0.0, vec![1], vec![4], 10.0),
                TraceRecord::uniform(3, 0.0, vec![2], vec![5], 20.0),
            ],
        );
        let mut s = SebfScheduler::new(&trace);
        let mut w = crate::sim::world_from_trace(&trace);
        w.active = vec![0, 1];
        let order = s.order(&w);
        assert_eq!(
            order.entries.iter().map(|e| e.coflow).collect::<Vec<_>>(),
            vec![1, 0]
        );
        // coflow 1 departs, coflow 2 arrives: carried order must converge
        w.coflows[1].finished_at = Some(1.0);
        w.active = vec![0, 2];
        let order = s.order(&w);
        assert_eq!(
            order.entries.iter().map(|e| e.coflow).collect::<Vec<_>>(),
            vec![2, 0]
        );
        // progress that inverts keys forces the repair sort
        w.coflows[0].bytes_sent = w.coflows[0].total_bytes * 0.9;
        let order = s.order(&w);
        assert_eq!(
            order.entries.iter().map(|e| e.coflow).collect::<Vec<_>>(),
            vec![0, 2]
        );
    }

    #[test]
    fn carried_and_fresh_scheduler_agree() {
        // the sorted plan is a pure function of the world: a scheduler that
        // carried state across calls and a fresh one must emit the same plan
        let trace = Trace::from_records(
            6,
            vec![
                TraceRecord::uniform(1, 0.0, vec![0], vec![3], 30.0),
                TraceRecord::uniform(2, 0.0, vec![1], vec![4], 10.0),
                TraceRecord::uniform(3, 0.0, vec![2], vec![5], 20.0),
            ],
        );
        let mut carried = SebfScheduler::new(&trace);
        let mut w = crate::sim::world_from_trace(&trace);
        w.active = vec![0, 1, 2];
        let _ = carried.order(&w);
        w.coflows[0].bytes_sent = 25.0e6;
        w.coflows[2].bytes_sent = 19.0e6;
        let a = carried.order(&w);
        let b = SebfScheduler::new(&trace).order(&w);
        assert_eq!(a.entries, b.entries);
    }
}
