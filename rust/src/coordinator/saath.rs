//! Saath-like scheduler (Jajoo et al., CoNEXT'17) — the strongest
//! priority-queue baseline in the paper's lineage. Three ideas on top of
//! Aalo (§1.1):
//!
//! 1. **All-or-none**: flows of a coflow are scheduled together so none of
//!    them goes out-of-sync (our coflow-contiguous order gives this).
//! 2. **Contention-aware intra-queue order** instead of FIFO.
//! 3. **Queue transition by the longest finished flow** rather than total
//!    bytes sent, which converges to the right queue faster.
//!
//! Transitions are event-driven (flow completions), but like all PQ-based
//! designs it still pays the sieving overhead Philae's sampling removes.

use super::{OrderEntry, Plan, Reaction, Scheduler, SchedulerConfig, World};
use crate::util::JsonValue;
use crate::{Bytes, CoflowId, FlowId};

/// Intra-queue comparator: `(queue, contention, seq, cid)` ascending —
/// seq is unique, so the order is total.
#[inline]
fn cmp_key(a: &(usize, f64, u64, CoflowId), b: &(usize, f64, u64, CoflowId)) -> std::cmp::Ordering {
    a.0.cmp(&b.0)
        .then(a.1.total_cmp(&b.1))
        .then(a.2.cmp(&b.2))
        .then(a.3.cmp(&b.3))
}

fn insert_key(v: &mut Vec<(usize, f64, u64, CoflowId)>, key: (usize, f64, u64, CoflowId)) {
    super::insert_sorted(v, key, cmp_key);
}

fn remove_key(v: &mut Vec<(usize, f64, u64, CoflowId)>, key: (usize, f64, u64, CoflowId)) {
    super::remove_sorted(v, &key, cmp_key, |e| e.3 == key.3);
}

pub struct SaathScheduler {
    cfg: SchedulerConfig,
    pub queue_moves: u64,
    /// Static D-CLAS group weights.
    weights: Vec<f64>,
    /// Incrementally maintained order, sorted by
    /// `(queue, contention, seq, cid)`. Queue transitions repair one entry;
    /// port-occupancy changes (which move contention terms wholesale)
    /// trigger the only full rebuild, keyed on `PortLoad::occ_epoch`.
    sorted: Vec<(usize, f64, u64, CoflowId)>,
    /// Cached key parts per coflow (`usize::MAX` queue = absent).
    cached_queue: Vec<usize>,
    cached_cont: Vec<f64>,
    cached_seq: Vec<u64>,
    seen: Vec<u64>,
    scan: u64,
    last_occ: u64,
}

impl SaathScheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        let weights = (0..cfg.num_queues).map(|q| 0.5f64.powi(q as i32)).collect();
        SaathScheduler {
            cfg,
            queue_moves: 0,
            weights,
            sorted: Vec::new(),
            cached_queue: Vec::new(),
            cached_cont: Vec::new(),
            cached_seq: Vec::new(),
            seen: Vec::new(),
            scan: 0,
            last_occ: u64::MAX,
        }
    }

    fn ensure(&mut self, cid: CoflowId) {
        if cid >= self.cached_queue.len() {
            self.cached_queue.resize(cid + 1, usize::MAX);
            self.cached_cont.resize(cid + 1, 0.0);
            self.cached_seq.resize(cid + 1, 0);
            self.seen.resize(cid + 1, 0);
        }
    }

    /// Queue from the longest *finished* flow: thresholds E·Sⁱ like Aalo,
    /// but keyed on a single flow length (a proxy for the coflow's flow
    /// size scale, which is what determines how long it will occupy ports).
    pub fn queue_of(&self, max_finished_flow: Bytes) -> usize {
        let mut threshold = self.cfg.q0_threshold;
        for q in 0..self.cfg.num_queues - 1 {
            if max_finished_flow < threshold {
                return q;
            }
            threshold *= self.cfg.queue_mult;
        }
        self.cfg.num_queues - 1
    }

    /// Contention: distinct active coflows sharing this coflow's ports,
    /// normalized per port (same definition as Philae's, so the two
    /// policies differ only in *size learning*).
    fn contention(&self, world: &World, cid: CoflowId) -> f64 {
        let c = &world.coflows[cid];
        let mut sharers = 0usize;
        let ports = c.senders.len() + c.receivers.len();
        for &p in &c.senders {
            sharers += world.load.up_coflows[p].saturating_sub(1);
        }
        for &p in &c.receivers {
            sharers += world.load.down_coflows[p].saturating_sub(1);
        }
        if ports == 0 {
            0.0
        } else {
            sharers as f64 / ports as f64
        }
    }
}

impl Scheduler for SaathScheduler {
    fn name(&self) -> String {
        "saath".into()
    }

    fn on_arrival(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        world.coflows[cid].queue = 0;
        Reaction::Reallocate
    }

    fn on_flow_complete(&mut self, fid: FlowId, world: &mut World) -> Reaction {
        // max_finished_flow is maintained by the engine before this call.
        let cid = world.flows[fid].coflow;
        let q = self.queue_of(world.coflows[cid].max_finished_flow);
        if q != world.coflows[cid].queue {
            world.coflows[cid].queue = q;
            self.queue_moves += 1;
        }
        Reaction::Reallocate
    }

    /// (queue, contention, FIFO seq): low-contention coflows first within
    /// a queue — they can be finished off and free their ports fastest.
    ///
    /// Incremental: contention terms are cached and only recomputed when
    /// `PortLoad::occ_epoch` moves (the rebuild path); otherwise only
    /// coflows whose queue changed are repositioned.
    fn order_into(&mut self, world: &World, plan: &mut Plan) {
        self.scan = self.scan.wrapping_add(1);
        let scan = self.scan;
        if self.last_occ != world.load.occ_epoch {
            // contention moved wholesale: rebuild into the reused buffer
            self.sorted.clear();
            for idx in 0..world.active.len() {
                let cid = world.active[idx];
                let c = &world.coflows[cid];
                if c.done() {
                    continue;
                }
                self.ensure(cid);
                self.seen[cid] = scan;
                let cont = self.contention(world, cid);
                self.cached_queue[cid] = c.queue;
                self.cached_cont[cid] = cont;
                self.cached_seq[cid] = c.seq;
                self.sorted.push((c.queue, cont, c.seq, cid));
            }
            self.sorted.sort_unstable_by(cmp_key);
            self.last_occ = world.load.occ_epoch;
        } else {
            for idx in 0..world.active.len() {
                let cid = world.active[idx];
                let c = &world.coflows[cid];
                if c.done() {
                    continue;
                }
                self.ensure(cid);
                self.seen[cid] = scan;
                if self.cached_queue[cid] == usize::MAX {
                    // new coflow under unchanged occupancy
                    let cont = self.contention(world, cid);
                    self.cached_queue[cid] = c.queue;
                    self.cached_cont[cid] = cont;
                    self.cached_seq[cid] = c.seq;
                    insert_key(&mut self.sorted, (c.queue, cont, c.seq, cid));
                } else if self.cached_queue[cid] != c.queue {
                    remove_key(
                        &mut self.sorted,
                        (
                            self.cached_queue[cid],
                            self.cached_cont[cid],
                            self.cached_seq[cid],
                            cid,
                        ),
                    );
                    self.cached_queue[cid] = c.queue;
                    insert_key(
                        &mut self.sorted,
                        (c.queue, self.cached_cont[cid], self.cached_seq[cid], cid),
                    );
                }
            }
        }
        plan.clear();
        let mut w = 0;
        for r in 0..self.sorted.len() {
            let (q, cont, seq, cid) = self.sorted[r];
            if self.seen[cid] == scan && self.cached_queue[cid] == q {
                self.sorted[w] = (q, cont, seq, cid);
                w += 1;
                plan.entries.push(OrderEntry::grouped(cid, q));
            } else if self.seen[cid] != scan {
                // departed coflow: reset the sentinel so a later re-entry
                // with an unchanged queue is re-inserted, not skipped
                self.cached_queue[cid] = usize::MAX;
            }
        }
        self.sorted.truncate(w);
        plan.group_weights.clone_from(&self.weights);
    }

    /// Cluster migration: keep the queue the coflow earned from its longest
    /// finished flow (`world.coflows[cid].queue` travels with the world; the
    /// default `on_arrival` would reset it to Q0). The incremental order
    /// cache needs no repair — the coflow is inserted on the next scan.
    fn on_coflow_attach(&mut self, _cid: CoflowId, _world: &mut World) -> Reaction {
        Reaction::Reallocate
    }

    /// The earned queue lives on the world (`CoflowState::queue`) and the
    /// order is self-healing — the only durable fact here is the
    /// transition counter.
    fn export_state(&self) -> JsonValue {
        let mut doc = std::collections::BTreeMap::new();
        doc.insert(
            "queue_moves".to_string(),
            super::recovery::u64_to_json(self.queue_moves),
        );
        JsonValue::Object(doc)
    }

    fn import_state(&mut self, state: &JsonValue, _world: &World, exact: bool) {
        if !exact {
            return; // stale counter would under-report; keep the fresh zero
        }
        if let Some(x) = state.get("queue_moves").and_then(super::recovery::u64_from_json) {
            self.queue_moves = x;
        }
    }

    /// From-scratch oracle rebuild (see trait docs).
    fn order_full_into(&mut self, world: &World, plan: &mut Plan) {
        let mut coflows: Vec<(usize, f64, u64, CoflowId)> = world
            .active
            .iter()
            .filter(|&&cid| !world.coflows[cid].done())
            .map(|&cid| {
                let c = &world.coflows[cid];
                (c.queue, self.contention(world, cid), c.seq, cid)
            })
            .collect();
        coflows.sort_unstable_by(cmp_key);
        plan.clear();
        plan.entries
            .extend(coflows.into_iter().map(|(q, _, _, cid)| OrderEntry::grouped(cid, q)));
        plan.group_weights.clone_from(&self.weights);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{CoflowState, FlowState};
    use crate::fabric::{Fabric, PortLoad};
    use crate::MB;

    fn world2() -> World {
        let flows = vec![
            FlowState::new(0, 0, 0, 2, 100.0 * MB),
            FlowState::new(1, 1, 1, 3, 100.0 * MB),
        ];
        let mut c0 = CoflowState::new(0, 0.0, vec![0], 100.0 * MB, 0);
        c0.senders = vec![0];
        c0.receivers = vec![2];
        let mut c1 = CoflowState::new(1, 0.0, vec![1], 100.0 * MB, 1);
        c1.senders = vec![1];
        c1.receivers = vec![3];
        let coflows = vec![c0, c1];
        World {
            now: 0.0,
            flows,
            coflows,
            fabric: Fabric::homogeneous(4, 100.0),
            load: PortLoad::new(4),
            active: vec![0, 1],
        }
    }

    #[test]
    fn transition_keyed_on_longest_finished_flow() {
        let mut w = world2();
        let mut s = SaathScheduler::new(SchedulerConfig::default());
        s.on_arrival(0, &mut w);
        // a 50 MB flow finished: above E=10MB, below E·S=100MB → queue 1
        w.coflows[0].max_finished_flow = 50.0 * MB;
        w.flows[0].finished_at = Some(1.0);
        s.on_flow_complete(0, &mut w);
        assert_eq!(w.coflows[0].queue, 1);
        assert_eq!(s.queue_moves, 1);
    }

    #[test]
    fn contention_breaks_queue_ties() {
        let mut w = world2();
        let mut s = SaathScheduler::new(SchedulerConfig::default());
        s.on_arrival(0, &mut w);
        s.on_arrival(1, &mut w);
        // coflow 0's ports are contended by 2 coflows, coflow 1's by none
        w.load.up_coflows[0] = 3;
        w.load.down_coflows[2] = 3;
        w.load.up_coflows[1] = 1;
        w.load.down_coflows[3] = 1;
        let order = s.order(&w);
        // same queue, but coflow 1 has lower contention → first despite seq
        assert_eq!(order.entries[0].coflow, 1);
    }
}
