//! Philae: sampling-based coflow size learning + contention-aware SCF.
//!
//! On coflow arrival Philae **pre-schedules pilot flows** (≈1% of the
//! coflow's flows, at least one, at most `pilot_max`, at most one per
//! distinct sender port, placed on the least-busy port pairs). When every
//! pilot has finished, the coflow's size is **estimated once** as
//! `width × mean(pilot sizes)` and the coflow joins the scheduled set,
//! ordered by contention-adjusted estimated remaining size (shortest
//! first). Rate allocation is event-triggered — there is no periodic tick.
//!
//! Priority lanes, highest first:
//!
//! 1. **Express** — coflows older than `age_threshold` (starvation
//!    freedom), FIFO.
//! 2. **Pilot** — pilot flows of coflows still being sampled, FIFO.
//! 3. **Scheduled** — estimated coflows by ascending
//!    `score = est_remaining × (1 + w · contention)`.
//! 4. **Backfill** — non-pilot flows of unestimated coflows, FIFO (work
//!    conservation: they only see capacity the upper lanes left over).
//!
//! ## Incremental order maintenance
//!
//! The lanes are **persistent sorted structures**, not per-event rebuilds:
//! express and pilot are seq-ordered FIFO vectors, the scheduled lane is a
//! vector sorted by `(score, deadline key, seq)` — the deadline key is the
//! coflow's SLO deadline under
//! [`DeadlineMode::Secondary`](crate::coordinator::DeadlineMode) and `+∞`
//! otherwise, so the default order is the classic `(score, seq)`. Each [`PhilaeCore::order_into`] call
//! lazily validates the cache against the world — a coflow whose estimate,
//! completed-flow count, or lane changed is repaired by a binary-search
//! remove/insert of just that coflow; a port-occupancy change (tracked by
//! [`crate::fabric::PortLoad::occ_epoch`]) invalidates every contention
//! term at once and triggers the only full re-sort, into the same reused
//! buffers. Steady-state ordering is therefore allocation-free and
//! sort-free. [`PhilaeCore::order_full_into`] keeps the from-scratch
//! rebuild as the equivalence oracle: both paths emit bit-identical plans.

use super::{EventBatch, OrderEntry, Plan, Reaction, Scheduler, SchedulerConfig, World};
use crate::coflow::{CoflowPhase, CoflowState};
use crate::util::JsonValue;
use crate::{Bytes, CoflowId, FlowId};

/// What a completion report meant to the sampling state machine.
#[derive(Debug, Clone, PartialEq)]
pub enum CompletionOutcome {
    /// A non-pilot flow (or a pilot of an already-estimated coflow) ended.
    Normal,
    /// The last outstanding pilot finished: the sample is complete and the
    /// coflow must be given an estimate now. Carries the pilot sizes.
    SampleComplete(Vec<Bytes>),
}

/// Which lane of the four-lane order a coflow currently occupies in the
/// incremental cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Lane {
    /// Not in any lane (never seen, or stale bookkeeping).
    Absent,
    Express,
    Piloting,
    Scheduled,
}

/// The incrementally maintained four-lane order (see module docs). All
/// vectors are reused across events; per-coflow tables are dense by id.
#[derive(Debug, Clone)]
struct OrderCache {
    /// Express lane entries, sorted by `(seq, cid)`.
    express: Vec<(u64, CoflowId)>,
    /// Pilot lane entries, sorted by `(seq, cid)`.
    piloting: Vec<(u64, CoflowId)>,
    /// Scheduled lane entries, sorted by `(score, deadline key, seq)` —
    /// the deadline key is `+∞` unless `DeadlineMode::Secondary` is on
    /// (see [`crate::coordinator::DeadlineMode`]), so the default order is
    /// exactly the pre-SLO `(score, seq)`.
    scheduled: Vec<(f64, f64, u64, CoflowId)>,
    /// Current lane per coflow.
    lane: Vec<Lane>,
    /// Cached scheduled-lane score per coflow (the removal key).
    score: Vec<f64>,
    /// Bit pattern of the estimate the cached score was computed from.
    est_bits: Vec<u64>,
    /// Completed-flow count the cached score was computed from.
    done_count: Vec<usize>,
    /// Scan stamp: entries whose coflow was not stamped in the current scan
    /// left the active set and are dropped at emit time.
    seen: Vec<u64>,
    scan: u64,
    /// `PortLoad::occ_epoch` the cached contention terms were computed
    /// under; `u64::MAX` = cache never built.
    last_occ: u64,
}

impl OrderCache {
    fn new() -> Self {
        OrderCache {
            express: Vec::new(),
            piloting: Vec::new(),
            scheduled: Vec::new(),
            lane: Vec::new(),
            score: Vec::new(),
            est_bits: Vec::new(),
            done_count: Vec::new(),
            seen: Vec::new(),
            scan: 0,
            last_occ: u64::MAX,
        }
    }

    fn ensure(&mut self, n: usize) {
        if self.lane.len() < n {
            self.lane.resize(n, Lane::Absent);
            self.score.resize(n, 0.0);
            self.est_bits.resize(n, 0);
            self.done_count.resize(n, 0);
            self.seen.resize(n, 0);
        }
    }
}

/// Estimate bit pattern used for exact change detection (`None` maps to the
/// same +∞ the score computation uses).
#[inline]
fn est_bits(c: &CoflowState) -> u64 {
    c.est_size.unwrap_or(f64::INFINITY).to_bits()
}

/// Scheduled-lane comparator: ascending `(score, deadline key, seq)` —
/// seq is unique per coflow, so the order is total and insert/remove
/// positions are unique. The deadline key is `+∞` outside
/// `DeadlineMode::Secondary`, collapsing to the classic `(score, seq)`.
#[inline]
fn cmp_scored(a: &(f64, f64, u64, CoflowId), b: &(f64, f64, u64, CoflowId)) -> std::cmp::Ordering {
    a.0.total_cmp(&b.0)
        .then(a.1.total_cmp(&b.1))
        .then(a.2.cmp(&b.2))
}

/// Binary-search insert into a `(seq, cid)` FIFO lane.
fn insert_seq(v: &mut Vec<(u64, CoflowId)>, seq: u64, cid: CoflowId) {
    super::insert_sorted(v, (seq, cid), |a, b| a.cmp(b));
}

/// Remove from a `(seq, cid)` FIFO lane (no-op if absent).
fn remove_seq(v: &mut Vec<(u64, CoflowId)>, seq: u64, cid: CoflowId) {
    super::remove_sorted(v, &(seq, cid), |a, b| a.cmp(b), |e| e.1 == cid);
}

/// Binary-search insert into the scheduled lane.
fn insert_scored(
    v: &mut Vec<(f64, f64, u64, CoflowId)>,
    score: f64,
    dkey: f64,
    seq: u64,
    cid: CoflowId,
) {
    super::insert_sorted(v, (score, dkey, seq, cid), cmp_scored);
}

/// Remove from the scheduled lane by its cached key (no-op if absent).
fn remove_scored(
    v: &mut Vec<(f64, f64, u64, CoflowId)>,
    score: f64,
    dkey: f64,
    seq: u64,
    cid: CoflowId,
) {
    super::remove_sorted(v, &(score, dkey, seq, cid), cmp_scored, |e| e.3 == cid);
}

/// Sampling/learning state shared by default Philae and the §2.2
/// error-correction variants.
#[derive(Debug, Clone)]
pub struct PhilaeCore {
    pub cfg: SchedulerConfig,
    /// Completed pilot sizes per coflow.
    pilot_sizes: Vec<Vec<Bytes>>,
    /// Flow ids already counted into `pilot_sizes` (per coflow) — makes
    /// sample recording idempotent per flow, so a report replayed after a
    /// cluster migration reconstructed the sample (see
    /// [`PhilaeCore::adopt`]) cannot duplicate a measurement.
    pilot_sampled: Vec<Vec<FlowId>>,
    /// Outstanding (unfinished) pilot count per coflow.
    pilots_left: Vec<usize>,
    /// Bytes of *completed* flows per coflow — Philae's view of progress
    /// (it never receives byte-granularity updates; see Table 1).
    done_bytes: Vec<Bytes>,
    /// Completed-flow count per coflow (drives the remaining-size score).
    flows_done: Vec<usize>,
    /// Incremental four-lane order (see module docs).
    cache: OrderCache,
}

impl PhilaeCore {
    pub fn new(cfg: SchedulerConfig) -> Self {
        PhilaeCore {
            cfg,
            pilot_sizes: Vec::new(),
            pilot_sampled: Vec::new(),
            pilots_left: Vec::new(),
            done_bytes: Vec::new(),
            flows_done: Vec::new(),
            cache: OrderCache::new(),
        }
    }

    fn ensure(&mut self, cid: CoflowId) {
        if cid >= self.pilot_sizes.len() {
            self.pilot_sizes.resize(cid + 1, Vec::new());
            self.pilot_sampled.resize(cid + 1, Vec::new());
            self.pilots_left.resize(cid + 1, 0);
            self.done_bytes.resize(cid + 1, 0.0);
            self.flows_done.resize(cid + 1, 0);
        }
    }

    /// Bytes of completed flows of `cid` (Philae's progress view).
    pub fn done_bytes(&self, cid: CoflowId) -> Bytes {
        self.done_bytes.get(cid).copied().unwrap_or(0.0)
    }

    /// Pilot selection (§2.1): up to `pilots_for(n)` flows, at most one per
    /// distinct sender port, preferring the least-busy (src,dst) pairs so
    /// piloting mostly displaces traffic that wasn't on any critical path.
    /// Marks the flows and flips the coflow to `Piloting`.
    pub fn handle_arrival(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.ensure(cid);
        let n = world.coflows[cid].flows.len();
        let want = self.cfg.pilots_for(n);
        if want == 0 {
            world.coflows[cid].phase = CoflowPhase::Running;
            world.coflows[cid].est_size = Some(0.0);
            return Reaction::Reallocate;
        }
        // Rank candidate flows by pair busyness.
        let mut candidates: Vec<(f64, FlowId)> = world.coflows[cid]
            .flows
            .iter()
            .map(|&f| {
                let fl = &world.flows[f];
                (world.load.pair_busyness(fl.src, fl.dst), f)
            })
            .collect();
        candidates.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));

        // Diversity passes: pilots must *sample the spatial dimension*, so
        // prefer flows on (1) unseen sender AND receiver ports, then
        // (2) unseen senders, then (3) anything — all least-busy first.
        // Receiver diversity matters because shuffle flow sizes correlate
        // per reducer (the benchmark format makes them equal): sampling one
        // reducer ten times would collapse the sample to a single draw.
        let mut chosen: Vec<FlowId> = Vec::with_capacity(want);
        let mut used_src: Vec<usize> = Vec::new();
        let mut used_dst: Vec<usize> = Vec::new();
        for &(_, f) in &candidates {
            if chosen.len() == want {
                break;
            }
            let (src, dst) = (world.flows[f].src, world.flows[f].dst);
            if !used_src.contains(&src) && !used_dst.contains(&dst) {
                used_src.push(src);
                used_dst.push(dst);
                chosen.push(f);
            }
        }
        for &(_, f) in &candidates {
            if chosen.len() == want {
                break;
            }
            let src = world.flows[f].src;
            if !used_src.contains(&src) && !chosen.contains(&f) {
                used_src.push(src);
                chosen.push(f);
            }
        }
        for &(_, f) in &candidates {
            if chosen.len() == want {
                break;
            }
            if !chosen.contains(&f) {
                chosen.push(f);
            }
        }

        for &f in &chosen {
            world.flows[f].pilot = true;
        }
        self.pilots_left[cid] = chosen.len();
        let c = &mut world.coflows[cid];
        c.pilots = chosen;
        c.phase = CoflowPhase::Piloting;
        Reaction::Reallocate
    }

    /// Record a completion report. Returns `SampleComplete` exactly once per
    /// coflow — when its last outstanding pilot finishes.
    ///
    /// The sampling gate is `pilots_left > 0` (internal state keyed only on
    /// the delivery sequence), **not** the coflow's phase: under batched
    /// admission all physical completions of an instant land before any
    /// report is delivered, so a sibling flow may already have flipped the
    /// coflow to `Done` — the pilot's sample must still count exactly as it
    /// does under per-event delivery.
    pub fn record_completion(&mut self, fid: FlowId, world: &mut World) -> CompletionOutcome {
        let flow = world.flows[fid];
        let cid = flow.coflow;
        self.ensure(cid);
        self.done_bytes[cid] += flow.size;
        self.flows_done[cid] += 1;
        // per-flow idempotence: a report replayed after a migration's
        // adopt() already counted this pilot must not re-enter the sample
        if flow.pilot && self.pilots_left[cid] > 0 && !self.pilot_sampled[cid].contains(&fid) {
            self.pilot_sampled[cid].push(fid);
            self.pilot_sizes[cid].push(flow.size);
            self.pilots_left[cid] -= 1;
            if self.pilots_left[cid] == 0 {
                return CompletionOutcome::SampleComplete(self.pilot_sizes[cid].clone());
            }
        }
        CompletionOutcome::Normal
    }

    /// Contention of a coflow: average number of *other* active coflows
    /// sharing its ports (paper: “with how many other coflows a coflow is
    /// sharing ports”). Matches the L1 `contention` kernel's
    /// `occ·occᵀ` row-sum semantics.
    pub fn contention(&self, world: &World, cid: CoflowId) -> f64 {
        let c = &world.coflows[cid];
        // The load counters include this coflow itself while active, hence
        // the −1 per port. Distinct-port lists are static (engine-filled).
        let mut sharers = 0usize;
        let ports = c.senders.len() + c.receivers.len();
        for &p in &c.senders {
            sharers += world.load.up_coflows[p].saturating_sub(1);
        }
        for &p in &c.receivers {
            sharers += world.load.down_coflows[p].saturating_sub(1);
        }
        if ports == 0 {
            0.0
        } else {
            sharers as f64 / ports as f64
        }
    }

    /// The Philae priority score (lower = sooner): contention-adjusted
    /// estimated remaining bytes. Mirrors the L2 `scorer` graph.
    ///
    /// Remaining size is estimated from the *completed-flow fraction*,
    /// `est × (1 − flows_done/n)`, not from `est − bytes_done`: the latter
    /// clamps to zero once a coflow out-sends an under-estimate, pinning a
    /// still-huge coflow at top priority for its whole residual life (the
    /// inverse of SJF). Flow counts are information Philae actually has —
    /// completion reports are its only updates (Table 1).
    pub fn score(&self, world: &World, cid: CoflowId) -> f64 {
        let est = world.coflows[cid].est_size.unwrap_or(f64::INFINITY);
        let n = world.coflows[cid].flows.len().max(1);
        let done = self.flows_done.get(cid).copied().unwrap_or(0).min(n);
        let remaining = est * (1.0 - done as f64 / n as f64);
        remaining * (1.0 + self.cfg.contention_weight * self.contention(world, cid))
    }

    /// Completed-flow count for `cid`.
    pub fn flows_done(&self, cid: CoflowId) -> usize {
        self.flows_done.get(cid).copied().unwrap_or(0)
    }

    /// Cluster migration: adopt `cid` mid-flight from another coordinator
    /// shard, reconstructing the learning state this core would hold had it
    /// owned the coflow since arrival. Everything is rebuilt from
    /// *completed-flow facts* — exactly the information the coflow's
    /// completion reports carried (sizes are only read off finished flows),
    /// so the handoff grants no clairvoyance:
    ///
    /// * `flows_done` / `done_bytes` from the finished flows;
    /// * the pilot sample from the finished pilots;
    /// * `pilots_left` from the outstanding pilots — unless the source
    ///   shard already completed the sample (the estimate is set), in which
    ///   case it is pinned to 0 so `SampleComplete` can never fire twice.
    ///
    /// Returns `Some(sample)` when the reconstructed sample is already
    /// complete but the coflow carries **no estimate yet** — the window
    /// where the last pilot finished physically while its (jittered)
    /// report was still in flight to the source shard at migration time.
    /// That report will replay against *this* core with the pilot gate
    /// already closed, so the attach hook must estimate from the returned
    /// sample immediately or the coflow would stay unestimated forever.
    ///
    /// Replay safety: adoption records which pilot flows it counted
    /// (`pilot_sampled`), and `record_completion` is idempotent per flow —
    /// a done-but-unreported pilot's replayed report cannot re-enter the
    /// sample, while a genuinely outstanding pilot's report still
    /// completes it. Replayed reports may still re-count `done_bytes` /
    /// `flows_done` the adoption already counted; the score clamps the
    /// done fraction at 1, so that distortion is bounded and transient.
    ///
    /// The incremental order cache needs no repair: the coflow simply
    /// starts appearing in this core's active scans and is inserted as
    /// `Absent → lane` on the next `order_into`.
    pub fn adopt(&mut self, cid: CoflowId, world: &World) -> Option<Vec<Bytes>> {
        self.ensure(cid);
        let c = &world.coflows[cid];
        let mut done_bytes = 0.0;
        let mut done_count = 0;
        for &f in &c.flows {
            if world.flows[f].done() {
                done_bytes += world.flows[f].size;
                done_count += 1;
            }
        }
        self.done_bytes[cid] = done_bytes;
        self.flows_done[cid] = done_count;
        self.pilot_sizes[cid].clear();
        self.pilot_sampled[cid].clear();
        let mut outstanding = 0;
        for &f in &c.pilots {
            if world.flows[f].done() {
                let size = world.flows[f].size;
                self.pilot_sizes[cid].push(size);
                self.pilot_sampled[cid].push(f);
            } else {
                outstanding += 1;
            }
        }
        self.pilots_left[cid] = if c.est_size.is_some() { 0 } else { outstanding };
        if c.est_size.is_none() && outstanding == 0 && !self.pilot_sizes[cid].is_empty() {
            Some(self.pilot_sizes[cid].clone())
        } else {
            None
        }
    }

    /// Serialize the learned sampling facts for a crash checkpoint (see
    /// `coordinator::recovery`): per coflow, the pilot sample **in report
    /// delivery order** (the float-sum order the estimate mean depends
    /// on), the idempotence ledger, the outstanding pilot count, and the
    /// completed-flow progress counters. Every slot is exported: an
    /// all-zero live entry is still meaningful when a flow has physically
    /// finished but its report is undelivered — [`adopt`](Self::adopt)
    /// would count that flow, and only the checkpoint can undo it.
    pub fn export_state(&self) -> JsonValue {
        use super::recovery::f64_to_json;
        let mut per = std::collections::BTreeMap::new();
        for cid in 0..self.pilot_sizes.len() {
            let mut e = std::collections::BTreeMap::new();
            e.insert(
                "pilot_sizes".to_string(),
                JsonValue::Array(self.pilot_sizes[cid].iter().map(|&b| f64_to_json(b)).collect()),
            );
            e.insert(
                "pilot_sampled".to_string(),
                JsonValue::Array(
                    self.pilot_sampled[cid]
                        .iter()
                        .map(|&f| JsonValue::Number(f as f64))
                        .collect(),
                ),
            );
            e.insert(
                "pilots_left".to_string(),
                JsonValue::Number(self.pilots_left[cid] as f64),
            );
            e.insert("done_bytes".to_string(), f64_to_json(self.done_bytes[cid]));
            e.insert(
                "flows_done".to_string(),
                JsonValue::Number(self.flows_done[cid] as f64),
            );
            per.insert(cid.to_string(), JsonValue::Object(e));
        }
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("coflows".to_string(), JsonValue::Object(per));
        JsonValue::Object(doc)
    }

    /// Wholesale overwrite from an [`export_state`](Self::export_state)
    /// checkpoint taken at the **same** event boundary — undoes the
    /// pilots-list sample order [`adopt`](Self::adopt) produced and
    /// restores the delivery-order sample bit-exactly. Never call with a
    /// stale checkpoint: a `pilots_left > 0` entry whose pilots have since
    /// physically finished would close the sampling gate forever and
    /// starve the coflow in the pilot lane (the restore driver passes
    /// stale checkpoints to the attach rebuild only).
    pub fn import_state_exact(&mut self, state: &JsonValue) {
        use super::recovery::f64_from_json;
        let Some(per) = state.get("coflows").and_then(|v| v.as_object()) else {
            return;
        };
        for (key, e) in per {
            let Ok(cid) = key.parse::<CoflowId>() else {
                continue;
            };
            self.ensure(cid);
            if let Some(sizes) = e.get("pilot_sizes").and_then(|v| v.as_array()) {
                self.pilot_sizes[cid] = sizes.iter().filter_map(f64_from_json).collect();
            }
            if let Some(ids) = e.get("pilot_sampled").and_then(|v| v.as_array()) {
                self.pilot_sampled[cid] = ids.iter().filter_map(|v| v.as_usize()).collect();
            }
            if let Some(left) = e.get("pilots_left").and_then(|v| v.as_usize()) {
                self.pilots_left[cid] = left;
            }
            if let Some(b) = e.get("done_bytes").and_then(f64_from_json) {
                self.done_bytes[cid] = b;
            }
            if let Some(n) = e.get("flows_done").and_then(|v| v.as_usize()) {
                self.flows_done[cid] = n;
            }
        }
    }

    /// Completed pilot sizes recorded so far for `cid` (feature marshalling
    /// for the PJRT scoring path).
    pub fn pilot_sizes(&self, cid: CoflowId) -> &[Bytes] {
        self.pilot_sizes
            .get(cid)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Build the four-lane priority order using externally computed scores
    /// for the scheduled lane (the PJRT scorer path); falls back to the
    /// native score for coflows missing from `scores`.
    pub fn order_with_scores(
        &self,
        world: &World,
        scores: &std::collections::HashMap<CoflowId, f64>,
    ) -> Plan {
        let mut plan = Plan::default();
        self.order_impl(world, Some(scores), &mut plan);
        plan
    }

    /// Like [`order_with_scores`](Self::order_with_scores) but writes into
    /// a caller-owned reused plan, so the scored path keeps the plan
    /// buffer alive across events like the native path does.
    pub fn order_with_scores_into(
        &self,
        world: &World,
        scores: &std::collections::HashMap<CoflowId, f64>,
        plan: &mut Plan,
    ) {
        self.order_impl(world, Some(scores), plan);
    }

    /// Build the four-lane priority order incrementally (see module docs),
    /// writing into the caller-owned `plan`. Steady-state calls perform no
    /// heap allocation and no sort.
    pub fn order_into(&mut self, world: &World, plan: &mut Plan) {
        self.cache.ensure(world.coflows.len());
        self.cache.scan = self.cache.scan.wrapping_add(1);
        let scan = self.cache.scan;
        if self.cache.last_occ != world.load.occ_epoch {
            // Port occupancy moved: every contention term (and thus every
            // scheduled score) is suspect — rebuild the lanes wholesale
            // into the reused buffers. This is the only sorting path.
            self.rebuild_cache(world);
        } else {
            // Occupancy unchanged: repair exactly the coflows whose own
            // inputs (lane, estimate, completed-flow count) moved.
            for idx in 0..world.active.len() {
                let cid = world.active[idx];
                let c = &world.coflows[cid];
                if c.done() {
                    continue; // unstamped → dropped at emit
                }
                let seq = c.seq;
                // static per coflow, so the cached removal key is exact
                let dk = self.cfg.deadline_mode.key(c.deadline);
                let desired = self.desired_lane(world, c);
                self.cache.seen[cid] = scan;
                let current = self.cache.lane[cid];
                if current != desired {
                    match current {
                        Lane::Absent => {}
                        Lane::Express => remove_seq(&mut self.cache.express, seq, cid),
                        Lane::Piloting => remove_seq(&mut self.cache.piloting, seq, cid),
                        Lane::Scheduled => remove_scored(
                            &mut self.cache.scheduled,
                            self.cache.score[cid],
                            dk,
                            seq,
                            cid,
                        ),
                    }
                    match desired {
                        Lane::Absent => unreachable!("desired lane is never Absent"),
                        Lane::Express => insert_seq(&mut self.cache.express, seq, cid),
                        Lane::Piloting => insert_seq(&mut self.cache.piloting, seq, cid),
                        Lane::Scheduled => {
                            let s = self.score(world, cid);
                            self.cache.score[cid] = s;
                            self.cache.est_bits[cid] = est_bits(c);
                            self.cache.done_count[cid] =
                                self.flows_done.get(cid).copied().unwrap_or(0);
                            insert_scored(&mut self.cache.scheduled, s, dk, seq, cid);
                        }
                    }
                    self.cache.lane[cid] = desired;
                } else if desired == Lane::Scheduled {
                    let eb = est_bits(c);
                    let dc = self.flows_done.get(cid).copied().unwrap_or(0);
                    if eb != self.cache.est_bits[cid] || dc != self.cache.done_count[cid] {
                        remove_scored(
                            &mut self.cache.scheduled,
                            self.cache.score[cid],
                            dk,
                            seq,
                            cid,
                        );
                        let s = self.score(world, cid);
                        self.cache.score[cid] = s;
                        self.cache.est_bits[cid] = eb;
                        self.cache.done_count[cid] = dc;
                        insert_scored(&mut self.cache.scheduled, s, dk, seq, cid);
                    }
                }
            }
        }
        self.emit(plan);
    }

    /// From-scratch four-lane rebuild — the equivalence oracle for
    /// [`order_into`](Self::order_into) and the pre-optimization baseline
    /// measured by `bench_hotpath`. Ignores and leaves untouched the
    /// incremental cache.
    pub fn order_full_into(&self, world: &World, plan: &mut Plan) {
        self.order_impl(world, None, plan);
    }

    fn desired_lane(&self, world: &World, c: &CoflowState) -> Lane {
        if world.now - c.arrival > self.cfg.age_threshold {
            Lane::Express
        } else if c.phase == CoflowPhase::Piloting {
            Lane::Piloting
        } else {
            Lane::Scheduled
        }
    }

    /// Reclassify and re-sort every active coflow into the reused lane
    /// buffers (the occupancy-change slow path).
    fn rebuild_cache(&mut self, world: &World) {
        let scan = self.cache.scan;
        self.cache.express.clear();
        self.cache.piloting.clear();
        self.cache.scheduled.clear();
        for &cid in &world.active {
            let c = &world.coflows[cid];
            if c.done() {
                continue;
            }
            self.cache.seen[cid] = scan;
            let lane = self.desired_lane(world, c);
            self.cache.lane[cid] = lane;
            match lane {
                Lane::Absent => unreachable!("desired lane is never Absent"),
                Lane::Express => self.cache.express.push((c.seq, cid)),
                Lane::Piloting => self.cache.piloting.push((c.seq, cid)),
                Lane::Scheduled => {
                    let s = self.score(world, cid);
                    self.cache.score[cid] = s;
                    self.cache.est_bits[cid] = est_bits(c);
                    self.cache.done_count[cid] = self.flows_done.get(cid).copied().unwrap_or(0);
                    let dk = self.cfg.deadline_mode.key(c.deadline);
                    self.cache.scheduled.push((s, dk, c.seq, cid));
                }
            }
        }
        // Unique keys (seq / (score, seq) with unique seq), so unstable
        // sorting is deterministic and matches the oracle's output.
        self.cache.express.sort_unstable();
        self.cache.piloting.sort_unstable();
        self.cache.scheduled.sort_unstable_by(cmp_scored);
        self.cache.last_occ = world.load.occ_epoch;
    }

    /// Copy the lanes into `plan`, compacting away entries whose coflow
    /// left the active set (stamp mismatch) since the last scan.
    fn emit(&mut self, plan: &mut Plan) {
        plan.clear();
        let cache = &mut self.cache;
        let scan = cache.scan;
        let mut w = 0;
        for r in 0..cache.express.len() {
            let (seq, cid) = cache.express[r];
            if cache.seen[cid] == scan && cache.lane[cid] == Lane::Express {
                cache.express[w] = (seq, cid);
                w += 1;
                plan.entries.push(OrderEntry::all(cid));
            } else if cache.seen[cid] != scan {
                // departed coflow: clear its lane so a later re-entry is
                // re-inserted, not skipped as already-cached
                cache.lane[cid] = Lane::Absent;
            }
        }
        cache.express.truncate(w);
        // Pilot lane: only the pilot flows.
        w = 0;
        for r in 0..cache.piloting.len() {
            let (seq, cid) = cache.piloting[r];
            if cache.seen[cid] == scan && cache.lane[cid] == Lane::Piloting {
                cache.piloting[w] = (seq, cid);
                w += 1;
                plan.entries.push(OrderEntry::pilots(cid));
            } else if cache.seen[cid] != scan {
                cache.lane[cid] = Lane::Absent;
            }
        }
        cache.piloting.truncate(w);
        w = 0;
        for r in 0..cache.scheduled.len() {
            let (score, dkey, seq, cid) = cache.scheduled[r];
            if cache.seen[cid] == scan && cache.lane[cid] == Lane::Scheduled {
                cache.scheduled[w] = (score, dkey, seq, cid);
                w += 1;
                plan.entries.push(OrderEntry::all(cid));
            } else if cache.seen[cid] != scan {
                cache.lane[cid] = Lane::Absent;
            }
        }
        cache.scheduled.truncate(w);
        // Backfill lane: the unestimated coflows' non-pilot flows (the
        // pilot lane was compacted above, so reuse it directly).
        for &(_, cid) in &cache.piloting {
            plan.entries.push(OrderEntry::backfill(cid));
        }
    }

    /// Convenience wrapper allocating a fresh plan (tests and one-shot
    /// callers; hot paths use [`order_into`](Self::order_into)).
    pub fn order(&mut self, world: &World) -> Plan {
        let mut plan = Plan::default();
        self.order_into(world, &mut plan);
        plan
    }

    fn order_impl(
        &self,
        world: &World,
        scores: Option<&std::collections::HashMap<CoflowId, f64>>,
        plan: &mut Plan,
    ) {
        let mut express: Vec<CoflowId> = Vec::new();
        let mut piloting: Vec<CoflowId> = Vec::new();
        let mut scheduled: Vec<(f64, f64, u64, CoflowId)> = Vec::new();
        for &cid in &world.active {
            let c = &world.coflows[cid];
            if c.done() {
                continue;
            }
            if world.now - c.arrival > self.cfg.age_threshold {
                express.push(cid);
            } else if c.phase == CoflowPhase::Piloting {
                piloting.push(cid);
            } else {
                let s = scores
                    .and_then(|m| m.get(&cid).copied())
                    .unwrap_or_else(|| self.score(world, cid));
                let dk = self.cfg.deadline_mode.key(c.deadline);
                scheduled.push((s, dk, c.seq, cid));
            }
        }
        // (seq, cid) is the same total key the incremental lanes maintain,
        // so the two paths agree even on degenerate duplicate seqs.
        express.sort_unstable_by_key(|&cid| (world.coflows[cid].seq, cid));
        piloting.sort_unstable_by_key(|&cid| (world.coflows[cid].seq, cid));
        scheduled.sort_unstable_by(cmp_scored);

        plan.clear();
        plan.entries
            .reserve(express.len() + 2 * piloting.len() + scheduled.len());
        for &cid in &express {
            plan.entries.push(OrderEntry::all(cid));
        }
        // Pilot lane: only the pilot flows.
        for &cid in &piloting {
            plan.entries.push(OrderEntry::pilots(cid));
        }
        for &(_, _, _, cid) in &scheduled {
            plan.entries.push(OrderEntry::all(cid));
        }
        // Backfill lane: the unestimated coflows' non-pilot flows.
        for &cid in &piloting {
            plan.entries.push(OrderEntry::backfill(cid));
        }
    }
}

/// The default Philae scheduler: unbiased mean estimate, no error
/// correction (the paper's best-performing configuration).
pub struct PhilaeScheduler {
    core: PhilaeCore,
}

impl PhilaeScheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        PhilaeScheduler { core: PhilaeCore::new(cfg) }
    }

    /// Point estimate from a completed pilot sample:
    /// `width × mean(pilot sizes)` (unbiased under i.i.d. flow sizes).
    pub fn estimate(samples: &[Bytes], num_flows: usize) -> Bytes {
        if samples.is_empty() {
            return 0.0;
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        mean * num_flows as f64
    }
}

impl Scheduler for PhilaeScheduler {
    fn name(&self) -> String {
        "philae".into()
    }

    fn on_arrival(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.core.handle_arrival(cid, world)
    }

    fn on_flow_complete(&mut self, fid: FlowId, world: &mut World) -> Reaction {
        match self.core.record_completion(fid, world) {
            CompletionOutcome::SampleComplete(samples) => {
                let cid = world.flows[fid].coflow;
                let n = world.coflows[cid].flows.len();
                world.coflows[cid].est_size = Some(Self::estimate(&samples, n));
                // a coflow whose sample completes with its own last report
                // is already Done — never resurrect its phase
                if world.coflows[cid].finished_at.is_none() {
                    world.coflows[cid].phase = CoflowPhase::Running;
                }
                Reaction::Reallocate
            }
            // Completion frees port capacity; Philae's rate calculation is
            // event-triggered, and completions are events (Table 1).
            CompletionOutcome::Normal => Reaction::Reallocate,
        }
    }

    /// Batch-aware delivery (the ROADMAP "batch-aware order repair" item):
    /// one tight pass over the coalesced instant instead of one virtual
    /// hook dispatch per event. Every Philae hook reacts with
    /// `Reallocate`, so the batch's reaction is computed once; the sampling
    /// state machine sees the reports in exactly the delivery order the
    /// default replay would have used, and the four-lane order structure is
    /// repaired **once per batch** by the engine's single `order_into`
    /// call that follows (no intermediate emits can occur). Pinned
    /// bit-identical to the per-event path in
    /// `rust/tests/cct_equivalence.rs`.
    fn on_batch(&mut self, batch: &EventBatch, world: &mut World) -> Reaction {
        for &cid in &batch.arrivals {
            self.core.handle_arrival(cid, world);
        }
        for &(fid, _coflow_done) in &batch.flow_reports {
            if let CompletionOutcome::SampleComplete(samples) =
                self.core.record_completion(fid, world)
            {
                let cid = world.flows[fid].coflow;
                let n = world.coflows[cid].flows.len();
                world.coflows[cid].est_size = Some(Self::estimate(&samples, n));
                if world.coflows[cid].finished_at.is_none() {
                    world.coflows[cid].phase = CoflowPhase::Running;
                }
            }
        }
        let mut reaction = if batch.arrivals.is_empty() && batch.flow_reports.is_empty() {
            Reaction::None
        } else {
            Reaction::Reallocate
        };
        if batch.tick {
            // Philae is event-triggered (no δ tick); kept for exactness
            // with the default replay should a tick ever be routed here.
            reaction = reaction.merge(self.on_tick(world));
        }
        reaction
    }

    fn order_into(&mut self, world: &World, plan: &mut Plan) {
        self.core.order_into(world, plan);
    }

    fn order_full_into(&mut self, world: &World, plan: &mut Plan) {
        self.core.order_full_into(world, plan);
    }

    /// Cluster migration: rebuild the sampling state from completed-flow
    /// facts instead of re-piloting (the default `on_arrival` would mark a
    /// fresh pilot set that can never complete). A sample that completed
    /// in the migration window (see [`PhilaeCore::adopt`]) is estimated
    /// right here — its `SampleComplete` can no longer fire.
    fn on_coflow_attach(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        if let Some(samples) = self.core.adopt(cid, world) {
            let n = world.coflows[cid].flows.len();
            world.coflows[cid].est_size = Some(Self::estimate(&samples, n));
            if world.coflows[cid].finished_at.is_none() {
                world.coflows[cid].phase = CoflowPhase::Running;
            }
        }
        Reaction::Reallocate
    }

    fn export_state(&self) -> JsonValue {
        self.core.export_state()
    }

    /// Stale checkpoints are ignored: the adopt rebuild is strictly fresher
    /// (see [`PhilaeCore::import_state_exact`] for the starvation hazard a
    /// stale `pilots_left` overwrite would create).
    fn import_state(&mut self, state: &JsonValue, _world: &World, exact: bool) {
        if exact {
            self.core.import_state_exact(state);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{CoflowState, FlowState};
    use crate::fabric::{Fabric, PortLoad};

    fn world_with(coflow_flows: &[&[(usize, usize, f64)]]) -> World {
        let mut flows = Vec::new();
        let mut coflows = Vec::new();
        for (cid, fl) in coflow_flows.iter().enumerate() {
            let mut ids = Vec::new();
            let mut total = 0.0;
            for &(src, dst, size) in fl.iter() {
                let id = flows.len();
                flows.push(FlowState::new(id, cid, src, dst, size));
                ids.push(id);
                total += size;
            }
            let mut c = CoflowState::new(cid, 0.0, ids, total, cid as u64);
            let mut senders: Vec<usize> = fl.iter().map(|&(s, _, _)| s).collect();
            senders.sort_unstable();
            senders.dedup();
            let mut receivers: Vec<usize> = fl.iter().map(|&(_, d, _)| d).collect();
            receivers.sort_unstable();
            receivers.dedup();
            c.senders = senders;
            c.receivers = receivers;
            coflows.push(c);
        }
        let n = 8;
        World {
            now: 0.0,
            flows,
            coflows,
            fabric: Fabric::homogeneous(n, 100.0),
            load: PortLoad::new(n),
            active: (0..coflow_flows.len()).collect(),
        }
    }

    #[test]
    fn estimate_is_mean_times_width() {
        assert_eq!(PhilaeScheduler::estimate(&[10.0, 20.0], 100), 1500.0);
        assert_eq!(PhilaeScheduler::estimate(&[], 100), 0.0);
    }

    #[test]
    fn pilot_selection_prefers_distinct_senders_and_least_busy() {
        let mut w = world_with(&[&[
            (0, 4, 10.0),
            (0, 5, 10.0),
            (1, 4, 10.0),
            (1, 5, 10.0),
            (2, 4, 10.0),
            (2, 5, 10.0),
        ]]);
        // make sender 0 and receiver 4 busy
        w.load.up_bytes[0] = 1000.0;
        w.load.down_bytes[4] = 500.0;
        let mut cfg = SchedulerConfig::default();
        cfg.pilot_min = 2;
        let mut core = PhilaeCore::new(cfg);
        core.handle_arrival(0, &mut w);
        let pilots = w.coflows[0].pilots.clone();
        assert_eq!(pilots.len(), 2);
        // distinct senders AND distinct receivers (spatial sampling)
        let srcs: Vec<_> = pilots.iter().map(|&f| w.flows[f].src).collect();
        let dsts: Vec<_> = pilots.iter().map(|&f| w.flows[f].dst).collect();
        assert_ne!(srcs[0], srcs[1]);
        assert_ne!(dsts[0], dsts[1]);
        // the busy sender 0 should not host a pilot; the least-busy pair
        // (1→5) must be the first pick
        assert!(!srcs.contains(&0));
        assert!(pilots.iter().any(|&f| w.flows[f].src == 1 && w.flows[f].dst == 5));
        for &f in &pilots {
            assert!(w.flows[f].pilot);
        }
        assert_eq!(w.coflows[0].phase, CoflowPhase::Piloting);
    }

    #[test]
    fn sample_completes_after_all_pilots() {
        let mut w = world_with(&[&[(0, 4, 10.0), (1, 5, 30.0), (2, 6, 50.0)]]);
        let mut cfg = SchedulerConfig::default();
        cfg.pilot_min = 2;
        let mut core = PhilaeCore::new(cfg);
        core.handle_arrival(0, &mut w);
        let pilots = w.coflows[0].pilots.clone();
        assert_eq!(pilots.len(), 2);
        // finish first pilot: not complete yet
        w.flows[pilots[0]].finished_at = Some(1.0);
        let sent0 = w.flows[pilots[0]].size;
        w.flows[pilots[0]].sent = sent0;
        assert_eq!(core.record_completion(pilots[0], &mut w), CompletionOutcome::Normal);
        // finish second pilot: sample complete with both sizes
        w.flows[pilots[1]].finished_at = Some(2.0);
        let sent1 = w.flows[pilots[1]].size;
        w.flows[pilots[1]].sent = sent1;
        match core.record_completion(pilots[1], &mut w) {
            CompletionOutcome::SampleComplete(s) => {
                assert_eq!(s.len(), 2);
                assert!((s.iter().sum::<f64>() - (sent0 + sent1)).abs() < 1e-9);
            }
            o => panic!("expected SampleComplete, got {o:?}"),
        }
        assert_eq!(core.done_bytes(0), sent0 + sent1);
    }

    #[test]
    fn order_lanes_pilots_before_estimated_before_backfill() {
        let mut w = world_with(&[
            &[(0, 4, 10.0), (1, 5, 10.0)], // coflow 0: estimated
            &[(2, 6, 10.0), (3, 7, 10.0)], // coflow 1: piloting
        ]);
        let mut cfg = SchedulerConfig::default();
        cfg.pilot_min = 1;
        cfg.pilot_max = 1;
        let mut core = PhilaeCore::new(cfg);
        core.handle_arrival(0, &mut w);
        core.handle_arrival(1, &mut w);
        // estimate coflow 0 directly
        w.coflows[0].est_size = Some(20.0);
        w.coflows[0].phase = CoflowPhase::Running;
        let order = core.order(&w);
        // pilot lane of coflow 1 first, then estimated coflow 0, then the
        // backfill lane of coflow 1
        assert_eq!(
            order.entries,
            vec![
                OrderEntry::pilots(1),
                OrderEntry::all(0),
                OrderEntry::backfill(1),
            ]
        );
    }

    #[test]
    fn shorter_estimated_coflow_ranks_first() {
        let mut w = world_with(&[
            &[(0, 4, 100.0)],
            &[(1, 5, 10.0)],
        ]);
        for cid in 0..2 {
            w.coflows[cid].phase = CoflowPhase::Running;
        }
        w.coflows[0].est_size = Some(100.0);
        w.coflows[1].est_size = Some(10.0);
        let mut core = PhilaeCore::new(SchedulerConfig::default());
        let order = core.order(&w);
        assert_eq!(order.entries, vec![OrderEntry::all(1), OrderEntry::all(0)]);
    }

    #[test]
    fn express_lane_preempts_everything() {
        let mut w = world_with(&[
            &[(0, 4, 10.0)], // will be aged
            &[(1, 5, 1.0)],
        ]);
        for cid in 0..2 {
            w.coflows[cid].phase = CoflowPhase::Running;
            w.coflows[cid].est_size = Some(w.coflows[cid].total_bytes);
        }
        let mut cfg = SchedulerConfig::default();
        cfg.age_threshold = 5.0;
        w.now = 10.0; // coflow 0 is 10s old > threshold
        w.coflows[1].arrival = 9.0; // coflow 1 is fresh
        let mut core = PhilaeCore::new(cfg);
        let order = core.order(&w);
        assert_eq!(order.entries[0].coflow, 0, "aged coflow must come first despite larger size");
    }

    #[test]
    fn incremental_order_tracks_transitions_and_matches_oracle() {
        let mut w = world_with(&[
            &[(0, 4, 10.0), (1, 5, 10.0)],
            &[(2, 6, 10.0), (3, 7, 10.0)],
            &[(0, 6, 30.0)],
        ]);
        let mut cfg = SchedulerConfig::default();
        cfg.pilot_min = 1;
        cfg.pilot_max = 1;
        let mut core = PhilaeCore::new(cfg);
        for cid in 0..3 {
            core.handle_arrival(cid, &mut w);
        }
        let check = |core: &mut PhilaeCore, w: &World| {
            let mut inc = Plan::default();
            let mut full = Plan::default();
            core.order_into(w, &mut inc);
            core.order_full_into(w, &mut full);
            assert_eq!(inc.entries, full.entries);
        };
        check(&mut core, &w); // all piloting
        // estimate coflow 1: piloting → scheduled transition
        w.coflows[1].est_size = Some(20.0);
        w.coflows[1].phase = CoflowPhase::Running;
        check(&mut core, &w);
        // estimate coflow 0 with a smaller size: must sort before coflow 1
        w.coflows[0].est_size = Some(5.0);
        w.coflows[0].phase = CoflowPhase::Running;
        check(&mut core, &w);
        // a score change repositions within the scheduled lane
        w.coflows[1].est_size = Some(1.0);
        check(&mut core, &w);
        // coflow 2 finishes: dropped from the emitted plan
        w.coflows[2].finished_at = Some(1.0);
        w.active.retain(|&c| c != 2);
        check(&mut core, &w);
        // aging flips coflow 1 into the express lane
        w.now = 1e9;
        check(&mut core, &w);
        // occupancy change forces the rebuild path
        w.load.occupy_up(0);
        check(&mut core, &w);
    }

    #[test]
    fn adopt_rebuilds_learning_state_from_completed_flows() {
        let mut w = world_with(&[&[(0, 4, 10.0), (1, 5, 30.0), (2, 6, 50.0), (3, 7, 70.0)]]);
        let mut cfg = SchedulerConfig::default();
        cfg.pilot_min = 2;
        cfg.pilot_max = 2;
        let mut src = PhilaeCore::new(cfg.clone());
        src.handle_arrival(0, &mut w);
        let pilots = w.coflows[0].pilots.clone();
        assert_eq!(pilots.len(), 2);
        // one pilot and one non-pilot finished on the source shard
        w.flows[pilots[0]].sent = w.flows[pilots[0]].size;
        w.flows[pilots[0]].finished_at = Some(1.0);
        src.record_completion(pilots[0], &mut w);
        let non_pilot = (0..4).find(|f| !w.flows[*f].pilot).unwrap();
        w.flows[non_pilot].sent = w.flows[non_pilot].size;
        w.flows[non_pilot].finished_at = Some(1.5);
        src.record_completion(non_pilot, &mut w);

        // a fresh core adopts mid-sample: the outstanding pilot still gates
        let mut dst = PhilaeCore::new(cfg.clone());
        assert!(dst.adopt(0, &w).is_none(), "sample is still outstanding");
        assert_eq!(dst.flows_done(0), 2);
        assert_eq!(dst.done_bytes(0), w.flows[pilots[0]].size + w.flows[non_pilot].size);
        assert_eq!(dst.pilot_sizes(0).to_vec(), vec![w.flows[pilots[0]].size]);
        // a replay of the already-counted pilot's report (its delivery was
        // in flight at migration time) must NOT re-enter the sample
        assert_eq!(dst.record_completion(pilots[0], &mut w), CompletionOutcome::Normal);
        assert_eq!(dst.pilot_sizes(0).len(), 1, "replayed pilot duplicated the sample");
        // finishing the second pilot on the adopter completes the sample
        w.flows[pilots[1]].sent = w.flows[pilots[1]].size;
        w.flows[pilots[1]].finished_at = Some(2.0);
        match dst.record_completion(pilots[1], &mut w) {
            CompletionOutcome::SampleComplete(s) => assert_eq!(s.len(), 2),
            o => panic!("expected SampleComplete, got {o:?}"),
        }

        // adopting after every pilot finished but before the estimate was
        // set (the in-flight-report migration window) hands the completed
        // sample to the adopter for immediate estimation
        let mut dst3 = PhilaeCore::new(cfg.clone());
        match dst3.adopt(0, &w) {
            Some(s) => assert_eq!(s.len(), 2),
            None => panic!("expected the completed sample at adopt time"),
        }

        // adopting an already-estimated coflow must never re-fire the
        // sample: the pilot gate is pinned to zero, so even a pilot's
        // report stays Normal
        w.coflows[0].est_size = Some(160.0);
        let mut dst2 = PhilaeCore::new(cfg);
        assert!(dst2.adopt(0, &w).is_none());
        assert_eq!(dst2.record_completion(pilots[1], &mut w), CompletionOutcome::Normal);
    }

    #[test]
    fn secondary_deadline_key_breaks_score_ties() {
        use crate::coordinator::DeadlineMode;
        let mk = || {
            let mut w = world_with(&[&[(0, 4, 10.0)], &[(1, 5, 10.0)]]);
            for cid in 0..2 {
                w.coflows[cid].phase = CoflowPhase::Running;
                w.coflows[cid].est_size = Some(10.0); // identical scores
            }
            w.coflows[0].deadline = Some(9.0);
            w.coflows[1].deadline = Some(3.0);
            w
        };
        // Ignore (default): deadlines invisible, FIFO seq breaks the tie
        let w = mk();
        let mut core = PhilaeCore::new(SchedulerConfig::default());
        let order = core.order(&w);
        assert_eq!(order.entries, vec![OrderEntry::all(0), OrderEntry::all(1)]);
        // Secondary: the earlier deadline wins the tie despite a later seq
        let mut cfg = SchedulerConfig::default();
        cfg.deadline_mode = DeadlineMode::Secondary;
        let mut core2 = PhilaeCore::new(cfg);
        let order2 = core2.order(&w);
        assert_eq!(order2.entries, vec![OrderEntry::all(1), OrderEntry::all(0)]);
        // incremental path agrees with the from-scratch oracle
        let mut full = Plan::default();
        core2.order_full_into(&w, &mut full);
        assert_eq!(order2.entries, full.entries);
    }

    #[test]
    fn contention_counts_other_coflows() {
        let mut w = world_with(&[
            &[(0, 4, 10.0)],
            &[(0, 4, 10.0)], // same ports as coflow 0
        ]);
        // both active on port 0 up and 4 down
        w.load.up_coflows[0] = 2;
        w.load.down_coflows[4] = 2;
        let core = PhilaeCore::new(SchedulerConfig::default());
        assert_eq!(core.contention(&w, 0), 1.0);
        w.load.up_coflows[0] = 1;
        w.load.down_coflows[4] = 1;
        assert_eq!(core.contention(&w, 0), 0.0);
    }
}
