//! FIFO baseline: coflows served strictly in arrival order (Baraat-like
//! decentralized FIFO without size learning). Included because the paper's
//! lineage (Aalo §7) compares against it, and as the weakest sane baseline
//! for the benchmark harness.

use super::{OrderEntry, Plan, Reaction, Scheduler, World};
use crate::{CoflowId, FlowId};

#[derive(Default)]
pub struct FifoScheduler {
    /// Persistent arrival order, sorted by `(seq, cid)`; arrivals are
    /// binary-search inserted, departures compacted out at emit time.
    sorted: Vec<(u64, CoflowId)>,
    /// Whether a coflow currently has an entry in `sorted`.
    present: Vec<bool>,
    /// Scan stamps for departure detection.
    seen: Vec<u64>,
    scan: u64,
}

impl FifoScheduler {
    pub fn new() -> Self {
        FifoScheduler::default()
    }

    fn ensure(&mut self, cid: CoflowId) {
        if cid >= self.present.len() {
            self.present.resize(cid + 1, false);
            self.seen.resize(cid + 1, 0);
        }
    }
}

impl Scheduler for FifoScheduler {
    fn name(&self) -> String {
        "fifo".into()
    }

    fn on_arrival(&mut self, _cid: CoflowId, _world: &mut World) -> Reaction {
        Reaction::Reallocate
    }

    fn on_flow_complete(&mut self, _fid: FlowId, _world: &mut World) -> Reaction {
        Reaction::Reallocate
    }

    fn order_into(&mut self, world: &World, plan: &mut Plan) {
        self.scan = self.scan.wrapping_add(1);
        let scan = self.scan;
        for idx in 0..world.active.len() {
            let cid = world.active[idx];
            if world.coflows[cid].done() {
                continue;
            }
            self.ensure(cid);
            self.seen[cid] = scan;
            if !self.present[cid] {
                let key = (world.coflows[cid].seq, cid);
                super::insert_sorted(&mut self.sorted, key, |a, b| a.cmp(b));
                self.present[cid] = true;
            }
        }
        plan.clear();
        let mut w = 0;
        for r in 0..self.sorted.len() {
            let (seq, cid) = self.sorted[r];
            if self.seen[cid] == scan {
                self.sorted[w] = (seq, cid);
                w += 1;
                plan.entries.push(OrderEntry::all(cid));
            } else {
                self.present[cid] = false;
            }
        }
        self.sorted.truncate(w);
    }

    /// From-scratch oracle rebuild (see trait docs).
    fn order_full_into(&mut self, world: &World, plan: &mut Plan) {
        let mut coflows: Vec<(u64, CoflowId)> = world
            .active
            .iter()
            .filter(|&&cid| !world.coflows[cid].done())
            .map(|&cid| (world.coflows[cid].seq, cid))
            .collect();
        coflows.sort_unstable();
        plan.clear();
        plan.entries
            .extend(coflows.into_iter().map(|(_, cid)| OrderEntry::all(cid)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{CoflowState, FlowState};
    use crate::fabric::{Fabric, PortLoad};

    #[test]
    fn strict_arrival_order() {
        let flows = vec![
            FlowState::new(0, 0, 0, 1, 10.0),
            FlowState::new(1, 1, 0, 1, 1.0),
        ];
        let coflows = vec![
            CoflowState::new(0, 0.0, vec![0], 10.0, 0),
            CoflowState::new(1, 0.1, vec![1], 1.0, 1),
        ];
        let w = World {
            now: 1.0,
            flows,
            coflows,
            fabric: Fabric::homogeneous(2, 100.0),
            load: PortLoad::new(2),
            active: vec![0, 1],
        };
        let mut s = FifoScheduler::new();
        // the tiny coflow arrived later: FIFO refuses to reorder
        let plan = s.order(&w);
        assert_eq!(plan.entries.iter().map(|e| e.coflow).collect::<Vec<_>>(), vec![0, 1]);
    }
}
