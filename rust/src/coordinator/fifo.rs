//! FIFO baseline: coflows served strictly in arrival order (Baraat-like
//! decentralized FIFO without size learning). Included because the paper's
//! lineage (Aalo §7) compares against it, and as the weakest sane baseline
//! for the benchmark harness.

use super::{Plan, Reaction, Scheduler, World};
use crate::{CoflowId, FlowId};

#[derive(Default)]
pub struct FifoScheduler;

impl FifoScheduler {
    pub fn new() -> Self {
        FifoScheduler
    }
}

impl Scheduler for FifoScheduler {
    fn name(&self) -> String {
        "fifo".into()
    }

    fn on_arrival(&mut self, _cid: CoflowId, _world: &mut World) -> Reaction {
        Reaction::Reallocate
    }

    fn on_flow_complete(&mut self, _fid: FlowId, _world: &mut World) -> Reaction {
        Reaction::Reallocate
    }

    fn order(&mut self, world: &World) -> Plan {
        let mut coflows: Vec<(u64, CoflowId)> = world
            .active
            .iter()
            .filter(|&&cid| !world.coflows[cid].done())
            .map(|&cid| (world.coflows[cid].seq, cid))
            .collect();
        coflows.sort_unstable();
        Plan::strict(coflows.into_iter().map(|(_, cid)| cid))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{CoflowState, FlowState};
    use crate::fabric::{Fabric, PortLoad};

    #[test]
    fn strict_arrival_order() {
        let flows = vec![
            FlowState::new(0, 0, 0, 1, 10.0),
            FlowState::new(1, 1, 0, 1, 1.0),
        ];
        let coflows = vec![
            CoflowState::new(0, 0.0, vec![0], 10.0, 0),
            CoflowState::new(1, 0.1, vec![1], 1.0, 1),
        ];
        let w = World {
            now: 1.0,
            flows,
            coflows,
            fabric: Fabric::homogeneous(2, 100.0),
            load: PortLoad::new(2),
            active: vec![0, 1],
        };
        let mut s = FifoScheduler::new();
        // the tiny coflow arrived later: FIFO refuses to reorder
        let plan = s.order(&w);
        assert_eq!(plan.entries.iter().map(|e| e.coflow).collect::<Vec<_>>(), vec![0, 1]);
    }
}
