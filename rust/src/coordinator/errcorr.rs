//! The §2.2 error-correction variants of Philae.
//!
//! The paper asks whether UCB-style confidence-interval machinery helps the
//! sampling estimator and finds it **hurts**: similar-sized coflows end up
//! round-robined by alternating lower-confidence-bounds, instead of one
//! running to completion. Three variants are evaluated against default
//! Philae on the FB trace:
//!
//! 1. [`ErrCorrMode::LcbOnly`] — use the bootstrap lower-confidence-bound
//!    `mean − 3σ_bootstrap` of the pilot sample as the size estimate.
//! 2. [`ErrCorrMode::OneRound`] — additionally re-estimate once, after the
//!    first set of `p` post-pilot flows completes (p = pilot count).
//! 3. [`ErrCorrMode::MultiRound`] — re-estimate after every further set of
//!    `p` completions until the coflow finishes.
//!
//! The bootstrap (resample the pilot sizes with replacement `B` times, take
//! the σ of the resampled means) is the same computation the L1 Pallas
//! `estimator` kernel performs with a host-provided index matrix; the
//! native implementation here uses an identical deterministic index stream
//! so the two paths agree (see `rust/tests/runtime_parity.rs`).

use super::philae::{CompletionOutcome, PhilaeCore};
use super::{Plan, Reaction, Scheduler, SchedulerConfig, World};
use crate::coflow::CoflowPhase;
use crate::util::{JsonValue, Rng};
use crate::{Bytes, CoflowId, FlowId};

/// Which §2.2 variant to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCorrMode {
    LcbOnly,
    OneRound,
    MultiRound,
}

impl ErrCorrMode {
    fn max_rounds(self) -> usize {
        match self {
            ErrCorrMode::LcbOnly => 0,
            ErrCorrMode::OneRound => 1,
            ErrCorrMode::MultiRound => usize::MAX,
        }
    }
}

/// Deterministic bootstrap: resample `samples` with replacement `b` times,
/// return (mean, σ of resampled means). The index stream is generated from
/// `seed` exactly like `python/compile/aot.py` generates the kernel's
/// resample-index matrix, so native and PJRT paths match.
pub fn bootstrap(samples: &[Bytes], b: usize, seed: u64) -> (f64, f64) {
    if samples.is_empty() {
        return (0.0, 0.0);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    if samples.len() == 1 || b == 0 {
        return (mean, 0.0);
    }
    let mut rng = Rng::seed_from_u64(seed);
    let mut means = Vec::with_capacity(b);
    for _ in 0..b {
        let mut acc = 0.0;
        for _ in 0..samples.len() {
            acc += samples[rng.below(samples.len())];
        }
        means.push(acc / samples.len() as f64);
    }
    let m = means.iter().sum::<f64>() / b as f64;
    let var = means.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / b as f64;
    (mean, var.sqrt())
}

/// Lower confidence bound `mean − k·σ_bootstrap`, floored at a small
/// positive value so a wildly uncertain coflow isn't treated as size ~0.
pub fn lcb_estimate(
    samples: &[Bytes],
    num_flows: usize,
    cfg: &SchedulerConfig,
    cid: CoflowId,
) -> Bytes {
    let (mean, sigma) = bootstrap(
        samples,
        cfg.bootstrap_resamples,
        cfg.bootstrap_seed ^ (cid as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
    );
    ((mean - cfg.lcb_sigmas * sigma) * num_flows as f64).max(1.0)
}

/// Philae with bootstrap-LCB estimation and optional iterative correction.
pub struct PhilaeErrCorrScheduler {
    core: PhilaeCore,
    mode: ErrCorrMode,
    cfg: SchedulerConfig,
    /// Per coflow: sizes of flows completed *after* estimation — the
    /// error-correction sets (§2.2: sets of `p` flows, grouped by start
    /// order; completion-grouped here since the sim dispatches in order).
    post_est: Vec<Vec<Bytes>>,
    /// Rounds of correction already applied per coflow.
    rounds_done: Vec<usize>,
    /// Pilot sample kept for re-estimation.
    pilot_sample: Vec<Vec<Bytes>>,
}

impl PhilaeErrCorrScheduler {
    pub fn new(cfg: SchedulerConfig, mode: ErrCorrMode) -> Self {
        PhilaeErrCorrScheduler {
            core: PhilaeCore::new(cfg.clone()),
            mode,
            cfg,
            post_est: Vec::new(),
            rounds_done: Vec::new(),
            pilot_sample: Vec::new(),
        }
    }

    fn ensure(&mut self, cid: CoflowId) {
        if cid >= self.post_est.len() {
            self.post_est.resize(cid + 1, Vec::new());
            self.rounds_done.resize(cid + 1, 0);
            self.pilot_sample.resize(cid + 1, Vec::new());
        }
    }
}

impl Scheduler for PhilaeErrCorrScheduler {
    fn name(&self) -> String {
        match self.mode {
            ErrCorrMode::LcbOnly => "philae-lcb".into(),
            ErrCorrMode::OneRound => "philae-ec1".into(),
            ErrCorrMode::MultiRound => "philae-ec-multi".into(),
        }
    }

    fn on_arrival(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.ensure(cid);
        self.core.handle_arrival(cid, world)
    }

    fn on_flow_complete(&mut self, fid: FlowId, world: &mut World) -> Reaction {
        let cid = world.flows[fid].coflow;
        self.ensure(cid);
        match self.core.record_completion(fid, world) {
            CompletionOutcome::SampleComplete(samples) => {
                let n = world.coflows[cid].flows.len();
                world.coflows[cid].est_size = Some(lcb_estimate(&samples, n, &self.cfg, cid));
                world.coflows[cid].phase = CoflowPhase::Running;
                self.pilot_sample[cid] = samples;
                Reaction::Reallocate
            }
            CompletionOutcome::Normal => {
                // Error-correction bookkeeping for estimated coflows.
                if world.coflows[cid].phase == CoflowPhase::Running
                    && world.coflows[cid].est_size.is_some()
                    && self.rounds_done[cid] < self.mode.max_rounds()
                {
                    self.post_est[cid].push(world.flows[fid].size);
                    let p = self.pilot_sample[cid].len().max(1);
                    if self.post_est[cid].len() >= p {
                        // one set of p flows completed → one correction round
                        self.rounds_done[cid] += 1;
                        let mut enlarged = self.pilot_sample[cid].clone();
                        enlarged.extend(self.post_est[cid].drain(..));
                        let n = world.coflows[cid].flows.len();
                        world.coflows[cid].est_size =
                            Some(lcb_estimate(&enlarged, n, &self.cfg, cid));
                        self.pilot_sample[cid] = enlarged;
                    }
                }
                Reaction::Reallocate
            }
        }
    }

    fn order_into(&mut self, world: &World, plan: &mut Plan) {
        self.core.order_into(world, plan);
    }

    fn order_full_into(&mut self, world: &World, plan: &mut Plan) {
        self.core.order_full_into(world, plan);
    }

    /// Cluster migration: rebuild the sampling core from completed-flow
    /// facts (see [`PhilaeCore::adopt`]) and restart the error-correction
    /// bookkeeping from the reconstructed pilot sample. The correction
    /// round counter restarts too — the new shard may re-run a round it
    /// cannot know already happened, which only refreshes the estimate
    /// with strictly more data (documented approximation).
    fn on_coflow_attach(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.ensure(cid);
        if let Some(samples) = self.core.adopt(cid, world) {
            // sample completed in the migration window (see
            // `PhilaeCore::adopt`): estimate now, with this variant's LCB
            let n = world.coflows[cid].flows.len();
            world.coflows[cid].est_size = Some(lcb_estimate(&samples, n, &self.cfg, cid));
            if world.coflows[cid].finished_at.is_none() {
                world.coflows[cid].phase = CoflowPhase::Running;
            }
        }
        self.pilot_sample[cid] = self.core.pilot_sizes(cid).to_vec();
        self.post_est[cid].clear();
        self.rounds_done[cid] = 0;
        Reaction::Reallocate
    }

    /// Durable facts: the sampling core's state plus the error-correction
    /// bookkeeping (partial post-estimation sets, applied round counts,
    /// and the enlarged samples the next round will re-estimate from).
    fn export_state(&self) -> JsonValue {
        use super::recovery::f64_to_json;
        let mut per = std::collections::BTreeMap::new();
        for cid in 0..self.post_est.len() {
            let mut e = std::collections::BTreeMap::new();
            e.insert(
                "post_est".to_string(),
                JsonValue::Array(self.post_est[cid].iter().map(|&b| f64_to_json(b)).collect()),
            );
            e.insert(
                "rounds_done".to_string(),
                JsonValue::Number(self.rounds_done[cid] as f64),
            );
            e.insert(
                "pilot_sample".to_string(),
                JsonValue::Array(
                    self.pilot_sample[cid].iter().map(|&b| f64_to_json(b)).collect(),
                ),
            );
            per.insert(cid.to_string(), JsonValue::Object(e));
        }
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("core".to_string(), self.core.export_state());
        doc.insert("coflows".to_string(), JsonValue::Object(per));
        JsonValue::Object(doc)
    }

    /// Exact restores overwrite wholesale (undoing the attach path's
    /// round-counter restart). Stale checkpoints are ignored — the
    /// documented migration semantics already restart correction from the
    /// reconstructed sample, which only refreshes the estimate with
    /// strictly more data.
    fn import_state(&mut self, state: &JsonValue, _world: &World, exact: bool) {
        use super::recovery::f64_from_json;
        if !exact {
            return;
        }
        let null = JsonValue::Null;
        self.core.import_state_exact(state.get("core").unwrap_or(&null));
        if let Some(per) = state.get("coflows").and_then(|v| v.as_object()) {
            for (key, e) in per {
                let Ok(cid) = key.parse::<CoflowId>() else {
                    continue;
                };
                self.ensure(cid);
                if let Some(v) = e.get("post_est").and_then(|v| v.as_array()) {
                    self.post_est[cid] = v.iter().filter_map(f64_from_json).collect();
                }
                if let Some(n) = e.get("rounds_done").and_then(|v| v.as_usize()) {
                    self.rounds_done[cid] = n;
                }
                if let Some(v) = e.get("pilot_sample").and_then(|v| v.as_array()) {
                    self.pilot_sample[cid] = v.iter().filter_map(f64_from_json).collect();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bootstrap_is_deterministic() {
        let s = [10.0, 20.0, 30.0, 40.0];
        let a = bootstrap(&s, 100, 7);
        let b = bootstrap(&s, 100, 7);
        assert_eq!(a, b);
        let c = bootstrap(&s, 100, 8);
        assert_ne!(a.1, c.1);
    }

    #[test]
    fn bootstrap_mean_matches_sample_mean() {
        let s = [10.0, 20.0, 30.0, 40.0];
        let (mean, sigma) = bootstrap(&s, 200, 1);
        assert_eq!(mean, 25.0);
        // σ of the bootstrap means ≈ sample σ/√n = 11.18/2 ≈ 5.6
        assert!(sigma > 2.0 && sigma < 10.0, "sigma={sigma}");
    }

    #[test]
    fn bootstrap_degenerate_cases() {
        assert_eq!(bootstrap(&[], 100, 1), (0.0, 0.0));
        assert_eq!(bootstrap(&[5.0], 100, 1), (5.0, 0.0));
        let (m, s) = bootstrap(&[3.0, 3.0, 3.0], 50, 1);
        assert_eq!(m, 3.0);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn lcb_below_mean_and_floored() {
        let cfg = SchedulerConfig::default();
        let samples = [10.0e6, 20.0e6, 90.0e6];
        let lcb = lcb_estimate(&samples, 100, &cfg, 0);
        let mean_est = (samples.iter().sum::<f64>() / 3.0) * 100.0;
        assert!(lcb < mean_est, "LCB {lcb} must undercut mean estimate {mean_est}");
        assert!(lcb >= 1.0);
        // huge σ with tiny mean floors at 1.0
        let tiny = lcb_estimate(&[0.0, 0.0], 10, &cfg, 0);
        assert_eq!(tiny, 1.0);
    }
}
