//! Aalo (Chowdhury & Stoica, SIGCOMM'15) — the prior-art baseline.
//!
//! Aalo learns coflow "length" implicitly with **discretized multi-level
//! feedback queues** (D-CLAS): a coflow starts in the highest-priority
//! queue Q0 and is demoted to Qi+1 once the total bytes it has sent cross
//! `E·Sⁱ`. Intra-queue order is FIFO. The coordinator needs **periodic
//! byte-count updates** from every local agent (every δ) and recomputes
//! rates every interval — exactly the overhead Table 1/Table 3 charge it
//! with. Our model keeps that staleness: queue positions only move at tick
//! boundaries, from the byte counts the coordinator has *seen* (updates can
//! be lost with `update_loss_prob`, the Table 5 network-error knob).

use super::{OrderEntry, Plan, Reaction, Scheduler, SchedulerConfig, World};
use crate::{Bytes, CoflowId, FlowId, Time};
use crate::util::Rng;

pub struct AaloScheduler {
    cfg: SchedulerConfig,
    /// Byte counts as last reported to the coordinator (stale up to δ).
    bytes_seen: Vec<Bytes>,
    /// FIFO position *within the current queue* — reset on every demotion
    /// (queue-entry order, not arrival order). This is what produces the
    /// paper's “inadvertent round-robin”: two similar coflows leapfrog each
    /// other every time one of them crosses a queue threshold.
    queue_seq: Vec<u64>,
    next_queue_seq: u64,
    /// Number of per-coflow updates received (Table 1 / Table 3 accounting).
    pub updates_received: u64,
    /// Queue moves performed (diagnostics).
    pub queue_moves: u64,
    rng: Rng,
}

impl AaloScheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        let rng = Rng::seed_from_u64(cfg.dynamics_seed.wrapping_mul(0x9e37_79b9).wrapping_add(7));
        AaloScheduler {
            cfg,
            bytes_seen: Vec::new(),
            queue_seq: Vec::new(),
            next_queue_seq: 0,
            updates_received: 0,
            queue_moves: 0,
            rng,
        }
    }

    fn ensure(&mut self, cid: CoflowId) {
        if cid >= self.bytes_seen.len() {
            self.bytes_seen.resize(cid + 1, 0.0);
            self.queue_seq.resize(cid + 1, 0);
        }
    }

    /// Queue index for a coflow that has sent `bytes`:
    /// Q0 while `bytes < E`, then Qi for `bytes < E·Sⁱ`, capped at K−1.
    pub fn queue_of(&self, bytes: Bytes) -> usize {
        let mut threshold = self.cfg.q0_threshold;
        for q in 0..self.cfg.num_queues - 1 {
            if bytes < threshold {
                return q;
            }
            threshold *= self.cfg.queue_mult;
        }
        self.cfg.num_queues - 1
    }
}

impl Scheduler for AaloScheduler {
    fn name(&self) -> String {
        "aalo".into()
    }

    fn tick_interval(&self) -> Option<Time> {
        Some(self.cfg.delta)
    }

    fn on_arrival(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.ensure(cid);
        world.coflows[cid].queue = 0;
        self.queue_seq[cid] = self.next_queue_seq;
        self.next_queue_seq += 1;
        Reaction::Reallocate
    }

    fn on_flow_complete(&mut self, _fid: FlowId, _world: &mut World) -> Reaction {
        // Local agents immediately backfill the freed port from their local
        // queues; the centralized model approximates that with a realloc.
        // Queue positions do NOT move here — only at tick boundaries.
        Reaction::Reallocate
    }

    /// δ tick: ingest byte updates (possibly lossy), demote coflows whose
    /// seen-bytes crossed their queue threshold. Aalo recomputes rates
    /// every interval regardless (the engine charges it for that).
    fn on_tick(&mut self, world: &mut World) -> Reaction {
        // Periodic pipeline: ingest byte updates, demote across queues, and
        // recompute rates — every δ, whether or not anything moved (the
        // paper's "Rate calculation: Periodic (δ)", Table 1).
        let mut reaction = if world.active.is_empty() {
            Reaction::None
        } else {
            Reaction::Reallocate
        };
        for i in 0..world.active.len() {
            let cid = world.active[i];
            self.ensure(cid);
            if self.cfg.update_loss_prob > 0.0
                && self.rng.chance(self.cfg.update_loss_prob)
            {
                continue; // update lost; coordinator keeps stale bytes
            }
            self.updates_received += 1;
            self.bytes_seen[cid] = world.coflows[cid].bytes_sent;
            let q = self.queue_of(self.bytes_seen[cid]);
            if q != world.coflows[cid].queue {
                debug_assert!(q > world.coflows[cid].queue, "Aalo demotions are monotone");
                world.coflows[cid].queue = q;
                // entering a new queue resets the FIFO position
                self.queue_seq[cid] = self.next_queue_seq;
                self.next_queue_seq += 1;
                self.queue_moves += 1;
                reaction = Reaction::Reallocate;
            }
        }
        reaction
    }

    /// D-CLAS plan: queues get **fixed weighted bandwidth shares** (§1.1:
    /// "each queue at each port receives a fixed bandwidth allocation"),
    /// decaying with queue depth; FIFO within a queue. Leftovers are
    /// backfilled in the same order (work conservation), so low queues can
    /// still run when high queues are idle.
    fn order(&mut self, world: &World) -> Plan {
        let mut coflows: Vec<(usize, u64, CoflowId)> = world
            .active
            .iter()
            .filter(|&&cid| !world.coflows[cid].done())
            .map(|&cid| {
                let qseq = self.queue_seq.get(cid).copied().unwrap_or(0);
                (world.coflows[cid].queue, qseq, cid)
            })
            .collect();
        coflows.sort_unstable();
        let entries = coflows
            .into_iter()
            .map(|(q, _, cid)| OrderEntry::grouped(cid, q))
            .collect();
        // exponentially decaying weights across the K queues
        let group_weights = (0..self.cfg.num_queues)
            .map(|q| 0.5f64.powi(q as i32))
            .collect();
        Plan { entries, group_weights }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{CoflowState, FlowState};
    use crate::fabric::{Fabric, PortLoad};
    use crate::MB;

    fn world2() -> World {
        let flows = vec![
            FlowState::new(0, 0, 0, 2, 100.0 * MB),
            FlowState::new(1, 1, 1, 3, 100.0 * MB),
        ];
        let coflows = vec![
            CoflowState::new(0, 0.0, vec![0], 100.0 * MB, 0),
            CoflowState::new(1, 0.0, vec![1], 100.0 * MB, 1),
        ];
        World {
            now: 0.0,
            flows,
            coflows,
            fabric: Fabric::homogeneous(4, 100.0),
            load: PortLoad::new(4),
            active: vec![0, 1],
        }
    }

    #[test]
    fn queue_thresholds_follow_e_times_s_powers() {
        let a = AaloScheduler::new(SchedulerConfig::default());
        // E = 10 MB, S = 10, K = 10
        assert_eq!(a.queue_of(0.0), 0);
        assert_eq!(a.queue_of(9.9 * MB), 0);
        assert_eq!(a.queue_of(10.0 * MB), 1);
        assert_eq!(a.queue_of(99.0 * MB), 1);
        assert_eq!(a.queue_of(100.0 * MB), 2);
        assert_eq!(a.queue_of(1e9 * MB), 9); // capped at K-1
    }

    #[test]
    fn tick_demotes_on_seen_bytes() {
        let mut w = world2();
        let mut a = AaloScheduler::new(SchedulerConfig::default());
        a.on_arrival(0, &mut w);
        a.on_arrival(1, &mut w);
        w.coflows[0].bytes_sent = 50.0 * MB; // crossed E
        a.on_tick(&mut w);
        assert_eq!(w.coflows[0].queue, 1);
        assert_eq!(w.coflows[1].queue, 0);
        assert_eq!(a.queue_moves, 1);
        assert_eq!(a.updates_received, 2);
        // demoted coflow now sorts after the fresh one
        let order = a.order(&w);
        assert_eq!(order.entries[0], OrderEntry::grouped(1, 0));
        assert_eq!(order.entries[1], OrderEntry::grouped(0, 1));
    }

    #[test]
    fn lost_updates_keep_stale_queue() {
        let mut w = world2();
        let mut cfg = SchedulerConfig::default();
        cfg.update_loss_prob = 1.0; // every update lost
        let mut a = AaloScheduler::new(cfg);
        a.on_arrival(0, &mut w);
        w.coflows[0].bytes_sent = 500.0 * MB;
        a.on_tick(&mut w);
        assert_eq!(w.coflows[0].queue, 0, "no update seen, no demotion");
        assert_eq!(a.updates_received, 0);
    }

    #[test]
    fn fifo_within_queue() {
        let mut w = world2();
        let mut a = AaloScheduler::new(SchedulerConfig::default());
        a.on_arrival(0, &mut w);
        a.on_arrival(1, &mut w);
        // both Q0, FIFO by seq
        let order = a.order(&w);
        assert_eq!(order.entries, vec![OrderEntry::grouped(0, 0), OrderEntry::grouped(1, 0)]);
        // queue weights decay
        assert!(order.group_weights[0] > order.group_weights[1]);
    }
}
