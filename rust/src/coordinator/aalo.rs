//! Aalo (Chowdhury & Stoica, SIGCOMM'15) — the prior-art baseline.
//!
//! Aalo learns coflow "length" implicitly with **discretized multi-level
//! feedback queues** (D-CLAS): a coflow starts in the highest-priority
//! queue Q0 and is demoted to Qi+1 once the total bytes it has sent cross
//! `E·Sⁱ`. Intra-queue order is FIFO. The coordinator needs **periodic
//! byte-count updates** from every local agent (every δ) and recomputes
//! rates every interval — exactly the overhead Table 1/Table 3 charge it
//! with. Our model keeps that staleness: queue positions only move at tick
//! boundaries, from the byte counts the coordinator has *seen* (updates can
//! be lost with `update_loss_prob`, the Table 5 network-error knob).

use super::{EventBatch, OrderEntry, Plan, Reaction, Scheduler, SchedulerConfig, World};
use crate::util::{JsonValue, Rng};
use crate::{Bytes, CoflowId, FlowId, Time};

/// Sorted-order key: `(queue, deadline key, qseq, cid)`. The deadline key
/// is `+∞` outside [`DeadlineMode::Secondary`]
/// (`crate::coordinator::DeadlineMode`), so the default order is the
/// classic D-CLAS `(queue, qseq)`.
type AaloKey = (usize, f64, u64, CoflowId);

#[inline]
fn cmp_key(a: &AaloKey, b: &AaloKey) -> std::cmp::Ordering {
    a.0.cmp(&b.0)
        .then(a.1.total_cmp(&b.1))
        .then(a.2.cmp(&b.2))
        .then(a.3.cmp(&b.3))
}

/// Binary-search insert into the sorted order.
fn insert_key(v: &mut Vec<AaloKey>, key: AaloKey) {
    super::insert_sorted(v, key, cmp_key);
}

/// Remove `key` from the sorted order (defensive linear fallback on a
/// stale key; no-op if the coflow is absent entirely).
fn remove_key(v: &mut Vec<AaloKey>, key: AaloKey) {
    super::remove_sorted(v, &key, cmp_key, |e| e.3 == key.3);
}

pub struct AaloScheduler {
    cfg: SchedulerConfig,
    /// Byte counts as last reported to the coordinator (stale up to δ).
    bytes_seen: Vec<Bytes>,
    /// FIFO position *within the current queue* — reset on every demotion
    /// (queue-entry order, not arrival order). This is what produces the
    /// paper's “inadvertent round-robin”: two similar coflows leapfrog each
    /// other every time one of them crosses a queue threshold.
    queue_seq: Vec<u64>,
    next_queue_seq: u64,
    /// Number of per-coflow updates received (Table 1 / Table 3 accounting).
    pub updates_received: u64,
    /// Queue moves performed (diagnostics).
    pub queue_moves: u64,
    rng: Rng,
    /// Exponentially decaying D-CLAS group weights (static per config).
    weights: Vec<f64>,
    /// Incrementally maintained order, sorted by
    /// `(queue, deadline key, qseq, cid)`; repaired around the single
    /// coflow whose queue position changed instead of re-sorting all
    /// active coflows per event.
    sorted: Vec<AaloKey>,
    /// Cached `(queue, qseq)` key per coflow (`usize::MAX` = absent).
    cached: Vec<(usize, u64)>,
    /// Scan stamps for dropping departed coflows at emit time.
    seen: Vec<u64>,
    scan: u64,
}

impl AaloScheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        let rng = Rng::seed_from_u64(cfg.dynamics_seed.wrapping_mul(0x9e37_79b9).wrapping_add(7));
        let weights = (0..cfg.num_queues).map(|q| 0.5f64.powi(q as i32)).collect();
        AaloScheduler {
            cfg,
            bytes_seen: Vec::new(),
            queue_seq: Vec::new(),
            next_queue_seq: 0,
            updates_received: 0,
            queue_moves: 0,
            rng,
            weights,
            sorted: Vec::new(),
            cached: Vec::new(),
            seen: Vec::new(),
            scan: 0,
        }
    }

    fn ensure(&mut self, cid: CoflowId) {
        if cid >= self.bytes_seen.len() {
            self.bytes_seen.resize(cid + 1, 0.0);
            self.queue_seq.resize(cid + 1, 0);
            self.cached.resize(cid + 1, (usize::MAX, 0));
            self.seen.resize(cid + 1, 0);
        }
    }

    /// Queue index for a coflow that has sent `bytes`:
    /// Q0 while `bytes < E`, then Qi for `bytes < E·Sⁱ`, capped at K−1.
    pub fn queue_of(&self, bytes: Bytes) -> usize {
        let mut threshold = self.cfg.q0_threshold;
        for q in 0..self.cfg.num_queues - 1 {
            if bytes < threshold {
                return q;
            }
            threshold *= self.cfg.queue_mult;
        }
        self.cfg.num_queues - 1
    }
}

impl Scheduler for AaloScheduler {
    fn name(&self) -> String {
        "aalo".into()
    }

    fn tick_interval(&self) -> Option<Time> {
        Some(self.cfg.delta)
    }

    fn on_arrival(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.ensure(cid);
        world.coflows[cid].queue = 0;
        self.queue_seq[cid] = self.next_queue_seq;
        self.next_queue_seq += 1;
        Reaction::Reallocate
    }

    fn on_flow_complete(&mut self, _fid: FlowId, _world: &mut World) -> Reaction {
        // Local agents immediately backfill the freed port from their local
        // queues; the centralized model approximates that with a realloc.
        // Queue positions do NOT move here — only at tick boundaries.
        Reaction::Reallocate
    }

    /// Batch-aware delivery (the ROADMAP "batch-aware order repair" item):
    /// handle the coalesced instant in one pass instead of one virtual
    /// hook dispatch per event. Flow/coflow-completion reports carry no
    /// Aalo state (queue positions only move at δ ticks), so the whole
    /// report list folds into a single `Reallocate`; arrivals and the tick
    /// run their usual hooks, and the sorted `(queue, deadline key, qseq)`
    /// order is repaired **once per batch** by the engine's single
    /// `order_into` call that follows. Pinned bit-identical to the
    /// per-event path in `rust/tests/cct_equivalence.rs`.
    fn on_batch(&mut self, batch: &EventBatch, world: &mut World) -> Reaction {
        let mut reaction = Reaction::None;
        for &cid in &batch.arrivals {
            reaction = reaction.merge(self.on_arrival(cid, world));
        }
        if !batch.flow_reports.is_empty() {
            // on_flow_complete and the default on_coflow_complete both
            // react with Reallocate and mutate nothing
            reaction = reaction.merge(Reaction::Reallocate);
        }
        if batch.tick {
            reaction = reaction.merge(self.on_tick(world));
        }
        reaction
    }

    /// δ tick: ingest byte updates (possibly lossy), demote coflows whose
    /// seen-bytes crossed their queue threshold. Aalo recomputes rates
    /// every interval regardless (the engine charges it for that).
    fn on_tick(&mut self, world: &mut World) -> Reaction {
        // Periodic pipeline: ingest byte updates, demote across queues, and
        // recompute rates — every δ, whether or not anything moved (the
        // paper's "Rate calculation: Periodic (δ)", Table 1).
        let mut reaction = if world.active.is_empty() {
            Reaction::None
        } else {
            Reaction::Reallocate
        };
        for i in 0..world.active.len() {
            let cid = world.active[i];
            self.ensure(cid);
            if self.cfg.update_loss_prob > 0.0
                && self.rng.chance(self.cfg.update_loss_prob)
            {
                continue; // update lost; coordinator keeps stale bytes
            }
            self.updates_received += 1;
            self.bytes_seen[cid] = world.coflows[cid].bytes_sent;
            let q = self.queue_of(self.bytes_seen[cid]);
            if q != world.coflows[cid].queue {
                debug_assert!(q > world.coflows[cid].queue, "Aalo demotions are monotone");
                world.coflows[cid].queue = q;
                // entering a new queue resets the FIFO position
                self.queue_seq[cid] = self.next_queue_seq;
                self.next_queue_seq += 1;
                self.queue_moves += 1;
                reaction = Reaction::Reallocate;
            }
        }
        reaction
    }

    /// D-CLAS plan: queues get **fixed weighted bandwidth shares** (§1.1:
    /// "each queue at each port receives a fixed bandwidth allocation"),
    /// decaying with queue depth; FIFO within a queue. Leftovers are
    /// backfilled in the same order (work conservation), so low queues can
    /// still run when high queues are idle.
    ///
    /// Incremental: the `(queue, deadline key, qseq, cid)` order persists
    /// across events; each call repairs only the coflows whose queue
    /// position moved (a demotion or a new arrival) and compacts out
    /// departed coflows while emitting — no per-event sort or allocation
    /// in steady state. The deadline key is static per coflow (`+∞`
    /// outside `DeadlineMode::Secondary`), so `(queue, qseq)` remains a
    /// complete change detector.
    fn order_into(&mut self, world: &World, plan: &mut Plan) {
        self.scan = self.scan.wrapping_add(1);
        let scan = self.scan;
        for idx in 0..world.active.len() {
            let cid = world.active[idx];
            if world.coflows[cid].done() {
                continue;
            }
            self.ensure(cid);
            self.seen[cid] = scan;
            // the deadline key is static per coflow, so the cached
            // (queue, qseq) pair remains a complete change detector
            let dk = self.cfg.deadline_mode.key(world.coflows[cid].deadline);
            let key = (world.coflows[cid].queue, self.queue_seq[cid]);
            if self.cached[cid] != key {
                if self.cached[cid].0 != usize::MAX {
                    let old = (self.cached[cid].0, dk, self.cached[cid].1, cid);
                    remove_key(&mut self.sorted, old);
                }
                insert_key(&mut self.sorted, (key.0, dk, key.1, cid));
                self.cached[cid] = key;
            }
        }
        plan.clear();
        let mut w = 0;
        for r in 0..self.sorted.len() {
            let (q, dk, qs, cid) = self.sorted[r];
            if self.seen[cid] == scan && self.cached[cid] == (q, qs) {
                self.sorted[w] = (q, dk, qs, cid);
                w += 1;
                plan.entries.push(OrderEntry::grouped(cid, q));
            } else if self.seen[cid] != scan {
                // departed coflow: reset the sentinel so a later re-entry
                // with an unchanged key is re-inserted, not skipped
                self.cached[cid] = (usize::MAX, 0);
            }
        }
        self.sorted.truncate(w);
        plan.group_weights.clone_from(&self.weights);
    }

    /// Cluster migration: the handoff ships the coordinator's last byte
    /// aggregate, and the coflow keeps the queue it earned — the default
    /// `on_arrival` would reset it to Q0, a priority *upgrade* for a large
    /// half-sent coflow. It enters the back of its queue's FIFO on the new
    /// shard (fresh `queue_seq`, the deterministic tie-break).
    fn on_coflow_attach(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.ensure(cid);
        self.bytes_seen[cid] = world.coflows[cid].bytes_sent;
        self.queue_seq[cid] = self.next_queue_seq;
        self.next_queue_seq += 1;
        Reaction::Reallocate
    }

    /// Durable facts: the coordinator's (possibly stale) seen-bytes view,
    /// each coflow's FIFO position within its queue, the sequence counter,
    /// the loss-model RNG position, and the Table 1/3 accounting counters.
    fn export_state(&self) -> JsonValue {
        use super::recovery::{f64_to_json, u64_to_json};
        let mut per = std::collections::BTreeMap::new();
        // every slot is exported: (0, 0.0) is indistinguishable from the
        // legitimate state of the first coflow, which must still overwrite
        // the fresh FIFO position the attach pass assigned it
        for cid in 0..self.bytes_seen.len() {
            let mut e = std::collections::BTreeMap::new();
            e.insert("bytes_seen".to_string(), f64_to_json(self.bytes_seen[cid]));
            e.insert("queue_seq".to_string(), u64_to_json(self.queue_seq[cid]));
            per.insert(cid.to_string(), JsonValue::Object(e));
        }
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("coflows".to_string(), JsonValue::Object(per));
        doc.insert("next_queue_seq".to_string(), u64_to_json(self.next_queue_seq));
        doc.insert("updates_received".to_string(), u64_to_json(self.updates_received));
        doc.insert("queue_moves".to_string(), u64_to_json(self.queue_moves));
        doc.insert("rng".to_string(), u64_to_json(self.rng.state()));
        JsonValue::Object(doc)
    }

    /// Exact restores overwrite wholesale — undoing the fresh FIFO
    /// positions the attach pass assigned — for bit-identity with the
    /// uninterrupted run. Stale checkpoints are ignored: entering the back
    /// of the earned queue's FIFO is precisely the documented migration
    /// semantics, and the attach pass already re-read the byte counts.
    fn import_state(&mut self, state: &JsonValue, _world: &World, exact: bool) {
        use super::recovery::{f64_from_json, u64_from_json};
        if !exact {
            return;
        }
        if let Some(per) = state.get("coflows").and_then(|v| v.as_object()) {
            for (key, e) in per {
                let Ok(cid) = key.parse::<CoflowId>() else {
                    continue;
                };
                self.ensure(cid);
                if let Some(b) = e.get("bytes_seen").and_then(f64_from_json) {
                    self.bytes_seen[cid] = b;
                }
                if let Some(qs) = e.get("queue_seq").and_then(u64_from_json) {
                    self.queue_seq[cid] = qs;
                }
            }
        }
        if let Some(x) = state.get("next_queue_seq").and_then(u64_from_json) {
            self.next_queue_seq = x;
        }
        if let Some(x) = state.get("updates_received").and_then(u64_from_json) {
            self.updates_received = x;
        }
        if let Some(x) = state.get("queue_moves").and_then(u64_from_json) {
            self.queue_moves = x;
        }
        if let Some(x) = state.get("rng").and_then(u64_from_json) {
            self.rng = Rng::from_state(x);
        }
    }

    /// From-scratch oracle rebuild (see trait docs).
    fn order_full_into(&mut self, world: &World, plan: &mut Plan) {
        let mut coflows: Vec<AaloKey> = world
            .active
            .iter()
            .filter(|&&cid| !world.coflows[cid].done())
            .map(|&cid| {
                let qseq = self.queue_seq.get(cid).copied().unwrap_or(0);
                let dk = self.cfg.deadline_mode.key(world.coflows[cid].deadline);
                (world.coflows[cid].queue, dk, qseq, cid)
            })
            .collect();
        coflows.sort_unstable_by(cmp_key);
        plan.clear();
        plan.entries
            .extend(coflows.into_iter().map(|(q, _, _, cid)| OrderEntry::grouped(cid, q)));
        // exponentially decaying weights across the K queues
        plan.group_weights.clear();
        plan.group_weights
            .extend((0..self.cfg.num_queues).map(|q| 0.5f64.powi(q as i32)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{CoflowState, FlowState};
    use crate::fabric::{Fabric, PortLoad};
    use crate::MB;

    fn world2() -> World {
        let flows = vec![
            FlowState::new(0, 0, 0, 2, 100.0 * MB),
            FlowState::new(1, 1, 1, 3, 100.0 * MB),
        ];
        let coflows = vec![
            CoflowState::new(0, 0.0, vec![0], 100.0 * MB, 0),
            CoflowState::new(1, 0.0, vec![1], 100.0 * MB, 1),
        ];
        World {
            now: 0.0,
            flows,
            coflows,
            fabric: Fabric::homogeneous(4, 100.0),
            load: PortLoad::new(4),
            active: vec![0, 1],
        }
    }

    #[test]
    fn queue_thresholds_follow_e_times_s_powers() {
        let a = AaloScheduler::new(SchedulerConfig::default());
        // E = 10 MB, S = 10, K = 10
        assert_eq!(a.queue_of(0.0), 0);
        assert_eq!(a.queue_of(9.9 * MB), 0);
        assert_eq!(a.queue_of(10.0 * MB), 1);
        assert_eq!(a.queue_of(99.0 * MB), 1);
        assert_eq!(a.queue_of(100.0 * MB), 2);
        assert_eq!(a.queue_of(1e9 * MB), 9); // capped at K-1
    }

    #[test]
    fn tick_demotes_on_seen_bytes() {
        let mut w = world2();
        let mut a = AaloScheduler::new(SchedulerConfig::default());
        a.on_arrival(0, &mut w);
        a.on_arrival(1, &mut w);
        w.coflows[0].bytes_sent = 50.0 * MB; // crossed E
        a.on_tick(&mut w);
        assert_eq!(w.coflows[0].queue, 1);
        assert_eq!(w.coflows[1].queue, 0);
        assert_eq!(a.queue_moves, 1);
        assert_eq!(a.updates_received, 2);
        // demoted coflow now sorts after the fresh one
        let order = a.order(&w);
        assert_eq!(order.entries[0], OrderEntry::grouped(1, 0));
        assert_eq!(order.entries[1], OrderEntry::grouped(0, 1));
    }

    #[test]
    fn lost_updates_keep_stale_queue() {
        let mut w = world2();
        let mut cfg = SchedulerConfig::default();
        cfg.update_loss_prob = 1.0; // every update lost
        let mut a = AaloScheduler::new(cfg);
        a.on_arrival(0, &mut w);
        w.coflows[0].bytes_sent = 500.0 * MB;
        a.on_tick(&mut w);
        assert_eq!(w.coflows[0].queue, 0, "no update seen, no demotion");
        assert_eq!(a.updates_received, 0);
    }

    #[test]
    fn incremental_order_matches_oracle_across_demotions() {
        let mut w = world2();
        let mut a = AaloScheduler::new(SchedulerConfig::default());
        a.on_arrival(0, &mut w);
        a.on_arrival(1, &mut w);
        let check = |a: &mut AaloScheduler, w: &World| {
            let mut inc = Plan::default();
            let mut full = Plan::default();
            a.order_into(w, &mut inc);
            a.order_full_into(w, &mut full);
            assert_eq!(inc.entries, full.entries);
            assert_eq!(inc.group_weights, full.group_weights);
        };
        check(&mut a, &w);
        // demotion repositions coflow 0 behind coflow 1
        w.coflows[0].bytes_sent = 50.0 * MB;
        a.on_tick(&mut w);
        check(&mut a, &w);
        // a second demotion
        w.coflows[0].bytes_sent = 500.0 * MB;
        a.on_tick(&mut w);
        check(&mut a, &w);
        // departure: coflow 1 finishes and leaves the active set
        w.coflows[1].finished_at = Some(1.0);
        w.active.retain(|&c| c != 1);
        check(&mut a, &w);
    }

    #[test]
    fn secondary_deadline_key_orders_within_queue() {
        use crate::coordinator::DeadlineMode;
        let mut w = world2();
        w.coflows[0].deadline = Some(9.0);
        w.coflows[1].deadline = Some(3.0);
        // Ignore: FIFO within the queue, deadlines invisible
        let mut a = AaloScheduler::new(SchedulerConfig::default());
        a.on_arrival(0, &mut w);
        a.on_arrival(1, &mut w);
        let order = a.order(&w);
        assert_eq!(order.entries[0].coflow, 0);
        // Secondary: same queue, earlier deadline first despite later qseq
        let mut cfg = SchedulerConfig::default();
        cfg.deadline_mode = DeadlineMode::Secondary;
        let mut b = AaloScheduler::new(cfg);
        b.on_arrival(0, &mut w);
        b.on_arrival(1, &mut w);
        let order = b.order(&w);
        assert_eq!(order.entries[0].coflow, 1);
        // incremental matches the oracle under the secondary key
        let mut full = Plan::default();
        b.order_full_into(&w, &mut full);
        let mut inc = Plan::default();
        b.order_into(&w, &mut inc);
        assert_eq!(inc.entries, full.entries);
    }

    #[test]
    fn fifo_within_queue() {
        let mut w = world2();
        let mut a = AaloScheduler::new(SchedulerConfig::default());
        a.on_arrival(0, &mut w);
        a.on_arrival(1, &mut w);
        // both Q0, FIFO by seq
        let order = a.order(&w);
        assert_eq!(order.entries, vec![OrderEntry::grouped(0, 0), OrderEntry::grouped(1, 0)]);
        // queue weights decay
        assert!(order.group_weights[0] > order.group_weights[1]);
    }
}
