//! Coordinator crash-failover: checkpoint, restore, and the sealed
//! checkpoint file format.
//!
//! ## Why (paper §3; ROADMAP item 4)
//!
//! Philae's scalability argument is that sampling shrinks coordinator–agent
//! interaction enough to track 900-node fabrics from **one** coordinator —
//! which makes that coordinator the single point of failure. A production
//! deployment must survive coordinator restarts without forgetting what the
//! cluster learned (pilot samples, earned queue positions, admission
//! verdicts), and without wedging the fabric while it recovers.
//!
//! ## Migration *is* recovery
//!
//! The multi-coordinator work (PR 3) already forced every scheduler to
//! answer "how do I adopt a mid-flight coflow from someone else?" —
//! [`Scheduler::on_coflow_attach`] rebuilds learning state from
//! *completed-flow facts*: Philae re-derives its sample from finished pilot
//! flows, Aalo re-reads earned bytes, dcoflow re-admits from remaining
//! bytes (arXiv 2205.01229's admission test is memoryless given remaining
//! work). A coordinator crash is simply the migration of **all** of a
//! shard's coflows to a fresh instance of the same policy, so recovery
//! needs no new scheduler theory: build a fresh scheduler, attach every
//! owned coflow, then overlay the checkpoint's durable facts.
//!
//! ## What is durable and what self-heals
//!
//! Two classes of scheduler state are deliberately **not** checkpointed:
//!
//! * *world-derived* state (bytes sent, remaining bytes, finished flows) —
//!   the agents' ground truth survives the coordinator and is re-read by
//!   the attach pass;
//! * *incremental order caches* — they are pure accelerations of
//!   `order_full_into` (pinned equivalent in `order_equivalence.rs`) and
//!   rebuild themselves on the next `order_into` scan.
//!
//! What remains is each policy's **learned/earned facts** that the world
//! cannot reproduce: Philae's pilot sample in delivery order (the float-sum
//! order matters for bit-exactness) and `pilots_left`, Aalo's seen bytes,
//! FIFO queue sequence and loss-model RNG position, Saath's queue-move
//! counter, dcoflow's admission verdicts, laxities and port reservations,
//! errcorr's correction rounds and enlarged samples. Those go through
//! [`Scheduler::export_state`] / [`Scheduler::import_state`].
//!
//! ## Restore order and bit-identity
//!
//! [`restore_scheduler`] runs: **build → attach every active coflow →
//! import → overlay** (the checkpoint's per-coflow `est_size`/`phase`,
//! which the attach pass rewrites). Import runs *after* attach so the
//! checkpoint is the last word — it undoes the attach path's deliberate
//! migration approximations (fresh Aalo FIFO position, dcoflow
//! re-admission, Philae's pilots-list sample order). With a checkpoint
//! taken at the same event boundary (`exact = true`) the restored
//! scheduler is **bit-identical** to the uninterrupted one for all ten
//! [`SchedulerKind`]s — `tests/chaos_recovery.rs` pins CCTs, counters and
//! deadline verdicts to the bit. With a stale periodic checkpoint
//! (`exact = false`, the chaos path) attach-derived facts are fresher and
//! win; only crash-critical certificates (dcoflow's admitted verdicts and
//! their reservations) are merged back from the checkpoint.
//!
//! ## File format
//!
//! A sealed checkpoint is a single JSON document
//! `{"checksum": "<fnv1a64 hex>", "payload": {...}, "version": 1}` whose
//! checksum covers the **canonical encoding** of the payload (sorted keys,
//! shortest round-trip floats — see `util::json`), so any reader can
//! re-serialize and verify. [`write_atomic`] publishes via
//! write-to-sibling + rename, so a crash mid-write never leaves a torn
//! checkpoint under the live name.

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use super::{Scheduler, SchedulerConfig, SchedulerKind, World};
use crate::coflow::CoflowPhase;
use crate::trace::Trace;
use crate::util::json::JsonError;
use crate::util::JsonValue;

/// Format version of sealed checkpoints.
pub const CHECKPOINT_VERSION: f64 = 1.0;

/// Why a checkpoint could not be restored.
#[derive(Debug)]
pub enum RecoveryError {
    /// Structurally valid JSON, but not a usable checkpoint.
    Corrupt(&'static str),
    /// Not valid JSON at all.
    Json(JsonError),
    /// Filesystem failure reading or writing the checkpoint.
    Io(io::Error),
}

impl fmt::Display for RecoveryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RecoveryError::Corrupt(msg) => write!(f, "corrupt checkpoint: {msg}"),
            RecoveryError::Json(e) => write!(f, "checkpoint parse failure: {e}"),
            RecoveryError::Io(e) => write!(f, "checkpoint io failure: {e}"),
        }
    }
}

impl std::error::Error for RecoveryError {}

impl From<JsonError> for RecoveryError {
    fn from(e: JsonError) -> Self {
        RecoveryError::Json(e)
    }
}

impl From<io::Error> for RecoveryError {
    fn from(e: io::Error) -> Self {
        RecoveryError::Io(e)
    }
}

/// FNV-1a 64-bit — the checkpoint integrity hash. Not cryptographic; it
/// guards against torn/bit-rotted files, not adversaries.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Wrap `payload` in the sealed checkpoint envelope: canonical encoding,
/// version, and an FNV-1a checksum over the canonical payload bytes.
pub fn seal(payload: JsonValue) -> String {
    let mut body = String::new();
    payload.write(&mut body);
    let sum = fnv1a64(body.as_bytes());
    let mut doc = BTreeMap::new();
    doc.insert("checksum".to_string(), JsonValue::String(format!("{sum:016x}")));
    doc.insert("version".to_string(), JsonValue::Number(CHECKPOINT_VERSION));
    doc.insert("payload".to_string(), payload);
    JsonValue::Object(doc).to_string()
}

/// Parse and verify a sealed checkpoint, returning its payload. The
/// checksum is recomputed over the payload's canonical re-encoding, so
/// verification is independent of the whitespace of the stored document.
pub fn unseal(text: &str) -> Result<JsonValue, RecoveryError> {
    let doc = JsonValue::parse(text)?;
    let version = doc
        .get("version")
        .and_then(|v| v.as_f64())
        .ok_or(RecoveryError::Corrupt("missing version"))?;
    if version != CHECKPOINT_VERSION {
        return Err(RecoveryError::Corrupt("unsupported checkpoint version"));
    }
    let claimed = doc
        .get("checksum")
        .and_then(|v| v.as_str())
        .ok_or(RecoveryError::Corrupt("missing checksum"))?;
    let payload = doc
        .get("payload")
        .ok_or(RecoveryError::Corrupt("missing payload"))?;
    let mut body = String::new();
    payload.write(&mut body);
    if format!("{:016x}", fnv1a64(body.as_bytes())) != claimed {
        return Err(RecoveryError::Corrupt("checksum mismatch"));
    }
    Ok(payload.clone())
}

/// Atomically publish `text` at `path`: write a `<path>.tmp` sibling, then
/// rename over the target. A crash mid-write leaves at worst a stale tmp
/// file; the live checkpoint name is always complete or absent.
pub fn write_atomic(path: &Path, text: &str) -> io::Result<()> {
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(".tmp");
    let tmp = PathBuf::from(tmp_name);
    fs::write(&tmp, text)?;
    fs::rename(&tmp, path)
}

/// Encode an `f64` losslessly: finite values as numbers (shortest
/// round-trip `Display`), the non-finite values — which JSON cannot carry
/// as numbers — as the strings `"inf"` / `"-inf"` / `"nan"`.
pub fn f64_to_json(x: f64) -> JsonValue {
    if x.is_finite() {
        JsonValue::Number(x)
    } else if x.is_nan() {
        JsonValue::String("nan".to_string())
    } else if x > 0.0 {
        JsonValue::String("inf".to_string())
    } else {
        JsonValue::String("-inf".to_string())
    }
}

/// Decode an [`f64_to_json`]-encoded value.
pub fn f64_from_json(v: &JsonValue) -> Option<f64> {
    match v {
        JsonValue::Number(n) => Some(*n),
        JsonValue::String(s) => match s.as_str() {
            "inf" => Some(f64::INFINITY),
            "-inf" => Some(f64::NEG_INFINITY),
            "nan" => Some(f64::NAN),
            _ => None,
        },
        _ => None,
    }
}

/// Encode a `u64` losslessly as a hex string (an `f64` number mantissa
/// only covers 53 bits — RNG states and sequence stamps need all 64).
pub fn u64_to_json(x: u64) -> JsonValue {
    JsonValue::String(format!("{x:x}"))
}

/// Decode a [`u64_to_json`]-encoded value.
pub fn u64_from_json(v: &JsonValue) -> Option<u64> {
    v.as_str().and_then(|s| u64::from_str_radix(s, 16).ok())
}

fn phase_str(p: CoflowPhase) -> &'static str {
    match p {
        CoflowPhase::Piloting => "piloting",
        CoflowPhase::Running => "running",
        CoflowPhase::Done => "done",
    }
}

fn phase_from_str(s: &str) -> Option<CoflowPhase> {
    match s {
        "piloting" => Some(CoflowPhase::Piloting),
        "running" => Some(CoflowPhase::Running),
        "done" => Some(CoflowPhase::Done),
        _ => None,
    }
}

/// Serialize one coordinator's durable state: the policy kind, its
/// [`Scheduler::export_state`] facts, and the per-coflow world overlay the
/// restore path must re-apply (the attach pass rewrites `est_size` and
/// `phase`; `remaining` records the byte position the checkpoint was taken
/// at, for diagnostics and staleness bounds). The view is `world.active` —
/// callers with a partitioned view (cluster shards) swap it in first.
pub fn checkpoint_scheduler(
    kind: SchedulerKind,
    sched: &dyn Scheduler,
    world: &World,
) -> JsonValue {
    checkpoint_with_state(kind, sched.export_state(), world)
}

/// [`checkpoint_scheduler`] for callers that hold the exported scheduler
/// state directly rather than a `&dyn Scheduler` (the live service drives
/// `PhilaeCore` outside the trait so the PJRT scorer can batch features).
pub fn checkpoint_with_state(
    kind: SchedulerKind,
    sched_state: JsonValue,
    world: &World,
) -> JsonValue {
    let mut coflows = Vec::with_capacity(world.active.len());
    for &cid in &world.active {
        let c = &world.coflows[cid];
        let remaining: f64 = c
            .active_list
            .iter()
            .map(|&f| world.flows[f].remaining())
            .sum();
        let mut e = BTreeMap::new();
        e.insert("id".to_string(), JsonValue::Number(cid as f64));
        e.insert(
            "est".to_string(),
            match c.est_size {
                Some(x) => f64_to_json(x),
                None => JsonValue::Null,
            },
        );
        e.insert("phase".to_string(), JsonValue::String(phase_str(c.phase).to_string()));
        e.insert("queue".to_string(), JsonValue::Number(c.queue as f64));
        e.insert("remaining".to_string(), f64_to_json(remaining));
        coflows.push(JsonValue::Object(e));
    }
    let mut doc = BTreeMap::new();
    doc.insert("kind".to_string(), JsonValue::String(kind.as_str().to_string()));
    doc.insert("sched".to_string(), sched_state);
    doc.insert("coflows".to_string(), JsonValue::Array(coflows));
    JsonValue::Object(doc)
}

/// Rebuild a coordinator from a [`checkpoint_scheduler`] payload against
/// the surviving `world`: build a fresh scheduler, run the
/// [`Scheduler::on_coflow_attach`] fact-rebuild for every active coflow,
/// overlay the checkpoint's durable facts via
/// [`Scheduler::import_state`], and (for `exact` restores) re-apply the
/// per-coflow `est_size`/`phase`/`queue` the attach pass rewrote. See the
/// module docs for why this order yields bit-identity on fresh checkpoints
/// and safe self-healing on stale ones.
pub fn restore_scheduler(
    payload: &JsonValue,
    trace: &Trace,
    cfg: &SchedulerConfig,
    world: &mut World,
    exact: bool,
) -> Result<Box<dyn Scheduler>, RecoveryError> {
    let kind: SchedulerKind = payload
        .get("kind")
        .and_then(|v| v.as_str())
        .ok_or(RecoveryError::Corrupt("missing scheduler kind"))?
        .parse()
        .map_err(|_| RecoveryError::Corrupt("unknown scheduler kind"))?;
    let mut sched = kind.build(trace, cfg);
    for i in 0..world.active.len() {
        let cid = world.active[i];
        if world.coflows[cid].done() {
            continue; // physically complete; its pending report replays below
        }
        sched.on_coflow_attach(cid, world);
    }
    let null = JsonValue::Null;
    let state = payload.get("sched").unwrap_or(&null);
    sched.import_state(state, world, exact);
    if exact {
        if let Some(entries) = payload.get("coflows").and_then(|v| v.as_array()) {
            for e in entries {
                let Some(cid) = e.get("id").and_then(|v| v.as_usize()) else {
                    continue;
                };
                if cid >= world.coflows.len() {
                    continue;
                }
                world.coflows[cid].est_size = match e.get("est") {
                    None | Some(JsonValue::Null) => None,
                    Some(v) => f64_from_json(v),
                };
                if let Some(p) = e.get("phase").and_then(|v| v.as_str()).and_then(phase_from_str) {
                    world.coflows[cid].phase = p;
                }
                if let Some(q) = e.get("queue").and_then(|v| v.as_usize()) {
                    world.coflows[cid].queue = q;
                }
            }
        }
    }
    Ok(sched)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_payload() -> JsonValue {
        let mut m = BTreeMap::new();
        m.insert("kind".to_string(), JsonValue::String("philae".to_string()));
        m.insert("x".to_string(), JsonValue::Number(0.1 + 0.2));
        m.insert(
            "arr".to_string(),
            JsonValue::Array(vec![JsonValue::Bool(true), JsonValue::Null]),
        );
        JsonValue::Object(m)
    }

    #[test]
    fn seal_unseal_round_trips() {
        let payload = sample_payload();
        let sealed = seal(payload.clone());
        let back = unseal(&sealed).expect("seal output must unseal");
        assert_eq!(back, payload);
        // sealing is deterministic (canonical writer underneath)
        assert_eq!(sealed, seal(payload));
    }

    #[test]
    fn unseal_rejects_tampering() {
        let sealed = seal(sample_payload());
        // flip a payload byte without touching the checksum header
        let tampered = sealed.replace("\"philae\"", "\"phileo\"");
        assert_ne!(tampered, sealed);
        match unseal(&tampered) {
            Err(RecoveryError::Corrupt(msg)) => assert_eq!(msg, "checksum mismatch"),
            other => panic!("tampered checkpoint accepted: {other:?}"),
        }
        assert!(unseal("not json").is_err());
        assert!(matches!(
            unseal("{\"payload\": {}}"),
            Err(RecoveryError::Corrupt("missing version"))
        ));
    }

    #[test]
    fn unseal_is_whitespace_independent() {
        let sealed = seal(sample_payload());
        let spaced = sealed.replace(",", ", ").replace(":", ": ");
        assert_eq!(unseal(&spaced).unwrap(), sample_payload());
    }

    #[test]
    fn atomic_write_publishes_whole_files_only() {
        let dir = std::env::temp_dir().join(format!("philae_ckpt_test_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.json");
        let sealed = seal(sample_payload());
        write_atomic(&path, &sealed).unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), sealed);
        // second write replaces atomically and leaves no tmp sibling
        let sealed2 = seal(JsonValue::Array(vec![JsonValue::Number(1.0)]));
        write_atomic(&path, &sealed2).unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), sealed2);
        assert!(!dir.join("ckpt.json.tmp").exists());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn f64_codec_covers_non_finite() {
        for x in [0.0, -0.0, 1.5, f64::MIN_POSITIVE, 1e300, -2.5e-9] {
            let v = f64_to_json(x);
            assert_eq!(f64_from_json(&v).unwrap().to_bits(), x.to_bits());
        }
        assert_eq!(f64_from_json(&f64_to_json(f64::INFINITY)), Some(f64::INFINITY));
        assert_eq!(
            f64_from_json(&f64_to_json(f64::NEG_INFINITY)),
            Some(f64::NEG_INFINITY)
        );
        assert!(f64_from_json(&f64_to_json(f64::NAN)).unwrap().is_nan());
        assert_eq!(f64_from_json(&JsonValue::Null), None);
    }

    #[test]
    fn u64_codec_is_lossless_at_full_width() {
        for x in [0u64, 1, u64::MAX, 0xdead_beef_cafe_f00d] {
            assert_eq!(u64_from_json(&u64_to_json(x)), Some(x));
        }
    }

    #[test]
    fn fnv_known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
