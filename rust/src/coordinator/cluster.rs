//! Multi-coordinator sharding with cross-shard capacity reconciliation.
//!
//! ## Why (paper §3, Table 4)
//!
//! Philae's scalability argument is that sampling slashes the per-event
//! work a *single* coordinator performs, which is what lets it track
//! 900-node fabrics where Aalo's periodic pipeline saturates. But the
//! coordinator is still one instance: §3 explicitly flags the central
//! coordinator as the residual bottleneck once update ingestion is cheap —
//! rate calculation still walks *every* active coflow on *every* event.
//! With the allocator port-sharded (PR 2) and admission batched, the next
//! scaling step is to partition the *coflows themselves* across K
//! independent coordinator instances, so per-event work is proportional to
//! a shard's working set, not the fabric's.
//!
//! ## Design
//!
//! [`CoordinatorCluster`] runs K **coordinator shards**. Each shard owns:
//!
//! * its own [`Scheduler`] instance (any [`SchedulerKind`]), fed only the
//!   events of the coflows it owns — its incremental `order_into` caches
//!   therefore scale with the shard's coflow count;
//! * a **capacity lease**: a per-port slice of the fabric's uplink and
//!   downlink capacity. A shard allocates rates with the ordinary
//!   [`rate::allocate_into`] pipeline (including the port-sharded parallel
//!   path) against its lease, so the K allocations are independent and the
//!   union of the grants is feasible by construction: per port,
//!   Σ_shard lease == fabric capacity.
//!
//! A hash router (`coflow id → shard`, SplitMix64 finalizer) assigns
//! arrivals; flow-completion reports follow their coflow's current owner.
//! Shards are recomputed lazily: an event only dirties its owner shard, so
//! a burst confined to one shard re-runs one order repair + one allocation
//! over that shard's lease — the other shards' last grants remain valid
//! (their plans and leases are untouched) and are re-emitted as-is.
//!
//! ## Reconciliation (periodic, demand-weighted water-filling)
//!
//! Static leases waste capacity: a port heavily used by one shard's
//! coflows and idle in another's would be half-stranded. Every
//! [`ClusterConfig::reconcile_every`] scheduling rounds the cluster runs a
//! reconciliation round:
//!
//! 1. **Observe demand** — per shard and per port direction, the remaining
//!    bytes of the shard's unfinished flows (the same information the
//!    coordinator's completion reports already imply; nothing clairvoyant).
//! 2. **Migrate on saturation** — a shard whose total demand exceeds
//!    [`ClusterConfig::imbalance_threshold`] × the mean donates coflows
//!    (smallest remaining first, ties to the lowest id) to the least-loaded
//!    shard, bounded by [`ClusterConfig::max_migrations_per_round`].
//!    Migration is a [`Scheduler::on_coflow_detach`] on the source and a
//!    [`Scheduler::on_coflow_attach`] on the target; schedulers with
//!    learning state (Philae's sampling machine, Aalo's seen bytes)
//!    override the attach hook to rebuild it from completed-flow facts.
//! 3. **Rebalance leases** — per port and direction, capacities are
//!    re-leased by *demand-weighted water-filling* ([`water_fill_port`]):
//!    max-min over shard demands, spare capacity split equally, a small
//!    equal-split floor ([`ClusterConfig::lease_floor_frac`]) so a shard
//!    that receives an arrival between reconciliations is never starved,
//!    and a final fix-up slot so the per-port lease sum is *exactly* the
//!    fabric capacity (the conservation property `cluster_equivalence.rs`
//!    asserts). All tie-breaks are deterministic (shard index).
//!
//! ## K = 1 is the single coordinator, bit for bit
//!
//! With one shard the cluster is a transparent pass-through: no routing, no
//! leases, no reconciliation — the exact `order_into` + `allocate_into`
//! sequence the engine runs without a cluster, against the fabric itself.
//! `tests/cct_equivalence.rs` pins K=1 CCTs/plans bit-identical to the
//! single-coordinator path, which makes the *entire* existing equivalence
//! suite (incremental vs oracle, batched vs per-event, sharded vs serial
//! allocation) the oracle for the cluster plumbing. K ≥ 2 intentionally
//! trades schedule quality for coordinator scalability (a shard only
//! orders its own coflows and spends only its lease) and is bounded by the
//! CCT tests rather than pinned.
//!
//! Shards execute sequentially in-process — the simulation models the
//! *decomposition* (per-shard working sets, lease feasibility, migration
//! dynamics); `benches/bench_cluster.rs` tracks the resulting events/sec
//! and per-round allocation cost vs K at 900 and 5000 ports in
//! `BENCH_cluster.json`.
//!
//! ## Crash-failover chaos
//!
//! A coordinator shard is soft state: everything it knows is either a
//! durable scheduling fact (checkpointed by `coordinator/recovery.rs`) or
//! rebuildable from the completed-flow record — the same split migration
//! already exploits. [`CoordinatorCluster::checkpoint`] seals the K
//! per-shard scheduler payloads;
//! [`CoordinatorCluster::kill_and_restore_shard`] replaces one shard's
//! scheduler with a restore (`exact = false`, the stale-merge path) while
//! keeping the shard's *current* lease, ownership list, and in-flight
//! batch routing — so lease conservation and unique ownership hold across
//! the crash by construction, and only the scheduler's learned state pays
//! the failover cost. [`set_chaos`](CoordinatorCluster::set_chaos) arms a
//! periodic checkpoint + randomized shard-kill driver inside
//! [`compute`](CoordinatorCluster::compute) so the existing engine loop
//! (`Simulation::run_with_cluster`) doubles as the chaos harness;
//! `tests/chaos_recovery.rs` asserts invariants and bounded CCT
//! degradation under it. Full-cluster restores are intentionally *not*
//! claimed bit-identical (a clean shard's last grants may outlive the
//! checkpoint); exact-restore bit-identity is pinned on the
//! single-coordinator path for every [`SchedulerKind`] instead.

use super::recovery::{
    checkpoint_scheduler, restore_scheduler, seal, u64_to_json, unseal, RecoveryError,
};
use super::{
    rate, AdmissionStats, EventBatch, Plan, Reaction, Scheduler, SchedulerConfig, SchedulerKind,
    World,
};
use crate::fabric::Fabric;
use crate::obs::{self, EventKind};
use crate::trace::Trace;
use crate::util::{JsonValue, Rng};
use crate::{CoflowId, FlowId, Time};

/// Owner sentinel: not (or no longer) assigned to any shard.
const NONE: u32 = u32::MAX;

/// Cluster tunables. `coordinators == 1` disables everything below it.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of coordinator shards K (≥ 1).
    pub coordinators: usize,
    /// Reconciliation period in scheduling rounds (0 = never reconcile;
    /// leases stay at the initial equal split).
    pub reconcile_every: u64,
    /// Max coflow migrations per reconciliation round.
    pub max_migrations_per_round: usize,
    /// A shard donates coflows while its demand exceeds this multiple of
    /// the mean shard demand.
    pub imbalance_threshold: f64,
    /// Fraction of every port's capacity reserved as an equal-split floor
    /// across shards (starvation guard between reconciliations).
    pub lease_floor_frac: f64,
    /// Assert cluster invariants (lease conservation, unique ownership)
    /// after every scheduling round — property-test hook, off on hot paths.
    pub validate: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            coordinators: 1,
            reconcile_every: 8,
            max_migrations_per_round: 4,
            imbalance_threshold: 1.5,
            lease_floor_frac: 0.05,
            validate: false,
        }
    }
}

/// One coordinator shard: scheduler + owned coflows + capacity lease +
/// its own reusable order/allocation workspace.
struct Shard {
    sched: Box<dyn Scheduler>,
    /// Owned coflows in admission order (swapped into `world.active` around
    /// every scheduler call, so schedulers see exactly their partition).
    active: Vec<CoflowId>,
    /// Leased per-port capacity slice (Σ over shards == fabric, per port).
    lease: Fabric,
    plan: Plan,
    scratch: rate::AllocScratch,
    /// Reused per-shard event batch for the batched-admission router.
    batch: EventBatch,
    /// Observed remaining-bytes demand per port (rebuilt at reconciliation).
    demand_up: Vec<f64>,
    demand_down: Vec<f64>,
}

/// Periodic checkpoint + randomized shard-kill driver (module docs
/// §Crash-failover chaos). Boxed off the hot path: `None` = chaos off.
struct ChaosState {
    /// Owned copies of the build inputs, so a kill can rebuild a shard's
    /// scheduler mid-run without threading `&Trace` through the engine.
    trace: Trace,
    sched_cfg: SchedulerConfig,
    rng: Rng,
    /// Seal a full-cluster checkpoint every this many scheduling rounds
    /// (0 = never; kills then restore by pure attach rebuild).
    checkpoint_every: u64,
    /// Kill-and-restore a random shard every this many rounds (0 = never).
    kill_every: u64,
    /// Most recent sealed checkpoint (the supervisor's in-memory copy).
    last_ckpt: Option<String>,
    kills: u64,
    checkpoints: u64,
}

/// K coordinator shards over one fabric — see the module docs.
pub struct CoordinatorCluster {
    cfg: ClusterConfig,
    kind: SchedulerKind,
    shards: Vec<Shard>,
    /// Coflow → owning shard (`NONE` = unassigned / completed).
    owner: Vec<u32>,
    /// Shards whose inputs changed since their last recompute.
    dirty: Vec<bool>,
    /// Scheduling rounds completed (drives the reconciliation period).
    rounds: u64,
    /// Merged grants of the last `compute` (K ≥ 2), in shard order.
    merged: Vec<(FlowId, f64)>,
    /// Epoch-stamped membership for `was_granted` (K ≥ 2).
    grant_epoch: Vec<u64>,
    epoch: u64,
    leases_ready: bool,
    /// Reused water-fill workspaces.
    wf_demand: Vec<f64>,
    wf_out: Vec<f64>,
    wf_scratch: Vec<(f64, usize)>,
    /// Per-shard total remaining-bytes demand (reconciliation scratch).
    demand_total: Vec<f64>,
    migrations: u64,
    reconciliations: u64,
    chaos: Option<Box<ChaosState>>,
    /// Buffer coordination-plane lifecycle events for the engine's flight
    /// recorder (see [`Self::set_obs`]); off by default, zero cost when off.
    obs_on: bool,
    /// Events since the last [`Self::drain_obs`] (time/sequence stamped by
    /// the consumer).
    obs_pending: Vec<obs::PendingEvent>,
}

/// SplitMix64 finalizer — the coflow→shard router hash (shared with the
/// live service's per-shard input router).
#[inline]
pub(crate) fn route_hash(cid: CoflowId) -> u64 {
    let mut z = (cid as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Demand-weighted water-filling of one port direction's capacity across K
/// shard demands (module docs §Reconciliation). Writes shard `s`'s lease
/// into `out[s]`; `scratch` is a reused K-sized workspace. Deterministic
/// (ties broken by shard index); the last slot absorbs float dust so
/// `Σ out == cap` exactly up to one rounding of the final subtraction.
pub fn water_fill_port(
    cap: f64,
    demand: &[f64],
    floor_frac: f64,
    out: &mut [f64],
    scratch: &mut Vec<(f64, usize)>,
) {
    let k = demand.len();
    debug_assert_eq!(out.len(), k);
    debug_assert!(k >= 1);
    if k == 1 {
        out[0] = cap;
        return;
    }
    let frac = floor_frac.clamp(0.0, 1.0);
    let floor = cap * frac / k as f64;
    let pool = cap - cap * frac;
    let total: f64 = demand.iter().sum();
    if total <= pool {
        // undersubscribed: everyone gets their demand, spare split equally
        let spare = (pool - total) / k as f64;
        for s in 0..k {
            out[s] = floor + demand[s] + spare;
        }
    } else {
        // oversubscribed: max-min water level over demands
        scratch.clear();
        scratch.extend(demand.iter().copied().zip(0..k));
        scratch.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut remaining = pool;
        let mut left = k;
        for &(d, s) in scratch.iter() {
            let level = remaining / left as f64;
            let give = d.min(level).max(0.0);
            out[s] = floor + give;
            remaining -= give;
            left -= 1;
        }
    }
    // exact conservation: the last shard absorbs rounding dust
    let acc: f64 = out[..k - 1].iter().sum();
    out[k - 1] = (cap - acc).max(0.0);
}

impl CoordinatorCluster {
    /// Build a K-shard cluster of `kind` schedulers. K comes from
    /// `cfg.coordinators` (clamped to ≥ 1).
    pub fn new(
        kind: SchedulerKind,
        trace: &Trace,
        sched_cfg: &SchedulerConfig,
        cfg: ClusterConfig,
    ) -> Self {
        let k = cfg.coordinators.max(1);
        let shards = (0..k)
            .map(|_| Shard {
                sched: kind.build(trace, sched_cfg),
                active: Vec::new(),
                lease: Fabric { num_ports: 0, up_capacity: Vec::new(), down_capacity: Vec::new() },
                plan: Plan::default(),
                scratch: rate::AllocScratch::new(),
                batch: EventBatch::default(),
                demand_up: Vec::new(),
                demand_down: Vec::new(),
            })
            .collect();
        CoordinatorCluster {
            cfg,
            kind,
            shards,
            owner: Vec::new(),
            dirty: vec![true; k],
            rounds: 0,
            merged: Vec::new(),
            grant_epoch: Vec::new(),
            epoch: 0,
            leases_ready: false,
            wf_demand: vec![0.0; k],
            wf_out: vec![0.0; k],
            wf_scratch: Vec::with_capacity(k),
            demand_total: vec![0.0; k],
            migrations: 0,
            reconciliations: 0,
            chaos: None,
            obs_on: false,
            obs_pending: Vec::new(),
        }
    }

    /// Arm (or disarm) coordination-plane event buffering for a flight
    /// recorder. Purely observational — scheduling behavior is identical
    /// either way.
    pub fn set_obs(&mut self, on: bool) {
        self.obs_on = on;
        if !on {
            self.obs_pending = Vec::new();
        }
    }

    /// Move buffered `(shard, kind, coflow, a, b)` events into `out`.
    pub fn drain_obs(&mut self, out: &mut Vec<obs::PendingEvent>) {
        out.append(&mut self.obs_pending);
    }

    /// Convenience constructor: `k` shards, default cluster tunables.
    pub fn with_coordinators(
        k: usize,
        kind: SchedulerKind,
        trace: &Trace,
        sched_cfg: &SchedulerConfig,
    ) -> Self {
        let cfg = ClusterConfig { coordinators: k.max(1), ..ClusterConfig::default() };
        Self::new(kind, trace, sched_cfg, cfg)
    }

    /// Number of coordinator shards K.
    pub fn coordinators(&self) -> usize {
        self.shards.len()
    }

    /// Set the allocator worker-shard count on every shard's scratch (the
    /// PR 2 port-sharded pipeline; orthogonal to coordinator sharding).
    pub fn set_alloc_shards(&mut self, shards: usize) {
        for sh in &mut self.shards {
            sh.scratch.set_shards(shards);
        }
    }

    /// Scheduler name (shard 0 — all shards run the same policy).
    pub fn name(&self) -> String {
        self.shards[0].sched.name()
    }

    /// Tick interval of the underlying policy.
    pub fn tick_interval(&self) -> Option<Time> {
        self.shards[0].sched.tick_interval()
    }

    /// Coflow migrations performed so far.
    pub fn migrations(&self) -> u64 {
        self.migrations
    }

    /// Reconciliation rounds performed so far.
    pub fn reconciliations(&self) -> u64 {
        self.reconciliations
    }

    /// Arm the chaos driver: seal a full-cluster checkpoint every
    /// `checkpoint_every` scheduling rounds and kill-and-restore a
    /// uniformly random shard every `kill_every` rounds (0 disables either
    /// leg). The driver runs inside [`compute`](Self::compute), so the
    /// ordinary engine loop (`Simulation::run_with_cluster`) becomes the
    /// chaos harness. K = 1 pass-through mode never reaches the driver.
    pub fn set_chaos(
        &mut self,
        trace: &Trace,
        sched_cfg: &SchedulerConfig,
        checkpoint_every: u64,
        kill_every: u64,
        seed: u64,
    ) {
        self.chaos = Some(Box::new(ChaosState {
            trace: trace.clone(),
            sched_cfg: sched_cfg.clone(),
            rng: Rng::seed_from_u64(seed),
            checkpoint_every,
            kill_every,
            last_ckpt: None,
            kills: 0,
            checkpoints: 0,
        }));
    }

    /// Shard kill-and-restores performed by the chaos driver.
    pub fn chaos_kills(&self) -> u64 {
        self.chaos.as_ref().map_or(0, |c| c.kills)
    }

    /// Checkpoints sealed by the chaos driver.
    pub fn chaos_checkpoints(&self) -> u64 {
        self.chaos.as_ref().map_or(0, |c| c.checkpoints)
    }

    /// Seal a full-cluster checkpoint: the K per-shard scheduler payloads
    /// (each via [`checkpoint_scheduler`], against the shard's own active
    /// view), the owner map, and the round counter. The sealed string is
    /// what a supervisor would persist;
    /// [`Self::kill_and_restore_shard`] consumes it.
    pub fn checkpoint(&mut self, world: &mut World) -> String {
        let mut shard_payloads = Vec::with_capacity(self.shards.len());
        for sh in &mut self.shards {
            std::mem::swap(&mut world.active, &mut sh.active);
            shard_payloads.push(checkpoint_scheduler(self.kind, sh.sched.as_ref(), world));
            std::mem::swap(&mut world.active, &mut sh.active);
        }
        let mut owner = Vec::with_capacity(self.owner.len());
        for &o in &self.owner {
            owner.push(if o == NONE { JsonValue::Null } else { JsonValue::Number(o as f64) });
        }
        let mut payload = std::collections::BTreeMap::new();
        payload.insert("shards".to_string(), JsonValue::Array(shard_payloads));
        payload.insert("owner".to_string(), JsonValue::Array(owner));
        payload.insert("rounds".to_string(), u64_to_json(self.rounds));
        seal(JsonValue::Object(payload))
    }

    /// Kill shard `s`'s scheduler and restore it — from its payload in the
    /// sealed cluster checkpoint `ckpt` when one exists (the stale-merge
    /// `exact = false` restore path: attach rebuild is primary, dcoflow
    /// re-asserts checkpointed admission certificates), or by pure attach
    /// rebuild when `ckpt` is `None` (a crash before the first
    /// checkpoint). The shard's *current* lease, ownership list, and
    /// demand observations are deliberately kept: per-port lease sums and
    /// unique ownership — the [`check_invariants`](Self::check_invariants)
    /// properties — therefore hold across the crash by construction.
    pub fn kill_and_restore_shard(
        &mut self,
        s: usize,
        trace: &Trace,
        sched_cfg: &SchedulerConfig,
        ckpt: Option<&str>,
        world: &mut World,
    ) -> Result<(), RecoveryError> {
        let shard_payload = match ckpt {
            Some(text) => {
                let payload = unseal(text)?;
                let shards = payload
                    .get("shards")
                    .and_then(|v| v.as_array())
                    .ok_or(RecoveryError::Corrupt("cluster checkpoint lacks shards"))?;
                shards
                    .get(s)
                    .cloned()
                    .ok_or(RecoveryError::Corrupt("cluster checkpoint shard count mismatch"))?
            }
            None => {
                // no checkpoint yet: a minimal payload drives the same
                // restore path with nothing but the attach rebuild
                let mut p = std::collections::BTreeMap::new();
                p.insert("kind".to_string(), JsonValue::String(self.kind.as_str().to_string()));
                p.insert("sched".to_string(), JsonValue::Null);
                p.insert("coflows".to_string(), JsonValue::Array(Vec::new()));
                JsonValue::Object(p)
            }
        };
        let sh = &mut self.shards[s];
        std::mem::swap(&mut world.active, &mut sh.active);
        let restored = restore_scheduler(&shard_payload, trace, sched_cfg, world, false);
        std::mem::swap(&mut world.active, &mut sh.active);
        sh.sched = restored?;
        self.dirty[s] = true;
        if self.obs_on {
            self.obs_pending.push((
                s as u32,
                EventKind::Restore,
                obs::NO_COFLOW,
                u64::from(ckpt.is_some()),
                0,
            ));
        }
        Ok(())
    }

    /// One chaos step (called per scheduling round from `compute`):
    /// checkpoint if due, then kill-and-restore a random shard if due. A
    /// kill restores from the latest checkpoint — necessarily stale by up
    /// to `checkpoint_every` rounds, which is exactly the staleness the
    /// `exact = false` restore path is designed for.
    fn run_chaos(&mut self, world: &mut World) {
        let Some(mut chaos) = self.chaos.take() else { return };
        if chaos.checkpoint_every > 0 && self.rounds % chaos.checkpoint_every == 0 {
            chaos.last_ckpt = Some(self.checkpoint(world));
            chaos.checkpoints += 1;
            if self.obs_on {
                self.obs_pending.push((
                    0,
                    EventKind::Checkpoint,
                    obs::NO_COFLOW,
                    chaos.checkpoints,
                    0,
                ));
            }
        }
        if chaos.kill_every > 0 && self.rounds % chaos.kill_every == 0 {
            let s = (chaos.rng.next_u64() % self.shards.len() as u64) as usize;
            self.kill_and_restore_shard(
                s,
                &chaos.trace,
                &chaos.sched_cfg,
                chaos.last_ckpt.as_deref(),
                world,
            )
            .expect("restore from a self-sealed checkpoint");
            chaos.kills += 1;
        }
        self.chaos = Some(chaos);
    }

    /// Aggregate admission-control counters across the K shards (`None`
    /// when the policy performs no deadline admission). Counters are
    /// per-decision, so a migrated coflow re-admitted by its new shard
    /// counts on both.
    pub fn admission_stats(&self) -> Option<AdmissionStats> {
        let mut acc = AdmissionStats::default();
        let mut any = false;
        for sh in &self.shards {
            if let Some(a) = sh.sched.admission_stats() {
                acc.merge(&a);
                any = true;
            }
        }
        any.then_some(acc)
    }

    /// Current owner shard of `cid` (K ≥ 2 only; `None` when unassigned,
    /// completed, or running in pass-through mode).
    pub fn owner_of(&self, cid: CoflowId) -> Option<usize> {
        match self.owner.get(cid).copied() {
            Some(s) if s != NONE => Some(s as usize),
            _ => None,
        }
    }

    /// Coflows currently owned by shard `s` (admission order).
    pub fn owned(&self, s: usize) -> &[CoflowId] {
        &self.shards[s].active
    }

    /// Shard `s`'s current capacity lease (valid once leases initialized).
    pub fn lease(&self, s: usize) -> &Fabric {
        &self.shards[s].lease
    }

    /// Whether the per-shard leases have been initialized from a fabric.
    pub fn leases_ready(&self) -> bool {
        self.leases_ready
    }

    fn ensure(&mut self, world: &World) {
        let nc = world.coflows.len();
        if self.owner.len() < nc {
            self.owner.resize(nc, NONE);
        }
    }

    /// Initialize (or re-initialize after a fabric-size change) the leases
    /// to an exact equal split of every port's capacity.
    fn ensure_leases(&mut self, fabric: &Fabric) {
        let k = self.shards.len();
        let np = fabric.num_ports;
        if self.leases_ready && self.shards[0].lease.num_ports == np {
            return;
        }
        for sh in &mut self.shards {
            sh.lease.num_ports = np;
            sh.lease.up_capacity.clear();
            sh.lease.up_capacity.resize(np, 0.0);
            sh.lease.down_capacity.clear();
            sh.lease.down_capacity.resize(np, 0.0);
        }
        // equal split == water-fill with zero demand everywhere
        self.wf_demand[..k].fill(0.0);
        for p in 0..np {
            water_fill_port(
                fabric.up_capacity[p],
                &self.wf_demand[..k],
                self.cfg.lease_floor_frac,
                &mut self.wf_out[..k],
                &mut self.wf_scratch,
            );
            for s in 0..k {
                self.shards[s].lease.up_capacity[p] = self.wf_out[s];
            }
            water_fill_port(
                fabric.down_capacity[p],
                &self.wf_demand[..k],
                self.cfg.lease_floor_frac,
                &mut self.wf_out[..k],
                &mut self.wf_scratch,
            );
            for s in 0..k {
                self.shards[s].lease.down_capacity[p] = self.wf_out[s];
            }
        }
        self.leases_ready = true;
    }

    /// Route a *new* coflow to its home shard and record ownership.
    fn assign(&mut self, cid: CoflowId) -> usize {
        let k = self.shards.len();
        let s = (route_hash(cid) % k as u64) as usize;
        self.owner[cid] = s as u32;
        self.shards[s].active.push(cid);
        self.dirty[s] = true;
        s
    }

    /// Owner shard of `cid`, with a defensive hash fallback (events for a
    /// coflow always follow an assignment in well-formed histories).
    fn owner_shard(&self, cid: CoflowId) -> usize {
        match self.owner.get(cid).copied() {
            Some(s) if s != NONE => s as usize,
            _ => {
                debug_assert!(false, "event for unassigned coflow {cid}");
                (route_hash(cid) % self.shards.len() as u64) as usize
            }
        }
    }

    // ---- event hooks (the engine's scheduler vocabulary) ----

    /// A coflow arrived (already admitted to `world.active`).
    pub fn on_arrival(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        if self.shards.len() == 1 {
            return self.shards[0].sched.on_arrival(cid, world);
        }
        self.ensure(world);
        let s = self.assign(cid);
        let sh = &mut self.shards[s];
        std::mem::swap(&mut world.active, &mut sh.active);
        let r = sh.sched.on_arrival(cid, world);
        std::mem::swap(&mut world.active, &mut sh.active);
        r
    }

    /// A flow-completion report arrived.
    pub fn on_flow_complete(&mut self, fid: FlowId, world: &mut World) -> Reaction {
        if self.shards.len() == 1 {
            return self.shards[0].sched.on_flow_complete(fid, world);
        }
        self.ensure(world);
        let s = self.owner_shard(world.flows[fid].coflow);
        self.dirty[s] = true;
        let sh = &mut self.shards[s];
        std::mem::swap(&mut world.active, &mut sh.active);
        let r = sh.sched.on_flow_complete(fid, world);
        std::mem::swap(&mut world.active, &mut sh.active);
        r
    }

    /// A whole coflow finished (delivered with its last completion report).
    pub fn on_coflow_complete(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        if self.shards.len() == 1 {
            return self.shards[0].sched.on_coflow_complete(cid, world);
        }
        self.ensure(world);
        let s = self.owner_shard(cid);
        self.dirty[s] = true;
        // mirror the single path: the completed coflow has already left the
        // active view when the hook fires
        self.shards[s].active.retain(|&x| x != cid);
        self.owner[cid] = NONE;
        let sh = &mut self.shards[s];
        std::mem::swap(&mut world.active, &mut sh.active);
        let r = sh.sched.on_coflow_complete(cid, world);
        std::mem::swap(&mut world.active, &mut sh.active);
        r
    }

    /// Periodic δ tick — delivered to every shard (each periodic scheduler
    /// instance runs its own queue pipeline over its partition).
    pub fn on_tick(&mut self, world: &mut World) -> Reaction {
        if self.shards.len() == 1 {
            return self.shards[0].sched.on_tick(world);
        }
        let mut reaction = Reaction::None;
        for s in 0..self.shards.len() {
            self.dirty[s] = true;
            let sh = &mut self.shards[s];
            std::mem::swap(&mut world.active, &mut sh.active);
            reaction = reaction.merge(sh.sched.on_tick(world));
            std::mem::swap(&mut world.active, &mut sh.active);
        }
        reaction
    }

    /// Route one coalesced [`EventBatch`] to the owning shards and deliver
    /// each shard's sub-batch through its scheduler's `on_batch` (batched
    /// admission, one scheduler call per shard per instant).
    pub fn on_batch(&mut self, batch: &EventBatch, world: &mut World) -> Reaction {
        if self.shards.len() == 1 {
            return self.shards[0].sched.on_batch(batch, world);
        }
        self.ensure(world);
        let k = self.shards.len();
        for sh in &mut self.shards {
            sh.batch.clear();
        }
        for &cid in &batch.arrivals {
            let s = self.assign(cid);
            self.shards[s].batch.arrivals.push(cid);
        }
        for &(fid, coflow_done) in &batch.flow_reports {
            let s = self.owner_shard(world.flows[fid].coflow);
            self.dirty[s] = true;
            self.shards[s].batch.flow_reports.push((fid, coflow_done));
        }
        if batch.tick {
            for s in 0..k {
                self.shards[s].batch.tick = true;
                self.dirty[s] = true;
            }
        }
        let mut reaction = Reaction::None;
        for s in 0..k {
            if self.shards[s].batch.is_empty() {
                continue;
            }
            // completed coflows leave the active view (and ownership)
            // before delivery, mirroring the single path's world.active
            for i in 0..self.shards[s].batch.flow_reports.len() {
                let (fid, coflow_done) = self.shards[s].batch.flow_reports[i];
                if coflow_done {
                    let cid = world.flows[fid].coflow;
                    self.shards[s].active.retain(|&x| x != cid);
                    self.owner[cid] = NONE;
                }
            }
            let sh = &mut self.shards[s];
            let shard_batch = std::mem::take(&mut sh.batch);
            std::mem::swap(&mut world.active, &mut sh.active);
            reaction = reaction.merge(sh.sched.on_batch(&shard_batch, world));
            std::mem::swap(&mut world.active, &mut sh.active);
            sh.batch = shard_batch;
        }
        reaction
    }

    // ---- scheduling ----

    /// One scheduling round: reconcile if due, recompute every dirty
    /// shard's order + allocation against its lease, and merge the grants.
    /// `full` routes ordering through `order_full_into` (the oracle path).
    pub fn compute(&mut self, world: &mut World, full: bool) {
        if self.shards.len() == 1 {
            // transparent pass-through: bit-identical to the engine's
            // single-coordinator sequence
            let sh = &mut self.shards[0];
            if full {
                sh.sched.order_full_into(world, &mut sh.plan);
            } else {
                sh.sched.order_into(world, &mut sh.plan);
            }
            rate::allocate_into(
                &world.fabric,
                &world.flows,
                &world.coflows,
                &sh.plan,
                &mut sh.scratch,
            );
            return;
        }
        self.ensure(world);
        self.ensure_leases(&world.fabric);
        self.rounds += 1;
        if self.cfg.reconcile_every > 0 && self.rounds % self.cfg.reconcile_every == 0 {
            self.reconcile(world);
        }
        if self.chaos.is_some() {
            self.run_chaos(world);
        }
        let k = self.shards.len();
        for s in 0..k {
            if !self.dirty[s] {
                continue; // last grants still valid: lease and inputs unchanged
            }
            let sh = &mut self.shards[s];
            std::mem::swap(&mut world.active, &mut sh.active);
            if full {
                sh.sched.order_full_into(world, &mut sh.plan);
            } else {
                sh.sched.order_into(world, &mut sh.plan);
            }
            std::mem::swap(&mut world.active, &mut sh.active);
            rate::allocate_into(&sh.lease, &world.flows, &world.coflows, &sh.plan, &mut sh.scratch);
            self.dirty[s] = false;
        }
        // merge, skipping flows that physically completed after a clean
        // shard's last recompute (their delayed report hasn't landed yet)
        self.epoch += 1;
        if self.grant_epoch.len() < world.flows.len() {
            self.grant_epoch.resize(world.flows.len(), 0);
        }
        self.merged.clear();
        for s in 0..k {
            for &(f, r) in self.shards[s].scratch.grants() {
                if world.flows[f].done() {
                    continue;
                }
                self.grant_epoch[f] = self.epoch;
                self.merged.push((f, r));
            }
        }
        if self.cfg.validate {
            self.check_invariants(world);
        }
    }

    /// Merged `(flow, rate)` grants of the last [`compute`](Self::compute),
    /// shard-major, priority order within a shard.
    pub fn grants(&self) -> &[(FlowId, f64)] {
        if self.shards.len() == 1 {
            self.shards[0].scratch.grants()
        } else {
            &self.merged
        }
    }

    /// Whether `fid` holds a grant from the last round.
    pub fn was_granted(&self, fid: FlowId) -> bool {
        if self.shards.len() == 1 {
            self.shards[0].scratch.was_granted(fid)
        } else {
            self.grant_epoch.get(fid).copied() == Some(self.epoch)
        }
    }

    // ---- reconciliation ----

    /// Run one reconciliation round immediately (test hook; the scheduled
    /// path runs from [`compute`](Self::compute)).
    pub fn reconcile_now(&mut self, world: &mut World) {
        if self.shards.len() == 1 {
            return;
        }
        self.ensure(world);
        self.ensure_leases(&world.fabric);
        self.reconcile(world);
    }

    fn reconcile(&mut self, world: &mut World) {
        let k = self.shards.len();
        let np = world.fabric.num_ports;
        // 1) observe demand: remaining bytes per owned unfinished flow
        for s in 0..k {
            let sh = &mut self.shards[s];
            if sh.demand_up.len() < np {
                sh.demand_up.resize(np, 0.0);
                sh.demand_down.resize(np, 0.0);
            }
            sh.demand_up[..np].fill(0.0);
            sh.demand_down[..np].fill(0.0);
            let mut total = 0.0;
            for i in 0..sh.active.len() {
                let cid = sh.active[i];
                let c = &world.coflows[cid];
                if c.done() {
                    continue;
                }
                for &f in &c.active_list {
                    let fl = &world.flows[f];
                    let rem = fl.remaining();
                    sh.demand_up[fl.src] += rem;
                    sh.demand_down[fl.dst] += rem;
                    total += rem;
                }
            }
            self.demand_total[s] = total;
        }
        // 2) migrate while the heaviest shard saturates its share
        let mut moves = 0;
        while moves < self.cfg.max_migrations_per_round {
            let mut smax = 0;
            let mut smin = 0;
            for s in 1..k {
                if self.demand_total[s] > self.demand_total[smax] {
                    smax = s;
                }
                if self.demand_total[s] < self.demand_total[smin] {
                    smin = s;
                }
            }
            let mean = self.demand_total[..k].iter().sum::<f64>() / k as f64;
            if smax == smin
                || self.shards[smax].active.len() < 2
                || self.demand_total[smax] <= self.cfg.imbalance_threshold * mean
            {
                break;
            }
            // victim: the donor's smallest unfinished coflow (ties: lowest id)
            let mut victim: Option<(f64, CoflowId)> = None;
            for i in 0..self.shards[smax].active.len() {
                let cid = self.shards[smax].active[i];
                let c = &world.coflows[cid];
                if c.done() {
                    continue;
                }
                let rem: f64 = c.active_list.iter().map(|&f| world.flows[f].remaining()).sum();
                if rem <= 0.0 {
                    continue;
                }
                let take = match victim {
                    None => true,
                    Some((vr, vc)) => rem < vr || (rem == vr && cid < vc),
                };
                if take {
                    victim = Some((rem, cid));
                }
            }
            let Some((rem, cid)) = victim else { break };
            self.migrate(cid, smax, smin, world);
            self.demand_total[smax] -= rem;
            self.demand_total[smin] += rem;
            moves += 1;
        }
        // 3) water-fill the leases from the (post-migration) demand
        for p in 0..np {
            for s in 0..k {
                self.wf_demand[s] = self.shards[s].demand_up[p];
            }
            water_fill_port(
                world.fabric.up_capacity[p],
                &self.wf_demand[..k],
                self.cfg.lease_floor_frac,
                &mut self.wf_out[..k],
                &mut self.wf_scratch,
            );
            for s in 0..k {
                self.shards[s].lease.up_capacity[p] = self.wf_out[s];
            }
            for s in 0..k {
                self.wf_demand[s] = self.shards[s].demand_down[p];
            }
            water_fill_port(
                world.fabric.down_capacity[p],
                &self.wf_demand[..k],
                self.cfg.lease_floor_frac,
                &mut self.wf_out[..k],
                &mut self.wf_scratch,
            );
            for s in 0..k {
                self.shards[s].lease.down_capacity[p] = self.wf_out[s];
            }
        }
        // leases moved: every shard's grants are stale
        for s in 0..k {
            self.dirty[s] = true;
        }
        self.reconciliations += 1;
        if self.obs_on {
            // a = shard count, b = migrations performed this round
            self.obs_pending
                .push((0, EventKind::LeaseReconcile, obs::NO_COFLOW, k as u64, moves as u64));
        }
    }

    /// Move `cid` from shard `from` to shard `to`, handing its per-port
    /// demand along and running the detach/attach scheduler hooks.
    fn migrate(&mut self, cid: CoflowId, from: usize, to: usize, world: &mut World) {
        debug_assert_ne!(from, to);
        // hand the coflow's per-port demand to the receiver
        for i in 0..world.coflows[cid].active_list.len() {
            let f = world.coflows[cid].active_list[i];
            let fl = &world.flows[f];
            let rem = fl.remaining();
            let (src, dst) = (fl.src, fl.dst);
            self.shards[from].demand_up[src] = (self.shards[from].demand_up[src] - rem).max(0.0);
            self.shards[from].demand_down[dst] =
                (self.shards[from].demand_down[dst] - rem).max(0.0);
            self.shards[to].demand_up[src] += rem;
            self.shards[to].demand_down[dst] += rem;
        }
        // detach from the source (its view no longer contains cid)
        self.shards[from].active.retain(|&x| x != cid);
        {
            let sh = &mut self.shards[from];
            std::mem::swap(&mut world.active, &mut sh.active);
            sh.sched.on_coflow_detach(cid, world);
            std::mem::swap(&mut world.active, &mut sh.active);
        }
        // attach to the target (its view already contains cid)
        self.owner[cid] = to as u32;
        self.shards[to].active.push(cid);
        {
            let sh = &mut self.shards[to];
            std::mem::swap(&mut world.active, &mut sh.active);
            sh.sched.on_coflow_attach(cid, world);
            std::mem::swap(&mut world.active, &mut sh.active);
        }
        self.dirty[from] = true;
        self.dirty[to] = true;
        self.migrations += 1;
        if self.obs_on {
            self.obs_pending
                .push((from as u32, EventKind::Migration, cid as u64, from as u64, to as u64));
        }
    }

    /// Assert the cluster's structural invariants against `world` (K ≥ 2):
    /// per-port lease conservation, unique coflow ownership, and owner-map
    /// consistency. Panics with context on violation. Driven per round by
    /// [`ClusterConfig::validate`]; also callable directly from tests.
    pub fn check_invariants(&self, world: &World) {
        let k = self.shards.len();
        if k == 1 {
            return;
        }
        if self.leases_ready {
            for p in 0..world.fabric.num_ports {
                let up: f64 = self.shards.iter().map(|sh| sh.lease.up_capacity[p]).sum();
                let cap = world.fabric.up_capacity[p];
                assert!(
                    (up - cap).abs() <= 1e-6 * cap.max(1.0),
                    "lease conservation violated on uplink {p}: Σ leases {up} != capacity {cap}"
                );
                let down: f64 = self.shards.iter().map(|sh| sh.lease.down_capacity[p]).sum();
                let cap = world.fabric.down_capacity[p];
                assert!(
                    (down - cap).abs() <= 1e-6 * cap.max(1.0),
                    "lease conservation violated on downlink {p}: Σ leases {down} != capacity {cap}"
                );
                for (s, sh) in self.shards.iter().enumerate() {
                    assert!(
                        sh.lease.up_capacity[p] >= 0.0 && sh.lease.down_capacity[p] >= 0.0,
                        "negative lease on port {p} of shard {s}"
                    );
                }
            }
        }
        // unique ownership: every owned coflow appears in exactly one
        // shard's list, and that list matches the owner map
        let mut seen = vec![false; world.coflows.len()];
        for (s, sh) in self.shards.iter().enumerate() {
            for &cid in &sh.active {
                assert!(
                    !seen[cid],
                    "coflow {cid} owned by more than one shard (second: {s})"
                );
                seen[cid] = true;
                assert_eq!(
                    self.owner.get(cid).copied(),
                    Some(s as u32),
                    "owner map disagrees for coflow {cid} in shard {s}"
                );
            }
        }
        for &cid in &world.active {
            let o = self.owner.get(cid).copied().unwrap_or(NONE);
            assert_ne!(o, NONE, "active coflow {cid} has no owner shard");
            assert!(
                self.shards[o as usize].active.contains(&cid),
                "active coflow {cid} missing from its owner shard {o}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::world_from_trace;
    use crate::trace::TraceSpec;

    fn sum(v: &[f64]) -> f64 {
        v.iter().sum()
    }

    #[test]
    fn water_fill_single_shard_gets_everything() {
        let mut out = [0.0];
        let mut scratch = Vec::new();
        water_fill_port(100.0, &[42.0], 0.05, &mut out, &mut scratch);
        assert_eq!(out, [100.0]);
    }

    #[test]
    fn water_fill_undersubscribed_spreads_spare() {
        let mut out = [0.0; 2];
        let mut scratch = Vec::new();
        water_fill_port(100.0, &[10.0, 30.0], 0.0, &mut out, &mut scratch);
        // demand met (10, 30) + 30 spare each
        assert!((out[0] - 40.0).abs() < 1e-9, "{out:?}");
        assert!((out[1] - 60.0).abs() < 1e-9, "{out:?}");
        assert!((sum(&out) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn water_fill_oversubscribed_is_max_min() {
        let mut out = [0.0; 3];
        let mut scratch = Vec::new();
        water_fill_port(90.0, &[10.0, 200.0, 200.0], 0.0, &mut out, &mut scratch);
        // shard 0's 10 is met; the rest split the remaining 80 evenly
        assert!((out[0] - 10.0).abs() < 1e-9, "{out:?}");
        assert!((out[1] - 40.0).abs() < 1e-9, "{out:?}");
        assert!((out[2] - 40.0).abs() < 1e-9, "{out:?}");
        assert!((sum(&out) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn water_fill_floor_guards_zero_demand_shards() {
        let mut out = [0.0; 2];
        let mut scratch = Vec::new();
        water_fill_port(100.0, &[1000.0, 0.0], 0.05, &mut out, &mut scratch);
        // the idle shard keeps its floor share (5% / 2 = 2.5)
        assert!(out[1] >= 2.5 - 1e-9, "{out:?}");
        assert!((sum(&out) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn water_fill_conserves_capacity_exactly_enough() {
        let mut scratch = Vec::new();
        for k in 2..6 {
            let demand: Vec<f64> = (0..k).map(|s| (s as f64) * 13.7 + 0.3).collect();
            let mut out = vec![0.0; k];
            water_fill_port(123.456, &demand, 0.05, &mut out, &mut scratch);
            assert!(
                (sum(&out) - 123.456).abs() <= 1e-9 * 123.456,
                "k={k}: Σ {}",
                sum(&out)
            );
        }
    }

    #[test]
    fn k1_compute_matches_plain_order_plus_allocate() {
        let trace = TraceSpec::tiny(8, 12).seed(4).generate();
        let cfg = SchedulerConfig::default();
        let mut world = world_from_trace(&trace);
        world.active = (0..trace.coflows.len()).collect();

        let mut cluster =
            CoordinatorCluster::with_coordinators(1, SchedulerKind::Philae, &trace, &cfg);
        let mut single = SchedulerKind::Philae.build(&trace, &cfg);
        // drive arrivals identically on two identical worlds
        let mut world2 = world_from_trace(&trace);
        world2.active = (0..trace.coflows.len()).collect();
        for cid in 0..trace.coflows.len() {
            cluster.on_arrival(cid, &mut world);
            single.on_arrival(cid, &mut world2);
        }
        cluster.compute(&mut world, false);
        let mut plan = Plan::default();
        single.order_into(&world2, &mut plan);
        let mut scratch = rate::AllocScratch::new();
        rate::allocate_into(&world2.fabric, &world2.flows, &world2.coflows, &plan, &mut scratch);
        assert_eq!(cluster.grants(), scratch.grants());
        for f in 0..world.flows.len() {
            assert_eq!(cluster.was_granted(f), scratch.was_granted(f), "flow {f}");
        }
    }

    #[test]
    fn arrivals_partition_across_shards_and_invariants_hold() {
        let trace = TraceSpec::tiny(10, 20).seed(9).generate();
        let cfg = SchedulerConfig::default();
        let mut world = world_from_trace(&trace);
        let mut cluster =
            CoordinatorCluster::with_coordinators(3, SchedulerKind::Philae, &trace, &cfg);
        for cid in 0..trace.coflows.len() {
            world.active.push(cid);
            cluster.on_arrival(cid, &mut world);
        }
        cluster.compute(&mut world, false);
        cluster.check_invariants(&world);
        let total: usize = (0..3).map(|s| cluster.owned(s).len()).sum();
        assert_eq!(total, trace.coflows.len());
        // with 20 coflows over 3 shards, no shard should be empty
        for s in 0..3 {
            assert!(!cluster.owned(s).is_empty(), "shard {s} got nothing");
        }
    }

    #[test]
    fn shard_kill_and_restore_keeps_invariants_and_grants() {
        let trace = TraceSpec::tiny(10, 20).seed(9).generate();
        let cfg = SchedulerConfig::default();
        let mut world = world_from_trace(&trace);
        let mut cluster =
            CoordinatorCluster::with_coordinators(3, SchedulerKind::Philae, &trace, &cfg);
        for cid in 0..trace.coflows.len() {
            world.active.push(cid);
            cluster.on_arrival(cid, &mut world);
        }
        cluster.compute(&mut world, false);
        let before = cluster.grants().len();
        assert!(before > 0);
        let ckpt = cluster.checkpoint(&mut world);
        // kill every shard in turn, restoring each from the checkpoint
        for s in 0..3 {
            cluster
                .kill_and_restore_shard(s, &trace, &cfg, Some(&ckpt), &mut world)
                .unwrap();
        }
        cluster.check_invariants(&world);
        cluster.compute(&mut world, false);
        assert_eq!(cluster.grants().len(), before, "restored cluster lost grants");
        // a crash before the first checkpoint: pure attach rebuild
        cluster
            .kill_and_restore_shard(1, &trace, &cfg, None, &mut world)
            .unwrap();
        cluster.check_invariants(&world);
        cluster.compute(&mut world, false);
        assert_eq!(cluster.grants().len(), before);
    }

    #[test]
    fn kill_and_restore_rejects_tampered_checkpoint() {
        let trace = TraceSpec::tiny(6, 8).seed(1).generate();
        let cfg = SchedulerConfig::default();
        let mut world = world_from_trace(&trace);
        let mut cluster =
            CoordinatorCluster::with_coordinators(2, SchedulerKind::Philae, &trace, &cfg);
        for cid in 0..trace.coflows.len() {
            world.active.push(cid);
            cluster.on_arrival(cid, &mut world);
        }
        cluster.compute(&mut world, false);
        let ckpt = cluster.checkpoint(&mut world).replace("philae", "phileo");
        let err = cluster.kill_and_restore_shard(0, &trace, &cfg, Some(&ckpt), &mut world);
        assert!(err.is_err(), "tampered checkpoint must be rejected");
        // the failed restore must not have replaced the scheduler
        cluster.compute(&mut world, false);
        cluster.check_invariants(&world);
    }

    #[test]
    fn chaos_driver_kills_and_restores_during_compute() {
        let trace = TraceSpec::tiny(10, 20).seed(3).generate();
        let cfg = SchedulerConfig::default();
        let mut cfg_cluster = ClusterConfig::default();
        cfg_cluster.coordinators = 2;
        cfg_cluster.validate = true;
        let mut world = world_from_trace(&trace);
        let mut cluster = CoordinatorCluster::new(SchedulerKind::Philae, &trace, &cfg, cfg_cluster);
        cluster.set_chaos(&trace, &cfg, 2, 3, 42);
        for cid in 0..trace.coflows.len() {
            world.active.push(cid);
            cluster.on_arrival(cid, &mut world);
            cluster.compute(&mut world, false);
        }
        assert!(cluster.chaos_checkpoints() > 0, "checkpoint leg never fired");
        assert!(cluster.chaos_kills() > 0, "kill leg never fired");
        cluster.check_invariants(&world);
        assert!(!cluster.grants().is_empty());
    }

    #[test]
    fn reconciliation_rebalances_and_migrates_deterministically() {
        let trace = TraceSpec::tiny(10, 24).seed(2).generate();
        let mut cfg_cluster = ClusterConfig::default();
        cfg_cluster.coordinators = 2;
        cfg_cluster.imbalance_threshold = 1.01;
        cfg_cluster.max_migrations_per_round = 16;
        cfg_cluster.validate = true;
        let cfg = SchedulerConfig::default();
        let mut world = world_from_trace(&trace);
        let mut a =
            CoordinatorCluster::new(SchedulerKind::Philae, &trace, &cfg, cfg_cluster.clone());
        let mut b = CoordinatorCluster::new(SchedulerKind::Philae, &trace, &cfg, cfg_cluster);
        let mut world_b = world_from_trace(&trace);
        for cid in 0..trace.coflows.len() {
            world.active.push(cid);
            world_b.active.push(cid);
            a.on_arrival(cid, &mut world);
            b.on_arrival(cid, &mut world_b);
        }
        a.reconcile_now(&mut world);
        b.reconcile_now(&mut world_b);
        a.check_invariants(&world);
        // deterministic: identical histories yield identical ownership
        assert_eq!(a.migrations(), b.migrations());
        for cid in 0..trace.coflows.len() {
            assert_eq!(a.owner_of(cid), b.owner_of(cid), "coflow {cid}");
        }
        // leases now demand-weighted but still conserved (checked above via
        // validate + explicit call); grants from both shards stay feasible
        a.compute(&mut world, false);
        let mut up = vec![0.0; world.fabric.num_ports];
        let mut down = vec![0.0; world.fabric.num_ports];
        for &(f, r) in a.grants() {
            up[world.flows[f].src] += r;
            down[world.flows[f].dst] += r;
        }
        for p in 0..world.fabric.num_ports {
            assert!(
                up[p] <= world.fabric.up_capacity[p] * (1.0 + 1e-9),
                "uplink {p} oversubscribed: {} > {}",
                up[p],
                world.fabric.up_capacity[p]
            );
            assert!(
                down[p] <= world.fabric.down_capacity[p] * (1.0 + 1e-9),
                "downlink {p} oversubscribed"
            );
        }
    }
}
