//! The coordinator: scheduler implementations and rate allocation.
//!
//! All schedulers implement [`Scheduler`]: the simulation (or the live tokio
//! service) feeds them coflow arrival / flow completion / periodic tick
//! events and asks for a **priority order over eligible flows** whenever a
//! reallocation is triggered; [`rate::allocate`] turns that order into
//! per-flow rates that respect port capacities (greedy max-min in priority
//! order, which is work-conserving by construction).
//!
//! Implemented policies:
//!
//! * [`PhilaeScheduler`] — the paper's contribution: pilot-flow sampling,
//!   explicit size estimation, contention-adjusted shortest-coflow-first.
//! * [`AaloScheduler`] — prior art baseline: D-CLAS multi-level feedback
//!   queues driven by periodic byte updates.
//! * [`SebfScheduler`], [`ScfScheduler`] — clairvoyant oracles
//!   (Varys-style shortest-effective-bottleneck-first; total-size SCF).
//! * [`FifoScheduler`] — non-clairvoyant FIFO (Baraat-like, no preemption
//!   across coflows).
//! * [`SaathScheduler`] — Saath-like: queue transitions by longest finished
//!   flow, contention-aware intra-queue order, all-or-none grouping.
//! * [`errcorr`] — the §2.2 error-correction variants of Philae
//!   (bootstrap lower-confidence-bound, one-round, multi-round).
//! * [`DcoflowScheduler`] — deadline-aware (DCoflow-style, arXiv
//!   2205.01229): reservation-based admission control plus
//!   earliest-deadline-first ordering; rejected/expired coflows drop to
//!   background priority. [`DeadlineMode`] additionally lets the
//!   deadline-blind policies use SLO deadlines as a secondary order key.

pub mod aalo;
pub mod cluster;
pub mod dcoflow;
pub mod errcorr;
pub mod fifo;
pub mod philae;
pub mod rate;
pub mod recovery;
pub mod saath;
pub mod scf;
pub mod sebf;

pub use aalo::AaloScheduler;
pub use cluster::{ClusterConfig, CoordinatorCluster};
pub use dcoflow::{AdmissionState, DcoflowScheduler};
pub use errcorr::{ErrCorrMode, PhilaeErrCorrScheduler};
pub use fifo::FifoScheduler;
pub use philae::PhilaeScheduler;
pub use rate::{
    allocate, allocate_into, apply_grants, AllocScratch, Allocation, FlowFilter, OrderEntry, Plan,
};
pub use recovery::{checkpoint_scheduler, restore_scheduler, seal, unseal, RecoveryError};
pub use saath::SaathScheduler;
pub use scf::ScfScheduler;
pub use sebf::SebfScheduler;

use crate::coflow::{CoflowState, FlowState};
use crate::fabric::{Fabric, PortLoad};
use crate::trace::Trace;
use crate::util::JsonValue;
use crate::{CoflowId, FlowId, Time, MB};

/// Binary-search insert into a vector kept sorted under `cmp` — the shared
/// repair primitive of the incremental order caches.
pub(crate) fn insert_sorted<T>(
    v: &mut Vec<T>,
    key: T,
    cmp: impl Fn(&T, &T) -> std::cmp::Ordering,
) {
    let pos = v.partition_point(|e| cmp(e, &key) == std::cmp::Ordering::Less);
    v.insert(pos, key);
}

/// Remove the entry matching `key` under `cmp`. If the cached key turned
/// out stale (binary search misses), fall back to a linear scan by
/// identity (`is_same`) so the structure self-heals; no-op when the item
/// is absent entirely.
pub(crate) fn remove_sorted<T>(
    v: &mut Vec<T>,
    key: &T,
    cmp: impl Fn(&T, &T) -> std::cmp::Ordering,
    is_same: impl Fn(&T) -> bool,
) {
    match v.binary_search_by(|e| cmp(e, key)) {
        Ok(pos) => {
            v.remove(pos);
        }
        Err(_) => {
            if let Some(pos) = v.iter().position(|e| is_same(e)) {
                v.remove(pos);
            }
        }
    }
}

/// Everything a scheduler may inspect and (for its own coflows' learning
/// state) mutate when reacting to an event.
pub struct World {
    pub now: Time,
    pub flows: Vec<FlowState>,
    pub coflows: Vec<CoflowState>,
    pub fabric: Fabric,
    pub load: PortLoad,
    /// Ids of arrived, unfinished coflows in arrival order.
    pub active: Vec<CoflowId>,
}

impl World {
    /// Eligible (arrived, unfinished) flows of a coflow.
    pub fn active_flows_of(&self, cid: CoflowId) -> impl Iterator<Item = FlowId> + '_ {
        self.coflows[cid]
            .flows
            .iter()
            .copied()
            .filter(move |&f| !self.flows[f].done())
    }
}

/// What an event handler wants the engine to do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reaction {
    /// Nothing changed that affects rates.
    None,
    /// Priorities changed: recompute the order and reallocate rates.
    Reallocate,
}

impl Reaction {
    pub fn merge(self, other: Reaction) -> Reaction {
        if self == Reaction::Reallocate || other == Reaction::Reallocate {
            Reaction::Reallocate
        } else {
            Reaction::None
        }
    }
}

/// One coalesced batch of same-instant scheduler events — the unit of
/// **batched admission**: the engine (and the live service) applies all
/// physical state updates of an instant first, then hands the scheduler one
/// batch and pays **one** order repair + **one** allocation for it, instead
/// of one reallocation per admit (the per-event regime the §4.3 deadline
/// model charges separately).
///
/// The buffers are caller-owned and reused across instants (cleared, never
/// reallocated in steady state).
#[derive(Debug, Clone, Default)]
pub struct EventBatch {
    /// Coflows that arrived at this instant, in arrival order (already
    /// admitted to `world.active`).
    pub arrivals: Vec<CoflowId>,
    /// Flow-completion reports in delivery order; the flag marks reports
    /// that complete their whole coflow (the coflow-completion event is
    /// delivered right after that report, exactly once per coflow).
    pub flow_reports: Vec<(FlowId, bool)>,
    /// A periodic δ tick fell on this instant.
    pub tick: bool,
}

impl EventBatch {
    /// Empty the batch, keeping buffer capacity for reuse.
    pub fn clear(&mut self) {
        self.arrivals.clear();
        self.flow_reports.clear();
        self.tick = false;
    }

    /// `true` if the batch carries no event at all.
    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty() && self.flow_reports.is_empty() && !self.tick
    }

    /// Number of events in the batch.
    pub fn len(&self) -> usize {
        self.arrivals.len() + self.flow_reports.len() + usize::from(self.tick)
    }
}

/// How a deadline-blind policy treats per-coflow SLO deadlines.
///
/// [`DeadlineMode::Secondary`] threads the deadline in as a **secondary
/// order key**: wherever the policy's own key ties (same Philae score, same
/// Aalo queue, same SEBF/SCF remaining bytes), the earlier deadline wins
/// before the FIFO sequence does. Coflows without a deadline key as `+∞`,
/// so on a deadline-free trace `Secondary` is **bit-identical** to
/// [`DeadlineMode::Ignore`] (pinned in `rust/tests/cct_equivalence.rs`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum DeadlineMode {
    /// Ignore deadlines entirely (the pre-SLO behavior; the default).
    #[default]
    Ignore,
    /// Use the deadline as a secondary sort key before the FIFO tie-break.
    Secondary,
}

impl DeadlineMode {
    /// The order key this mode derives from a coflow's deadline: the
    /// absolute deadline under [`DeadlineMode::Secondary`], `+∞` otherwise
    /// (and for best-effort coflows), so `Ignore` orders are untouched.
    #[inline]
    pub fn key(self, deadline: Option<Time>) -> f64 {
        match self {
            DeadlineMode::Secondary => deadline.unwrap_or(f64::INFINITY),
            DeadlineMode::Ignore => f64::INFINITY,
        }
    }
}

/// Admission-control counters of a deadline-aware scheduler
/// ([`DcoflowScheduler`]); surfaced through
/// [`Scheduler::admission_stats`] into sim results and the live-service
/// report. Counters count **admission decisions** — under cluster
/// migration a coflow re-admitted by its new shard counts again.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Deadline coflows that passed the feasibility test.
    pub admitted: u64,
    /// Deadline coflows rejected up front (scheduled at background
    /// priority instead).
    pub rejected: u64,
    /// Admitted coflows that nevertheless missed their deadline and were
    /// demoted to background priority.
    pub expired: u64,
}

impl AdmissionStats {
    /// Accumulate another shard's counters (cluster aggregation).
    pub fn merge(&mut self, other: &AdmissionStats) {
        self.admitted += other.admitted;
        self.rejected += other.rejected;
        self.expired += other.expired;
    }
}

/// The scheduler interface shared by the simulator and the live service.
pub trait Scheduler: Send {
    fn name(&self) -> String;

    /// Admission-control counters, for schedulers that perform deadline
    /// admission ([`DcoflowScheduler`]); `None` for everyone else.
    fn admission_stats(&self) -> Option<AdmissionStats> {
        None
    }

    /// `Some(δ)` if the policy needs a periodic tick (Aalo's scheduling
    /// interval); Philae is event-triggered and returns `None`.
    fn tick_interval(&self) -> Option<Time> {
        None
    }

    /// A coflow arrived (already appended to `world.active`).
    fn on_arrival(&mut self, cid: CoflowId, world: &mut World) -> Reaction;

    /// A flow finished (completion report from a local agent; Philae's only
    /// steady-state update — see Table 1).
    fn on_flow_complete(&mut self, fid: FlowId, world: &mut World) -> Reaction;

    /// A whole coflow finished.
    fn on_coflow_complete(&mut self, _cid: CoflowId, _world: &mut World) -> Reaction {
        Reaction::Reallocate
    }

    /// Periodic tick (only called when `tick_interval` is `Some`).
    fn on_tick(&mut self, _world: &mut World) -> Reaction {
        Reaction::None
    }

    /// Multi-coordinator support: `cid` is being **migrated away** to
    /// another coordinator shard — stop tracking it. The default treats it
    /// like a completed coflow, which is sufficient for every in-tree
    /// scheduler: their incremental order caches drop coflows that stop
    /// appearing in the active scan (stamp mismatch) and self-heal on the
    /// next `order_into`.
    fn on_coflow_detach(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.on_coflow_complete(cid, world)
    }

    /// Multi-coordinator support: **adopt** `cid` mid-flight from another
    /// shard, reconstructing whatever learning state this scheduler keeps
    /// per coflow. The default treats it as a fresh arrival — correct for
    /// schedulers whose order keys derive entirely from the world (FIFO,
    /// SEBF, SCF). Schedulers with per-coflow learning state (Philae's
    /// sampling machine, Aalo's seen-bytes, Saath's queue) override this so
    /// migration neither resets a coflow's earned priority nor re-pilots it.
    fn on_coflow_attach(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.on_arrival(cid, world)
    }

    /// Serialize this scheduler's **durable facts** for a crash checkpoint
    /// (`coordinator::recovery`): everything that is *learned from events*
    /// and cannot be rebuilt from the surviving world alone — Philae's
    /// pilot samples, Aalo's seen bytes and FIFO sequence, dcoflow's
    /// admission verdicts and reservations. Incremental order caches are
    /// deliberately **not** durable: they self-heal on the next
    /// `order_into` and are pinned equivalent to a full rebuild. The
    /// default (`Null`) is correct for stateless/oracle schedulers.
    fn export_state(&self) -> JsonValue {
        JsonValue::Null
    }

    /// Overlay previously exported durable facts onto this scheduler.
    /// Called by the restore driver **after** the `on_coflow_attach`
    /// rebuild pass. With `exact = true` the checkpoint is from the *same*
    /// event boundary as the restore (crash-with-warm-standby): the import
    /// is a wholesale overwrite and is the last word — it undoes
    /// attach-path divergence (fresh Aalo FIFO sequence, dcoflow
    /// re-admission, Philae adopt's sample-order float sums) and makes the
    /// restored scheduler bit-identical to the uninterrupted one. With
    /// `exact = false` the checkpoint may be **stale** (periodic chaos
    /// restore): the attach rebuild already recovered everything derivable
    /// from the surviving world, so schedulers only merge back facts that
    /// must survive a crash and are safe when stale — dcoflow re-instates
    /// admitted verdicts (the SLO certificate) — and otherwise keep the
    /// fresher attach-derived state. The default ignores the state
    /// (nothing durable to restore).
    fn import_state(&mut self, _state: &JsonValue, _world: &World, _exact: bool) {}

    /// Deliver one coalesced [`EventBatch`] (batched admission). The
    /// default implementation replays the per-event hooks in the batch's
    /// delivery order — arrivals, then flow reports (each followed by its
    /// coflow-completion event when flagged), then the tick — and merges
    /// their reactions, so every scheduler is batch-capable out of the box.
    /// Schedulers may override it to repair their order structures once per
    /// batch instead of once per event.
    fn on_batch(&mut self, batch: &EventBatch, world: &mut World) -> Reaction {
        let mut reaction = Reaction::None;
        for &cid in &batch.arrivals {
            reaction = reaction.merge(self.on_arrival(cid, world));
        }
        for &(fid, coflow_done) in &batch.flow_reports {
            reaction = reaction.merge(self.on_flow_complete(fid, world));
            if coflow_done {
                let cid = world.flows[fid].coflow;
                reaction = reaction.merge(self.on_coflow_complete(cid, world));
            }
        }
        if batch.tick {
            reaction = reaction.merge(self.on_tick(world));
        }
        reaction
    }

    /// Write the scheduling plan into `plan` (cleared first): priority
    /// order over coflows (highest first), lane filters, and any
    /// bandwidth-group weights. Flows of one coflow are contiguous by
    /// construction (all-or-none).
    ///
    /// The plan is **caller-owned and reused** across events; schedulers
    /// maintain their order incrementally (repairing a sorted structure
    /// around the coflows whose key changed, validated lazily against
    /// `world`), so steady-state calls perform no heap allocation and no
    /// full re-sort.
    fn order_into(&mut self, world: &World, plan: &mut Plan);

    /// From-scratch rebuild of the plan, bypassing any incremental order
    /// state — the reference ("oracle") path that incremental
    /// implementations are property-tested against, and the pre-optimization
    /// baseline the hot-path benches measure. Must emit exactly the same
    /// plan as [`Scheduler::order_into`] on the same world.
    fn order_full_into(&mut self, world: &World, plan: &mut Plan) {
        self.order_into(world, plan);
    }

    /// Convenience wrapper allocating a fresh [`Plan`] per call (tests and
    /// one-shot callers; hot paths use [`Scheduler::order_into`]).
    fn order(&mut self, world: &World) -> Plan {
        let mut plan = Plan::default();
        self.order_into(world, &mut plan);
        plan
    }
}

/// Which scheduler to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Philae: sampling-based size learning + contention-aware SCF.
    Philae,
    /// Aalo: multi-level feedback queues (prior art).
    Aalo,
    /// Clairvoyant shortest-effective-bottleneck-first (Varys).
    Sebf,
    /// Clairvoyant shortest-total-size coflow first.
    Scf,
    /// Non-clairvoyant FIFO.
    Fifo,
    /// Saath-like priority-queue scheduler.
    Saath,
    /// Philae + bootstrap lower-confidence-bound estimate (§2.2 variant 1).
    PhilaeLcb,
    /// Philae + LCB + one round of error correction (§2.2 variant 2).
    PhilaeEc1,
    /// Philae + LCB + error correction until completion (§2.2 variant 3).
    PhilaeEcMulti,
    /// Deadline-aware DCoflow-style: reservation admission control +
    /// earliest-deadline-first with laxity tie-breaks.
    Dcoflow,
}

impl SchedulerKind {
    /// Instantiate the scheduler for `trace` under `cfg`. Clairvoyant
    /// policies receive the oracle; non-clairvoyant ones must not touch it.
    pub fn build(self, trace: &Trace, cfg: &SchedulerConfig) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Philae => Box::new(PhilaeScheduler::new(cfg.clone())),
            SchedulerKind::Aalo => Box::new(AaloScheduler::new(cfg.clone())),
            SchedulerKind::Sebf => {
                Box::new(SebfScheduler::new(trace).with_deadline_mode(cfg.deadline_mode))
            }
            SchedulerKind::Scf => {
                Box::new(ScfScheduler::new(trace).with_deadline_mode(cfg.deadline_mode))
            }
            SchedulerKind::Fifo => Box::new(FifoScheduler::new()),
            SchedulerKind::Dcoflow => Box::new(DcoflowScheduler::new()),
            SchedulerKind::Saath => Box::new(SaathScheduler::new(cfg.clone())),
            SchedulerKind::PhilaeLcb => {
                Box::new(PhilaeErrCorrScheduler::new(cfg.clone(), ErrCorrMode::LcbOnly))
            }
            SchedulerKind::PhilaeEc1 => {
                Box::new(PhilaeErrCorrScheduler::new(cfg.clone(), ErrCorrMode::OneRound))
            }
            SchedulerKind::PhilaeEcMulti => {
                Box::new(PhilaeErrCorrScheduler::new(cfg.clone(), ErrCorrMode::MultiRound))
            }
        }
    }

    /// CLI name of the scheduler.
    pub fn as_str(self) -> &'static str {
        match self {
            SchedulerKind::Philae => "philae",
            SchedulerKind::Aalo => "aalo",
            SchedulerKind::Sebf => "sebf",
            SchedulerKind::Scf => "scf",
            SchedulerKind::Fifo => "fifo",
            SchedulerKind::Saath => "saath",
            SchedulerKind::PhilaeLcb => "philae-lcb",
            SchedulerKind::PhilaeEc1 => "philae-ec1",
            SchedulerKind::PhilaeEcMulti => "philae-ec-multi",
            SchedulerKind::Dcoflow => "dcoflow",
        }
    }

    pub fn all() -> &'static [SchedulerKind] {
        &[
            SchedulerKind::Philae,
            SchedulerKind::Aalo,
            SchedulerKind::Sebf,
            SchedulerKind::Scf,
            SchedulerKind::Fifo,
            SchedulerKind::Saath,
            SchedulerKind::PhilaeLcb,
            SchedulerKind::PhilaeEc1,
            SchedulerKind::PhilaeEcMulti,
            SchedulerKind::Dcoflow,
        ]
    }
}

impl std::str::FromStr for SchedulerKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        SchedulerKind::all()
            .iter()
            .copied()
            .find(|k| k.as_str() == s)
            .ok_or_else(|| {
                let names: Vec<&str> = SchedulerKind::all().iter().map(|k| k.as_str()).collect();
                format!("unknown scheduler {s:?}; expected one of {names:?}")
            })
    }
}

/// Tunables for all policies; defaults follow the paper (§IV “all the
/// experiments use default parameters K, E, S and the default pilot flow
/// selection policy”, plus Aalo's published defaults).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulerConfig {
    // ---- Philae (sampling) ----
    /// Fraction of a coflow's flows to pilot (paper: “never larger than 1%”
    /// for wide coflows).
    pub pilot_frac: f64,
    /// Lower bound on pilot flows per coflow.
    pub pilot_min: usize,
    /// Upper bound on pilot flows per coflow.
    pub pilot_max: usize,
    /// Weight of contention in the priority score:
    /// `score = est_remaining × (1 + w · avg_extra_sharers)`.
    pub contention_weight: f64,
    /// Starvation avoidance: coflows waiting longer than this enter the
    /// express lane (FIFO, above everything else). A rare safety valve —
    /// far above typical CCTs, so SJF ordering is undisturbed unless a
    /// coflow is genuinely starving.
    pub age_threshold: Time,
    // ---- Aalo / Saath (priority queues) ----
    /// Number of logical priority queues K.
    pub num_queues: usize,
    /// First queue threshold E in bytes.
    pub q0_threshold: f64,
    /// Per-queue threshold multiplier S.
    pub queue_mult: f64,
    /// Scheduling interval δ (seconds) for periodic policies.
    pub delta: Time,
    // ---- error correction (§2.2) ----
    /// Bootstrap resamples for the confidence interval.
    pub bootstrap_resamples: usize,
    /// LCB = mean − `lcb_sigmas` · bootstrap σ.
    pub lcb_sigmas: f64,
    /// Seed for the (deterministic) bootstrap resampling.
    pub bootstrap_seed: u64,
    // ---- failure / dynamics modelling ----
    /// Probability an Aalo per-interval byte update is lost (Table 5's
    /// network-error robustness study perturbs this via run seeds).
    pub update_loss_prob: f64,
    /// Max extra latency (seconds) on completion reports.
    pub report_jitter: Time,
    /// Seed for the dynamics above (varied across the 5 runs of Table 5).
    pub dynamics_seed: u64,
    // ---- deadline (SLO) workloads ----
    /// How deadline-blind policies (Philae, Aalo, SEBF, SCF) treat
    /// per-coflow deadlines; see [`DeadlineMode`]. The default (`Ignore`)
    /// keeps their pre-SLO behavior bit for bit.
    pub deadline_mode: DeadlineMode,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            pilot_frac: 0.01,
            pilot_min: 1,
            pilot_max: 10,
            contention_weight: 0.5,
            age_threshold: 3600.0,
            num_queues: 10,
            q0_threshold: 10.0 * MB,
            queue_mult: 10.0,
            delta: 0.008,
            bootstrap_resamples: 100,
            lcb_sigmas: 3.0,
            bootstrap_seed: 1,
            update_loss_prob: 0.0,
            report_jitter: 0.0,
            dynamics_seed: 0,
            deadline_mode: DeadlineMode::default(),
        }
    }
}

impl SchedulerConfig {
    /// Number of pilot flows for a coflow with `n` flows:
    /// `clamp(⌈frac·n⌉, pilot_min, pilot_max)`, capped at `n`.
    pub fn pilots_for(&self, n: usize) -> usize {
        let want = (self.pilot_frac * n as f64).ceil() as usize;
        want.clamp(self.pilot_min, self.pilot_max).min(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pilot_count_defaults() {
        let cfg = SchedulerConfig::default();
        assert_eq!(cfg.pilots_for(1), 1);
        assert_eq!(cfg.pilots_for(50), 1);
        assert_eq!(cfg.pilots_for(400), 4);
        assert_eq!(cfg.pilots_for(5000), 10); // capped at pilot_max
        assert_eq!(cfg.pilots_for(0), 0);
    }

    #[test]
    fn event_batch_buffers() {
        let mut b = EventBatch::default();
        assert!(b.is_empty());
        assert_eq!(b.len(), 0);
        b.arrivals.push(3);
        b.flow_reports.push((7, true));
        b.tick = true;
        assert!(!b.is_empty());
        assert_eq!(b.len(), 3);
        let cap = b.arrivals.capacity();
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.arrivals.capacity(), cap, "clear must keep capacity");
    }

    #[test]
    fn reaction_merge() {
        assert_eq!(Reaction::None.merge(Reaction::None), Reaction::None);
        assert_eq!(Reaction::None.merge(Reaction::Reallocate), Reaction::Reallocate);
        assert_eq!(Reaction::Reallocate.merge(Reaction::None), Reaction::Reallocate);
    }

    #[test]
    fn deadline_mode_keys() {
        assert_eq!(DeadlineMode::Ignore.key(Some(3.0)), f64::INFINITY);
        assert_eq!(DeadlineMode::Ignore.key(None), f64::INFINITY);
        assert_eq!(DeadlineMode::Secondary.key(Some(3.0)), 3.0);
        assert_eq!(DeadlineMode::Secondary.key(None), f64::INFINITY);
    }

    #[test]
    fn admission_stats_merge() {
        let mut a = AdmissionStats { admitted: 1, rejected: 2, expired: 3 };
        a.merge(&AdmissionStats { admitted: 10, rejected: 20, expired: 30 });
        assert_eq!(a, AdmissionStats { admitted: 11, rejected: 22, expired: 33 });
    }

    #[test]
    fn all_kinds_buildable() {
        let trace = crate::trace::TraceSpec::tiny(4, 3).generate();
        let cfg = SchedulerConfig::default();
        for &k in SchedulerKind::all() {
            let s = k.build(&trace, &cfg);
            assert!(!s.name().is_empty());
        }
    }
}
