//! Deadline-aware coflow scheduling: admission control + EDF.
//!
//! DCoflow-style scheduler (after *DCoflow: coflow scheduling with
//! deadlines in the cloud*, arXiv 2205.01229; deadline-met evaluation
//! methodology per Qiu/Stein/Zhong, arXiv 1603.07981). Where every other
//! policy in this crate minimizes average CCT, this one maximizes the
//! **deadline-met ratio** of SLO-carrying coflows:
//!
//! 1. **Admission control** — on arrival, a deadline coflow is feasibility
//!    tested against the *remaining reservable capacity* of every port it
//!    touches: finishing `bytes_p` through port `p` by deadline `D` needs a
//!    sustained rate of `bytes_p / (D − now)`, and the test admits iff that
//!    rate fits under the port capacity minus the rates already reserved by
//!    admitted, unfinished coflows. On admit, the rates are **reserved**
//!    (the coflow's feasibility certificate); later arrivals can only claim
//!    what is left, so an admission can never invalidate an earlier one —
//!    `rust/tests/deadline_admission.rs` property-tests that certificate.
//! 2. **EDF among admitted** — admitted coflows are ordered
//!    earliest-deadline-first, ties broken by **laxity** (admission-time
//!    slack minus the coflow's ideal bottleneck CCT — the coflow with less
//!    room to spare goes first), then FIFO. Rate allocation stays the
//!    greedy work-conserving max-min of [`super::rate`], which front-loads
//!    each admitted coflow at least as fast as its reserved constant-rate
//!    schedule.
//! 3. **Rejection / expiry → background** — a coflow that fails the test
//!    is *rejected up front* and scheduled at background priority (behind
//!    every admitted and best-effort coflow), so it can only soak up
//!    leftover capacity and never delays an admitted coflow; an admitted
//!    coflow that nevertheless misses its deadline is *expired*: its
//!    reservation is released and it drops to the same background lane.
//!    Best-effort coflows (no deadline) are admitted without a
//!    reservation and run after all SLO coflows in FIFO order, so on a
//!    deadline-free trace this scheduler degenerates to FIFO.
//!
//! Reservations are released when a coflow completes, expires, or is
//! migrated away ([`Scheduler::on_coflow_detach`]); a migrated-in coflow is
//! re-admitted from its *remaining* bytes and slack
//! ([`Scheduler::on_coflow_attach`]), so cluster migration keeps the
//! certificate meaningful on the new shard. Note that under
//! multi-coordinator sharding each shard admission-tests against the full
//! fabric capacity while allocating within its lease — conservative
//! deployments should budget headroom (looser tightness); lease-aware
//! admission is a ROADMAP follow-on.
//!
//! Like SEBF/SCF, this is a **clairvoyant** policy: the admission test
//! reads true remaining flow sizes (DCoflow assumes known volumes). The
//! sampling question — whether Philae-style learned sizes can drive the
//! same admission test — is exactly what `benches/bench_deadline.rs`
//! probes by sweeping deadline tightness across this scheduler and the
//! deadline-blind family.
//!
//! Ordering is rebuilt per reallocation into reused scratch buffers (the
//! SEBF/SCF regime: zero steady-state allocation, no incremental repair —
//! the admitted set changes on every admission/expiry anyway);
//! `order_full_into` is therefore identical to `order_into` by
//! construction.

use super::{AdmissionStats, OrderEntry, Plan, Reaction, Scheduler, World};
use crate::util::JsonValue;
use crate::{Bytes, CoflowId, FlowId, PortId, Time, EPS};

/// Where a coflow stands with the admission controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionState {
    /// Not yet seen by the admission test.
    #[default]
    Unknown,
    /// Best-effort (no deadline): scheduled after all SLO coflows, FIFO.
    BestEffort,
    /// Deadline coflow that passed the feasibility test (reservation held
    /// until completion, expiry, or migration).
    Admitted,
    /// Deadline coflow rejected up front (background lane).
    Rejected,
    /// Admitted coflow that missed its deadline (demoted to background).
    Expired,
}

fn state_str(s: AdmissionState) -> &'static str {
    match s {
        AdmissionState::Unknown => "unknown",
        AdmissionState::BestEffort => "best-effort",
        AdmissionState::Admitted => "admitted",
        AdmissionState::Rejected => "rejected",
        AdmissionState::Expired => "expired",
    }
}

fn state_from_str(s: &str) -> Option<AdmissionState> {
    match s {
        "unknown" => Some(AdmissionState::Unknown),
        "best-effort" => Some(AdmissionState::BestEffort),
        "admitted" => Some(AdmissionState::Admitted),
        "rejected" => Some(AdmissionState::Rejected),
        "expired" => Some(AdmissionState::Expired),
        _ => None,
    }
}

/// Relative tolerance of the per-port feasibility comparison (reservation
/// sums accumulate float dust as coflows come and go).
const RESERVE_SLACK: f64 = 1e-9;

pub struct DcoflowScheduler {
    /// Schedule rejected/expired coflows at background priority (the
    /// default, work-conserving). `false` drops them from the plan
    /// entirely — the property-test hook proving rejected coflows never
    /// block admitted ones.
    background: bool,
    /// Per-coflow admission state.
    state: Vec<AdmissionState>,
    /// Admission-time laxity (slack − ideal CCT), the EDF tie-break.
    laxity: Vec<f64>,
    /// When a coflow entered the background lane (rejection or expiry
    /// time; `+∞` = not in background). Drives the aging valve.
    bg_since: Vec<Time>,
    /// Background aging valve: a rejected/expired coflow waiting longer
    /// than this jumps to an express lane **ahead of EDF** (FIFO by entry
    /// time), so the background lane cannot be starved indefinitely. Large
    /// by default — a rare safety valve, mirroring Philae's
    /// `age_threshold`, not a scheduling feature.
    bg_age_threshold: Time,
    /// Reserved rate per uplink/downlink across admitted coflows.
    reserved_up: Vec<f64>,
    reserved_down: Vec<f64>,
    /// Per-coflow committed reservations (released exactly once).
    res_up: Vec<Vec<(PortId, f64)>>,
    res_down: Vec<Vec<(PortId, f64)>>,
    /// Admitted coflows with live reservations (completion/expiry watch).
    tracked: Vec<CoflowId>,
    admitted: u64,
    rejected: u64,
    expired: u64,
    /// Reused per-admission port-aggregation tables: dense per-port byte
    /// sums plus touched lists for O(flows) reset (the
    /// `Trace::assign_deadlines` pattern — no per-flow linear scans on
    /// wide coflows).
    acc_up: Vec<Bytes>,
    acc_down: Vec<Bytes>,
    touched_up: Vec<PortId>,
    touched_down: Vec<PortId>,
    /// Reused order buffers: (deadline, laxity, seq, cid) EDF lane,
    /// (seq, cid) background lane, and the (bg_since, seq, cid) aged
    /// express lane the aging valve promotes into.
    edf: Vec<(f64, f64, u64, CoflowId)>,
    bg: Vec<(u64, CoflowId)>,
    bg_aged: Vec<(f64, u64, CoflowId)>,
}

impl Default for DcoflowScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl DcoflowScheduler {
    pub fn new() -> Self {
        DcoflowScheduler {
            background: true,
            state: Vec::new(),
            laxity: Vec::new(),
            bg_since: Vec::new(),
            bg_age_threshold: 3600.0,
            reserved_up: Vec::new(),
            reserved_down: Vec::new(),
            res_up: Vec::new(),
            res_down: Vec::new(),
            tracked: Vec::new(),
            admitted: 0,
            rejected: 0,
            expired: 0,
            acc_up: Vec::new(),
            acc_down: Vec::new(),
            touched_up: Vec::new(),
            touched_down: Vec::new(),
            edf: Vec::new(),
            bg: Vec::new(),
            bg_aged: Vec::new(),
        }
    }

    /// Disable the background lane: rejected/expired coflows are dropped
    /// from the plan instead of backfilling leftovers (test hook — see the
    /// module docs).
    pub fn without_background(mut self) -> Self {
        self.background = false;
        self
    }

    /// Override the background aging valve threshold (seconds of waiting
    /// in the background lane before a coflow is promoted ahead of EDF).
    pub fn with_bg_age_threshold(mut self, threshold: Time) -> Self {
        self.bg_age_threshold = threshold;
        self
    }

    /// Admission state of `cid`.
    pub fn status_of(&self, cid: CoflowId) -> AdmissionState {
        self.state.get(cid).copied().unwrap_or_default()
    }

    /// Rate currently reserved on uplink `p` by admitted coflows.
    pub fn reserved_up(&self, p: PortId) -> f64 {
        self.reserved_up.get(p).copied().unwrap_or(0.0)
    }

    /// Rate currently reserved on downlink `p` by admitted coflows.
    pub fn reserved_down(&self, p: PortId) -> f64 {
        self.reserved_down.get(p).copied().unwrap_or(0.0)
    }

    fn ensure(&mut self, cid: CoflowId) {
        if cid >= self.state.len() {
            self.state.resize(cid + 1, AdmissionState::Unknown);
            self.laxity.resize(cid + 1, f64::INFINITY);
            self.bg_since.resize(cid + 1, f64::INFINITY);
            self.res_up.resize(cid + 1, Vec::new());
            self.res_down.resize(cid + 1, Vec::new());
        }
    }

    fn ensure_ports(&mut self, np: usize) {
        if self.reserved_up.len() < np {
            self.reserved_up.resize(np, 0.0);
            self.reserved_down.resize(np, 0.0);
            self.acc_up.resize(np, 0.0);
            self.acc_down.resize(np, 0.0);
        }
    }

    /// Release `cid`'s reservation (idempotent: the per-coflow lists are
    /// cleared on first release, keeping their capacity).
    fn release(&mut self, cid: CoflowId) {
        for i in 0..self.res_up[cid].len() {
            let (p, r) = self.res_up[cid][i];
            self.reserved_up[p] = (self.reserved_up[p] - r).max(0.0);
        }
        self.res_up[cid].clear();
        for i in 0..self.res_down[cid].len() {
            let (p, r) = self.res_down[cid][i];
            self.reserved_down[p] = (self.reserved_down[p] - r).max(0.0);
        }
        self.res_down[cid].clear();
    }

    /// Sweep tracked reservations: release completed coflows (counting a
    /// late finish as expired) and demote admitted coflows whose deadline
    /// passed without completion.
    fn purge(&mut self, world: &World) {
        let mut i = 0;
        while i < self.tracked.len() {
            let cid = self.tracked[i];
            let c = &world.coflows[cid];
            if c.done() {
                self.release(cid);
                if c.met_deadline() == Some(false) {
                    self.state[cid] = AdmissionState::Expired;
                    self.expired += 1;
                }
                self.tracked.swap_remove(i);
            } else if c.deadline.is_some_and(|d| world.now > d + EPS) {
                self.release(cid);
                self.state[cid] = AdmissionState::Expired;
                self.expired += 1;
                self.bg_since[cid] = world.now;
                self.tracked.swap_remove(i);
            } else {
                i += 1;
            }
        }
    }

    /// Run the admission test for a coflow not yet classified. Reads the
    /// coflow's *remaining* bytes (so re-admission after a migration uses
    /// what is actually left) and commits the reservation on success.
    fn consider(&mut self, cid: CoflowId, world: &World) {
        self.ensure(cid);
        self.ensure_ports(world.fabric.num_ports);
        if self.state[cid] != AdmissionState::Unknown {
            return;
        }
        let c = &world.coflows[cid];
        let Some(d) = c.deadline else {
            self.state[cid] = AdmissionState::BestEffort;
            return;
        };
        let slack = d - world.now;
        // dense per-port byte sums, reset in O(touched) afterwards
        for &f in &c.active_list {
            let fl = &world.flows[f];
            let rem = fl.remaining();
            if rem <= 0.0 {
                continue;
            }
            if self.acc_up[fl.src] == 0.0 {
                self.touched_up.push(fl.src);
            }
            self.acc_up[fl.src] += rem;
            if self.acc_down[fl.dst] == 0.0 {
                self.touched_down.push(fl.dst);
            }
            self.acc_down[fl.dst] += rem;
        }
        let mut ideal: Time = 0.0;
        for &p in &self.touched_up {
            ideal = ideal.max(self.acc_up[p] / world.fabric.up_capacity[p].max(1.0));
        }
        for &p in &self.touched_down {
            ideal = ideal.max(self.acc_down[p] / world.fabric.down_capacity[p].max(1.0));
        }
        let feasible = slack > EPS
            && self.touched_up.iter().all(|&p| {
                self.reserved_up[p] + self.acc_up[p] / slack
                    <= world.fabric.up_capacity[p] * (1.0 + RESERVE_SLACK)
            })
            && self.touched_down.iter().all(|&p| {
                self.reserved_down[p] + self.acc_down[p] / slack
                    <= world.fabric.down_capacity[p] * (1.0 + RESERVE_SLACK)
            });
        if feasible {
            for i in 0..self.touched_up.len() {
                let p = self.touched_up[i];
                let r = self.acc_up[p] / slack;
                self.reserved_up[p] += r;
                self.res_up[cid].push((p, r));
            }
            for i in 0..self.touched_down.len() {
                let p = self.touched_down[i];
                let r = self.acc_down[p] / slack;
                self.reserved_down[p] += r;
                self.res_down[cid].push((p, r));
            }
            self.laxity[cid] = slack - ideal;
            self.state[cid] = AdmissionState::Admitted;
            self.tracked.push(cid);
            self.admitted += 1;
        } else {
            self.state[cid] = AdmissionState::Rejected;
            self.rejected += 1;
            self.bg_since[cid] = world.now;
        }
        // reset the dense tables for the next admission
        for i in 0..self.touched_up.len() {
            let p = self.touched_up[i];
            self.acc_up[p] = 0.0;
        }
        self.touched_up.clear();
        for i in 0..self.touched_down.len() {
            let p = self.touched_down[i];
            self.acc_down[p] = 0.0;
        }
        self.touched_down.clear();
    }
}

impl Scheduler for DcoflowScheduler {
    fn name(&self) -> String {
        "dcoflow".into()
    }

    fn admission_stats(&self) -> Option<AdmissionStats> {
        Some(AdmissionStats {
            admitted: self.admitted,
            rejected: self.rejected,
            expired: self.expired,
        })
    }

    fn on_arrival(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.purge(world);
        self.consider(cid, world);
        Reaction::Reallocate
    }

    fn on_flow_complete(&mut self, _fid: FlowId, _world: &mut World) -> Reaction {
        // completion frees port capacity for lower-priority lanes; the
        // reservation sweep runs in `order_into` right before reallocation
        Reaction::Reallocate
    }

    fn on_coflow_complete(&mut self, _cid: CoflowId, world: &mut World) -> Reaction {
        self.purge(world);
        Reaction::Reallocate
    }

    /// Cluster migration away: hand the reservation back and forget the
    /// verdict so the adopting shard re-runs admission from the coflow's
    /// remaining bytes.
    fn on_coflow_detach(&mut self, cid: CoflowId, _world: &mut World) -> Reaction {
        self.ensure(cid);
        self.release(cid);
        if let Some(i) = self.tracked.iter().position(|&x| x == cid) {
            self.tracked.swap_remove(i);
        }
        self.state[cid] = AdmissionState::Unknown;
        self.bg_since[cid] = f64::INFINITY;
        Reaction::Reallocate
    }

    /// Cluster migration in: re-admit from remaining bytes and remaining
    /// slack against this shard's reservation book.
    fn on_coflow_attach(&mut self, cid: CoflowId, world: &mut World) -> Reaction {
        self.ensure(cid);
        self.state[cid] = AdmissionState::Unknown;
        self.bg_since[cid] = f64::INFINITY;
        self.purge(world);
        self.consider(cid, world);
        Reaction::Reallocate
    }

    /// EDF plan over the admitted set, best-effort FIFO behind it, then
    /// the background lane (rejected + expired, FIFO). Rebuilt per call
    /// into reused buffers — zero steady-state allocation; identical to
    /// `order_full_into` by construction.
    ///
    /// The aging valve runs first: a background coflow waiting past
    /// `bg_age_threshold` is promoted to an express lane **ahead of EDF**
    /// (FIFO by background-entry time), bounding background starvation by
    /// the threshold. Admitted reservations are rate certificates, not
    /// priorities — a promoted coflow briefly outranking EDF delays but
    /// cannot revoke an admission, the same trade Philae's express lane
    /// makes against SJF.
    fn order_into(&mut self, world: &World, plan: &mut Plan) {
        self.purge(world);
        self.edf.clear();
        self.bg.clear();
        self.bg_aged.clear();
        for idx in 0..world.active.len() {
            let cid = world.active[idx];
            let c = &world.coflows[cid];
            if c.done() {
                continue;
            }
            self.consider(cid, world); // no-op for already-classified coflows
            match self.state[cid] {
                AdmissionState::Admitted => {
                    let d = c.deadline.unwrap_or(f64::INFINITY);
                    self.edf.push((d, self.laxity[cid], c.seq, cid));
                }
                AdmissionState::BestEffort => {
                    self.edf.push((f64::INFINITY, f64::INFINITY, c.seq, cid));
                }
                AdmissionState::Rejected | AdmissionState::Expired => {
                    if world.now - self.bg_since[cid] >= self.bg_age_threshold {
                        self.bg_aged.push((self.bg_since[cid], c.seq, cid));
                    } else {
                        self.bg.push((c.seq, cid));
                    }
                }
                AdmissionState::Unknown => unreachable!("consider() classifies every coflow"),
            }
        }
        self.edf.sort_unstable_by(|a, b| {
            a.0.total_cmp(&b.0)
                .then(a.1.total_cmp(&b.1))
                .then(a.2.cmp(&b.2))
                .then(a.3.cmp(&b.3))
        });
        self.bg.sort_unstable();
        self.bg_aged
            .sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
        plan.clear();
        if self.background {
            plan.entries
                .extend(self.bg_aged.iter().map(|&(_, _, cid)| OrderEntry::all(cid)));
        }
        plan.entries
            .extend(self.edf.iter().map(|&(_, _, _, cid)| OrderEntry::all(cid)));
        if self.background {
            plan.entries
                .extend(self.bg.iter().map(|&(_, cid)| OrderEntry::all(cid)));
        }
    }

    /// Durable facts: every verdict, laxity, background-entry stamp, and
    /// committed per-port reservation, plus the tracked set and the
    /// admission counters. The reservation book (`reserved_up/down`) is
    /// not serialized — it is the sum of the per-coflow commitments and is
    /// rebuilt on import.
    fn export_state(&self) -> JsonValue {
        use super::recovery::f64_to_json;
        let res_list = |v: &[(PortId, f64)]| {
            JsonValue::Array(
                v.iter()
                    .map(|&(p, r)| {
                        JsonValue::Array(vec![JsonValue::Number(p as f64), f64_to_json(r)])
                    })
                    .collect(),
            )
        };
        let mut per = std::collections::BTreeMap::new();
        for cid in 0..self.state.len() {
            let mut e = std::collections::BTreeMap::new();
            e.insert(
                "state".to_string(),
                JsonValue::String(state_str(self.state[cid]).to_string()),
            );
            e.insert("laxity".to_string(), f64_to_json(self.laxity[cid]));
            e.insert("bg_since".to_string(), f64_to_json(self.bg_since[cid]));
            e.insert("res_up".to_string(), res_list(&self.res_up[cid]));
            e.insert("res_down".to_string(), res_list(&self.res_down[cid]));
            per.insert(cid.to_string(), JsonValue::Object(e));
        }
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("coflows".to_string(), JsonValue::Object(per));
        doc.insert(
            "tracked".to_string(),
            JsonValue::Array(self.tracked.iter().map(|&c| JsonValue::Number(c as f64)).collect()),
        );
        doc.insert("admitted".to_string(), JsonValue::Number(self.admitted as f64));
        doc.insert("rejected".to_string(), JsonValue::Number(self.rejected as f64));
        doc.insert("expired".to_string(), JsonValue::Number(self.expired as f64));
        JsonValue::Object(doc)
    }

    /// Exact restores overwrite the whole admission book (undoing the
    /// attach path's re-admission verdicts and reservation float dust) and
    /// rebuild `reserved_up/down` from the per-coflow commitments.
    ///
    /// Stale restores merge back **only the SLO certificate**: a coflow
    /// the checkpoint had admitted with a live reservation is re-instated
    /// as admitted with its checkpointed (larger — computed from more
    /// remaining bytes) reservation if the attach re-admission came to a
    /// different verdict. Over-reservation is conservative: it can only
    /// make later admission tests stricter, never invalidate an earlier
    /// certificate. Everything else (fresh verdicts, counters) keeps the
    /// attach-derived state.
    fn import_state(&mut self, state: &JsonValue, world: &World, exact: bool) {
        use super::recovery::f64_from_json;
        let parse_res = |v: Option<&JsonValue>| -> Vec<(PortId, f64)> {
            v.and_then(|v| v.as_array())
                .map(|items| {
                    items
                        .iter()
                        .filter_map(|pair| {
                            let pair = pair.as_array()?;
                            let p = pair.first()?.as_usize()?;
                            let r = f64_from_json(pair.get(1)?)?;
                            Some((p, r))
                        })
                        .collect()
                })
                .unwrap_or_default()
        };
        self.ensure_ports(world.fabric.num_ports);
        let tracked: Vec<CoflowId> = state
            .get("tracked")
            .and_then(|v| v.as_array())
            .map(|items| items.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default();
        if exact {
            self.state.clear();
            self.laxity.clear();
            self.bg_since.clear();
            self.res_up.clear();
            self.res_down.clear();
            self.tracked.clear();
            for r in self.reserved_up.iter_mut().chain(self.reserved_down.iter_mut()) {
                *r = 0.0;
            }
            if let Some(per) = state.get("coflows").and_then(|v| v.as_object()) {
                for (key, e) in per {
                    let Ok(cid) = key.parse::<CoflowId>() else {
                        continue;
                    };
                    self.ensure(cid);
                    let st = e.get("state").and_then(|v| v.as_str());
                    if let Some(s) = st.and_then(state_from_str) {
                        self.state[cid] = s;
                    }
                    if let Some(l) = e.get("laxity").and_then(f64_from_json) {
                        self.laxity[cid] = l;
                    }
                    if let Some(b) = e.get("bg_since").and_then(f64_from_json) {
                        self.bg_since[cid] = b;
                    }
                    self.res_up[cid] = parse_res(e.get("res_up"));
                    self.res_down[cid] = parse_res(e.get("res_down"));
                    for &(p, r) in &self.res_up[cid] {
                        if p < self.reserved_up.len() {
                            self.reserved_up[p] += r;
                        }
                    }
                    for &(p, r) in &self.res_down[cid] {
                        if p < self.reserved_down.len() {
                            self.reserved_down[p] += r;
                        }
                    }
                }
            }
            self.tracked = tracked;
            if let Some(x) = state.get("admitted").and_then(|v| v.as_f64()) {
                self.admitted = x as u64;
            }
            if let Some(x) = state.get("rejected").and_then(|v| v.as_f64()) {
                self.rejected = x as u64;
            }
            if let Some(x) = state.get("expired").and_then(|v| v.as_f64()) {
                self.expired = x as u64;
            }
            return;
        }
        // stale merge: re-instate checkpointed admissions only
        let Some(per) = state.get("coflows").and_then(|v| v.as_object()) else {
            return;
        };
        for &cid in &tracked {
            if cid >= world.coflows.len() || world.coflows[cid].done() {
                continue; // departed since the checkpoint
            }
            let Some(e) = per.get(&cid.to_string()) else {
                continue;
            };
            if e.get("state").and_then(|v| v.as_str()).and_then(state_from_str)
                != Some(AdmissionState::Admitted)
            {
                continue;
            }
            self.ensure(cid);
            if self.state[cid] == AdmissionState::Admitted {
                continue; // attach re-admitted it; its fresh certificate stands
            }
            self.release(cid); // idempotent; non-admitted coflows hold none
            self.res_up[cid] = parse_res(e.get("res_up"));
            self.res_down[cid] = parse_res(e.get("res_down"));
            for &(p, r) in &self.res_up[cid] {
                if p < self.reserved_up.len() {
                    self.reserved_up[p] += r;
                }
            }
            for &(p, r) in &self.res_down[cid] {
                if p < self.reserved_down.len() {
                    self.reserved_down[p] += r;
                }
            }
            if let Some(l) = e.get("laxity").and_then(f64_from_json) {
                self.laxity[cid] = l;
            }
            self.state[cid] = AdmissionState::Admitted;
            self.bg_since[cid] = f64::INFINITY;
            if !self.tracked.contains(&cid) {
                self.tracked.push(cid);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{CoflowState, FlowState};
    use crate::fabric::{Fabric, PortLoad};

    /// World with one flow per coflow: (src, dst, size, deadline).
    fn world_with(defs: &[(usize, usize, f64, Option<f64>)]) -> World {
        let mut flows = Vec::new();
        let mut coflows = Vec::new();
        for (cid, &(src, dst, size, deadline)) in defs.iter().enumerate() {
            flows.push(FlowState::new(cid, cid, src, dst, size));
            let mut c = CoflowState::new(cid, 0.0, vec![cid], size, cid as u64);
            c.deadline = deadline;
            c.senders = vec![src];
            c.receivers = vec![dst];
            coflows.push(c);
        }
        World {
            now: 0.0,
            flows,
            coflows,
            fabric: Fabric::homogeneous(4, 100.0),
            load: PortLoad::new(4),
            active: (0..defs.len()).collect(),
        }
    }

    fn arrive_all(s: &mut DcoflowScheduler, w: &mut World) {
        for cid in 0..w.coflows.len() {
            s.on_arrival(cid, w);
        }
    }

    #[test]
    fn admits_while_reservations_fit_then_rejects() {
        // port capacity 100; A needs 80/1s = 80, B needs 50/2s = 25:
        // 80 + 25 > 100 on the shared uplink → B rejected
        let mut w = world_with(&[
            (0, 1, 80.0, Some(1.0)),
            (0, 2, 50.0, Some(2.0)),
            (2, 3, 50.0, Some(2.0)), // disjoint ports: admitted
        ]);
        let mut s = DcoflowScheduler::new();
        arrive_all(&mut s, &mut w);
        assert_eq!(s.status_of(0), AdmissionState::Admitted);
        assert_eq!(s.status_of(1), AdmissionState::Rejected);
        assert_eq!(s.status_of(2), AdmissionState::Admitted);
        assert!((s.reserved_up(0) - 80.0).abs() < 1e-9);
        let stats = s.admission_stats().unwrap();
        assert_eq!((stats.admitted, stats.rejected, stats.expired), (2, 1, 0));
    }

    #[test]
    fn edf_orders_admitted_before_best_effort_before_background() {
        let mut w = world_with(&[
            (0, 1, 10.0, None),            // best-effort, seq 0
            (1, 2, 10.0, Some(5.0)),       // admitted, later deadline
            (2, 3, 10.0, Some(2.0)),       // admitted, earliest deadline
            (0, 2, 1000.0, Some(0.00001)), // infeasible → rejected
        ]);
        let mut s = DcoflowScheduler::new();
        arrive_all(&mut s, &mut w);
        let plan = s.order(&w);
        let order: Vec<_> = plan.entries.iter().map(|e| e.coflow).collect();
        assert_eq!(order, vec![2, 1, 0, 3]);
    }

    #[test]
    fn laxity_breaks_deadline_ties() {
        // same deadline; coflow 1 has more bytes → smaller laxity → first
        let mut w = world_with(&[(0, 1, 10.0, Some(4.0)), (2, 3, 200.0, Some(4.0))]);
        let mut s = DcoflowScheduler::new();
        arrive_all(&mut s, &mut w);
        let plan = s.order(&w);
        assert_eq!(plan.entries[0].coflow, 1);
        assert_eq!(plan.entries[1].coflow, 0);
    }

    #[test]
    fn expiry_demotes_once_and_releases_the_reservation() {
        let mut w = world_with(&[(0, 1, 80.0, Some(1.0))]);
        let mut s = DcoflowScheduler::new();
        arrive_all(&mut s, &mut w);
        assert!((s.reserved_up(0) - 80.0).abs() < 1e-9);
        w.now = 2.0; // deadline passed, coflow unfinished
        let plan = s.order(&w);
        assert_eq!(s.status_of(0), AdmissionState::Expired);
        assert_eq!(s.admission_stats().unwrap().expired, 1);
        assert_eq!(s.reserved_up(0), 0.0, "expiry must free the reservation");
        // still scheduled, at background priority
        assert_eq!(plan.entries.len(), 1);
        // a second sweep must not double-count
        let _ = s.order(&w);
        assert_eq!(s.admission_stats().unwrap().expired, 1);
    }

    #[test]
    fn completion_releases_and_late_finish_counts_expired() {
        let mut w = world_with(&[(0, 1, 80.0, Some(1.0)), (2, 3, 80.0, Some(1.0))]);
        let mut s = DcoflowScheduler::new();
        arrive_all(&mut s, &mut w);
        // coflow 0 finishes in time; coflow 1 finishes late
        w.now = 0.9;
        for (cid, t) in [(0usize, 0.9), (1usize, 1.5)] {
            w.flows[cid].sent = w.flows[cid].size;
            w.flows[cid].finished_at = Some(t);
            w.coflows[cid].active_list.clear();
            w.coflows[cid].active_flows = 0;
            w.coflows[cid].finished_at = Some(t);
        }
        w.active.clear();
        s.on_coflow_complete(0, &mut w);
        s.on_coflow_complete(1, &mut w);
        assert_eq!(s.status_of(0), AdmissionState::Admitted); // met
        assert_eq!(s.status_of(1), AdmissionState::Expired); // late
        assert_eq!(s.reserved_up(0), 0.0);
        assert_eq!(s.reserved_up(2), 0.0);
        assert_eq!(s.admission_stats().unwrap().expired, 1);
    }

    #[test]
    fn released_capacity_readmits_later_arrivals() {
        let mut w = world_with(&[
            (0, 1, 80.0, Some(1.0)),
            (0, 2, 80.0, Some(2.0)), // would need 40 on uplink 0: 80+40 > 100
        ]);
        let mut s = DcoflowScheduler::new();
        s.on_arrival(0, &mut w);
        // coflow 0 completes before coflow 1 arrives
        w.flows[0].sent = 80.0;
        w.flows[0].finished_at = Some(0.5);
        w.coflows[0].active_list.clear();
        w.coflows[0].active_flows = 0;
        w.coflows[0].finished_at = Some(0.5);
        w.active.retain(|&c| c != 0);
        w.now = 0.5;
        s.on_arrival(1, &mut w);
        assert_eq!(s.status_of(1), AdmissionState::Admitted);
    }

    #[test]
    fn detach_then_attach_readmits_from_remaining_bytes() {
        let mut w = world_with(&[(0, 1, 80.0, Some(1.0))]);
        let mut s = DcoflowScheduler::new();
        arrive_all(&mut s, &mut w);
        s.on_coflow_detach(0, &mut w);
        assert_eq!(s.status_of(0), AdmissionState::Unknown);
        assert_eq!(s.reserved_up(0), 0.0);
        // half the bytes moved; re-admission reserves remaining/slack
        w.flows[0].sent = 40.0;
        w.now = 0.5;
        let mut t = DcoflowScheduler::new();
        t.on_coflow_attach(0, &mut w);
        assert_eq!(t.status_of(0), AdmissionState::Admitted);
        assert!((t.reserved_up(0) - 40.0 / 0.5).abs() < 1e-9);
    }

    #[test]
    fn without_background_drops_rejected_from_the_plan() {
        let mut w = world_with(&[
            (0, 1, 80.0, Some(1.0)),
            (0, 2, 1000.0, Some(1.0)), // rejected
        ]);
        let mut s = DcoflowScheduler::new().without_background();
        arrive_all(&mut s, &mut w);
        let plan = s.order(&w);
        assert_eq!(plan.entries.len(), 1);
        assert_eq!(plan.entries[0].coflow, 0);
    }

    #[test]
    fn aging_valve_bounds_background_starvation() {
        // coflow 0 holds a comfortable admission; coflow 1 is infeasible
        // and lands in the background lane at t = 0
        let defs = [
            (0, 1, 80.0, Some(100.0)),
            (0, 2, 1000.0, Some(0.00001)),
        ];
        let mut w = world_with(&defs);
        let mut s = DcoflowScheduler::new().with_bg_age_threshold(10.0);
        arrive_all(&mut s, &mut w);
        assert_eq!(s.status_of(1), AdmissionState::Rejected);
        // below the threshold: background stays behind the admitted lane
        w.now = 5.0;
        let plan = s.order(&w);
        let order: Vec<_> = plan.entries.iter().map(|e| e.coflow).collect();
        assert_eq!(order, vec![0, 1]);
        // past the threshold: promoted ahead of EDF — waiting is bounded
        // by the valve, so the background lane cannot starve indefinitely
        w.now = 10.0;
        let plan = s.order(&w);
        let order: Vec<_> = plan.entries.iter().map(|e| e.coflow).collect();
        assert_eq!(order, vec![1, 0]);
        // the admission certificate survives the promotion
        assert_eq!(s.status_of(0), AdmissionState::Admitted);
        assert!((s.reserved_up(0) - 0.8).abs() < 1e-9);
        // the default threshold is a rare safety valve: same scenario, no
        // promotion within any plausible simulated horizon
        let mut w2 = world_with(&defs);
        let mut d = DcoflowScheduler::new();
        arrive_all(&mut d, &mut w2);
        w2.now = 10.0;
        let plan = d.order(&w2);
        let order: Vec<_> = plan.entries.iter().map(|e| e.coflow).collect();
        assert_eq!(order, vec![0, 1]);
    }

    #[test]
    fn deadline_free_trace_degenerates_to_fifo() {
        let mut w = world_with(&[(0, 1, 10.0, None), (2, 3, 500.0, None), (1, 2, 1.0, None)]);
        let mut s = DcoflowScheduler::new();
        arrive_all(&mut s, &mut w);
        let plan = s.order(&w);
        let order: Vec<_> = plan.entries.iter().map(|e| e.coflow).collect();
        assert_eq!(order, vec![0, 1, 2], "no SLOs → arrival order");
        let stats = s.admission_stats().unwrap();
        assert_eq!((stats.admitted, stats.rejected, stats.expired), (0, 0, 0));
    }
}
