//! Clairvoyant Shortest-Coflow-First oracle.
//!
//! Knows every coflow's true total size on arrival (the assumption the
//! paper argues is implausible in practice — §1) and always serves the
//! coflow with the least *remaining* bytes. This is the upper-bound policy
//! Philae's sampling approximates; the gap between Philae and SCF is the
//! cost of learning.

use super::{DeadlineMode, OrderEntry, Plan, Reaction, Scheduler, World};
use crate::trace::Trace;
use crate::{Bytes, CoflowId, FlowId};

pub struct ScfScheduler {
    total_bytes: Vec<Bytes>,
    /// SLO handling: `Secondary` uses the coflow deadline as a tie-break
    /// behind remaining size (`Ignore`, the default, is deadline-blind).
    deadline_mode: DeadlineMode,
    /// Reused sort buffer — remaining size moves with every byte sent, so
    /// the order is rebuilt per event but allocation-free in steady state.
    scratch: Vec<(f64, f64, u64, CoflowId)>,
}

impl ScfScheduler {
    pub fn new(trace: &Trace) -> Self {
        let oracles = trace.oracles();
        ScfScheduler {
            total_bytes: oracles.iter().map(|o| o.total_bytes).collect(),
            deadline_mode: DeadlineMode::default(),
            scratch: Vec::new(),
        }
    }

    /// Builder-style [`DeadlineMode`] (default: `Ignore`).
    pub fn with_deadline_mode(mut self, mode: DeadlineMode) -> Self {
        self.deadline_mode = mode;
        self
    }
}

impl Scheduler for ScfScheduler {
    fn name(&self) -> String {
        "scf-oracle".into()
    }

    fn on_arrival(&mut self, _cid: CoflowId, _world: &mut World) -> Reaction {
        Reaction::Reallocate
    }

    fn on_flow_complete(&mut self, _fid: FlowId, _world: &mut World) -> Reaction {
        Reaction::Reallocate
    }

    fn order_into(&mut self, world: &World, plan: &mut Plan) {
        self.scratch.clear();
        for &cid in &world.active {
            let c = &world.coflows[cid];
            if c.done() {
                continue;
            }
            // beyond-trace cids (live-service dynamic registrations) fall
            // back to the world's own total
            let total = self.total_bytes.get(cid).copied().unwrap_or(c.total_bytes);
            let remaining = (total - c.bytes_sent).max(0.0);
            let dk = self.deadline_mode.key(c.deadline);
            self.scratch.push((remaining, dk, c.seq, cid));
        }
        self.scratch.sort_unstable_by(|a, b| {
            a.0.total_cmp(&b.0)
                .then(a.1.total_cmp(&b.1))
                .then(a.2.cmp(&b.2))
        });
        plan.clear();
        plan.entries
            .extend(self.scratch.iter().map(|&(_, _, _, cid)| OrderEntry::all(cid)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Trace, TraceRecord};

    #[test]
    fn shortest_remaining_first() {
        let trace = Trace::from_records(
            4,
            vec![
                TraceRecord::uniform(1, 0.0, vec![0], vec![2], 100.0),
                TraceRecord::uniform(2, 0.0, vec![1], vec![3], 1.0),
            ],
        );
        let mut s = ScfScheduler::new(&trace);
        let mut w = crate::sim::world_from_trace(&trace);
        w.active = vec![0, 1];
        let order = s.order(&w);
        // coflow 1 (1 MB) before coflow 0 (100 MB)
        assert_eq!(order.entries[0].coflow, 1);
        // after coflow 0 sends most of its bytes it jumps ahead
        w.coflows[0].bytes_sent = w.coflows[0].total_bytes - 1.0;
        let order = s.order(&w);
        assert_eq!(order.entries[0].coflow, 0);
    }
}
