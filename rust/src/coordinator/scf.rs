//! Clairvoyant Shortest-Coflow-First oracle.
//!
//! Knows every coflow's true total size on arrival (the assumption the
//! paper argues is implausible in practice — §1) and always serves the
//! coflow with the least *remaining* bytes. This is the upper-bound policy
//! Philae's sampling approximates; the gap between Philae and SCF is the
//! cost of learning.
//!
//! Sizes come from [`crate::coflow::CoflowState::total_bytes`] in the
//! world, not a trace-indexed table, so the scheduler works unchanged on
//! the streaming engine path. Like `sebf.rs`, the sorted order is carried
//! across calls with refreshed keys and re-sorted only when an O(n)
//! sortedness scan finds an inversion; the emitted plan is a pure function
//! of the world, so the carried state is self-healing after a restore.

use super::{DeadlineMode, OrderEntry, Plan, Reaction, Scheduler, World};
use crate::trace::Trace;
use crate::{CoflowId, FlowId};

/// `(remaining, deadline key, seq, coflow)` — seq-unique, deterministic
/// under unstable sort.
type Entry = (f64, f64, u64, CoflowId);

#[inline]
fn cmp_entry(a: &Entry, b: &Entry) -> std::cmp::Ordering {
    a.0.total_cmp(&b.0)
        .then(a.1.total_cmp(&b.1))
        .then(a.2.cmp(&b.2))
}

pub struct ScfScheduler {
    /// SLO handling: `Secondary` uses the coflow deadline as a tie-break
    /// behind remaining size (`Ignore`, the default, is deadline-blind).
    deadline_mode: DeadlineMode,
    /// Sorted order carried across calls (keys refreshed per call).
    cached: Vec<Entry>,
    /// Epoch-stamped membership (`epoch` = active, `epoch + 1` = carried);
    /// +2 stride, never cleared.
    stamp: Vec<u64>,
    epoch: u64,
}

impl ScfScheduler {
    /// The trace parameter is kept for constructor-signature stability;
    /// all scheduling state now comes from the world.
    pub fn new(_trace: &Trace) -> Self {
        ScfScheduler {
            deadline_mode: DeadlineMode::default(),
            cached: Vec::new(),
            stamp: Vec::new(),
            epoch: 0,
        }
    }

    /// Builder-style [`DeadlineMode`] (default: `Ignore`).
    pub fn with_deadline_mode(mut self, mode: DeadlineMode) -> Self {
        self.deadline_mode = mode;
        self
    }
}

impl Scheduler for ScfScheduler {
    fn name(&self) -> String {
        "scf-oracle".into()
    }

    fn on_arrival(&mut self, _cid: CoflowId, _world: &mut World) -> Reaction {
        Reaction::Reallocate
    }

    fn on_flow_complete(&mut self, _fid: FlowId, _world: &mut World) -> Reaction {
        Reaction::Reallocate
    }

    fn order_into(&mut self, world: &World, plan: &mut Plan) {
        self.epoch += 2;
        let e = self.epoch;
        if self.stamp.len() < world.coflows.len() {
            self.stamp.resize(world.coflows.len(), 0);
        }
        for &cid in &world.active {
            if !world.coflows[cid].done() {
                self.stamp[cid] = e;
            }
        }
        let stamp = &mut self.stamp;
        let dm = &self.deadline_mode;
        self.cached.retain_mut(|entry| {
            let cid = entry.3;
            if stamp[cid] != e {
                return false;
            }
            let c = &world.coflows[cid];
            entry.0 = (c.total_bytes - c.bytes_sent).max(0.0);
            entry.1 = dm.key(c.deadline);
            stamp[cid] = e + 1;
            true
        });
        for &cid in &world.active {
            if self.stamp[cid] == e {
                let c = &world.coflows[cid];
                self.cached.push((
                    (c.total_bytes - c.bytes_sent).max(0.0),
                    self.deadline_mode.key(c.deadline),
                    c.seq,
                    cid,
                ));
                self.stamp[cid] = e + 1;
            }
        }
        let sorted = self
            .cached
            .windows(2)
            .all(|w| cmp_entry(&w[0], &w[1]) != std::cmp::Ordering::Greater);
        if !sorted {
            self.cached.sort_unstable_by(cmp_entry);
        }
        plan.clear();
        plan.entries
            .extend(self.cached.iter().map(|&(_, _, _, cid)| OrderEntry::all(cid)));
    }

    fn order_full_into(&mut self, world: &World, plan: &mut Plan) {
        self.cached.clear();
        self.order_into(world, plan);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Trace, TraceRecord};

    #[test]
    fn shortest_remaining_first() {
        let trace = Trace::from_records(
            4,
            vec![
                TraceRecord::uniform(1, 0.0, vec![0], vec![2], 100.0),
                TraceRecord::uniform(2, 0.0, vec![1], vec![3], 1.0),
            ],
        );
        let mut s = ScfScheduler::new(&trace);
        let mut w = crate::sim::world_from_trace(&trace);
        w.active = vec![0, 1];
        let order = s.order(&w);
        // coflow 1 (1 MB) before coflow 0 (100 MB)
        assert_eq!(order.entries[0].coflow, 1);
        // after coflow 0 sends most of its bytes it jumps ahead
        w.coflows[0].bytes_sent = w.coflows[0].total_bytes - 1.0;
        let order = s.order(&w);
        assert_eq!(order.entries[0].coflow, 0);
    }

    #[test]
    fn carried_and_fresh_scheduler_agree() {
        let trace = Trace::from_records(
            6,
            vec![
                TraceRecord::uniform(1, 0.0, vec![0], vec![3], 50.0),
                TraceRecord::uniform(2, 0.0, vec![1], vec![4], 10.0),
                TraceRecord::uniform(3, 0.0, vec![2], vec![5], 30.0),
            ],
        );
        let mut carried = ScfScheduler::new(&trace);
        let mut w = crate::sim::world_from_trace(&trace);
        w.active = vec![0, 1, 2];
        let _ = carried.order(&w);
        // progress inverts the order; a departure shrinks it
        w.coflows[0].bytes_sent = 45.0e6;
        w.coflows[1].finished_at = Some(1.0);
        w.active = vec![0, 2];
        let a = carried.order(&w);
        let b = ScfScheduler::new(&trace).order(&w);
        assert_eq!(a.entries, b.entries);
        assert_eq!(a.entries[0].coflow, 0);
    }
}
