//! `philae` — CLI for the coflow-scheduling reproduction.
//!
//! ```text
//! philae sim       --scheduler philae --ports 150 --coflows 526
//! philae compare   --ports 150 --coflows 526 [--baseline aalo --candidate philae]
//! philae serve     --scheduler philae --coflows 60 [--artifacts artifacts]
//! philae obs       archive-dir [--kind sched --csv-out events.csv]
//! philae gen-trace --ports 150 --coflows 526 --out fb_like.txt
//! ```
//!
//! (No clap on this offline image — a small hand-rolled parser below.)

use philae::coordinator::cluster::CoordinatorCluster;
use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::fabric::Fabric;
use philae::metrics::SpeedupRow;
use philae::service::{run_service, ServiceConfig};
use philae::sim::{SimConfig, SimResult, Simulation};
use philae::trace::{DeadlineModel, Trace, TraceSpec};
use std::collections::HashMap;
use std::time::Duration;

const USAGE: &str = "\
philae — sampling-based coflow scheduling (Philae, Jajoo/Hu/Lin 2021)

USAGE:
  philae <sim|compare|serve|explain|obs|gen-trace> [flags]

COMMON FLAGS:
  --trace <file>       load a coflow-benchmark trace instead of generating
  --scenario <name>    generator scenario               [default: fb-like]
                       (fb-like mixed-rate tiny incast all-reduce diurnal
                       adversarial-skew — see docs/SCENARIOS.md)
  --ports <n>          generated trace ports            [default: 150]
  --coflows <n>        generated trace coflows          [default: 526]
  --seed <n>           generator seed                   [default: 42]
  --load <x>           scale arrival rate by x (shrinks inter-arrival gaps)
  --wide-only          keep only wide coflows (Table 2 row 2)
  --replicate <k>      replicate k× across ports (900-port derivation)
  --deadline-tightness <t>  give every coflow an SLO deadline of
                       t × ideal CCT (uniform spread up to 1.5t); the
                       deadline-aware scheduler is `dcoflow`
  --coordinators <k>   coordinator shards with leased capacity  [default: 1]
  --shards <s>         allocator worker shards (sim/serve)      [default: 1]
  --checkpoint-every <n>  coordinator crash-failover: checkpoint the
                       scheduler every n events (sim, K=1: kill+restore at
                       every checkpoint, bit-identical), every n scheduling
                       rounds (sim, K>1), or every n δ intervals (serve)
  --chaos <n>          kill-and-restore a random coordinator shard every n
                       rounds (sim, K>1) / δ intervals (serve)  [default: off]
  --trace-out <file>   flight recorder: write the run's lifecycle events as
                       Chrome trace-event JSON (open in Perfetto or
                       chrome://tracing; sim + serve)
  --metrics-out <file> write the metrics + event-log snapshot (JSON, schema
                       philae.obs.v1 — see docs/OBSERVABILITY.md)
  --archive-dir <dir>  durable obs archive: spool every recorded event to
                       rotated, checksummed segment files under <dir>
                       (bounded memory; replay offline with `philae obs`;
                       sim + serve)
  --heatmap-out <file> per-port utilization heatmap time-series; a .json
                       path writes the philae.obs.heatmap.v1 JSON, anything
                       else the port,dir,bin CSV (sim paths)

sim:      --scheduler <name>                            [default: philae]
          --stream     admit coflows from a bounded-memory arrival stream
                       instead of materializing the trace (scales to 1M+
                       coflows / 10k+ ports; bit-identical results)
          --gap        report the offline CCT lower bound (SRPT relaxation)
                       and this run's optimality gap (materialized only)
compare:  --baseline <name> --candidate <name>          [default: aalo vs philae]
serve:    --scheduler <name> --artifacts <dir> --time-scale <x> --delta-ms <n>
          --checkpoint-dir <dir> --agent-miss <auto|n> --tick-max <ms>
          (accepts every scheduler below; --artifacts drives PJRT, philae
          only; --agent-miss ages silent ports out of the plan — a number
          is a flat threshold in δ intervals, `auto` derives it per port
          from the observed report cadence; a checkpoint-dir holding
          shard_<s>.ckpt seals from a previous run is restored on start;
          --tick-max arms the adaptive tick: δ stretches up to <ms> when
          reallocation work crowds the period and shrinks back when it
          clears, each retarget logged as a tick_adjust event)
explain:  philae explain <cid> [sim flags] — re-run the sim with the
          flight recorder on and print where coflow <cid>'s time went
          (waiting / sampling / scheduled / starved segments + totals)
          philae explain --all [--csv-out <file>] [sim flags] — the same
          decomposition for every coflow at once, as CSV
          (both forms accept --from <archive-dir> to replay a durable
          archive instead of re-running the simulation)
obs:      philae obs <archive-dir> [--kind <event>] [--coflow <cid>]
          [--shard <s>] [--csv-out <file>] [--trace-out <file>]
          offline archive queries: summarize the segments, filter the
          event log, re-export it as CSV or a Chrome trace
gen-trace: --out <file>

schedulers: philae aalo sebf scf fifo saath philae-lcb philae-ec1
            philae-ec-multi dcoflow";

struct Flags {
    map: HashMap<String, String>,
}

impl Flags {
    fn parse(args: &[String]) -> Result<Flags, String> {
        let mut map = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if !a.starts_with("--") {
                return Err(format!("unexpected argument {a:?}"));
            }
            let key = a.trim_start_matches("--").to_string();
            // boolean flags
            if key == "wide-only" || key == "stream" || key == "gap" || key == "all" {
                map.insert(key, "true".into());
                i += 1;
                continue;
            }
            let val = args
                .get(i + 1)
                .ok_or_else(|| format!("flag --{key} needs a value"))?;
            map.insert(key, val.clone());
            i += 2;
        }
        Ok(Flags { map })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| format!("--{key}: {e}")),
        }
    }

    fn get_opt(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }
}

/// The generator spec described by `--scenario/--ports/--coflows/--seed/
/// --load` — shared by the materialized and the streaming paths so both
/// see the exact same arrival process.
fn build_spec(flags: &Flags) -> anyhow::Result<TraceSpec> {
    let ports = flags.get("ports", 150usize).map_err(anyhow::Error::msg)?;
    let coflows = flags.get("coflows", 526usize).map_err(anyhow::Error::msg)?;
    let seed = flags.get("seed", 42u64).map_err(anyhow::Error::msg)?;
    let name = flags.get_opt("scenario").unwrap_or("fb-like");
    let mut spec = TraceSpec::scenario(name, ports, coflows).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown scenario {name:?}; known: {}",
            TraceSpec::scenario_names().join(" ")
        )
    })?;
    if flags.has("seed") {
        spec = spec.seed(seed);
    }
    if let Some(load) = flags.get_opt("load") {
        let load: f64 = load.parse().map_err(|e| anyhow::anyhow!("--load: {e}"))?;
        anyhow::ensure!(
            load > 0.0 && load.is_finite(),
            "--load must be a positive factor, got {load}"
        );
        spec = spec.with_load_factor(load);
    }
    Ok(spec)
}

fn build_trace(flags: &Flags) -> anyhow::Result<Trace> {
    let mut t = match flags.get_opt("trace") {
        Some(path) => Trace::load(path)?,
        None => build_spec(flags)?.generate(),
    };
    if flags.has("wide-only") {
        t = t.wide_only();
    }
    let replicate = flags.get("replicate", 1usize).map_err(anyhow::Error::msg)?;
    if replicate > 1 {
        t = t.replicate(replicate);
    }
    // SLO deadlines (applied last, so wide-only/replicate see them too via
    // the records — or get freshly assigned ones here). Ideal CCTs are
    // computed at the paper's 1 Gbps line rate.
    if let Some(tight) = flags.get_opt("deadline-tightness") {
        let tight: f64 = tight
            .parse()
            .map_err(|e| anyhow::anyhow!("--deadline-tightness: {e}"))?;
        anyhow::ensure!(
            tight > 0.0 && tight.is_finite(),
            "--deadline-tightness must be a positive factor, got {tight}"
        );
        let seed = flags.get("seed", 42u64).map_err(anyhow::Error::msg)?;
        t.assign_deadlines(
            &DeadlineModel::tightness(tight),
            &Fabric::gbps(t.num_ports),
            seed,
        );
    }
    Ok(t)
}

/// Flight-recorder ring capacity (events per shard) when `--trace-out` /
/// `--metrics-out` / `explain` arms the observability plane.
const OBS_RING_DEFAULT: usize = 1 << 16;

/// Events per shard the observability plane should record: the default
/// ring when any obs output flag asks for it, 0 (plane off) otherwise.
fn obs_ring(flags: &Flags) -> usize {
    if flags.has("trace-out")
        || flags.has("metrics-out")
        || flags.has("archive-dir")
        || flags.has("heatmap-out")
    {
        OBS_RING_DEFAULT
    } else {
        0
    }
}

/// `--archive-dir` → the durable spool config threaded into the run.
fn archive_cfg(flags: &Flags) -> Option<philae::obs::ArchiveConfig> {
    flags.get_opt("archive-dir").map(philae::obs::ArchiveConfig::new)
}

/// `--heatmap-out` arms the per-port utilization heatmap (sim paths).
fn heatmap_bins(flags: &Flags) -> usize {
    if flags.has("heatmap-out") {
        philae::obs::heatmap::DEFAULT_BINS
    } else {
        0
    }
}

/// Write `--trace-out` (Chrome trace-event JSON, for Perfetto /
/// chrome://tracing) and `--metrics-out` (`philae.obs.v1` snapshot JSON)
/// from a run's observability snapshot.
fn write_obs_outputs(
    obs: Option<&philae::obs::ObsSnapshot>,
    flags: &Flags,
) -> anyhow::Result<()> {
    if let Some(path) = flags.get_opt("trace-out") {
        let snap =
            obs.ok_or_else(|| anyhow::anyhow!("--trace-out: the run recorded no events"))?;
        std::fs::write(path, snap.chrome_trace_json())?;
        println!(
            "  wrote Chrome trace ({} events kept, {} dropped) to {path}",
            snap.events.len(),
            snap.dropped,
        );
    }
    if let Some(path) = flags.get_opt("metrics-out") {
        let snap =
            obs.ok_or_else(|| anyhow::anyhow!("--metrics-out: the run recorded no events"))?;
        std::fs::write(path, snap.to_json().to_string())?;
        println!("  wrote metrics snapshot (philae.obs.v1) to {path}");
    }
    if let Some(path) = flags.get_opt("heatmap-out") {
        let snap =
            obs.ok_or_else(|| anyhow::anyhow!("--heatmap-out: the run recorded no events"))?;
        let hm = snap.heatmap.as_ref().ok_or_else(|| {
            anyhow::anyhow!("--heatmap-out: this path records no heatmap (sim paths only)")
        })?;
        if path.ends_with(".json") {
            std::fs::write(path, hm.to_json().to_string())?;
        } else {
            std::fs::write(path, hm.to_csv())?;
        }
        println!(
            "  wrote port heatmap ({} ports × {} bins, {}s wide, {} folds) to {path}",
            hm.ports(),
            hm.bins(),
            hm.bin_width(),
            hm.folds(),
        );
    }
    if let Some(a) = obs.and_then(|s| s.archive.as_ref()) {
        println!(
            "  archive: spooled {} = kept {} + dropped_ring {} + dropped_spool {} | {} segment(s), {} bytes, {} io error(s)",
            a.spooled, a.kept, a.dropped_ring, a.dropped_spool, a.segments, a.bytes, a.io_errors,
        );
    }
    Ok(())
}

/// Run one simulation honoring `--coordinators`/`--shards`: K ≥ 2 routes
/// through the multi-coordinator cluster, K = 1 through the single path
/// (the cluster's K=1 is bit-identical, but the direct path skips the
/// frontend indirection entirely). `--checkpoint-every`/`--chaos` arm the
/// crash-failover paths: K = 1 kills and restores the coordinator from a
/// fresh checkpoint at every boundary (pinned bit-identical), K ≥ 2 runs
/// the cluster chaos driver (periodic checkpoint + random shard kills).
fn run_sim(
    trace: &philae::trace::Trace,
    kind: SchedulerKind,
    cfg: &SchedulerConfig,
    flags: &Flags,
    obs_events: usize,
) -> anyhow::Result<SimResult> {
    let coordinators = flags.get("coordinators", 1usize).map_err(anyhow::Error::msg)?;
    let alloc_shards = flags.get("shards", 1usize).map_err(anyhow::Error::msg)?;
    let checkpoint_every = flags.get("checkpoint-every", 0u64).map_err(anyhow::Error::msg)?;
    let chaos = flags.get("chaos", 0u64).map_err(anyhow::Error::msg)?;
    let sim_cfg = SimConfig {
        coordinators,
        alloc_shards,
        obs_events,
        archive: archive_cfg(flags),
        heatmap_bins: heatmap_bins(flags),
        ..SimConfig::default()
    };
    if coordinators > 1 {
        let mut cluster = CoordinatorCluster::with_coordinators(coordinators, kind, trace, cfg);
        if checkpoint_every > 0 || chaos > 0 {
            let seed = flags.get("seed", 42u64).map_err(anyhow::Error::msg)?;
            cluster.set_chaos(trace, cfg, checkpoint_every, chaos, seed);
        }
        let res = Simulation::run_with_cluster(trace, &mut cluster, cfg, &sim_cfg);
        if checkpoint_every > 0 || chaos > 0 {
            println!(
                "chaos: {} checkpoints sealed, {} shard kill+restores",
                cluster.chaos_checkpoints(),
                cluster.chaos_kills(),
            );
        }
        Ok(res)
    } else if checkpoint_every > 0 {
        let (res, restores) =
            Simulation::run_with_restore(trace, kind, cfg, &sim_cfg, checkpoint_every);
        println!("crash-restore: {restores} coordinator kill+restores (exact checkpoints)");
        Ok(res)
    } else {
        let mut sched = kind.build(trace, cfg);
        Ok(Simulation::run_with(trace, sched.as_mut(), cfg, &sim_cfg))
    }
}

/// `philae sim --stream`: drive the engine from a bounded-memory arrival
/// stream. Generated specs stream straight out of the generator — no trace
/// is ever materialized, which is what lets a single run admit 1M+ coflows
/// over 10k+ ports — while `--trace` files are replayed in arrival order
/// through the same interface. Results are bit-identical to the
/// materialized path. Crash-failover and the post-hoc trace transforms
/// need the full trace in memory and are rejected here.
fn run_sim_streaming(
    kind: SchedulerKind,
    cfg: &SchedulerConfig,
    flags: &Flags,
) -> anyhow::Result<()> {
    for unsupported in
        ["wide-only", "replicate", "deadline-tightness", "checkpoint-every", "chaos", "gap"]
    {
        anyhow::ensure!(
            !flags.has(unsupported),
            "--{unsupported} needs a materialized trace; drop --stream"
        );
    }
    let coordinators = flags.get("coordinators", 1usize).map_err(anyhow::Error::msg)?;
    let alloc_shards = flags.get("shards", 1usize).map_err(anyhow::Error::msg)?;
    let sim_cfg = SimConfig {
        coordinators,
        alloc_shards,
        obs_events: obs_ring(flags),
        archive: archive_cfg(flags),
        heatmap_bins: heatmap_bins(flags),
        ..SimConfig::default()
    };
    let loaded;
    let mut spec_stream;
    let mut trace_stream;
    let stream: &mut dyn philae::trace::ArrivalStream = match flags.get_opt("trace") {
        Some(path) => {
            loaded = Trace::load(path)?;
            trace_stream = philae::trace::TraceStream::new(&loaded);
            &mut trace_stream
        }
        None => {
            spec_stream = build_spec(flags)?.stream();
            &mut spec_stream
        }
    };
    let num_ports = stream.num_ports();
    let res = if coordinators > 1 {
        Simulation::run_stream_cluster(stream, kind, cfg, &sim_cfg)
    } else {
        Simulation::run_stream(stream, kind, cfg, &sim_cfg)
    };
    println!(
        "{} (K={}, streamed): {} coflows on {} ports | avg CCT {:.3}s | makespan {:.1}s | peak active flows {} | flow slots {} | rate calcs {} | updates {}",
        res.scheduler,
        coordinators.max(1),
        res.ccts.len(),
        num_ports,
        res.avg_cct(),
        res.makespan,
        res.peak_active_flows,
        res.flow_slots,
        res.rate_calcs,
        res.update_msgs,
    );
    let dl = &res.deadline;
    if dl.with_deadline > 0 {
        println!(
            "  SLO: {}/{} deadlines met ({:.1}%) | goodput {:.1}% | admitted {} rejected {} expired {}",
            dl.met,
            dl.with_deadline,
            100.0 * dl.met_ratio(),
            100.0 * dl.goodput_ratio(),
            dl.admitted,
            dl.rejected,
            dl.expired,
        );
    }
    write_obs_outputs(res.obs.as_ref(), flags)?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{USAGE}");
        std::process::exit(2);
    };
    // `explain` takes its coflow id (absent for `--all`) and `obs` its
    // archive directory as a positional argument before the flags;
    // everything else is pure `--flag` pairs
    let mut flag_args = &args[1..];
    let mut explain_cid: Option<u64> = None;
    let mut obs_dir: Option<String> = None;
    if cmd == "explain" {
        if let Some(raw) = args.get(1).filter(|a| !a.starts_with("--")) {
            explain_cid =
                Some(raw.parse().map_err(|e| anyhow::anyhow!("explain <cid>: {e}"))?);
            flag_args = &args[2..];
        }
    }
    if cmd == "obs" {
        let raw = args.get(1).filter(|a| !a.starts_with("--")).ok_or_else(|| {
            anyhow::anyhow!("obs requires an archive directory: philae obs <dir>")
        })?;
        obs_dir = Some(raw.clone());
        flag_args = &args[2..];
    }
    let flags = Flags::parse(flag_args).map_err(|e| {
        eprintln!("{USAGE}");
        anyhow::anyhow!(e)
    })?;
    let cfg = SchedulerConfig::default();

    match cmd.as_str() {
        "sim" => {
            let kind: SchedulerKind = flags
                .get("scheduler", SchedulerKind::Philae)
                .map_err(anyhow::Error::msg)?;
            let coordinators = flags.get("coordinators", 1usize).map_err(anyhow::Error::msg)?;
            if flags.has("stream") {
                return run_sim_streaming(kind, &cfg, &flags);
            }
            let t = build_trace(&flags)?;
            let res = run_sim(&t, kind, &cfg, &flags, obs_ring(&flags))?;
            println!(
                "{} (K={}): {} coflows on {} ports | avg CCT {:.3}s | makespan {:.1}s | rate calcs {} | updates {}",
                res.scheduler,
                coordinators.max(1),
                t.coflows.len(),
                t.num_ports,
                res.avg_cct(),
                res.makespan,
                res.rate_calcs,
                res.update_msgs,
            );
            if flags.has("gap") {
                let lb = philae::analysis::cct_lower_bound_default(&t);
                let gap = philae::analysis::optimality_gap(res.avg_cct(), lb.avg_cct());
                println!(
                    "  oracle: avg CCT lower bound {:.3}s | optimality gap {:.1}%",
                    lb.avg_cct(),
                    100.0 * gap,
                );
            }
            let dl = &res.deadline;
            if dl.with_deadline > 0 {
                println!(
                    "  SLO: {}/{} deadlines met ({:.1}%) | goodput {:.1}% | admitted {} rejected {} expired {}",
                    dl.met,
                    dl.with_deadline,
                    100.0 * dl.met_ratio(),
                    100.0 * dl.goodput_ratio(),
                    dl.admitted,
                    dl.rejected,
                    dl.expired,
                );
            }
            write_obs_outputs(res.obs.as_ref(), &flags)?;
        }
        "explain" => {
            let all = flags.has("all");
            anyhow::ensure!(
                explain_cid.is_some() || all,
                "explain needs a coflow id or --all: philae explain <cid> | philae explain --all"
            );
            // --from <archive-dir> replays a durable archive instead of
            // re-running the simulation
            let snap: philae::obs::ObsSnapshot = match flags.get_opt("from") {
                Some(dir) => philae::obs::ArchiveReader::snapshot(std::path::Path::new(dir))?,
                None => {
                    let kind: SchedulerKind = flags
                        .get("scheduler", SchedulerKind::Philae)
                        .map_err(anyhow::Error::msg)?;
                    let t = build_trace(&flags)?;
                    if let Some(cid) = explain_cid {
                        anyhow::ensure!(
                            (cid as usize) < t.coflows.len(),
                            "coflow {cid} out of range: trace has {} coflows",
                            t.coflows.len()
                        );
                    }
                    let res =
                        run_sim(&t, kind, &cfg, &flags, obs_ring(&flags).max(OBS_RING_DEFAULT))?;
                    res.obs.expect("explain runs with the recorder on")
                }
            };
            if all {
                let csv = snap.explain_all_csv();
                match flags.get_opt("csv-out") {
                    Some(path) => {
                        std::fs::write(path, &csv)?;
                        println!(
                            "wrote CCT decomposition for {} coflows to {path}",
                            csv.lines().count().saturating_sub(1),
                        );
                    }
                    None => print!("{csv}"),
                }
            } else {
                let cid = explain_cid.expect("checked above");
                match snap.explain(cid) {
                    Some(tl) => print!("{}", tl.render()),
                    None => anyhow::bail!(
                        "coflow {cid} has no surviving events (ring dropped {}); \
                         the flight recorder keeps the newest {} events per shard — \
                         run with --archive-dir and query the archive via --from \
                         for a complete log",
                        snap.dropped,
                        OBS_RING_DEFAULT,
                    ),
                }
            }
            write_obs_outputs(Some(&snap), &flags)?;
        }
        "obs" => {
            let dir = obs_dir.expect("parsed before the flags");
            let out = philae::obs::ArchiveReader::read_dir(std::path::Path::new(&dir))?;
            print!("{}", out.summary());
            let stats = out.stats;
            let mut events = out.events;
            // filters narrow the log for the exports below
            if let Some(k) = flags.get_opt("kind") {
                let kind = philae::obs::EventKind::parse(k)
                    .ok_or_else(|| anyhow::anyhow!("--kind: unknown event kind {k:?}"))?;
                events.retain(|e| e.kind == kind);
            }
            if let Some(c) = flags.get_opt("coflow") {
                let cid: u64 = c.parse().map_err(|e| anyhow::anyhow!("--coflow: {e}"))?;
                events.retain(|e| e.coflow == cid);
            }
            if let Some(s) = flags.get_opt("shard") {
                let sh: u32 = s.parse().map_err(|e| anyhow::anyhow!("--shard: {e}"))?;
                events.retain(|e| e.shard == sh);
            }
            if flags.has("kind") || flags.has("coflow") || flags.has("shard") {
                println!("filtered: {} event(s) match", events.len());
            }
            let recorded = events.len() as u64;
            let snap = philae::obs::ObsSnapshot {
                registry: Default::default(),
                events,
                dropped: 0,
                recorded,
                archive: stats,
                heatmap: None,
            };
            if let Some(path) = flags.get_opt("csv-out") {
                std::fs::write(path, snap.to_csv())?;
                println!("  wrote event CSV to {path}");
            }
            if let Some(path) = flags.get_opt("trace-out") {
                std::fs::write(path, snap.chrome_trace_json())?;
                println!("  wrote Chrome trace to {path}");
            }
        }
        "compare" => {
            let t = build_trace(&flags)?;
            let baseline: SchedulerKind = flags
                .get("baseline", SchedulerKind::Aalo)
                .map_err(anyhow::Error::msg)?;
            let candidate: SchedulerKind = flags
                .get("candidate", SchedulerKind::Philae)
                .map_err(anyhow::Error::msg)?;
            let base = run_sim(&t, baseline, &cfg, &flags, 0)?;
            let cand = run_sim(&t, candidate, &cfg, &flags, obs_ring(&flags))?;
            let row = SpeedupRow::from_ccts(&base.ccts, &cand.ccts);
            println!(
                "{} vs {} on {} coflows / {} ports:",
                cand.scheduler,
                base.scheduler,
                t.coflows.len(),
                t.num_ports
            );
            println!("  {row}");
            println!(
                "  updates: {} vs {} | rate calcs: {} vs {}",
                cand.update_msgs, base.update_msgs, cand.rate_calcs, base.rate_calcs
            );
            if cand.deadline.with_deadline > 0 {
                println!(
                    "  deadline-met: {:.1}% vs {:.1}% | goodput: {:.1}% vs {:.1}%",
                    100.0 * cand.deadline.met_ratio(),
                    100.0 * base.deadline.met_ratio(),
                    100.0 * cand.deadline.goodput_ratio(),
                    100.0 * base.deadline.goodput_ratio(),
                );
            }
            // obs outputs come from the candidate run (the one under study)
            write_obs_outputs(cand.obs.as_ref(), &flags)?;
        }
        "serve" => {
            let t = build_trace(&flags)?;
            let kind: SchedulerKind = flags
                .get("scheduler", SchedulerKind::Philae)
                .map_err(anyhow::Error::msg)?;
            let svc = ServiceConfig {
                kind,
                sched: cfg,
                time_scale: flags.get("time-scale", 20.0f64).map_err(anyhow::Error::msg)?,
                delta_wall: Duration::from_millis(
                    flags.get("delta-ms", 8u64).map_err(anyhow::Error::msg)?,
                ),
                engine_dir: flags.get_opt("artifacts").map(Into::into),
                port_rate: philae::GBPS,
                alloc_shards: flags.get("shards", 1usize).map_err(anyhow::Error::msg)?,
                coordinators: flags.get("coordinators", 1usize).map_err(anyhow::Error::msg)?,
                checkpoint_every: flags.get("checkpoint-every", 0u64).map_err(anyhow::Error::msg)?,
                chaos_kill_every: flags.get("chaos", 0u64).map_err(anyhow::Error::msg)?,
                checkpoint_dir: flags.get_opt("checkpoint-dir").map(Into::into),
                agent_miss_intervals: match flags.get_opt("agent-miss") {
                    Some("auto") | None => 0,
                    Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--agent-miss: {e}"))?,
                },
                agent_miss_auto: flags.get_opt("agent-miss") == Some("auto"),
                obs_events: obs_ring(&flags),
                archive: archive_cfg(&flags),
                tick_max: match flags.get_opt("tick-max") {
                    None => None,
                    Some(v) => {
                        let ms: u64 =
                            v.parse().map_err(|e| anyhow::anyhow!("--tick-max: {e}"))?;
                        anyhow::ensure!(ms > 0, "--tick-max must be a positive ms count");
                        Some(Duration::from_millis(ms))
                    }
                },
            };
            let report = run_service(&t, &svc)?;
            println!(
                "{} (engine={}): avg CCT {:.3}s | missed intervals {:.1}% | idle-rate intervals {:.1}%",
                report.scheduler,
                report.used_engine,
                report.avg_cct(),
                100.0 * report.missed_fraction,
                100.0 * report.idle_rate_fraction,
            );
            println!(
                "  per-interval ms: calc {:.3} ({:.3}) | send {:.3} ({:.3}) | recv {:.3} ({:.3})",
                report.rate_calc.mean() * 1e3,
                report.rate_calc.stddev() * 1e3,
                report.rate_send.mean() * 1e3,
                report.rate_send.stddev() * 1e3,
                report.update_recv.mean() * 1e3,
                report.update_recv.stddev() * 1e3,
            );
            if report.deadline.with_deadline > 0 {
                println!(
                    "  SLO: {}/{} deadlines met ({:.1}%) | admitted {} rejected {} expired {}",
                    report.deadline.met,
                    report.deadline.with_deadline,
                    100.0 * report.deadline.met_ratio(),
                    report.deadline.admitted,
                    report.deadline.rejected,
                    report.deadline.expired,
                );
            }
            println!(
                "  realloc latency ms: p50 {:.3} | p99 {:.3} | p999 {:.3} | sched bufs recycled {}",
                report.realloc_p50 * 1e3,
                report.realloc_p99 * 1e3,
                report.realloc_p999 * 1e3,
                report.sched_bufs_reused,
            );
            if report.tick_adjusts > 0 {
                println!(
                    "  adaptive δ: {} tick retargets (gauge svc.tick_period_s holds the final period)",
                    report.tick_adjusts,
                );
            }
            write_obs_outputs(report.obs.as_ref(), &flags)?;
            if report.checkpoints_written > 0
                || report.crashes_injected > 0
                || report.ports_aged_out > 0
                || report.restored_shards > 0
            {
                println!(
                    "  recovery: {} shards restored from disk | {} checkpoints | {} crashes -> {} recoveries ({:.3} ms avg) | ports aged out {} / restored {}",
                    report.restored_shards,
                    report.checkpoints_written,
                    report.crashes_injected,
                    report.recoveries,
                    report.recovery_wall.mean() * 1e3,
                    report.ports_aged_out,
                    report.ports_restored,
                );
            }
        }
        "gen-trace" => {
            let t = build_trace(&flags)?;
            let out = flags
                .get_opt("out")
                .ok_or_else(|| anyhow::anyhow!("gen-trace requires --out <file>"))?;
            t.save(out)?;
            println!(
                "wrote {} coflows / {} ports to {}",
                t.coflows.len(),
                t.num_ports,
                out
            );
        }
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
