//! Minimal JSON reader/writer — just enough for `artifacts/manifest.json`
//! and the coordinator checkpoints (`coordinator::recovery`).
//!
//! Supports objects, arrays, strings (with escapes), numbers, booleans and
//! null. No serde on this image; see `util` module docs.
//!
//! The writer is **canonical**: object keys come out in `BTreeMap` order
//! and finite numbers use Rust's shortest round-trip `Display`, so
//! `write(parse(write(v))) == write(v)` byte for byte. The recovery module
//! relies on this to checksum checkpoints over their canonical encoding.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<JsonValue, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Array(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&std::collections::BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize canonically (sorted keys, shortest round-trip floats).
    /// Non-finite numbers have no JSON encoding and come out as `null`;
    /// callers that need them (the recovery module) string-encode first.
    pub fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Number(n) => {
                if n.is_finite() {
                    out.push_str(&n.to_string());
                } else {
                    out.push_str("null");
                }
            }
            JsonValue::String(s) => write_json_string(s, out),
            JsonValue::Array(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            JsonValue::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for JsonValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse failure with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: &'static str,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &'static str) -> JsonError {
        JsonError { pos: self.pos, msg }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err("unexpected character"))
        }
    }

    fn value(&mut self) -> Result<JsonValue, JsonError> {
        self.skip_ws();
        match self.peek().ok_or(self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(JsonValue::String(self.string()?)),
            b't' => self.lit("true", JsonValue::Bool(true)),
            b'f' => self.lit("false", JsonValue::Bool(false)),
            b'n' => self.lit("null", JsonValue::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: JsonValue) -> Result<JsonValue, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<JsonValue, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Object(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Array(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek().ok_or(self.err("unterminated string"))?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or(self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // collect the utf8 run starting at b
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len()
                        && self.bytes[end] != b'"'
                        && self.bytes[end] != b'\\'
                    {
                        end += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue, JsonError> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(JsonValue::Number)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
            "C": 128, "M": 16, "B": 100, "P": 2048,
            "lcb_sigmas": 3.0,
            "artifacts": {"scorer": {"file": "scorer.hlo.txt", "inputs": [[128,16]], "chars": 1}},
            "format": "hlo-text"
        }"#;
        let v = JsonValue::parse(doc).unwrap();
        assert_eq!(v.get("C").unwrap().as_usize(), Some(128));
        assert_eq!(v.get("lcb_sigmas").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        let scorer = v.get("artifacts").unwrap().get("scorer").unwrap();
        assert_eq!(scorer.get("file").unwrap().as_str(), Some("scorer.hlo.txt"));
    }

    #[test]
    fn strings_with_escapes() {
        let v = JsonValue::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn arrays_and_nesting() {
        let v = JsonValue::parse("[1, [2, 3], {\"x\": [true, false, null]}]").unwrap();
        if let JsonValue::Array(items) = &v {
            assert_eq!(items.len(), 3);
            assert_eq!(items[0].as_f64(), Some(1.0));
        } else {
            panic!("not an array");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(JsonValue::parse("").is_err());
        assert!(JsonValue::parse("{").is_err());
        assert!(JsonValue::parse("[1,]").is_err());
        assert!(JsonValue::parse("{\"a\" 1}").is_err());
        assert!(JsonValue::parse("123abc").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(JsonValue::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(JsonValue::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(JsonValue::parse("1.5").unwrap().as_usize(), None);
    }

    #[test]
    fn writer_is_canonical_fixed_point() {
        // write(parse(write(v))) == write(v): keys sorted, floats shortest
        let doc = r#"{"b": [1, 2.5, -3e-7], "a": {"x": true, "y": null, "s": "q\"\\\n"}}"#;
        let v = JsonValue::parse(doc).unwrap();
        let s1 = v.to_string();
        let v2 = JsonValue::parse(&s1).unwrap();
        assert_eq!(v, v2);
        assert_eq!(s1, v2.to_string());
        // keys come out sorted regardless of input order
        assert!(s1.find("\"a\"").unwrap() < s1.find("\"b\"").unwrap());
    }

    #[test]
    fn writer_floats_round_trip_bit_exact() {
        for &x in &[0.1, 1.0 / 3.0, 1e300, -2.5e-9, 123456789.123456789, 0.0, -0.0] {
            let s = JsonValue::Number(x).to_string();
            let back = JsonValue::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "via {s:?}");
        }
    }

    #[test]
    fn writer_escapes_strings() {
        let s = JsonValue::String("a\"b\\c\nd\u{1}".into()).to_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
        let back = JsonValue::parse(&s).unwrap();
        assert_eq!(back.as_str(), Some("a\"b\\c\nd\u{1}"));
    }
}
