//! A tiny property-test driver (no proptest on this image).
//!
//! [`for_all`] runs a property over `n` seeded cases; on failure it reports
//! the failing seed so the case can be replayed deterministically:
//!
//! ```
//! use philae::util::{prop, Rng};
//! prop::for_all(64, |rng| {
//!     let x = rng.below(100);
//!     assert!(x < 100);
//! });
//! ```

use super::rng::Rng;

/// Default case count for in-crate property tests.
pub const CASES: u64 = 128;

/// Run `property` over `cases` deterministic seeds. Panics (propagating the
/// property's panic, annotated with the seed) on the first failure.
pub fn for_all<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cases: u64, property: F) {
    for case in 0..cases {
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::seed_from_u64(0xA11C_E000 + case);
            property(&mut rng);
        });
        if let Err(e) = result {
            eprintln!("property failed at case {case} (seed {})", 0xA11C_E000u64 + case);
            std::panic::resume_unwind(e);
        }
    }
}

/// Like [`for_all`] but the property returns `Result`, for invariants that
/// want early-exit error plumbing instead of asserts.
pub fn for_all_ok<E: std::fmt::Debug>(
    cases: u64,
    property: impl Fn(&mut Rng) -> Result<(), E>,
) {
    for case in 0..cases {
        let mut rng = Rng::seed_from_u64(0xA11C_E000 + case);
        if let Err(e) = property(&mut rng) {
            panic!("property failed at case {case}: {e:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let counter = std::sync::atomic::AtomicU64::new(0);
        for_all(10, |_| {
            counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::SeqCst), 10);
    }

    #[test]
    #[should_panic]
    fn propagates_failure() {
        for_all(10, |rng| {
            assert!(rng.below(10) < 5, "eventually fails");
        });
    }

    #[test]
    fn ok_variant() {
        for_all_ok::<String>(5, |_| Ok(()));
    }
}
