//! Deterministic PRNG + the distributions the trace generator and the
//! dynamics models need.
//!
//! Core generator: **SplitMix64** (Steele et al., *Fast Splittable
//! Pseudorandom Number Generators*) — tiny state, excellent equidistribution
//! for simulation workloads, stable across platforms (pure u64 arithmetic),
//! which keeps every experiment bit-reproducible from its seed.

/// Seeded deterministic RNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // Avoid the all-zeros orbit and decorrelate small seeds.
        Rng {
            state: seed ^ 0x9E37_79B9_7F4A_7C15,
        }
    }

    /// Raw generator state, for checkpointing; restore with
    /// [`Rng::from_state`] to resume the exact stream position.
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild an RNG at a previously captured [`state`](Rng::state).
    pub fn from_state(state: u64) -> Self {
        Rng { state }
    }

    /// Next raw 64 random bits (SplitMix64 step).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift bounded sampling (Lemire); bias is < 2^-64·n
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with mean `mean` (inverse-CDF).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // (0,1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with ln-median `mu` and ln-σ `sigma`.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Choose `n` distinct values from `0..pool` (partial Fisher–Yates),
    /// returned sorted.
    pub fn sample_distinct(&mut self, pool: usize, n: usize) -> Vec<usize> {
        let n = n.min(pool);
        let mut items: Vec<usize> = (0..pool).collect();
        for i in 0..n {
            let j = i + self.below(pool - i);
            items.swap(i, j);
        }
        let mut chosen = items[..n].to_vec();
        chosen.sort_unstable();
        chosen
    }
}

/// Reusable scratch for distinct sampling in O(n) per draw instead of
/// the O(pool) identity-array rebuild [`Rng::sample_distinct`] pays.
///
/// The trick: the partial Fisher–Yates only ever *reads* positions it has
/// already swapped plus the swap target, so instead of materializing
/// `0..pool` we keep an epoch-stamped override dictionary — a position
/// holds its identity value unless stamped in the current epoch. The RNG
/// draw sequence (`below(pool - i)` for each of the `n` picks) and the
/// sorted output are **bit-identical** to `sample_distinct`; only the
/// allocation profile changes. This is what lets the streaming trace
/// generator draw ports for millions of coflows over 10k+ port fabrics
/// without an 80 KB rebuild per coflow.
#[derive(Debug, Clone, Default)]
pub struct SampleScratch {
    stamp: Vec<u64>,
    value: Vec<usize>,
    epoch: u64,
}

impl SampleScratch {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn get(&self, i: usize) -> usize {
        if self.stamp[i] == self.epoch {
            self.value[i]
        } else {
            i
        }
    }

    #[inline]
    fn set(&mut self, i: usize, v: usize) {
        self.stamp[i] = self.epoch;
        self.value[i] = v;
    }

    /// Fill `out` with `n.min(pool)` distinct values from `0..pool`,
    /// sorted ascending — same draws, same result as
    /// [`Rng::sample_distinct`].
    pub fn sample_into(&mut self, rng: &mut Rng, pool: usize, n: usize, out: &mut Vec<usize>) {
        let n = n.min(pool);
        if self.stamp.len() < pool {
            self.stamp.resize(pool, 0);
            self.value.resize(pool, 0);
        }
        self.epoch += 1;
        out.clear();
        for i in 0..n {
            let j = i + rng.below(pool - i);
            let (vi, vj) = (self.get(i), self.get(j));
            self.set(i, vj);
            self.set(j, vi);
            out.push(vj);
        }
        out.sort_unstable();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(8);
        assert_ne!(Rng::seed_from_u64(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_uniformish() {
        let mut r = Rng::seed_from_u64(2);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::seed_from_u64(3);
        let n = 200_000;
        let s: f64 = (0..n).map(|_| r.exp(5.0)).sum();
        let mean = s / n as f64;
        assert!((mean - 5.0).abs() < 0.1, "exp mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(4);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "normal mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "normal var {var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::seed_from_u64(5);
        let n = 100_001;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(2.0_f64.ln(), 1.0)).collect();
        xs.sort_by(f64::total_cmp);
        let median = xs[n / 2];
        assert!((median - 2.0).abs() < 0.1, "lognormal median {median}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::seed_from_u64(6);
        for _ in 0..100 {
            let s = r.sample_distinct(20, 7);
            assert_eq!(s.len(), 7);
            let mut d = s.clone();
            d.dedup();
            assert_eq!(d.len(), 7, "duplicates in {s:?}");
            assert!(s.iter().all(|&x| x < 20));
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        }
        // n > pool clamps
        assert_eq!(r.sample_distinct(3, 10).len(), 3);
    }

    #[test]
    fn sample_scratch_matches_sample_distinct() {
        let mut scratch = SampleScratch::new();
        let mut out = Vec::new();
        for seed in 0..20u64 {
            let mut a = Rng::seed_from_u64(seed);
            let mut b = Rng::seed_from_u64(seed);
            for (pool, n) in [(1, 1), (5, 3), (20, 7), (20, 20), (150, 40), (3, 10)] {
                let want = a.sample_distinct(pool, n);
                scratch.sample_into(&mut b, pool, n, &mut out);
                assert_eq!(out, want, "pool={pool} n={n} seed={seed}");
                // identical post-call stream position too
                assert_eq!(a.state(), b.state(), "pool={pool} n={n} seed={seed}");
            }
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::seed_from_u64(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let x = r.range_inclusive(3, 5);
            assert!((3..=5).contains(&x));
            seen_lo |= x == 3;
            seen_hi |= x == 5;
        }
        assert!(seen_lo && seen_hi);
    }
}
