//! Offline-image substrates: deterministic RNG + distributions, a minimal
//! JSON reader for the artifact manifest, and a tiny property-test driver.
//!
//! The build image carries no crates.io mirror beyond `xla` and `anyhow`,
//! so the usual `rand`/`serde`/`proptest` stack is reimplemented here with
//! exactly the surface this project needs.

pub mod json;
pub mod prop;
pub mod rng;

pub use json::JsonValue;
pub use rng::{Rng, SampleScratch};
