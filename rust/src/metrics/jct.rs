//! Job-completion-time model (§4.2).
//!
//! The paper maps CCT improvements to JCT improvements with the shuffle
//! fraction distribution used in Aalo: 61% of jobs spend <25% of their time
//! in shuffle, 13% spend 25–49%, 14% spend 50–74%, and the rest ≥75%.
//! For a job whose baseline JCT decomposes into compute + shuffle with
//! shuffle fraction `f`, a new CCT yields
//! `JCT' = (1−f)·JCT + CCT'·(f·JCT/CCT)` — i.e. only the shuffle part
//! scales with the CCT speedup.

use crate::Time;
use crate::util::Rng;

/// The Aalo shuffle-fraction buckets: (probability, f_low, f_high).
#[derive(Debug, Clone)]
pub struct ShuffleFractionModel {
    pub buckets: Vec<(f64, f64, f64)>,
    pub seed: u64,
}

impl Default for ShuffleFractionModel {
    fn default() -> Self {
        ShuffleFractionModel {
            buckets: vec![
                (0.61, 0.05, 0.25),
                (0.13, 0.25, 0.49),
                (0.14, 0.50, 0.74),
                (0.12, 0.75, 0.95),
            ],
            seed: 2021,
        }
    }
}

impl ShuffleFractionModel {
    /// Sample one shuffle fraction.
    fn sample(&self, rng: &mut Rng) -> f64 {
        let total: f64 = self.buckets.iter().map(|b| b.0).sum();
        let mut x = rng.f64() * total;
        for &(w, lo, hi) in &self.buckets {
            if x < w {
                return rng.uniform(lo, hi);
            }
            x -= w;
        }
        let last = self.buckets.last().unwrap();
        last.2
    }
}

/// Per-job JCT speedups given matched per-coflow CCTs under the baseline
/// and the candidate scheduler. Job `i`'s shuffle == coflow `i` (the paper
/// uses 526 jobs, one per FB-trace coflow).
pub fn jct_speedups(
    baseline_cct: &[Time],
    candidate_cct: &[Time],
    model: &ShuffleFractionModel,
) -> Vec<f64> {
    let mut rng = Rng::seed_from_u64(model.seed);
    baseline_cct
        .iter()
        .zip(candidate_cct.iter())
        .filter(|(&b, &c)| b > 0.0 && c > 0.0)
        .map(|(&b, &c)| {
            let frac = model.sample(&mut rng);
            // baseline job time normalized to 1: shuffle = frac, compute = 1-frac
            // candidate shuffle time scales by c/b.
            let jct_base = 1.0;
            let jct_cand = (1.0 - frac) + frac * (c / b);
            jct_base / jct_cand
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{mean, percentile};

    #[test]
    fn jct_speedup_bounded_by_cct_speedup() {
        let base = vec![10.0; 1000];
        let cand = vec![2.0; 1000]; // 5x CCT speedup
        let sp = jct_speedups(&base, &cand, &ShuffleFractionModel::default());
        assert_eq!(sp.len(), 1000);
        for &s in &sp {
            assert!(s >= 1.0 - 1e-9, "jct speedup {s} < 1");
            assert!(s <= 5.0 + 1e-9, "jct speedup {s} exceeds cct speedup");
        }
        // most jobs are compute-heavy, so median JCT gain is far below 5x
        assert!(percentile(&sp, 50.0) < 2.0);
        // but high-shuffle jobs approach it
        assert!(percentile(&sp, 95.0) > 2.0);
    }

    #[test]
    fn no_cct_change_no_jct_change() {
        let base = vec![10.0; 100];
        let sp = jct_speedups(&base, &base, &ShuffleFractionModel::default());
        assert!(sp.iter().all(|&s| (s - 1.0).abs() < 1e-12));
    }

    #[test]
    fn deterministic_given_seed() {
        let base = vec![10.0, 20.0, 30.0];
        let cand = vec![5.0, 10.0, 15.0];
        let m = ShuffleFractionModel::default();
        assert_eq!(jct_speedups(&base, &cand, &m), jct_speedups(&base, &cand, &m));
    }

    #[test]
    fn slower_candidate_gives_sub_one_speedup() {
        let base = vec![10.0; 200];
        let cand = vec![20.0; 200];
        let sp = jct_speedups(&base, &cand, &ShuffleFractionModel::default());
        assert!(mean(&sp) < 1.0);
    }
}
