//! Coordinator-cost accounting: per-interval statistics for Tables 3/4 and
//! resource-usage proxies for Table 6.


/// Online mean/std (Welford) so million-interval runs don't store a vector.
#[derive(Debug, Clone, Default)]
pub struct RunningStat {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub max: f64,
}

impl RunningStat {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x > self.max {
            self.max = x;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }
}

/// Cost model for coordinator↔agent RPCs in the *simulated* coordinator.
///
/// The live tokio service (`service::`) measures real send/recv times; the
/// discrete-event simulator instead charges a constant per message,
/// calibrated to the paper's Table 3 (Aalo @900 ports: 17.65 ms to send to
/// ~900 agents ≈ 20 µs/msg; 10.97 ms to receive from 429 agents ≈ 25 µs/msg).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MessageCostModel {
    /// Seconds to push one new-rate message to one agent.
    pub send_per_msg: f64,
    /// Seconds to ingest one agent update.
    pub recv_per_msg: f64,
}

impl Default for MessageCostModel {
    fn default() -> Self {
        MessageCostModel {
            send_per_msg: 20e-6,
            recv_per_msg: 25e-6,
        }
    }
}

/// Aggregated per-scheduling-interval coordinator work, the unit of
/// Tables 3 and 4. One `IntervalStats` accumulates a whole run.
#[derive(Debug, Clone, Default)]
pub struct IntervalStats {
    /// Number of accounting intervals observed (busy intervals only —
    /// intervals with no active coflows are skipped, as in the testbed
    /// where the trace replay is always busy).
    pub intervals: u64,
    /// Intervals whose total coordinator work exceeded δ (Table 4).
    pub over_budget: u64,
    /// Intervals in which no rate calculation happened at all (the paper:
    /// “Philae did not have to calculate and send new rates in 66% of the
    /// intervals”).
    pub idle_rate_intervals: u64,
    /// Per-interval rate-calculation seconds.
    pub rate_calc: RunningStat,
    /// Per-interval new-rate-send seconds (modelled or measured).
    pub rate_send: RunningStat,
    /// Per-interval update-receive seconds (modelled or measured).
    pub update_recv: RunningStat,
    /// Per-interval updates received (the “49 vs 429 agents” comparison).
    pub updates_per_interval: RunningStat,
    /// Per-interval rate messages pushed.
    pub rate_msgs_per_interval: RunningStat,
}

impl IntervalStats {
    /// Fold one finished interval into the aggregate.
    pub fn push_interval(
        &mut self,
        budget: f64,
        rate_calc_s: f64,
        rate_send_s: f64,
        update_recv_s: f64,
        updates: u64,
        rate_msgs: u64,
        rate_calcs: u64,
    ) {
        self.intervals += 1;
        if rate_calc_s + rate_send_s + update_recv_s > budget {
            self.over_budget += 1;
        }
        if rate_calcs == 0 {
            self.idle_rate_intervals += 1;
        }
        self.rate_calc.push(rate_calc_s);
        self.rate_send.push(rate_send_s);
        self.update_recv.push(update_recv_s);
        self.updates_per_interval.push(updates as f64);
        self.rate_msgs_per_interval.push(rate_msgs as f64);
    }

    /// Fraction of intervals whose work exceeded the budget (Table 4).
    pub fn missed_fraction(&self) -> f64 {
        if self.intervals == 0 {
            0.0
        } else {
            self.over_budget as f64 / self.intervals as f64
        }
    }

    /// Fraction of intervals with no rate calculation.
    pub fn idle_rate_fraction(&self) -> f64 {
        if self.intervals == 0 {
            0.0
        } else {
            self.idle_rate_intervals as f64 / self.intervals as f64
        }
    }

    /// Mean total coordinator milliseconds per interval (Table 3 “Total”).
    pub fn total_ms_mean(&self) -> f64 {
        (self.rate_calc.mean() + self.rate_send.mean() + self.update_recv.mean()) * 1e3
    }
}

/// Table 6 proxies: totals over a run plus peak working-set counters.
#[derive(Debug, Clone, Default)]
pub struct ResourceUsage {
    /// Total coordinator busy seconds (calc + modelled messaging).
    pub coordinator_busy_s: f64,
    /// Wall/simulated seconds of the run.
    pub span_s: f64,
    /// Total messages in either direction.
    pub messages: u64,
    /// Peak simultaneous active coflows.
    pub peak_active_coflows: usize,
    /// Peak simultaneous unfinished flows of active coflows.
    pub peak_active_flows: usize,
    /// 90th-percentile per-interval busy seconds (the “Busy” column).
    pub busy_p90_s: f64,
}

impl ResourceUsage {
    /// Average coordinator utilization in percent (Table 6 “CPU (%)”).
    pub fn cpu_percent(&self) -> f64 {
        if self.span_s == 0.0 {
            0.0
        } else {
            100.0 * self.coordinator_busy_s / self.span_s
        }
    }

    /// Working-set proxy in MB assuming ~1 KB of coordinator state per
    /// active coflow and ~100 B per active flow (Table 6 “Memory (MB)”).
    pub fn memory_mb(&self) -> f64 {
        (self.peak_active_coflows as f64 * 1024.0 + self.peak_active_flows as f64 * 100.0) / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let mut s = RunningStat::default();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.0).abs() < 1e-12);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn interval_budget_accounting() {
        let mut st = IntervalStats::default();
        st.push_interval(0.008, 0.001, 0.001, 0.001, 10, 5, 1);
        st.push_interval(0.008, 0.010, 0.001, 0.001, 10, 5, 1);
        st.push_interval(0.008, 0.0, 0.0, 0.0, 0, 0, 0);
        assert_eq!(st.intervals, 3);
        assert_eq!(st.over_budget, 1);
        assert!((st.missed_fraction() - 1.0 / 3.0).abs() < 1e-12);
        assert!((st.idle_rate_fraction() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn resource_usage_percent() {
        let r = ResourceUsage {
            coordinator_busy_s: 5.0,
            span_s: 100.0,
            ..Default::default()
        };
        assert!((r.cpu_percent() - 5.0).abs() < 1e-12);
    }
}
