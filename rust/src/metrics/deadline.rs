//! Deadline (SLO) accounting: deadline-met ratio, goodput, and the
//! admission/expiry counters of the deadline workload family.
//!
//! The evaluation follows the deadline-scheduling literature the
//! reproduction extends toward (DCoflow, arXiv 2205.01229; Qiu/Stein/Zhong,
//! arXiv 1603.07981): the primary metric for SLO workloads is the
//! **deadline-met ratio** — the fraction of deadline-carrying coflows that
//! finish by their deadline — and **goodput**, the bytes belonging to
//! coflows that met their SLO (bytes delivered after the deadline are
//! operationally worthless to an SLO job). CCT remains the secondary
//! metric: a deadline scheduler should not wreck the average for the
//! best-effort remainder.

use crate::{Bytes, Time, EPS};

/// SLO outcome summary of one run. Built by folding per-coflow outcomes
/// through [`DeadlineStats::record`]; the admission counters come from the
/// scheduler ([`crate::coordinator::AdmissionStats`]) and stay zero for
/// deadline-blind policies.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DeadlineStats {
    /// All coflows in the run.
    pub coflows: usize,
    /// Coflows carrying a deadline.
    pub with_deadline: usize,
    /// Deadline coflows that finished by their deadline.
    pub met: usize,
    /// Deadline coflows that missed (including never-finished ones).
    pub missed: usize,
    /// Admission decisions (deadline-aware schedulers only).
    pub admitted: u64,
    pub rejected: u64,
    /// Admitted coflows that nevertheless missed their deadline.
    pub expired: u64,
    /// Total bytes of deadline-carrying coflows.
    pub bytes_with_deadline: Bytes,
    /// Goodput: bytes of deadline coflows that met their SLO.
    pub goodput_bytes: Bytes,
}

impl DeadlineStats {
    /// Fold one coflow's outcome in.
    pub fn record(&mut self, deadline: Option<Time>, finished_at: Option<Time>, bytes: Bytes) {
        self.coflows += 1;
        let Some(d) = deadline else { return };
        self.with_deadline += 1;
        self.bytes_with_deadline += bytes;
        if finished_at.is_some_and(|t| t <= d + EPS) {
            self.met += 1;
            self.goodput_bytes += bytes;
        } else {
            self.missed += 1;
        }
    }

    /// Fraction of deadline coflows that met their SLO (1.0 on an
    /// SLO-free run, where no deadline can be missed).
    pub fn met_ratio(&self) -> f64 {
        if self.with_deadline == 0 {
            1.0
        } else {
            self.met as f64 / self.with_deadline as f64
        }
    }

    /// Fraction of deadline bytes delivered within their SLO.
    pub fn goodput_ratio(&self) -> f64 {
        if self.bytes_with_deadline <= 0.0 {
            1.0
        } else {
            self.goodput_bytes / self.bytes_with_deadline
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_ratios() {
        let mut s = DeadlineStats::default();
        s.record(None, Some(1.0), 100.0); // best-effort: no SLO accounting
        s.record(Some(2.0), Some(1.5), 10.0); // met
        s.record(Some(2.0), Some(2.5), 30.0); // missed late
        s.record(Some(2.0), None, 60.0); // missed unfinished
        assert_eq!(s.coflows, 4);
        assert_eq!(s.with_deadline, 3);
        assert_eq!((s.met, s.missed), (1, 2));
        assert!((s.met_ratio() - 1.0 / 3.0).abs() < 1e-12);
        assert!((s.goodput_ratio() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn slo_free_run_is_vacuously_met() {
        let mut s = DeadlineStats::default();
        s.record(None, Some(1.0), 5.0);
        assert_eq!(s.met_ratio(), 1.0);
        assert_eq!(s.goodput_ratio(), 1.0);
        assert_eq!(s.with_deadline, 0);
    }

    #[test]
    fn exact_deadline_counts_as_met() {
        let mut s = DeadlineStats::default();
        s.record(Some(2.0), Some(2.0), 1.0);
        assert_eq!(s.met, 1);
    }
}
