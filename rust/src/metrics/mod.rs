//! Metrics: percentile/CDF helpers, speedup tables, coordinator-cost
//! accounting (Tables 3/4/6), the shuffle-fraction JCT model (§4.2), and
//! deadline/SLO accounting (met ratio, goodput — `deadline`).

mod counters;
mod deadline;
mod jct;

pub use counters::{IntervalStats, MessageCostModel, ResourceUsage, RunningStat};
pub use deadline::DeadlineStats;
pub use jct::{jct_speedups, ShuffleFractionModel};

use crate::Time;

/// Percentile of a sample (nearest-rank on a sorted copy); `p` in [0,100].
pub fn percentile(values: &[f64], p: f64) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Arithmetic mean.
pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    values.iter().sum::<f64>() / values.len() as f64
}

/// Population standard deviation.
pub fn stddev(values: &[f64]) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    let m = mean(values);
    (values.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / values.len() as f64).sqrt()
}

/// Mean-normalized standard deviation (Table 5's robustness metric).
pub fn mean_normalized_stddev(values: &[f64]) -> f64 {
    let m = mean(values);
    if m == 0.0 {
        0.0
    } else {
        stddev(values) / m
    }
}

/// Per-coflow speedups `baseline/candidate`, skipping degenerate zeros.
pub fn speedups(baseline: &[Time], candidate: &[Time]) -> Vec<f64> {
    baseline
        .iter()
        .zip(candidate.iter())
        .filter(|(&b, &c)| b > 0.0 && c > 0.0)
        .map(|(&b, &c)| b / c)
        .collect()
}

/// Empirical CDF as `(value, fraction ≤ value)` pairs at `points` evenly
/// spaced quantiles — what the paper's Fig. CDF-of-speedup plots show.
pub fn cdf(values: &[f64], points: usize) -> Vec<(f64, f64)> {
    if values.is_empty() {
        return Vec::new();
    }
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(f64::total_cmp);
    (0..points)
        .map(|i| {
            let q = (i as f64 + 0.5) / points as f64;
            let idx = ((q * v.len() as f64) as usize).min(v.len() - 1);
            (v[idx], q)
        })
        .collect()
}

/// The summary row the paper reports per comparison: P50 / P90 / average
/// speedup of per-coflow CCTs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpeedupRow {
    pub p10: f64,
    pub p50: f64,
    pub p90: f64,
    /// Ratio of average CCTs (paper's “Avg. CCT” column): avg(base)/avg(cand).
    pub avg_cct_ratio: f64,
    /// Average of per-coflow speedups (a different, noisier statistic).
    pub mean_speedup: f64,
    pub n: usize,
}

impl SpeedupRow {
    /// Build from matched per-coflow CCT vectors.
    pub fn from_ccts(baseline: &[Time], candidate: &[Time]) -> Self {
        let sp = speedups(baseline, candidate);
        let avg_b = mean(baseline);
        let avg_c = mean(candidate);
        SpeedupRow {
            p10: percentile(&sp, 10.0),
            p50: percentile(&sp, 50.0),
            p90: percentile(&sp, 90.0),
            avg_cct_ratio: if avg_c > 0.0 { avg_b / avg_c } else { f64::NAN },
            mean_speedup: mean(&sp),
            n: sp.len(),
        }
    }
}

impl std::fmt::Display for SpeedupRow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "P50 {:.2}x  P90 {:.2}x  avg-CCT {:.2}x  (n={})",
            self.p50, self.p90, self.avg_cct_ratio, self.n
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn percentile_unsorted_input() {
        let v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&v, 50.0), 3.0);
    }

    #[test]
    fn stddev_and_normalized() {
        let v = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&v) - 2.0).abs() < 1e-12);
        assert!((mean_normalized_stddev(&v) - 2.0 / 5.0).abs() < 1e-12);
    }

    #[test]
    fn speedup_row() {
        let base = [10.0, 10.0, 100.0];
        let cand = [5.0, 10.0, 10.0];
        let row = SpeedupRow::from_ccts(&base, &cand);
        assert_eq!(row.n, 3);
        assert_eq!(row.p50, 2.0);
        assert!((row.avg_cct_ratio - 120.0 / 25.0).abs() < 1e-12);
    }

    #[test]
    fn speedups_skip_zeros() {
        assert_eq!(speedups(&[0.0, 10.0], &[1.0, 5.0]), vec![2.0]);
    }

    #[test]
    fn cdf_monotone() {
        let v = [3.0, 1.0, 2.0, 5.0, 4.0];
        let c = cdf(&v, 10);
        assert_eq!(c.len(), 10);
        for w in c.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
    }
}
