//! FB-like synthetic trace generator.
//!
//! The published FB trace (526 coflows, 150 ports, ~1 hour) has three
//! properties that drive every result in the paper:
//!
//! 1. **Count is dominated by small coflows, bytes by large ones** — the
//!    average CCT improvement is therefore dominated by how fast the
//!    scheduler learns *large* coflows' sizes (paper §2.2, §4.1).
//! 2. **Widths are heavy-tailed**: most coflows touch a handful of ports,
//!    a few span (nearly) the whole cluster.
//! 3. **Intra-coflow flow sizes are skewed** (max/min spans orders of
//!    magnitude for some coflows) — the sampling robustness question.
//!
//! [`TraceSpec`] generates traces with a four-class mixture (the classic
//! Varys/Aalo taxonomy: short-narrow, long-narrow, short-wide, long-wide)
//! and per-class lognormal flow sizes whose σ sets the intra-coflow skew.
//! Every knob is public so evaluation sweeps (skew, load, width) can be
//! expressed directly.

use super::stream::{ArrivalStream, CoflowArrival, SpecStream};
use super::Trace;
use crate::fabric::Fabric;
use crate::util::Rng;
use crate::Time;

/// One class of the coflow mixture.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoflowClass {
    /// Probability of drawing this class.
    pub weight: f64,
    /// Mapper count range (inclusive).
    pub mappers: (usize, usize),
    /// Reducer count range (inclusive).
    pub reducers: (usize, usize),
    /// Median per-flow size in MB (lognormal μ = ln(median)).
    pub flow_mb_median: f64,
    /// Lognormal σ of per-flow sizes — sets the intra-coflow skew.
    pub flow_mb_sigma: f64,
}

/// Per-coflow completion-deadline (SLO) model, DCoflow-style (arXiv
/// 2205.01229; evaluation methodology per Qiu/Stein/Zhong, arXiv
/// 1603.07981): a covered coflow's deadline is its **ideal CCT** (the
/// bottleneck bound at line rate, with zero contention) scaled by a
/// tightness factor drawn uniformly from
/// `[tightness, tightness × (1 + spread)]`. Tightness 1 is only reachable
/// by a coflow alone on its ports; production SLOs are quoted as small
/// multiples of the ideal (2× = "tight", 4×+ = "loose").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeadlineModel {
    /// Base tightness factor multiplying the ideal CCT (≥ 1 is sane).
    pub tightness: f64,
    /// Uniform spread of the tightness draw (0 = deterministic factor).
    pub spread: f64,
    /// Fraction of coflows that carry a deadline (1.0 = every coflow).
    pub coverage: f64,
}

impl DeadlineModel {
    /// Model with the given base tightness and the default spread (0.5)
    /// and full coverage.
    pub fn tightness(tightness: f64) -> Self {
        assert!(tightness > 0.0, "tightness must be positive");
        DeadlineModel { tightness, spread: 0.5, coverage: 1.0 }
    }
}

/// How a coflow's flows connect its ports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FlowPattern {
    /// Mapper × reducer shuffle (the FB benchmark's bipartite expansion).
    #[default]
    Bipartite,
    /// All-reduce ring step: W workers, one equal-size chunk per link,
    /// flows `worker[i] → worker[(i+1) mod W]`. The class's mapper range
    /// doubles as the worker-count range; reducer ranges are unused.
    Ring,
}

/// Generator parameters; defaults approximate the FB trace marginals.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpec {
    pub num_ports: usize,
    pub num_coflows: usize,
    /// Mean inter-arrival gap in seconds (Poisson arrivals).
    pub mean_interarrival: Time,
    /// Fraction of coflows arriving inside a burst — production traces are
    /// strongly clustered (jobs launch in waves), which is what creates
    /// contention among small coflows.
    pub burstiness: f64,
    /// Mean intra-burst gap in seconds.
    pub burst_gap: Time,
    /// The class mixture.
    pub classes: Vec<CoflowClass>,
    /// RNG seed.
    pub rng_seed: u64,
    /// Per-port line-rate pattern in Gbps, cycled across ports (see
    /// [`TraceSpec::fabric`]); empty = homogeneous 1 Gbps. Models
    /// mixed-NIC-generation clusters (e.g. 1/10/40 Gbps side by side).
    pub port_gbps_cycle: Vec<f64>,
    /// Optional SLO model: when set, [`TraceSpec::generate`] assigns
    /// per-coflow deadlines via [`crate::trace::Trace::assign_deadlines`]
    /// against this spec's fabric. Deadline assignment uses its own RNG
    /// stream, so the flows/arrivals are bit-identical with and without it.
    pub deadline: Option<DeadlineModel>,
    /// Flow topology per coflow (bipartite shuffle vs all-reduce ring).
    pub flow_pattern: FlowPattern,
    /// Diurnal load-cycle period in seconds (used only when
    /// `diurnal_amplitude > 0`).
    pub diurnal_period: Time,
    /// Peak extra load of the diurnal cycle: inter-arrival gaps are divided
    /// by `1 + amplitude·½(1 + sin(2πt/period))`, so peak load is
    /// `(1 + amplitude)×` the trough. `0.0` disables modulation and keeps
    /// the arrival process bit-identical to the flat generator.
    pub diurnal_amplitude: f64,
}

impl TraceSpec {
    /// FB-like defaults: the four-class mixture of the Varys/Aalo taxonomy.
    /// With 150 ports and 526 coflows this yields ≈60% narrow coflows by
    /// count while long-wide coflows carry the vast majority of bytes.
    pub fn fb_like(num_ports: usize, num_coflows: usize) -> Self {
        TraceSpec {
            num_ports,
            num_coflows,
            // 526 coflows over ~1 hour; roughly half arrive in bursts, so
            // the base gap is doubled to keep the span.
            mean_interarrival: 2.0 * 3600.0 / num_coflows.max(1) as f64,
            burstiness: 0.5,
            burst_gap: 0.25,
            classes: vec![
                // short & narrow: the bulk of coflows by count
                CoflowClass {
                    weight: 0.52,
                    mappers: (1, 4),
                    reducers: (1, 4),
                    flow_mb_median: 2.0,
                    flow_mb_sigma: 0.8,
                },
                // long & narrow
                CoflowClass {
                    weight: 0.16,
                    mappers: (1, 4),
                    reducers: (1, 4),
                    flow_mb_median: 60.0,
                    flow_mb_sigma: 1.0,
                },
                // short & wide
                CoflowClass {
                    weight: 0.15,
                    mappers: (5, 40),
                    reducers: (5, 40),
                    flow_mb_median: 1.0,
                    flow_mb_sigma: 0.8,
                },
                // long & wide: few coflows, most of the bytes. Port spans
                // reach the full cluster through the mapper range cap; the
                // flow-count tail is kept near the published trace's scale
                // so full-trace simulations stay tractable.
                CoflowClass {
                    weight: 0.17,
                    mappers: (10, 60),
                    reducers: (10, 60),
                    flow_mb_median: 25.0,
                    flow_mb_sigma: 1.2,
                },
            ],
            rng_seed: 42,
            port_gbps_cycle: Vec::new(),
            deadline: None,
            flow_pattern: FlowPattern::Bipartite,
            diurnal_period: 0.0,
            diurnal_amplitude: 0.0,
        }
    }

    /// Mixed-rate scenario: the FB-like workload on a heterogeneous fabric
    /// cycling 1/1/10/40 Gbps NICs across the ports — half the cluster on
    /// the old generation, the rest split across two upgrades. Pair with
    /// [`TraceSpec::fabric`] when building the simulation.
    pub fn mixed_rate(num_ports: usize, num_coflows: usize) -> Self {
        let mut spec = Self::fb_like(num_ports, num_coflows);
        spec.port_gbps_cycle = vec![1.0, 1.0, 10.0, 40.0];
        spec
    }

    /// The fabric this scenario runs on: heterogeneous per
    /// `port_gbps_cycle`, or the paper's homogeneous 1 Gbps testbed when
    /// the cycle is empty.
    pub fn fabric(&self) -> Fabric {
        if self.port_gbps_cycle.is_empty() {
            Fabric::gbps(self.num_ports)
        } else {
            Fabric::mixed_gbps(self.num_ports, &self.port_gbps_cycle)
        }
    }

    /// Incast scenario: many-to-one shuffles (DCoflow's motivating
    /// pattern, arXiv 2205.01229 §2 — aggregation stages whose single
    /// reducer port is the structural bottleneck). Every coflow funnels a
    /// wide mapper fan-in into exactly one reducer; arrivals are strongly
    /// burst-clustered the way query fan-outs launch in waves. Own RNG
    /// stream (seed 71), so existing scenarios are untouched.
    pub fn incast(num_ports: usize, num_coflows: usize) -> Self {
        let mut spec = Self::fb_like(num_ports, num_coflows);
        spec.classes = vec![
            // shallow aggregations: the bulk by count
            CoflowClass {
                weight: 0.6,
                mappers: (8, 32),
                reducers: (1, 1),
                flow_mb_median: 1.0,
                flow_mb_sigma: 0.8,
            },
            // deep fan-ins: few coflows, severe single-port contention
            CoflowClass {
                weight: 0.4,
                mappers: (32, 128),
                reducers: (1, 1),
                flow_mb_median: 8.0,
                flow_mb_sigma: 1.0,
            },
        ];
        spec.burstiness = 0.7;
        spec.burst_gap = 0.1;
        spec.rng_seed = 71;
        spec
    }

    /// All-reduce scenario: ring all-reduce steps from synchronous ML
    /// training (each coflow is one ring pass over W sampled workers,
    /// equal chunk per link). Ring traffic is the pattern where clairvoyant
    /// bottleneck ordering degenerates — every port carries the same
    /// bytes — so it isolates the schedulers' inter-coflow behavior. Own
    /// RNG stream (seed 73).
    pub fn all_reduce(num_ports: usize, num_coflows: usize) -> Self {
        assert!(num_ports >= 2, "a ring needs at least two ports");
        let mut spec = Self::fb_like(num_ports, num_coflows);
        spec.flow_pattern = FlowPattern::Ring;
        spec.classes = vec![
            // small data-parallel jobs
            CoflowClass {
                weight: 0.7,
                mappers: (2, 8),
                reducers: (1, 1), // unused by Ring
                flow_mb_median: 24.0,
                flow_mb_sigma: 0.4,
            },
            // large jobs spanning a big worker set
            CoflowClass {
                weight: 0.3,
                mappers: (8, 64),
                reducers: (1, 1),
                flow_mb_median: 96.0,
                flow_mb_sigma: 0.4,
            },
        ];
        spec.burstiness = 0.3;
        spec.burst_gap = 0.5;
        spec.rng_seed = 73;
        spec
    }

    /// Diurnal scenario: the FB mixture under a sinusoidal load cycle —
    /// gaps are compressed by up to `(1 + amplitude)×` at the peak, so the
    /// trace alternates quiet troughs with heavily contended rush hours
    /// (the production shape flat Poisson arrivals miss). Own RNG stream
    /// (seed 79).
    pub fn diurnal(num_ports: usize, num_coflows: usize) -> Self {
        let mut spec = Self::fb_like(num_ports, num_coflows);
        // one full cycle per generated hour of trace at fb_like's span
        spec.diurnal_period = 3600.0;
        spec.diurnal_amplitude = 3.0;
        spec.rng_seed = 79;
        spec
    }

    /// Adversarial-skew scenario: the sampling-robustness stress from
    /// paper §2.2/§4.4 pushed to the edge — heavy-tailed classes at
    /// lognormal σ up to 3 (pilot flows can miss the coflow's true size by
    /// orders of magnitude) interleaved with a near-uniform "decoy" class
    /// that sampling estimates perfectly. Own RNG stream (seed 83).
    pub fn adversarial_skew(num_ports: usize, num_coflows: usize) -> Self {
        let mut spec = Self::fb_like(num_ports, num_coflows);
        spec.classes = vec![
            CoflowClass {
                weight: 0.5,
                mappers: (2, 8),
                reducers: (2, 8),
                flow_mb_median: 4.0,
                flow_mb_sigma: 3.0,
            },
            CoflowClass {
                weight: 0.3,
                mappers: (10, 60),
                reducers: (10, 60),
                flow_mb_median: 10.0,
                flow_mb_sigma: 2.5,
            },
            // decoy: tiny, perfectly uniform — trivial for sampling,
            // present to punish schedulers that mis-bin the heavy tail
            CoflowClass {
                weight: 0.2,
                mappers: (1, 2),
                reducers: (1, 2),
                flow_mb_median: 0.5,
                flow_mb_sigma: 0.05,
            },
        ];
        spec.rng_seed = 83;
        spec
    }

    /// Scenario registry: the named workloads reachable from the CLI
    /// (`--scenario`) and docs. Returns `None` for unknown names.
    pub fn scenario(name: &str, num_ports: usize, num_coflows: usize) -> Option<Self> {
        Some(match name {
            "fb-like" | "fb_like" => Self::fb_like(num_ports, num_coflows),
            "mixed-rate" | "mixed_rate" => Self::mixed_rate(num_ports, num_coflows),
            "tiny" => Self::tiny(num_ports, num_coflows),
            "incast" => Self::incast(num_ports, num_coflows),
            "all-reduce" | "all_reduce" | "ring" => Self::all_reduce(num_ports, num_coflows),
            "diurnal" => Self::diurnal(num_ports, num_coflows),
            "adversarial-skew" | "adversarial_skew" | "skew" => {
                Self::adversarial_skew(num_ports, num_coflows)
            }
            _ => return None,
        })
    }

    /// Canonical scenario names, in registry order.
    pub fn scenario_names() -> &'static [&'static str] {
        &["fb-like", "mixed-rate", "tiny", "incast", "all-reduce", "diurnal", "adversarial-skew"]
    }

    /// A small trace for tests and the quickstart example.
    pub fn tiny(num_ports: usize, num_coflows: usize) -> Self {
        let mut spec = Self::fb_like(num_ports, num_coflows);
        spec.mean_interarrival = 0.5;
        for c in &mut spec.classes {
            c.mappers.1 = c.mappers.1.min(num_ports);
            c.reducers.1 = c.reducers.1.min(num_ports);
            c.flow_mb_median = (c.flow_mb_median / 4.0).max(0.25);
        }
        spec
    }

    /// Uniform-skew variant: every class uses lognormal σ `sigma`, so
    /// `max/min` within a coflow grows with σ — the §2.2 skew sweep.
    pub fn with_skew_sigma(mut self, sigma: f64) -> Self {
        for c in &mut self.classes {
            c.flow_mb_sigma = sigma;
        }
        self
    }

    /// Scale offered load by shrinking/stretching inter-arrival gaps.
    pub fn with_load_factor(mut self, load: f64) -> Self {
        assert!(load > 0.0, "load factor must be positive");
        self.mean_interarrival /= load;
        self
    }

    /// Set the RNG seed (builder style).
    pub fn seed(mut self, seed: u64) -> Self {
        self.rng_seed = seed;
        self
    }

    /// Attach an SLO model (builder style) — see [`DeadlineModel`].
    pub fn with_deadlines(mut self, model: DeadlineModel) -> Self {
        self.deadline = Some(model);
        self
    }

    /// Shorthand for [`TraceSpec::with_deadlines`] with
    /// [`DeadlineModel::tightness`] (default spread, full coverage).
    pub fn with_deadline_tightness(self, tightness: f64) -> Self {
        self.with_deadlines(DeadlineModel::tightness(tightness))
    }

    /// The streaming form of this spec: yields [`CoflowArrival`]s one at a
    /// time in O(active) memory. [`TraceSpec::generate`] is the drain of
    /// this stream, so materialized and streamed workloads are
    /// bit-identical by construction.
    pub fn stream(&self) -> SpecStream {
        SpecStream::new(self)
    }

    /// Instantaneous diurnal load multiplier at trace time `t` (1.0 when
    /// modulation is off).
    pub fn diurnal_load(&self, t: Time) -> f64 {
        if self.diurnal_amplitude <= 0.0 {
            return 1.0;
        }
        let phase = 2.0 * std::f64::consts::PI * t / self.diurnal_period.max(1e-9);
        1.0 + self.diurnal_amplitude * 0.5 * (1.0 + phase.sin())
    }

    /// Generate the trace by draining [`TraceSpec::stream`].
    pub fn generate(&self) -> Trace {
        let mut stream = self.stream();
        let mut trace = Trace {
            num_ports: self.num_ports,
            coflows: Vec::with_capacity(self.num_coflows),
            flows: Vec::new(),
        };
        let mut arrival = CoflowArrival::default();
        while stream.next_arrival(&mut arrival) {
            trace.push_arrival(&arrival);
        }
        trace
    }

    pub(crate) fn pick_class(&self, rng: &mut Rng, total_w: f64) -> &CoflowClass {
        let mut x = rng.f64() * total_w;
        for c in &self.classes {
            if x < c.weight {
                return c;
            }
            x -= c.weight;
        }
        self.classes.last().unwrap()
    }
}


#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let a = TraceSpec::fb_like(50, 40).seed(9).generate();
        let b = TraceSpec::fb_like(50, 40).seed(9).generate();
        assert_eq!(a.coflows.len(), b.coflows.len());
        assert_eq!(a.flows.len(), b.flows.len());
        for (x, y) in a.flows.iter().zip(b.flows.iter()) {
            assert_eq!(x, y);
        }
        let c = TraceSpec::fb_like(50, 40).seed(10).generate();
        let diverged = a.flows.len() != c.flows.len()
            || a.flows.iter().zip(c.flows.iter()).any(|(x, y)| x != y);
        assert!(diverged);
    }

    #[test]
    fn respects_counts_and_port_range() {
        let t = TraceSpec::fb_like(150, 100).seed(1).generate();
        assert_eq!(t.num_ports, 150);
        assert_eq!(t.coflows.len(), 100);
        for f in &t.flows {
            assert!(f.src < 150 && f.dst < 150);
            assert!(f.size > 0.0);
        }
        // arrivals are sorted and span a realistic window
        for w in t.coflows.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn bytes_dominated_by_wide_coflows() {
        let t = TraceSpec::fb_like(150, 526).seed(42).generate();
        let total = t.total_bytes();
        let wide_bytes: f64 = t
            .coflows
            .iter()
            .filter(|c| c.width() >= 30)
            .flat_map(|c| c.flows.iter().map(|&f| t.flows[f].size))
            .sum();
        // the long-wide class must dominate total bytes (FB property)
        assert!(
            wide_bytes / total > 0.5,
            "wide coflows carry {:.0}% of bytes",
            100.0 * wide_bytes / total
        );
        // ...while most coflows are narrow by count
        let narrow_count = t.coflows.iter().filter(|c| c.width() <= 10).count();
        assert!(narrow_count as f64 / t.coflows.len() as f64 > 0.5);
    }

    #[test]
    fn skew_sigma_increases_intra_coflow_skew() {
        let lo = TraceSpec::fb_like(60, 80).with_skew_sigma(0.1).seed(3).generate();
        let hi = TraceSpec::fb_like(60, 80).with_skew_sigma(2.0).seed(3).generate();
        let avg_skew = |t: &Trace| {
            let oracles = t.oracles();
            let mut skews: Vec<f64> = t
                .coflows
                .iter()
                .zip(&oracles)
                .filter(|(c, _)| c.num_flows() > 1)
                .map(|(_, o)| o.skew())
                .filter(|s| s.is_finite())
                .collect();
            skews.sort_by(f64::total_cmp);
            skews[skews.len() / 2]
        };
        assert!(avg_skew(&hi) > avg_skew(&lo) * 2.0);
    }

    #[test]
    fn mixed_rate_scenario_builds_heterogeneous_fabric() {
        let spec = TraceSpec::mixed_rate(10, 20);
        let f = spec.fabric();
        assert_eq!(f.num_ports, 10);
        assert_eq!(f.up_capacity[0], crate::GBPS);
        assert_eq!(f.up_capacity[2], 10.0 * crate::GBPS);
        assert_eq!(f.up_capacity[3], 40.0 * crate::GBPS);
        // the trace itself is unchanged workload-wise
        let t = spec.generate();
        assert_eq!(t.coflows.len(), 20);
        // homogeneous default stays the paper's 1 Gbps testbed
        let homo = TraceSpec::fb_like(10, 20).fabric();
        assert!(homo.up_capacity.iter().all(|&c| c == crate::GBPS));
    }

    #[test]
    fn load_factor_compresses_arrivals() {
        let base = TraceSpec::fb_like(50, 60).seed(5).generate();
        let hot = TraceSpec::fb_like(50, 60).with_load_factor(4.0).seed(5).generate();
        assert!(hot.makespan_lower_bound() < base.makespan_lower_bound());
    }

    #[test]
    fn deadline_model_does_not_perturb_the_workload() {
        // the SLO model draws from its own RNG stream: flows and arrivals
        // must be bit-identical with and without it
        let plain = TraceSpec::fb_like(50, 60).seed(5).generate();
        let slo = TraceSpec::fb_like(50, 60)
            .seed(5)
            .with_deadline_tightness(2.0)
            .generate();
        assert_eq!(plain.flows, slo.flows);
        for (a, b) in plain.coflows.iter().zip(slo.coflows.iter()) {
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(a.flows, b.flows);
            assert!(a.deadline.is_none());
            let d = b.deadline.expect("full coverage assigns every coflow");
            assert!(d > b.arrival, "deadline must lie after arrival");
        }
        // deterministic given the seed
        let again = TraceSpec::fb_like(50, 60)
            .seed(5)
            .with_deadline_tightness(2.0)
            .generate();
        for (a, b) in slo.coflows.iter().zip(again.coflows.iter()) {
            assert_eq!(a.deadline, b.deadline);
        }
    }

    #[test]
    fn scenario_registry_resolves_all_names() {
        for &name in TraceSpec::scenario_names() {
            let spec = TraceSpec::scenario(name, 50, 20).unwrap_or_else(|| panic!("{name}"));
            let t = spec.generate();
            assert_eq!(t.coflows.len(), 20, "{name}");
            assert_eq!(t.num_ports, 50, "{name}");
        }
        assert!(TraceSpec::scenario("no-such-scenario", 10, 10).is_none());
    }

    #[test]
    fn scenario_determinism_pins() {
        // same seed → same trace, per scenario; distinct scenario streams
        // must not collide with fb_like's
        let fb = TraceSpec::fb_like(60, 40).generate();
        for &name in &["incast", "all-reduce", "diurnal", "adversarial-skew"] {
            let a = TraceSpec::scenario(name, 60, 40).unwrap().generate();
            let b = TraceSpec::scenario(name, 60, 40).unwrap().generate();
            assert_eq!(a.flows, b.flows, "{name}");
            for (x, y) in a.coflows.iter().zip(b.coflows.iter()) {
                assert_eq!(x.arrival.to_bits(), y.arrival.to_bits(), "{name}");
            }
            let same_as_fb = a.flows.len() == fb.flows.len()
                && a.flows.iter().zip(fb.flows.iter()).all(|(x, y)| x == y);
            assert!(!same_as_fb, "{name} collides with fb_like");
        }
    }

    #[test]
    fn incast_is_many_to_one() {
        let t = TraceSpec::incast(150, 60).generate();
        for c in &t.coflows {
            assert_eq!(c.receivers.len(), 1, "incast coflow has one reducer");
            assert!(c.senders.len() >= 8, "incast fan-in is wide");
        }
    }

    #[test]
    fn all_reduce_builds_rings() {
        let t = TraceSpec::all_reduce(100, 50).generate();
        for c in &t.coflows {
            let w = c.senders.len();
            assert!(w >= 2, "ring spans at least two workers");
            assert_eq!(c.flows.len(), w, "one flow per ring link");
            assert_eq!(c.senders, c.receivers, "every worker sends and receives");
            // each worker appears exactly once as src and once as dst,
            // and all chunks are equal
            let mut out_deg = std::collections::HashMap::new();
            let mut in_deg = std::collections::HashMap::new();
            let first = t.flows[c.flows[0]].size;
            for &fid in &c.flows {
                let f = &t.flows[fid];
                *out_deg.entry(f.src).or_insert(0) += 1;
                *in_deg.entry(f.dst).or_insert(0) += 1;
                assert_eq!(f.size.to_bits(), first.to_bits());
            }
            assert!(out_deg.values().all(|&d| d == 1));
            assert!(in_deg.values().all(|&d| d == 1));
        }
    }

    #[test]
    fn diurnal_compresses_peak_arrivals() {
        let spec = TraceSpec::diurnal(60, 400);
        // the load multiplier swings between 1× and (1+amplitude)×
        assert!((spec.diurnal_load(0.0) - (1.0 + spec.diurnal_amplitude / 2.0)).abs() < 1e-9);
        let peak_t = spec.diurnal_period / 4.0; // sin = 1
        assert!((spec.diurnal_load(peak_t) - (1.0 + spec.diurnal_amplitude)).abs() < 1e-9);
        // the modulated trace finishes arriving sooner than the flat one
        let flat = {
            let mut s = spec.clone();
            s.diurnal_amplitude = 0.0;
            s.generate()
        };
        let wavy = spec.generate();
        assert!(wavy.makespan_lower_bound() < flat.makespan_lower_bound());
        // amplitude 0 keeps the legacy arrival process bit-identical
        let fb = TraceSpec::fb_like(60, 400).seed(79).generate();
        for (a, b) in flat.coflows.iter().zip(fb.coflows.iter()) {
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        }
    }

    #[test]
    fn adversarial_skew_has_extreme_and_uniform_classes() {
        let t = TraceSpec::adversarial_skew(80, 120).generate();
        let oracles = t.oracles();
        let skews: Vec<f64> = t
            .coflows
            .iter()
            .zip(&oracles)
            .filter(|(c, _)| c.num_flows() > 1)
            .map(|(_, o)| o.skew())
            .filter(|s| s.is_finite())
            .collect();
        let max_skew = skews.iter().cloned().fold(0.0, f64::max);
        let min_skew = skews.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max_skew > 50.0, "heavy tail missing (max skew {max_skew})");
        assert!(min_skew < 1.5, "decoy class missing (min skew {min_skew})");
    }

    #[test]
    fn tighter_model_yields_earlier_deadlines() {
        let tight = TraceSpec::fb_like(40, 40)
            .seed(3)
            .with_deadlines(DeadlineModel { tightness: 1.2, spread: 0.0, coverage: 1.0 })
            .generate();
        let loose = TraceSpec::fb_like(40, 40)
            .seed(3)
            .with_deadlines(DeadlineModel { tightness: 4.0, spread: 0.0, coverage: 1.0 })
            .generate();
        for (a, b) in tight.coflows.iter().zip(loose.coflows.iter()) {
            let (da, db) = (a.deadline.unwrap(), b.deadline.unwrap());
            assert!(da <= db, "tightness 1.2 gave a later deadline than 4.0");
        }
    }
}
