//! Trace toolkit: the coflow-benchmark trace format, an FB-like synthetic
//! generator, port-replication (the paper's 900-port derivation), and the
//! wide-coflow filter.
//!
//! ## Substitution note (DESIGN.md §3)
//!
//! The paper replays a production Facebook trace (526 coflows, 150 ports,
//! one hour of a 3000-machine cluster) in the standard coflow-benchmark
//! format. That trace is an external artifact; [`TraceSpec::fb_like`]
//! generates a synthetic trace with the published marginals (port count,
//! coflow count, arrival process, the narrow/wide × short/long mix in which
//! most coflows are small but large coflows dominate bytes, heavy-tailed
//! flow sizes with intra-coflow skew). [`Trace::load`] reads the real file
//! format, so the genuine trace drops in unchanged if present.

mod format;
mod generator;
mod stream;

pub use format::{parse_trace, render_trace};
pub use generator::{CoflowClass, DeadlineModel, FlowPattern, TraceSpec};
pub use stream::{ArrivalStream, CoflowArrival, SpecStream, TraceStream};

use crate::coflow::{CoflowOracle, CoflowSpec, FlowSpec};
use crate::fabric::Fabric;
use crate::util::Rng;
use crate::{Time, MB};
use anyhow::Result;
use std::path::Path;

/// A fully expanded workload: ports, coflows, and the global flow table.
#[derive(Debug, Clone)]
pub struct Trace {
    pub num_ports: usize,
    pub coflows: Vec<CoflowSpec>,
    pub flows: Vec<FlowSpec>,
}

impl Trace {
    /// Assemble a trace from raw (arrival, mappers, reducer:bytes) records,
    /// expanding every mapper×reducer pair into a flow whose size is the
    /// reducer total divided by the mapper count — exactly how the FB
    /// benchmark defines flow sizes.
    pub fn from_records(num_ports: usize, records: Vec<TraceRecord>) -> Self {
        let mut coflows = Vec::with_capacity(records.len());
        let mut flows = Vec::new();
        for (cid, rec) in records.into_iter().enumerate() {
            let mut flow_ids = Vec::with_capacity(rec.mappers.len() * rec.reducers.len());
            for &(dst, reducer_bytes) in &rec.reducers {
                let per_flow = reducer_bytes / rec.mappers.len() as f64;
                for &src in &rec.mappers {
                    let id = flows.len();
                    flows.push(FlowSpec { id, coflow: cid, src, dst, size: per_flow });
                    flow_ids.push(id);
                }
            }
            let mut senders = rec.mappers.clone();
            senders.sort_unstable();
            senders.dedup();
            let mut receivers: Vec<_> = rec.reducers.iter().map(|&(p, _)| p).collect();
            receivers.sort_unstable();
            receivers.dedup();
            coflows.push(CoflowSpec {
                id: cid,
                external_id: rec.external_id,
                arrival: rec.arrival,
                deadline: rec.deadline,
                flows: flow_ids,
                senders,
                receivers,
            });
        }
        Trace { num_ports, coflows, flows }
    }

    /// Append one pre-expanded [`CoflowArrival`] (the streaming unit) as
    /// the next coflow. Flow expansion order is the arrival's `flows`
    /// order, which for bipartite patterns matches
    /// [`Trace::from_records`] exactly — [`TraceSpec::generate`] drains a
    /// stream through this.
    pub fn push_arrival(&mut self, a: &CoflowArrival) {
        let cid = self.coflows.len();
        let mut flow_ids = Vec::with_capacity(a.flows.len());
        for &(src, dst, size) in &a.flows {
            let id = self.flows.len();
            self.flows.push(FlowSpec { id, coflow: cid, src, dst, size });
            flow_ids.push(id);
        }
        self.coflows.push(CoflowSpec {
            id: cid,
            external_id: a.external_id,
            arrival: a.arrival,
            deadline: a.deadline,
            flows: flow_ids,
            senders: a.senders.clone(),
            receivers: a.receivers.clone(),
        });
    }

    /// Load a coflow-benchmark format trace file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        parse_trace(&text)
    }

    /// Save in coflow-benchmark format (lossy: flow sizes re-aggregate to
    /// per-reducer MB).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, render_trace(self))?;
        Ok(())
    }

    /// The paper's Table 2 “Wide-coflow-only” filter: keep coflows that are
    /// present on more than one sender or receiver port.
    pub fn wide_only(&self) -> Trace {
        let records: Vec<TraceRecord> = self
            .coflows
            .iter()
            .filter(|c| c.is_wide())
            .map(|c| self.record_of(c))
            .collect();
        Trace::from_records(self.num_ports, records)
    }

    /// Derive a `k×`-port trace exactly as §4.3: replicate every coflow `k`
    /// times, same arrival times, sender/receiver ports shifted by
    /// `i × num_ports` for copy `i`.
    pub fn replicate(&self, k: usize) -> Trace {
        let mut records = Vec::with_capacity(self.coflows.len() * k);
        for c in &self.coflows {
            for i in 0..k {
                let off = i * self.num_ports;
                let mut rec = self.record_of(c);
                rec.external_id = rec.external_id * k as u64 + i as u64;
                for m in &mut rec.mappers {
                    *m += off;
                }
                for r in &mut rec.reducers {
                    r.0 += off;
                }
                records.push(rec);
            }
        }
        // Keep arrival-sorted order so dense ids stay arrival-monotone.
        records.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        Trace::from_records(self.num_ports * k, records)
    }

    /// Re-derive the raw record of a coflow (inverse of `from_records`).
    fn record_of(&self, c: &CoflowSpec) -> TraceRecord {
        let mappers = c.senders.clone();
        let mut reducers: Vec<(usize, f64)> = c.receivers.iter().map(|&p| (p, 0.0)).collect();
        for &fid in &c.flows {
            let f = &self.flows[fid];
            if let Some(r) = reducers.iter_mut().find(|(p, _)| *p == f.dst) {
                r.1 += f.size;
            }
        }
        TraceRecord {
            external_id: c.external_id,
            arrival: c.arrival,
            deadline: c.deadline,
            mappers,
            reducers,
        }
    }

    /// Attach per-coflow completion deadlines (SLO model, DCoflow-style —
    /// arXiv 2205.01229): every covered coflow gets
    /// `deadline = arrival + tightness × ideal CCT`, where the ideal CCT is
    /// the coflow's bottleneck bound on `fabric` (max over its ports of the
    /// bytes it must move through that port divided by the port's line
    /// rate) and the tightness factor is drawn from `model`'s distribution.
    /// Deadline assignment draws from its own seeded RNG, so the flows and
    /// arrivals of the trace are **bit-identical** with and without
    /// deadlines — deadline-blind schedulers cannot tell the difference.
    pub fn assign_deadlines(&mut self, model: &DeadlineModel, fabric: &Fabric, seed: u64) {
        assert_eq!(
            fabric.num_ports, self.num_ports,
            "deadline fabric must cover the trace's ports"
        );
        let mut rng = Rng::seed_from_u64(seed ^ 0xDEAD_11E5_C0F1_0035);
        let mut up = vec![0.0f64; self.num_ports];
        let mut down = vec![0.0f64; self.num_ports];
        let mut touched: Vec<usize> = Vec::new();
        for c in &mut self.coflows {
            if !rng.chance(model.coverage) {
                c.deadline = None;
                continue;
            }
            let tightness = model.tightness * (1.0 + rng.f64() * model.spread);
            for &fid in &c.flows {
                let f = &self.flows[fid];
                if up[f.src] == 0.0 {
                    touched.push(f.src);
                }
                if down[f.dst] == 0.0 {
                    touched.push(f.dst);
                }
                up[f.src] += f.size;
                down[f.dst] += f.size;
            }
            let mut ideal: Time = 0.0;
            for &p in c.senders.iter() {
                ideal = ideal.max(up[p] / fabric.up_capacity[p].max(1.0));
            }
            for &p in c.receivers.iter() {
                ideal = ideal.max(down[p] / fabric.down_capacity[p].max(1.0));
            }
            for &p in &touched {
                up[p] = 0.0;
                down[p] = 0.0;
            }
            touched.clear();
            c.deadline = Some(c.arrival + tightness * ideal);
        }
    }

    /// Oracle aggregates for every coflow (for clairvoyant baselines and
    /// analysis).
    pub fn oracles(&self) -> Vec<CoflowOracle> {
        self.coflows
            .iter()
            .map(|c| CoflowOracle::compute(c, &self.flows, self.num_ports))
            .collect()
    }

    /// Total bytes across the whole trace.
    pub fn total_bytes(&self) -> f64 {
        self.flows.iter().map(|f| f.size).sum()
    }

    /// Span of arrivals in seconds.
    pub fn makespan_lower_bound(&self) -> Time {
        self.coflows
            .iter()
            .map(|c| c.arrival)
            .fold(0.0, f64::max)
    }
}

/// One line of a coflow-benchmark trace: a coflow with its mapper ports and
/// per-reducer (port, total bytes) pairs. `Default` (an empty record) is
/// what a recycled registration buffer starts from — see
/// [`crate::runtime::evloop::BufferPool`] and the `CoflowOp::Register`
/// recycle path.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TraceRecord {
    pub external_id: u64,
    /// Arrival in seconds.
    pub arrival: Time,
    /// Optional completion deadline in seconds (absolute, same clock as
    /// `arrival`). `None` = no SLO; the trace format carries it behind an
    /// optional `deadline:<ms>` column so deadline-free traces stay valid.
    pub deadline: Option<Time>,
    pub mappers: Vec<usize>,
    /// (reducer port, total bytes received by that reducer).
    pub reducers: Vec<(usize, f64)>,
}

impl TraceRecord {
    /// Convenience for tests: a coflow with uniform per-reducer size in MB.
    pub fn uniform(external_id: u64, arrival: Time, mappers: Vec<usize>, reducer_ports: Vec<usize>, reducer_mb: f64) -> Self {
        TraceRecord {
            external_id,
            arrival,
            deadline: None,
            mappers,
            reducers: reducer_ports.into_iter().map(|p| (p, reducer_mb * MB)).collect(),
        }
    }

    /// Builder-style deadline (absolute seconds).
    pub fn with_deadline(mut self, deadline: Time) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_coflow_trace() -> Trace {
        Trace::from_records(
            4,
            vec![
                TraceRecord::uniform(1, 0.0, vec![0, 1], vec![2, 3], 10.0),
                TraceRecord::uniform(2, 1.0, vec![0], vec![2], 5.0),
            ],
        )
    }

    #[test]
    fn expansion_counts_and_sizes() {
        let t = two_coflow_trace();
        assert_eq!(t.coflows.len(), 2);
        // coflow 0: 2 mappers × 2 reducers = 4 flows of 5 MB each
        assert_eq!(t.coflows[0].num_flows(), 4);
        assert!((t.flows[0].size - 5.0 * MB).abs() < 1e-6);
        // coflow 1: 1×1
        assert_eq!(t.coflows[1].num_flows(), 1);
        assert!((t.total_bytes() - 25.0 * MB).abs() < 1e-3);
    }

    #[test]
    fn wide_only_drops_narrow() {
        let t = two_coflow_trace();
        let w = t.wide_only();
        assert_eq!(w.coflows.len(), 1);
        assert_eq!(w.coflows[0].external_id, 1);
    }

    #[test]
    fn replicate_shifts_ports_and_preserves_arrivals() {
        let t = two_coflow_trace();
        let r = t.replicate(3);
        assert_eq!(r.num_ports, 12);
        assert_eq!(r.coflows.len(), 6);
        assert!((r.total_bytes() - 3.0 * t.total_bytes()).abs() < 1e-3);
        // every copy keeps its arrival time
        let arrivals: Vec<_> = r.coflows.iter().map(|c| c.arrival).collect();
        assert_eq!(arrivals.iter().filter(|&&a| a == 0.0).count(), 3);
        assert_eq!(arrivals.iter().filter(|&&a| a == 1.0).count(), 3);
        // port shifts: some coflow uses port 0+4=4 or 0+8=8 as mapper
        assert!(r.coflows.iter().any(|c| c.senders.contains(&4)));
        assert!(r.coflows.iter().any(|c| c.senders.contains(&8)));
        // no copy crosses its 4-port slice
        for c in &r.coflows {
            let slice = c.senders[0] / 4;
            for &p in c.senders.iter().chain(c.receivers.iter()) {
                assert_eq!(p / 4, slice);
            }
        }
    }

    #[test]
    fn record_roundtrip_through_from_records() {
        let t = two_coflow_trace();
        let rec = t.record_of(&t.coflows[0]);
        assert_eq!(rec.mappers, vec![0, 1]);
        assert_eq!(rec.reducers.len(), 2);
        assert!((rec.reducers[0].1 - 10.0 * MB).abs() < 1e-3);
        assert_eq!(rec.deadline, None);
    }

    #[test]
    fn assign_deadlines_sets_tightness_times_bottleneck() {
        let mut t = two_coflow_trace();
        let fabric = crate::fabric::Fabric::gbps(4);
        let model = DeadlineModel { tightness: 2.0, spread: 0.0, coverage: 1.0 };
        t.assign_deadlines(&model, &fabric, 7);
        // coflow 1: single 5 MB flow → ideal = 5 MB / 1 Gbps, arrival 1.0
        let ideal = 5.0 * MB / crate::GBPS;
        let d = t.coflows[1].deadline.expect("deadline assigned");
        assert!((d - (1.0 + 2.0 * ideal)).abs() < 1e-9, "deadline {d}");
        // coflow 0: 10 MB per reducer is the bottleneck
        let ideal0 = 10.0 * MB / crate::GBPS;
        let d0 = t.coflows[0].deadline.expect("deadline assigned");
        assert!((d0 - 2.0 * ideal0).abs() < 1e-9, "deadline {d0}");
        // deadlines survive the record round-trip (replicate/wide_only path)
        let rec = t.record_of(&t.coflows[0]);
        assert_eq!(rec.deadline, t.coflows[0].deadline);
        let rebuilt = Trace::from_records(4, vec![rec]);
        assert_eq!(rebuilt.coflows[0].deadline, t.coflows[0].deadline);
    }

    #[test]
    fn assign_deadlines_coverage_zero_leaves_trace_slo_free() {
        let mut t = two_coflow_trace();
        let model = DeadlineModel { tightness: 2.0, spread: 0.5, coverage: 0.0 };
        t.assign_deadlines(&model, &crate::fabric::Fabric::gbps(4), 7);
        assert!(t.coflows.iter().all(|c| c.deadline.is_none()));
    }
}
