//! Streaming arrival sources: bounded-memory trace iteration.
//!
//! A materialized [`Trace`] holds every flow of every coflow up front —
//! fine at bench scale (hundreds of coflows), prohibitive at the
//! million-coflow scale ROADMAP item 3 targets. An [`ArrivalStream`]
//! instead yields one [`CoflowArrival`] at a time, in non-decreasing
//! arrival order, into a caller-owned buffer; the engine admits each
//! coflow only when simulated time reaches it and retires its heavy state
//! once it completes, so resident memory tracks the *concurrent* coflow
//! population, not the trace length.
//!
//! Two implementations:
//!
//! - [`SpecStream`] generates arrivals directly from a [`TraceSpec`],
//!   replaying **exactly** the RNG draw sequence of
//!   [`TraceSpec::generate`] — a materialized trace and its stream are
//!   bit-identical by construction (`generate` is itself implemented by
//!   draining the stream).
//! - [`TraceStream`] replays an already-materialized [`Trace`] in
//!   (arrival, id) order — the equivalence-pin bridge between the two
//!   engine paths.

use super::generator::{FlowPattern, TraceSpec};
use super::Trace;
use crate::fabric::Fabric;
use crate::util::{Rng, SampleScratch};
use crate::{Bytes, CoflowId, PortId, Time, MB};

/// One coflow arrival, fully expanded to flows. Reused as an output
/// buffer by [`ArrivalStream::next_arrival`] so steady-state streaming
/// does not allocate per coflow.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CoflowArrival {
    pub external_id: u64,
    /// Arrival in seconds.
    pub arrival: Time,
    /// Optional absolute completion deadline (same clock as `arrival`).
    pub deadline: Option<Time>,
    /// `(src, dst, size)` per flow, in canonical expansion order
    /// (reducer-major for bipartite patterns — exactly the order
    /// [`Trace::from_records`] produces).
    pub flows: Vec<(PortId, PortId, Bytes)>,
    /// Distinct sender ports, sorted ascending.
    pub senders: Vec<PortId>,
    /// Distinct receiver ports, sorted ascending.
    pub receivers: Vec<PortId>,
}

impl CoflowArrival {
    /// Total bytes across the coflow's flows.
    pub fn total_bytes(&self) -> Bytes {
        self.flows.iter().map(|&(_, _, s)| s).sum()
    }
}

/// A source of coflow arrivals in non-decreasing arrival order.
pub trait ArrivalStream {
    /// Port count of the fabric the arrivals are defined over.
    fn num_ports(&self) -> usize;

    /// Fill `out` with the next arrival; returns `false` when the stream
    /// is exhausted (`out` is then unspecified). Arrivals must be
    /// non-decreasing — the engine asserts this.
    fn next_arrival(&mut self, out: &mut CoflowArrival) -> bool;

    /// Number of arrivals still to come, when known (sizing hint only).
    fn remaining_hint(&self) -> Option<usize> {
        None
    }
}

/// Streaming generator for a [`TraceSpec`]: yields the spec's coflows one
/// at a time with O(active) memory. The RNG draw sequence per coflow is
/// identical to the historical materializing generator (gap → class →
/// port counts → port samples → per-reducer sizes), and deadline draws
/// come from the same decorrelated second stream `assign_deadlines` uses,
/// consumed in the same per-coflow order — so
/// `spec.generate()` == "drain `spec.stream()`" holds bitwise.
pub struct SpecStream {
    spec: TraceSpec,
    rng: Rng,
    /// Decorrelated deadline stream (present iff the spec has an SLO
    /// model) — same derivation as [`Trace::assign_deadlines`].
    deadline_rng: Option<Rng>,
    fabric: Fabric,
    total_w: f64,
    t: Time,
    emitted: usize,
    sample: SampleScratch,
    mappers: Vec<usize>,
    reducers: Vec<usize>,
    // per-port ideal-CCT scratch for inline deadline assignment
    up: Vec<f64>,
    down: Vec<f64>,
    touched: Vec<usize>,
}

impl SpecStream {
    pub(super) fn new(spec: &TraceSpec) -> Self {
        assert!(spec.num_ports >= 1, "need at least one port");
        assert!(!spec.classes.is_empty(), "need at least one coflow class");
        let np = spec.num_ports;
        let has_deadline = spec.deadline.is_some();
        SpecStream {
            rng: Rng::seed_from_u64(spec.rng_seed),
            deadline_rng: has_deadline
                .then(|| Rng::seed_from_u64(spec.rng_seed ^ 0xDEAD_11E5_C0F1_0035)),
            fabric: spec.fabric(),
            total_w: spec.classes.iter().map(|c| c.weight).sum(),
            t: 0.0,
            emitted: 0,
            sample: SampleScratch::new(),
            mappers: Vec::new(),
            reducers: Vec::new(),
            up: if has_deadline { vec![0.0; np] } else { Vec::new() },
            down: if has_deadline { vec![0.0; np] } else { Vec::new() },
            touched: Vec::new(),
            spec: spec.clone(),
        }
    }

    /// Inline equivalent of [`Trace::assign_deadlines`] for one arrival:
    /// same RNG draws, same flow-order byte accumulation, same
    /// bottleneck fold.
    fn assign_deadline(&mut self, out: &mut CoflowArrival) {
        let Some(model) = self.spec.deadline else {
            out.deadline = None;
            return;
        };
        let drng = self.deadline_rng.as_mut().expect("deadline stream");
        if !drng.chance(model.coverage) {
            out.deadline = None;
            return;
        }
        let tightness = model.tightness * (1.0 + drng.f64() * model.spread);
        for &(src, dst, size) in &out.flows {
            if self.up[src] == 0.0 {
                self.touched.push(src);
            }
            if self.down[dst] == 0.0 {
                self.touched.push(dst);
            }
            self.up[src] += size;
            self.down[dst] += size;
        }
        let mut ideal: Time = 0.0;
        for &p in &out.senders {
            ideal = ideal.max(self.up[p] / self.fabric.up_capacity[p].max(1.0));
        }
        for &p in &out.receivers {
            ideal = ideal.max(self.down[p] / self.fabric.down_capacity[p].max(1.0));
        }
        for &p in &self.touched {
            self.up[p] = 0.0;
            self.down[p] = 0.0;
        }
        self.touched.clear();
        out.deadline = Some(out.arrival + tightness * ideal);
    }
}

impl ArrivalStream for SpecStream {
    fn num_ports(&self) -> usize {
        self.spec.num_ports
    }

    fn remaining_hint(&self) -> Option<usize> {
        Some(self.spec.num_coflows - self.emitted)
    }

    fn next_arrival(&mut self, out: &mut CoflowArrival) -> bool {
        if self.emitted >= self.spec.num_coflows {
            return false;
        }
        let ext = self.emitted;
        self.emitted += 1;
        if ext > 0 {
            let gap = if self.rng.chance(self.spec.burstiness) {
                self.rng.exp(self.spec.burst_gap.max(1e-9))
            } else {
                self.rng.exp(self.spec.mean_interarrival.max(1e-9))
            };
            // Diurnal modulation compresses gaps at peak load; the gate
            // keeps amplitude-0 specs bit-identical to the historical
            // generator (no division on the legacy path).
            self.t += if self.spec.diurnal_amplitude > 0.0 {
                gap / self.spec.diurnal_load(self.t)
            } else {
                gap
            };
        }
        out.external_id = ext as u64 + 1;
        out.arrival = self.t;
        out.flows.clear();
        out.senders.clear();
        out.receivers.clear();

        let class = *self.spec.pick_class(&mut self.rng, self.total_w);
        let cap = self.spec.num_ports;
        match self.spec.flow_pattern {
            FlowPattern::Bipartite => {
                let (m0, m1) = (class.mappers.0.min(cap), class.mappers.1.min(cap));
                let (r0, r1) = (class.reducers.0.min(cap), class.reducers.1.min(cap));
                let nm = self.rng.range_inclusive(m0, m1).max(1);
                let nr = self.rng.range_inclusive(r0, r1).max(1);
                self.sample.sample_into(&mut self.rng, cap, nm, &mut self.mappers);
                self.sample.sample_into(&mut self.rng, cap, nr, &mut self.reducers);
                // Draw a size per reducer aggregated over mappers so the
                // per-flow size (reducer_total / nm) follows the class
                // lognormal; expand reducer-major exactly like
                // `Trace::from_records`.
                for ri in 0..self.reducers.len() {
                    let dst = self.reducers[ri];
                    let per_flow_mb: f64 = self
                        .rng
                        .lognormal(class.flow_mb_median.ln(), class.flow_mb_sigma)
                        .clamp(0.01, 10_000.0);
                    let reducer_bytes = per_flow_mb * nm as f64 * MB;
                    let per_flow = reducer_bytes / self.mappers.len() as f64;
                    for &src in &self.mappers {
                        out.flows.push((src, dst, per_flow));
                    }
                }
                out.senders.extend_from_slice(&self.mappers);
                out.receivers.extend_from_slice(&self.reducers);
            }
            FlowPattern::Ring => {
                // All-reduce ring step: W workers (the class's mapper
                // range doubles as the worker-count range), one chunk
                // size per coflow, flows worker[i] → worker[i+1 mod W].
                let (w0, w1) = (class.mappers.0.min(cap), class.mappers.1.min(cap));
                let nw = self.rng.range_inclusive(w0, w1).max(1);
                self.sample.sample_into(&mut self.rng, cap, nw, &mut self.mappers);
                let chunk_mb: f64 = self
                    .rng
                    .lognormal(class.flow_mb_median.ln(), class.flow_mb_sigma)
                    .clamp(0.01, 10_000.0);
                let bytes = chunk_mb * MB;
                let nw = self.mappers.len();
                for i in 0..nw {
                    out.flows.push((self.mappers[i], self.mappers[(i + 1) % nw], bytes));
                }
                // every worker both sends and receives
                out.senders.extend_from_slice(&self.mappers);
                out.receivers.extend_from_slice(&self.mappers);
            }
        }
        self.assign_deadline(out);
        true
    }
}

/// Replay an already-materialized [`Trace`] as a stream, in (arrival, id)
/// order. For arrival-sorted traces (everything [`TraceSpec`] generates;
/// [`Trace::replicate`] re-sorts) the replay order equals id order, so a
/// streamed simulation assigns the same dense coflow/flow identities as
/// the materialized path and the two are bit-identical. Loaded trace
/// files are not guaranteed arrival-sorted; the stream is still valid,
/// but streamed coflow ids then follow arrival order, not file order.
pub struct TraceStream<'a> {
    trace: &'a Trace,
    order: Vec<CoflowId>,
    next: usize,
}

impl<'a> TraceStream<'a> {
    pub fn new(trace: &'a Trace) -> Self {
        let mut order: Vec<CoflowId> = (0..trace.coflows.len()).collect();
        order.sort_by(|&a, &b| {
            trace.coflows[a]
                .arrival
                .total_cmp(&trace.coflows[b].arrival)
                .then(a.cmp(&b))
        });
        TraceStream { trace, order, next: 0 }
    }
}

impl ArrivalStream for TraceStream<'_> {
    fn num_ports(&self) -> usize {
        self.trace.num_ports
    }

    fn remaining_hint(&self) -> Option<usize> {
        Some(self.order.len() - self.next)
    }

    fn next_arrival(&mut self, out: &mut CoflowArrival) -> bool {
        let Some(&cid) = self.order.get(self.next) else {
            return false;
        };
        self.next += 1;
        let c = &self.trace.coflows[cid];
        out.external_id = c.external_id;
        out.arrival = c.arrival;
        out.deadline = c.deadline;
        out.flows.clear();
        for &fid in &c.flows {
            let f = &self.trace.flows[fid];
            out.flows.push((f.src, f.dst, f.size));
        }
        out.senders.clear();
        out.senders.extend_from_slice(&c.senders);
        out.receivers.clear();
        out.receivers.extend_from_slice(&c.receivers);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_drains_to_the_materialized_trace() {
        // bitwise: generate() is implemented by draining the stream, so
        // compare a fresh stream drain against generate() output.
        for spec in [
            TraceSpec::fb_like(50, 40).seed(9),
            TraceSpec::mixed_rate(30, 25),
            TraceSpec::fb_like(50, 40).seed(5).with_deadline_tightness(2.0),
        ] {
            let trace = spec.generate();
            let mut stream = spec.stream();
            let mut a = CoflowArrival::default();
            let mut n = 0;
            while stream.next_arrival(&mut a) {
                let c = &trace.coflows[n];
                assert_eq!(a.external_id, c.external_id);
                assert_eq!(a.arrival.to_bits(), c.arrival.to_bits());
                assert_eq!(a.deadline.map(f64::to_bits), c.deadline.map(f64::to_bits));
                assert_eq!(a.senders, c.senders);
                assert_eq!(a.receivers, c.receivers);
                assert_eq!(a.flows.len(), c.flows.len());
                for (k, &fid) in c.flows.iter().enumerate() {
                    let f = &trace.flows[fid];
                    assert_eq!(a.flows[k].0, f.src);
                    assert_eq!(a.flows[k].1, f.dst);
                    assert_eq!(a.flows[k].2.to_bits(), f.size.to_bits());
                }
                n += 1;
            }
            assert_eq!(n, trace.coflows.len());
        }
    }

    #[test]
    fn trace_stream_replays_in_arrival_order() {
        let trace = TraceSpec::fb_like(40, 30).seed(4).generate();
        let mut stream = TraceStream::new(&trace);
        assert_eq!(stream.remaining_hint(), Some(30));
        let mut a = CoflowArrival::default();
        let mut last = f64::NEG_INFINITY;
        let mut n = 0;
        while stream.next_arrival(&mut a) {
            assert!(a.arrival >= last);
            last = a.arrival;
            assert_eq!(a.external_id, trace.coflows[n].external_id);
            n += 1;
        }
        assert_eq!(n, 30);
    }

    #[test]
    fn streams_are_bounded_buffers_not_materializations() {
        // the output buffer is caller-owned and reused; a million-coflow
        // spec costs O(1) to construct and O(arrival) to step
        let spec = TraceSpec::fb_like(100, 1_000_000);
        let mut stream = spec.stream();
        let mut a = CoflowArrival::default();
        for _ in 0..100 {
            assert!(stream.next_arrival(&mut a));
        }
        assert_eq!(stream.remaining_hint(), Some(1_000_000 - 100));
    }
}
