//! Coflow-benchmark trace file format (the format the FB trace ships in).
//!
//! ```text
//! <num_ports> <num_coflows>
//! <id> <arrival_ms> <num_mappers> <m1> ... <num_reducers> <r1:mb> <r2:mb> ... [deadline:<ms>]
//! ```
//!
//! Ports are 1-based in the file (as in the published trace) and 0-based in
//! memory. Reducer entries are `port:size_in_MB`.
//!
//! The trailing `deadline:<ms>` column is **optional** per line (an SLO
//! extension for the deadline workload family, `trace::DeadlineModel`):
//! lines without it parse exactly as before, so every published
//! coflow-benchmark trace stays valid, and rendering only emits the column
//! for coflows that carry a deadline.

use super::{Trace, TraceRecord};
use crate::MB;
use anyhow::{bail, Context, Result};

/// Parse a coflow-benchmark trace file body.
pub fn parse_trace(text: &str) -> Result<Trace> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().context("empty trace file")?;
    let mut it = header.split_whitespace();
    let num_ports: usize = it
        .next()
        .context("missing port count")?
        .parse()
        .context("bad port count")?;
    let num_coflows: usize = it
        .next()
        .context("missing coflow count")?
        .parse()
        .context("bad coflow count")?;

    let mut records = Vec::with_capacity(num_coflows);
    for (lineno, line) in lines.enumerate() {
        let rec = parse_record(line)
            .with_context(|| format!("trace line {} malformed: {line:?}", lineno + 2))?;
        for &m in &rec.mappers {
            if m >= num_ports {
                bail!("mapper port {} out of range (num_ports={num_ports})", m + 1);
            }
        }
        for &(r, _) in &rec.reducers {
            if r >= num_ports {
                bail!("reducer port {} out of range (num_ports={num_ports})", r + 1);
            }
        }
        records.push(rec);
    }
    if records.len() != num_coflows {
        bail!("header says {num_coflows} coflows, file has {}", records.len());
    }
    Ok(Trace::from_records(num_ports, records))
}

fn parse_record(line: &str) -> Result<TraceRecord> {
    let mut it = line.split_whitespace();
    let external_id: u64 = it.next().context("missing id")?.parse()?;
    let arrival_ms: f64 = it.next().context("missing arrival")?.parse()?;
    let nm: usize = it.next().context("missing mapper count")?.parse()?;
    let mut mappers = Vec::with_capacity(nm);
    for _ in 0..nm {
        let p: usize = it.next().context("missing mapper port")?.parse()?;
        if p == 0 {
            bail!("ports are 1-based in trace files");
        }
        mappers.push(p - 1);
    }
    let nr: usize = it.next().context("missing reducer count")?.parse()?;
    let mut reducers = Vec::with_capacity(nr);
    for _ in 0..nr {
        let tok = it.next().context("missing reducer entry")?;
        let (port, mb) = tok
            .split_once(':')
            .with_context(|| format!("reducer entry {tok:?} not port:mb"))?;
        let port: usize = port.parse()?;
        if port == 0 {
            bail!("ports are 1-based in trace files");
        }
        let mb: f64 = mb.parse()?;
        reducers.push((port - 1, mb * MB));
    }
    if mappers.is_empty() || reducers.is_empty() {
        bail!("coflow {external_id} has no mappers or no reducers");
    }
    // optional SLO column (module docs); other trailing tokens stay
    // tolerated as before for forward compatibility
    let mut deadline = None;
    if let Some(tok) = it.next() {
        if let Some(ms) = tok.strip_prefix("deadline:") {
            let ms: f64 = ms
                .parse()
                .with_context(|| format!("bad deadline entry {tok:?}"))?;
            if !ms.is_finite() || ms < 0.0 {
                bail!("deadline must be a non-negative millisecond count, got {tok:?}");
            }
            deadline = Some(ms / 1000.0);
        }
    }
    Ok(TraceRecord {
        external_id,
        arrival: arrival_ms / 1000.0,
        deadline,
        mappers,
        reducers,
    })
}

/// Render a trace back to the benchmark format.
pub fn render_trace(trace: &Trace) -> String {
    let mut out = String::new();
    out.push_str(&format!("{} {}\n", trace.num_ports, trace.coflows.len()));
    for c in &trace.coflows {
        // re-aggregate per-reducer bytes
        let mut reducers: Vec<(usize, f64)> = c.receivers.iter().map(|&p| (p, 0.0)).collect();
        for &fid in &c.flows {
            let f = &trace.flows[fid];
            if let Some(r) = reducers.iter_mut().find(|(p, _)| *p == f.dst) {
                r.1 += f.size;
            }
        }
        out.push_str(&format!(
            "{} {} {}",
            c.external_id,
            (c.arrival * 1000.0).round() as u64,
            c.senders.len()
        ));
        for &m in &c.senders {
            out.push_str(&format!(" {}", m + 1));
        }
        out.push_str(&format!(" {}", reducers.len()));
        for (p, bytes) in reducers {
            out.push_str(&format!(" {}:{}", p + 1, bytes / MB));
        }
        if let Some(d) = c.deadline {
            out.push_str(&format!(" deadline:{}", d * 1000.0));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "4 2\n\
        1 0 2 1 2 2 3:10 4:10\n\
        7 1500 1 1 1 3:5\n";

    #[test]
    fn parse_sample() {
        let t = parse_trace(SAMPLE).unwrap();
        assert_eq!(t.num_ports, 4);
        assert_eq!(t.coflows.len(), 2);
        assert_eq!(t.coflows[0].senders, vec![0, 1]);
        assert_eq!(t.coflows[0].receivers, vec![2, 3]);
        assert_eq!(t.coflows[1].arrival, 1.5);
        assert_eq!(t.coflows[1].external_id, 7);
        // 2 mappers × 10 MB reducer → 5 MB flows
        assert!((t.flows[0].size - 5.0 * MB).abs() < 1e-6);
    }

    #[test]
    fn roundtrip() {
        let t = parse_trace(SAMPLE).unwrap();
        let rendered = render_trace(&t);
        let t2 = parse_trace(&rendered).unwrap();
        assert_eq!(t.coflows.len(), t2.coflows.len());
        for (a, b) in t.coflows.iter().zip(t2.coflows.iter()) {
            assert_eq!(a.senders, b.senders);
            assert_eq!(a.receivers, b.receivers);
            assert!((a.arrival - b.arrival).abs() < 1e-3);
        }
        assert!((t.total_bytes() - t2.total_bytes()).abs() < 1.0);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(parse_trace("").is_err());
        assert!(parse_trace("x y\n").is_err());
    }

    #[test]
    fn rejects_out_of_range_port() {
        let bad = "2 1\n1 0 1 3 1 1:5\n";
        assert!(parse_trace(bad).is_err());
    }

    #[test]
    fn rejects_zero_port() {
        let bad = "2 1\n1 0 1 0 1 1:5\n";
        assert!(parse_trace(bad).is_err());
    }

    #[test]
    fn rejects_count_mismatch() {
        let bad = "4 3\n1 0 1 1 1 2:5\n";
        assert!(parse_trace(bad).is_err());
    }

    #[test]
    fn deadline_column_is_optional_per_line() {
        let text = "4 2\n\
            1 0 2 1 2 2 3:10 4:10 deadline:2500\n\
            7 1500 1 1 1 3:5\n";
        let t = parse_trace(text).unwrap();
        assert_eq!(t.coflows[0].deadline, Some(2.5));
        assert_eq!(t.coflows[1].deadline, None);
        // round-trips: the column is re-emitted only where present
        let rendered = render_trace(&t);
        assert!(rendered.lines().nth(1).unwrap().contains("deadline:2500"));
        assert!(!rendered.lines().nth(2).unwrap().contains("deadline"));
        let t2 = parse_trace(&rendered).unwrap();
        assert_eq!(t2.coflows[0].deadline, Some(2.5));
        assert_eq!(t2.coflows[1].deadline, None);
    }

    #[test]
    fn rejects_malformed_deadline() {
        assert!(parse_trace("2 1\n1 0 1 1 1 2:5 deadline:xyz\n").is_err());
        assert!(parse_trace("2 1\n1 0 1 1 1 2:5 deadline:-3\n").is_err());
    }
}
